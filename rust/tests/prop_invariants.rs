//! Cross-module property tests: coordinator routing/batching/state
//! invariants and algorithm-level laws that hold across random workloads.

use std::sync::Arc;
use std::time::Duration;

use sole::coordinator::{Backend, BatchPolicy, Batcher, Coordinator, OpBackend};
use sole::layernorm::AiLayerNorm;
use sole::ops::E2SoftmaxOp;
use sole::softmax::{E2Softmax, E2SoftmaxConfig};
use sole::util::proptest::{check, size};

fn softmax_backend(l: usize, buckets: Vec<usize>) -> Arc<OpBackend> {
    Arc::new(OpBackend::try_new(Arc::new(E2SoftmaxOp::try_new(l).unwrap()), buckets).unwrap())
}

// ---------------------------------------------------------------------------
// Batcher invariants
// ---------------------------------------------------------------------------

#[test]
fn batcher_bucket_always_covers_or_caps() {
    check("bucket-covers", 300, 1, |rng| {
        let mut buckets: Vec<usize> = (0..size(rng, 5)).map(|_| 1 << rng.range_i64(0, 6)).collect();
        buckets.push(1);
        let max_batch = rng.range_usize(1, 64);
        let b = Batcher::new(
            BatchPolicy { max_wait: Duration::from_millis(5), max_batch, ..BatchPolicy::default() },
            buckets.clone(),
        );
        let n = rng.range_usize(1, 128);
        let pick = b.pick_bucket(n);
        assert!(buckets.contains(&pick));
        // covering: the pick is >= n unless capped
        let cap = buckets.iter().filter(|&&x| x <= max_batch).max().copied()
            .unwrap_or(*buckets.iter().min().unwrap());
        assert!(pick >= n.min(cap));
    });
}

#[test]
fn batcher_dispatch_monotone_in_time_and_queue() {
    check("dispatch-monotone", 200, 2, |rng| {
        let b = Batcher::new(
            BatchPolicy {
                max_wait: Duration::from_millis(rng.range_i64(1, 50) as u64),
                max_batch: 16,
                ..BatchPolicy::default()
            },
            vec![1, 4, 8, 16],
        );
        let n = rng.range_usize(1, 32);
        let t = Duration::from_millis(rng.range_i64(0, 100) as u64);
        if b.should_dispatch(n, t) {
            // more queue or more waiting can never flip the decision off
            assert!(b.should_dispatch(n + 1, t));
            assert!(b.should_dispatch(n, t + Duration::from_millis(10)));
        }
    });
}

// ---------------------------------------------------------------------------
// Coordinator state: every submitted request is answered exactly once,
// outputs are routed to their own request (no cross-talk)
// ---------------------------------------------------------------------------

#[test]
fn coordinator_routes_outputs_to_correct_requests() {
    // Each request's row has a unique argmax position; E2Softmax preserves
    // the argmax (monotone), so response routing errors would be visible.
    let l = 64;
    let co = Coordinator::start(
        softmax_backend(l, vec![1, 4, 8]),
        BatchPolicy { max_wait: Duration::from_millis(3), max_batch: 8, ..BatchPolicy::default() },
        2,
    );
    let cl = co.client();
    let rxs: Vec<_> = (0..64)
        .map(|i| {
            let mut row = vec![0f32; l];
            row[i % l] = 8.0; // unique peak
            (i % l, cl.submit(row).unwrap())
        })
        .collect();
    for (peak, rx) in rxs {
        let r = rx.recv().unwrap();
        let am = r
            .output
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(am, peak, "response routed to wrong request");
    }
    assert_eq!(co.metrics.completed(), 64);
    co.shutdown();
}

#[test]
fn coordinator_conserves_requests_under_concurrency() {
    check("conserve-requests", 10, 3, |rng| {
        let l = 32;
        let workers = rng.range_usize(1, 4);
        let co = Coordinator::start(
            softmax_backend(l, vec![1, 2, 4, 8]),
            BatchPolicy {
                max_wait: Duration::from_millis(rng.range_i64(0, 4) as u64),
                max_batch: 8,
                ..BatchPolicy::default()
            },
            workers,
        );
        let cl = co.client();
        let n = rng.range_usize(1, 40);
        let rxs: Vec<_> = (0..n).map(|_| cl.submit(vec![0.1; l]).unwrap()).collect();
        let mut got = 0;
        for rx in rxs {
            if rx.recv().is_ok() {
                got += 1;
            }
        }
        assert_eq!(got, n);
        assert_eq!(co.metrics.completed() as usize, n);
        co.shutdown();
    });
}

#[test]
fn backend_padding_never_leaks_into_real_outputs() {
    // run bucket 8 with only 3 real rows; padded rows are zeros — the
    // per-row softmax of real rows must match bucket-1 runs exactly
    let l = 48;
    let be = softmax_backend(l, vec![1, 8]);
    let mut rows = vec![0f32; 8 * l];
    let mut rng = sole::util::rng::Rng::new(9);
    rng.fill_normal(&mut rows[..3 * l], 0.0, 2.0);
    let out8 = be.run_alloc(8, &rows).unwrap();
    for r in 0..3 {
        let single = be.run_alloc(1, &rows[r * l..(r + 1) * l]).unwrap();
        assert_eq!(&out8[r * l..(r + 1) * l], &single[..], "row {r}");
    }
}

// ---------------------------------------------------------------------------
// Algorithm laws across random inputs
// ---------------------------------------------------------------------------

#[test]
fn e2softmax_shift_invariance() {
    // softmax(x + c) == softmax(x): adding a constant code offset must not
    // change any output (the algorithm only sees q - max)
    check("e2-shift-invariant", 150, 5, |rng| {
        let n = size(rng, 128);
        let q: Vec<i64> = (0..n).map(|_| -rng.range_i64(0, 256)).collect();
        let c = rng.range_i64(-1000, 1000);
        let shifted: Vec<i64> = q.iter().map(|&v| v + c).collect();
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        assert_eq!(
            sm.forward_introspect(&q).out_q23,
            sm.forward_introspect(&shifted).out_q23
        );
    });
}

#[test]
fn e2softmax_uniform_rows_give_uniform_outputs() {
    check("e2-uniform-rows", 100, 6, |rng| {
        let n = size(rng, 256);
        let v = -rng.range_i64(0, 200);
        let q = vec![v; n];
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let out = sm.forward_introspect(&q).out_q23;
        assert!(out.windows(2).all(|w| w[0] == w[1]));
    });
}

#[test]
fn ailayernorm_gamma_scaling_law() {
    // scaling gamma by t scales (y - beta) by t exactly
    check("ai-gamma-scale", 100, 7, |rng| {
        let c = size(rng, 256).max(4);
        let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
        let g1 = vec![1f32; c];
        let g2 = vec![2f32; c];
        let beta = vec![0.5f32; c];
        let ln = AiLayerNorm::default();
        let y1 = ln.forward_introspect(&codes, &alpha, &g1, &beta).y;
        let y2 = ln.forward_introspect(&codes, &alpha, &g2, &beta).y;
        for (a, b) in y1.iter().zip(&y2) {
            assert!(((b - 0.5) - 2.0 * (a - 0.5)).abs() < 1e-9);
        }
    });
}

#[test]
fn ailayernorm_alpha_shift_consistency() {
    // alpha uniformly +1 doubles every D and sigma: output unchanged up to
    // the rsqrt LUT's bucket quantization of the 4x-scaled variance
    check("ai-alpha-shift", 100, 8, |rng| {
        let c = size(rng, 200).max(8);
        let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let a0 = vec![0u8; c];
        let a1 = vec![1u8; c];
        let g = vec![1f32; c];
        let b = vec![0f32; c];
        let ln = AiLayerNorm::default();
        let y0 = ln.forward_introspect(&codes, &a0, &g, &b).y;
        let y1 = ln.forward_introspect(&codes, &a1, &g, &b).y;
        for (p, q) in y0.iter().zip(&y1) {
            assert!((p - q).abs() < 0.02 * p.abs().max(1.0), "{p} vs {q}");
        }
    });
}
