//! Integration: the serving coordinator end to end — over the software
//! op-services (always run, pinned bit-exact against direct kernel
//! invocation) and over the real PJRT backend (bucketed deit_t fp32_sole
//! artifacts; skips without artifacts).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use sole::coordinator::{Backend, BatchPolicy, Coordinator, OpBackend, PjrtBackend};
use sole::layernorm::{config::DEFAULT_ZP, AiLayerNorm};
use sole::ops::{AiLayerNormOp, E2SoftmaxOp};
use sole::quant::{ptf_quantize_into, PtfCalib};
use sole::runtime::Engine;
use sole::softmax::{quantize_logits_into, E2Scratch, E2Softmax, E2SoftmaxConfig};
use sole::tensor::Bundle;
use sole::util::rng::Rng;

fn softmax_backend(l: usize, buckets: Vec<usize>) -> Arc<OpBackend> {
    Arc::new(OpBackend::try_new(Arc::new(E2SoftmaxOp::try_new(l).unwrap()), buckets).unwrap())
}

fn layernorm_backend(c: usize, buckets: Vec<usize>) -> Arc<OpBackend> {
    Arc::new(OpBackend::try_new(Arc::new(AiLayerNormOp::try_new(c).unwrap()), buckets).unwrap())
}

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

fn policy(max_wait_ms: u64, max_batch: usize) -> BatchPolicy {
    BatchPolicy {
        max_wait: Duration::from_millis(max_wait_ms),
        max_batch,
        ..BatchPolicy::default()
    }
}

// ---------------------------------------------------------------------------
// Software op-services through the coordinator (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn softmax_coordinator_matches_direct_kernel() {
    // responses routed through submit -> batcher -> worker arena must be
    // bit-identical to quantize + forward_row_f32 called directly
    let l = 96;
    let co = Coordinator::start(softmax_backend(l, vec![1, 4, 8]), policy(5, 8), 4);
    let cl = co.client();
    let mut rng = Rng::new(17);
    let rows: Vec<Vec<f32>> = (0..48)
        .map(|_| {
            let mut r = vec![0f32; l];
            rng.fill_normal(&mut r, 0.0, 2.0);
            r
        })
        .collect();
    let rxs: Vec<_> = rows.iter().map(|r| cl.submit(r.clone()).unwrap()).collect();
    let sm = E2Softmax::new(E2SoftmaxConfig::default());
    let mut codes = Vec::new();
    let mut scratch = E2Scratch::default();
    let mut want = vec![0f32; l];
    for (i, (row, rx)) in rows.iter().zip(rxs).enumerate() {
        let resp = rx.recv().unwrap();
        quantize_logits_into(row, sm.cfg().e, &mut codes);
        sm.forward_row_f32(&codes, &mut want, &mut scratch);
        assert_eq!(resp.output, want, "request {i}");
    }
    assert_eq!(co.metrics.completed(), 48);
    assert_eq!(co.metrics.errors(), 0);
    co.shutdown();
}

#[test]
fn layernorm_coordinator_matches_direct_kernel() {
    let c = 192;
    let cal = PtfCalib { alpha: vec![0u8; c], s: 1.0 / 32.0, zp: DEFAULT_ZP };
    let gamma = vec![1f32; c];
    let beta = vec![0f32; c];
    let op = AiLayerNormOp::with_calibration(c, cal.clone(), gamma.clone(), beta.clone()).unwrap();
    let be = Arc::new(OpBackend::try_new(Arc::new(op), vec![1, 4, 8]).unwrap());
    let co = Coordinator::start(be, policy(5, 8), 4);
    let cl = co.client();
    let mut rng = Rng::new(23);
    let rows: Vec<Vec<f32>> = (0..48)
        .map(|_| {
            let mut r = vec![0f32; c];
            rng.fill_normal(&mut r, 0.2, 1.5);
            r
        })
        .collect();
    let rxs: Vec<_> = rows.iter().map(|r| cl.submit(r.clone()).unwrap()).collect();
    let ln = AiLayerNorm::new(cal.zp);
    let mut codes = Vec::new();
    let mut want = vec![0f32; c];
    for (i, (row, rx)) in rows.iter().zip(rxs).enumerate() {
        let resp = rx.recv().unwrap();
        ptf_quantize_into(row, &cal, &mut codes);
        ln.forward_row_f32(&codes, &cal.alpha, &gamma, &beta, &mut want);
        assert_eq!(resp.output, want, "request {i}");
    }
    assert_eq!(co.metrics.completed(), 48);
    co.shutdown();
}

#[test]
fn both_operators_serve_through_the_same_batcher_shape() {
    // the coordinator is operator-agnostic: the same policy drives either
    // op-service and metrics stay coherent
    let sm: Arc<dyn Backend> = softmax_backend(64, vec![1, 4, 8]);
    let ln: Arc<dyn Backend> = layernorm_backend(64, vec![1, 4, 8]);
    for be in [sm, ln] {
        let co = Coordinator::start(be, policy(2, 8), 2);
        let cl = co.client();
        let rxs: Vec<_> = (0..40).map(|_| cl.submit(vec![0.3; 64]).unwrap()).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().output.len(), 64);
        }
        assert_eq!(co.metrics.completed(), 40);
        co.shutdown();
    }
}

#[test]
fn metrics_shards_merge_under_four_workers() {
    let co = Coordinator::start(softmax_backend(32, vec![1, 2, 4, 8]), policy(1, 8), 4);
    assert_eq!(co.metrics.shard_count(), 4);
    let cl = co.client();
    let rxs: Vec<_> = (0..200).map(|_| cl.submit(vec![0.1; 32]).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    assert_eq!(co.metrics.completed(), 200);
    // the merged view must account for every request recorded across shards
    let (p50, p99, mean) = co.metrics.total_latency();
    assert!(p50 > 0.0 && p99 >= p50 && mean > 0.0, "p50={p50} p99={p99} mean={mean}");
    assert!(co.metrics.mean_batch() >= 1.0);
    let s = co.metrics.summary();
    assert!(s.contains("completed=200"), "{s}");
    co.shutdown();
}

// ---------------------------------------------------------------------------
// PJRT backend (skips without artifacts)
// ---------------------------------------------------------------------------

#[test]
fn serves_images_through_bucketed_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let backend = Arc::new(PjrtBackend::from_family(&engine, "deit_t", "fp32_sole").unwrap());
    // serving buckets 1/4/8/16 plus the b64 eval artifact
    assert!(backend.buckets().contains(&1));
    assert!(backend.buckets().contains(&16));
    let item = backend.item_input_len();
    assert_eq!(item, 32 * 32);

    let co = Coordinator::start(backend, policy(10, 16), 1);
    let cl = co.client();

    let data = Bundle::load(&dir.join("data/cv_eval")).unwrap();
    let xs = data.get("x").unwrap().as_f32().unwrap();
    let y = data.get("y").unwrap().as_i32().unwrap();

    let n = 24;
    let rxs: Vec<_> = (0..n)
        .map(|i| cl.submit(xs[i * item..(i + 1) * item].to_vec()).unwrap())
        .collect();
    let mut correct = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap();
        assert_eq!(r.output.len(), 10);
        let pred = r
            .output
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == y[i] {
            correct += 1;
        }
    }
    // trained surrogate: well above chance through the full serving path
    assert!(correct as f64 / n as f64 > 0.6, "correct {correct}/{n}");
    assert_eq!(co.metrics.completed() as usize, n);
    assert_eq!(co.metrics.errors(), 0);
    // batching happened: mean batch should exceed 1 given a burst of 24
    assert!(co.metrics.mean_batch() >= 1.0);
    co.shutdown();
}

#[test]
fn single_request_uses_small_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let backend = Arc::new(PjrtBackend::from_family(&engine, "deit_t", "fp32_sole").unwrap());
    let item = backend.item_input_len();
    let co = Coordinator::start(backend, policy(1, 16), 1);
    let cl = co.client();
    let r = cl.infer(vec![0.25; item]).unwrap();
    assert_eq!(r.batch_size, 1);
    assert_eq!(r.output.len(), 10);
    co.shutdown();
}
