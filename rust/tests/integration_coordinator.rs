//! Integration: the serving coordinator over the real PJRT backend
//! (bucketed deit_t fp32_sole artifacts).  Skips without artifacts.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use sole::coordinator::{Backend, BatchPolicy, Coordinator, PjrtBackend};
use sole::runtime::Engine;
use sole::tensor::Bundle;

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn serves_images_through_bucketed_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let backend = Arc::new(PjrtBackend::from_family(&engine, "deit_t", "fp32_sole").unwrap());
    // serving buckets 1/4/8/16 plus the b64 eval artifact
    assert!(backend.buckets().contains(&1));
    assert!(backend.buckets().contains(&16));
    let item = backend.item_input_len();
    assert_eq!(item, 32 * 32);

    let co = Coordinator::start(
        backend,
        BatchPolicy { max_wait: Duration::from_millis(10), max_batch: 16 },
        1,
    );
    let cl = co.client();

    let data = Bundle::load(&dir.join("data/cv_eval")).unwrap();
    let xs = data.get("x").unwrap().as_f32().unwrap();
    let y = data.get("y").unwrap().as_i32().unwrap();

    let n = 24;
    let rxs: Vec<_> = (0..n)
        .map(|i| cl.submit(xs[i * item..(i + 1) * item].to_vec()).unwrap())
        .collect();
    let mut correct = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap();
        assert_eq!(r.output.len(), 10);
        let pred = r
            .output
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == y[i] {
            correct += 1;
        }
    }
    // trained surrogate: well above chance through the full serving path
    assert!(correct as f64 / n as f64 > 0.6, "correct {correct}/{n}");
    assert_eq!(co.metrics.completed() as usize, n);
    assert_eq!(co.metrics.errors(), 0);
    // batching happened: mean batch should exceed 1 given a burst of 24
    assert!(co.metrics.mean_batch() >= 1.0);
    co.shutdown();
}

#[test]
fn single_request_uses_small_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let backend = Arc::new(PjrtBackend::from_family(&engine, "deit_t", "fp32_sole").unwrap());
    let item = backend.item_input_len();
    let co = Coordinator::start(
        backend,
        BatchPolicy { max_wait: Duration::from_millis(1), max_batch: 16 },
        1,
    );
    let cl = co.client();
    let r = cl.infer(vec![0.25; item]).unwrap();
    assert_eq!(r.batch_size, 1);
    assert_eq!(r.output.len(), 10);
    co.shutdown();
}
