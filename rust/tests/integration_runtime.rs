//! Integration: PJRT runtime over the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skips politely otherwise).
//! Validates: HLO-text loading, compilation, weight/calib literal binding,
//! op graphs vs the Rust bit-exact models, model-variant coherence.

use std::path::PathBuf;

use sole::runtime::Engine;
use sole::softmax::{E2Softmax, E2SoftmaxConfig};
use sole::tensor::Bundle;
use sole::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn engine_opens_and_lists_models() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let models = engine.manifest.models();
    assert!(models.iter().any(|m| m == "deit_t"), "models: {models:?}");
    assert!(models.iter().any(|m| m.starts_with("bert_")));
}

#[test]
fn op_e2softmax_matches_rust_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let m = engine.load("op_e2softmax").unwrap();
    let (rows, length) = (m.meta.input_shape[0], m.meta.input_shape[1]);
    let mut rng = Rng::new(7);
    let mut x = vec![0f32; rows * length];
    rng.fill_normal(&mut x, 0.0, 2.0);
    let out = m.run_f32(&x).unwrap();
    assert_eq!(out.len(), rows * length);

    // the pallas kernel inside the HLO is the chunked-online algorithm;
    // our Rust model must agree bit-for-bit on the Q23 grid
    let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk: 32 });
    for r in 0..rows {
        let row = &x[r * length..(r + 1) * length];
        let rowmax = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let q: Vec<i64> = row
            .iter()
            .map(|&v| (((v - rowmax) as f64 * 16.0).round() as i64).clamp(-255, 0))
            .collect();
        let gold = sm.forward_introspect(&q);
        let gold_f = gold.out_f64();
        for (i, (&got, want)) in out[r * length..(r + 1) * length].iter().zip(&gold_f).enumerate() {
            assert_eq!(got as f64, *want, "row {r} col {i}");
        }
    }
}

#[test]
fn op_exact_softmax_is_ieee() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let m = engine.load("op_softmax_exact").unwrap();
    let (rows, length) = (m.meta.input_shape[0], m.meta.input_shape[1]);
    let mut rng = Rng::new(9);
    let mut x = vec![0f32; rows * length];
    rng.fill_normal(&mut x, 0.0, 1.5);
    let out = m.run_f32(&x).unwrap();
    for r in 0..rows {
        let row = &x[r * length..(r + 1) * length];
        let want = sole::softmax::e2::softmax_exact(row);
        for (got, w) in out[r * length..(r + 1) * length].iter().zip(&want) {
            assert!((*got as f64 - w).abs() < 1e-5);
        }
    }
}

#[test]
fn op_ailayernorm_runs_and_normalizes() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let m = engine.load("op_ailayernorm").unwrap();
    let (rows, c) = (m.meta.input_shape[0], m.meta.input_shape[1]);
    let mut rng = Rng::new(11);
    // u8 codes as f32
    let x: Vec<f32> = (0..rows * c).map(|_| rng.range_i64(0, 256) as f32).collect();
    let out = m.run_f32(&x).unwrap();
    assert_eq!(out.len(), rows * c);
    // alpha=0, gamma=1, beta=0 artifact: rows should be ~standardized
    for r in 0..rows {
        let row = &out[r * c..(r + 1) * c];
        let mean: f32 = row.iter().sum::<f32>() / c as f32;
        assert!(mean.abs() < 0.1, "row {r} mean {mean}");
    }
}

#[test]
fn model_artifact_end_to_end_accuracy_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let ids = engine.find("deit_t", "fp32");
    let id = ids.iter().find(|i| i.ends_with("b64")).expect("b64 artifact");
    let m = engine.load(id).unwrap();
    let data = Bundle::load(&dir.join("data/cv_eval")).unwrap();
    let x = data.get("x").unwrap();
    let y = data.get("y").unwrap().as_i32().unwrap();
    let xs = x.as_f32().unwrap();
    let item: usize = x.shape[1..].iter().product();
    let b = m.batch();
    let ncls = m.meta.output_shape[1];
    let mut correct = 0usize;
    let n_batches = 2; // smoke: 128 samples
    for bi in 0..n_batches {
        let xb = &xs[bi * b * item..(bi + 1) * b * item];
        let logits = m.run_f32(xb).unwrap();
        for i in 0..b {
            let row = &logits[i * ncls..(i + 1) * ncls];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred as i32 == y[bi * b + i] {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / (n_batches * b) as f64;
    assert!(acc > 0.5, "trained model should beat chance by far, got {acc}");
}

#[test]
fn sole_variant_tracks_fp32_predictions() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let fid = engine.find("deit_t", "fp32");
    let sid = engine.find("deit_t", "fp32_sole");
    let fid = fid.iter().find(|i| i.ends_with("b64")).unwrap();
    let sid = sid.iter().find(|i| i.ends_with("b64")).unwrap();
    let f = engine.load(fid).unwrap();
    let s = engine.load(sid).unwrap();
    let data = Bundle::load(&dir.join("data/cv_eval")).unwrap();
    let xs = data.get("x").unwrap().as_f32().unwrap();
    let item = 32 * 32;
    let b = f.batch();
    let xb = &xs[..b * item];
    let lf = f.run_f32(xb).unwrap();
    let ls = s.run_f32(xb).unwrap();
    let ncls = f.meta.output_shape[1];
    let am = |v: &[f32]| v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
    let mut agree = 0;
    for i in 0..b {
        if am(&lf[i * ncls..(i + 1) * ncls]) == am(&ls[i * ncls..(i + 1) * ncls]) {
            agree += 1;
        }
    }
    // SOLE is a drop-in approximation: predictions should mostly agree
    assert!(agree as f64 / b as f64 > 0.9, "agreement {agree}/{b}");
}

#[test]
fn bert_artifact_runs_on_tokens() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let ids = engine.find("bert_sst2", "int8_sole");
    let Some(id) = ids.first() else {
        eprintln!("skipping: no bert_sst2 artifacts");
        return;
    };
    let m = engine.load(id).unwrap();
    let data = Bundle::load(&dir.join("data/bert_sst2_eval")).unwrap();
    let x = data.get("x").unwrap().as_i32().unwrap();
    let b = m.batch();
    let seq = m.meta.input_shape[1];
    let out = m.run_i32(&x[..b * seq]).unwrap();
    assert_eq!(out.len(), b * m.meta.output_shape[1]);
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn unknown_artifact_errors() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    assert!(engine.load("no_such_artifact").is_err());
}
