//! Lane-parallel kernel conformance (DESIGN.md §3.4): every dispatch arm
//! this host can run must be BIT-exact against the scalar arm — the
//! scalar loops are the oracle, the AVX2 arms a pure re-expression.
//!
//! The sweeps cover the adversarial shapes for an 8-lane kernel: rows
//! shorter than a vector, one element either side of the lane width, the
//! paper's shapes (L = 49, 785) whose tails land mid-vector, narrow
//! chunks that disable the softmax SIMD arm entirely, NaN logits (code
//! -255 through the quantize path), hand-built off-grid codes that force
//! the stage-1 gather fallback, alpha >= 16 and out-of-u8 zero points
//! that force the layernorm eligibility gates scalar, and degenerate
//! 1x1 attention.  A seeded property sweep fuzzes the same invariant.

use sole::layernorm::{config::DEFAULT_ZP, AiLayerNorm};
use sole::ops::attention::AttnAvOp;
use sole::ops::{Op, OpRegistry, PortMut, PortRef, PortType};
use sole::simd::Dispatch;
use sole::softmax::config::ALDIV_C0;
use sole::softmax::e2::quantize_logits_batch_into;
use sole::softmax::{E2Scratch, E2Softmax, E2SoftmaxConfig, CODE_SIDE_LEN};
use sole::util::proptest;
use sole::util::rng::Rng;

/// The arms under test beyond the scalar oracle (empty on a host with no
/// SIMD support — the suite then only checks the reporting surface).
fn extra_arms() -> Vec<Dispatch> {
    Dispatch::available().into_iter().filter(|&d| d != Dispatch::Scalar).collect()
}

/// Assert two f32 buffers are bit-identical (plain `==` would let a
/// NaN-producing bug pass as "equal to itself differs").
fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: elem {i} ({g} vs {w})");
    }
}

/// Run every arm of E2Softmax over one packed batch and pin both entry
/// points (f32 and code twin) to the scalar arm bitwise.
fn check_e2(cfg: E2SoftmaxConfig, l: usize, q: &[i64], what: &str) {
    let rows = q.len() / l;
    let oracle = E2Softmax::with_dispatch(cfg, Dispatch::Scalar);
    let mut want = vec![0f32; q.len()];
    let mut want_codes = vec![0u8; q.len()];
    let mut want_side = vec![0f32; rows * CODE_SIDE_LEN];
    let mut s = E2Scratch::default();
    oracle.forward_batch_f32(q, l, &mut want, &mut s);
    oracle.forward_batch_codes(q, l, &mut want_codes, &mut want_side, &mut s);
    for arm in extra_arms() {
        let sm = E2Softmax::with_dispatch(cfg, arm);
        assert_eq!(sm.dispatch(), arm, "{what}: arm survives construction");
        let mut got = vec![0f32; q.len()];
        let mut got_codes = vec![0u8; q.len()];
        let mut got_side = vec![0f32; rows * CODE_SIDE_LEN];
        let mut s = E2Scratch::default();
        sm.forward_batch_f32(q, l, &mut got, &mut s);
        sm.forward_batch_codes(q, l, &mut got_codes, &mut got_side, &mut s);
        assert_bits(&got, &want, &format!("{what} [{arm}] f32"));
        assert_eq!(got_codes, want_codes, "{what} [{arm}] codes");
        assert_bits(&got_side, &want_side, &format!("{what} [{arm}] side"));
    }
}

/// Run every arm of AILayerNorm over one packed batch and pin the f32
/// and q8 batch entry points to the scalar arm bitwise.
fn check_ln(zp: i64, c: usize, codes: &[u8], alpha: &[u8], what: &str) {
    let rows = codes.len() / c;
    let mut rng = Rng::new(0xA11A);
    let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
    let beta: Vec<f32> = (0..c).map(|_| 0.2 * rng.normal() as f32).collect();
    let oracle = AiLayerNorm::with_dispatch(zp, Dispatch::Scalar);
    let mut want = vec![0f32; codes.len()];
    oracle.forward_batch_f32(codes, alpha, &gamma, &beta, &mut want);
    let mut row = Vec::new();
    let mut want_q8 = vec![0u8; codes.len()];
    let mut want_scale = vec![0f32; rows];
    oracle.forward_batch_q8(codes, alpha, &gamma, &beta, &mut row, &mut want_q8, &mut want_scale);
    for arm in extra_arms() {
        let ln = AiLayerNorm::with_dispatch(zp, arm);
        assert_eq!(ln.dispatch(), arm, "{what}: arm survives construction");
        let mut got = vec![0f32; codes.len()];
        ln.forward_batch_f32(codes, alpha, &gamma, &beta, &mut got);
        assert_bits(&got, &want, &format!("{what} [{arm}] f32"));
        let mut got_q8 = vec![0u8; codes.len()];
        let mut got_scale = vec![0f32; rows];
        ln.forward_batch_q8(codes, alpha, &gamma, &beta, &mut row, &mut got_q8, &mut got_scale);
        assert_eq!(got_q8, want_q8, "{what} [{arm}] q8 codes");
        assert_bits(&got_scale, &want_scale, &format!("{what} [{arm}] q8 scales"));
    }
}

#[test]
fn e2softmax_arms_bitwise_equal_across_shapes() {
    let mut rng = Rng::new(0x51D1);
    // lane_width +/- 1, sub-vector rows, the paper's shapes, a pow-2 point
    for &l in &[7usize, 8, 9, 31, 32, 33, 49, 128, 785, 1024] {
        for &chunk in &[1usize, 7, 32] {
            for &rows in &[0usize, 1, 16] {
                let q: Vec<i64> = (0..rows * l).map(|_| -rng.range_i64(0, 256)).collect();
                let cfg = E2SoftmaxConfig { chunk, ..E2SoftmaxConfig::default() };
                check_e2(cfg, l, &q, &format!("L={l} chunk={chunk} rows={rows}"));
            }
        }
    }
}

#[test]
fn e2softmax_arms_agree_on_nan_logits() {
    // NaN logits quantize to the bottom code -255 (treated as -inf);
    // the arms must agree on rows that mix NaN with real values and on
    // an all-NaN row (uniform floor).
    let l = 33;
    let mut rng = Rng::new(0xF100D);
    let mut x = vec![0f32; 3 * l];
    rng.fill_normal(&mut x, 0.0, 2.0);
    for i in 0..l {
        if i % 5 == 0 {
            x[i] = f32::NAN;
        }
        x[2 * l + i] = f32::NAN; // whole last row NaN
    }
    let cfg = E2SoftmaxConfig::default();
    let mut q = Vec::new();
    quantize_logits_batch_into(&x, l, cfg.e, &mut q);
    assert!(q.contains(&-255), "NaN must reach the bottom code");
    check_e2(cfg, l, &q, "nan logits");
}

#[test]
fn e2softmax_arms_agree_on_off_grid_codes() {
    // Hand-built codes below the 8-bit grid (unreachable through the
    // quantize path) force stage 1's gather fallback: any 8-group with a
    // delta > 255 must take the same scalar k_pow route in both arms.
    let l = 40;
    let mut q = vec![0i64; 2 * l];
    for (i, v) in q.iter_mut().enumerate() {
        *v = match i % 4 {
            0 => -(i as i64 % 200),
            1 => -1000 - i as i64, // off-grid
            2 => -(i as i64 % 30),
            _ => -100_000, // far off-grid
        };
    }
    for &chunk in &[8usize, 32] {
        let cfg = E2SoftmaxConfig { chunk, ..E2SoftmaxConfig::default() };
        check_e2(cfg, l, &q, &format!("off-grid chunk={chunk}"));
    }
}

#[test]
fn ailayernorm_arms_bitwise_equal_across_shapes() {
    let mut rng = Rng::new(0x1A7E);
    for &c in &[7usize, 8, 9, 49, 128, 768, 785, 1024] {
        for &rows in &[0usize, 1, 16] {
            let codes: Vec<u8> = (0..rows * c).map(|_| rng.range_i64(0, 256) as u8).collect();
            let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
            check_ln(DEFAULT_ZP, c, &codes, &alpha, &format!("C={c} rows={rows}"));
        }
    }
}

#[test]
fn ailayernorm_arms_agree_where_the_gates_fall_scalar() {
    // The SIMD arm gates itself off (whole-row or stage-by-stage) on
    // large alpha, saturating stage-2 numerators and out-of-u8 zero
    // points; the contract — arm equals scalar bitwise — must hold
    // regardless of which gate fired.
    let mut rng = Rng::new(0x6A7E);
    let c = 100;
    let codes: Vec<u8> = (0..4 * c).map(|_| rng.range_i64(0, 256) as u8).collect();
    // alpha up to 15: stats stay SIMD-eligible but the stage-2 i32
    // bound trips for large C; alpha >= 16 disables the SIMD arm whole
    for alpha_max in [16i64, 20, 32] {
        let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, alpha_max) as u8).collect();
        check_ln(DEFAULT_ZP, c, &codes, &alpha, &format!("alpha<{alpha_max}"));
    }
    // stage-2 saturation: wide C with the largest in-gate alpha
    let cw = 2048;
    let codes_w: Vec<u8> = (0..2 * cw).map(|_| rng.range_i64(0, 256) as u8).collect();
    let alpha_w: Vec<u8> = (0..cw).map(|_| rng.range_i64(12, 16) as u8).collect();
    check_ln(DEFAULT_ZP, cw, &codes_w, &alpha_w, "stage-2 saturation");
    // out-of-u8 zero points
    let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
    for zp in [-3i64, 300] {
        check_ln(zp, c, &codes, &alpha, &format!("zp={zp}"));
    }
}

#[test]
fn attn_av_arms_bitwise_equal_on_both_ports() {
    let mut rng = Rng::new(0xAA01);
    for &(l, d) in &[(49usize, 64usize), (128, 64), (8, 7), (16, 9), (1, 1)] {
        let b = 3usize;
        // f32 port: random probabilities and values through run_batch
        let oracle =
            AttnAvOp::with_dispatch(l, d, PortType::F32, Dispatch::Scalar).expect("scalar f32");
        let mut input = vec![0f32; b * oracle.item_len()];
        rng.fill_normal(&mut input, 0.0, 1.0);
        let mut want = vec![0f32; b * l * d];
        let mut s = oracle.make_scratch();
        oracle.run_batch(b, &input, &mut want, &mut s).expect("scalar run");
        for arm in extra_arms() {
            let av = AttnAvOp::with_dispatch(l, d, PortType::F32, arm).expect("arm f32");
            let mut got = vec![0f32; b * l * d];
            let mut s = av.make_scratch();
            av.run_batch(b, &input, &mut got, &mut s).expect("arm run");
            assert_bits(&got, &want, &format!("attn-av f32 L={l} D={d} [{arm}]"));
        }

        // code port: in-table codes plus valid per-row divider headers
        let oracle = AttnAvOp::with_dispatch(l, d, PortType::Log2Code5, Dispatch::Scalar)
            .expect("scalar codes");
        let codes: Vec<u8> = (0..b * l * l).map(|i| (i % 32) as u8).collect();
        let side_item = CODE_SIDE_LEN * l + l * d;
        let mut side = vec![0f32; b * side_item];
        for item in side.chunks_exact_mut(side_item) {
            let (headers, v) = item.split_at_mut(CODE_SIDE_LEN * l);
            for h in headers.chunks_exact_mut(CODE_SIDE_LEN) {
                h[0] = ALDIV_C0 as f32;
                h[1] = 6.0;
            }
            rng.fill_normal(v, 0.0, 1.0);
        }
        let mut want = vec![0f32; b * l * d];
        let mut s = oracle.make_scratch();
        oracle
            .run_batch_ports(
                b,
                PortRef::Log2Code5 { codes: &codes, side: &side },
                PortMut::F32(&mut want),
                &mut s,
            )
            .expect("scalar ports run");
        for arm in extra_arms() {
            let av = AttnAvOp::with_dispatch(l, d, PortType::Log2Code5, arm).expect("arm codes");
            let mut got = vec![0f32; b * l * d];
            let mut s = av.make_scratch();
            av.run_batch_ports(
                b,
                PortRef::Log2Code5 { codes: &codes, side: &side },
                PortMut::F32(&mut got),
                &mut s,
            )
            .expect("arm ports run");
            assert_bits(&got, &want, &format!("attn-av codes L={l} D={d} [{arm}]"));
        }
    }
}

#[test]
fn property_arms_match_scalar_on_random_shapes() {
    proptest::check("e2softmax-simd-eq", 40, 0x51D2, |rng| {
        let l = proptest::size(rng, 300);
        let chunk = proptest::size(rng, 64);
        let rows = proptest::size(rng, 4);
        let q: Vec<i64> = (0..rows * l).map(|_| -rng.range_i64(0, 256)).collect();
        let cfg = E2SoftmaxConfig { chunk, ..E2SoftmaxConfig::default() };
        check_e2(cfg, l, &q, &format!("prop L={l} chunk={chunk} rows={rows}"));
    });
    proptest::check("ailayernorm-simd-eq", 40, 0x1A7F, |rng| {
        let c = proptest::size(rng, 900);
        let rows = proptest::size(rng, 4);
        let codes: Vec<u8> = (0..rows * c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 16) as u8).collect();
        check_ln(DEFAULT_ZP, c, &codes, &alpha, &format!("prop C={c} rows={rows}"));
    });
}

#[test]
fn op_layer_reports_the_selected_arm() {
    let detected = Dispatch::detect();
    assert!(Dispatch::available().contains(&detected));
    let registry = OpRegistry::builtin();
    // the paper pair and the A·V stage carry a vectorized kernel and
    // report the host arm; the exact baselines have none
    for spec in ["e2softmax/L128", "ailayernorm/C768"] {
        let (_, op) = registry.build(spec).expect(spec);
        assert_eq!(op.dispatch(), Some(detected), "{spec}");
    }
    for spec in ["softmax-exact/L128", "layernorm-exact/C768"] {
        let (_, op) = registry.build(spec).expect(spec);
        assert_eq!(op.dispatch(), None, "{spec}");
    }
    // pipelines surface their first dispatched stage
    let (_, op) = registry.build("attention/L128xD64").expect("attention");
    assert_eq!(op.dispatch(), Some(detected), "fused attention pipeline");
    let (_, op) = registry.build("attention-exact/L128xD64").expect("attention-exact");
    assert_eq!(
        op.dispatch(),
        Some(detected),
        "exact attention still stages A·V through the dispatched kernel"
    );
}
