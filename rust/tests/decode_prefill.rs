//! The decode-vs-prefill oracle: a KV-cache decode session is the same
//! computation as a one-shot prefill, unrolled one token per request.
//!
//! E2Softmax quantizes every probability row against its own max and the
//! A·V kernels are row-length-parameterized, so decode step `t` must be
//! **bit-identical** to the last row of the fused `attention/L<t>xD<d>`
//! prefill pipeline over the same first `t` tokens — no tolerance.  The
//! suite pins that chain at sampled session lengths up to 160 tokens
//! (the acceptance bar is ≥ 128), pins the Scalar kernel arm against the
//! dispatched one, and then pins the *served* paths — `DecodeService`
//! directly and `RouterClient::infer_decode` through a `ServiceRouter` —
//! against the same oracle stream.  CI runs the suite forced-scalar and
//! with AVX2 enabled, so both arms cross the full chain.

use std::sync::Arc;

use sole::coordinator::{DecodeService, ServiceRouter};
use sole::ops::{DecodeAttnOp, Op, OpRegistry};
use sole::simd::Dispatch;
use sole::util::rng::Rng;

/// Session length: past the 128-token acceptance bar, with a tail that
/// is not a multiple of the 8-lane AVX2 width anywhere (160 = 8·20, but
/// the sampled prefill lengths include odd and prime `t`).
const CAP: usize = 160;
const D: usize = 16;

/// One deterministic token stream: `CAP` packed `[q | k | v]` steps.
fn token_stream(seed: u64) -> Vec<f32> {
    let mut v = vec![0f32; CAP * 3 * D];
    let mut rng = Rng::new(seed);
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

/// Run the whole stream through one decode session, one step per call,
/// returning the `CAP x D` context rows.
fn decode_outputs(op: &DecodeAttnOp, stream: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; CAP * D];
    let mut scratch = op.make_scratch();
    let mut state = op.make_state();
    for (item, o_row) in stream.chunks_exact(3 * D).zip(out.chunks_exact_mut(D)) {
        op.run_batch_stateful(1, item, o_row, &mut scratch, &mut state).unwrap();
    }
    out
}

/// The oracle: the last context row of the registered fused attention
/// pipeline over the first `t` tokens, with the step stream repacked
/// into the pipeline's planar `[Q | K | V]` item.
fn prefill_last_row(t: usize, stream: &[f32]) -> Vec<f32> {
    let registry = OpRegistry::builtin();
    let (_, attn) = registry.build(&format!("attention/L{t}xD{D}")).unwrap();
    let mut item = vec![0f32; 3 * t * D];
    for (i, step) in stream.chunks_exact(3 * D).take(t).enumerate() {
        item[i * D..(i + 1) * D].copy_from_slice(&step[..D]);
        item[(t + i) * D..(t + i + 1) * D].copy_from_slice(&step[D..2 * D]);
        item[(2 * t + i) * D..(2 * t + i + 1) * D].copy_from_slice(&step[2 * D..]);
    }
    let mut out = vec![0f32; t * D];
    let mut scratch = attn.make_scratch();
    attn.run_batch(1, &item, &mut out, &mut scratch).unwrap();
    out[(t - 1) * D..].to_vec()
}

#[test]
fn every_decode_step_is_bit_equal_to_its_prefill_row() {
    let stream = token_stream(0x0DEC);
    let op = DecodeAttnOp::try_new(CAP, D).unwrap();
    let decoded = decode_outputs(&op, &stream);
    // sampled prefill lengths: tiny, odd, prime, lane-aligned, and the
    // full 160-token session
    for &t in &[1usize, 2, 3, 17, 64, 128, CAP] {
        let want = prefill_last_row(t, &stream);
        assert_eq!(&decoded[(t - 1) * D..t * D], &want[..], "step {t}");
    }
}

#[test]
fn the_pinned_scalar_arm_matches_the_dispatched_arm() {
    // on an AVX2 host this crosses the kernel arms; forced-scalar (CI's
    // SOLE_FORCE_SCALAR leg) it degenerates to scalar == scalar
    let stream = token_stream(0x0DEC);
    let detected = DecodeAttnOp::try_new(CAP, D).unwrap();
    let scalar = DecodeAttnOp::with_dispatch(CAP, D, Dispatch::Scalar).unwrap();
    assert_eq!(decode_outputs(&detected, &stream), decode_outputs(&scalar, &stream));
}

#[test]
fn the_decode_service_and_router_reproduce_the_oracle() {
    // the same stream as the oracle test (same seed), served two ways:
    // straight through a DecodeService and through a ServiceRouter's
    // decode route — every step must be bit-equal to the local replay,
    // which the oracle test ties to prefill
    let stream = token_stream(0x0DEC);
    let op = DecodeAttnOp::try_new(CAP, D).unwrap();
    let want = decode_outputs(&op, &stream);

    let svc = DecodeService::start(Arc::new(DecodeAttnOp::try_new(CAP, D).unwrap()), 2).unwrap();
    let cl = svc.client();
    let name = format!("decode-attention/L{CAP}xD{D}");
    let registry = OpRegistry::builtin();
    let router =
        ServiceRouter::builder(2).decode_service(&registry, &name, 1).unwrap().start().unwrap();
    let rcl = router.client();
    for (step, (item, w)) in stream.chunks_exact(3 * D).zip(want.chunks_exact(D)).enumerate() {
        let got = cl.infer(9, item.to_vec()).unwrap();
        assert_eq!(got.output, w, "service step {}", step + 1);
        let got = rcl.infer_decode(&name, 4, item.to_vec()).unwrap();
        assert_eq!(got.output, w, "router step {}", step + 1);
    }
    assert_eq!(svc.sessions(), 1);
    assert_eq!(router.sessions(&name), Some(1));
    assert_eq!(svc.metrics.completed(), CAP as u64);
    svc.shutdown();
    router.shutdown();
}
