//! Integration: the whole build pipeline hangs together — manifest,
//! datasets, weight/calib bundles, python-side accuracy cross-check.

use std::path::PathBuf;

use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::json;

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_covers_all_tables() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    let models = engine.manifest.models();
    // Table I surrogates
    for m in ["deit_t", "deit_s", "swin_t"] {
        assert!(models.iter().any(|x| x == m), "missing {m}");
        for v in ["fp32", "fp32_sole", "int8", "int8_sole"] {
            assert!(!engine.find(m, v).is_empty(), "{m}/{v}");
        }
    }
    // Table II surrogates: all eight GLUE/SQuAD analogues
    for t in ["cola", "mrpc", "sst2", "qqp", "mnli", "qnli", "rte", "squad"] {
        assert!(models.iter().any(|x| x == &format!("bert_{t}")), "missing bert_{t}");
    }
    // serving buckets
    let sole_ids = engine.find("deit_t", "fp32_sole");
    for b in [1usize, 4, 8, 16] {
        assert!(sole_ids.iter().any(|i| i.ends_with(&format!("_b{b}"))), "bucket {b}");
    }
    // op graphs
    for op in ["op_e2softmax", "op_softmax_exact", "op_ailayernorm", "op_layernorm_exact"] {
        assert!(engine.manifest.get(op).is_some(), "{op}");
    }
}

#[test]
fn datasets_match_manifest_metadata() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    for ds in engine.manifest.datasets.values() {
        let b = Bundle::load(&dir.join(&ds.path)).unwrap();
        let x = b.get("x").unwrap();
        let y = b.get("y").unwrap();
        assert_eq!(x.shape[0], ds.n, "{}", ds.id);
        assert_eq!(y.shape[0], ds.n, "{}", ds.id);
        // labels are sane class ids
        let labels = y.as_i32().unwrap();
        assert!(labels.iter().all(|&v| (0..10).contains(&v)));
    }
}

#[test]
fn weight_bundles_complete_for_every_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(&dir).unwrap();
    for meta in engine.manifest.entries.values() {
        if meta.params.is_empty() {
            continue;
        }
        let weights = Bundle::load(&dir.join(meta.weights.as_ref().unwrap())).unwrap();
        let calib = meta.calib.as_ref().map(|c| Bundle::load(&dir.join(c)).unwrap());
        for p in &meta.params {
            if p.starts_with("calib/") {
                assert!(calib.as_ref().unwrap().get(p).is_ok(), "{}: {p}", meta.id);
            } else {
                assert!(weights.get(p).is_ok(), "{}: {p}", meta.id);
            }
        }
    }
}

#[test]
fn rust_eval_matches_python_accuracy_crosscheck() {
    // accuracy_py.json was computed with the jnp twins (use_pallas=False);
    // the artifacts contain the pallas kernels.  The two paths are the
    // same algorithm in different formulations: accuracies must agree
    // within a few percentage points on the same eval set.
    let Some(dir) = artifacts_dir() else { return };
    let Ok(text) = std::fs::read_to_string(dir.join("accuracy_py.json")) else { return };
    let py = json::parse(&text).unwrap();
    let engine = Engine::open(&dir).unwrap();
    let model = "deit_t";
    for variant in ["fp32", "fp32_sole"] {
        let rust_acc =
            sole::experiments::accuracy::eval_model(&engine, &dir, model, variant, 256).unwrap();
        let py_acc = py.get(model).unwrap().get_f64(variant).unwrap();
        assert!(
            (rust_acc - py_acc).abs() < 0.05,
            "{model}/{variant}: rust {rust_acc} vs python {py_acc}"
        );
    }
}
