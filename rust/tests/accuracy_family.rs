//! Family-wide softmax accuracy harness (ISSUE 10 tentpole): every
//! registered softmax-family op — exact baseline, the paper kernel, the
//! prior-work comparators, and the reduction-free streaming pair — runs
//! over the shared logit distributions of `util::dist`, and the measured
//! max-abs / mean-rel / normalization-defect numbers are asserted
//! against the per-op ceilings below (the same table `ACCURACY.md`
//! renders, pinned to the committed file by
//! `committed_ceilings_match_code`).  A regression past a ceiling fails
//! tier-1.
//!
//! Modes: the default quick mode keeps tier-1 fast; `SOLE_ACCURACY_FULL=1`
//! widens the length sweep and row count (the CI `accuracy` job runs full
//! on both dispatch arms — plain and `SOLE_FORCE_SCALAR=1`).
//! `SOLE_WRITE_ACCURACY=1` regenerates `ACCURACY.md` from the measured
//! rows.
//!
//! The streaming satellites live here too: the reduction-free set is
//! pinned to exactly {consmax, gn-softmax}, chunked streaming
//! (`begin_row` / `push_chunk` / `finish_row`, chunk sizes 1, 7, 64, L)
//! is bit-identical to `run_batch`, streamed rows exceed `item_len()`,
//! and an L=4096 row streamed over real TCP through the stream service
//! bit-equals the local whole-row batch.

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::time::Duration;

use sole::coordinator::ServiceRouter;
use sole::ops::{Op, OpRegistry};
use sole::server::{NetClient, Server, ServerConfig};
use sole::softmax::consmax::ConSmax;
use sole::util::dist::{LogitDist, DIST_SEED};
use sole::util::rng::Rng;

/// Quick-mode row lengths (tier-1 default).
const QUICK_LENS: [usize; 2] = [49, 128];
/// Full-mode row lengths (`SOLE_ACCURACY_FULL=1`): adds an odd
/// non-power-of-two and the paper's longest sequence.
const FULL_LENS: [usize; 4] = [49, 128, 785, 1024];
const QUICK_ROWS: usize = 16;
const FULL_ROWS: usize = 64;

/// Asserted error ceilings for one op; `None` = record-only (the metric
/// is measured and written to `ACCURACY.md` but not asserted).
struct Ceil {
    max_abs: Option<f64>,
    defect: Option<f64>,
}

/// The family under test with its ceilings.  Every ceiling is a proven
/// upper bound, not a measured-plus-margin guess, because the numbers
/// must hold on any host:
///
/// * `softmax-exact` computes in f64 and casts — only the f64→f32 cast
///   separates it from the reference, so ≤ 2⁻²⁴ relative per element.
/// * `e2softmax` saturates outputs at ~0.818 (Q.15 sum floor) and its
///   AL-division carries ≤ 25% per-element relative error, so a
///   near-delta row (heavy-tail leg) forces max-abs ≥ 0.18 and a row
///   defect up to ~0.25 + the saturated-tail truncation.
/// * `softermax` floor-quantizes the unnormalized 2^z intermediates at
///   2⁻⁸, which can understate the denominator on rows whose mass sits
///   just under the quantization step; outputs stay normalized by the
///   computed sum, so the defect is pure float rounding.
/// * `ibert-softmax` floors logits to its 1/16 input scale (≤ e^(1/16)−1
///   ≈ 6.4% relative on a numerator) on top of the i-exp polynomial;
///   normalized, so the defect is float rounding.
/// * `consmax` is unnormalized by design — γ matches the row sum only in
///   expectation, and on the heavy-tail leg E[e^x] diverges (Laplace
///   scale √2 > 1), so no vs-exact ceiling is sound; the kernel-fidelity
///   test below pins the datapath to its own closed form instead.
/// * `gn-softmax` has hard guarantees: y_i ≤ 2^−S ≤ 1 and Σy ≤ 1, so
///   both metrics are ≤ 1 by construction (and Σ ≤ 1 is asserted
///   strictly per row).
const FAMILY: [(&str, Ceil); 6] = [
    ("softmax-exact", Ceil { max_abs: Some(1e-5), defect: Some(1e-4) }),
    ("e2softmax", Ceil { max_abs: Some(0.3), defect: Some(0.4) }),
    ("softermax", Ceil { max_abs: Some(0.35), defect: Some(0.01) }),
    ("ibert-softmax", Ceil { max_abs: Some(0.2), defect: Some(0.05) }),
    ("consmax", Ceil { max_abs: None, defect: None }),
    ("gn-softmax", Ceil { max_abs: Some(1.0), defect: Some(1.0) }),
];

fn full_mode() -> bool {
    std::env::var("SOLE_ACCURACY_FULL").is_ok_and(|v| v == "1")
}

/// f64 exact softmax — the reference every op is measured against (the
/// same max-subtract / exp / normalize algorithm as `softmax-exact`, so
/// that op's error is exactly the output cast).
fn exact_ref(row: &[f32]) -> Vec<f64> {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let e: Vec<f64> = row.iter().map(|&v| ((v as f64) - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.iter().map(|v| v / s).collect()
}

/// Deterministic per-case seed, derived from the shared base so an
/// `ACCURACY.md` row names the exact input batch it measured.
fn case_seed(dist_idx: usize, l: usize) -> u64 {
    DIST_SEED ^ (((dist_idx as u64) + 1) << 32) ^ ((l as u64) << 8)
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// One measured `(op, dist, L)` case, as rendered into `ACCURACY.md`.
struct CaseRow {
    op: &'static str,
    dist: &'static str,
    l: usize,
    rows: usize,
    seed: u64,
    max_abs: f64,
    mean_rel: f64,
    defect: f64,
}

/// The asserted-ceilings table, rendered exactly as `ACCURACY.md`
/// commits it (pinned by `committed_ceilings_match_code`).
fn ceilings_markdown() -> String {
    let fmt = |v: Option<f64>| v.map_or("- (record-only)".to_string(), |x| x.to_string());
    let mut s = String::from("| op | max-abs vs exact | norm defect |\n|---|---|---|\n");
    for (fam, c) in &FAMILY {
        let _ = writeln!(s, "| {fam} | {} | {} |", fmt(c.max_abs), fmt(c.defect));
    }
    s
}

fn write_accuracy_md(rows: &[CaseRow]) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../ACCURACY.md");
    let mode = if full_mode() { "full" } else { "quick" };
    let mut s = String::from("# ACCURACY.md — softmax-family accuracy record\n\n");
    let _ = writeln!(
        s,
        "Status: generated ({mode} mode) by `tests/accuracy_family.rs` with \
         `SOLE_WRITE_ACCURACY=1`.  See EXPERIMENTS.md 'Accuracy harness' for the methodology; \
         inputs come from the shared `util::dist` generator (base seed `DIST_SEED = 0xD157`, \
         per-case seed recorded in each row), the reference is f64 exact softmax, and `mean-rel` \
         uses the denominator floor `max(p, 1e-6)`.  The defect column is the worst per-row \
         `|Σy − 1|`.  Ceilings below are asserted in the test; a regression fails tier-1.\n"
    );
    s.push_str("## Asserted ceilings\n\n");
    s.push_str(&ceilings_markdown());
    s.push_str("\n## Measured error\n\n");
    s.push_str("| op | dist | L | rows | seed | max-abs | mean-rel | defect |\n");
    s.push_str("|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {:#x} | {:.3e} | {:.3e} | {:.3e} |",
            r.op, r.dist, r.l, r.rows, r.seed, r.max_abs, r.mean_rel, r.defect
        );
    }
    std::fs::write(path, s).unwrap();
}

#[test]
fn family_error_ceilings_hold() {
    let registry = OpRegistry::builtin();
    let (lens, rows_per_case): (&[usize], usize) =
        if full_mode() { (&FULL_LENS, FULL_ROWS) } else { (&QUICK_LENS, QUICK_ROWS) };
    let mut table: Vec<CaseRow> = Vec::new();
    for (di, dist) in LogitDist::ALL.iter().enumerate() {
        for &l in lens {
            let seed = case_seed(di, l);
            let mut rng = Rng::new(seed);
            let mut input = vec![0f32; rows_per_case * l];
            dist.fill_batch(&mut rng, l, &mut input);
            // every op in a case sees the same batch, so rows compare
            let reference: Vec<f64> = input.chunks_exact(l).flat_map(exact_ref).collect();
            for (fam, ceil) in &FAMILY {
                let (_, op) = registry.build(&format!("{fam}/L{l}")).unwrap();
                let mut out = vec![0f32; rows_per_case * l];
                let mut scratch = op.make_scratch();
                op.run_batch(rows_per_case, &input, &mut out, &mut scratch).unwrap();
                let mut max_abs = 0f64;
                let mut rel_sum = 0f64;
                let mut defect = 0f64;
                for (r, row_out) in out.chunks_exact(l).enumerate() {
                    let mut sum = 0f64;
                    for (i, &y) in row_out.iter().enumerate() {
                        assert!(
                            y.is_finite() && y >= 0.0,
                            "{fam} {} L{l} row {r} elem {i}: {y}",
                            dist.name()
                        );
                        let y = y as f64;
                        let p = reference[r * l + i];
                        max_abs = max_abs.max((y - p).abs());
                        rel_sum += (y - p).abs() / p.max(1e-6);
                        sum += y;
                    }
                    if *fam == "gn-softmax" {
                        // the guaranteed-normalization property itself
                        assert!(
                            sum <= 1.0 + 1e-9,
                            "gn-softmax {} L{l} row {r}: sum {sum}",
                            dist.name()
                        );
                    }
                    defect = defect.max((sum - 1.0).abs());
                }
                let mean_rel = rel_sum / (rows_per_case * l) as f64;
                if let Some(c) = ceil.max_abs {
                    assert!(
                        max_abs <= c,
                        "{fam} {} L{l}: max_abs {max_abs} > ceiling {c}",
                        dist.name()
                    );
                }
                if let Some(c) = ceil.defect {
                    assert!(
                        defect <= c,
                        "{fam} {} L{l}: defect {defect} > ceiling {c}",
                        dist.name()
                    );
                }
                table.push(CaseRow {
                    op: *fam,
                    dist: dist.name(),
                    l,
                    rows: rows_per_case,
                    seed,
                    max_abs,
                    mean_rel,
                    defect,
                });
            }
        }
    }
    if std::env::var("SOLE_WRITE_ACCURACY").is_ok_and(|v| v == "1") {
        write_accuracy_md(&table);
    }
}

#[test]
fn committed_ceilings_match_code() {
    // ACCURACY.md is a committed artifact; its asserted-ceilings table
    // must track the in-code table line for line
    let md = std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../ACCURACY.md"))
        .expect("ACCURACY.md must be committed at the repo root");
    for line in ceilings_markdown().lines() {
        assert!(
            md.contains(line),
            "ACCURACY.md is missing ceilings line '{line}' — \
             regenerate with SOLE_WRITE_ACCURACY=1"
        );
    }
}

#[test]
fn consmax_kernel_tracks_ideal_closed_form() {
    // consmax has no vs-exact ceiling (unnormalized by design), so pin
    // the datapath to its own ideal e^(x−β)/γ instead: the Q8 base-2 LUT
    // floors the exponent code, losing at most 2^(1/256) − 1 ≈ 0.27%
    // relative per element, plus f32 grid rounding
    for l in [49usize, 128, 1024] {
        let sm = ConSmax::for_len(l);
        let cfg = sm.cfg();
        let mut rng = Rng::new(DIST_SEED ^ 0xC0);
        let mut x = vec![0f32; 8 * l];
        LogitDist::Gaussian.fill_row(&mut rng, &mut x);
        let mut y = vec![0f32; x.len()];
        sm.forward_chunk(&x, &mut y);
        let mut max_rel = 0f64;
        let mut rel_sum = 0f64;
        for (&xi, &yi) in x.iter().zip(&y) {
            let ideal = (xi as f64 - cfg.beta).exp() / cfg.gamma;
            let rel = (yi as f64 - ideal).abs() / ideal;
            max_rel = max_rel.max(rel);
            rel_sum += rel;
        }
        assert!(max_rel <= 0.02, "L{l}: max_rel {max_rel}");
        assert!(rel_sum / x.len() as f64 <= 0.01, "L{l}: mean_rel {}", rel_sum / x.len() as f64);
    }
}

#[test]
fn reduction_free_set_is_exactly_the_streaming_family() {
    // the stream service trusts `reduction_free()`; an op gaining the
    // flag without the streaming trio (or losing it) must be deliberate
    let registry = OpRegistry::builtin();
    let mut free = BTreeSet::new();
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap().to_string();
        let (_, op) = registry.build(&spec).unwrap();
        if op.reduction_free() {
            free.insert(name.to_string());
        }
    }
    let free: Vec<String> = free.into_iter().collect();
    assert_eq!(free, vec!["consmax".to_string(), "gn-softmax".to_string()]);
}

#[test]
fn chunked_streaming_is_bitwise_run_batch() {
    // online == offline: any chunking of a row through the streaming
    // trio concatenates to exactly the whole-row batch output (the
    // contract `Op::reduction_free` documents), on every dist leg
    let registry = OpRegistry::builtin();
    for fam in ["consmax", "gn-softmax"] {
        for &l in &[49usize, 128, 311] {
            let (_, op) = registry.build(&format!("{fam}/L{l}")).unwrap();
            for (di, dist) in LogitDist::ALL.iter().enumerate() {
                let mut rng = Rng::new(case_seed(di, l) ^ 0x57);
                let mut row = vec![0f32; l];
                dist.fill_row(&mut rng, &mut row);
                let mut whole = vec![0f32; l];
                let mut scratch = op.make_scratch();
                op.run_batch(1, &row, &mut whole, &mut scratch).unwrap();
                for &chunk in &[1usize, 7, 64, l] {
                    let mut state = op.begin_row();
                    let mut cat = Vec::with_capacity(l);
                    for piece in row.chunks(chunk) {
                        op.push_chunk(&mut state, piece, &mut cat).unwrap();
                    }
                    op.finish_row(&mut state, &mut cat).unwrap();
                    assert_eq!(
                        bits(&cat),
                        bits(&whole),
                        "{fam}/L{l} {} chunk {chunk}",
                        dist.name()
                    );
                }
            }
        }
    }
}

#[test]
fn streamed_rows_are_not_bounded_by_item_len() {
    // item_len() is the batch-path shape only: a streamed row three
    // times that length equals run_batch over three rows, elementwise
    let registry = OpRegistry::builtin();
    let l = 64;
    for fam in ["consmax", "gn-softmax"] {
        let (_, op) = registry.build(&format!("{fam}/L{l}")).unwrap();
        let mut rng = Rng::new(DIST_SEED ^ 0x3F);
        let mut long = vec![0f32; 3 * l];
        LogitDist::HeavyTail.fill_row(&mut rng, &mut long);
        let mut batch = vec![0f32; 3 * l];
        let mut scratch = op.make_scratch();
        op.run_batch(3, &long, &mut batch, &mut scratch).unwrap();
        let mut state = op.begin_row();
        let mut cat = Vec::new();
        for piece in long.chunks(40) {
            op.push_chunk(&mut state, piece, &mut cat).unwrap();
        }
        op.finish_row(&mut state, &mut cat).unwrap();
        assert_eq!(bits(&cat), bits(&batch), "{fam}");
    }
}

#[test]
fn reduction_bearing_ops_refuse_to_stream() {
    let registry = OpRegistry::builtin();
    let (_, op) = registry.build("e2softmax/L49").unwrap();
    assert!(!op.reduction_free());
    let mut state = op.begin_row();
    let err = op.push_chunk(&mut state, &[0.0], &mut Vec::new()).unwrap_err();
    assert!(err.to_string().contains("not reduction-free"), "{err:#}");
    let err = op.finish_row(&mut state, &mut Vec::new()).unwrap_err();
    assert!(err.to_string().contains("not reduction-free"), "{err:#}");
}

#[test]
fn streamed_l4096_row_over_tcp_is_bitwise_run_batch() {
    // the acceptance path: a long row chunk-streamed through the real
    // TCP front door bit-equals the local whole-row batch — sockets,
    // framing and the lane add no arithmetic — with the conservation
    // ledger and zero open rows checked after shutdown
    let registry = OpRegistry::builtin();
    let specs = ["consmax/L4096", "gn-softmax/L4096"];
    let mut builder = ServiceRouter::builder(2);
    for s in specs {
        builder = builder.stream_service(&registry, s, 1).unwrap();
    }
    let router = builder.start().unwrap();
    let server = Server::start(router, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let addr = server.addr().to_string();
    let mut cl = NetClient::connect(&addr, Duration::from_secs(10)).unwrap();
    for (i, spec) in specs.iter().enumerate() {
        let (_, op) = registry.build(spec).unwrap();
        let mut rng = Rng::new(DIST_SEED ^ ((i as u64) << 1) ^ 0x4096);
        let mut row = vec![0f32; 4096];
        LogitDist::Attention.fill_row(&mut rng, &mut row);
        let mut local = vec![0f32; 4096];
        let mut scratch = op.make_scratch();
        op.run_batch(1, &row, &mut local, &mut scratch).unwrap();
        let streamed = cl.stream_row(&format!("{spec}/stream"), i as u64 + 1, &row, 256).unwrap();
        assert_eq!(bits(&streamed), bits(&local), "{spec}");
    }
    let router = server.shutdown().unwrap();
    for spec in specs {
        let name = format!("{spec}/stream");
        let m = router.metrics(&name).unwrap();
        assert_eq!(m.errors(), 0, "{name}");
        assert_eq!(m.completed() + m.errors() + m.shed(), m.offered(), "{name}");
        assert_eq!(router.open_rows(&name), Some(0), "{name}");
    }
    router.shutdown();
}
