//! Shared conformance suite for the `Op` layer: every operator the
//! builtin `OpRegistry` can construct is held to the same contract —
//!
//! * bit-exact to its direct kernel (the registry path adds routing and
//!   scratch management, never arithmetic);
//! * correct at the edge shapes rows ∈ {1, cap};
//! * deterministic under scratch reuse (no state leaks between batches);
//! * spec round-trip: `parse(format(spec)) == spec`.
//!
//! A newly registered op joins every check automatically — only
//! `reference_row` needs a matching arm (and the suite fails loudly,
//! naming the op, if it is missing).

use sole::coordinator::{Backend, OpBackend};
use sole::layernorm::ai::layernorm_exact;
use sole::layernorm::baselines::ibert_layernorm;
use sole::layernorm::AiLayerNorm;
use sole::ops::ailayernorm::identity_calibration;
use sole::ops::baselines::{IBERT_LAYERNORM_SCALE, IBERT_SOFTMAX_SCALE, SOFTERMAX_FRAC_BITS};
use sole::ops::exact::EXACT_LN_EPS;
use sole::ops::{Op, OpRegistry, OpSpec};
use sole::quant::ptf_quantize_into;
use sole::softmax::baselines::{ibert_softmax, softermax};
use sole::softmax::e2::softmax_exact;
use sole::softmax::{quantize_logits_into, E2Scratch, E2Softmax, E2SoftmaxConfig};
use sole::util::rng::Rng;

/// The registered op's direct kernel, invoked without any Op machinery.
fn reference_row(op: &str, row: &[f32]) -> Vec<f32> {
    match op {
        "e2softmax" => {
            let sm = E2Softmax::new(E2SoftmaxConfig::default());
            let mut codes = Vec::new();
            quantize_logits_into(row, sm.cfg().e, &mut codes);
            let mut out = vec![0f32; row.len()];
            let mut scratch = E2Scratch::default();
            sm.forward_row_f32(&codes, &mut out, &mut scratch);
            out
        }
        "softmax-exact" => softmax_exact(row).into_iter().map(|v| v as f32).collect(),
        "softermax" => softermax(row, SOFTERMAX_FRAC_BITS).into_iter().map(|v| v as f32).collect(),
        "ibert-softmax" => {
            ibert_softmax(row, IBERT_SOFTMAX_SCALE).into_iter().map(|v| v as f32).collect()
        }
        "ailayernorm" => {
            let c = row.len();
            let cal = identity_calibration(c);
            let ln = AiLayerNorm { zp: cal.zp };
            let mut codes = Vec::new();
            ptf_quantize_into(row, &cal, &mut codes);
            let mut out = vec![0f32; c];
            ln.forward_row_f32(&codes, &cal.alpha, &vec![1f32; c], &vec![0f32; c], &mut out);
            out
        }
        "layernorm-exact" => {
            let c = row.len();
            layernorm_exact(row, &vec![1f32; c], &vec![0f32; c], EXACT_LN_EPS)
                .into_iter()
                .map(|v| v as f32)
                .collect()
        }
        "ibert-layernorm" => {
            let c = row.len();
            ibert_layernorm(row, &vec![1f32; c], &vec![0f32; c], IBERT_LAYERNORM_SCALE)
                .into_iter()
                .map(|v| v as f32)
                .collect()
        }
        other => panic!("op '{other}' has no reference kernel — extend the conformance suite"),
    }
}

/// Each op at its canonical length plus a small off-default length, so
/// the conformance sweep covers more than one shape per family.
fn conformance_specs(registry: &OpRegistry) -> Vec<OpSpec> {
    let mut specs = Vec::new();
    for name in registry.names() {
        let canon = registry.canonical_spec(name).unwrap();
        let small = OpSpec { len: 17, ..canon.clone() };
        specs.push(canon);
        specs.push(small);
    }
    specs
}

fn rows_for(rng: &mut Rng, len: usize, rows: usize) -> Vec<f32> {
    let mut v = vec![0f32; rows * len];
    rng.fill_normal(&mut v, 0.1, 1.5);
    v
}

const CAP: usize = 16;

#[test]
fn every_registered_op_is_bit_exact_to_its_direct_kernel() {
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C0F);
    for spec in conformance_specs(&registry) {
        let (parsed, op) = registry.build(&spec.to_string()).unwrap();
        assert_eq!(parsed, spec);
        let rows = 4;
        let input = rows_for(&mut rng, spec.len, rows);
        let mut out = vec![0f32; rows * spec.len];
        let mut scratch = op.make_scratch();
        op.run_batch(rows, &input, &mut out, &mut scratch).unwrap();
        for r in 0..rows {
            let row = &input[r * spec.len..(r + 1) * spec.len];
            let want = reference_row(&spec.op, row);
            assert_eq!(&out[r * spec.len..(r + 1) * spec.len], &want[..], "{spec} row {r}");
        }
    }
}

#[test]
fn every_registered_op_handles_edge_shapes_through_the_backend() {
    // rows = 1 and rows = cap through OpBackend, the exact wrapper the
    // router serves: bucket validation + scratch unwrap included
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C1F);
    for spec in conformance_specs(&registry) {
        let be =
            OpBackend::from_spec(&registry, &spec.to_string(), vec![1, CAP]).unwrap();
        for rows in [1usize, CAP] {
            let input = rows_for(&mut rng, spec.len, rows);
            let out = be.run_alloc(rows, &input).unwrap();
            for r in 0..rows {
                let row = &input[r * spec.len..(r + 1) * spec.len];
                let want = reference_row(&spec.op, row);
                let got = &out[r * spec.len..(r + 1) * spec.len];
                assert_eq!(got, &want[..], "{spec} rows={rows} r={r}");
            }
        }
    }
}

#[test]
fn every_registered_op_is_deterministic_under_scratch_reuse() {
    // one scratch arena across three batches: run A, run B, run A again —
    // the second A must be bit-identical to the first (warm buffers carry
    // no state between batches)
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C2F);
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        let rows = 8;
        let a = rows_for(&mut rng, spec.len, rows);
        let b = rows_for(&mut rng, spec.len, rows);
        let mut scratch = op.make_scratch();
        let mut out1 = vec![0f32; rows * spec.len];
        let mut out2 = vec![0f32; rows * spec.len];
        let mut out3 = vec![0f32; rows * spec.len];
        op.run_batch(rows, &a, &mut out1, &mut scratch).unwrap();
        op.run_batch(rows, &b, &mut out2, &mut scratch).unwrap();
        op.run_batch(rows, &a, &mut out3, &mut scratch).unwrap();
        assert_eq!(out1, out3, "{spec}: scratch reuse changed the result");
        assert_ne!(a, b, "{spec}: degenerate test inputs");
    }
}

#[test]
fn every_registered_op_round_trips_its_spec() {
    let registry = OpRegistry::builtin();
    for spec in conformance_specs(&registry) {
        let rendered = spec.to_string();
        assert_eq!(OpSpec::parse(&rendered).unwrap(), spec, "{rendered}");
        // and through the registry-validated path
        assert_eq!(registry.parse_spec(&rendered).unwrap(), spec, "{rendered}");
        // the constructed op renders the same canonical spec
        let (_, op) = registry.build(&rendered).unwrap();
        assert_eq!(op.spec(), spec, "{rendered}");
    }
}

#[test]
fn every_registered_op_rejects_malformed_batches() {
    let registry = OpRegistry::builtin();
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        let mut scratch = op.make_scratch();
        let mut out = vec![0f32; spec.len];
        // short input
        let short = vec![0f32; spec.len - 1];
        assert!(op.run_batch(1, &short, &mut out, &mut scratch).is_err(), "{spec}: short input");
        // mismatched output
        let input = vec![0f32; 2 * spec.len];
        assert!(op.run_batch(2, &input, &mut out, &mut scratch).is_err(), "{spec}: short out");
        // zero rows
        assert!(op.run_batch(0, &[], &mut [], &mut scratch).is_err(), "{spec}: zero rows");
    }
}
