//! Shared conformance suite for the `Op` layer: every operator the
//! builtin `OpRegistry` can construct is held to the same contract —
//!
//! * bit-exact to its direct kernel (the registry path adds routing and
//!   scratch management, never arithmetic) — for the attention pipelines
//!   the direct kernel is the stage math composed from the raw kernels;
//! * correct at the edge shapes rows ∈ {0, 1, cap} (rows = 0 is a no-op
//!   success, not an error);
//! * deterministic under scratch reuse (no state leaks between batches);
//! * spec round-trip: `parse(format(spec)) == spec`;
//! * f32 outer edges: whatever quantized ports a pipeline stages
//!   internally (DESIGN.md §3.3), its router-facing ports are f32, and
//!   the families with quantized boundaries are pinned by name.
//!
//! A newly registered op joins every check automatically — only
//! `reference_item` needs a matching arm (and the suite fails loudly,
//! naming the op, if it is missing).  The fused attention pipeline is
//! additionally pinned bit-exact against composing its stages as
//! *separate services* through `OpBackend` — the acceptance bar for the
//! shift-accumulate A·V path.
//!
//! Stateful families (`Op::stateful`) are exempt from the run-based
//! checks — their `run_batch` errors by design and `OpBackend` refuses
//! them — and are pinned by name plus sealed-entry-point checks in
//! `stateful_families_are_pinned_and_sealed` instead; their serving
//! contract lives in `tests/decode_prefill.rs`.

use sole::coordinator::{Backend, OpBackend};
use sole::layernorm::ai::layernorm_exact;
use sole::layernorm::baselines::ibert_layernorm;
use sole::layernorm::AiLayerNorm;
use sole::ops::ailayernorm::identity_calibration;
use sole::ops::attention::{AttnAvOp, AttnLogitsOp};
use sole::ops::baselines::{IBERT_LAYERNORM_SCALE, IBERT_SOFTMAX_SCALE, SOFTERMAX_FRAC_BITS};
use sole::ops::exact::EXACT_LN_EPS;
use sole::ops::{Op, OpRegistry, OpSpec, PortType};
use sole::quant::{ptf_quantize_into, q8_dequantize, q8_quantize_row_into};
use sole::softmax::baselines::{ibert_softmax, softermax};
use sole::softmax::e2::softmax_exact;
use sole::softmax::{
    quantize_logits_into, ConSmax, E2Scratch, E2Softmax, E2SoftmaxConfig, GnSoftmax,
};
use sole::util::rng::Rng;

/// One row through the direct kernel of a shape-preserving family.
fn reference_row(op: &str, row: &[f32]) -> Vec<f32> {
    match op {
        "e2softmax" => {
            let sm = E2Softmax::new(E2SoftmaxConfig::default());
            let mut codes = Vec::new();
            quantize_logits_into(row, sm.cfg().e, &mut codes);
            let mut out = vec![0f32; row.len()];
            let mut scratch = E2Scratch::default();
            sm.forward_row_f32(&codes, &mut out, &mut scratch);
            out
        }
        "softmax-exact" => softmax_exact(row).into_iter().map(|v| v as f32).collect(),
        "softermax" => softermax(row, SOFTERMAX_FRAC_BITS).into_iter().map(|v| v as f32).collect(),
        "ibert-softmax" => {
            ibert_softmax(row, IBERT_SOFTMAX_SCALE).into_iter().map(|v| v as f32).collect()
        }
        "consmax" => {
            let sm = ConSmax::for_len(row.len());
            let mut out = vec![0f32; row.len()];
            sm.forward_row_f32(row, &mut out);
            out
        }
        "gn-softmax" => {
            let sm = GnSoftmax::for_len(row.len());
            let mut out = vec![0f32; row.len()];
            sm.forward_row_f32(row, &mut out);
            out
        }
        "ailayernorm" => {
            let c = row.len();
            let cal = identity_calibration(c);
            let ln = AiLayerNorm::new(cal.zp);
            let mut codes = Vec::new();
            ptf_quantize_into(row, &cal, &mut codes);
            let mut out = vec![0f32; c];
            ln.forward_row_f32(&codes, &cal.alpha, &vec![1f32; c], &vec![0f32; c], &mut out);
            out
        }
        "ailayernorm-ptf" => {
            // the ailayernorm kernel, staged through the q8 row codec the
            // PtfU8 port stores — what the dequant adapter reconstructs
            let out = reference_row("ailayernorm", row);
            let mut codes = vec![0u8; out.len()];
            let scale = q8_quantize_row_into(&out, &mut codes);
            codes.iter().map(|&c| q8_dequantize(c, scale)).collect()
        }
        "layernorm-exact" => {
            let c = row.len();
            layernorm_exact(row, &vec![1f32; c], &vec![0f32; c], EXACT_LN_EPS)
                .into_iter()
                .map(|v| v as f32)
                .collect()
        }
        "ibert-layernorm" => {
            let c = row.len();
            ibert_layernorm(row, &vec![1f32; c], &vec![0f32; c], IBERT_LAYERNORM_SCALE)
                .into_iter()
                .map(|v| v as f32)
                .collect()
        }
        other => panic!("op '{other}' has no reference kernel — extend the conformance suite"),
    }
}

/// Attention stage math composed from direct kernels, mirroring the
/// pipeline's accumulation order exactly: QKᵀ-scaled logits, the named
/// softmax row kernel, then the j-then-d A·V accumulation.
fn attention_reference(l: usize, d: usize, item: &[f32], softmax_op: &str) -> Vec<f32> {
    let ld = l * d;
    let (q, rest) = item.split_at(ld);
    let (k, v) = rest.split_at(ld);
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = vec![0f32; l * l];
    for (qi, s_row) in q.chunks_exact(d).zip(s.chunks_exact_mut(l)) {
        for (kj, s_elem) in k.chunks_exact(d).zip(s_row.iter_mut()) {
            let mut acc = 0f32;
            for (&x, &y) in qi.iter().zip(kj) {
                acc += x * y;
            }
            *s_elem = acc * scale;
        }
    }
    let mut out = vec![0f32; l * d];
    for (s_row, o_row) in s.chunks_exact(l).zip(out.chunks_exact_mut(d)) {
        let p_row = reference_row(softmax_op, s_row);
        for (&pij, v_row) in p_row.iter().zip(v.chunks_exact(d)) {
            for (o, &vv) in o_row.iter_mut().zip(v_row) {
                *o += pij * vv;
            }
        }
    }
    out
}

/// Block stage math composed from direct kernels, mirroring the fused
/// pipeline's arithmetic exactly: per token row the ailayernorm kernel
/// staged through the q8 row codec, self-attention logits over the
/// normed rows (acc over d, then one scale multiply), the e2softmax row
/// kernel, the j-then-d A·V accumulation over the normed rows, one more
/// q8 round trip, then the residual add against the raw input.
fn block_reference(l: usize, d: usize, item: &[f32]) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut n = vec![0f32; l * d];
    for (x_row, n_row) in item.chunks_exact(d).zip(n.chunks_exact_mut(d)) {
        n_row.copy_from_slice(&reference_row("ailayernorm-ptf", x_row));
    }
    let mut s = vec![0f32; l * l];
    for (ni, s_row) in n.chunks_exact(d).zip(s.chunks_exact_mut(l)) {
        for (nj, s_elem) in n.chunks_exact(d).zip(s_row.iter_mut()) {
            let mut acc = 0f32;
            for (&x, &y) in ni.iter().zip(nj) {
                acc += x * y;
            }
            *s_elem = acc * scale;
        }
    }
    let mut out = vec![0f32; l * d];
    for ((s_row, o_row), x_row) in
        s.chunks_exact(l).zip(out.chunks_exact_mut(d)).zip(item.chunks_exact(d))
    {
        let p_row = reference_row("e2softmax", s_row);
        let mut acc = vec![0f32; d];
        for (&pij, n_row) in p_row.iter().zip(n.chunks_exact(d)) {
            for (o, &nv) in acc.iter_mut().zip(n_row) {
                *o += pij * nv;
            }
        }
        let mut codes = vec![0u8; d];
        let qs = q8_quantize_row_into(&acc, &mut codes);
        for ((y, &xv), &c) in o_row.iter_mut().zip(x_row).zip(&codes) {
            *y = xv + q8_dequantize(c, qs);
        }
    }
    out
}

/// One item through the direct kernel of any registered family.
fn reference_item(spec: &OpSpec, item: &[f32]) -> Vec<f32> {
    match spec.op.as_str() {
        "attention" => attention_reference(spec.len, spec.extra[0].1, item, "e2softmax"),
        "attention-exact" => {
            attention_reference(spec.len, spec.extra[0].1, item, "softmax-exact")
        }
        "block" => block_reference(spec.len, spec.extra[0].1, item),
        _ => reference_row(&spec.op, item),
    }
}

/// Each op at its canonical shape plus a small off-default primary
/// length, so the conformance sweep covers more than one shape per
/// family (pipelines keep their extra dimensions at the default).
fn conformance_specs(registry: &OpRegistry) -> Vec<OpSpec> {
    let mut specs = Vec::new();
    for name in registry.names() {
        let canon = registry.canonical_spec(name).unwrap();
        let small = OpSpec { len: 17, ..canon.clone() };
        specs.push(canon);
        specs.push(small);
    }
    specs
}

fn rows_for(rng: &mut Rng, len: usize, rows: usize) -> Vec<f32> {
    let mut v = vec![0f32; rows * len];
    rng.fill_normal(&mut v, 0.1, 1.5);
    v
}

const CAP: usize = 16;

#[test]
fn every_registered_op_is_bit_exact_to_its_direct_kernel() {
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C0F);
    for spec in conformance_specs(&registry) {
        let (parsed, op) = registry.build(&spec.to_string()).unwrap();
        assert_eq!(parsed, spec);
        if op.stateful() {
            continue; // sealed run_batch; pinned separately below
        }
        let (item_in, item_out) = (op.item_len(), op.out_len());
        let rows = 4;
        let input = rows_for(&mut rng, item_in, rows);
        let mut out = vec![0f32; rows * item_out];
        let mut scratch = op.make_scratch();
        op.run_batch(rows, &input, &mut out, &mut scratch).unwrap();
        for r in 0..rows {
            let item = &input[r * item_in..(r + 1) * item_in];
            let want = reference_item(&spec, item);
            assert_eq!(&out[r * item_out..(r + 1) * item_out], &want[..], "{spec} row {r}");
        }
    }
}

#[test]
fn every_registered_op_handles_edge_shapes_through_the_backend() {
    // rows = 1 and rows = cap through OpBackend, the exact wrapper the
    // router serves: bucket validation + scratch unwrap included
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C1F);
    for spec in conformance_specs(&registry) {
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        if op.stateful() {
            continue; // OpBackend refuses stateful ops by design
        }
        let be = OpBackend::from_spec(&registry, &spec.to_string(), vec![1, CAP]).unwrap();
        let (item_in, item_out) = (be.item_input_len(), be.item_output_len());
        for rows in [1usize, CAP] {
            let input = rows_for(&mut rng, item_in, rows);
            let out = be.run_alloc(rows, &input).unwrap();
            for r in 0..rows {
                let item = &input[r * item_in..(r + 1) * item_in];
                let want = reference_item(&spec, item);
                let got = &out[r * item_out..(r + 1) * item_out];
                assert_eq!(got, &want[..], "{spec} rows={rows} r={r}");
            }
        }
    }
}

#[test]
fn every_registered_op_is_deterministic_under_scratch_reuse() {
    // one scratch arena across three batches: run A, run B, run A again —
    // the second A must be bit-identical to the first (warm buffers carry
    // no state between batches)
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C2F);
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        if op.stateful() {
            continue; // per-session state is the contract, not a leak
        }
        let rows = 8;
        let a = rows_for(&mut rng, op.item_len(), rows);
        let b = rows_for(&mut rng, op.item_len(), rows);
        let mut scratch = op.make_scratch();
        let mut out1 = vec![0f32; rows * op.out_len()];
        let mut out2 = vec![0f32; rows * op.out_len()];
        let mut out3 = vec![0f32; rows * op.out_len()];
        op.run_batch(rows, &a, &mut out1, &mut scratch).unwrap();
        op.run_batch(rows, &b, &mut out2, &mut scratch).unwrap();
        op.run_batch(rows, &a, &mut out3, &mut scratch).unwrap();
        assert_eq!(out1, out3, "{spec}: scratch reuse changed the result");
        assert_ne!(a, b, "{spec}: degenerate test inputs");
    }
}

#[test]
fn every_registered_op_round_trips_its_spec() {
    let registry = OpRegistry::builtin();
    for spec in conformance_specs(&registry) {
        let rendered = spec.to_string();
        assert_eq!(OpSpec::parse(&rendered).unwrap(), spec, "{rendered}");
        // and through the registry-validated path
        assert_eq!(registry.parse_spec(&rendered).unwrap(), spec, "{rendered}");
        // the constructed op renders the same canonical spec
        let (_, op) = registry.build(&rendered).unwrap();
        assert_eq!(op.spec(), spec, "{rendered}");
    }
}

#[test]
fn every_registered_op_rejects_malformed_batches() {
    let registry = OpRegistry::builtin();
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        if op.stateful() {
            continue; // run_batch rejects everything, shapes included
        }
        let mut scratch = op.make_scratch();
        let mut out = vec![0f32; op.out_len()];
        // short input
        let short = vec![0f32; op.item_len() - 1];
        assert!(op.run_batch(1, &short, &mut out, &mut scratch).is_err(), "{spec}: short input");
        // mismatched output
        let input = vec![0f32; 2 * op.item_len()];
        assert!(op.run_batch(2, &input, &mut out, &mut scratch).is_err(), "{spec}: short out");
        // zero rows with non-empty buffers is still a shape error
        assert!(op.run_batch(0, &input, &mut out, &mut scratch).is_err(), "{spec}: 0 rows, data");
    }
}

#[test]
fn every_registered_op_treats_an_empty_batch_as_a_no_op_success() {
    // a drained queue can legitimately hand a worker zero rows; that is
    // not an error for any registered op
    let registry = OpRegistry::builtin();
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        if op.stateful() {
            continue; // the sealed run_batch rejects even empty batches
        }
        let mut scratch = op.make_scratch();
        op.run_batch(0, &[], &mut [], &mut scratch)
            .unwrap_or_else(|e| panic!("{spec}: empty batch should be a no-op: {e:#}"));
        // and the scratch arena stays usable afterwards
        let input = vec![0.25f32; op.item_len()];
        let mut out = vec![0f32; op.out_len()];
        op.run_batch(1, &input, &mut out, &mut scratch).unwrap();
    }
}

#[test]
fn quantized_boundaries_are_pinned_to_the_expected_families() {
    // the port system is opt-in per stage boundary: the families staging
    // a quantized format internally are pinned by name, and every
    // registered op keeps f32 router-facing edges regardless
    let registry = OpRegistry::builtin();
    let mut quantized = Vec::new();
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        assert_eq!((op.in_port(), op.out_port()), (PortType::F32, PortType::F32), "{spec}");
        if op.boundary_ports().iter().any(|&p| p != PortType::F32) {
            quantized.push(name.to_string());
        }
    }
    assert_eq!(quantized, vec!["ailayernorm-ptf", "attention", "block"]);
}

#[test]
fn stateful_families_are_pinned_and_sealed() {
    // statefulness is opt-in per family and pinned by name: a stateful
    // op's stateless entry points are sealed (run_batch errors, OpBackend
    // refuses it at construction), while run_batch_stateful works against
    // a fresh per-session state from make_state
    let registry = OpRegistry::builtin();
    let mut stateful = Vec::new();
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        if !op.stateful() {
            continue;
        }
        stateful.push(name.to_string());
        let mut scratch = op.make_scratch();
        let input = vec![0.25f32; op.item_len()];
        let mut out = vec![0f32; op.out_len()];
        let err = op.run_batch(1, &input, &mut out, &mut scratch).unwrap_err();
        assert!(format!("{err:#}").contains("run_batch_stateful"), "{spec}: {err:#}");
        let be = OpBackend::from_spec(&registry, &spec.to_string(), vec![1, CAP]);
        let err = format!("{:#}", be.unwrap_err());
        assert!(err.contains("stateful"), "{spec}: {err}");
        let mut state = op.make_state();
        op.run_batch_stateful(1, &input, &mut out, &mut scratch, &mut state)
            .unwrap_or_else(|e| panic!("{spec}: stateful path failed: {e:#}"));
    }
    assert_eq!(stateful, vec!["decode-attention"]);
}

#[test]
fn reduction_free_families_are_pinned_and_stream_bit_exact() {
    // reduction-freeness is opt-in per family and pinned by name: the
    // streaming trio works (and matches run_batch bitwise over a whole
    // row) exactly for the pinned families, and errors for every other
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C4F);
    let mut streaming = Vec::new();
    for name in registry.names() {
        let spec = registry.canonical_spec(name).unwrap();
        let (_, op) = registry.build(&spec.to_string()).unwrap();
        let mut state = op.begin_row();
        let mut cat = Vec::new();
        if !op.reduction_free() {
            let err = op.push_chunk(&mut state, &[0.25; 4], &mut cat).unwrap_err();
            assert!(format!("{err:#}").contains("not reduction-free"), "{spec}: {err:#}");
            continue;
        }
        streaming.push(name.to_string());
        let row = rows_for(&mut rng, op.item_len(), 1);
        let mut whole = vec![0f32; op.out_len()];
        let mut scratch = op.make_scratch();
        op.run_batch(1, &row, &mut whole, &mut scratch).unwrap();
        for piece in row.chunks(13) {
            op.push_chunk(&mut state, piece, &mut cat).unwrap();
        }
        op.finish_row(&mut state, &mut cat).unwrap();
        assert_eq!(cat, whole, "{spec}: streamed row diverges from run_batch");
    }
    assert_eq!(streaming, vec!["consmax", "gn-softmax"]);
}

#[test]
fn fused_attention_is_bit_exact_vs_separate_stage_services() {
    // THE acceptance pin of the fused path: the registered attention
    // pipeline (shift-accumulate A·V over packed log2 codes) must equal,
    // bit for bit, composing its stages as three separate OpBackend
    // services — logits, a plain e2softmax service over the L×L block,
    // and the f32 A·V matmul — exactly how a non-fused deployment would
    // chain them
    let registry = OpRegistry::builtin();
    let mut rng = Rng::new(0x0C3F);
    for &(l, d) in &[(16usize, 8usize), (128, 64)] {
        let fused =
            OpBackend::from_spec(&registry, &format!("attention/L{l}xD{d}"), vec![1, CAP])
                .unwrap();
        let logits = OpBackend::try_new(
            std::sync::Arc::new(AttnLogitsOp::try_new(l, d).unwrap()),
            vec![1, CAP],
        )
        .unwrap();
        let softmax =
            OpBackend::from_spec(&registry, &format!("e2softmax/L{l}"), vec![l]).unwrap();
        let av = OpBackend::try_new(
            std::sync::Arc::new(AttnAvOp::try_new(l, d).unwrap()),
            vec![1, CAP],
        )
        .unwrap();
        for rows in [1usize, CAP] {
            let input = rows_for(&mut rng, 3 * l * d, rows);
            let got = fused.run_alloc(rows, &input).unwrap();
            // stage 1: [Q|K|V] -> [S|V]
            let staged = logits.run_alloc(rows, &input).unwrap();
            // stage 2: e2softmax over each item's L×L logit block, served
            // as its own L-row service; V passes through untouched
            let area = l * l + l * d;
            let mut probs = staged.clone();
            for item in probs.chunks_exact_mut(area) {
                let p = softmax.run_alloc(l, &item[..l * l]).unwrap();
                item[..l * l].copy_from_slice(&p);
            }
            // stage 3: [P|V] -> O
            let want = av.run_alloc(rows, &probs).unwrap();
            assert_eq!(got, want, "L{l}xD{d} rows={rows}");
        }
    }
}

#[test]
fn attention_specs_reject_malformed_shapes() {
    let registry = OpRegistry::builtin();
    for bad in [
        "attention/L128",        // missing head dimension
        "attention/L128xC64",    // wrong letter
        "attention/D64xL128",    // wrong order
        "attention/L128xD0",     // zero length
        "attention/L128xD64xD2", // too many dimensions
        "attention/L128xd64",    // lowercase letter
        "e2softmax/L128xD64",    // extra dims on a 1-D family
    ] {
        let err = OpBackend::from_spec(&registry, bad, vec![1, 4]);
        assert!(err.is_err(), "'{bad}' should be rejected");
    }
    // the error names the expected signature
    let err = format!("{:#}", registry.parse_spec("attention/L128").unwrap_err());
    assert!(err.contains("L<len>xD<len>"), "{err}");
}
