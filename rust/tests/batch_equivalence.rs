//! Batch-vs-row-vs-introspect equivalence for the planar LUT-driven
//! kernels, across the edge shapes (B=1, L=1, uneven chunk tails, rows
//! past the unit's 1024-element buffer) plus a proptest sweep.
//!
//! Contract: `forward_batch_f32` is bit-identical to per-row
//! `forward_row_f32`; the E2Softmax f32 kernels are bit-exact to
//! `forward_introspect` on the Q23 grid; the AILayerNorm f32 kernels track
//! the f64 introspection within f32-rounding tolerance.

use sole::layernorm::AiLayerNorm;
use sole::quant::{ptf_quantize_batch_into, ptf_quantize_into, PtfCalib};
use sole::softmax::aldivision::q23_to_f64;
use sole::softmax::{
    quantize_logits_batch_into, quantize_logits_into, E2Scratch, E2Softmax, E2SoftmaxConfig,
};
use sole::util::proptest::{check, size};
use sole::util::rng::Rng;

fn codes(rng: &mut Rng, n: usize) -> Vec<i64> {
    (0..n).map(|_| -rng.range_i64(0, 256)).collect()
}

/// One full three-way check: batch == row (bitwise) == introspect (Q23).
fn assert_e2_equivalence(b: usize, l: usize, chunk: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let q = codes(&mut rng, b * l);
    let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk });
    let mut batch_out = vec![0f32; b * l];
    let mut scratch = E2Scratch::default();
    sm.forward_batch_f32(&q, l, &mut batch_out, &mut scratch);
    let mut row_out = vec![0f32; l];
    for r in 0..b {
        let row = &q[r * l..(r + 1) * l];
        sm.forward_row_f32(row, &mut row_out, &mut scratch);
        let gold = sm.forward_introspect(row);
        for i in 0..l {
            let bv = batch_out[r * l + i];
            assert_eq!(
                bv.to_bits(),
                row_out[i].to_bits(),
                "batch != row at b={b} l={l} chunk={chunk} r={r} i={i}"
            );
            assert_eq!(
                bv as f64,
                q23_to_f64(gold.out_q23[i]),
                "kernel != introspect at b={b} l={l} chunk={chunk} r={r} i={i}"
            );
        }
    }
}

#[test]
fn e2softmax_three_way_equivalence_edge_shapes() {
    for &(b, l, chunk) in &[
        (1usize, 1usize, 1usize), // minimal everything
        (1, 1, 32),               // single element, wide unit
        (4, 1, 32),               // batch of single-element rows
        (1, 49, 32),              // DeiT-T attention row, uneven tail (49 = 32 + 17)
        (3, 7, 7),                // slice == row
        (2, 31, 32),              // row shorter than one slice
        (8, 128, 32),             // bucketed serving shape
        (5, 300, 1),              // Algorithm 1 verbatim
        (2, 785, 32),             // ViT-B/8 attention row, uneven tail
        (2, 1024, 32),            // the unit's full buffer
        (1, 1025, 32),            // one past the buffer
        (1, 1500, 7),             // uneven everything
        (16, 33, 32),             // max bucket, tail of 1
    ] {
        assert_e2_equivalence(b, l, chunk, 0xA11CE + (b * 31 + l) as u64);
    }
}

#[test]
fn e2softmax_three_way_equivalence_sweep() {
    check("batch-e2-sweep", 40, 97, |rng| {
        let b = size(rng, 6);
        let l = size(rng, 200);
        let chunk = [1usize, 7, 32][rng.range_usize(0, 3)];
        assert_e2_equivalence(b, l, chunk, rng.range_i64(0, 1 << 30) as u64);
    });
}

#[test]
fn e2softmax_batch_through_quantization_matches_row_path() {
    // the full serving pipeline: packed f32 logits -> batch quantize ->
    // batch kernel must equal the per-row pipeline bit-for-bit
    let mut rng = Rng::new(0xF00D);
    let l = 96;
    let b = 7;
    let mut x = vec![0f32; b * l];
    rng.fill_normal(&mut x, 0.0, 2.0);
    x[2 * l + 5] = f32::NAN; // NaN guard must behave identically in both paths
    let sm = E2Softmax::new(E2SoftmaxConfig::default());
    let mut q_batch = Vec::new();
    quantize_logits_batch_into(&x, l, sm.cfg().e, &mut q_batch);
    let mut batch_out = vec![0f32; b * l];
    let mut scratch = E2Scratch::default();
    sm.forward_batch_f32(&q_batch, l, &mut batch_out, &mut scratch);
    let mut q_row = Vec::new();
    let mut row_out = vec![0f32; l];
    for r in 0..b {
        quantize_logits_into(&x[r * l..(r + 1) * l], sm.cfg().e, &mut q_row);
        assert_eq!(&q_batch[r * l..(r + 1) * l], &q_row[..], "codes row {r}");
        sm.forward_row_f32(&q_row, &mut row_out, &mut scratch);
        assert_eq!(&batch_out[r * l..(r + 1) * l], &row_out[..], "outputs row {r}");
    }
}

fn ln_params(rng: &mut Rng, c: usize) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
    let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 6) as u8).collect();
    let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.2 * rng.normal() as f32).collect();
    let beta: Vec<f32> = (0..c).map(|_| 0.3 * rng.normal() as f32).collect();
    (alpha, gamma, beta)
}

fn assert_ln_equivalence(b: usize, c: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..b * c).map(|_| rng.range_i64(0, 256) as u8).collect();
    let (alpha, gamma, beta) = ln_params(&mut rng, c);
    let ln = AiLayerNorm::default();
    let mut batch_out = vec![0f32; b * c];
    ln.forward_batch_f32(&codes, &alpha, &gamma, &beta, &mut batch_out);
    let mut row_out = vec![0f32; c];
    for r in 0..b {
        let row = &codes[r * c..(r + 1) * c];
        ln.forward_row_f32(row, &alpha, &gamma, &beta, &mut row_out);
        let gold = ln.forward_introspect(row, &alpha, &gamma, &beta);
        for i in 0..c {
            let bv = batch_out[r * c + i];
            assert_eq!(
                bv.to_bits(),
                row_out[i].to_bits(),
                "batch != row at b={b} c={c} r={r} i={i}"
            );
            let tol = 1e-4 * (1.0 + gold.y[i].abs());
            assert!(
                (bv as f64 - gold.y[i]).abs() < tol,
                "kernel != introspect at b={b} c={c} r={r} i={i}: {bv} vs {}",
                gold.y[i]
            );
        }
    }
}

#[test]
fn ailayernorm_three_way_equivalence_edge_shapes() {
    for &(b, c) in &[
        (1usize, 1usize), // single channel: var_num = 0 -> y = beta exactly
        (1, 2),
        (4, 1),
        (1, 192),  // DeiT-T
        (8, 384),  // Swin-T
        (16, 768), // BERT-base
        (2, 1023), // uneven large row
    ] {
        assert_ln_equivalence(b, c, 0xBEEF + (b * 37 + c) as u64);
    }
}

#[test]
fn ailayernorm_three_way_equivalence_sweep() {
    check("batch-ln-sweep", 40, 101, |rng| {
        let b = size(rng, 6);
        let c = size(rng, 300);
        assert_ln_equivalence(b, c, rng.range_i64(0, 1 << 30) as u64);
    });
}

#[test]
fn ailayernorm_batch_through_ptf_matches_row_path() {
    let mut rng = Rng::new(0xCAFE);
    let c = 64;
    let b = 5;
    let mut x = vec![0f32; b * c];
    rng.fill_normal(&mut x, 0.0, 2.0);
    let cal = PtfCalib {
        alpha: (0..c).map(|_| rng.range_i64(0, 4) as u8).collect(),
        s: 1.0 / 24.0,
        zp: 128,
    };
    let ln = AiLayerNorm::new(cal.zp);
    let gamma = vec![1f32; c];
    let beta = vec![0f32; c];
    let mut codes_batch = Vec::new();
    ptf_quantize_batch_into(&x, &cal, &mut codes_batch);
    let mut batch_out = vec![0f32; b * c];
    ln.forward_batch_f32(&codes_batch, &cal.alpha, &gamma, &beta, &mut batch_out);
    let mut codes_row = Vec::new();
    let mut row_out = vec![0f32; c];
    for r in 0..b {
        ptf_quantize_into(&x[r * c..(r + 1) * c], &cal, &mut codes_row);
        assert_eq!(&codes_batch[r * c..(r + 1) * c], &codes_row[..], "codes row {r}");
        ln.forward_row_f32(&codes_row, &cal.alpha, &gamma, &beta, &mut row_out);
        assert_eq!(&batch_out[r * c..(r + 1) * c], &row_out[..], "outputs row {r}");
    }
}
