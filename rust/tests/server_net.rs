//! Integration: the TCP front door end to end with real sockets —
//! loopback bit-exactness against direct op invocation, typed
//! rejections for malformed and mistargeted frames, load shedding with
//! the conservation ledger checked across the wire, the rebalancer
//! shifting a worker to the hot service under skewed traffic, decode
//! sessions (with explicit `end_session`) over TCP, chunked-infer
//! streaming (typed `StreamProtocol` violations, interleaved rows on
//! one connection, frame-cap overflow mid-stream with the row state
//! surviving reconnection), and graceful wire-initiated shutdown.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sole::coordinator::{Backend, BackendScratch, BatchPolicy, ServiceRouter};
use sole::ops::OpRegistry;
use sole::server::{
    wire, AdmissionConfig, ErrCode, NetClient, RebalanceConfig, Reply, Server, ServerConfig,
};
use sole::util::rng::Rng;

/// Echo after a fixed sleep: known capacity, so overload and queue
/// pressure are forced by construction, not by host speed.
struct SlowEcho {
    item: usize,
    delay: Duration,
    buckets: Vec<usize>,
}

impl Backend for SlowEcho {
    fn item_input_len(&self) -> usize {
        self.item
    }
    fn item_output_len(&self) -> usize {
        self.item
    }
    fn buckets(&self) -> &[usize] {
        &self.buckets
    }
    fn run(
        &self,
        _bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        _scratch: &mut BackendScratch,
    ) -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        out.copy_from_slice(inputs);
        Ok(())
    }
}

fn slow_echo(item: usize, delay_ms: u64) -> Arc<SlowEcho> {
    Arc::new(SlowEcho { item, delay: Duration::from_millis(delay_ms), buckets: vec![1] })
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn tcp_results_are_bit_exact_to_direct_invocation() {
    // the wire carries raw f32 bit patterns: a served response must be
    // bit-identical to running the same registry op directly — sockets,
    // framing and batching add no arithmetic
    let registry = OpRegistry::builtin();
    let specs = ["e2softmax/L49", "ailayernorm/C96", "attention/L64xD32"];
    let mut builder = ServiceRouter::builder(3).default_policy(BatchPolicy {
        max_wait: Duration::from_millis(1),
        max_batch: 8,
        queue_cap: None,
    });
    for s in specs {
        builder = builder.op_service(&registry, s, vec![1, 4, 8]).unwrap();
    }
    let router = builder.start().unwrap();
    let server = Server::start(router, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let addr = server.addr().to_string();

    let mut cl = NetClient::connect(&addr, Duration::from_secs(10)).unwrap();
    let mut rng = Rng::new(0xA11CE);
    for spec in specs {
        let (_, op) = registry.build(spec).unwrap();
        let mut scratch = op.make_scratch();
        for i in 0..6 {
            let mut row = vec![0f32; op.item_len()];
            rng.fill_normal(&mut row, 0.0, 1.5);
            let mut want = vec![0f32; op.out_len()];
            op.run_batch(1, &row, &mut want, &mut scratch).unwrap();
            match cl.infer(spec, &row).unwrap() {
                Reply::Output(r) => {
                    assert_eq!(bits(&r.output), bits(&want), "{spec} request {i}");
                    assert!(r.batch >= 1, "{spec}: batch size populated");
                }
                other => panic!("{spec} request {i}: unexpected {other:?}"),
            }
        }
    }
    drop(cl);
    let router = server.shutdown().unwrap();
    for spec in specs {
        let m = router.metrics(spec).unwrap();
        assert_eq!(m.completed(), 6, "{spec}");
        assert_eq!(m.errors(), 0, "{spec}");
    }
    router.shutdown();
}

#[test]
fn malformed_and_mistargeted_frames_get_typed_errors() {
    let registry = OpRegistry::builtin();
    let router = ServiceRouter::builder(1)
        .default_policy(BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_batch: 4,
            queue_cap: None,
        })
        .op_service(&registry, "e2softmax/L8", vec![1, 4])
        .unwrap()
        .start()
        .unwrap();
    let cfg = ServerConfig { max_frame: 4096, ..ServerConfig::default() };
    let server = Server::start(router, "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr();

    let read_resp = |sock: &mut TcpStream| -> wire::Resp {
        match wire::read_frame(sock, wire::MAX_FRAME).unwrap() {
            wire::FrameRead::Frame(b) => wire::decode_resp(&b).unwrap(),
            other => panic!("expected a response frame, got {other:?}"),
        }
    };

    // protocol-level garbage on a raw socket: typed Malformed, and the
    // connection survives to serve the next (valid) frame
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    wire::write_frame(&mut sock, &[0xEE]).unwrap(); // unknown message type
    match read_resp(&mut sock) {
        wire::Resp::Error(e) => assert_eq!(e.code, ErrCode::Malformed, "{e}"),
        other => panic!("unexpected {other:?}"),
    }
    wire::write_frame(&mut sock, &[1, 10, 0, b'a']).unwrap(); // truncated infer
    match read_resp(&mut sock) {
        wire::Resp::Error(e) => assert_eq!(e.code, ErrCode::Malformed, "{e}"),
        other => panic!("unexpected {other:?}"),
    }
    wire::write_frame(&mut sock, &wire::encode_msg(&wire::Msg::Status)).unwrap();
    assert!(
        matches!(read_resp(&mut sock), wire::Resp::Text(_)),
        "connection must survive typed rejections"
    );
    drop(sock);

    // mistargeted but well-formed requests: typed, specific codes
    let mut cl = NetClient::connect(&addr.to_string(), Duration::from_secs(10)).unwrap();
    match cl.infer("nope/L8", &[0.0; 8]).unwrap() {
        Reply::Rejected(e) => {
            assert_eq!(e.code, ErrCode::UnknownService, "{e}");
            assert!(e.msg.contains("e2softmax/L8"), "lists registered services: {e}");
        }
        other => panic!("unexpected {other:?}"),
    }
    match cl.infer("e2softmax/L8", &[0.0; 3]).unwrap() {
        Reply::Rejected(e) => assert_eq!(e.code, ErrCode::BadItemLen, "{e}"),
        other => panic!("unexpected {other:?}"),
    }
    // the connection still serves valid requests afterwards
    assert!(matches!(cl.infer("e2softmax/L8", &[0.5; 8]).unwrap(), Reply::Output(_)));
    drop(cl);

    // an oversized declared length: typed error, then the stream closes
    // (the unread body desynchronizes it)
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    sock.write_all(&8192u32.to_le_bytes()).unwrap();
    sock.flush().unwrap();
    match read_resp(&mut sock) {
        wire::Resp::Error(e) => assert_eq!(e.code, ErrCode::FrameTooLarge, "{e}"),
        other => panic!("unexpected {other:?}"),
    }
    assert!(
        matches!(wire::read_frame(&mut sock, wire::MAX_FRAME).unwrap(), wire::FrameRead::Eof),
        "connection must close after an oversized frame"
    );

    server.shutdown().unwrap().shutdown();
}

#[test]
fn overload_sheds_with_typed_errors_and_the_ledger_conserves() {
    // one worker at 2ms/row behind a queue of 2: eight blocking
    // connections offer ~8 concurrent requests, so most must come back
    // as typed Shed — and accepted + shed must equal offered exactly,
    // counted on both sides of the socket
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 10;
    let router = ServiceRouter::builder(1)
        .default_policy(BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_batch: 1,
            queue_cap: Some(2),
        })
        .service("slow", slow_echo(16, 2))
        .start()
        .unwrap();
    let cfg = ServerConfig {
        conn_threads: CLIENTS,
        pending_conns: CLIENTS,
        admission: AdmissionConfig::default(),
        rebalance: None,
        ..ServerConfig::default()
    };
    let server = Server::start(router, "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();

    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(900 + c as u64);
                let mut row = vec![0f32; 16];
                rng.fill_normal(&mut row, 0.0, 1.0);
                let mut cl = NetClient::connect(&addr, Duration::from_secs(30)).unwrap();
                let (mut done, mut shed) = (0u64, 0u64);
                for _ in 0..PER_CLIENT {
                    match cl.infer("slow", &row).unwrap() {
                        Reply::Output(r) => {
                            assert_eq!(bits(&r.output), bits(&row), "echo must be exact");
                            done += 1;
                        }
                        Reply::Rejected(e) => {
                            assert_eq!(e.code, ErrCode::Shed, "only sheds expected: {e}");
                            shed += 1;
                        }
                        Reply::Text(t) => panic!("unexpected text reply: {t}"),
                    }
                }
                (done, shed)
            })
        })
        .collect();
    let (mut completed, mut shed) = (0u64, 0u64);
    for h in handles {
        let (d, s) = h.join().unwrap();
        completed += d;
        shed += s;
    }
    let offered = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(completed + shed, offered, "every request got exactly one reply");
    assert!(shed > 0, "overload must actually shed");
    assert!(completed > 0, "overload must not starve everything");

    let router = server.shutdown().unwrap();
    let m = router.metrics("slow").unwrap();
    assert_eq!(m.offered(), offered, "wire offered matches the ledger");
    assert_eq!(m.completed(), completed, "wire completions match");
    assert_eq!(m.shed(), shed, "wire sheds match");
    assert_eq!(m.errors(), 0);
    assert_eq!(m.completed() + m.errors() + m.shed(), m.offered(), "conservation");
    router.shutdown();
}

#[test]
fn rebalancer_moves_a_worker_to_the_hot_service_under_skew() {
    // "slow" and a real op start at 2 workers each; sustained blocking
    // traffic on "slow" only must make the control plane move exactly
    // one worker (the donor floor keeps the cold service at 1), and the
    // cold service must keep serving bit-exact afterwards
    const CLIENTS: usize = 8;
    let registry = OpRegistry::builtin();
    let router = ServiceRouter::builder(4)
        .default_policy(BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_batch: 1,
            queue_cap: None,
        })
        .service("slow", slow_echo(32, 2))
        .op_service(&registry, "e2softmax/L49", vec![1, 4])
        .unwrap()
        .start()
        .unwrap();
    assert_eq!(router.workers("slow"), Some(2), "even split before traffic");
    assert_eq!(router.workers("e2softmax/L49"), Some(2));
    let cfg = ServerConfig {
        conn_threads: CLIENTS + 1,
        pending_conns: CLIENTS + 1,
        rebalance: Some(RebalanceConfig {
            interval: Duration::from_millis(50),
            min_gap: 1.0,
        }),
        ..ServerConfig::default()
    };
    let server = Server::start(router, "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();

    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(7000 + c as u64);
                let mut row = vec![0f32; 32];
                rng.fill_normal(&mut row, 0.0, 1.0);
                let mut cl = NetClient::connect(&addr, Duration::from_secs(30)).unwrap();
                while !stop.load(Ordering::SeqCst) {
                    match cl.infer("slow", &row).unwrap() {
                        Reply::Output(_) => {}
                        other => panic!("hot traffic must be served: {other:?}"),
                    }
                }
            })
        })
        .collect();

    // the acceptance clock: the move must happen within 5 seconds
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let hot = server.router().workers("slow").unwrap();
        if hot >= 3 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "rebalancer made no move in 5s (hot workers still {hot}, queue {:?})",
            server.router().queue_depth("slow")
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(server.router().workers("slow"), Some(3), "one worker moved to the hot pool");
    assert_eq!(
        server.router().workers("e2softmax/L49"),
        Some(1),
        "the donor stops at the one-worker floor"
    );
    stop.store(true, Ordering::SeqCst);
    for h in handles {
        h.join().unwrap();
    }

    // idle-service correctness is preserved after losing a worker
    let (_, op) = registry.build("e2softmax/L49").unwrap();
    let mut scratch = op.make_scratch();
    let mut cl = NetClient::connect(&addr, Duration::from_secs(10)).unwrap();
    let mut rng = Rng::new(0xC01D);
    for i in 0..4 {
        let mut row = vec![0f32; 49];
        rng.fill_normal(&mut row, 0.0, 2.0);
        let mut want = vec![0f32; 49];
        op.run_batch(1, &row, &mut want, &mut scratch).unwrap();
        match cl.infer("e2softmax/L49", &row).unwrap() {
            Reply::Output(r) => assert_eq!(bits(&r.output), bits(&want), "cold request {i}"),
            other => panic!("cold request {i}: unexpected {other:?}"),
        }
    }
    drop(cl);

    let router = server.shutdown().unwrap();
    for name in ["slow", "e2softmax/L49"] {
        let m = router.metrics(name).unwrap();
        assert_eq!(m.errors(), 0, "{name}");
        assert_eq!(m.completed() + m.shed(), m.offered(), "{name}: conservation");
    }
    router.shutdown();
}

#[test]
fn decode_sessions_over_tcp_with_explicit_end_session() {
    let registry = OpRegistry::builtin();
    let spec = "decode-attention/L8xD4";
    let router = ServiceRouter::builder(2)
        .decode_service(&registry, spec, 1)
        .unwrap()
        .start()
        .unwrap();
    let server = Server::start(router, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let addr = server.addr().to_string();
    let mut cl = NetClient::connect(&addr, Duration::from_secs(10)).unwrap();

    let (_, op) = registry.build(spec).unwrap();
    let d = 4usize;
    let steps = 3usize;
    let mut rng = Rng::new(0xDEC0);
    // pre-generate every step so the local replay sees identical inputs
    let rows: Vec<Vec<Vec<f32>>> = (0..2)
        .map(|_| {
            (0..steps)
                .map(|_| {
                    let mut item = vec![0f32; 3 * d];
                    rng.fill_normal(&mut item, 0.0, 1.0);
                    item
                })
                .collect()
        })
        .collect();

    // interleave two sessions; each reply must match a local stateful
    // replay of that session bit-for-bit
    let mut states: Vec<_> = (0..2).map(|_| op.make_state()).collect();
    let mut scratch = op.make_scratch();
    for step in 0..steps {
        for sid in 0..2u64 {
            let item = &rows[sid as usize][step];
            let mut want = vec![0f32; d];
            op.run_batch_stateful(1, item, &mut want, &mut scratch, &mut states[sid as usize])
                .unwrap();
            match cl.infer_decode(spec, sid, item).unwrap() {
                Reply::Output(r) => {
                    assert_eq!(bits(&r.output), bits(&want), "session {sid} step {step}")
                }
                other => panic!("session {sid} step {step}: unexpected {other:?}"),
            }
        }
    }
    assert_eq!(server.router().live_sessions(spec), Some(2));

    // ending a session frees its server-side state...
    match cl.end_session(spec, 0).unwrap() {
        Reply::Output(r) => assert!(r.output.is_empty(), "end_session acks with no payload"),
        other => panic!("end_session: unexpected {other:?}"),
    }
    assert_eq!(server.router().live_sessions(spec), Some(1));
    // ...and an unknown decode service is a typed rejection
    match cl.end_session("nope", 0).unwrap() {
        Reply::Rejected(e) => assert_eq!(e.code, ErrCode::UnknownService, "{e}"),
        other => panic!("unexpected {other:?}"),
    }

    // a reused id is a fresh session: its first step equals a fresh
    // local replay at step 0, not a continuation
    let mut fresh = op.make_state();
    let item = &rows[0][0];
    let mut want = vec![0f32; d];
    op.run_batch_stateful(1, item, &mut want, &mut scratch, &mut fresh).unwrap();
    match cl.infer_decode(spec, 0, item).unwrap() {
        Reply::Output(r) => assert_eq!(bits(&r.output), bits(&want), "reused id restarts at 0"),
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(server.router().live_sessions(spec), Some(2));

    drop(cl);
    let router = server.shutdown().unwrap();
    let m = router.metrics(spec).unwrap();
    assert_eq!(m.errors(), 0);
    router.shutdown();
}

#[test]
fn stream_chunked_infer_is_typed_isolated_and_survives_reconnects() {
    // the chunked-infer path end to end: typed StreamProtocol rejections
    // that leave the connection AND the row-id space serving, rows
    // interleaved on one connection staying bit-exact, and a frame-cap
    // overflow mid-stream closing only the connection — the row's
    // server-side state survives for a reconnecting client to finish
    let registry = OpRegistry::builtin();
    let spec = "consmax/L32";
    let service = "consmax/L32/stream";
    let router = ServiceRouter::builder(2).stream_service(&registry, spec, 1).unwrap();
    let router = router.start().unwrap();
    let cfg = ServerConfig { max_frame: 4096, ..ServerConfig::default() };
    let server = Server::start(router, "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();
    let mut cl = NetClient::connect(&addr, Duration::from_secs(10)).unwrap();

    // an unknown stream service is a typed rejection listing what exists
    match cl.stream_chunk("nope/stream", 1, true, false, &[0.5]).unwrap() {
        Reply::Rejected(e) => {
            assert_eq!(e.code, ErrCode::UnknownService, "{e}");
            assert!(e.msg.contains(service), "lists stream services: {e}");
        }
        other => panic!("unexpected {other:?}"),
    }
    // a zero-length chunk is a violation and does NOT open the row
    match cl.stream_chunk(service, 5, true, false, &[]).unwrap() {
        Reply::Rejected(e) => {
            assert_eq!(e.code, ErrCode::StreamProtocol, "{e}");
            assert!(e.msg.contains("at least one element"), "{e}");
        }
        other => panic!("unexpected {other:?}"),
    }
    // a chunk after finish targets a closed row: typed, not fatal
    assert!(matches!(
        cl.stream_chunk(service, 1, true, true, &[0.5, -1.0, 2.0, 0.0]).unwrap(),
        Reply::Output(_)
    ));
    match cl.stream_chunk(service, 1, false, false, &[0.1]).unwrap() {
        Reply::Rejected(e) => {
            assert_eq!(e.code, ErrCode::StreamProtocol, "{e}");
            assert!(e.msg.contains("not open"), "chunk after finish: {e}");
        }
        other => panic!("unexpected {other:?}"),
    }
    // a chunk for a row that was never opened
    match cl.stream_chunk(service, 7, false, false, &[0.2]).unwrap() {
        Reply::Rejected(e) => assert_eq!(e.code, ErrCode::StreamProtocol, "{e}"),
        other => panic!("unexpected {other:?}"),
    }
    // re-beginning an open row
    assert!(matches!(cl.stream_chunk(service, 9, true, false, &[0.3]).unwrap(), Reply::Output(_)));
    match cl.stream_chunk(service, 9, true, false, &[0.4]).unwrap() {
        Reply::Rejected(e) => {
            assert_eq!(e.code, ErrCode::StreamProtocol, "{e}");
            assert!(e.msg.contains("already open"), "{e}");
        }
        other => panic!("unexpected {other:?}"),
    }
    // ...and the same row still finishes normally afterwards
    assert!(matches!(cl.stream_chunk(service, 9, false, true, &[0.5]).unwrap(), Reply::Output(_)));

    // two rows interleaved on ONE connection stay isolated: each row's
    // concatenated outputs are bit-identical to a whole-row run_batch
    let (_, op) = registry.build(spec).unwrap();
    let mut scratch = op.make_scratch();
    let mut rng = Rng::new(0x57A3);
    let rows: Vec<Vec<f32>> = (0..2)
        .map(|_| {
            let mut row = vec![0f32; op.item_len()];
            rng.fill_normal(&mut row, 0.0, 2.0);
            row
        })
        .collect();
    let mut got = vec![Vec::new(), Vec::new()];
    let pieces = [(0usize, 12usize), (12, 12), (24, 8)];
    for (i, &(start, n)) in pieces.iter().enumerate() {
        for (r, row) in rows.iter().enumerate() {
            let begin = i == 0;
            let finish = i == pieces.len() - 1;
            let id = 11 + r as u64;
            match cl.stream_chunk(service, id, begin, finish, &row[start..start + n]).unwrap() {
                Reply::Output(resp) => got[r].extend_from_slice(&resp.output),
                other => panic!("row {id} piece {i}: unexpected {other:?}"),
            }
        }
    }
    for (r, row) in rows.iter().enumerate() {
        let mut want = vec![0f32; op.out_len()];
        op.run_batch(1, row, &mut want, &mut scratch).unwrap();
        assert_eq!(bits(&got[r]), bits(&want), "interleaved row {r} is bit-exact");
    }

    // frame-cap overflow mid-stream: the connection dies with a typed
    // error, but the open row's state lives in the service — a new
    // connection finishes it and the result is still bit-exact
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let long_row = &rows[0];
    let open = wire::Msg::Stream {
        service: service.to_string(),
        row: 21,
        flags: sole::server::STREAM_BEGIN,
        chunk: long_row[..16].to_vec(),
    };
    wire::write_frame(&mut raw, &wire::encode_msg(&open)).unwrap();
    let first = match wire::read_frame(&mut raw, wire::MAX_FRAME).unwrap() {
        wire::FrameRead::Frame(b) => match wire::decode_resp(&b).unwrap() {
            wire::Resp::Output { output, .. } => output,
            other => panic!("unexpected {other:?}"),
        },
        other => panic!("expected a frame, got {other:?}"),
    };
    raw.write_all(&8192u32.to_le_bytes()).unwrap(); // declares > max_frame
    raw.flush().unwrap();
    match wire::read_frame(&mut raw, wire::MAX_FRAME).unwrap() {
        wire::FrameRead::Frame(b) => match wire::decode_resp(&b).unwrap() {
            wire::Resp::Error(e) => assert_eq!(e.code, ErrCode::FrameTooLarge, "{e}"),
            other => panic!("unexpected {other:?}"),
        },
        other => panic!("expected a frame, got {other:?}"),
    }
    assert!(
        matches!(wire::read_frame(&mut raw, wire::MAX_FRAME).unwrap(), wire::FrameRead::Eof),
        "connection must close after an oversized frame"
    );
    let mut cl2 = NetClient::connect(&addr, Duration::from_secs(10)).unwrap();
    let tail = match cl2.stream_chunk(service, 21, false, true, &long_row[16..]).unwrap() {
        Reply::Output(r) => r.output,
        other => panic!("finishing after reconnect: unexpected {other:?}"),
    };
    let mut full = first;
    full.extend_from_slice(&tail);
    let mut want = vec![0f32; op.out_len()];
    op.run_batch(1, long_row, &mut want, &mut scratch).unwrap();
    assert_eq!(bits(&full), bits(&want), "row finished across connections is bit-exact");

    drop(cl);
    drop(cl2);
    let router = server.shutdown().unwrap();
    let m = router.metrics(service).unwrap();
    assert_eq!(m.errors(), 4, "one per protocol violation");
    assert_eq!(m.completed() + m.errors() + m.shed(), m.offered(), "conservation");
    assert_eq!(router.open_rows(service), Some(0), "every opened row was closed");
    router.shutdown();
}

#[test]
fn wire_shutdown_request_is_observed_and_drains_cleanly() {
    let registry = OpRegistry::builtin();
    let router = ServiceRouter::builder(1)
        .op_service(&registry, "e2softmax/L16", vec![1, 4])
        .unwrap()
        .start()
        .unwrap();
    let server = Server::start(router, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let addr = server.addr().to_string();

    let mut cl = NetClient::connect(&addr, Duration::from_secs(10)).unwrap();
    assert!(matches!(cl.infer("e2softmax/L16", &[0.25; 16]).unwrap(), Reply::Output(_)));
    assert!(!server.wait(Duration::from_millis(10)), "no shutdown requested yet");
    let ack = cl.shutdown_server().unwrap();
    assert!(ack.contains("shutting down"), "{ack}");
    assert!(server.wait(Duration::from_secs(5)), "the wire request must be observed");
    // the request is a signal to the owner; the server still serves
    // until the owner actually drains it
    assert!(matches!(cl.infer("e2softmax/L16", &[0.5; 16]).unwrap(), Reply::Output(_)));
    drop(cl);

    let router = server.shutdown().unwrap();
    let m = router.metrics("e2softmax/L16").unwrap();
    assert_eq!(m.completed(), 2);
    assert_eq!(m.errors(), 0);
    router.shutdown();
}
