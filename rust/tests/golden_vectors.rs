//! Golden-vector cross-language contract: replays artifacts/golden/*.json
//! (emitted by python/compile/gen_golden.py from ref.py) against the Rust
//! bit-exact models and asserts exact equality at every pinned stage.

use std::path::PathBuf;

use sole::layernorm::{dynamic_compress, rsqrt_hw, AiLayerNorm};
use sole::softmax::{aldivision, log2exp, E2Softmax, E2SoftmaxConfig};
use sole::util::json::{self, Json};

fn golden(name: &str) -> Option<Json> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden").join(name);
    let Ok(text) = std::fs::read_to_string(&p) else {
        eprintln!("skipping: {} missing (run `make artifacts`)", p.display());
        return None;
    };
    Some(json::parse(&text).unwrap())
}

#[test]
fn log2exp_golden() {
    let Some(doc) = golden("log2exp.json") else { return };
    let cases = doc.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 500);
    for c in cases {
        let d = c.get_i64("d").unwrap();
        let e = c.get_i64("e").unwrap() as u32;
        let k = c.get_i64("k").unwrap();
        assert_eq!(log2exp(d, e), k, "d={d} e={e}");
    }
}

#[test]
fn aldivision_golden() {
    let Some(doc) = golden("aldivision.json") else { return };
    for c in doc.get("cases").unwrap().as_arr().unwrap() {
        let k_y = c.get_i64("k_y").unwrap();
        let s = c.get_i64("sum_q15").unwrap() as u64;
        let o = aldivision(k_y, s);
        assert_eq!(o.q23, c.get_i64("out_q23").unwrap(), "k_y={k_y} s={s}");
        assert_eq!(o.u8code as i64, c.get_i64("out_u8").unwrap());
    }
}

#[test]
fn e2softmax_golden() {
    let Some(doc) = golden("e2softmax.json") else { return };
    for c in doc.get("cases").unwrap().as_arr().unwrap() {
        let q = c.get_vec_i64("q").unwrap();
        let e = c.get_i64("e").unwrap() as u32;
        let chunk = c.get_i64("chunk").unwrap() as usize;
        let sm = E2Softmax::new(E2SoftmaxConfig { e, chunk });
        let out = sm.forward_introspect(&q);
        assert_eq!(out.k, c.get_vec_i64("k").unwrap(), "chunk={chunk} q={q:?}");
        assert_eq!(out.sum_q15 as i64, c.get_i64("sum_q15").unwrap());
        assert_eq!(out.out_q23, c.get_vec_i64("out_q23").unwrap());
        let u8s: Vec<i64> = out.out_u8.iter().map(|&v| v as i64).collect();
        assert_eq!(u8s, c.get_vec_i64("out_u8").unwrap());
    }
}

#[test]
fn compress_golden() {
    let Some(doc) = golden("compress.json") else { return };
    let cases = doc.get("cases").unwrap().as_arr().unwrap();
    assert_eq!(cases.len(), 256);
    for c in cases {
        let x = c.get_i64("x").unwrap() as u8;
        let (y, s) = dynamic_compress(x);
        assert_eq!(y as i64, c.get_i64("y").unwrap(), "x={x}");
        assert_eq!(s as i64, c.get_i64("s").unwrap(), "x={x}");
    }
}

#[test]
fn rsqrt_golden() {
    let Some(doc) = golden("rsqrt.json") else { return };
    // the LUT itself
    let lut = doc.get_vec_i64("lut").unwrap();
    let ours = sole::layernorm::rsqrt::rsqrt_lut();
    assert_eq!(lut.len(), 64);
    for (i, (&a, &b)) in lut.iter().zip(ours.iter()).enumerate() {
        assert_eq!(a, b, "lut[{i}]");
    }
    for c in doc.get("cases").unwrap().as_arr().unwrap() {
        let num = c.get_i64("num").unwrap() as u128;
        let den = c.get_i64("den").unwrap() as u128;
        let want = c.get_f64("out").unwrap();
        let got = rsqrt_hw(num, den);
        assert!((got - want).abs() <= want.abs() * 1e-12, "num={num} den={den}");
    }
}

#[test]
fn ailayernorm_golden() {
    let Some(doc) = golden("ailayernorm.json") else { return };
    let ln = AiLayerNorm::default();
    for c in doc.get("cases").unwrap().as_arr().unwrap() {
        let codes: Vec<u8> =
            c.get_vec_i64("codes").unwrap().into_iter().map(|v| v as u8).collect();
        let alpha: Vec<u8> =
            c.get_vec_i64("alpha").unwrap().into_iter().map(|v| v as u8).collect();
        let gamma: Vec<f32> =
            c.get_vec_f64("gamma").unwrap().into_iter().map(|v| v as f32).collect();
        let beta: Vec<f32> =
            c.get_vec_f64("beta").unwrap().into_iter().map(|v| v as f32).collect();
        let out = ln.forward_introspect(&codes, &alpha, &gamma, &beta);
        assert_eq!(out.ex, c.get_i64("ex").unwrap());
        assert_eq!(out.ex2, c.get_i64("ex2").unwrap());
        let want_std = c.get_f64("std_inv").unwrap();
        assert!((out.std_inv - want_std).abs() <= want_std.abs() * 1e-12 + 1e-15);
        let want_y = c.get_vec_f64("y").unwrap();
        for (i, (got, want)) in out.y.iter().zip(&want_y).enumerate() {
            // gamma/beta crossed f32 casts on both sides; the remaining
            // difference is float-print noise in the JSON
            assert!((got - want).abs() < 1e-6, "y[{i}] {got} vs {want}");
        }
    }
}
