//! Integration: the ServiceRouter end to end — the paper's full mixed
//! workload (E2Softmax at L ∈ {49, 128, 785, 1024}, AILayerNorm at
//! C = 768, and the fused attention pipeline at L128xD64) through one
//! process, registered purely via registry spec strings, pinned
//! bit-exact against direct kernel invocation per service, plus a
//! mixed-op soak with interleaved clients and the exact baselines
//! served side by side with SOLE.

use std::sync::Arc;
use std::time::Duration;

use sole::coordinator::{
    paper_services, Backend, BackendScratch, BatchPolicy, ServiceRouter, TrySubmit,
};
use sole::layernorm::ai::layernorm_exact;
use sole::layernorm::{config::DEFAULT_ZP, AiLayerNorm};
use sole::ops::exact::EXACT_LN_EPS;
use sole::ops::{attention, Op, OpRegistry};
use sole::quant::{ptf_quantize_into, PtfCalib};
use sole::softmax::e2::softmax_exact;
use sole::softmax::{quantize_logits_into, E2Scratch, E2Softmax, E2SoftmaxConfig};
use sole::util::rng::Rng;

fn start_paper_router(total_workers: usize, max_wait_ms: u64) -> ServiceRouter {
    let mut builder = ServiceRouter::builder(total_workers).default_policy(BatchPolicy {
        max_wait: Duration::from_millis(max_wait_ms),
        max_batch: 16,
        queue_cap: None,
    });
    for (name, be) in paper_services().unwrap() {
        builder = builder.service(&name, be);
    }
    builder.start().unwrap()
}

#[test]
fn every_softmax_service_matches_direct_kernel_at_paper_shapes() {
    // responses routed by service name through the shared-budget pools
    // must be bit-identical to quantize + forward_row_f32 called directly
    let router = start_paper_router(8, 3);
    let cl = router.client();
    let sm = E2Softmax::new(E2SoftmaxConfig::default());
    let mut rng = Rng::new(41);
    for &l in &[49usize, 128, 785, 1024] {
        let service = format!("e2softmax/L{l}");
        assert_eq!(cl.item_len(&service).unwrap(), l);
        let rows: Vec<Vec<f32>> = (0..12)
            .map(|_| {
                let mut r = vec![0f32; l];
                rng.fill_normal(&mut r, 0.0, 2.0);
                r
            })
            .collect();
        let rxs: Vec<_> = rows.iter().map(|r| cl.submit(&service, r.clone()).unwrap()).collect();
        let mut codes = Vec::new();
        let mut scratch = E2Scratch::default();
        let mut want = vec![0f32; l];
        for (i, (row, rx)) in rows.iter().zip(rxs).enumerate() {
            let resp = rx.recv().unwrap();
            quantize_logits_into(row, sm.cfg().e, &mut codes);
            sm.forward_row_f32(&codes, &mut want, &mut scratch);
            assert_eq!(resp.output, want, "{service} request {i}");
        }
        assert_eq!(router.metrics(&service).unwrap().completed(), 12, "{service}");
    }
    router.shutdown();
}

#[test]
fn layernorm_service_matches_direct_kernel_at_c768() {
    let c = 768;
    let router = start_paper_router(8, 3);
    let cl = router.client();
    // the same identity calibration AiLayerNormOp::try_new uses
    let cal = PtfCalib { alpha: vec![0u8; c], s: 1.0 / 32.0, zp: DEFAULT_ZP };
    let ln = AiLayerNorm::new(cal.zp);
    let gamma = vec![1f32; c];
    let beta = vec![0f32; c];
    let mut rng = Rng::new(43);
    let rows: Vec<Vec<f32>> = (0..16)
        .map(|_| {
            let mut r = vec![0f32; c];
            rng.fill_normal(&mut r, 0.3, 1.5);
            r
        })
        .collect();
    let rxs: Vec<_> =
        rows.iter().map(|r| cl.submit("ailayernorm/C768", r.clone()).unwrap()).collect();
    let mut codes = Vec::new();
    let mut want = vec![0f32; c];
    for (i, (row, rx)) in rows.iter().zip(rxs).enumerate() {
        let resp = rx.recv().unwrap();
        ptf_quantize_into(row, &cal, &mut codes);
        ln.forward_row_f32(&codes, &cal.alpha, &gamma, &beta, &mut want);
        assert_eq!(resp.output, want, "request {i}");
    }
    assert_eq!(router.metrics("ailayernorm/C768").unwrap().completed(), 16);
    router.shutdown();
}

#[test]
fn attention_service_matches_direct_pipeline_invocation() {
    // the served fused pipeline must be bit-identical to running the
    // PipelineOp directly: routing, batching and arena staging add no
    // arithmetic
    let router = start_paper_router(8, 3);
    let cl = router.client();
    let service = "attention/L128xD64";
    let item_in = 3 * 128 * 64;
    assert_eq!(cl.item_len(service).unwrap(), item_in);
    let pipeline = attention::fused_pipeline(128, 64).unwrap();
    let mut rng = Rng::new(47);
    let items: Vec<Vec<f32>> = (0..6)
        .map(|_| {
            let mut it = vec![0f32; item_in];
            rng.fill_normal(&mut it, 0.0, 1.0);
            it
        })
        .collect();
    let rxs: Vec<_> = items.iter().map(|it| cl.submit(service, it.clone()).unwrap()).collect();
    let mut scratch = pipeline.make_scratch();
    let mut want = vec![0f32; 128 * 64];
    for (i, (item, rx)) in items.iter().zip(rxs).enumerate() {
        let resp = rx.recv().unwrap();
        pipeline.run_batch(1, item, &mut want, &mut scratch).unwrap();
        assert_eq!(resp.output, want, "{service} request {i}");
    }
    assert_eq!(router.metrics(service).unwrap().completed(), 6);
    router.shutdown();
}

#[test]
fn mixed_op_soak_interleaved_clients_answer_everything() {
    // several client threads interleave every service; all requests must
    // be answered, per-service metrics populated, and the conservation
    // invariant hold everywhere (no errors on the software services)
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 60; // 10 per service per client
    let router = start_paper_router(6, 2);
    let names: Vec<String> = router.services().iter().map(|s| s.to_string()).collect();
    assert_eq!(names.len(), 6);
    let handles: Vec<_> = (0..CLIENTS)
        .map(|cid| {
            let cl = router.client();
            let names = names.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + cid as u64);
                let mut pending = Vec::new();
                for i in 0..PER_CLIENT {
                    let service = &names[(cid + i) % names.len()];
                    let mut row = vec![0f32; cl.item_len(service).unwrap()];
                    rng.fill_normal(&mut row, 0.0, 2.0);
                    pending.push((service.clone(), cl.submit(service, row).unwrap()));
                }
                for (service, rx) in pending {
                    let r = rx.recv().unwrap_or_else(|e| panic!("{service} dropped: {e}"));
                    assert!(!r.output.is_empty());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let per_service = (CLIENTS * PER_CLIENT / names.len()) as u64;
    let mut total = 0u64;
    for name in &names {
        let m = router.metrics(name).unwrap();
        assert_eq!(m.accepted(), per_service, "{name}: accepted");
        assert_eq!(m.completed(), per_service, "{name}: completed");
        assert_eq!(m.errors(), 0, "{name}: errors");
        let (p50, p99, _) = m.total_latency();
        assert!(p50 > 0.0 && p99 >= p50, "{name}: latency populated");
        assert!(m.mean_batch() >= 1.0, "{name}: batch stats populated");
        total += m.completed();
    }
    assert_eq!(total, (CLIENTS * PER_CLIENT) as u64);
    let merged = router.merged_summary();
    assert!(merged.contains(&format!("completed={total}")), "{merged}");
    let s = router.summary();
    for name in &names {
        assert!(s.contains(name.as_str()), "summary missing {name}: {s}");
    }
    router.shutdown();
}

/// Echo after a fixed sleep: a service whose capacity is known exactly,
/// so bounded-queue saturation is forced, not hoped for.
struct SlowEcho {
    item: usize,
    delay: Duration,
    buckets: Vec<usize>,
}

impl Backend for SlowEcho {
    fn item_input_len(&self) -> usize {
        self.item
    }
    fn item_output_len(&self) -> usize {
        self.item
    }
    fn buckets(&self) -> &[usize] {
        &self.buckets
    }
    fn run(
        &self,
        _bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        _scratch: &mut BackendScratch,
    ) -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        out.copy_from_slice(inputs);
        Ok(())
    }
}

#[test]
fn overload_conservation_ledger_holds_under_queue_saturation() {
    // two deliberately slow services with tiny bounded queues plus a fast
    // real op in the same mix: burst far past capacity via try_submit and
    // every request must land in exactly one ledger bucket, per service —
    // offered == accepted + shed == completed + errors + shed, no losses,
    // no double counts.
    let registry = OpRegistry::builtin();
    let slow = |item| {
        Arc::new(SlowEcho { item, delay: Duration::from_millis(3), buckets: vec![1] })
    };
    let router = ServiceRouter::builder(3)
        .default_policy(BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_batch: 1,
            queue_cap: Some(2),
        })
        .service("slow-a", slow(8))
        .service("slow-b", slow(16))
        .op_service(&registry, "e2softmax/L49", vec![1, 4, 8])
        .unwrap()
        .start()
        .unwrap();
    let cl = router.client();
    let names = ["slow-a", "slow-b", "e2softmax/L49"];

    let mut rng = Rng::new(77);
    let mut submitted = std::collections::BTreeMap::new();
    let mut full = std::collections::BTreeMap::new();
    let mut pending = Vec::new();
    for i in 0..120 {
        let name = names[i % names.len()];
        let mut row = vec![0f32; cl.item_len(name).unwrap()];
        rng.fill_normal(&mut row, 0.0, 1.0);
        *submitted.entry(name).or_insert(0u64) += 1;
        match cl.try_submit(name, row).unwrap() {
            TrySubmit::Accepted(rx) => pending.push(rx),
            TrySubmit::Full(_) => *full.entry(name).or_insert(0u64) += 1,
        }
    }
    for rx in pending {
        rx.recv().unwrap();
    }

    let (mut offered, mut completed, mut shed) = (0u64, 0u64, 0u64);
    for name in names {
        let m = router.metrics(name).unwrap();
        let local_full = full.get(name).copied().unwrap_or(0);
        assert_eq!(m.offered(), submitted[name], "{name}: every submission is offered");
        assert_eq!(m.shed(), local_full, "{name}: shed matches the Full returns we saw");
        assert_eq!(m.errors(), 0, "{name}: errors");
        assert_eq!(m.accepted(), m.completed() + m.errors(), "{name}: accepted ledger");
        assert_eq!(
            m.offered(),
            m.completed() + m.errors() + m.shed(),
            "{name}: conservation"
        );
        offered += m.offered();
        completed += m.completed();
        shed += m.shed();
    }
    assert_eq!(offered, 120);
    assert_eq!(completed + shed, 120, "merged conservation");
    // saturation must actually have happened on the slow services: 40
    // near-instant submissions against 1 in-exec + 2 queued slots
    for name in ["slow-a", "slow-b"] {
        assert!(
            full.get(name).copied().unwrap_or(0) > 0,
            "{name}: expected bounded-queue sheds, got none"
        );
    }
    router.shutdown();
}

#[test]
fn router_rejects_cross_service_shapes() {
    // a request sized for one service must not slip into another
    let router = start_paper_router(5, 1);
    let cl = router.client();
    let err = format!("{:#}", cl.submit("e2softmax/L49", vec![0.0; 128]).unwrap_err());
    assert!(err.contains("e2softmax/L49"), "{err}");
    // correct sizes still round-trip on both ops
    assert_eq!(cl.infer("e2softmax/L128", vec![0.1; 128]).unwrap().output.len(), 128);
    assert_eq!(cl.infer("ailayernorm/C768", vec![0.1; 768]).unwrap().output.len(), 768);
    router.shutdown();
}

#[test]
fn exact_baselines_serve_through_router_via_spec_strings() {
    // the acceptance bar of the Op redesign: the exact softmax/layernorm
    // baselines become servable purely by naming their registry specs,
    // side by side with the SOLE kernels, bit-exact to the direct kernels
    let registry = OpRegistry::builtin();
    let router = ServiceRouter::builder(4)
        .default_policy(BatchPolicy {
            max_wait: Duration::from_millis(2),
            max_batch: 16,
            queue_cap: None,
        })
        .op_service(&registry, "e2softmax/L49", vec![1, 4, 8])
        .unwrap()
        .op_service(&registry, "softmax-exact/L49", vec![1, 4, 8])
        .unwrap()
        .op_service(&registry, "ailayernorm/C96", vec![1, 4, 8])
        .unwrap()
        .op_service(&registry, "layernorm-exact/C96", vec![1, 4, 8])
        .unwrap()
        .start()
        .unwrap();
    let cl = router.client();
    assert_eq!(
        router.services(),
        vec!["ailayernorm/C96", "e2softmax/L49", "layernorm-exact/C96", "softmax-exact/L49"]
    );

    let mut rng = Rng::new(71);
    for i in 0..8 {
        let mut sm_row = vec![0f32; 49];
        rng.fill_normal(&mut sm_row, 0.0, 2.0);
        let got = cl.infer("softmax-exact/L49", sm_row.clone()).unwrap().output;
        let want: Vec<f32> = softmax_exact(&sm_row).into_iter().map(|v| v as f32).collect();
        assert_eq!(got, want, "softmax-exact request {i}");

        let mut ln_row = vec![0f32; 96];
        rng.fill_normal(&mut ln_row, 0.3, 1.5);
        let got = cl.infer("layernorm-exact/C96", ln_row.clone()).unwrap().output;
        let gamma = vec![1f32; 96];
        let beta = vec![0f32; 96];
        let want: Vec<f32> = layernorm_exact(&ln_row, &gamma, &beta, EXACT_LN_EPS)
            .into_iter()
            .map(|v| v as f32)
            .collect();
        assert_eq!(got, want, "layernorm-exact request {i}");

        // the SOLE services keep serving the same traffic in the same mix
        assert_eq!(cl.infer("e2softmax/L49", sm_row).unwrap().output.len(), 49);
        assert_eq!(cl.infer("ailayernorm/C96", ln_row).unwrap().output.len(), 96);
    }
    router.shutdown();
}
