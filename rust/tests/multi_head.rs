//! Multi-head packing is pure batch geometry: the registered
//! `H<h>x`-prefixed attention and block pipelines must be bit-identical
//! to staging each head slice through the single-head pipeline — the
//! `H` dimension never changes arithmetic, only how many head blocks one
//! router item carries.
//!
//! Fixed shapes pin H ∈ {1, 2, 8} at lane-aligned and odd sequence
//! lengths; the property sweep draws random (H, odd L, D) shapes so the
//! AVX2 tails (L and D not multiples of the 8-lane width) are crossed on
//! every run.  CI runs the suite forced-scalar and with AVX2 enabled.

use sole::ops::{Op, OpRegistry};
use sole::util::proptest::{check, size};
use sole::util::rng::Rng;

fn run(op: &dyn Op, rows: usize, input: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; rows * op.out_len()];
    let mut scratch = op.make_scratch();
    op.run_batch(rows, input, &mut out, &mut scratch).unwrap();
    out
}

/// The packed `family/H<h>xL<l>xD<d>` op over `rows` items vs every head
/// slice staged one at a time through `family/L<l>xD<d>`.
fn packed_equals_per_head(family: &str, h: usize, l: usize, d: usize, rng: &mut Rng) {
    let registry = OpRegistry::builtin();
    let (_, packed) = registry.build(&format!("{family}/H{h}xL{l}xD{d}")).unwrap();
    let (_, single) = registry.build(&format!("{family}/L{l}xD{d}")).unwrap();
    assert_eq!(packed.item_len(), h * single.item_len(), "{family} H{h}");
    assert_eq!(packed.out_len(), h * single.out_len(), "{family} H{h}");
    let rows = 2;
    let mut input = vec![0f32; rows * packed.item_len()];
    rng.fill_normal(&mut input, 0.0, 1.0);
    let got = run(&*packed, rows, &input);
    let (il, ol) = (single.item_len(), single.out_len());
    let mut want = vec![0f32; rows * packed.out_len()];
    for (i, item) in input.chunks_exact(il).enumerate() {
        want[i * ol..(i + 1) * ol].copy_from_slice(&run(&*single, 1, item));
    }
    assert_eq!(got, want, "{family} H{h}xL{l}xD{d}");
}

#[test]
fn fused_multi_head_attention_equals_per_head_staging() {
    let mut rng = Rng::new(0xA110);
    for &(h, l, d) in &[(1usize, 16usize, 8usize), (2, 9, 4), (8, 16, 8), (3, 17, 5)] {
        packed_equals_per_head("attention", h, l, d, &mut rng);
    }
}

#[test]
fn fused_multi_head_block_equals_per_head_staging() {
    let mut rng = Rng::new(0xB110);
    for &(h, l, d) in &[(1usize, 16usize, 8usize), (2, 9, 4), (8, 16, 8), (3, 17, 5)] {
        packed_equals_per_head("block", h, l, d, &mut rng);
    }
}

#[test]
fn property_packed_heads_never_change_arithmetic() {
    // random H ∈ {1, 2, 8}, odd L (always an AVX2 tail), small-biased D
    check("packed-heads-geometry", 10, 0x4EAD, |rng| {
        let h = [1usize, 2, 8][((rng.f64() * 3.0) as usize).min(2)];
        let l = 2 * size(rng, 8) + 1;
        let d = size(rng, 8);
        packed_equals_per_head("attention", h, l, d, rng);
        packed_equals_per_head("block", h, l, d, rng);
    });
}
