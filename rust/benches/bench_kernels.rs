//! Old-vs-new operator kernel throughput at the paper's shapes, with a
//! machine-readable record (`BENCH_kernels.json`) so every future PR has a
//! perf trajectory to beat.
//!
//! The "legacy" implementations are verbatim copies of the pre-planar row
//! kernels (per-element `log2exp` shift-add calls, push-based scratch, f64
//! stage 2 in AILayerNorm), kept here so the recorded speedup is measured
//! against real code, not a strawman.  Correctness of the comparison is
//! asserted before timing: the planar softmax kernel must match legacy
//! bit-for-bit, the layernorm kernel within f32-rounding tolerance.
//!
//! A third section measures the fused attention pipeline (A·V consuming
//! packed log2 codes, `impl = fused_codes`) against the staged pipeline
//! that materializes the f32 probability matrix (`impl = staged_f32`);
//! for those rows `l` is the sequence length (head dim 64) and
//! `speedup_vs_legacy` is the fused-over-staged ratio.  The two are
//! asserted bit-identical before timing, and the attention rows also
//! report `staging_bytes_per_item` — what one item's probability matrix
//! costs at the softmax→A·V stage boundary (the paper's low bit-width
//! storage claim): the code port must stay ≤ 1/3 of the f32-staged
//! bytes, asserted against `PipelineOp::staging_bytes_per_item()`.
//!
//! The block section extends the storage claim to the full transformer
//! block (`impl = fused_ports` vs `impl = staged_dequant`): the fused
//! `block/*` pipeline consumes its `ptf-u8` and `log2c5` boundaries
//! natively, the comparator widens the same producers through dequant
//! adapter stages.  Bit-exactness and the total-staging-bytes win are
//! asserted before timing.
//!
//! A fourth section measures the lane-parallel kernel arms (DESIGN.md
//! §3.4): the same planar kernels with dispatch pinned to `scalar` vs
//! whatever `Dispatch::detect()` picks on this host.  The two arms are
//! asserted bit-identical in every mode — including `--quick` — before
//! any timing; when an AVX2 arm ran, the 1024-point shapes must come in
//! at >= 2x scalar.  Every JSON row carries a `dispatch` field (and the
//! document a top-level one) so trajectories from different machines
//! stay comparable.
//!
//! Flags: `--json` writes the JSON artifact (default path
//! `<repo>/BENCH_kernels.json`, override with `--out <path>`); `--quick`
//! is the CI smoke mode (equivalent to `SOLE_BENCH_QUICK=1`: numbers are
//! meaningless, the point is that every code path executes).

use std::time::Duration;

use sole::fixedpoint::leading_one;
use sole::layernorm::compress::COMPRESSED_SQUARE_TABLE;
use sole::layernorm::rsqrt::rsqrt_hw;
use sole::layernorm::AiLayerNorm;
use sole::layernorm::config::DEFAULT_ZP;
use sole::ops::attention::{fused_pipeline, unfused_pipeline, AttnAvOp};
use sole::ops::block::{fused_block, unfused_block};
use sole::ops::{Op, PortMut, PortRef, PortType};
use sole::simd::Dispatch;
use sole::softmax::{config, log2exp, E2Scratch, E2Softmax, E2SoftmaxConfig, CODE_SIDE_LEN};
use sole::util::bench::{bench, quick_mode, report, set_quick_mode, BenchResult};
use sole::util::cli::Args;
use sole::util::json::{obj, Json};
use sole::util::rng::Rng;

// ---------------------------------------------------------------------------
// Legacy kernels (pre-planar state, PR 1) — the old-vs-new baseline
// ---------------------------------------------------------------------------

#[derive(Default)]
struct LegacyE2Scratch {
    k: Vec<i64>,
    m: Vec<i64>,
}

/// The old `E2Softmax::forward_row_f32`: per-element shift-add `log2exp`
/// in both stages, per-element running-max storage, push-based scratch.
fn legacy_softmax_row(cfg: &E2SoftmaxConfig, q: &[i64], out: &mut [f32], s: &mut LegacyE2Scratch) {
    let chunk = cfg.chunk.max(1);
    let e = cfg.e;
    let n = q.len();
    s.k.clear();
    s.k.reserve(n);
    s.m.clear();
    s.m.reserve(n);
    let mut sum: u64 = 0;
    let mut m_prev = i64::MIN;
    for sl in q.chunks(chunk) {
        let mut local = sl[0];
        for &v in &sl[1..] {
            local = local.max(v);
        }
        let m_new = if m_prev == i64::MIN { local } else { m_prev.max(local) };
        if m_prev != i64::MIN && m_prev != m_new {
            sum >>= log2exp(m_prev - m_new, e) as u32;
        }
        for &qi in sl {
            let k = log2exp(qi - m_new, e);
            sum += 1u64 << (config::SUM_FRAC as i64 - k);
            s.k.push(k);
            s.m.push(m_new);
        }
        m_prev = m_new;
    }
    let m_final = m_prev;
    let msb = leading_one(sum) as i64;
    let k_s = msb - config::SUM_FRAC as i64;
    let s1 = if msb >= 1 { (sum >> (msb - 1)) & 1 } else { 0 };
    let c = if s1 == 1 { config::ALDIV_C1 } else { config::ALDIV_C0 };
    let inv = 1.0f32 / (1i64 << config::ALDIV_Q) as f32;
    let base_shift = k_s + 1;
    for i in 0..n {
        let sub = log2exp(s.m[i] - m_final, e);
        let shift = s.k[i] + sub + base_shift;
        let q23 = if shift >= 64 {
            0
        } else if shift >= 0 {
            c >> shift
        } else {
            c << -shift
        };
        out[i] = q23 as f32 * inv;
    }
}

/// The old `AiLayerNorm::forward_row_f32`: i64 stage 1, but two f64
/// multiplies and an f64 add per element in stage 2.
fn legacy_layernorm_row(
    zp: i64,
    codes: &[u8],
    alpha: &[u8],
    gamma: &[f32],
    beta: &[f32],
    out: &mut [f32],
) {
    let c = codes.len();
    let sq_table = &*COMPRESSED_SQUARE_TABLE;
    let mut ex: i64 = 0;
    let mut ex2: i64 = 0;
    for i in 0..c {
        let xi = codes[i] as i64 - zp;
        let a = alpha[i] as u32;
        ex += xi << a;
        let mag = xi.unsigned_abs().min(255) as usize;
        ex2 += sq_table[mag] << (2 * a);
    }
    ex2 <<= 4;
    let var_num = ex2 as i128 * c as i128 - (ex as i128) * (ex as i128);
    let mean = ex as f64 / c as f64;
    let std_inv = if var_num > 0 {
        rsqrt_hw(var_num as u128, (c as u128) * (c as u128))
    } else {
        0.0
    };
    for i in 0..c {
        let d = ((codes[i] as i64 - zp) << alpha[i]) as f64;
        out[i] = (gamma[i] as f64 * std_inv * (d - mean) + beta[i] as f64) as f32;
    }
}

// ---------------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------------

const TARGET: Duration = Duration::from_millis(300);

/// One JSON row.  `l` is the shape label (row length / channels /
/// sequence length); `row_elems` is the number of f32 input elements one
/// row actually consumes, which `melem_per_sec` is computed from — for
/// the row ops they coincide, for attention a row is a whole `[Q|K|V]`
/// item (3·L·D), keeping `melem_per_sec` comparable across all rows.
// one flat row-builder call per bench result beats a builder struct here
#[allow(clippy::too_many_arguments)]
fn record(
    op: &str,
    l: usize,
    row_elems: usize,
    b: usize,
    impl_name: &str,
    dispatch: &str,
    r: &BenchResult,
    speedup: Option<f64>,
    staging_bytes: Option<usize>,
) -> Json {
    let rows_per_sec = b as f64 * r.per_sec();
    let melem_per_sec = (b * row_elems) as f64 * r.per_sec() / 1e6;
    let mut fields = vec![
        ("op", Json::Str(op.to_string())),
        ("l", Json::Int(l as i64)),
        ("batch", Json::Int(b as i64)),
        ("impl", Json::Str(impl_name.to_string())),
        ("dispatch", Json::Str(dispatch.to_string())),
        ("mean_ns", Json::Int(r.mean.as_nanos() as i64)),
        ("p50_ns", Json::Int(r.p50.as_nanos() as i64)),
        ("p99_ns", Json::Int(r.p99.as_nanos() as i64)),
        ("rows_per_sec", Json::Num(rows_per_sec)),
        ("melem_per_sec", Json::Num(melem_per_sec)),
    ];
    if let Some(s) = speedup {
        fields.push(("speedup_vs_legacy", Json::Num(s)));
    }
    if let Some(bytes) = staging_bytes {
        fields.push(("staging_bytes_per_item", Json::Int(bytes as i64)));
    }
    obj(fields)
}

fn main() {
    let args = Args::from_env();
    if args.flag("quick") {
        set_quick_mode(true);
    }
    println!(
        "bench_kernels — old-vs-new operator kernels at the paper's shapes{}",
        if quick_mode() { " [QUICK smoke mode — numbers meaningless]" } else { "" }
    );

    let mut rng = Rng::new(0xBE7C);
    let mut results: Vec<Json> = Vec::new();
    // the acceptance shape: single-row E2Softmax at L=1024
    let mut accept_speedup = f64::NAN;

    println!("\ne2softmax — legacy per-row shift-add vs planar LUT batch kernel");
    for &l in &[49usize, 128, 785, 1024] {
        for &b in &[1usize, 8, 16] {
            let q: Vec<i64> = (0..b * l).map(|_| -rng.range_i64(0, 256)).collect();
            let cfg = E2SoftmaxConfig::default();
            let sm = E2Softmax::new(cfg);
            let mut out_legacy = vec![0f32; b * l];
            let mut out_new = vec![0f32; b * l];
            let mut ls = LegacyE2Scratch::default();
            let mut ns = E2Scratch::default();
            // correctness of the comparison: bit-exact old vs new
            for (row, row_out) in q.chunks(l).zip(out_legacy.chunks_mut(l)) {
                legacy_softmax_row(&cfg, row, row_out, &mut ls);
            }
            sm.forward_batch_f32(&q, l, &mut out_new, &mut ns);
            assert_eq!(out_legacy, out_new, "planar kernel diverged at L={l} B={b}");

            let rl = bench(&format!("e2softmax legacy  L={l:<4} B={b:<2}"), TARGET, || {
                for (row, row_out) in
                    std::hint::black_box(&q).chunks(l).zip(out_legacy.chunks_mut(l))
                {
                    legacy_softmax_row(&cfg, row, row_out, &mut ls);
                }
            });
            report(&rl);
            let rn = bench(&format!("e2softmax planar  L={l:<4} B={b:<2}"), TARGET, || {
                sm.forward_batch_f32(std::hint::black_box(&q), l, &mut out_new, &mut ns);
            });
            report(&rn);
            let speedup = rl.mean.as_secs_f64() / rn.mean.as_secs_f64();
            println!(
                "    -> {:.1} Melem/s legacy, {:.1} Melem/s planar ({speedup:.2}x)",
                (b * l) as f64 * rl.per_sec() / 1e6,
                (b * l) as f64 * rn.per_sec() / 1e6,
            );
            if l == 1024 && b == 1 {
                accept_speedup = speedup;
            }
            results.push(record("e2softmax", l, l, b, "legacy_row", "scalar", &rl, None, None));
            results.push(record(
                "e2softmax",
                l,
                l,
                b,
                "planar_batch",
                sm.dispatch().as_str(),
                &rn,
                Some(speedup),
                None,
            ));
        }
    }

    println!("\nailayernorm — legacy f64 stage 2 vs fused f32 batch kernel");
    for &c in &[192usize, 384, 768] {
        for &b in &[1usize, 8, 16] {
            let codes: Vec<u8> = (0..b * c).map(|_| rng.range_i64(0, 256) as u8).collect();
            let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
            let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
            let beta: Vec<f32> = (0..c).map(|_| 0.2 * rng.normal() as f32).collect();
            let ln = AiLayerNorm::default();
            let mut out_legacy = vec![0f32; b * c];
            let mut out_new = vec![0f32; b * c];
            for (row, row_out) in codes.chunks(c).zip(out_legacy.chunks_mut(c)) {
                legacy_layernorm_row(ln.zp, row, &alpha, &gamma, &beta, row_out);
            }
            ln.forward_batch_f32(&codes, &alpha, &gamma, &beta, &mut out_new);
            for (i, (a, w)) in out_new.iter().zip(&out_legacy).enumerate() {
                assert!(
                    (a - w).abs() < 1e-4 * (1.0 + w.abs()),
                    "fused kernel diverged at C={c} B={b} i={i}: {a} vs {w}"
                );
            }

            let rl = bench(&format!("ailayernorm legacy C={c:<4} B={b:<2}"), TARGET, || {
                for (row, row_out) in
                    std::hint::black_box(&codes).chunks(c).zip(out_legacy.chunks_mut(c))
                {
                    legacy_layernorm_row(ln.zp, row, &alpha, &gamma, &beta, row_out);
                }
            });
            report(&rl);
            let rn = bench(&format!("ailayernorm fused  C={c:<4} B={b:<2}"), TARGET, || {
                ln.forward_batch_f32(
                    std::hint::black_box(&codes),
                    &alpha,
                    &gamma,
                    &beta,
                    &mut out_new,
                );
            });
            report(&rn);
            let speedup = rl.mean.as_secs_f64() / rn.mean.as_secs_f64();
            println!(
                "    -> {:.1} Melem/s legacy, {:.1} Melem/s fused ({speedup:.2}x)",
                (b * c) as f64 * rl.per_sec() / 1e6,
                (b * c) as f64 * rn.per_sec() / 1e6,
            );
            results.push(record("ailayernorm", c, c, b, "legacy_row", "scalar", &rl, None, None));
            results.push(record(
                "ailayernorm",
                c,
                c,
                b,
                "fused_batch",
                ln.dispatch().as_str(),
                &rn,
                Some(speedup),
                None,
            ));
        }
    }

    // Fused attention (DESIGN.md §3.2): the pipeline consuming packed
    // log2 codes directly in A·V vs the same arithmetic staged through a
    // materialized f32 probability buffer.  Bit-exactness is asserted
    // before timing (also pinned by tests/op_conformance.rs), so the
    // speedup measures fusion alone — skipped probability store/reload —
    // not a numerics change.  Head dim is the transformer-standard 64.
    println!("\nattention — fused shift-accumulate A·V over log2 codes vs staged e2softmax + matmul");
    const HEAD_D: usize = 64;
    for &l in &[49usize, 128] {
        for &b in &[1usize, 8] {
            let fused = fused_pipeline(l, HEAD_D).expect("fused attention pipeline");
            let staged = unfused_pipeline(l, HEAD_D).expect("staged attention pipeline");
            let mut input = vec![0f32; b * fused.item_len()];
            rng.fill_normal(&mut input, 0.0, 1.0);
            let mut out_fused = vec![0f32; b * fused.out_len()];
            let mut out_staged = vec![0f32; b * staged.out_len()];
            let mut fs = fused.make_scratch();
            let mut ss = staged.make_scratch();
            fused.run_batch(b, &input, &mut out_fused, &mut fs).expect("fused run");
            staged.run_batch(b, &input, &mut out_staged, &mut ss).expect("staged run");
            assert_eq!(out_fused, out_staged, "fused A·V diverged at L={l} D={HEAD_D} B={b}");

            // the storage claim, asserted before timing like bit-exactness:
            // one item's probability matrix at the softmax->A·V boundary
            // costs 1 byte/weight + the 2-f32 row headers on the code port
            // vs 4 bytes/weight staged — the V passthrough block is
            // byte-identical on both paths and excluded from the ratio
            let staged_pq = 4 * l * l;
            let fused_pq = l * l + 4 * CODE_SIDE_LEN * l;
            assert!(
                fused_pq * 3 <= staged_pq,
                "code-port staging must be <= 1/3 of f32 at L={l}: {fused_pq} vs {staged_pq} bytes"
            );
            // cross-check against the pipeline's own boundary accounting
            // (which includes the V block on both sides)
            let v_bytes = 4 * l * HEAD_D;
            assert_eq!(fused.staging_bytes_per_item()[1], fused_pq + v_bytes);
            assert_eq!(staged.staging_bytes_per_item()[1], staged_pq + v_bytes);

            let rs = bench(&format!("attention staged  L={l:<4} B={b:<2}"), TARGET, || {
                staged
                    .run_batch(b, std::hint::black_box(&input), &mut out_staged, &mut ss)
                    .expect("staged run");
            });
            report(&rs);
            let rf = bench(&format!("attention fused   L={l:<4} B={b:<2}"), TARGET, || {
                fused
                    .run_batch(b, std::hint::black_box(&input), &mut out_fused, &mut fs)
                    .expect("fused run");
            });
            report(&rf);
            let speedup = rs.mean.as_secs_f64() / rf.mean.as_secs_f64();
            println!(
                "    -> {:.1} items/s staged, {:.1} items/s fused ({speedup:.2}x)",
                b as f64 * rs.per_sec(),
                b as f64 * rf.per_sec(),
            );
            let row_elems = fused.item_len();
            let staged_disp = staged.dispatch().map_or("-", |d| d.as_str());
            let fused_disp = fused.dispatch().map_or("-", |d| d.as_str());
            results.push(record(
                "attention",
                l,
                row_elems,
                b,
                "staged_f32",
                staged_disp,
                &rs,
                None,
                Some(staged_pq),
            ));
            results.push(record(
                "attention",
                l,
                row_elems,
                b,
                "fused_codes",
                fused_disp,
                &rf,
                Some(speedup),
                Some(fused_pq),
            ));
        }
    }

    // Transformer block (DESIGN.md §3.5): the fused block pipeline whose
    // quantized boundaries are consumed natively vs the comparator that
    // widens the same producers through dequant adapter stages.  Same
    // arithmetic in the same order — bit-exactness is asserted before
    // timing (also pinned in ops/block.rs), so the ratio measures what
    // consuming the low-bit ports in place buys.
    println!("\nblock — fused quantized-boundary block vs dequant-adapter comparator");
    for &l in &[49usize, 128] {
        let (d, b) = (HEAD_D, 4usize);
        let fused = fused_block(l, d).expect("fused block pipeline");
        let staged = unfused_block(l, d).expect("staged block pipeline");
        let mut input = vec![0f32; b * fused.item_len()];
        rng.fill_normal(&mut input, 0.0, 1.0);
        let mut out_fused = vec![0f32; b * fused.out_len()];
        let mut out_staged = vec![0f32; b * staged.out_len()];
        let mut fs = fused.make_scratch();
        let mut ss = staged.make_scratch();
        fused.run_batch(b, &input, &mut out_fused, &mut fs).expect("fused run");
        staged.run_batch(b, &input, &mut out_staged, &mut ss).expect("staged run");
        assert_eq!(out_fused, out_staged, "fused block diverged at L={l} D={d} B={b}");

        // the storage claim across the whole block: summed over every
        // stage boundary, the fused path (codes + f32 sidecars) must
        // stage fewer bytes per item than the adapter-widened comparator
        let fused_total: usize = fused.staging_bytes_per_item().iter().sum();
        let staged_total: usize = staged.staging_bytes_per_item().iter().sum();
        assert!(
            fused_total < staged_total,
            "fused block staging must beat the comparator at L={l}: \
             {fused_total} vs {staged_total} bytes"
        );

        let rs = bench(&format!("block staged      L={l:<4} B={b:<2}"), TARGET, || {
            staged
                .run_batch(b, std::hint::black_box(&input), &mut out_staged, &mut ss)
                .expect("staged run");
        });
        report(&rs);
        let rf = bench(&format!("block fused       L={l:<4} B={b:<2}"), TARGET, || {
            fused
                .run_batch(b, std::hint::black_box(&input), &mut out_fused, &mut fs)
                .expect("fused run");
        });
        report(&rf);
        let speedup = rs.mean.as_secs_f64() / rf.mean.as_secs_f64();
        println!(
            "    -> {:.1} items/s staged, {:.1} items/s fused ({speedup:.2}x), \
             staging {fused_total} vs {staged_total} bytes/item",
            b as f64 * rs.per_sec(),
            b as f64 * rf.per_sec(),
        );
        let row_elems = fused.item_len();
        results.push(record(
            "block",
            l,
            row_elems,
            b,
            "staged_dequant",
            staged.dispatch().map_or("-", |x| x.as_str()),
            &rs,
            None,
            Some(staged_total),
        ));
        results.push(record(
            "block",
            l,
            row_elems,
            b,
            "fused_ports",
            fused.dispatch().map_or("-", |x| x.as_str()),
            &rf,
            Some(speedup),
            Some(fused_total),
        ));
    }

    // Lane-parallel kernels (DESIGN.md §3.4): the same planar kernels
    // with the dispatch pinned to Scalar vs whatever this host detects.
    // Bit-exactness of the AVX2 arm against the scalar arm is asserted
    // in every mode — including quick — before any timing; the timing
    // acceptance (>= 2x at the 1024 shapes) only applies when an AVX2
    // arm actually ran.
    let detected = Dispatch::detect();
    let simd_active = detected != Dispatch::Scalar;
    println!("\nsimd — forced-scalar vs runtime-dispatched kernels (detected: {detected})");
    let mut accept_simd_sm = f64::NAN;
    let mut accept_simd_ln = f64::NAN;

    for &l in &[49usize, 128, 785, 1024] {
        let b = 4usize;
        let q: Vec<i64> = (0..b * l).map(|_| -rng.range_i64(0, 256)).collect();
        let cfg = E2SoftmaxConfig::default();
        let sm_scalar = E2Softmax::with_dispatch(cfg, Dispatch::Scalar);
        let sm_auto = E2Softmax::new(cfg);
        let mut out_scalar = vec![0f32; b * l];
        let mut out_auto = vec![0f32; b * l];
        let mut ss = E2Scratch::default();
        let mut sa = E2Scratch::default();
        sm_scalar.forward_batch_f32(&q, l, &mut out_scalar, &mut ss);
        sm_auto.forward_batch_f32(&q, l, &mut out_auto, &mut sa);
        assert_eq!(out_scalar, out_auto, "e2softmax {detected} arm diverged at L={l}");
        let mut codes_s = vec![0u8; b * l];
        let mut codes_a = vec![0u8; b * l];
        let mut side_s = vec![0f32; b * CODE_SIDE_LEN];
        let mut side_a = vec![0f32; b * CODE_SIDE_LEN];
        sm_scalar.forward_batch_codes(&q, l, &mut codes_s, &mut side_s, &mut ss);
        sm_auto.forward_batch_codes(&q, l, &mut codes_a, &mut side_a, &mut sa);
        assert_eq!(codes_s, codes_a, "e2softmax {detected} code arm diverged at L={l}");
        assert_eq!(side_s, side_a, "e2softmax {detected} side arm diverged at L={l}");

        let rs = bench(&format!("e2softmax scalar  L={l:<4} B={b:<2}"), TARGET, || {
            sm_scalar.forward_batch_f32(std::hint::black_box(&q), l, &mut out_scalar, &mut ss);
        });
        report(&rs);
        let ra = bench(&format!("e2softmax {detected:<7} L={l:<4} B={b:<2}"), TARGET, || {
            sm_auto.forward_batch_f32(std::hint::black_box(&q), l, &mut out_auto, &mut sa);
        });
        report(&ra);
        let speedup = rs.mean.as_secs_f64() / ra.mean.as_secs_f64();
        println!("    -> {speedup:.2}x {detected}-vs-scalar");
        if l == 1024 {
            accept_simd_sm = speedup;
        }
        results.push(record("e2softmax", l, l, b, "planar_batch", "scalar", &rs, None, None));
        results.push(record(
            "e2softmax",
            l,
            l,
            b,
            "planar_batch",
            sm_auto.dispatch().as_str(),
            &ra,
            Some(speedup),
            None,
        ));
    }

    for &c in &[192usize, 768, 1024] {
        let b = 4usize;
        let codes: Vec<u8> = (0..b * c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
        let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let beta: Vec<f32> = (0..c).map(|_| 0.2 * rng.normal() as f32).collect();
        let ln_scalar = AiLayerNorm::with_dispatch(DEFAULT_ZP, Dispatch::Scalar);
        let ln_auto = AiLayerNorm::new(DEFAULT_ZP);
        let mut out_scalar = vec![0f32; b * c];
        let mut out_auto = vec![0f32; b * c];
        ln_scalar.forward_batch_f32(&codes, &alpha, &gamma, &beta, &mut out_scalar);
        ln_auto.forward_batch_f32(&codes, &alpha, &gamma, &beta, &mut out_auto);
        for (i, (a, w)) in out_auto.iter().zip(&out_scalar).enumerate() {
            assert_eq!(a.to_bits(), w.to_bits(), "ailayernorm {detected} arm diverged C={c} i={i}");
        }

        let rs = bench(&format!("ailayernorm scalar  C={c:<4} B={b:<2}"), TARGET, || {
            ln_scalar.forward_batch_f32(
                std::hint::black_box(&codes),
                &alpha,
                &gamma,
                &beta,
                &mut out_scalar,
            );
        });
        report(&rs);
        let ra = bench(&format!("ailayernorm {detected:<7} C={c:<4} B={b:<2}"), TARGET, || {
            ln_auto.forward_batch_f32(
                std::hint::black_box(&codes),
                &alpha,
                &gamma,
                &beta,
                &mut out_auto,
            );
        });
        report(&ra);
        let speedup = rs.mean.as_secs_f64() / ra.mean.as_secs_f64();
        println!("    -> {speedup:.2}x {detected}-vs-scalar");
        if c == 1024 {
            accept_simd_ln = speedup;
        }
        results.push(record("ailayernorm", c, c, b, "fused_batch", "scalar", &rs, None, None));
        results.push(record(
            "ailayernorm",
            c,
            c,
            b,
            "fused_batch",
            ln_auto.dispatch().as_str(),
            &ra,
            Some(speedup),
            None,
        ));
    }

    {
        // A·V over packed codes: synthetic in-grid codes plus valid
        // per-row divider headers, driven through the typed code port.
        let (l, d, b) = (128usize, 64usize, 4usize);
        let av_scalar = AttnAvOp::with_dispatch(l, d, PortType::Log2Code5, Dispatch::Scalar)
            .expect("scalar attn-av");
        let av_auto = AttnAvOp::with_in_port(l, d, PortType::Log2Code5).expect("auto attn-av");
        let codes: Vec<u8> = (0..b * l * l).map(|i| (i % 32) as u8).collect();
        let side_item = CODE_SIDE_LEN * l + l * d;
        let mut side = vec![0f32; b * side_item];
        for item in side.chunks_exact_mut(side_item) {
            let (headers, v) = item.split_at_mut(CODE_SIDE_LEN * l);
            for h in headers.chunks_exact_mut(CODE_SIDE_LEN) {
                h[0] = config::ALDIV_C0 as f32;
                h[1] = 6.0;
            }
            rng.fill_normal(v, 0.0, 1.0);
        }
        let mut out_scalar = vec![0f32; b * l * d];
        let mut out_auto = vec![0f32; b * l * d];
        let mut ws = av_scalar.make_scratch();
        let mut wa = av_auto.make_scratch();
        let input = PortRef::Log2Code5 { codes: &codes, side: &side };
        av_scalar
            .run_batch_ports(b, input, PortMut::F32(&mut out_scalar), &mut ws)
            .expect("scalar A·V");
        let input = PortRef::Log2Code5 { codes: &codes, side: &side };
        av_auto
            .run_batch_ports(b, input, PortMut::F32(&mut out_auto), &mut wa)
            .expect("auto A·V");
        assert_eq!(out_scalar, out_auto, "attn-av {detected} arm diverged at L={l} D={d}");

        let rs = bench(&format!("attn-av codes scalar  L={l:<4} B={b:<2}"), TARGET, || {
            let input =
                PortRef::Log2Code5 { codes: std::hint::black_box(&codes), side: &side };
            av_scalar
                .run_batch_ports(b, input, PortMut::F32(&mut out_scalar), &mut ws)
                .expect("scalar A·V");
        });
        report(&rs);
        let ra = bench(&format!("attn-av codes {detected:<7} L={l:<4} B={b:<2}"), TARGET, || {
            let input =
                PortRef::Log2Code5 { codes: std::hint::black_box(&codes), side: &side };
            av_auto
                .run_batch_ports(b, input, PortMut::F32(&mut out_auto), &mut wa)
                .expect("auto A·V");
        });
        report(&ra);
        let speedup = rs.mean.as_secs_f64() / ra.mean.as_secs_f64();
        println!("    -> {speedup:.2}x {detected}-vs-scalar");
        results.push(record("attn-av", l, l * l, b, "codes_port", "scalar", &rs, None, None));
        results.push(record(
            "attn-av",
            l,
            l * l,
            b,
            "codes_port",
            av_auto.dispatch().map_or("-", |x| x.as_str()),
            &ra,
            Some(speedup),
            None,
        ));
    }

    let simd_pass = accept_simd_sm >= 2.0 && accept_simd_ln >= 2.0;
    println!(
        "\nacceptance (simd): e2softmax L=1024 {accept_simd_sm:.2}x, ailayernorm C=1024 \
         {accept_simd_ln:.2}x {detected}-vs-scalar (required >= 2.0x) -> {}",
        if quick_mode() {
            "SKIPPED (quick mode)"
        } else if !simd_active {
            "SKIPPED (no simd arm on this host)"
        } else if simd_pass {
            "PASS"
        } else {
            "FAIL"
        }
    );

    let pass = accept_speedup >= 2.0;
    println!(
        "\nacceptance: e2softmax L=1024 B=1 planar-vs-legacy speedup {accept_speedup:.2}x \
         (required >= 2.0x) -> {}",
        if quick_mode() { "SKIPPED (quick mode)" } else if pass { "PASS" } else { "FAIL" }
    );

    if args.flag("json") {
        let default_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json");
        if quick_mode() && args.opt("out").is_none() {
            // never let ~2ms smoke numbers silently replace the committed
            // perf trajectory; smoke runs must name an explicit path
            println!(
                "quick mode: refusing to overwrite {default_out} with smoke numbers \
                 (pass --out <path> to write them elsewhere)"
            );
            return;
        }
        let path = args.opt_str("out", default_out);
        let doc = obj(vec![
            ("bench", Json::Str("bench_kernels".to_string())),
            ("quick", Json::Bool(quick_mode())),
            ("dispatch", Json::Str(detected.as_str().to_string())),
            (
                "units",
                obj(vec![
                    ("mean_ns", Json::Str("mean wall-clock per kernel call, ns".to_string())),
                    ("rows_per_sec", Json::Str("batch rows completed per second".to_string())),
                    (
                        "melem_per_sec",
                        Json::Str(
                            "million input f32 elements per second (attention rows count \
                             the whole [Q|K|V] item, 3*L*D)"
                                .to_string(),
                        ),
                    ),
                    (
                        "staging_bytes_per_item",
                        Json::Str(
                            "attention only: bytes one item's probability matrix occupies \
                             at the softmax->A*V stage boundary (code/f32 payload plus \
                             header sidecar; the V passthrough block, byte-identical on \
                             both paths, excluded)"
                                .to_string(),
                        ),
                    ),
                ]),
            ),
            (
                "acceptance",
                obj(vec![
                    ("shape", Json::Str("e2softmax L=1024 B=1".to_string())),
                    ("required_speedup", Json::Num(2.0)),
                    ("measured_speedup", Json::Num(accept_speedup)),
                    ("pass", Json::Bool(pass && !quick_mode())),
                ]),
            ),
            (
                "acceptance_simd",
                obj(vec![
                    (
                        "shape",
                        Json::Str("e2softmax L=1024 B=4 + ailayernorm C=1024 B=4".to_string()),
                    ),
                    ("dispatch", Json::Str(detected.as_str().to_string())),
                    ("required_speedup", Json::Num(2.0)),
                    ("e2softmax_speedup", Json::Num(accept_simd_sm)),
                    ("ailayernorm_speedup", Json::Num(accept_simd_ln)),
                    ("pass", Json::Bool(simd_pass && simd_active && !quick_mode())),
                ]),
            ),
            ("results", Json::Arr(results)),
        ]);
        let mut text = doc.to_string_compact();
        text.push('\n');
        std::fs::write(path, text).expect("write BENCH_kernels.json");
        println!("wrote {path}");
    }

    if !quick_mode() {
        assert!(
            pass,
            "acceptance regression: planar E2Softmax must be >= 2x legacy at L=1024 B=1 \
             (measured {accept_speedup:.2}x)"
        );
        if simd_active {
            assert!(
                simd_pass,
                "acceptance regression: the {detected} arms must be >= 2x scalar at the 1024 \
                 shapes (e2softmax {accept_simd_sm:.2}x, ailayernorm {accept_simd_ln:.2}x)"
            );
        }
    }
}
