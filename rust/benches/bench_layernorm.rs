//! Bit-exact AILayerNorm vs exact/I-BERT software baselines across the
//! paper's channel widths (DeiT-T 192 ... BERT 768).

use std::time::Duration;

use sole::layernorm::ai::layernorm_exact;
use sole::layernorm::baselines::ibert_layernorm;
use sole::layernorm::AiLayerNorm;
use sole::util::bench::{bench, report};
use sole::util::rng::Rng;

fn main() {
    println!("bench_layernorm — software implementations, rows of C channels");
    let mut rng = Rng::new(2);
    for &c in &[64usize, 192, 384, 768] {
        let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let x: Vec<f32> = codes.iter().map(|&v| (v as f32 - 128.0) / 32.0).collect();
        let ln = AiLayerNorm::default();
        let mut out = vec![0f32; c];
        let r = bench(&format!("ailayernorm C={c}"), Duration::from_millis(300), || {
            ln.forward_row_f32(std::hint::black_box(&codes), &alpha, &gamma, &beta, &mut out);
        });
        report(&r);
        println!("    -> {:.1} M elem/s", c as f64 * r.per_sec() / 1e6);
        report(&bench(&format!("layernorm_exact C={c}"), Duration::from_millis(300), || {
            std::hint::black_box(layernorm_exact(std::hint::black_box(&x), &gamma, &beta, 1e-6));
        }));
        report(&bench(&format!("ibert layernorm C={c}"), Duration::from_millis(300), || {
            std::hint::black_box(ibert_layernorm(std::hint::black_box(&x), &gamma, &beta, 1.0 / 64.0));
        }));
    }
}
