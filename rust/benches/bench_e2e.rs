//! End-to-end PJRT execution: per-batch latency and images/s for every
//! lowered deit_t variant (the serving-side counterpart of Fig 6(b)).
//! Needs artifacts; prints a notice and exits cleanly otherwise.

use std::path::PathBuf;
use std::time::Duration;

use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::bench::{bench, report};

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_e2e: no artifacts (run `make artifacts`) — skipping");
        return;
    }
    let engine = Engine::open(&dir).unwrap();
    let data = Bundle::load(&dir.join("data/cv_eval")).unwrap();
    let xs = data.get("x").unwrap().as_f32().unwrap();
    let item = 32 * 32;
    println!("bench_e2e — PJRT artifact execution (deit_t)");
    for variant in ["fp32", "fp32_sole", "int8", "int8_sole"] {
        let ids = engine.find("deit_t", variant);
        let Some(id) = ids.iter().find(|i| i.ends_with("_b64")) else { continue };
        let m = engine.load(id).unwrap();
        let b = m.batch();
        let input = &xs[..b * item];
        let r = bench(&format!("deit_t/{variant} b{b}"), Duration::from_millis(1500), || {
            std::hint::black_box(m.run_f32(std::hint::black_box(input)).unwrap());
        });
        report(&r);
        println!("    -> {:.1} img/s", b as f64 * r.per_sec());
    }
    // bucketed serving artifacts: latency vs batch for fp32_sole
    for bkt in [1usize, 4, 8, 16] {
        let id = format!("deit_t_fp32_sole_b{bkt}");
        if engine.manifest.get(&id).is_none() {
            continue;
        }
        let m = engine.load(&id).unwrap();
        let input = &xs[..bkt * item];
        let r = bench(&format!("deit_t/fp32_sole bucket b{bkt}"), Duration::from_millis(800), || {
            std::hint::black_box(m.run_f32(std::hint::black_box(input)).unwrap());
        });
        report(&r);
        println!("    -> {:.1} img/s", bkt as f64 * r.per_sec());
    }
}
