//! Coordinator hot path: submit->batch->execute->respond over the software
//! backends (no PJRT), isolating router/batcher overhead — plus a heap
//! allocation audit proving the arena execution path is allocation-free at
//! steady state (the whole point of the per-worker scratch redesign).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sole::coordinator::{Backend, BatchPolicy, Coordinator, OpBackend};
use sole::ops::{AiLayerNormOp, E2SoftmaxOp};
use sole::softmax::{quantize_logits_batch_into, E2Scratch, E2Softmax, E2SoftmaxConfig};
use sole::util::bench::{bench, quick_mode, report};

/// Counting allocator: every heap allocation bumps a global counter, so the
/// steady-state audit below can assert "0 allocs per batch" empirically
/// rather than by inspection.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations observed across `iters` runs of `f`, after warmup.
fn count_allocs<F: FnMut()>(mut f: F, iters: u64) -> u64 {
    f();
    f(); // warm the reusable buffers
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..iters {
        f();
    }
    ALLOCS.load(Ordering::SeqCst) - before
}

fn softmax_backend(l: usize, buckets: Vec<usize>) -> OpBackend {
    OpBackend::try_new(Arc::new(E2SoftmaxOp::try_new(l).expect("row len")), buckets)
        .expect("bucket list")
}

fn layernorm_backend(c: usize, buckets: Vec<usize>) -> OpBackend {
    OpBackend::try_new(Arc::new(AiLayerNormOp::try_new(c).expect("channels")), buckets)
        .expect("bucket list")
}

fn alloc_audit() {
    const L: usize = 128;
    const BUCKET: usize = 16;
    let be = softmax_backend(L, vec![1, 4, 8, 16]);
    let mut rng = sole::util::rng::Rng::new(1);
    let mut inputs = vec![0f32; BUCKET * L];
    rng.fill_normal(&mut inputs, 0.0, 2.0);

    println!("\nallocation audit — {BUCKET}x{L} softmax batch, 100 batches after warmup");

    // legacy path: what the softmax backend's run used to do before the
    // arena redesign — forward_logits per row (introspect vectors + output
    // collection allocate every call)
    let sm = E2Softmax::new(E2SoftmaxConfig::default());
    let mut sink = 0f64;
    let legacy = count_allocs(
        || {
            for row in inputs.chunks(L) {
                let out = sm.forward_logits(row);
                sink += out[0];
            }
        },
        100,
    );

    // arena path: the coordinator's actual steady state — reused codes
    // buffer, E2Scratch, and output staging, one batch-kernel call per run
    let mut scratch = be.make_scratch();
    let mut out = vec![0f32; BUCKET * L];
    let arena = count_allocs(
        || {
            be.run(BUCKET, &inputs, &mut out, &mut scratch).unwrap();
        },
        100,
    );

    // raw batch kernel, below the backend layer: packed quantization +
    // forward_batch_f32 against a reused scratch must also be alloc-free
    let sm2 = E2Softmax::new(E2SoftmaxConfig::default());
    let mut codes: Vec<i64> = Vec::new();
    let mut e2 = E2Scratch::default();
    let kernel = count_allocs(
        || {
            quantize_logits_batch_into(&inputs, L, sm2.cfg().e, &mut codes);
            sm2.forward_batch_f32(&codes, L, &mut out, &mut e2);
        },
        100,
    );
    std::hint::black_box(sink);

    println!(
        "  legacy forward_logits path:  {legacy:>6} allocs / 100 batches ({:.1} per row)",
        legacy as f64 / (100.0 * BUCKET as f64)
    );
    println!(
        "  arena batch-kernel path:     {arena:>6} allocs / 100 batches ({:.1} per row)",
        arena as f64 / (100.0 * BUCKET as f64)
    );
    println!("  raw forward_batch_f32 path:  {kernel:>6} allocs / 100 batches");
    assert_eq!(arena, 0, "steady-state backend execution must not allocate");
    assert_eq!(kernel, 0, "steady-state batch kernel must not allocate");

    // same audit for the layernorm service
    let ln = layernorm_backend(L, vec![1, 4, 8, 16]);
    let mut ln_scratch = ln.make_scratch();
    let ln_allocs = count_allocs(
        || {
            ln.run(BUCKET, &inputs, &mut out, &mut ln_scratch).unwrap();
        },
        100,
    );
    println!("  layernorm arena path:       {ln_allocs:>6} allocs / 100 batches");
    assert_eq!(ln_allocs, 0, "steady-state layernorm execution must not allocate");
}

fn throughput_sweep() {
    // quick mode (CI smoke): shrink the request counts, keep every path
    let n = if quick_mode() { 32 } else { 256 };
    println!("\nthroughput — routing + batching overhead (software softmax backend)");
    let sweeps = [(0u64, 1usize, n), (2, 1, n), (2, 2, n), (2, 4, n), (5, 2, n)];
    for &(wait_ms, workers, nreq) in &sweeps {
        let be = Arc::new(softmax_backend(128, vec![1, 4, 8, 16]));
        let co = Coordinator::start(
            be,
            BatchPolicy {
                max_wait: Duration::from_millis(wait_ms),
                max_batch: 16,
                ..BatchPolicy::default()
            },
            workers,
        );
        let cl = co.client();
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..nreq).map(|_| cl.submit(vec![0.3; 128]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "max_wait={wait_ms}ms workers={workers}: {nreq} reqs in {dt:?} ({:.0} req/s), {}",
            nreq as f64 / dt.as_secs_f64(),
            co.metrics.summary()
        );
        co.shutdown();
    }

    println!("\nthroughput — software layernorm backend, 4 workers");
    let be = Arc::new(layernorm_backend(192, vec![1, 4, 8, 16]));
    let co = Coordinator::start(
        be,
        BatchPolicy { max_wait: Duration::from_millis(2), max_batch: 16, ..BatchPolicy::default() },
        4,
    );
    let cl = co.client();
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n).map(|_| cl.submit(vec![0.4; 192]).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let dt = t0.elapsed();
    println!(
        "layernorm: {n} reqs in {dt:?} ({:.0} req/s), {}",
        n as f64 / dt.as_secs_f64(),
        co.metrics.summary()
    );
    co.shutdown();
}

fn main() {
    println!("bench_coordinator — serving hot path (software backends)");
    alloc_audit();
    throughput_sweep();

    // raw single-request round-trip latency
    let be = Arc::new(softmax_backend(128, vec![1]));
    let co = Coordinator::start(
        be,
        BatchPolicy { max_wait: Duration::ZERO, max_batch: 1, ..BatchPolicy::default() },
        1,
    );
    let cl = co.client();
    report(&bench("single-request round trip", Duration::from_millis(400), || {
        cl.infer(vec![0.3; 128]).unwrap();
    }));
    co.shutdown();
}
