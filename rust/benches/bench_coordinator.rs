//! Coordinator hot path: submit->batch->execute->respond over the software
//! backend (no PJRT), isolating router/batcher overhead.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sole::coordinator::{BatchPolicy, Coordinator, SoftwareSoftmaxBackend};
use sole::util::bench::{bench, report};

fn main() {
    println!("bench_coordinator — routing + batching overhead (software backend)");
    for &(wait_ms, nreq) in &[(0u64, 256usize), (2, 256), (5, 256)] {
        let be = Arc::new(SoftwareSoftmaxBackend::new(128, vec![1, 4, 8, 16]));
        let co = Coordinator::start(
            be,
            BatchPolicy { max_wait: Duration::from_millis(wait_ms), max_batch: 16 },
            2,
        );
        let cl = co.client();
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..nreq).map(|_| cl.submit(vec![0.3; 128]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "max_wait={wait_ms}ms: {nreq} reqs in {dt:?} ({:.0} req/s), {}",
            nreq as f64 / dt.as_secs_f64(),
            co.metrics.summary()
        );
        co.shutdown();
    }
    // raw single-request round-trip latency
    let be = Arc::new(SoftwareSoftmaxBackend::new(128, vec![1]));
    let co = Coordinator::start(be, BatchPolicy { max_wait: Duration::ZERO, max_batch: 1 }, 1);
    let cl = co.client();
    report(&bench("single-request round trip", Duration::from_millis(400), || {
        cl.infer(vec![0.3; 128]).unwrap();
    }));
    co.shutdown();
}
