//! Bit-exact softmax kernels: throughput of the coordinator's software hot
//! path across the paper's row lengths (the shapes behind Fig 6a).

use std::time::Duration;

use sole::softmax::baselines::{ibert_softmax, softermax};
use sole::softmax::e2::{softmax_exact, E2Scratch};
use sole::softmax::{E2Softmax, E2SoftmaxConfig};
use sole::util::bench::{bench, report};
use sole::util::rng::Rng;

fn main() {
    println!("bench_softmax — software implementations, rows of length L");
    let mut rng = Rng::new(1);
    for &l in &[49usize, 128, 785, 1024] {
        let q: Vec<i64> = (0..l).map(|_| -rng.range_i64(0, 256)).collect();
        let x: Vec<f32> = q.iter().map(|&v| v as f32 / 16.0).collect();
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let mut out = vec![0f32; l];
        let mut scratch = E2Scratch::default();
        let r = bench(&format!("e2softmax(chunked-online) L={l}"), Duration::from_millis(300), || {
            sm.forward_row_f32(std::hint::black_box(&q), &mut out, &mut scratch);
        });
        report(&r);
        println!("    -> {:.1} M elem/s", l as f64 * r.per_sec() / 1e6);
        report(&bench(&format!("softmax_exact          L={l}"), Duration::from_millis(300), || {
            std::hint::black_box(softmax_exact(std::hint::black_box(&x)));
        }));
        report(&bench(&format!("softermax baseline     L={l}"), Duration::from_millis(300), || {
            std::hint::black_box(softermax(std::hint::black_box(&x), 8));
        }));
        report(&bench(&format!("ibert baseline         L={l}"), Duration::from_millis(300), || {
            std::hint::black_box(ibert_softmax(std::hint::black_box(&x), 1.0 / 16.0));
        }));
    }
}
