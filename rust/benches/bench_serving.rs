//! Mixed-load serving throughput through the `ServiceRouter` at the
//! paper's shapes, with a machine-readable record (`BENCH_serving.json`)
//! so the serving stack has a perf trajectory alongside the kernel one.
//!
//! One router process serves the full mixed workload — E2Softmax at
//! L ∈ {49, 128, 785, 1024} and AILayerNorm at C = 768 — under an
//! open-loop interleaved burst; per-service throughput and p50/p99/mean
//! latency come from each service's own metrics shards, the merged view
//! from the router's merge-on-read.  Request conservation
//! (`completed + errors == accepted`, errors == 0) is asserted before
//! anything is recorded.
//!
//! Flags: `--json` writes the JSON artifact (default path
//! `<repo>/BENCH_serving.json`, override with `--out <path>`); `--quick`
//! is the CI smoke mode (equivalent to `SOLE_BENCH_QUICK=1`: numbers are
//! meaningless, the point is that every code path executes).

use std::time::Instant;

use sole::coordinator::{paper_services, Backend, BatchPolicy, ServiceRouter};
use sole::util::bench::quick_mode;
use sole::util::cli::Args;
use sole::util::json::{obj, Json};
use sole::util::rng::Rng;

// one worker per paper service: the min-one-per-service floor makes any
// smaller budget silently run 5 threads anyway, and the recorded
// total_workers must match the threads that actually served the load
const TOTAL_WORKERS: usize = 5;

fn main() {
    let args = Args::from_env();
    if args.flag("quick") {
        std::env::set_var("SOLE_BENCH_QUICK", "1");
    }
    let per_service = if quick_mode() { 48 } else { 2048 };
    println!(
        "bench_serving — mixed paper workload through the ServiceRouter \
         ({TOTAL_WORKERS} workers, {per_service} requests/service){}",
        if quick_mode() { " [QUICK smoke mode — numbers meaningless]" } else { "" }
    );

    let services = paper_services();
    let policy =
        BatchPolicy { max_wait: std::time::Duration::from_millis(1), ..BatchPolicy::default() };
    let mut builder = ServiceRouter::builder(TOTAL_WORKERS).default_policy(policy);
    for (name, be) in &services {
        builder = builder.service(name, be.clone());
    }
    let router = builder.start().expect("router start");
    let client = router.client();

    // pre-generate one block of normal rows per service
    let mut rng = Rng::new(0x501E);
    let lanes: Vec<(String, usize, Vec<f32>)> = services
        .iter()
        .map(|(name, be)| {
            let item = be.item_input_len();
            let mut inputs = vec![0f32; 32 * item];
            rng.fill_normal(&mut inputs, 0.0, 2.0);
            (name.clone(), item, inputs)
        })
        .collect();

    // open-loop interleaved burst: every service submits `per_service`
    // requests, round-robin, as fast as the submitter can go
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(per_service * lanes.len());
    for i in 0..per_service {
        for (name, item, inputs) in &lanes {
            let row = i % (inputs.len() / item);
            let input = inputs[row * item..(row + 1) * item].to_vec();
            pending.push(client.submit(name, input).expect("submit"));
        }
    }
    for rx in pending {
        rx.recv().expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let submitted = (per_service * lanes.len()) as u64;

    // conservation before anything is recorded: every accepted request
    // completed, nothing errored, nothing lost
    let mut results: Vec<Json> = Vec::new();
    let mut total_completed = 0u64;
    println!(
        "\n{:>16} {:>4} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "service", "wrk", "rows/s", "p50 ms", "p99 ms", "mean ms", "avg batch"
    );
    for (name, item, _) in &lanes {
        let m = router.metrics(name).expect("registered service");
        assert_eq!(m.accepted(), per_service as u64, "{name}: accepted");
        assert_eq!(m.errors(), 0, "{name}: errors");
        assert_eq!(m.completed() + m.errors(), m.accepted(), "{name}: conservation");
        total_completed += m.completed();
        let (p50, p99, mean) = m.total_latency();
        let rows_per_sec = m.completed() as f64 / wall;
        println!(
            "{:>16} {:>4} {:>10.0} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
            name,
            router.workers(name).unwrap_or(0),
            rows_per_sec,
            p50 * 1e3,
            p99 * 1e3,
            mean * 1e3,
            m.mean_batch(),
        );
        results.push(obj(vec![
            ("service", Json::Str(name.clone())),
            ("item_len", Json::Int(*item as i64)),
            ("workers", Json::Int(router.workers(name).unwrap_or(0) as i64)),
            ("completed", Json::Int(m.completed() as i64)),
            ("rows_per_sec", Json::Num(rows_per_sec)),
            ("p50_ms", Json::Num(p50 * 1e3)),
            ("p99_ms", Json::Num(p99 * 1e3)),
            ("mean_ms", Json::Num(mean * 1e3)),
            ("mean_batch", Json::Num(m.mean_batch())),
        ]));
    }
    assert_eq!(total_completed, submitted, "merged conservation");
    // the recorded budget is the actual thread count (floor-one split)
    let worker_sum: usize = lanes.iter().filter_map(|(n, _, _)| router.workers(n)).sum();
    assert_eq!(worker_sum, TOTAL_WORKERS, "budget must match the served thread count");
    let (mp50, mp99, mmean) = router.merged_latency();
    let merged_rows_per_sec = submitted as f64 / wall;
    println!(
        "\nmerged: {submitted} requests in {wall:.2}s ({merged_rows_per_sec:.0} rows/s), \
         p50 {:.2}ms p99 {:.2}ms mean {:.2}ms",
        mp50 * 1e3,
        mp99 * 1e3,
        mmean * 1e3
    );
    println!("{}", router.summary());
    router.shutdown();

    if args.flag("json") {
        let default_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
        if quick_mode() && args.opt("out").is_none() {
            // never let smoke numbers silently replace the committed perf
            // trajectory; smoke runs must name an explicit path
            println!(
                "quick mode: refusing to overwrite {default_out} with smoke numbers \
                 (pass --out <path> to write them elsewhere)"
            );
            return;
        }
        let path = args.opt_str("out", default_out);
        let doc = obj(vec![
            ("bench", Json::Str("bench_serving".to_string())),
            ("quick", Json::Bool(quick_mode())),
            ("total_workers", Json::Int(TOTAL_WORKERS as i64)),
            ("requests_per_service", Json::Int(per_service as i64)),
            (
                "units",
                obj(vec![
                    (
                        "rows_per_sec",
                        Json::Str("requests completed per wall second, mixed load".to_string()),
                    ),
                    (
                        "p50_ms",
                        Json::Str("median end-to-end latency (queue + exec), ms".to_string()),
                    ),
                    ("p99_ms", Json::Str("p99 end-to-end latency, ms".to_string())),
                ]),
            ),
            (
                "merged",
                obj(vec![
                    ("wall_s", Json::Num(wall)),
                    ("completed", Json::Int(submitted as i64)),
                    ("rows_per_sec", Json::Num(merged_rows_per_sec)),
                    ("p50_ms", Json::Num(mp50 * 1e3)),
                    ("p99_ms", Json::Num(mp99 * 1e3)),
                    ("mean_ms", Json::Num(mmean * 1e3)),
                ]),
            ),
            ("results", Json::Arr(results)),
        ]);
        let mut text = doc.to_string_compact();
        text.push('\n');
        std::fs::write(path, text).expect("write BENCH_serving.json");
        println!("wrote {path}");
    }
}
