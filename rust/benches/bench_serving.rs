//! Serving throughput through the `ServiceRouter` for EVERY registered
//! operator, with a machine-readable record (`BENCH_serving.json`) so the
//! serving stack has a perf trajectory alongside the kernel one — and so
//! SOLE's comparative claim is measured, not asserted: the same table
//! holds `e2softmax` next to `softmax-exact`, `softermax` and
//! `ibert-softmax`, and `ailayernorm` next to `layernorm-exact` and
//! `ibert-layernorm`.
//!
//! One router process serves one service per registry op at its canonical
//! spec (`<op>/<DIM><default-len>`) under an open-loop interleaved burst —
//! which now includes the attention pipelines (`attention/L128xD64` fused,
//! `attention-exact/L128xD64`), joined by a second fused shape
//! (`attention/L49xD64`, the paper's DeiT sequence length) so the table
//! carries an attention row *family*, not a single point.  Per-op
//! throughput and p50/p99/mean latency come from each service's own
//! metrics shards, the merged view from the router's merge-on-read.
//! Request conservation (`completed + errors == accepted`, errors == 0)
//! is asserted before anything is recorded.  Every row carries a
//! `dispatch` field — the SIMD kernel arm the served op selected at
//! construction (DESIGN.md §3.4), `-` for ops with no vectorized kernel
//! — and the document a top-level one, so records from different
//! machines stay comparable.
//!
//! Every row carries a `mode` field: `prefill` for the batching
//! services above, `decode` for the second phase, which registers the
//! stateful `decode-attention` family as a session-affine decode service
//! on the *same* router budget and drives interleaved KV-cache sessions
//! token by token — the serving regime the batching pool cannot express
//! (stateless families sweep as prefill; stateful ones are skipped there
//! and measured here).  Decode rows report tokens/s and per-step
//! latency from the same sharded metrics schema.
//!
//! The third phase (`mode: "overload"`) measures behavior *past*
//! capacity with real sockets in the loop: a dedicated one-worker
//! SlowEcho service (fixed 2ms per row, so capacity is known exactly)
//! behind the TCP front door, hammered by one blocking connection per
//! client thread.  Two legs — shedding disabled (only the bounded
//! queue pushes back, late) vs depth-based admission control (sheds
//! early) — record shed rate and p99 side by side, and the ledger
//! `offered == completed + errors + shed` is asserted against the
//! wire-side counts before anything is written.
//!
//! Workload inputs come from the shared logit-distribution generator
//! (`util::dist`, the same one the accuracy harness samples from), so a
//! row here and a row in `ACCURACY.md` describe the same distribution:
//! the Gaussian leg at `DIST_SIGMA`, seeded from `DIST_SEED` (overload
//! clients derive per-connection seeds as `DIST_SEED + 1000 + client`).
//! Every JSON row records its `workload` name and `seed`.
//!
//! Flags: `--json` writes the JSON artifact (default path
//! `<repo>/BENCH_serving.json`, override with `--out <path>`); `--quick`
//! is the CI smoke mode (equivalent to `SOLE_BENCH_QUICK=1`: numbers are
//! meaningless, the point is that every code path executes).

use std::sync::Arc;
use std::time::{Duration, Instant};

use sole::coordinator::{Backend, BackendScratch, BatchPolicy, ServiceRouter};
use sole::ops::OpRegistry;
use sole::server::{AdmissionConfig, ErrCode, NetClient, Reply, Server, ServerConfig};
use sole::simd::Dispatch;
use sole::util::bench::{quick_mode, set_quick_mode};
use sole::util::cli::Args;
use sole::util::dist::{LogitDist, DIST_SEED};
use sole::util::json::{obj, Json};
use sole::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    if args.flag("quick") {
        set_quick_mode(true);
    }
    let per_service = if quick_mode() { 48 } else { 1024 };

    let registry = OpRegistry::builtin();
    // one worker per service: the min-one-per-service floor makes any
    // smaller budget silently run that many threads anyway, and the
    // recorded total_workers must match the threads that actually served
    let mut specs: Vec<String> = Vec::new();
    for n in registry.names() {
        let spec = registry.canonical_spec(n).expect("registered op").to_string();
        let (_, op) = registry.build(&spec).expect("registered spec");
        if op.stateful() {
            continue; // stateful families get the decode phase below
        }
        specs.push(spec);
    }
    // the attention row family: the canonical fused + exact pipelines are
    // already in the registry sweep; add the paper's DeiT sequence length
    specs.push("attention/L49xD64".to_string());
    // the decode phase: the stateful family at its canonical spec, one
    // lane from the same worker budget
    let decode_spec =
        registry.canonical_spec("decode-attention").expect("registered op").to_string();
    let decode_sessions = 4usize;
    let decode_steps = if quick_mode() { 16 } else { 128 };
    let total_workers = specs.len() + 1;
    println!(
        "bench_serving — every registered op through the ServiceRouter \
         ({total_workers} workers, {per_service} requests/op, then \
         {decode_sessions}x{decode_steps} decode steps){}",
        if quick_mode() { " [QUICK smoke mode — numbers meaningless]" } else { "" }
    );

    let policy =
        BatchPolicy { max_wait: std::time::Duration::from_millis(1), ..BatchPolicy::default() };
    let mut builder = ServiceRouter::builder(total_workers).default_policy(policy);
    for spec in &specs {
        builder = builder.op_service(&registry, spec, vec![1, 4, 8, 16]).expect("registry spec");
    }
    builder = builder.decode_service(&registry, &decode_spec, 1).expect("decode spec");
    let router = builder.start().expect("router start");
    let client = router.client();

    // pre-generate one block of rows per service from the shared
    // Gaussian workload leg (util::dist — the accuracy harness samples
    // the same distribution at the same σ); a throwaway registry build
    // of the same spec reports which kernel arm the served instances
    // dispatched to (construction is deterministic)
    let mut rng = Rng::new(DIST_SEED);
    let lanes: Vec<(String, usize, String, Vec<f32>)> = specs
        .iter()
        .map(|spec| {
            let item = client.item_len(spec).expect("registered service");
            let (_, op) = registry.build(spec).expect("registered spec");
            let dispatch = op.dispatch().map_or("-", |d| d.as_str()).to_string();
            let mut inputs = vec![0f32; 32 * item];
            LogitDist::Gaussian.fill_batch(&mut rng, item, &mut inputs);
            (spec.clone(), item, dispatch, inputs)
        })
        .collect();

    // open-loop interleaved burst: every service submits `per_service`
    // requests, round-robin, as fast as the submitter can go
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(per_service * lanes.len());
    for i in 0..per_service {
        for (name, item, _, inputs) in &lanes {
            let row = i % (inputs.len() / item);
            let input = inputs[row * item..(row + 1) * item].to_vec();
            pending.push(client.submit(name, input).expect("submit"));
        }
    }
    for rx in pending {
        rx.recv().expect("response");
    }
    let wall = t0.elapsed().as_secs_f64();
    let submitted = (per_service * lanes.len()) as u64;

    // conservation before anything is recorded: every accepted request
    // completed, nothing errored, nothing lost
    let mut results: Vec<Json> = Vec::new();
    let mut total_completed = 0u64;
    println!(
        "\n{:>20} {:>4} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "op service", "wrk", "rows/s", "p50 ms", "p99 ms", "mean ms", "avg batch"
    );
    for (name, item, dispatch, _) in &lanes {
        let m = router.metrics(name).expect("registered service");
        assert_eq!(m.accepted(), per_service as u64, "{name}: accepted");
        assert_eq!(m.errors(), 0, "{name}: errors");
        assert_eq!(m.completed() + m.errors(), m.accepted(), "{name}: conservation");
        total_completed += m.completed();
        let (p50, p99, mean) = m.total_latency();
        let rows_per_sec = m.completed() as f64 / wall;
        println!(
            "{:>20} {:>4} {:>10.0} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
            name,
            router.workers(name).unwrap_or(0),
            rows_per_sec,
            p50 * 1e3,
            p99 * 1e3,
            mean * 1e3,
            m.mean_batch(),
        );
        let op = name.split('/').next().unwrap_or(name.as_str()).to_string();
        results.push(obj(vec![
            ("op", Json::Str(op)),
            ("spec", Json::Str(name.clone())),
            ("mode", Json::Str("prefill".to_string())),
            ("workload", Json::Str(LogitDist::Gaussian.name().to_string())),
            ("seed", Json::Int(DIST_SEED as i64)),
            ("item_len", Json::Int(*item as i64)),
            ("dispatch", Json::Str(dispatch.clone())),
            ("workers", Json::Int(router.workers(name).unwrap_or(0) as i64)),
            ("completed", Json::Int(m.completed() as i64)),
            ("rows_per_sec", Json::Num(rows_per_sec)),
            ("p50_ms", Json::Num(p50 * 1e3)),
            ("p99_ms", Json::Num(p99 * 1e3)),
            ("mean_ms", Json::Num(mean * 1e3)),
            ("mean_batch", Json::Num(m.mean_batch())),
        ]));
    }
    assert_eq!(total_completed, submitted, "merged conservation");
    // the recorded budget is the actual thread count (floor-one split),
    // decode lane included
    let worker_sum: usize = lanes.iter().filter_map(|(n, _, _, _)| router.workers(n)).sum::<usize>()
        + router.workers(&decode_spec).expect("decode service");
    assert_eq!(worker_sum, total_workers, "budget must match the served thread count");

    // decode phase: interleaved KV-cache sessions, one token per request,
    // so every step depends on server-side state from the previous one
    let decode_item = client.decode_item_len(&decode_spec).expect("decode service");
    let (_, decode_op) = registry.build(&decode_spec).expect("registered spec");
    let decode_dispatch = decode_op.dispatch().map_or("-", |d| d.as_str()).to_string();
    let mut step = vec![0f32; decode_item];
    let d0 = Instant::now();
    for _ in 0..decode_steps {
        let rxs: Vec<_> = (0..decode_sessions as u64)
            .map(|sid| {
                rng.fill_normal(&mut step, 0.0, 1.0);
                client.submit_decode(&decode_spec, sid, step.clone()).expect("decode submit")
            })
            .collect();
        for rx in rxs {
            rx.recv().expect("decode response");
        }
    }
    let decode_wall = d0.elapsed().as_secs_f64();
    let dm = router.metrics(&decode_spec).expect("decode service");
    let decode_completed = (decode_sessions * decode_steps) as u64;
    assert_eq!(dm.accepted(), decode_completed, "{decode_spec}: accepted");
    assert_eq!(dm.errors(), 0, "{decode_spec}: errors");
    assert_eq!(dm.completed(), decode_completed, "{decode_spec}: conservation");
    let (dp50, dp99, dmean) = dm.total_latency();
    let tokens_per_sec = decode_completed as f64 / decode_wall;
    println!(
        "{:>20} {:>4} {:>10.0} {:>10.2} {:>10.2} {:>10.2} {:>10}",
        decode_spec,
        router.workers(&decode_spec).unwrap_or(0),
        tokens_per_sec,
        dp50 * 1e3,
        dp99 * 1e3,
        dmean * 1e3,
        format!("{}sess", router.sessions(&decode_spec).unwrap_or(0)),
    );
    results.push(obj(vec![
        ("op", Json::Str("decode-attention".to_string())),
        ("spec", Json::Str(decode_spec.clone())),
        ("mode", Json::Str("decode".to_string())),
        ("workload", Json::Str(LogitDist::Gaussian.name().to_string())),
        ("seed", Json::Int(DIST_SEED as i64)),
        ("item_len", Json::Int(decode_item as i64)),
        ("dispatch", Json::Str(decode_dispatch)),
        ("workers", Json::Int(router.workers(&decode_spec).unwrap_or(0) as i64)),
        ("sessions", Json::Int(decode_sessions as i64)),
        ("steps_per_session", Json::Int(decode_steps as i64)),
        ("completed", Json::Int(decode_completed as i64)),
        ("rows_per_sec", Json::Num(tokens_per_sec)),
        ("p50_ms", Json::Num(dp50 * 1e3)),
        ("p99_ms", Json::Num(dp99 * 1e3)),
        ("mean_ms", Json::Num(dmean * 1e3)),
        ("mean_batch", Json::Num(dm.mean_batch())),
    ]));

    let (mp50, mp99, mmean) = router.merged_latency();
    let merged_rows_per_sec = submitted as f64 / wall;
    println!(
        "\nmerged: {submitted} requests in {wall:.2}s ({merged_rows_per_sec:.0} rows/s), \
         p50 {:.2}ms p99 {:.2}ms mean {:.2}ms",
        mp50 * 1e3,
        mp99 * 1e3,
        mmean * 1e3
    );
    println!("{}", router.summary());
    router.shutdown();

    // overload phase: the front door past capacity, shed vs no-shed
    let n_clients = 12usize;
    let per_client = if quick_mode() { 6 } else { 20 };
    println!(
        "\noverload phase: slow/L32 (1 worker, 2ms/row) behind the TCP front door, \
         {n_clients} blocking connections x {per_client} requests"
    );
    println!(
        "{:>20} {:>8} {:>10} {:>8} {:>10} {:>10}",
        "shed policy", "offered", "completed", "shed", "shed rate", "p99 ms"
    );
    results.push(overload_leg("none", AdmissionConfig::default(), n_clients, per_client));
    results.push(overload_leg(
        "depth4",
        AdmissionConfig { max_queue_depth: Some(4), max_in_flight: None, max_p99: None },
        n_clients,
        per_client,
    ));

    if args.flag("json") {
        let default_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
        if quick_mode() && args.opt("out").is_none() {
            // never let smoke numbers silently replace the committed perf
            // trajectory; smoke runs must name an explicit path
            println!(
                "quick mode: refusing to overwrite {default_out} with smoke numbers \
                 (pass --out <path> to write them elsewhere)"
            );
            return;
        }
        let path = args.opt_str("out", default_out);
        let doc = obj(vec![
            ("bench", Json::Str("bench_serving".to_string())),
            ("quick", Json::Bool(quick_mode())),
            ("dispatch", Json::Str(Dispatch::detect().as_str().to_string())),
            ("total_workers", Json::Int(total_workers as i64)),
            ("requests_per_service", Json::Int(per_service as i64)),
            (
                "units",
                obj(vec![
                    (
                        "rows_per_sec",
                        Json::Str(
                            "requests completed per wall second, mixed load \
                             (decode rows: tokens/s across the interleaved sessions)"
                                .to_string(),
                        ),
                    ),
                    (
                        "mode",
                        Json::Str(
                            "prefill = batching service sweep; decode = session-affine \
                             KV-cache phase"
                                .to_string(),
                        ),
                    ),
                    (
                        "p50_ms",
                        Json::Str("median end-to-end latency (queue + exec), ms".to_string()),
                    ),
                    ("p99_ms", Json::Str("p99 end-to-end latency, ms".to_string())),
                    (
                        "workload",
                        Json::Str(
                            "util::dist logit distribution the inputs were sampled \
                             from (shared with the accuracy harness)"
                                .to_string(),
                        ),
                    ),
                    (
                        "seed",
                        Json::Str(
                            "base RNG seed (DIST_SEED); overload clients derive \
                             seed + 1000 + client"
                                .to_string(),
                        ),
                    ),
                ]),
            ),
            (
                "merged",
                obj(vec![
                    ("wall_s", Json::Num(wall)),
                    ("completed", Json::Int(submitted as i64)),
                    ("rows_per_sec", Json::Num(merged_rows_per_sec)),
                    ("p50_ms", Json::Num(mp50 * 1e3)),
                    ("p99_ms", Json::Num(mp99 * 1e3)),
                    ("mean_ms", Json::Num(mmean * 1e3)),
                ]),
            ),
            ("results", Json::Arr(results)),
        ]);
        let mut text = doc.to_string_compact();
        text.push('\n');
        std::fs::write(path, text).expect("write BENCH_serving.json");
        println!("wrote {path}");
    }
}

/// A backend with exactly known capacity: echoes its input after a
/// fixed sleep, batch size pinned to 1, so one worker serves precisely
/// `1/delay` rows per second and overload is a property of the offered
/// load, not of kernel speed on the host.
struct SlowEcho {
    item: usize,
    delay: Duration,
    buckets: Vec<usize>,
}

impl Backend for SlowEcho {
    fn item_input_len(&self) -> usize {
        self.item
    }
    fn item_output_len(&self) -> usize {
        self.item
    }
    fn buckets(&self) -> &[usize] {
        &self.buckets
    }
    fn run(
        &self,
        _bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        _scratch: &mut BackendScratch,
    ) -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        out.copy_from_slice(inputs);
        Ok(())
    }
}

/// One overload leg: a fresh one-worker router + front door, hammered
/// by `n_clients` blocking connections, `per_client` requests each.
/// Returns the JSON record row after asserting the shed ledger against
/// the wire-side counts.
fn overload_leg(
    policy_label: &str,
    admission: AdmissionConfig,
    n_clients: usize,
    per_client: usize,
) -> Json {
    const ITEM: usize = 32;
    let backend =
        Arc::new(SlowEcho { item: ITEM, delay: Duration::from_millis(2), buckets: vec![1] });
    let policy =
        BatchPolicy { max_wait: Duration::from_millis(1), max_batch: 1, queue_cap: Some(16) };
    let router = ServiceRouter::builder(1)
        .default_policy(policy)
        .service("slow", backend)
        .start()
        .expect("overload router");
    let cfg = ServerConfig {
        conn_threads: n_clients,
        pending_conns: n_clients,
        admission,
        rebalance: None,
        ..ServerConfig::default()
    };
    let server = Server::start(router, "127.0.0.1:0", cfg).expect("server start");
    let addr = server.addr().to_string();

    let mut handles = Vec::with_capacity(n_clients);
    for c in 0..n_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            // per-connection seed derived from the shared workload base
            let mut rng = Rng::new(DIST_SEED + 1000 + c as u64);
            let mut row = vec![0f32; ITEM];
            LogitDist::Gaussian.fill_row(&mut rng, &mut row);
            let mut cl = NetClient::connect(&addr, Duration::from_secs(30)).expect("connect");
            let (mut done, mut shed) = (0u64, 0u64);
            for _ in 0..per_client {
                match cl.infer("slow", &row).expect("round trip") {
                    Reply::Output(r) => {
                        assert_eq!(r.output.len(), ITEM, "echo length");
                        done += 1;
                    }
                    Reply::Rejected(e) => {
                        assert_eq!(e.code, ErrCode::Shed, "unexpected rejection: {e}");
                        shed += 1;
                    }
                    Reply::Text(t) => panic!("unexpected text reply: {t}"),
                }
            }
            (done, shed)
        }));
    }
    let (mut completed, mut shed) = (0u64, 0u64);
    for h in handles {
        let (d, s) = h.join().expect("client thread");
        completed += d;
        shed += s;
    }
    let offered = (n_clients * per_client) as u64;

    let router = server.shutdown().expect("server shutdown");
    let m = router.metrics("slow").expect("slow service").clone();
    router.shutdown();

    // the ledger, with real sockets in the loop: what the clients saw is
    // exactly what the router accounted for
    assert_eq!(m.offered(), offered, "{policy_label}: every wire request is offered");
    assert_eq!(m.errors(), 0, "{policy_label}: errors");
    assert_eq!(m.completed(), completed, "{policy_label}: wire completions match");
    assert_eq!(m.shed(), shed, "{policy_label}: wire sheds match");
    assert_eq!(
        m.completed() + m.errors() + m.shed(),
        m.offered(),
        "{policy_label}: conservation"
    );
    let (_, p99, mean) = m.total_latency();
    let shed_rate = shed as f64 / offered as f64;
    println!(
        "{:>20} {:>8} {:>10} {:>8} {:>9.1}% {:>10.2}",
        policy_label,
        offered,
        completed,
        shed,
        shed_rate * 100.0,
        p99 * 1e3
    );
    obj(vec![
        ("op", Json::Str("slow-echo".to_string())),
        ("spec", Json::Str("slow/L32".to_string())),
        ("mode", Json::Str("overload".to_string())),
        ("workload", Json::Str(LogitDist::Gaussian.name().to_string())),
        ("seed", Json::Int(DIST_SEED as i64)),
        ("shed_policy", Json::Str(policy_label.to_string())),
        ("workers", Json::Int(1)),
        ("conn_threads", Json::Int(n_clients as i64)),
        ("offered", Json::Int(offered as i64)),
        ("completed", Json::Int(m.completed() as i64)),
        ("shed", Json::Int(m.shed() as i64)),
        ("shed_rate", Json::Num(shed_rate)),
        ("p99_ms", Json::Num(p99 * 1e3)),
        ("mean_ms", Json::Num(mean * 1e3)),
    ])
}
