//! Hardware-model evaluation speed + the Table III / Fig 6(a) numbers as a
//! bench target (regenerates the paper's efficiency rows).

use std::time::Duration;

use sole::experiments;
use sole::hw::units::{AiLayerNormUnit, E2SoftmaxUnit, HwUnit, NnLutLayerNormUnit, SoftermaxUnit};
use sole::util::bench::{bench, report};

fn main() {
    println!("bench_hw_units — cycle/energy/area model evaluation");
    let sm = E2SoftmaxUnit::default();
    let soft = SoftermaxUnit::default();
    let ln = AiLayerNormUnit::default();
    let nn = NnLutLayerNormUnit::default();
    report(&bench("e2softmax_unit energy+area model", Duration::from_millis(200), || {
        std::hint::black_box((sm.energy_per_row(785), sm.area()));
    }));
    report(&bench("softermax_unit energy+area model", Duration::from_millis(200), || {
        std::hint::black_box((soft.energy_per_row(785), soft.area()));
    }));
    report(&bench("ailayernorm_unit energy+area model", Duration::from_millis(200), || {
        std::hint::black_box((ln.energy_per_row(192), ln.area()));
    }));
    report(&bench("nnlut_unit energy+area model", Duration::from_millis(200), || {
        std::hint::black_box((nn.energy_per_row(192), nn.area()));
    }));
    // regenerate the paper tables that depend only on the models
    experiments::table3::run().print();
    experiments::fig6::run_a(&[1, 2, 4, 8, 16]).print();
    experiments::fig6::run_b(&[1, 4, 8, 16]).print();
    experiments::fig1::run(8).print();
    experiments::compress_error::run().print();
}
