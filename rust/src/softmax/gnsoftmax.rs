//! GN-Softmax — guaranteed-normalization softmax (Choi et al., arxiv
//! 2604.23647), functional model.
//!
//! The design removes both reductions while keeping a *hard* bound on
//! the row sum.  Each element is quantized to a power of two against a
//! calibration reference μ (not the row max — μ is a frozen constant):
//!
//! ```text
//! c_i = clamp(round((x_i - μ) · log2 e), -R, 0)     // 4-bit code
//! y_i = 2^(c_i - S),  S = ceil(log2 L)
//! ```
//!
//! Since every `c_i ≤ 0` and `2^S ≥ L`, the row sum obeys
//! `Σ y_i ≤ L · 2^-S ≤ 1` for *any* input — normalization is guaranteed
//! by construction, with no sum ever computed.  Like ConSmax the map is
//! elementwise, so chunked streaming is bit-identical to the whole-row
//! kernel; unlike ConSmax every output is an exact power of two, so the
//! kernel involves no floating-point rounding at all (the only
//! real-valued step is the code quantization) and its outputs are
//! platform-exact.

use super::consmax::pow2_f32;

/// Code depth R of the power-of-two quantizer: codes span [-R, 0]
/// (a 4-bit magnitude, matching the paper's exponent bitwidth and the
/// E2Softmax k range).
pub const GN_CODE_RANGE: i64 = 15;

/// Reference logit std-dev the default μ is calibrated against (the
/// Gaussian leg of `util/dist.rs`, same reference as ConSmax).
pub const GN_SIGMA_REF: f64 = 2.0;

/// Frozen GN-Softmax parameters.
#[derive(Debug, Clone, Copy)]
pub struct GnSoftmaxConfig {
    /// Calibration reference μ standing in for the row max.
    pub mu: f64,
    /// Denominator shift S (the row length's `ceil(log2 L)`).
    pub shift: u32,
}

/// One GN-Softmax instance (stateless beyond its frozen config).
pub struct GnSoftmax {
    cfg: GnSoftmaxConfig,
}

/// `ceil(log2 l)` for `l >= 1` — the denominator shift that makes the
/// sum bound airtight (`2^shift >= l`).
pub fn shift_for_len(l: usize) -> u32 {
    assert!(l > 0, "gn-softmax rows must be non-empty");
    (usize::BITS - (l - 1).leading_zeros()).min(63)
}

impl GnSoftmax {
    /// Build from explicit parameters.  Panics on a non-finite μ or a
    /// shift outside the f32 exponent budget (construction-time
    /// programmer errors).
    pub fn new(cfg: GnSoftmaxConfig) -> GnSoftmax {
        assert!(cfg.mu.is_finite(), "gn-softmax mu must be finite");
        assert!(
            (cfg.shift as i64) + GN_CODE_RANGE <= 126,
            "gn-softmax shift {} overflows the f32 exponent range",
            cfg.shift
        );
        GnSoftmax { cfg }
    }

    /// The registered calibration for rows of length `l`: shift =
    /// ceil(log2 l), and μ = σ·√(2 ln l) — the expected maximum of `l`
    /// draws from N(0, σ²) at σ = [`GN_SIGMA_REF`], i.e. the constant
    /// that best impersonates the row max the quantizer can no longer
    /// compute.
    pub fn for_len(l: usize) -> GnSoftmax {
        let shift = shift_for_len(l);
        let mu = GN_SIGMA_REF * (2.0 * (l as f64).ln()).sqrt();
        GnSoftmax::new(GnSoftmaxConfig { mu, shift })
    }

    /// The (construction-frozen) parameters.
    pub fn cfg(&self) -> GnSoftmaxConfig {
        self.cfg
    }

    /// One element through the quantizer.  NaN logits map to probability
    /// 0 (treated as -inf); everything else lands on an exact power of
    /// two in [2^-(R+S), 2^-S].
    #[inline]
    pub fn forward_elem(&self, x: f32) -> f32 {
        if x.is_nan() {
            return 0.0;
        }
        let d = (x as f64 - self.cfg.mu) * std::f64::consts::LOG2_E;
        // `as i64` saturates on overflow, so ±inf and huge logits clamp
        // cleanly into the code range
        let c = (d.round() as i64).clamp(-GN_CODE_RANGE, 0);
        pow2_f32((c - self.cfg.shift as i64) as i32)
    }

    /// Elementwise kernel over any slice — the streaming primitive
    /// (arbitrary chunk splits concatenate bit-identically).
    pub fn forward_chunk(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), out.len(), "gn-softmax chunk out len mismatch");
        for (o, &v) in out.iter_mut().zip(x) {
            *o = self.forward_elem(v);
        }
    }

    /// One whole row (identical math to `forward_chunk`).
    pub fn forward_row_f32(&self, x: &[f32], out: &mut [f32]) {
        self.forward_chunk(x, out);
    }

    /// Packed planar batch of rows of length `l` — bit-exact to per-row
    /// `forward_row_f32`.
    pub fn forward_batch_f32(&self, x: &[f32], l: usize, out: &mut [f32]) {
        assert!(l > 0, "gn-softmax rows must be non-empty");
        assert!(x.len() % l == 0, "packed batch len {} is not a multiple of {l}", x.len());
        assert!(x.len() == out.len(), "out len {} != batch len {}", out.len(), x.len());
        self.forward_chunk(x, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::e2::softmax_exact;
    use crate::util::proptest::{check, size};
    use crate::util::rng::Rng;

    fn gen(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * GN_SIGMA_REF) as f32).collect()
    }

    #[test]
    fn shift_is_ceil_log2() {
        for (l, s) in [(1usize, 0u32), (2, 1), (3, 2), (4, 2), (5, 3), (128, 7), (4096, 12)] {
            assert_eq!(shift_for_len(l), s, "l={l}");
            assert!(1u64 << shift_for_len(l) >= l as u64);
        }
    }

    #[test]
    fn sum_is_guaranteed_at_most_one_for_any_input() {
        // adversarial sweep: uniform huge logits, all-equal rows, mixed
        // infinities — the bound must hold unconditionally
        check("gn-sum-bound", 80, 0x61B, |rng| {
            let n = size(rng, 4096);
            let sm = GnSoftmax::for_len(n);
            let mode = rng.range_usize(0, 4);
            let x: Vec<f32> = (0..n)
                .map(|_| match mode {
                    0 => (rng.normal() * GN_SIGMA_REF) as f32,
                    1 => 1e30,
                    2 => f32::INFINITY,
                    _ => (rng.f64() * 200.0 - 100.0) as f32,
                })
                .collect();
            let mut out = vec![0f32; n];
            sm.forward_row_f32(&x, &mut out);
            let sum: f64 = out.iter().map(|&v| v as f64).sum();
            assert!(sum <= 1.0 + 1e-12, "n={n} mode={mode} sum={sum}");
            for &v in &out {
                assert!(v > 0.0, "outputs are positive powers of two");
            }
        });
    }

    #[test]
    fn outputs_are_exact_powers_of_two() {
        let mut rng = Rng::new(7);
        let n = 256;
        let x = gen(&mut rng, n);
        let sm = GnSoftmax::for_len(n);
        let mut out = vec![0f32; n];
        sm.forward_row_f32(&x, &mut out);
        for &v in &out {
            // one mantissa bit set, nothing else
            assert_eq!(v.to_bits() & 0x007f_ffff, 0, "{v} is not a power of two");
        }
    }

    #[test]
    fn chunked_concatenation_is_bitwise_whole_row() {
        check("gn-chunked", 60, 0x61C, |rng| {
            let n = size(rng, 512);
            let x = gen(rng, n);
            let sm = GnSoftmax::for_len(n);
            let mut whole = vec![0f32; n];
            sm.forward_row_f32(&x, &mut whole);
            for &chunk in &[1usize, 7, 64, n] {
                let mut cat = Vec::with_capacity(n);
                for piece in x.chunks(chunk) {
                    let mut o = vec![0f32; piece.len()];
                    sm.forward_chunk(piece, &mut o);
                    cat.extend_from_slice(&o);
                }
                assert_eq!(cat, whole, "chunk={chunk} n={n}");
            }
        });
    }

    #[test]
    fn batch_matches_rows_bitwise() {
        let l = 96;
        let b = 5;
        let mut rng = Rng::new(29);
        let x = gen(&mut rng, b * l);
        let sm = GnSoftmax::for_len(l);
        let mut batch = vec![0f32; b * l];
        sm.forward_batch_f32(&x, l, &mut batch);
        let mut row = vec![0f32; l];
        for r in 0..b {
            sm.forward_row_f32(&x[r * l..(r + 1) * l], &mut row);
            assert_eq!(&batch[r * l..(r + 1) * l], &row[..], "row {r}");
        }
    }

    #[test]
    fn tracks_exact_softmax_on_the_calibrated_distribution() {
        // the power-of-two grid + frozen μ are coarse; pin the order of
        // magnitude (the accuracy harness records the measured values)
        let mut rng = Rng::new(11);
        let mut worst: f64 = 0.0;
        for _ in 0..20 {
            let x = gen(&mut rng, 64);
            let sm = GnSoftmax::for_len(64);
            let exact = softmax_exact(&x);
            let mut out = vec![0f32; 64];
            sm.forward_row_f32(&x, &mut out);
            for (o, e) in out.iter().zip(&exact) {
                worst = worst.max((*o as f64 - e).abs());
            }
        }
        assert!(worst < 0.5, "worst {worst}");
    }

    #[test]
    fn monotone_on_the_code_grid() {
        check("gn-monotone", 40, 0x61D, |rng| {
            let n = size(rng, 200).max(2);
            let x = gen(rng, n);
            let sm = GnSoftmax::for_len(n);
            let mut out = vec![0f32; n];
            sm.forward_row_f32(&x, &mut out);
            for i in 0..n {
                for j in 0..n {
                    if x[i] > x[j] {
                        assert!(out[i] >= out[j], "i={i} j={j}");
                    }
                }
            }
        });
    }

    #[test]
    fn nan_maps_to_zero_and_infinities_clamp() {
        let sm = GnSoftmax::for_len(8);
        assert_eq!(sm.forward_elem(f32::NAN), 0.0);
        // +inf pins the top code (2^-shift), -inf the bottom code
        let top = pow2_f32(-(sm.cfg().shift as i32));
        let bottom = pow2_f32(-(GN_CODE_RANGE as i32) - sm.cfg().shift as i32);
        assert_eq!(sm.forward_elem(f32::INFINITY), top);
        assert_eq!(sm.forward_elem(f32::NEG_INFINITY), bottom);
    }
}
