//! Log2Exp Unit — Eq. (7)/(8): k = clip(round(-x/ln2), 0, 15) implemented
//! as the shift-add datapath `x + x>>1 - x>>4` (1/ln2 ~ 1.4375).
//!
//! Bit-exact twin of `ref.log2exp_int`; the hardware unit is two shifters,
//! two adders and a rounder — no LUT, no multiplier.

use super::config::{K_MAX, LOG2EXP_F};

/// Log2Exp on an integer code difference `d <= 0` whose real value is
/// `d * 2^-e`.  Returns k in [0, 15] with exp(d * 2^-e) ~ 2^-k.
#[inline]
pub fn log2exp(d: i64, e: u32) -> i64 {
    debug_assert!(d <= 0, "Log2Exp domain is (-inf, 0], got {d}");
    let f = LOG2EXP_F;
    let v = d << f;
    // v * 1.4375 with arithmetic (floor) shifts, exactly as the RTL would
    let t = v + (v >> 1) - (v >> 4);
    // round-half-up of (-t) / 2^(f+e)
    let k = (-t + (1 << (f + e - 1))) >> (f + e);
    k.min(K_MAX)
}

/// Vectorized helper used by the coordinator's software-fallback path.
pub fn log2exp_slice(out: &mut [i64], d: &[i64], e: u32) {
    debug_assert_eq!(out.len(), d.len());
    for (o, &di) in out.iter_mut().zip(d) {
        *o = log2exp(di, e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn zero_maps_to_zero() {
        assert_eq!(log2exp(0, 4), 0);
    }

    #[test]
    fn saturates_at_15() {
        assert_eq!(log2exp(-255, 4), 15);
        assert_eq!(log2exp(-1000, 4), 15);
    }

    #[test]
    fn known_values_e4() {
        // d = -16 -> x = -1.0 -> -x/ln2 ~ 1.4427, shift-add gives 1.4375 -> k=1
        assert_eq!(log2exp(-16, 4), 1);
        // d = -8 -> x = -0.5 -> ~0.72 -> rounds to 1
        assert_eq!(log2exp(-8, 4), 1);
        // d = -1 -> x = -1/16 -> 0.0899 -> rounds to 0
        assert_eq!(log2exp(-1, 4), 0);
    }

    #[test]
    fn monotone_nonincreasing_input_nondecreasing_k() {
        let mut last = 0;
        for d in 0..=255 {
            let k = log2exp(-d, 4);
            assert!(k >= last, "d={d}");
            last = k;
        }
    }

    #[test]
    fn within_one_of_ideal() {
        check("log2exp-vs-ideal", 300, 17, |rng| {
            let d = -rng.range_i64(0, 256);
            let e = rng.range_i64(3, 7) as u32;
            let k = log2exp(d, e);
            let ideal = (-(d as f64) * 2f64.powi(-(e as i32)) / std::f64::consts::LN_2)
                .round()
                .clamp(0.0, 15.0) as i64;
            assert!((k - ideal).abs() <= 1, "d={d} e={e} k={k} ideal={ideal}");
        });
    }

    #[test]
    fn slice_matches_scalar() {
        let d: Vec<i64> = (0..64).map(|i| -(i * 3) % 256).collect();
        let mut out = vec![0i64; 64];
        log2exp_slice(&mut out, &d, 4);
        for (i, &di) in d.iter().enumerate() {
            assert_eq!(out[i], log2exp(di, 4));
        }
    }
}
