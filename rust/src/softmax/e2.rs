//! E2Softmax — Algorithm 1, bit-exact integer model.
//!
//! Single pass (stage 1): running max + Log2Exp + online sum with shift
//! rescaling; stage 2: per-element correction + Approximate Log-based
//! Division.  `chunk = 1` is Algorithm 1 verbatim; `chunk = V` models the
//! V-lane E2Softmax Unit (local max per slice via the comparison tree) and
//! matches the Pallas kernel.
//!
//! This is also the coordinator's software hot path, so next to the
//! introspection model there is a planar, LUT-driven kernel
//! (`forward_row_f32` / `forward_batch_f32`): stage 1 is one indexed load
//! per element out of the precomputed [`Log2ExpTable`] (k and the Q(.15)
//! summand together), the running max is stored per *slice* rather than
//! per element, and stage 2 collapses to `val[k[i] + sub_slice]` against a
//! per-row table of the ≤ 31 reachable ALDivision outputs.  Both kernels
//! are allocation-free given a reusable [`E2Scratch`] and bit-exact to
//! `forward_introspect` (enforced by tests at every shape).

use super::aldivision::{aldivision, q23_to_f64};
use super::config::{DEFAULT_E, SUM_FRAC};
use super::log2exp::{log2exp, Log2ExpTable};
use crate::simd::Dispatch;

/// Configuration of the E2Softmax datapath.
#[derive(Debug, Clone, Copy)]
pub struct E2SoftmaxConfig {
    /// Power-of-two input scale exponent: input real value = code * 2^-e.
    pub e: u32,
    /// Lane count of the simulated unit (1 = Algorithm 1 verbatim,
    /// 32 = the paper's vector size).
    pub chunk: usize,
}

impl Default for E2SoftmaxConfig {
    fn default() -> Self {
        E2SoftmaxConfig { e: DEFAULT_E, chunk: 32 }
    }
}

/// Full per-row output with intermediates (golden tests pin all of them).
#[derive(Debug, Clone)]
pub struct E2SoftmaxOut {
    /// 4-bit Log2Exp codes per element.
    pub k: Vec<i64>,
    /// Running max (the slice's reference max) per element.
    pub running_max: Vec<i64>,
    /// Final reduced sum, Q(.15).
    pub sum_q15: u64,
    /// Q(.23) output values.
    pub out_q23: Vec<i64>,
    /// 8-bit output codes (scale 2^-8).
    pub out_u8: Vec<u8>,
}

impl E2SoftmaxOut {
    pub fn out_f64(&self) -> Vec<f64> {
        self.out_q23.iter().map(|&v| q23_to_f64(v)).collect()
    }
}

/// Stage 2 indexes `val[k + sub]` with k, sub in [0, 15]: 31 reachable
/// entries, padded to 32.  Consumers of the `Log2Code5` port rebuild
/// this table per row from the compact [`CODE_SIDE_LEN`]-f32 divider
/// header via [`expand_row_side`].
pub const VAL_TABLE_LEN: usize = 32;

/// f32 sidecar elements per code row on the `Log2Code5` port
/// (`ops/port.rs`): the row's divider header `[c, base_shift]`.  Both
/// round-trip f32 exactly — the ALDivision constants are < 2^24 and
/// `base_shift` is a small positive integer — so shipping the header
/// instead of the expanded [`VAL_TABLE_LEN`]-entry table loses nothing
/// and shrinks the sidecar 16x.
pub const CODE_SIDE_LEN: usize = 2;

/// Per-row ALDivision constants: every reachable divider output is
/// `(c >> (ti + base_shift)) * 2^-23` — the whole dequantization table
/// in two small integers.
#[derive(Clone, Copy)]
struct RowDivider {
    c: i64,
    base_shift: i64,
}

/// Expand `(c, base_shift)` into the full shift table.  Shared by the
/// f32 row kernel and every `Log2Code5` consumer, so both sides of the
/// port dequantize through literally the same code.
fn expand_table(c: i64, base_shift: i64) -> [f32; VAL_TABLE_LEN] {
    let inv = 1.0f32 / (1i64 << super::config::ALDIV_Q) as f32;
    let mut val = [0f32; VAL_TABLE_LEN];
    for (ti, v) in val.iter_mut().enumerate() {
        let shift = ti as i64 + base_shift;
        let q23 = if shift >= 64 { 0 } else { c >> shift };
        *v = q23 as f32 * inv;
    }
    val
}

/// Expand one row's `Log2Code5` divider header (`[c, base_shift]`, see
/// [`CODE_SIDE_LEN`]) into its [`VAL_TABLE_LEN`]-entry shift table:
/// `table[code]` is bit-identical to the f32 probability
/// [`E2Softmax::forward_batch_f32`] writes for an element with that
/// total-shift code, because both paths share one expansion kernel.
pub fn expand_row_side(side: &[f32]) -> [f32; VAL_TABLE_LEN] {
    assert_eq!(side.len(), CODE_SIDE_LEN, "divider header must be {CODE_SIDE_LEN} f32");
    expand_table(side[0] as i64, side[1] as i64)
}

/// Reusable scratch for the allocation-free kernels.  Buffers are
/// `resize`d to the row at hand, so capacity grows to the largest row seen
/// and then stays put across varying row lengths.
#[derive(Debug, Default)]
pub struct E2Scratch {
    /// Per-element 4-bit Log2Exp codes (byte-packed for memory traffic).
    k: Vec<u8>,
    /// Per-slice running max (constant within a slice by construction).
    slice_m: Vec<i64>,
}

/// The paper's system: one softmax row over integer codes.
///
/// The configuration is frozen at construction — the Log2Exp table is
/// built from `cfg.e` in `new`, so a mutable `cfg` would let the LUT
/// kernels silently desync from `forward_introspect`.  Read it via
/// [`E2Softmax::cfg`].
pub struct E2Softmax {
    cfg: E2SoftmaxConfig,
    /// Precomputed Log2Exp for the `[-255, 0]` delta range at `cfg.e`
    /// (built once in `new`; the generator is the bit-exact `log2exp`).
    table: Log2ExpTable,
    /// Kernel arm for the planar hot paths, chosen once at construction
    /// (DESIGN.md §3.4); `forward_introspect` is always scalar.
    dispatch: Dispatch,
}

impl E2Softmax {
    pub fn new(cfg: E2SoftmaxConfig) -> Self {
        Self::with_dispatch(cfg, Dispatch::detect())
    }

    /// Construction with an explicit kernel arm (tests and benches pin
    /// arms to compare them); the request is clamped to what this host
    /// can run.
    pub fn with_dispatch(cfg: E2SoftmaxConfig, dispatch: Dispatch) -> Self {
        E2Softmax { table: Log2ExpTable::new(cfg.e), cfg, dispatch: dispatch.sanitize() }
    }

    /// The kernel arm the planar hot paths run on.
    pub fn dispatch(&self) -> Dispatch {
        self.dispatch
    }

    /// The (construction-frozen) datapath configuration.
    pub fn cfg(&self) -> E2SoftmaxConfig {
        self.cfg
    }

    /// The AVX2 arms step 8 elements inside one slice, so they only pay
    /// off (and are only exercised) at hardware-width chunks; narrow
    /// chunks take the scalar arm whole.
    fn simd_row(&self) -> bool {
        self.dispatch == Dispatch::Avx2 && self.cfg.chunk.max(1) >= 8
    }

    /// Full-introspection version (tests, golden vectors).  Deliberately
    /// table-free: this is the independent reference the LUT-driven
    /// kernels are pinned against.
    pub fn forward_introspect(&self, q: &[i64]) -> E2SoftmaxOut {
        assert!(!q.is_empty());
        let chunk = self.cfg.chunk.max(1);
        let e = self.cfg.e;
        let n = q.len();
        let mut ks = Vec::with_capacity(n);
        let mut ms = Vec::with_capacity(n);
        let mut sum: u64 = 0;
        let mut m_prev: Option<i64> = None;
        for sl in q.chunks(chunk) {
            let local = *sl.iter().max().unwrap();
            let m_new = match m_prev {
                Some(m) => m.max(local),
                None => local,
            };
            if let Some(m) = m_prev {
                if m != m_new {
                    let sub = log2exp(m - m_new, e);
                    sum >>= sub as u32;
                }
            }
            for &qi in sl {
                let k = log2exp(qi - m_new, e);
                sum += 1u64 << (SUM_FRAC as i64 - k);
                ks.push(k);
                ms.push(m_new);
            }
            m_prev = Some(m_new);
        }
        let m_final = m_prev.unwrap();
        let mut out_q23 = Vec::with_capacity(n);
        let mut out_u8 = Vec::with_capacity(n);
        for i in 0..n {
            let sub = log2exp(ms[i] - m_final, e);
            let o = aldivision(ks[i] + sub, sum);
            out_q23.push(o.q23);
            out_u8.push(o.u8code);
        }
        E2SoftmaxOut { k: ks, running_max: ms, sum_q15: sum, out_q23, out_u8 }
    }

    /// Hot path: writes Q23-grid f32 probabilities into `out`, reusing
    /// `scratch`.  No allocation after warmup.
    pub fn forward_row_f32(&self, q: &[i64], out: &mut [f32], scratch: &mut E2Scratch) {
        debug_assert_eq!(q.len(), out.len());
        self.row_kernel(q, out, scratch);
    }

    /// Batch hot path: `q` is a packed planar batch of rows, each `l`
    /// codes; one call, one reused scratch.  Bit-exact to per-row
    /// `forward_row_f32` (the rows go through the same kernel).
    pub fn forward_batch_f32(&self, q: &[i64], l: usize, out: &mut [f32], scratch: &mut E2Scratch) {
        assert!(l > 0, "softmax rows must be non-empty");
        assert!(q.len() % l == 0, "packed batch len {} is not a multiple of {l}", q.len());
        assert!(q.len() == out.len(), "out len {} != batch len {}", out.len(), q.len());
        for (row, row_out) in q.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
            self.row_kernel(row, row_out, scratch);
        }
    }

    /// Batch code path for fused consumers (DESIGN.md §3.3): instead of
    /// dequantizing to f32, expose what the hardware actually stores —
    /// one packed 5-bit *total shift* code per element (`k_i + sub_slice`,
    /// the full index into the row's divider table) plus each row's
    /// compact divider header (`side`, [`CODE_SIDE_LEN`] f32 per row:
    /// `[c, base_shift]`, both exact in f32).  Consumers rebuild the
    /// ≤ 32-entry shift table with [`expand_row_side`]; `table[code]` is
    /// bit-identical to the f32 value `forward_batch_f32` would have
    /// written for that element — both paths share one
    /// stage-1/expansion kernel — so a fused A·V consumer that multiplies
    /// `table[code] * v` in the same order as an unfused f32 matmul
    /// produces bit-identical output while never materializing the
    /// probability matrix at full width.  This is the producing side of
    /// the op layer's `Log2Code5` port (`ops/port.rs`); the caller sizes
    /// both slices (one code per element, one header per row).
    pub fn forward_batch_codes(
        &self,
        q: &[i64],
        l: usize,
        codes: &mut [u8],
        side: &mut [f32],
        scratch: &mut E2Scratch,
    ) {
        assert!(l > 0, "softmax rows must be non-empty");
        assert!(q.len() % l == 0, "packed batch len {} is not a multiple of {l}", q.len());
        assert!(codes.len() == q.len(), "codes len {} != batch len {}", codes.len(), q.len());
        let rows = q.len() / l;
        assert!(
            side.len() == rows * CODE_SIDE_LEN,
            "side len {} != {rows} rows * {CODE_SIDE_LEN}",
            side.len()
        );
        for ((row, row_codes), row_side) in q
            .chunks_exact(l)
            .zip(codes.chunks_exact_mut(l))
            .zip(side.chunks_exact_mut(CODE_SIDE_LEN))
        {
            let div = self.row_codes(row, row_codes, scratch);
            row_side[0] = div.c as f32;
            row_side[1] = div.base_shift as f32;
        }
    }

    /// The planar LUT-driven row kernel behind both f32 entry points:
    /// shared stage 1 + divider constants, table expansion, then the f32
    /// dequant loop.
    fn row_kernel(&self, q: &[i64], out: &mut [f32], scratch: &mut E2Scratch) {
        let (div, m_final) = self.row_prepare(q, scratch);
        let val = expand_table(div.c, div.base_shift);
        let chunk = self.cfg.chunk.max(1);
        let t = &self.table;
        if self.simd_row() {
            // SAFETY: the Avx2 arm only exists after runtime detection
            // (Dispatch::sanitize), and row_prepare sized the buffers.
            unsafe {
                crate::simd::e2::stage2_f32_avx2(
                    t,
                    chunk,
                    &scratch.k,
                    &scratch.slice_m,
                    m_final,
                    &val,
                    out,
                );
            }
            return;
        }
        // Stage 2: the correction sub = k(m_slice - m_final) is constant
        // per slice — hoist it, leaving a pure table[k] -> scale pipeline.
        for ((ks, os), &m_sl) in scratch
            .k
            .chunks(chunk)
            .zip(out.chunks_mut(chunk))
            .zip(scratch.slice_m.iter())
        {
            let sub = t.k(m_sl - m_final);
            for (o, &k) in os.iter_mut().zip(ks) {
                *o = val[(k as i64 + sub) as usize];
            }
        }
    }

    /// Code twin of `row_kernel`: identical stage 1 + divider constants,
    /// but stage 2 stores each element's total shift `k_i + sub_slice`
    /// (the index `forward_batch_f32` would have dequantized through)
    /// instead of the dequantized f32, and returns the row's divider —
    /// the table stays implicit until a consumer expands it.
    fn row_codes(&self, q: &[i64], codes: &mut [u8], scratch: &mut E2Scratch) -> RowDivider {
        debug_assert_eq!(q.len(), codes.len());
        let (div, m_final) = self.row_prepare(q, scratch);
        let chunk = self.cfg.chunk.max(1);
        let t = &self.table;
        if self.simd_row() {
            // SAFETY: as in row_kernel — detected arm, sized buffers.
            unsafe {
                crate::simd::e2::stage2_codes_avx2(
                    t,
                    chunk,
                    &scratch.k,
                    &scratch.slice_m,
                    m_final,
                    codes,
                );
            }
            return div;
        }
        for ((ks, cs), &m_sl) in scratch
            .k
            .chunks(chunk)
            .zip(codes.chunks_mut(chunk))
            .zip(scratch.slice_m.iter())
        {
            let sub = t.k(m_sl - m_final);
            for (c, &k) in cs.iter_mut().zip(ks) {
                *c = (k as i64 + sub) as u8;
            }
        }
        div
    }

    /// Stage 1 + divider-constant selection shared by `row_kernel` and
    /// `row_codes`: fills `scratch.k` (4-bit k codes) and
    /// `scratch.slice_m` (per-slice running max), returns the row's
    /// divider constants and its final max.
    fn row_prepare(&self, q: &[i64], scratch: &mut E2Scratch) -> (RowDivider, i64) {
        debug_assert!(!q.is_empty());
        let chunk = self.cfg.chunk.max(1);
        let t = &self.table;
        debug_assert_eq!(t.e(), self.cfg.e, "cfg.e mutated after construction; table is stale");
        let n = q.len();
        scratch.k.resize(n, 0);
        scratch.slice_m.resize(n.div_ceil(chunk), 0);

        let (sum, m_final) = if self.simd_row() {
            // SAFETY: the Avx2 arm only exists after runtime detection
            // (Dispatch::sanitize); buffers were just sized to the row.
            unsafe {
                crate::simd::e2::stage1_avx2(t, chunk, q, &mut scratch.k, &mut scratch.slice_m)
            }
        } else {
            // Stage 1 (scalar arm, the oracle): per-slice local max, then
            // a branch-free element loop — one table load yields both k
            // and the Q(.15) summand.
            let mut sum: u64 = 0;
            let mut m_prev = i64::MIN;
            for (sl, (ks, ms)) in q
                .chunks(chunk)
                .zip(scratch.k.chunks_mut(chunk).zip(scratch.slice_m.iter_mut()))
            {
                let mut local = sl[0];
                for &v in &sl[1..] {
                    local = local.max(v);
                }
                let m_new = if m_prev == i64::MIN { local } else { m_prev.max(local) };
                if m_prev != i64::MIN && m_prev != m_new {
                    sum >>= t.k(m_prev - m_new) as u32;
                }
                for (ko, &qi) in ks.iter_mut().zip(sl) {
                    let (k, pow) = t.k_pow(qi - m_new);
                    sum += pow;
                    *ko = k;
                }
                *ms = m_new;
                m_prev = m_new;
            }
            (sum, m_prev)
        };

        // ALDivision's LOD / mantissa-probe / constant-select depend only on
        // the reduced sum — per-row constants, hoisted out of the element
        // loop (the hardware does the same: one LOD per row, Fig. 4).  The
        // total shift is k_i + sub + k_s + 1 with k_i, sub in [0, 15], so
        // every reachable divider output fits the ≤ 32-entry table
        // `expand_table` rebuilds from these two constants.
        let msb = crate::fixedpoint::leading_one(sum) as i64;
        let k_s = msb - SUM_FRAC as i64;
        let s1 = if msb >= 1 { (sum >> (msb - 1)) & 1 } else { 0 };
        let c = if s1 == 1 { super::config::ALDIV_C1 } else { super::config::ALDIV_C0 };
        // base_shift >= 1: the global max contributes 2^SUM_FRAC, so
        // msb >= SUM_FRAC and the divider never left-shifts here.
        (RowDivider { c, base_shift: k_s + 1 }, m_final)
    }

    /// Quantize real logits to codes and run; convenience for the accuracy
    /// cross-checks.  The serving path uses `quantize_logits_into` +
    /// `forward_row_f32` instead, which allocate nothing at steady state.
    pub fn forward_logits(&self, x: &[f32]) -> Vec<f64> {
        let mut q = Vec::with_capacity(x.len());
        quantize_logits_into(x, self.cfg.e, &mut q);
        self.forward_introspect(&q).out_f64()
    }
}

/// One row of max-referenced quantization appended to `out`.  NaN logits
/// cannot participate in the row max (`f32::max` ignores them), and are
/// clamped to the bottom code `-255` — i.e. treated as -inf, receiving the
/// smallest representable probability instead of poisoning the row by
/// casting to code 0 (the row max).
fn append_row_codes(x: &[f32], e: u32, out: &mut Vec<i64>) {
    let scale = (1u64 << e) as f64;
    let xmax = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    out.extend(x.iter().map(|&v| {
        if v.is_nan() {
            -255
        } else {
            (((v as f64 - xmax) * scale).round() as i64).clamp(-255, 0)
        }
    }));
}

/// Quantize real logits to the integer code grid (row-max referenced,
/// scale 2^-e, clamped to the 8-bit code range `[-255, 0]`) into a
/// reusable buffer.  Shared by `forward_logits` and the coordinator's
/// software backend so both paths see bit-identical codes.  NaN logits map
/// to the bottom code `-255` (see `append_row_codes`); an all-equal row
/// quantizes to all zeros (every element *is* the row max).
pub fn quantize_logits_into(x: &[f32], e: u32, out: &mut Vec<i64>) {
    out.clear();
    append_row_codes(x, e, out);
}

/// Batch variant: `x` is a packed planar batch of rows of length `l`; each
/// row is max-referenced independently, exactly as `quantize_logits_into`
/// would do row by row.
pub fn quantize_logits_batch_into(x: &[f32], l: usize, e: u32, out: &mut Vec<i64>) {
    assert!(l > 0, "rows must be non-empty");
    assert!(x.len() % l == 0, "packed batch len {} is not a multiple of {l}", x.len());
    out.clear();
    out.reserve(x.len());
    for row in x.chunks_exact(l) {
        append_row_codes(row, e, out);
    }
}

/// Exact f64 softmax (baseline for error measurements).
pub fn softmax_exact(x: &[f32]) -> Vec<f64> {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let e: Vec<f64> = x.iter().map(|&v| ((v as f64) - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.iter().map(|v| v / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, size};
    use crate::util::rng::Rng;

    fn codes(rng: &mut Rng, n: usize) -> Vec<i64> {
        (0..n).map(|_| -rng.range_i64(0, 256)).collect()
    }

    #[test]
    fn single_element_row() {
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let o = sm.forward_introspect(&[0]);
        assert_eq!(o.sum_q15, 1 << 15);
        assert!((o.out_f64()[0] - 0.818).abs() < 1e-3);
    }

    #[test]
    fn outputs_in_range_and_sum_reasonable() {
        check("e2-range", 100, 31, |rng| {
            let n = size(rng, 200);
            let q = codes(rng, n);
            let chunk = if rng.f64() < 0.5 { 1 } else { 32 };
            let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk });
            let o = sm.forward_introspect(&q);
            assert!(o.sum_q15 >= 1 << 15);
            for (&k, &v) in o.k.iter().zip(&o.out_q23) {
                assert!((0..=15).contains(&k));
                assert!(v >= 0);
                assert!(q23_to_f64(v) <= 0.818 + 1e-9);
            }
        });
    }

    #[test]
    fn monotone_in_input() {
        check("e2-monotone", 60, 37, |rng| {
            let n = size(rng, 100).max(2);
            let q = codes(rng, n);
            let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk: 1 });
            let o = sm.forward_introspect(&q);
            // the online scheme rounds k_i and the stage-2 correction
            // separately (and both saturate at 15), so one-step inversions
            // are possible and the saturated tail (p < ~1e-3) can reorder
            // freely; anything beyond that would be a real bug.
            let tail = 1 << 13; // ~1e-3 in Q23
            for i in 0..n {
                for j in 0..n {
                    if q[i] > q[j] && o.out_q23[j] >= tail {
                        assert!(
                            2 * o.out_q23[i] >= o.out_q23[j],
                            "i={i} j={j} {} {}",
                            o.out_q23[i],
                            o.out_q23[j]
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn close_to_exact_softmax() {
        let mut rng = Rng::new(5);
        let mut worst: f64 = 0.0;
        for _ in 0..50 {
            let x: Vec<f32> = (0..64).map(|_| (rng.normal() * 2.0) as f32).collect();
            let exact = softmax_exact(&x);
            let sm = E2Softmax::new(E2SoftmaxConfig::default());
            let approx = sm.forward_logits(&x);
            for (a, b) in approx.iter().zip(&exact) {
                worst = worst.max((a - b).abs());
            }
        }
        assert!(worst < 0.16, "worst {worst}");
    }

    fn assert_hot_path_matches(n: usize, chunk: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let q = codes(&mut rng, n);
        let sm = E2Softmax::new(E2SoftmaxConfig { e: DEFAULT_E_TEST, chunk });
        let gold = sm.forward_introspect(&q);
        let mut out = vec![0f32; n];
        let mut scratch = E2Scratch::default();
        sm.forward_row_f32(&q, &mut out, &mut scratch);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v as f64, q23_to_f64(gold.out_q23[i]), "n={n} chunk={chunk} i={i}");
        }
        // reuse the same scratch for a second row: warm buffers must not
        // leak state between rows
        let q2 = codes(&mut rng, n);
        let gold2 = sm.forward_introspect(&q2);
        sm.forward_row_f32(&q2, &mut out, &mut scratch);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v as f64, q23_to_f64(gold2.out_q23[i]), "reuse n={n} chunk={chunk} i={i}");
        }
    }

    const DEFAULT_E_TEST: u32 = 4;

    #[test]
    fn hot_path_matches_introspect() {
        // random sweep over sizes and chunk widths (1 = Algorithm 1
        // verbatim, 32 = the unit's vector size, 7 = an uneven tail slice)
        check("e2-hotpath", 50, 41, |rng| {
            let n = size(rng, 300);
            let chunk = [1usize, 7, 32][rng.range_usize(0, 3)];
            let q = codes(rng, n);
            let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk });
            let gold = sm.forward_introspect(&q);
            let mut out = vec![0f32; n];
            let mut scratch = E2Scratch::default();
            sm.forward_row_f32(&q, &mut out, &mut scratch);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v as f64, q23_to_f64(gold.out_q23[i]), "chunk={chunk}");
            }
        });
    }

    #[test]
    fn hot_path_matches_introspect_edge_shapes() {
        // the paper-edge shapes the random sweep can miss: single-element
        // rows, chunk=1, and rows beyond the unit's 1024-element buffer
        for &(n, chunk) in &[
            (1usize, 1usize),
            (1, 32),
            (2, 1),
            (31, 32),
            (33, 32),
            (300, 1),
            (1024, 32),
            (1025, 32),
            (1500, 32),
            (2048, 1),
        ] {
            assert_hot_path_matches(n, chunk, 0x5150 + n as u64);
        }
    }

    #[test]
    fn scratch_capacity_stable_across_varying_row_lengths() {
        // resize-based reuse: after the largest row, smaller and larger
        // rows must not force reallocation churn (capacity only ratchets)
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let mut scratch = E2Scratch::default();
        let mut rng = Rng::new(77);
        let mut out = vec![0f32; 1024];
        let q = codes(&mut rng, 1024);
        sm.forward_row_f32(&q, &mut out[..1024], &mut scratch);
        let cap_k = scratch.k.capacity();
        let cap_m = scratch.slice_m.capacity();
        for &n in &[17usize, 1024, 64, 513, 1] {
            let q = codes(&mut rng, n);
            sm.forward_row_f32(&q, &mut out[..n], &mut scratch);
            assert_eq!(scratch.k.capacity(), cap_k, "n={n}");
            assert_eq!(scratch.slice_m.capacity(), cap_m, "n={n}");
        }
    }

    #[test]
    fn batch_matches_rows_bitwise() {
        let l = 96;
        let b = 5;
        let mut rng = Rng::new(23);
        let q = codes(&mut rng, b * l);
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let mut batch_out = vec![0f32; b * l];
        let mut scratch = E2Scratch::default();
        sm.forward_batch_f32(&q, l, &mut batch_out, &mut scratch);
        let mut row_out = vec![0f32; l];
        for r in 0..b {
            sm.forward_row_f32(&q[r * l..(r + 1) * l], &mut row_out, &mut scratch);
            assert_eq!(&batch_out[r * l..(r + 1) * l], &row_out[..], "row {r}");
        }
    }

    #[test]
    fn quantize_into_matches_forward_logits_codes() {
        let mut rng = Rng::new(13);
        let x: Vec<f32> = (0..64).map(|_| (rng.normal() * 2.0) as f32).collect();
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let mut q = Vec::new();
        quantize_logits_into(&x, sm.cfg().e, &mut q);
        assert_eq!(q.len(), x.len());
        assert!(q.iter().all(|&v| (-255..=0).contains(&v)));
        // the max logit quantizes to code 0
        assert!(q.contains(&0));
        // the full path through forward_logits agrees with quantize+introspect
        let via_logits = sm.forward_logits(&x);
        let via_codes = sm.forward_introspect(&q).out_f64();
        assert_eq!(via_logits, via_codes);
    }

    #[test]
    fn quantize_all_equal_row_is_all_zero_codes() {
        let mut q = Vec::new();
        quantize_logits_into(&[1.25f32; 17], DEFAULT_E, &mut q);
        assert_eq!(q, vec![0i64; 17]);
        // and the softmax of it is exactly uniform on the code grid
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let o = sm.forward_introspect(&q);
        assert!(o.out_q23.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn quantize_nan_logits_get_bottom_code() {
        let x = [0.5f32, f32::NAN, 2.0, -1.0, f32::NAN];
        let mut q = Vec::new();
        quantize_logits_into(&x, DEFAULT_E, &mut q);
        // NaN cannot shift the row max (2.0) nor become the max code
        assert_eq!(q[1], -255);
        assert_eq!(q[4], -255);
        assert_eq!(q[2], 0);
        // the non-NaN codes are identical to the NaN-free row
        let x_clean = [0.5f32, 2.0, -1.0];
        let mut q_clean = Vec::new();
        quantize_logits_into(&x_clean, DEFAULT_E, &mut q_clean);
        assert_eq!(q[0], q_clean[0]);
        assert_eq!(q[2], q_clean[1]);
        assert_eq!(q[3], q_clean[2]);
        // downstream softmax stays finite and the NaN slots get the floor
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let o = sm.forward_introspect(&q);
        for &v in &o.out_q23 {
            assert!(v >= 0);
        }
        assert!(o.out_q23[1] <= o.out_q23[2]);
    }

    #[test]
    fn quantize_all_nan_row_is_uniform_floor() {
        let mut q = Vec::new();
        quantize_logits_into(&[f32::NAN; 8], DEFAULT_E, &mut q);
        assert_eq!(q, vec![-255i64; 8]);
        // max-referenced softmax still works (codes are all equal)
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let o = sm.forward_introspect(&q);
        assert!(o.out_q23.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn quantize_batch_matches_per_row() {
        let mut rng = Rng::new(31);
        let l = 48;
        let b = 4;
        let mut x = vec![0f32; b * l];
        rng.fill_normal(&mut x, 0.0, 2.0);
        x[l + 3] = f32::NAN; // NaN guard must apply per row in the batch too
        let mut batch = Vec::new();
        quantize_logits_batch_into(&x, l, DEFAULT_E, &mut batch);
        assert_eq!(batch.len(), b * l);
        let mut row = Vec::new();
        for r in 0..b {
            quantize_logits_into(&x[r * l..(r + 1) * l], DEFAULT_E, &mut row);
            assert_eq!(&batch[r * l..(r + 1) * l], &row[..], "row {r}");
        }
    }

    #[test]
    fn batch_codes_dequantize_bitwise_to_batch_f32() {
        // the Log2Code5 port contract: expanding the compact divider
        // header and indexing with the packed code must recover the exact
        // f32 the dequantizing kernel writes, at every shape and chunk
        check("e2-codes", 60, 47, |rng| {
            let l = size(rng, 200);
            let b = 1 + rng.range_usize(0, 4);
            let chunk = [1usize, 7, 32][rng.range_usize(0, 3)];
            let q = codes(rng, b * l);
            let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk });
            let mut out = vec![0f32; b * l];
            let mut scratch = E2Scratch::default();
            sm.forward_batch_f32(&q, l, &mut out, &mut scratch);
            let mut packed = vec![0u8; b * l];
            let mut side = vec![0f32; b * CODE_SIDE_LEN];
            sm.forward_batch_codes(&q, l, &mut packed, &mut side, &mut scratch);
            for r in 0..b {
                let hdr = &side[r * CODE_SIDE_LEN..(r + 1) * CODE_SIDE_LEN];
                // the header is exact in f32: c is one of the two 24-bit
                // ALDivision constants, base_shift a small positive integer
                let c = hdr[0] as i64;
                assert!(
                    c == crate::softmax::config::ALDIV_C0 || c == crate::softmax::config::ALDIV_C1,
                    "row {r}: c {c}"
                );
                assert!(hdr[1] >= 1.0 && hdr[1].fract() == 0.0, "row {r}: base_shift {}", hdr[1]);
                let row_val = expand_row_side(hdr);
                for i in 0..l {
                    let code = packed[r * l + i] as usize;
                    assert!(code < VAL_TABLE_LEN, "code {code} out of table");
                    assert_eq!(
                        row_val[code],
                        out[r * l + i],
                        "row {r} elem {i} chunk {chunk}"
                    );
                }
            }
        });
    }

    #[test]
    fn batch_codes_scratch_reuse_is_deterministic() {
        // the same scratch (and the same codes/side buffers) across calls
        // must not leak state between batches
        let l = 96;
        let mut rng = Rng::new(61);
        let q1 = codes(&mut rng, 3 * l);
        let q2 = codes(&mut rng, 5 * l);
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let mut scratch = E2Scratch::default();
        let mut c1 = vec![0u8; 5 * l];
        let mut v1 = vec![0f32; 5 * CODE_SIDE_LEN];
        sm.forward_batch_codes(&q1, l, &mut c1[..3 * l], &mut v1[..3 * CODE_SIDE_LEN], &mut scratch);
        let first_c = c1[..3 * l].to_vec();
        let first_v = v1[..3 * CODE_SIDE_LEN].to_vec();
        sm.forward_batch_codes(&q2, l, &mut c1, &mut v1, &mut scratch);
        sm.forward_batch_codes(&q1, l, &mut c1[..3 * l], &mut v1[..3 * CODE_SIDE_LEN], &mut scratch);
        assert_eq!(&c1[..3 * l], &first_c[..]);
        assert_eq!(&v1[..3 * CODE_SIDE_LEN], &first_v[..]);
    }

    #[test]
    fn descending_rows_chunk_invariant() {
        let mut q: Vec<i64> = (0..96).map(|i| -(i as i64 * 2)).collect();
        q.sort_unstable_by(|a, b| b.cmp(a));
        let a = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk: 1 }).forward_introspect(&q);
        let b = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk: 32 }).forward_introspect(&q);
        assert_eq!(a.out_q23, b.out_q23);
        assert_eq!(a.sum_q15, b.sum_q15);
    }
}
