//! E2Softmax — Algorithm 1, bit-exact integer model.
//!
//! Single pass (stage 1): running max + Log2Exp + online sum with shift
//! rescaling; stage 2: per-element correction + Approximate Log-based
//! Division.  `chunk = 1` is Algorithm 1 verbatim; `chunk = V` models the
//! V-lane E2Softmax Unit (local max per slice via the comparison tree) and
//! matches the Pallas kernel.
//!
//! This is also the coordinator's software hot path (bench_softmax), so the
//! row kernel is allocation-free given a reusable scratch.

use super::aldivision::{aldivision, q23_to_f64};
use super::config::{DEFAULT_E, SUM_FRAC};
use super::log2exp::log2exp;

/// Configuration of the E2Softmax datapath.
#[derive(Debug, Clone, Copy)]
pub struct E2SoftmaxConfig {
    /// Power-of-two input scale exponent: input real value = code * 2^-e.
    pub e: u32,
    /// Lane count of the simulated unit (1 = Algorithm 1 verbatim,
    /// 32 = the paper's vector size).
    pub chunk: usize,
}

impl Default for E2SoftmaxConfig {
    fn default() -> Self {
        E2SoftmaxConfig { e: DEFAULT_E, chunk: 32 }
    }
}

/// Full per-row output with intermediates (golden tests pin all of them).
#[derive(Debug, Clone)]
pub struct E2SoftmaxOut {
    /// 4-bit Log2Exp codes per element.
    pub k: Vec<i64>,
    /// Running max (the slice's reference max) per element.
    pub running_max: Vec<i64>,
    /// Final reduced sum, Q(.15).
    pub sum_q15: u64,
    /// Q(.23) output values.
    pub out_q23: Vec<i64>,
    /// 8-bit output codes (scale 2^-8).
    pub out_u8: Vec<u8>,
}

impl E2SoftmaxOut {
    pub fn out_f64(&self) -> Vec<f64> {
        self.out_q23.iter().map(|&v| q23_to_f64(v)).collect()
    }
}

/// Reusable scratch for the allocation-free row kernel.
#[derive(Debug, Default)]
pub struct E2Scratch {
    k: Vec<i64>,
    m: Vec<i64>,
}

/// The paper's system: one softmax row over integer codes.
pub struct E2Softmax {
    pub cfg: E2SoftmaxConfig,
}

impl E2Softmax {
    pub fn new(cfg: E2SoftmaxConfig) -> Self {
        E2Softmax { cfg }
    }

    /// Full-introspection version (tests, golden vectors).
    pub fn forward_introspect(&self, q: &[i64]) -> E2SoftmaxOut {
        assert!(!q.is_empty());
        let chunk = self.cfg.chunk.max(1);
        let e = self.cfg.e;
        let n = q.len();
        let mut ks = Vec::with_capacity(n);
        let mut ms = Vec::with_capacity(n);
        let mut sum: u64 = 0;
        let mut m_prev: Option<i64> = None;
        for sl in q.chunks(chunk) {
            let local = *sl.iter().max().unwrap();
            let m_new = match m_prev {
                Some(m) => m.max(local),
                None => local,
            };
            if let Some(m) = m_prev {
                if m != m_new {
                    let sub = log2exp(m - m_new, e);
                    sum >>= sub as u32;
                }
            }
            for &qi in sl {
                let k = log2exp(qi - m_new, e);
                sum += 1u64 << (SUM_FRAC as i64 - k);
                ks.push(k);
                ms.push(m_new);
            }
            m_prev = Some(m_new);
        }
        let m_final = m_prev.unwrap();
        let mut out_q23 = Vec::with_capacity(n);
        let mut out_u8 = Vec::with_capacity(n);
        for i in 0..n {
            let sub = log2exp(ms[i] - m_final, e);
            let o = aldivision(ks[i] + sub, sum);
            out_q23.push(o.q23);
            out_u8.push(o.u8code);
        }
        E2SoftmaxOut { k: ks, running_max: ms, sum_q15: sum, out_q23, out_u8 }
    }

    /// Hot path: writes Q23-grid f32 probabilities into `out`, reusing
    /// `scratch`.  No allocation after warmup.
    pub fn forward_row_f32(&self, q: &[i64], out: &mut [f32], scratch: &mut E2Scratch) {
        debug_assert_eq!(q.len(), out.len());
        let chunk = self.cfg.chunk.max(1);
        let e = self.cfg.e;
        let n = q.len();
        scratch.k.clear();
        scratch.k.reserve(n);
        scratch.m.clear();
        scratch.m.reserve(n);
        let mut sum: u64 = 0;
        let mut m_prev = i64::MIN;
        for sl in q.chunks(chunk) {
            let mut local = sl[0];
            for &v in &sl[1..] {
                local = local.max(v);
            }
            let m_new = if m_prev == i64::MIN { local } else { m_prev.max(local) };
            if m_prev != i64::MIN && m_prev != m_new {
                sum >>= log2exp(m_prev - m_new, e) as u32;
            }
            for &qi in sl {
                let k = log2exp(qi - m_new, e);
                sum += 1u64 << (SUM_FRAC as i64 - k);
                scratch.k.push(k);
                scratch.m.push(m_new);
            }
            m_prev = m_new;
        }
        let m_final = m_prev;
        // ALDivision's LOD / mantissa-probe / constant-select depend only on
        // the reduced sum — per-row constants, hoisted out of the element
        // loop (the hardware does the same: one LOD per row, Fig. 4).
        let msb = crate::fixedpoint::leading_one(sum) as i64;
        let k_s = msb - super::config::SUM_FRAC as i64;
        let s1 = if msb >= 1 { (sum >> (msb - 1)) & 1 } else { 0 };
        let c = if s1 == 1 { super::config::ALDIV_C1 } else { super::config::ALDIV_C0 };
        let inv = 1.0f32 / (1i64 << super::config::ALDIV_Q) as f32;
        let base_shift = k_s + 1;
        for i in 0..n {
            let sub = log2exp(scratch.m[i] - m_final, e);
            let shift = scratch.k[i] + sub + base_shift;
            let q23 = if shift >= 64 { 0 } else if shift >= 0 { c >> shift } else { c << -shift };
            out[i] = q23 as f32 * inv;
        }
    }

    /// Quantize real logits to codes and run; convenience for the accuracy
    /// cross-checks.  The serving path uses `quantize_logits_into` +
    /// `forward_row_f32` instead, which allocate nothing at steady state.
    pub fn forward_logits(&self, x: &[f32]) -> Vec<f64> {
        let mut q = Vec::with_capacity(x.len());
        quantize_logits_into(x, self.cfg.e, &mut q);
        self.forward_introspect(&q).out_f64()
    }
}

/// Quantize real logits to the integer code grid (row-max referenced,
/// scale 2^-e, clamped to the 8-bit code range) into a reusable buffer.
/// Shared by `forward_logits` and the coordinator's software backend so
/// both paths see bit-identical codes.
pub fn quantize_logits_into(x: &[f32], e: u32, out: &mut Vec<i64>) {
    let scale = (1u64 << e) as f64;
    let xmax = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    out.clear();
    out.extend(x.iter().map(|&v| (((v as f64 - xmax) * scale).round() as i64).clamp(-255, 0)));
}

/// Exact f64 softmax (baseline for error measurements).
pub fn softmax_exact(x: &[f32]) -> Vec<f64> {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let e: Vec<f64> = x.iter().map(|&v| ((v as f64) - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.iter().map(|v| v / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, size};
    use crate::util::rng::Rng;

    fn codes(rng: &mut Rng, n: usize) -> Vec<i64> {
        (0..n).map(|_| -rng.range_i64(0, 256)).collect()
    }

    #[test]
    fn single_element_row() {
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let o = sm.forward_introspect(&[0]);
        assert_eq!(o.sum_q15, 1 << 15);
        assert!((o.out_f64()[0] - 0.818).abs() < 1e-3);
    }

    #[test]
    fn outputs_in_range_and_sum_reasonable() {
        check("e2-range", 100, 31, |rng| {
            let n = size(rng, 200);
            let q = codes(rng, n);
            let chunk = if rng.f64() < 0.5 { 1 } else { 32 };
            let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk });
            let o = sm.forward_introspect(&q);
            assert!(o.sum_q15 >= 1 << 15);
            for (&k, &v) in o.k.iter().zip(&o.out_q23) {
                assert!((0..=15).contains(&k));
                assert!(v >= 0);
                assert!(q23_to_f64(v) <= 0.818 + 1e-9);
            }
        });
    }

    #[test]
    fn monotone_in_input() {
        check("e2-monotone", 60, 37, |rng| {
            let n = size(rng, 100).max(2);
            let q = codes(rng, n);
            let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk: 1 });
            let o = sm.forward_introspect(&q);
            // the online scheme rounds k_i and the stage-2 correction
            // separately (and both saturate at 15), so one-step inversions
            // are possible and the saturated tail (p < ~1e-3) can reorder
            // freely; anything beyond that would be a real bug.
            let tail = 1 << 13; // ~1e-3 in Q23
            for i in 0..n {
                for j in 0..n {
                    if q[i] > q[j] && o.out_q23[j] >= tail {
                        assert!(
                            2 * o.out_q23[i] >= o.out_q23[j],
                            "i={i} j={j} {} {}",
                            o.out_q23[i],
                            o.out_q23[j]
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn close_to_exact_softmax() {
        let mut rng = Rng::new(5);
        let mut worst: f64 = 0.0;
        for _ in 0..50 {
            let x: Vec<f32> = (0..64).map(|_| (rng.normal() * 2.0) as f32).collect();
            let exact = softmax_exact(&x);
            let sm = E2Softmax::new(E2SoftmaxConfig::default());
            let approx = sm.forward_logits(&x);
            for (a, b) in approx.iter().zip(&exact) {
                worst = worst.max((a - b).abs());
            }
        }
        assert!(worst < 0.16, "worst {worst}");
    }

    fn assert_hot_path_matches(n: usize, chunk: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let q = codes(&mut rng, n);
        let sm = E2Softmax::new(E2SoftmaxConfig { e: DEFAULT_E_TEST, chunk });
        let gold = sm.forward_introspect(&q);
        let mut out = vec![0f32; n];
        let mut scratch = E2Scratch::default();
        sm.forward_row_f32(&q, &mut out, &mut scratch);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v as f64, q23_to_f64(gold.out_q23[i]), "n={n} chunk={chunk} i={i}");
        }
        // reuse the same scratch for a second row: warm buffers must not
        // leak state between rows
        let q2 = codes(&mut rng, n);
        let gold2 = sm.forward_introspect(&q2);
        sm.forward_row_f32(&q2, &mut out, &mut scratch);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v as f64, q23_to_f64(gold2.out_q23[i]), "reuse n={n} chunk={chunk} i={i}");
        }
    }

    const DEFAULT_E_TEST: u32 = 4;

    #[test]
    fn hot_path_matches_introspect() {
        // random sweep over sizes and chunk widths (1 = Algorithm 1
        // verbatim, 32 = the unit's vector size, 7 = an uneven tail slice)
        check("e2-hotpath", 50, 41, |rng| {
            let n = size(rng, 300);
            let chunk = [1usize, 7, 32][rng.range_usize(0, 3)];
            let q = codes(rng, n);
            let sm = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk });
            let gold = sm.forward_introspect(&q);
            let mut out = vec![0f32; n];
            let mut scratch = E2Scratch::default();
            sm.forward_row_f32(&q, &mut out, &mut scratch);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v as f64, q23_to_f64(gold.out_q23[i]), "chunk={chunk}");
            }
        });
    }

    #[test]
    fn hot_path_matches_introspect_edge_shapes() {
        // the paper-edge shapes the random sweep can miss: single-element
        // rows, chunk=1, and rows beyond the unit's 1024-element buffer
        for &(n, chunk) in &[
            (1usize, 1usize),
            (1, 32),
            (2, 1),
            (31, 32),
            (33, 32),
            (300, 1),
            (1024, 32),
            (1025, 32),
            (1500, 32),
            (2048, 1),
        ] {
            assert_hot_path_matches(n, chunk, 0x5150 + n as u64);
        }
    }

    #[test]
    fn quantize_into_matches_forward_logits_codes() {
        let mut rng = Rng::new(13);
        let x: Vec<f32> = (0..64).map(|_| (rng.normal() * 2.0) as f32).collect();
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        let mut q = Vec::new();
        quantize_logits_into(&x, sm.cfg.e, &mut q);
        assert_eq!(q.len(), x.len());
        assert!(q.iter().all(|&v| (-255..=0).contains(&v)));
        // the max logit quantizes to code 0
        assert!(q.contains(&0));
        // the full path through forward_logits agrees with quantize+introspect
        let via_logits = sm.forward_logits(&x);
        let via_codes = sm.forward_introspect(&q).out_f64();
        assert_eq!(via_logits, via_codes);
    }

    #[test]
    fn descending_rows_chunk_invariant() {
        let mut q: Vec<i64> = (0..96).map(|i| -(i as i64 * 2)).collect();
        q.sort_unstable_by(|a, b| b.cmp(a));
        let a = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk: 1 }).forward_introspect(&q);
        let b = E2Softmax::new(E2SoftmaxConfig { e: 4, chunk: 32 }).forward_introspect(&q);
        assert_eq!(a.out_q23, b.out_q23);
        assert_eq!(a.sum_q15, b.sum_q15);
    }
}
