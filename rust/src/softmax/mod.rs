//! Softmax algorithms: the paper's E2Softmax (bit-exact integer model of
//! Algorithm 1) plus the exact baseline, the prior-work comparators
//! (Softermax, I-BERT) used in Table III and the accuracy ablations, and
//! the reduction-free streaming family (ConSmax, GN-Softmax) behind the
//! chunked streaming service path (DESIGN.md §3.6).

pub mod aldivision;
pub mod baselines;
pub mod consmax;
pub mod e2;
pub mod gnsoftmax;
pub mod log2exp;

pub use aldivision::{aldivision, AldivOut};
pub use consmax::{ConSmax, ConSmaxConfig};
pub use e2::{
    expand_row_side, quantize_logits_batch_into, quantize_logits_into, E2Scratch, E2Softmax,
    E2SoftmaxConfig, E2SoftmaxOut, CODE_SIDE_LEN, VAL_TABLE_LEN,
};
pub use gnsoftmax::{GnSoftmax, GnSoftmaxConfig};
pub use log2exp::{log2exp, Log2ExpTable};

/// Contract constants shared with python/compile/kernels/ref.py — see
/// DESIGN.md §6.  Changing any of these invalidates the golden vectors.
pub mod config {
    /// Internal fraction bits of the Log2Exp shift-add datapath.
    pub const LOG2EXP_F: u32 = 8;
    /// 4-bit log2-quantized exponent output: k in [0, K_MAX].
    pub const K_MAX: i64 = 15;
    /// Q(.15) online sum accumulator.
    pub const SUM_FRAC: u32 = 15;
    /// Q(.23) ALDivision constants (chosen to stay f32-exact for the
    /// Pallas twin).
    pub const ALDIV_Q: u32 = 23;
    /// round(1.636 * 2^23) — the unbiased constant, s' = 0 branch.
    pub const ALDIV_C0: i64 = 13723763;
    /// round(1.136 * 2^23) — s' = 1 branch.
    pub const ALDIV_C1: i64 = 9529459;
    /// 8-bit softmax output code, scale 2^-8.
    pub const OUT_FRAC: u32 = 8;
    /// Default power-of-two input scale exponent (input scale 2^-e).
    pub const DEFAULT_E: u32 = 4;
}

#[cfg(test)]
mod tests {
    use super::config::*;

    #[test]
    fn constants_match_ref_py() {
        assert_eq!(ALDIV_C0, (1.636f64 * (1u64 << ALDIV_Q) as f64).round() as i64);
        assert_eq!(ALDIV_C1, (1.136f64 * (1u64 << ALDIV_Q) as f64).round() as i64);
        assert_eq!(K_MAX, (1 << 4) - 1);
    }
}
