//! Prior-work softmax comparators (functional models, twins of ref.py):
//! Softermax (Stevens et al., DAC'21) and I-BERT i-exp (Kim et al.,
//! ICML'21).  Used for the accuracy ablations and as the algorithmic side
//! of the Table III baseline units.

/// Softermax: base-2 softmax with 2^-frac_bits quantized un-normalized
/// intermediates (the 16-bit buffer of the Softermax unit).
pub fn softermax(x: &[f32], frac_bits: u32) -> Vec<f64> {
    let scale = (1u64 << frac_bits) as f64;
    let ln2 = std::f64::consts::LN_2;
    let z: Vec<f64> = x.iter().map(|&v| (v as f64 / ln2 * scale).floor() / scale).collect();
    let zmax = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max).ceil();
    let q: Vec<f64> = z
        .iter()
        .map(|&v| ((v - zmax).exp2() * scale).floor() / scale)
        .collect();
    let s: f64 = q.iter().sum();
    let s = if s > 0.0 { s } else { 1.0 };
    q.iter().map(|v| v / s).collect()
}

/// I-BERT i-exp softmax: integer polynomial 0.3585(p + 1.353)^2 + 0.344
/// after range reduction x~ = -z ln2 + p, all in the integer pipeline at
/// input scale `scale`.
pub fn ibert_softmax(x: &[f32], scale: f64) -> Vec<f64> {
    let q: Vec<f64> = x.iter().map(|&v| (v as f64 / scale).floor()).collect();
    let qmax = q.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let ln2_q = (std::f64::consts::LN_2 / scale).floor();
    let qb = (1.353 / scale).floor();
    let qc = (0.344 / (0.3585 * scale * scale)).floor();
    let mut qexp = Vec::with_capacity(x.len());
    for &qi in &q {
        let d = qi - qmax;
        let z = (-d / ln2_q).floor();
        let p = d + z * ln2_q;
        let qout = (p + qb) * (p + qb) + qc;
        qexp.push((qout / 2f64.powf(z)).floor());
    }
    let s: f64 = qexp.iter().sum();
    let s = if s > 0.0 { s } else { 1.0 };
    qexp.iter().map(|v| v / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::e2::softmax_exact;
    use crate::util::rng::Rng;

    fn gen(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 2.0) as f32).collect()
    }

    #[test]
    fn softermax_close_to_exact() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let x = gen(&mut rng, 64);
            let a = softermax(&x, 8);
            let b = softmax_exact(&x);
            let worst = a.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
            assert!(worst < 0.08, "worst {worst}");
        }
    }

    #[test]
    fn ibert_close_to_exact() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let x = gen(&mut rng, 64);
            let a = ibert_softmax(&x, 1.0 / 16.0);
            let b = softmax_exact(&x);
            let worst = a.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
            assert!(worst < 0.05, "worst {worst}");
        }
    }

    #[test]
    fn both_normalize() {
        let mut rng = Rng::new(3);
        let x = gen(&mut rng, 128);
        let s1: f64 = softermax(&x, 8).iter().sum();
        let s2: f64 = ibert_softmax(&x, 1.0 / 16.0).iter().sum();
        assert!((s1 - 1.0).abs() < 1e-9);
        assert!((s2 - 1.0).abs() < 1e-9);
    }
}
