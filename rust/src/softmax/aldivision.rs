//! Approximate Log-based Divider — Eq. (13)/(17).
//!
//! Divides 2^-k_y by the online reduced sum using: a leading-one detector,
//! one subtraction, a 1-bit mantissa probe (the bit below the leading one),
//! a two-way mux between the unbiased constants 1.636/1.136, and a shifter.
//! Bit-exact twin of `ref.aldivision_int`.

use super::config::{ALDIV_C0, ALDIV_C1, ALDIV_Q, OUT_FRAC, SUM_FRAC};
use crate::fixedpoint::leading_one;

/// Divider output: the Q(.23) value and the 8-bit output code (scale 2^-8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AldivOut {
    pub q23: i64,
    pub u8code: u8,
}

/// `k_y`: log2-domain numerator exponent (>= 0); `sum_q15`: reduced sum in
/// Q(.15), > 0 (the global max always contributes 2^0 = 1 << 15).
#[inline]
pub fn aldivision(k_y: i64, sum_q15: u64) -> AldivOut {
    debug_assert!(sum_q15 > 0);
    debug_assert!(k_y >= 0);
    let msb = leading_one(sum_q15) as i64;
    let k_s = msb - SUM_FRAC as i64;
    let s1 = if msb >= 1 { (sum_q15 >> (msb - 1)) & 1 } else { 0 };
    let c = if s1 == 1 { ALDIV_C1 } else { ALDIV_C0 };
    let shift = k_y + k_s + 1;
    let q23 = if shift >= 64 {
        0
    } else if shift >= 0 {
        c >> shift
    } else {
        c << -shift
    };
    // round-half-up to the 8-bit output code
    let code = ((q23 + (1 << (ALDIV_Q - OUT_FRAC - 1))) >> (ALDIV_Q - OUT_FRAC)).min(255);
    AldivOut { q23, u8code: code as u8 }
}

/// The Q23 value as f64 (scale 2^-23).
#[inline]
pub fn q23_to_f64(q23: i64) -> f64 {
    q23 as f64 / (1i64 << ALDIV_Q) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn eq17_constants() {
        // sum = 2^15 exactly (single max element), k_y = 0 -> 1.636/2 = 0.818
        let o = aldivision(0, 1 << 15);
        assert!((q23_to_f64(o.q23) - 0.818).abs() < 1e-3);
        // s' = 1 branch -> 0.568
        let o = aldivision(0, (1 << 15) | (1 << 14));
        assert!((q23_to_f64(o.q23) - 0.568).abs() < 1e-3);
    }

    #[test]
    fn deep_shift_underflows_to_zero() {
        let o = aldivision(60, 1 << 20);
        assert_eq!(o.q23, 0);
        assert_eq!(o.u8code, 0);
    }

    #[test]
    fn code_is_rounded_q23() {
        check("aldiv-code", 300, 23, |rng| {
            let k_y = rng.range_i64(0, 31);
            let s = rng.range_i64(1 << 15, 1 << 26) as u64;
            let o = aldivision(k_y, s);
            let expect = ((o.q23 + (1 << 14)) >> 15).min(255);
            assert_eq!(o.u8code as i64, expect);
        });
    }

    #[test]
    fn bounded_relative_error_and_unbiased() {
        // |rel err| < 25% pointwise; mean ~ 0 (the paper's -0.636/2 fix)
        let mut sum_rel = 0.0;
        let mut n = 0.0;
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..4000 {
            let k_y = rng.range_i64(0, 8);
            let s = rng.range_i64(1 << 15, 1 << 20) as u64;
            let o = aldivision(k_y, s);
            let exact = 2f64.powi(-k_y as i32) / (s as f64 / (1u64 << 15) as f64);
            let rel = q23_to_f64(o.q23) / exact - 1.0;
            assert!(rel.abs() < 0.25, "rel={rel}");
            sum_rel += rel;
            n += 1.0;
        }
        assert!((sum_rel / n).abs() < 0.03, "bias {}", sum_rel / n);
    }
}
