//! ConSmax — hardware-friendly softmax with learnable parameters
//! (Liu et al., arxiv 2402.10930), functional model.
//!
//! ConSmax replaces both softmax reductions with learnable constants: the
//! row max becomes a trained offset β and the denominator a trained scale
//! γ, so `y_i = exp(x_i - β) / γ` is *elementwise* — no running max, no
//! online sum, no second pass.  That is the property the streaming
//! service path is built on: a row can be processed chunk by chunk (any
//! chunk boundaries) and the concatenated outputs are bit-identical to
//! the whole-row kernel, because element `i` never sees element `j`.
//!
//! The datapath mirrors the unit in the paper: base-2 re-expression
//! `exp(x - β) = 2^((x - β) · log2 e)`, integer/fraction split of the
//! exponent, a 2^[`CONSMAX_FRAC_BITS`]-entry LUT for the fractional
//! power, and an exponent-field shift for the integer part.  Inference
//! uses frozen β/γ (this repo has no training loop); the defaults are
//! calibrated for the shared logit distributions in `util/dist.rs` — see
//! [`ConSmax::for_len`].  Output stays on the f32 grid the LUT induces;
//! every step is deterministic (the only libm call is the one-time LUT
//! build), so chunked-vs-whole-row equality holds on every platform.

/// Fraction bits of the 2^f LUT (256 entries — the paper's bitwidth
/// ablation settles at 8 fractional bits).
pub const CONSMAX_FRAC_BITS: u32 = 8;

/// Frozen β of the registered `consmax` services.  Calibration: for the
/// reference logit distribution N(0, σ²) with σ = [`CONSMAX_SIGMA_REF`],
/// `E[exp(x - β)] = exp(σ²/2 - β) = 1`, so β = σ²/2 puts the per-element
/// mean on the normalization target.
pub const CONSMAX_BETA: f64 = 2.0;

/// Reference logit std-dev the default β/γ are calibrated against (the
/// Gaussian leg of `util/dist.rs`).
pub const CONSMAX_SIGMA_REF: f64 = 2.0;

/// Exponent clamp of the datapath: (x - β)·log2 e saturates into
/// [-S, S] so the integer part always fits the f32 exponent field.
const EXP_CLAMP: f64 = 126.0;

const LUT_LEN: usize = 1 << CONSMAX_FRAC_BITS;
const FRAC_MASK: i64 = LUT_LEN as i64 - 1;

/// Construction-time ConSmax parameters (frozen at inference).
#[derive(Debug, Clone, Copy)]
pub struct ConSmaxConfig {
    /// Learnable max-replacement offset β.
    pub beta: f64,
    /// Learnable denominator γ (must be positive and finite).
    pub gamma: f64,
}

/// Exact power of two as f32, built in the exponent field (no libm).
/// `e` must be in the normal range [-126, 127].
#[inline]
pub(crate) fn pow2_f32(e: i32) -> f32 {
    debug_assert!((-126..=127).contains(&e), "pow2_f32 exponent {e} out of normal range");
    f32::from_bits(((e + 127) as u32) << 23)
}

/// One ConSmax instance: frozen β/γ plus the fractional-power LUT.
pub struct ConSmax {
    cfg: ConSmaxConfig,
    inv_gamma: f64,
    /// `lut[i] = 2^(i / LUT_LEN)` — the fractional power, f32 grid.
    lut: [f32; LUT_LEN],
}

impl ConSmax {
    /// Build from explicit parameters.  Panics on a non-positive or
    /// non-finite γ (a construction-time programmer error, like a zero
    /// row length).
    pub fn new(cfg: ConSmaxConfig) -> ConSmax {
        assert!(
            cfg.gamma.is_finite() && cfg.gamma > 0.0 && cfg.beta.is_finite(),
            "consmax parameters must be finite with gamma > 0 (beta {}, gamma {})",
            cfg.beta,
            cfg.gamma
        );
        let mut lut = [0f32; LUT_LEN];
        for (i, v) in lut.iter_mut().enumerate() {
            *v = (i as f64 / LUT_LEN as f64).exp2() as f32;
        }
        ConSmax { inv_gamma: 1.0 / cfg.gamma, cfg, lut }
    }

    /// The registered calibration for rows of length `l`: β =
    /// [`CONSMAX_BETA`] and γ = l · exp(σ²/2 - β) = l at σ =
    /// [`CONSMAX_SIGMA_REF`] — the γ that normalizes the *expected* row
    /// sum over the reference distribution.  Real rows deviate (that is
    /// the trade ConSmax makes); the accuracy harness measures by how
    /// much.
    pub fn for_len(l: usize) -> ConSmax {
        assert!(l > 0, "consmax rows must be non-empty");
        let gamma = l as f64
            * (CONSMAX_SIGMA_REF * CONSMAX_SIGMA_REF / 2.0 - CONSMAX_BETA).exp();
        ConSmax::new(ConSmaxConfig { beta: CONSMAX_BETA, gamma })
    }

    /// The (construction-frozen) parameters.
    pub fn cfg(&self) -> ConSmaxConfig {
        self.cfg
    }

    /// One element through the datapath.  NaN logits map to probability
    /// 0 (treated as -inf, the same row-poisoning guard as the E2Softmax
    /// quantizer's bottom code).
    #[inline]
    pub fn forward_elem(&self, x: f32) -> f32 {
        if x.is_nan() {
            return 0.0;
        }
        let s = ((x as f64 - self.cfg.beta) * std::f64::consts::LOG2_E)
            .clamp(-EXP_CLAMP, EXP_CLAMP);
        // Q(.FRAC_BITS) exponent code: integer part -> exponent field,
        // fractional part -> LUT index.  `>>` is an arithmetic shift on
        // i64, so negative codes floor-divide as the hardware would.
        let t = (s * LUT_LEN as f64).floor() as i64;
        let q = (t >> CONSMAX_FRAC_BITS) as i32;
        let f = (t & FRAC_MASK) as usize;
        (self.lut[f] as f64 * pow2_f32(q) as f64 * self.inv_gamma) as f32
    }

    /// Elementwise kernel over any slice — *the* streaming primitive:
    /// `forward_chunk` over arbitrary splits of a row concatenates to
    /// exactly `forward_row_f32` of the whole row.
    pub fn forward_chunk(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), out.len(), "consmax chunk out len mismatch");
        for (o, &v) in out.iter_mut().zip(x) {
            *o = self.forward_elem(v);
        }
    }

    /// One whole row (identical math to `forward_chunk`; kept for API
    /// parallelism with the reduction-bearing kernels).
    pub fn forward_row_f32(&self, x: &[f32], out: &mut [f32]) {
        self.forward_chunk(x, out);
    }

    /// Packed planar batch of rows of length `l` — bit-exact to per-row
    /// `forward_row_f32`.
    pub fn forward_batch_f32(&self, x: &[f32], l: usize, out: &mut [f32]) {
        assert!(l > 0, "consmax rows must be non-empty");
        assert!(x.len() % l == 0, "packed batch len {} is not a multiple of {l}", x.len());
        assert!(x.len() == out.len(), "out len {} != batch len {}", out.len(), x.len());
        self.forward_chunk(x, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::e2::softmax_exact;
    use crate::util::proptest::{check, size};
    use crate::util::rng::Rng;

    fn gen(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * CONSMAX_SIGMA_REF) as f32).collect()
    }

    #[test]
    fn pow2_matches_exp2() {
        for e in -126..=127 {
            assert_eq!(pow2_f32(e), (e as f32).exp2(), "e={e}");
        }
    }

    #[test]
    fn chunked_concatenation_is_bitwise_whole_row() {
        check("consmax-chunked", 60, 0xC05, |rng| {
            let n = size(rng, 512);
            let x = gen(rng, n);
            let sm = ConSmax::for_len(n);
            let mut whole = vec![0f32; n];
            sm.forward_row_f32(&x, &mut whole);
            for &chunk in &[1usize, 7, 64, n] {
                let mut cat = Vec::with_capacity(n);
                for piece in x.chunks(chunk) {
                    let mut o = vec![0f32; piece.len()];
                    sm.forward_chunk(piece, &mut o);
                    cat.extend_from_slice(&o);
                }
                assert_eq!(cat, whole, "chunk={chunk} n={n}");
            }
        });
    }

    #[test]
    fn batch_matches_rows_bitwise() {
        let l = 96;
        let b = 5;
        let mut rng = Rng::new(17);
        let x = gen(&mut rng, b * l);
        let sm = ConSmax::for_len(l);
        let mut batch = vec![0f32; b * l];
        sm.forward_batch_f32(&x, l, &mut batch);
        let mut row = vec![0f32; l];
        for r in 0..b {
            sm.forward_row_f32(&x[r * l..(r + 1) * l], &mut row);
            assert_eq!(&batch[r * l..(r + 1) * l], &row[..], "row {r}");
        }
    }

    #[test]
    fn tracks_exact_softmax_on_the_calibrated_distribution() {
        // ConSmax is not normalized per row — the constant γ only matches
        // the row sum in expectation — so the ceiling is looser than the
        // reduction-bearing comparators'.  The accuracy harness records
        // the measured defect; this pins the order of magnitude.
        let mut rng = Rng::new(5);
        let mut worst: f64 = 0.0;
        for _ in 0..20 {
            let x = gen(&mut rng, 64);
            let sm = ConSmax::for_len(64);
            let exact = softmax_exact(&x);
            let mut out = vec![0f32; 64];
            sm.forward_row_f32(&x, &mut out);
            for (o, e) in out.iter().zip(&exact) {
                worst = worst.max((*o as f64 - e).abs());
            }
        }
        assert!(worst < 0.35, "worst {worst}");
    }

    #[test]
    fn monotone_and_positive() {
        check("consmax-monotone", 40, 0xC06, |rng| {
            let n = size(rng, 200).max(2);
            let x = gen(rng, n);
            let sm = ConSmax::for_len(n);
            let mut out = vec![0f32; n];
            sm.forward_row_f32(&x, &mut out);
            for i in 0..n {
                assert!(out[i] >= 0.0, "negative probability at {i}");
                for j in 0..n {
                    if x[i] > x[j] {
                        // the LUT floor-quantizes the exponent, so ties on
                        // the code grid are allowed but never inversions
                        assert!(out[i] >= out[j], "i={i} j={j} {} {}", out[i], out[j]);
                    }
                }
            }
        });
    }

    #[test]
    fn nan_maps_to_zero_and_does_not_poison_neighbors() {
        let sm = ConSmax::for_len(4);
        let x = [0.5f32, f32::NAN, 2.0, -1.0];
        let clean = [0.5f32, 0.0, 2.0, -1.0];
        let mut out = vec![0f32; 4];
        sm.forward_row_f32(&x, &mut out);
        assert_eq!(out[1], 0.0);
        let mut out_clean = vec![0f32; 4];
        sm.forward_row_f32(&clean, &mut out_clean);
        // elementwise: the other slots are untouched by the NaN
        assert_eq!(out[0], out_clean[0]);
        assert_eq!(out[2], out_clean[2]);
        assert_eq!(out[3], out_clean[3]);
    }

    #[test]
    fn extreme_logits_saturate_finite() {
        let sm = ConSmax::for_len(8);
        for &v in &[f32::MAX, f32::MIN, 1e30, -1e30, f32::INFINITY, f32::NEG_INFINITY] {
            let y = sm.forward_elem(v);
            assert!(y.is_finite(), "input {v} -> {y}");
            assert!(y >= 0.0, "input {v} -> {y}");
        }
        // -inf lands on (a scaled version of) the bottom of the grid
        assert!(sm.forward_elem(f32::NEG_INFINITY) < sm.forward_elem(0.0));
    }

    #[test]
    fn default_calibration_gamma_is_row_length() {
        // σ²/2 == β at the reference calibration, so γ = l exactly
        let sm = ConSmax::for_len(64);
        assert_eq!(sm.cfg().gamma, 64.0);
        assert_eq!(sm.cfg().beta, CONSMAX_BETA);
    }
}
