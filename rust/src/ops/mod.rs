#![warn(missing_docs)]
//! The operator layer: one `Op` trait from kernel to router.
//!
//! SOLE's claim is comparative — E2Softmax and AILayerNorm versus exact
//! and prior approximations — so the serving stack must treat "which
//! operator" as data, not as a hand-rolled backend struct per algorithm.
//! Everything that computes a batch-of-items operator implements [`Op`]:
//!
//! * `name()` / `dim()` / `item_len()` / `out_len()` — identity and
//!   shape, rendered as the spec string `<op>/<DIM><len>[x<DIM><len>...]`
//!   ([`OpSpec`], e.g. `e2softmax/L128`, `attention/L128xD64`) that the
//!   registry, router, CLI and benches speak;
//! * `make_scratch()` — an opaque per-worker scratch arena so hot ops
//!   stay allocation-free at steady state without interior mutability;
//! * `run_batch(rows, input, out, scratch)` — one call over a packed
//!   planar batch, writing into caller buffers;
//! * `in_port()` / `out_port()` / `run_batch_ports(...)` — the typed
//!   inter-stage port system ([`port`], DESIGN.md §3.3): an op can
//!   declare that it emits or consumes a quantized format
//!   ([`PortType::Log2Code5`], [`PortType::PtfU8`]) instead of f32, and
//!   `PipelineOp` stages it at that width.  Everything defaults to
//!   [`PortType::F32`], so single-stage ops are untouched.
//!
//! [`OpRegistry`] maps family names to fallible constructors, so a new
//! variant (a ConSmax-style softmax, a fused GELU) is one trait impl plus
//! one `register` call — the coordinator (`OpBackend`), `ServiceRouter`,
//! `sole serve --ops`, `sole ops` and `bench_serving` pick it up with no
//! further plumbing.  Construction is fallible end to end: there is no
//! panicking constructor anywhere in this layer.
//!
//! Registered families: the paper pair (`e2softmax`, `ailayernorm`), the
//! exact baselines (`softmax-exact`, `layernorm-exact`), the prior-work
//! comparators from `softmax/baselines.rs` / `layernorm/baselines.rs`
//! (`softermax`, `ibert-softmax`, `ibert-layernorm`), the multi-stage
//! attention pipelines (`attention`, `attention-exact` — [`PipelineOp`]s
//! built in [`attention`], DESIGN.md §3.2; the fused `attention` chains
//! softmax→A·V through the `Log2Code5` port), and `ailayernorm-ptf`
//! (AILayerNorm staged through its `PtfU8` out-port plus the
//! auto-inserted [`port::DequantOp`] adapter) — every one servable side
//! by side for accuracy/throughput comparison.  PR 8 adds the
//! transformer-block tier: multi-head attention packing (`H` specs like
//! `attention/H8xL128xD64`, [`PipelineOp`] heads), the `block` family
//! ([`block`]: AILayerNorm → attention → residual-add with every
//! internal boundary on a quantized port, including a direct `ptf-u8`
//! consumer), and the stateful `decode-attention` family ([`decode`]: a
//! KV-cache op served through the session-affine decode service, never
//! through `OpBackend`).  PR 10 adds the reduction-free streaming family
//! ([`streaming`]: `consmax`, `gn-softmax` — elementwise softmax
//! variants that declare [`Op::reduction_free`] and implement the
//! chunked streaming trio [`Op::begin_row`] / [`Op::push_chunk`] /
//! [`Op::finish_row`], served a row at a time by the stream service,
//! DESIGN.md §3.6).  A shared conformance suite
//! (`tests/op_conformance.rs`) pins each registered op bit-exact to its
//! direct kernel.
//!
//! ## Spec parsing
//!
//! ```
//! use sole::ops::{Op, OpRegistry, OpSpec};
//!
//! // the grammar alone: <op>/<DIM><len>[x<DIM><len>...]
//! let spec = OpSpec::parse("attention/L128xD64")?;
//! assert_eq!(spec.op, "attention");
//! assert_eq!((spec.dim, spec.len), ('L', 128));
//! assert_eq!(spec.extra, vec![('D', 64)]);
//! assert_eq!(spec.to_string(), "attention/L128xD64");
//!
//! // the registry-validated path used by `sole serve --ops`: unknown
//! // families and wrong dimension letters are errors, and `build`
//! // returns the constructed operator alongside its canonical spec
//! let registry = OpRegistry::builtin();
//! let (spec, op) = registry.build("e2softmax/L49")?;
//! assert_eq!(spec.to_string(), "e2softmax/L49");
//! assert_eq!(op.item_len(), 49);
//! assert!(registry.build("e2softmax/C49").is_err());
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod ailayernorm;
pub mod attention;
pub mod baselines;
pub mod block;
pub mod decode;
pub mod e2softmax;
pub mod exact;
pub mod pipeline;
pub mod port;
pub mod registry;
pub mod spec;
pub mod streaming;

use anyhow::Result;

pub use ailayernorm::AiLayerNormOp;
pub use baselines::{IbertLayerNormOp, IbertSoftmaxOp, SoftermaxOp};
pub use decode::DecodeAttnOp;
pub use e2softmax::E2SoftmaxOp;
pub use exact::{ExactLayerNormOp, ExactSoftmaxOp};
pub use pipeline::PipelineOp;
pub use port::{check_batch_ports, DequantOp, PortMut, PortRef, PortType, StageBuf};
pub use registry::OpRegistry;
pub use spec::OpSpec;
pub use streaming::{ConSmaxOp, GnSoftmaxOp};

/// Opaque per-worker scratch arena.  A worker creates one per op via
/// [`Op::make_scratch`] and hands it back on every `run_batch`, so ops
/// reuse buffers without locks; stateless ops keep the default `()`.
pub type OpScratch = Box<dyn std::any::Any + Send>;

/// Opaque per-session state for stateful ops ([`Op::make_state`]).
/// Unlike scratch (per worker, contents never observable across
/// batches), state is per *session* and carries meaning between requests
/// — e.g. the KV cache a decode op appends to.  State lives in the
/// serving layer (the decode service's worker owns it, keyed by session
/// id), never inside the op itself, so one op instance serves any number
/// of concurrent sessions.
pub type OpState = Box<dyn std::any::Any + Send>;

/// One batch operator: the single API every kernel is served through.
///
/// Most of the paper's nonlinear ops are shape-preserving row transforms
/// (`out_len() == item_len()`, the default); pipelines such as the fused
/// attention op consume one shape and produce another.
pub trait Op: Send + Sync {
    /// Registry family name, e.g. `e2softmax` (no `/`).
    fn name(&self) -> &str;

    /// Primary dimension letter of the spec grammar (`L` rows,
    /// `C` channels).
    fn dim(&self) -> char;

    /// Flat f32 length of one input item.
    fn item_len(&self) -> usize;

    /// Flat f32 length of one output item.  Defaults to `item_len()`
    /// (shape-preserving row transforms); pipelines override.
    fn out_len(&self) -> usize {
        self.item_len()
    }

    /// Canonical spec of this instance; `OpSpec::parse` round-trips it.
    /// The default covers one-dimensional ops; multi-dimensional ops
    /// (pipelines) override with their full shape.
    fn spec(&self) -> OpSpec {
        OpSpec {
            op: self.name().to_string(),
            dim: self.dim(),
            len: self.item_len(),
            extra: vec![],
        }
    }

    /// Numeric format of one input item — the port the previous stage
    /// (or the router edge, which only speaks f32) must produce.
    /// Defaults to [`PortType::F32`], so single-stage ops are untouched
    /// by the port system.
    fn in_port(&self) -> PortType {
        PortType::F32
    }

    /// Numeric format of one output item.  Defaults to
    /// [`PortType::F32`].
    fn out_port(&self) -> PortType {
        PortType::F32
    }

    /// f32 sidecar elements accompanying one *input* item on a quantized
    /// in-port (per-code-row dequantization headers, then any f32
    /// passthrough tail).  Always 0 for an `F32` in-port.
    fn in_side_len(&self) -> usize {
        0
    }

    /// f32 sidecar elements accompanying one *output* item on a
    /// quantized out-port.  Always 0 for an `F32` out-port.
    fn out_side_len(&self) -> usize {
        0
    }

    /// On a quantized out-port: how many dequantization groups ("code
    /// rows") one item's codes split into.  The sidecar leads with one
    /// header per code row ([`PortType::side_per_code_row`] f32 each),
    /// optionally followed by an f32 passthrough tail.  Irrelevant for
    /// `F32` (default 1).
    fn out_code_rows(&self) -> usize {
        1
    }

    /// Port type at each *internal* stage boundary, in execution order —
    /// empty for single-stage ops.  Pipelines override so callers (the
    /// CLI listing, benches, the conformance quantized-boundary guard)
    /// can see where quantized staging happens without downcasting.
    fn boundary_ports(&self) -> Vec<PortType> {
        Vec::new()
    }

    /// Bytes one item occupies in the staging buffer at each internal
    /// stage boundary, in execution order — empty for single-stage ops.
    /// For pipelines this is code bytes at the boundary port's width plus
    /// the f32 sidecar: the number the paper's inter-stage storage claim
    /// lives in, surfaced by `sole ops` and `bench_kernels --json`.
    fn staging_bytes_per_item(&self) -> Vec<usize> {
        Vec::new()
    }

    /// The SIMD kernel arm this op's hot loops selected at construction
    /// (`crate::simd::Dispatch`, DESIGN.md §3.4) — `None` for ops with
    /// no vectorized kernel.  Surfaced by `sole ops` and both bench
    /// records so trajectories from different machines stay comparable;
    /// pipelines report their first dispatched stage.
    fn dispatch(&self) -> Option<crate::simd::Dispatch> {
        None
    }

    /// Create the per-worker scratch arena (stateless ops keep the
    /// default).
    fn make_scratch(&self) -> OpScratch {
        Box::new(())
    }

    /// Run `rows` items: `input.len() == rows * item_len()`, writing
    /// `rows * out_len()` f32s into `out`.  Hot-path implementations keep
    /// every temporary in `scratch` so steady-state execution is
    /// allocation-free; baseline/comparator ops may allocate.  A
    /// `rows == 0` batch (empty slices) is a no-op success.  Ops with a
    /// quantized port error here and are driven through
    /// [`Op::run_batch_ports`] instead.
    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()>;

    /// Typed-port twin of [`Op::run_batch`]: the same batch contract,
    /// with input and output tagged by format.  The default handles the
    /// all-f32 case by delegating to `run_batch`; ops with a quantized
    /// port override.
    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        match (input, out) {
            (PortRef::F32(input), PortMut::F32(out)) => self.run_batch(rows, input, out, scratch),
            (input, out) => anyhow::bail!(
                "op '{}': no {} -> {} path (op declares {} -> {}; override run_batch_ports)",
                self.name(),
                input.port(),
                out.port(),
                self.in_port(),
                self.out_port()
            ),
        }
    }

    /// Whether this op carries per-session state across requests
    /// ([`Op::make_state`] / [`Op::run_batch_stateful`]).  Stateful ops
    /// cannot be served through the stateless `OpBackend` path — the
    /// decode service drives them with session affinity instead.
    /// Defaults to `false`; everything registered before the decode
    /// family is stateless.
    fn stateful(&self) -> bool {
        false
    }

    /// Create fresh per-session state (a new, empty KV cache for a
    /// decode op).  Stateless ops keep the default `()`.
    fn make_state(&self) -> OpState {
        Box::new(())
    }

    /// Stateful twin of [`Op::run_batch`]: the same batch contract, plus
    /// mutable per-session state that persists across calls.  Rows are
    /// processed in order — for a decode op, each row appends one step to
    /// the session.  The default delegates to `run_batch` (stateless ops
    /// ignore the state); stateful ops override and make `run_batch`
    /// error, so a stateless serving path cannot silently drop state.
    fn run_batch_stateful(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
        _state: &mut OpState,
    ) -> Result<()> {
        self.run_batch(rows, input, out, scratch)
    }

    /// Whether this op needs no row-wide reduction: every output element
    /// is a function of its own input element alone (ConSmax replaces
    /// the max/sum with learnable constants, GN-Softmax with a
    /// calibration reference and a fixed shift).  Reduction-free ops
    /// additionally implement the streaming trio ([`Op::begin_row`] /
    /// [`Op::push_chunk`] / [`Op::finish_row`]), and the stream service
    /// (`coordinator/stream.rs`, DESIGN.md §3.6) serves them a row at a
    /// time in arbitrary chunks — the length of a streamed row is *not*
    /// bounded by `item_len()` (that is the batch-path shape); the
    /// contract is that chunked processing of an `item_len()`-length row
    /// is bit-identical to [`Op::run_batch`] over it.  Defaults to
    /// `false`; ops with a reduction (or a quantized port) never stream.
    fn reduction_free(&self) -> bool {
        false
    }

    /// Open fresh per-row streaming state ([`Op::reduction_free`] ops
    /// only).  Like session state, row state lives in the serving layer
    /// — the stream service's worker owns it, keyed by row id — never
    /// inside the op.  Purely elementwise ops keep the default `()`.
    fn begin_row(&self) -> OpState {
        Box::new(())
    }

    /// Append the outputs for one chunk of an open row to `out`.  The
    /// concatenation of every `push_chunk` output plus the
    /// [`Op::finish_row`] tail, in order, is bit-identical to
    /// `run_batch` over the whole row.  Chunks are non-empty; chunk
    /// boundaries are arbitrary.  The default errors: ops that carry a
    /// reduction cannot stream.
    fn push_chunk(&self, _state: &mut OpState, _chunk: &[f32], _out: &mut Vec<f32>) -> Result<()> {
        anyhow::bail!(
            "op '{}' is not reduction-free; it cannot stream row chunks",
            self.name()
        )
    }

    /// Close an open row, appending any tail output to `out` (empty for
    /// purely elementwise ops).  The default errors like
    /// [`Op::push_chunk`].
    fn finish_row(&self, _state: &mut OpState, _out: &mut Vec<f32>) -> Result<()> {
        anyhow::bail!(
            "op '{}' is not reduction-free; it cannot stream row chunks",
            self.name()
        )
    }
}

/// Shared shape validation every `run_batch` implementation starts with
/// (public so operators registered from outside this crate can enforce
/// the same contract; `OpBackend` also checks it at the serving
/// boundary, so a forgetful impl still cannot read a mis-sized buffer).
/// `rows == 0` with empty slices is valid — an empty batch is a no-op
/// success for every op, not an error (pinned per registered op by the
/// conformance suite).
pub fn check_batch(op: &dyn Op, rows: usize, input: &[f32], out: &[f32]) -> Result<()> {
    let item = op.item_len();
    let out_item = op.out_len();
    anyhow::ensure!(
        input.len() == rows * item,
        "op '{}': input len {} != {rows} rows * {item}",
        op.name(),
        input.len()
    );
    anyhow::ensure!(
        out.len() == rows * out_item,
        "op '{}': out len {} != {rows} rows * {out_item}",
        op.name(),
        out.len()
    );
    Ok(())
}
