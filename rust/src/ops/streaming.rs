//! The reduction-free streaming softmax family as [`Op`]s: ConSmax
//! (learnable β/γ, arxiv 2402.10930) and GN-Softmax (guaranteed
//! normalization, arxiv 2604.23647).  These wrap the functional models
//! in `softmax/consmax.rs` / `softmax/gnsoftmax.rs`.
//!
//! Both ops are elementwise, so besides the usual planar `run_batch`
//! they implement the streaming trio (`begin_row` / `push_chunk` /
//! `finish_row`) and declare [`Op::reduction_free`]: the stream service
//! feeds them a row in arbitrary chunks and the concatenated outputs are
//! bit-identical to the whole-row batch path.  The spec length `L` fixes
//! the *batch-path* row shape (and the calibration γ / μ·S); streamed
//! rows are not length-checked — that is the point of the family.

use anyhow::Result;

use super::{check_batch, Op, OpScratch, OpState};
use crate::softmax::{ConSmax, GnSoftmax};

/// ConSmax rows of length `l` (spec `consmax/L<l>`), the registered
/// β/γ calibration of [`ConSmax::for_len`].
pub struct ConSmaxOp {
    l: usize,
    sm: ConSmax,
}

impl ConSmaxOp {
    /// Row length `l` at the registered calibration.
    pub fn try_new(l: usize) -> Result<ConSmaxOp> {
        anyhow::ensure!(l > 0, "consmax rows must be non-empty");
        Ok(ConSmaxOp { l, sm: ConSmax::for_len(l) })
    }

    /// The wrapped kernel (accuracy harness access).
    pub fn kernel(&self) -> &ConSmax {
        &self.sm
    }
}

impl Op for ConSmaxOp {
    fn name(&self) -> &str {
        "consmax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        if rows > 0 {
            self.sm.forward_batch_f32(input, self.l, out);
        }
        Ok(())
    }

    fn reduction_free(&self) -> bool {
        true
    }

    fn push_chunk(&self, _state: &mut OpState, chunk: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let start = out.len();
        out.resize(start + chunk.len(), 0.0);
        self.sm.forward_chunk(chunk, &mut out[start..]);
        Ok(())
    }

    fn finish_row(&self, _state: &mut OpState, _out: &mut Vec<f32>) -> Result<()> {
        Ok(())
    }
}

/// GN-Softmax rows of length `l` (spec `gn-softmax/L<l>`), the
/// registered μ/S calibration of [`GnSoftmax::for_len`].
pub struct GnSoftmaxOp {
    l: usize,
    sm: GnSoftmax,
}

impl GnSoftmaxOp {
    /// Row length `l` at the registered calibration.
    pub fn try_new(l: usize) -> Result<GnSoftmaxOp> {
        anyhow::ensure!(l > 0, "gn-softmax rows must be non-empty");
        Ok(GnSoftmaxOp { l, sm: GnSoftmax::for_len(l) })
    }

    /// The wrapped kernel (accuracy harness access).
    pub fn kernel(&self) -> &GnSoftmax {
        &self.sm
    }
}

impl Op for GnSoftmaxOp {
    fn name(&self) -> &str {
        "gn-softmax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        if rows > 0 {
            self.sm.forward_batch_f32(input, self.l, out);
        }
        Ok(())
    }

    fn reduction_free(&self) -> bool {
        true
    }

    fn push_chunk(&self, _state: &mut OpState, chunk: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let start = out.len();
        out.resize(start + chunk.len(), 0.0);
        self.sm.forward_chunk(chunk, &mut out[start..]);
        Ok(())
    }

    fn finish_row(&self, _state: &mut OpState, _out: &mut Vec<f32>) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ops() -> Vec<Box<dyn Op>> {
        vec![
            Box::new(ConSmaxOp::try_new(96).unwrap()),
            Box::new(GnSoftmaxOp::try_new(96).unwrap()),
        ]
    }

    #[test]
    fn family_declares_reduction_free() {
        for op in ops() {
            assert!(op.reduction_free(), "{}", op.name());
            assert!(!op.stateful(), "{}", op.name());
            assert_eq!(op.out_len(), op.item_len(), "{}", op.name());
        }
    }

    #[test]
    fn streamed_chunks_match_run_batch_bitwise() {
        let mut rng = Rng::new(0x57A3);
        for op in ops() {
            let l = op.item_len();
            let mut x = vec![0f32; l];
            rng.fill_normal(&mut x, 0.0, 2.0);
            let mut whole = vec![0f32; l];
            let mut scratch = op.make_scratch();
            op.run_batch(1, &x, &mut whole, &mut scratch).unwrap();
            for &chunk in &[1usize, 7, 64, l] {
                let mut state = op.begin_row();
                let mut cat = Vec::with_capacity(l);
                for piece in x.chunks(chunk) {
                    op.push_chunk(&mut state, piece, &mut cat).unwrap();
                }
                op.finish_row(&mut state, &mut cat).unwrap();
                assert_eq!(cat, whole, "{} chunk={chunk}", op.name());
            }
        }
    }

    #[test]
    fn streamed_rows_are_not_bounded_by_the_spec_length() {
        // the spec L pins the batch shape and calibration only; the
        // stream path takes rows of any length
        let mut rng = Rng::new(0x57A4);
        for op in ops() {
            let n = 3 * op.item_len() + 11;
            let mut x = vec![0f32; n];
            rng.fill_normal(&mut x, 0.0, 2.0);
            let mut state = op.begin_row();
            let mut out = Vec::new();
            for piece in x.chunks(100) {
                op.push_chunk(&mut state, piece, &mut out).unwrap();
            }
            op.finish_row(&mut state, &mut out).unwrap();
            assert_eq!(out.len(), n, "{}", op.name());
        }
    }

    #[test]
    fn reduction_bearing_ops_refuse_to_stream() {
        let op = crate::ops::E2SoftmaxOp::try_new(32).unwrap();
        assert!(!op.reduction_free());
        let mut state = op.begin_row();
        let mut out = Vec::new();
        let err = op.push_chunk(&mut state, &[0.0; 4], &mut out).unwrap_err();
        assert!(format!("{err:#}").contains("not reduction-free"), "{err:#}");
        assert!(op.finish_row(&mut state, &mut out).is_err());
    }

    #[test]
    fn zero_rows_batch_is_a_no_op() {
        for op in ops() {
            let mut scratch = op.make_scratch();
            op.run_batch(0, &[], &mut [], &mut scratch).unwrap();
        }
    }
}
