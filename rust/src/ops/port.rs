//! Typed inter-stage ports: the numeric *format* flowing between pipeline
//! stages, not just the shape.
//!
//! SOLE's second headline claim is low bit-width **storage** — E2Softmax
//! emits 5-bit log2 shift codes, AILayerNorm's PTF stage emits u8 codes —
//! yet an all-f32 staging arena would dequantize, re-materialize f32 and
//! re-quantize at every stage boundary, paying 4x the memory traffic the
//! paper's datapath pays.  A [`PortType`] names what one item actually
//! looks like on the wire between two stages; [`PortRef`]/[`PortMut`] are
//! the tagged views a stage reads/writes; [`StageBuf`] is the staging
//! buffer `PipelineOp`'s ping-pong arena carries instead of `Vec<f32>`.
//!
//! Quantized ports carry two planes per batch:
//!
//! * **codes** — one `u8` per payload element (`Op::out_len` elements per
//!   item).  `Log2Code5` stores the 5-bit total-shift code of E2Softmax;
//!   `PtfU8` stores an 8-bit affine code around `DEFAULT_ZP`.
//! * **side** — `Op::out_side_len` f32 per item: one small dequantization
//!   header per *code row* (`Op::out_code_rows` rows per item —
//!   `[c, base_shift]` for `Log2Code5`, one row scale for `PtfU8`),
//!   optionally followed by an f32 passthrough tail for payload the
//!   format does not touch (e.g. the V block riding through attention's
//!   softmax stage).
//!
//! Boundaries that genuinely mix formats are bridged by [`DequantOp`], an
//! explicit adapter stage `PipelineOp::try_new` auto-inserts (and the
//! registry can serve/bench, e.g. `ailayernorm-ptf`): quantized ports are
//! never silently widened — the adapter shows up in `stages()`, the CLI
//! listing and the bench tables.  The fused `attention` pipeline and the
//! `block` residual family are the native consumers: their stages read
//! `Log2Code5`/`PtfU8` inputs and dequantize inside their accumulation
//! loops, so those chains carry **no** adapter stages at all.  See
//! DESIGN.md §3.3 and §3.5.

use anyhow::Result;

use super::{Op, OpScratch};
use crate::quant::q8_dequantize;
use crate::softmax::e2::{expand_row_side, CODE_SIDE_LEN};

/// Numeric format of one item on a stage boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PortType {
    /// Plain f32 payload, 4 bytes/element, no sidecar.  The only format
    /// router-facing edges speak.
    #[default]
    F32,
    /// E2Softmax total-shift codes: one u8 (5 significant bits) per
    /// element plus a [`CODE_SIDE_LEN`]-f32 divider header per code row,
    /// expanded by consumers via
    /// [`expand_row_side`](crate::softmax::e2::expand_row_side).
    Log2Code5,
    /// Affine u8 codes around `DEFAULT_ZP` with one f32 scale per code
    /// row (the degenerate per-row PTF of `quant::q8_quantize_row_into`).
    PtfU8,
}

impl PortType {
    /// Short stable label used by the CLI listing and bench tables.
    pub fn label(self) -> &'static str {
        match self {
            PortType::F32 => "f32",
            PortType::Log2Code5 => "log2c5",
            PortType::PtfU8 => "ptf-u8",
        }
    }

    /// Staging bytes one *payload* element costs in this format
    /// (sidecar f32s are accounted separately: 4 bytes each).
    pub fn bytes_per_elem(self) -> usize {
        match self {
            PortType::F32 => 4,
            PortType::Log2Code5 | PortType::PtfU8 => 1,
        }
    }

    /// Sidecar header f32s per code row (0 for `F32`).
    pub fn side_per_code_row(self) -> usize {
        match self {
            PortType::F32 => 0,
            PortType::Log2Code5 => CODE_SIDE_LEN,
            PortType::PtfU8 => 1,
        }
    }
}

impl std::fmt::Display for PortType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Read-only tagged view of one staged batch.
#[derive(Debug, Clone, Copy)]
pub enum PortRef<'a> {
    /// `rows * item_len` plain f32.
    F32(&'a [f32]),
    /// E2Softmax shift codes + f32 sidecar (headers, then passthrough).
    Log2Code5 {
        /// `rows * item_len` packed total-shift codes.
        codes: &'a [u8],
        /// `rows * in_side_len` f32: per-code-row divider headers
        /// followed by the passthrough tail.
        side: &'a [f32],
    },
    /// PTF u8 codes + f32 sidecar (row scales, then passthrough).
    PtfU8 {
        /// `rows * item_len` affine u8 codes.
        codes: &'a [u8],
        /// `rows * in_side_len` f32: per-code-row scales followed by the
        /// passthrough tail.
        side: &'a [f32],
    },
}

impl PortRef<'_> {
    /// The format this view is tagged with.
    pub fn port(&self) -> PortType {
        match self {
            PortRef::F32(_) => PortType::F32,
            PortRef::Log2Code5 { .. } => PortType::Log2Code5,
            PortRef::PtfU8 { .. } => PortType::PtfU8,
        }
    }

    /// Payload elements in the view (f32 count or code count).
    pub fn elems(&self) -> usize {
        match self {
            PortRef::F32(v) => v.len(),
            PortRef::Log2Code5 { codes, .. } | PortRef::PtfU8 { codes, .. } => codes.len(),
        }
    }

    /// Sidecar f32 elements in the view (0 for `F32`).
    pub fn side_elems(&self) -> usize {
        match self {
            PortRef::F32(_) => 0,
            PortRef::Log2Code5 { side, .. } | PortRef::PtfU8 { side, .. } => side.len(),
        }
    }
}

/// Mutable tagged view of one staged batch (what a stage writes).
#[derive(Debug)]
pub enum PortMut<'a> {
    /// `rows * out_len` plain f32.
    F32(&'a mut [f32]),
    /// E2Softmax shift codes + f32 sidecar (headers, then passthrough).
    Log2Code5 {
        /// `rows * out_len` packed total-shift codes.
        codes: &'a mut [u8],
        /// `rows * out_side_len` f32: per-code-row divider headers
        /// followed by the passthrough tail.
        side: &'a mut [f32],
    },
    /// PTF u8 codes + f32 sidecar (row scales, then passthrough).
    PtfU8 {
        /// `rows * out_len` affine u8 codes.
        codes: &'a mut [u8],
        /// `rows * out_side_len` f32: per-code-row scales followed by the
        /// passthrough tail.
        side: &'a mut [f32],
    },
}

impl PortMut<'_> {
    /// The format this view is tagged with.
    pub fn port(&self) -> PortType {
        match self {
            PortMut::F32(_) => PortType::F32,
            PortMut::Log2Code5 { .. } => PortType::Log2Code5,
            PortMut::PtfU8 { .. } => PortType::PtfU8,
        }
    }

    /// Payload elements in the view (f32 count or code count).
    pub fn elems(&self) -> usize {
        match self {
            PortMut::F32(v) => v.len(),
            PortMut::Log2Code5 { codes, .. } | PortMut::PtfU8 { codes, .. } => codes.len(),
        }
    }

    /// Sidecar f32 elements in the view (0 for `F32`).
    pub fn side_elems(&self) -> usize {
        match self {
            PortMut::F32(_) => 0,
            PortMut::Log2Code5 { side, .. } | PortMut::PtfU8 { side, .. } => side.len(),
        }
    }
}

/// One tagged staging buffer of `PipelineOp`'s ping-pong arena.  All
/// three planes live side by side so switching a buffer between formats
/// across batches (or across differently-typed boundaries) reuses
/// capacity instead of reallocating — the same resize-no-clear contract
/// the f32 arena had, now per plane.
#[derive(Debug, Default)]
pub struct StageBuf {
    port: PortType,
    f32s: Vec<f32>,
    codes: Vec<u8>,
    side: Vec<f32>,
}

impl StageBuf {
    /// Retag the buffer as `port` sized for `elems` payload elements and
    /// `side_elems` sidecar f32, and return the writable view.  Plain
    /// resize, no clear: the `Op` contract requires the producing stage
    /// to write every element, so stale content from a previous batch is
    /// never observable.
    pub fn prepare(&mut self, port: PortType, elems: usize, side_elems: usize) -> PortMut<'_> {
        self.port = port;
        match port {
            PortType::F32 => {
                debug_assert_eq!(side_elems, 0, "f32 ports carry no sidecar");
                self.f32s.resize(elems, 0.0);
                PortMut::F32(&mut self.f32s)
            }
            PortType::Log2Code5 => {
                self.codes.resize(elems, 0);
                self.side.resize(side_elems, 0.0);
                PortMut::Log2Code5 { codes: &mut self.codes, side: &mut self.side }
            }
            PortType::PtfU8 => {
                self.codes.resize(elems, 0);
                self.side.resize(side_elems, 0.0);
                PortMut::PtfU8 { codes: &mut self.codes, side: &mut self.side }
            }
        }
    }

    /// Read-only view of whatever `prepare` last staged here.
    pub fn as_port_ref(&self) -> PortRef<'_> {
        match self.port {
            PortType::F32 => PortRef::F32(&self.f32s),
            PortType::Log2Code5 => PortRef::Log2Code5 { codes: &self.codes, side: &self.side },
            PortType::PtfU8 => PortRef::PtfU8 { codes: &self.codes, side: &self.side },
        }
    }
}

/// Shared port/shape validation for `run_batch_ports` implementations —
/// the typed twin of [`check_batch`](super::check_batch): the views must
/// carry the declared formats and exactly `rows` items of payload and
/// sidecar.
pub fn check_batch_ports(
    op: &dyn Op,
    rows: usize,
    input: &PortRef<'_>,
    out: &PortMut<'_>,
) -> Result<()> {
    anyhow::ensure!(
        input.port() == op.in_port(),
        "op '{}': {} input handed to a {} in-port",
        op.name(),
        input.port(),
        op.in_port()
    );
    anyhow::ensure!(
        out.port() == op.out_port(),
        "op '{}': {} output buffer handed to a {} out-port",
        op.name(),
        out.port(),
        op.out_port()
    );
    let item = op.item_len();
    anyhow::ensure!(
        input.elems() == rows * item,
        "op '{}': input len {} != {rows} rows * {item}",
        op.name(),
        input.elems()
    );
    let in_side = op.in_side_len();
    anyhow::ensure!(
        input.side_elems() == rows * in_side,
        "op '{}': input sidecar len {} != {rows} rows * {in_side}",
        op.name(),
        input.side_elems()
    );
    let out_item = op.out_len();
    anyhow::ensure!(
        out.elems() == rows * out_item,
        "op '{}': out len {} != {rows} rows * {out_item}",
        op.name(),
        out.elems()
    );
    let out_side = op.out_side_len();
    anyhow::ensure!(
        out.side_elems() == rows * out_side,
        "op '{}': out sidecar len {} != {rows} rows * {out_side}",
        op.name(),
        out.side_elems()
    );
    Ok(())
}

/// Explicit dequantization adapter: widens one quantized port back to
/// f32, code row by code row, copying any f32 passthrough tail through
/// unchanged.  `PipelineOp::try_new` auto-inserts one wherever a
/// boundary genuinely mixes formats (quantized producer, f32 consumer —
/// including the pipeline's own f32 tail edge); it is an ordinary
/// [`Op`], so adapters show up in `stages()`, the CLI listing and the
/// bench tables rather than hiding inside the arena.
pub struct DequantOp {
    name: &'static str,
    dim: char,
    in_port: PortType,
    /// u8 code elements per item (= producer `out_len`).
    elems: usize,
    /// Dequantization groups per item (= producer `out_code_rows`).
    code_rows: usize,
    /// Total sidecar f32 per item (= producer `out_side_len`).
    side: usize,
    /// f32 passthrough elements at the sidecar tail, appended verbatim
    /// after the widened codes.
    tail: usize,
}

impl DequantOp {
    /// Build the adapter matching `producer`'s out-port exactly.  Errors
    /// if the producer already emits f32 or declares an inconsistent
    /// code-row/sidecar layout.
    pub fn for_producer(producer: &dyn Op) -> Result<DequantOp> {
        let port = producer.out_port();
        let name = match port {
            PortType::F32 => anyhow::bail!(
                "dequant adapter: producer '{}' already emits f32",
                producer.name()
            ),
            PortType::Log2Code5 => "dequant-log2c5",
            PortType::PtfU8 => "dequant-ptf-u8",
        };
        let elems = producer.out_len();
        let code_rows = producer.out_code_rows();
        let side = producer.out_side_len();
        let headers = code_rows * port.side_per_code_row();
        anyhow::ensure!(
            elems > 0 && code_rows > 0 && elems % code_rows == 0,
            "dequant adapter: producer '{}' splits {elems} codes into {code_rows} rows",
            producer.name()
        );
        anyhow::ensure!(
            side >= headers,
            "dequant adapter: producer '{}' sidecar {side} f32/item is smaller than its \
             {code_rows} row headers ({headers} f32)",
            producer.name()
        );
        Ok(DequantOp {
            name,
            dim: producer.dim(),
            in_port: port,
            elems,
            code_rows,
            side,
            tail: side - headers,
        })
    }

    fn row_len(&self) -> usize {
        self.elems / self.code_rows
    }
}

impl Op for DequantOp {
    fn name(&self) -> &str {
        self.name
    }

    fn dim(&self) -> char {
        self.dim
    }

    fn item_len(&self) -> usize {
        self.elems
    }

    fn out_len(&self) -> usize {
        self.elems + self.tail
    }

    fn in_port(&self) -> PortType {
        self.in_port
    }

    fn in_side_len(&self) -> usize {
        self.side
    }

    fn run_batch(
        &self,
        _rows: usize,
        _input: &[f32],
        _out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::bail!(
            "op '{}' consumes a {} in-port; drive it through run_batch_ports",
            self.name,
            self.in_port
        )
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        let headers_len = self.code_rows * self.in_port.side_per_code_row();
        let (codes, side, o) = match (input, out) {
            (PortRef::Log2Code5 { codes, side }, PortMut::F32(o))
            | (PortRef::PtfU8 { codes, side }, PortMut::F32(o)) => (codes, side, o),
            (i, o) => anyhow::bail!(
                "op '{}': no {} -> {} path",
                self.name,
                i.port(),
                o.port()
            ),
        };
        for ((c_item, s_item), o_item) in codes
            .chunks_exact(self.elems)
            .zip(side.chunks_exact(self.side))
            .zip(o.chunks_exact_mut(self.out_len()))
        {
            let (headers, tail) = s_item.split_at(headers_len);
            let (o_codes, o_tail) = o_item.split_at_mut(self.elems);
            match self.in_port {
                PortType::Log2Code5 => {
                    for ((code_row, hdr), o_row) in c_item
                        .chunks_exact(self.row_len())
                        .zip(headers.chunks_exact(CODE_SIDE_LEN))
                        .zip(o_codes.chunks_exact_mut(self.row_len()))
                    {
                        let val = expand_row_side(hdr);
                        for (o, &c) in o_row.iter_mut().zip(code_row) {
                            *o = val[c as usize];
                        }
                    }
                }
                PortType::PtfU8 => {
                    for ((code_row, hdr), o_row) in c_item
                        .chunks_exact(self.row_len())
                        .zip(headers.chunks_exact(1))
                        .zip(o_codes.chunks_exact_mut(self.row_len()))
                    {
                        let scale = hdr[0];
                        for (o, &c) in o_row.iter_mut().zip(code_row) {
                            *o = q8_dequantize(c, scale);
                        }
                    }
                }
                PortType::F32 => unreachable!("for_producer rejects f32 producers"),
            }
            o_tail.copy_from_slice(tail);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::E2SoftmaxOp;
    use crate::quant::q8_quantize_row_into;
    use crate::softmax::config::ALDIV_C0;

    #[test]
    fn port_labels_and_byte_costs_are_pinned() {
        // the CLI listing, bench tables and DESIGN.md §3.3 all print these
        assert_eq!(PortType::F32.label(), "f32");
        assert_eq!(PortType::Log2Code5.label(), "log2c5");
        assert_eq!(PortType::PtfU8.label(), "ptf-u8");
        assert_eq!(PortType::F32.bytes_per_elem(), 4);
        assert_eq!(PortType::Log2Code5.bytes_per_elem(), 1);
        assert_eq!(PortType::PtfU8.bytes_per_elem(), 1);
        assert_eq!(PortType::Log2Code5.side_per_code_row(), CODE_SIDE_LEN);
        assert_eq!(PortType::PtfU8.side_per_code_row(), 1);
    }

    #[test]
    fn stage_buf_retags_and_reuses_capacity_across_formats() {
        let mut buf = StageBuf::default();
        match buf.prepare(PortType::F32, 64, 0) {
            PortMut::F32(v) => {
                assert_eq!(v.len(), 64);
                v.fill(1.5);
            }
            other => panic!("expected f32 view, got {}", other.port()),
        }
        let cap_f32 = buf.f32s.capacity();
        match buf.prepare(PortType::Log2Code5, 32, 2 * CODE_SIDE_LEN) {
            PortMut::Log2Code5 { codes, side } => {
                assert_eq!(codes.len(), 32);
                assert_eq!(side.len(), 2 * CODE_SIDE_LEN);
            }
            other => panic!("expected code view, got {}", other.port()),
        }
        assert_eq!(buf.as_port_ref().port(), PortType::Log2Code5);
        // switching back to a smaller f32 batch must not shrink capacity
        match buf.prepare(PortType::F32, 8, 0) {
            PortMut::F32(v) => assert_eq!(v.len(), 8),
            other => panic!("expected f32 view, got {}", other.port()),
        }
        assert_eq!(buf.f32s.capacity(), cap_f32);
        assert_eq!(buf.as_port_ref().elems(), 8);
        assert_eq!(buf.as_port_ref().side_elems(), 0);
    }

    #[test]
    fn check_batch_ports_rejects_format_and_shape_mismatches() {
        let op = E2SoftmaxOp::try_new(8).unwrap(); // f32 -> f32
        let input = vec![0f32; 16];
        let mut out = vec![0f32; 16];
        let codes = vec![0u8; 16];
        let side = vec![0f32; 4];
        // wrong input format
        let err = check_batch_ports(
            &op,
            2,
            &PortRef::Log2Code5 { codes: &codes, side: &side },
            &PortMut::F32(&mut out),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("log2c5 input handed to a f32 in-port"), "{err:#}");
        // wrong payload length
        let err =
            check_batch_ports(&op, 3, &PortRef::F32(&input), &PortMut::F32(&mut out)).unwrap_err();
        assert!(format!("{err:#}").contains("input len 16 != 3 rows * 8"), "{err:#}");
        // correct views pass
        check_batch_ports(&op, 2, &PortRef::F32(&input), &PortMut::F32(&mut out)).unwrap();
    }

    #[test]
    fn for_producer_rejects_f32_producers() {
        let op = E2SoftmaxOp::try_new(8).unwrap();
        let err = DequantOp::for_producer(&op).unwrap_err();
        assert!(format!("{err:#}").contains("already emits f32"), "{err:#}");
    }

    #[test]
    fn dequant_log2c5_expands_headers_and_copies_the_tail() {
        // a hand-built producer layout: 2 code rows of 4 codes + a 3-f32
        // passthrough tail per item
        struct FakeCodes;
        impl Op for FakeCodes {
            fn name(&self) -> &str {
                "fake-codes"
            }
            fn dim(&self) -> char {
                'L'
            }
            fn item_len(&self) -> usize {
                8
            }
            fn out_port(&self) -> PortType {
                PortType::Log2Code5
            }
            fn out_code_rows(&self) -> usize {
                2
            }
            fn out_side_len(&self) -> usize {
                2 * CODE_SIDE_LEN + 3
            }
            fn run_batch(
                &self,
                _rows: usize,
                _input: &[f32],
                _out: &mut [f32],
                _scratch: &mut OpScratch,
            ) -> Result<()> {
                unreachable!("test producer is never run")
            }
        }
        let ad = DequantOp::for_producer(&FakeCodes).unwrap();
        assert_eq!(ad.name(), "dequant-log2c5");
        assert_eq!((ad.item_len(), ad.out_len()), (8, 8 + 3));
        assert_eq!((ad.in_port(), ad.out_port()), (PortType::Log2Code5, PortType::F32));
        assert_eq!(ad.in_side_len(), 2 * CODE_SIDE_LEN + 3);

        let codes: Vec<u8> = vec![0, 1, 2, 3, 4, 3, 2, 1];
        // two divider headers with different base shifts, then the tail
        let side = [
            ALDIV_C0 as f32,
            1.0,
            ALDIV_C0 as f32,
            3.0,
            10.0,
            11.0,
            12.0,
        ];
        let mut out = vec![0f32; 11];
        let mut scratch = ad.make_scratch();
        ad.run_batch_ports(
            1,
            PortRef::Log2Code5 { codes: &codes, side: &side },
            PortMut::F32(&mut out),
            &mut scratch,
        )
        .unwrap();
        let t0 = expand_row_side(&side[0..2]);
        let t1 = expand_row_side(&side[2..4]);
        let want = [t0[0], t0[1], t0[2], t0[3], t1[4], t1[3], t1[2], t1[1], 10.0, 11.0, 12.0];
        assert_eq!(out, want);
        // the f32 entry point refuses: codes cannot arrive as f32
        let err = ad.run_batch(1, &[0.0; 8], &mut out[..8], &mut scratch).unwrap_err();
        assert!(format!("{err:#}").contains("run_batch_ports"), "{err:#}");
    }

    #[test]
    fn dequant_ptf_u8_round_trips_the_q8_row_codec() {
        struct FakePtf;
        impl Op for FakePtf {
            fn name(&self) -> &str {
                "fake-ptf"
            }
            fn dim(&self) -> char {
                'C'
            }
            fn item_len(&self) -> usize {
                6
            }
            fn out_port(&self) -> PortType {
                PortType::PtfU8
            }
            fn out_side_len(&self) -> usize {
                1
            }
            fn run_batch(
                &self,
                _rows: usize,
                _input: &[f32],
                _out: &mut [f32],
                _scratch: &mut OpScratch,
            ) -> Result<()> {
                unreachable!("test producer is never run")
            }
        }
        let ad = DequantOp::for_producer(&FakePtf).unwrap();
        assert_eq!(ad.name(), "dequant-ptf-u8");
        let rows = [[0.5f32, -1.25, 2.0, 0.0, -0.125, 1.0], [3.0, 0.25, -3.0, 1.5, 0.75, -0.5]];
        let mut codes = vec![0u8; 12];
        let mut side = vec![0f32; 2];
        for (r, row) in rows.iter().enumerate() {
            side[r] = q8_quantize_row_into(row, &mut codes[r * 6..(r + 1) * 6]);
        }
        let mut out = vec![0f32; 12];
        let mut scratch = ad.make_scratch();
        ad.run_batch_ports(
            2,
            PortRef::PtfU8 { codes: &codes, side: &side },
            PortMut::F32(&mut out),
            &mut scratch,
        )
        .unwrap();
        for (r, row) in rows.iter().enumerate() {
            for (i, &v) in row.iter().enumerate() {
                let got = out[r * 6 + i];
                assert_eq!(got, q8_dequantize(codes[r * 6 + i], side[r]), "row {r} elem {i}");
                assert!((got - v).abs() <= side[r] * 0.5 + 1e-6, "row {r} elem {i}: {got} vs {v}");
            }
        }
    }
}
