//! Attention pipelines: S = QKᵀ-scaled logits → softmax → A·V, as
//! [`PipelineOp`]s (DESIGN.md §3.2).
//!
//! This is the workload E2Softmax was co-designed for: the paper stores
//! attention probabilities as log2-quantized codes precisely so the
//! downstream A·V product degenerates into shift-and-accumulate instead
//! of full-width multiplies.  Three variants of the same datapath:
//!
//! * **`attention/L<len>xD<dim>`** (registered, fused) — [`AttnLogitsOp`]
//!   then [`AttnE2AvOp`]: the A·V stage consumes the packed 5-bit shift
//!   codes from [`E2Softmax::forward_batch_codes`] directly, dequantizing
//!   each weight through the row's ≤ 32-entry shifted-constant table
//!   inside the accumulation loop — the probability matrix is never
//!   materialized at f32 width.
//! * **`attention-unfused`** (unregistered comparator, built by
//!   [`unfused_pipeline`]) — [`AttnLogitsOp`] → [`AttnSoftmaxOp`] over
//!   [`E2SoftmaxOp`] → [`AttnAvOp`]: the same arithmetic staged through a
//!   full f32 probability buffer.  Bit-identical to the fused pipeline
//!   (pinned by `tests/op_conformance.rs`): both dequantize through the
//!   same table and accumulate in the same order, the fused path just
//!   never stores the f32s.
//! * **`attention-exact/L<len>xD<dim>`** (registered) — the same chain
//!   over [`ExactSoftmaxOp`], the error/latency reference.
//!
//! One item is one attention head instance, packed `[Q | K | V]` with
//! each of Q, K, V a row-major `L x D` block (item length `3·L·D`); the
//! output item is the `L x D` context block `O = softmax(QKᵀ/√D)·V`.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::{check_batch, E2SoftmaxOp, ExactSoftmaxOp, Op, OpScratch, OpSpec, PipelineOp};
use crate::softmax::e2::quantize_logits_batch_into;
use crate::softmax::{E2Scratch, E2Softmax, E2SoftmaxConfig, VAL_TABLE_LEN};

/// The canonical spec of an attention-family pipeline:
/// `<op>/L<len>xD<dim>`.
pub fn attention_spec(op: &str, l: usize, d: usize) -> OpSpec {
    OpSpec { op: op.to_string(), dim: 'L', len: l, extra: vec![('D', d)] }
}

/// The fused pipeline behind the registered `attention/L<len>xD<dim>`
/// spec: logits, then shift-accumulate A·V over E2Softmax log2 codes.
pub fn fused_pipeline(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(
        attention_spec("attention", l, d),
        vec![Arc::new(AttnLogitsOp::try_new(l, d)?), Arc::new(AttnE2AvOp::try_new(l, d)?)],
    )
}

/// The staged comparator (`attention-unfused`, not registered): the same
/// E2Softmax arithmetic through a materialized f32 probability buffer.
/// Bit-identical to [`fused_pipeline`]; exists so benches and tests can
/// measure exactly what fusing buys.
pub fn unfused_pipeline(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(
        attention_spec("attention-unfused", l, d),
        vec![
            Arc::new(AttnLogitsOp::try_new(l, d)?),
            Arc::new(AttnSoftmaxOp::try_new(l, d, Arc::new(E2SoftmaxOp::try_new(l)?))?),
            Arc::new(AttnAvOp::try_new(l, d)?),
        ],
    )
}

/// The exact-softmax pipeline behind the registered
/// `attention-exact/L<len>xD<dim>` spec: the error/latency reference the
/// fused pipeline is compared against.
pub fn exact_pipeline(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(
        attention_spec("attention-exact", l, d),
        vec![
            Arc::new(AttnLogitsOp::try_new(l, d)?),
            Arc::new(AttnSoftmaxOp::try_new(l, d, Arc::new(ExactSoftmaxOp::try_new(l)?))?),
            Arc::new(AttnAvOp::try_new(l, d)?),
        ],
    )
}

fn ensure_shape(name: &str, l: usize, d: usize) -> Result<()> {
    anyhow::ensure!(l > 0, "{name}: sequence length must be positive");
    anyhow::ensure!(d > 0, "{name}: head dimension must be positive");
    Ok(())
}

/// Stage 1 of every attention pipeline: `[Q | K | V]` (each `L x D`) →
/// `[S | V]` where `S = (QKᵀ)/√D` is the `L x L` logit block and V
/// passes through untouched for the downstream A·V stage.
pub struct AttnLogitsOp {
    l: usize,
    d: usize,
    scale: f32,
}

impl AttnLogitsOp {
    /// Sequence length `l`, head dimension `d`; the logit scale is the
    /// standard `1/√d`.
    pub fn try_new(l: usize, d: usize) -> Result<AttnLogitsOp> {
        ensure_shape("attn-logits", l, d)?;
        Ok(AttnLogitsOp { l, d, scale: 1.0 / (d as f32).sqrt() })
    }
}

impl Op for AttnLogitsOp {
    fn name(&self) -> &str {
        "attn-logits"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        3 * self.l * self.d
    }

    fn out_len(&self) -> usize {
        self.l * self.l + self.l * self.d
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let ld = self.l * self.d;
        for (item, out_item) in
            input.chunks_exact(self.item_len()).zip(out.chunks_exact_mut(self.out_len()))
        {
            let (q, rest) = item.split_at(ld);
            let (k, v) = rest.split_at(ld);
            let (s_out, v_out) = out_item.split_at_mut(self.l * self.l);
            for (qi, s_row) in q.chunks_exact(self.d).zip(s_out.chunks_exact_mut(self.l)) {
                for (kj, s_elem) in k.chunks_exact(self.d).zip(s_row.iter_mut()) {
                    let mut acc = 0f32;
                    for (&x, &y) in qi.iter().zip(kj) {
                        acc += x * y;
                    }
                    *s_elem = acc * self.scale;
                }
            }
            v_out.copy_from_slice(v);
        }
        Ok(())
    }
}

/// The staged softmax stage: applies any row softmax [`Op`] (item length
/// `l`) to the `L x L` logit block of `[S | V]`, passing V through.
/// Shape-preserving: `[S | V]` → `[P | V]`.
pub struct AttnSoftmaxOp {
    l: usize,
    d: usize,
    inner: Arc<dyn Op>,
}

/// Per-worker arena: the wrapped softmax op's own scratch.
struct SoftmaxScratch {
    inner: OpScratch,
}

impl AttnSoftmaxOp {
    /// Wrap `inner` (a shape-preserving row softmax of item length `l`)
    /// as the softmax stage of an `L x D` attention pipeline.
    pub fn try_new(l: usize, d: usize, inner: Arc<dyn Op>) -> Result<AttnSoftmaxOp> {
        ensure_shape("attn-softmax", l, d)?;
        anyhow::ensure!(
            inner.item_len() == l && inner.out_len() == l,
            "attn-softmax: inner op '{}' is {}->{} f32/item, need {l}->{l}",
            inner.name(),
            inner.item_len(),
            inner.out_len()
        );
        Ok(AttnSoftmaxOp { l, d, inner })
    }
}

impl Op for AttnSoftmaxOp {
    fn name(&self) -> &str {
        "attn-softmax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l * self.l + self.l * self.d
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(SoftmaxScratch { inner: self.inner.make_scratch() })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<SoftmaxScratch>()
            .context("attn-softmax handed a foreign scratch arena")?;
        let area = self.item_len();
        for (item, out_item) in input.chunks_exact(area).zip(out.chunks_exact_mut(area)) {
            let (s_in, v_in) = item.split_at(self.l * self.l);
            let (s_out, v_out) = out_item.split_at_mut(self.l * self.l);
            self.inner.run_batch(self.l, s_in, s_out, &mut s.inner)?;
            v_out.copy_from_slice(v_in);
        }
        Ok(())
    }
}

/// The staged A·V stage: `[P | V]` → `O`, a plain f32 matmul
/// `O[i] = Σ_j P[i,j]·V[j]`.  The j-then-d accumulation order is the
/// contract [`AttnE2AvOp`] mirrors for bit-exactness.
pub struct AttnAvOp {
    l: usize,
    d: usize,
}

impl AttnAvOp {
    /// Sequence length `l`, head dimension `d`.
    pub fn try_new(l: usize, d: usize) -> Result<AttnAvOp> {
        ensure_shape("attn-av", l, d)?;
        Ok(AttnAvOp { l, d })
    }
}

impl Op for AttnAvOp {
    fn name(&self) -> &str {
        "attn-av"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l * self.l + self.l * self.d
    }

    fn out_len(&self) -> usize {
        self.l * self.d
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        for (item, out_item) in
            input.chunks_exact(self.item_len()).zip(out.chunks_exact_mut(self.out_len()))
        {
            let (p, v) = item.split_at(self.l * self.l);
            for (p_row, o_row) in p.chunks_exact(self.l).zip(out_item.chunks_exact_mut(self.d)) {
                o_row.fill(0.0);
                for (&pij, v_row) in p_row.iter().zip(v.chunks_exact(self.d)) {
                    for (o, &vv) in o_row.iter_mut().zip(v_row) {
                        *o += pij * vv;
                    }
                }
            }
        }
        Ok(())
    }
}

/// The fused softmax + A·V stage: `[S | V]` → `O` without ever storing
/// the probability matrix as f32.  Each item's logit rows are quantized
/// to the 8-bit code grid and run through
/// [`E2Softmax::forward_batch_codes`], which yields one packed 5-bit
/// total-shift code per attention weight plus a ≤ 32-entry per-row table
/// of reachable divider outputs (shifted copies of one constant — the
/// software model of the hardware shift network).  The accumulation
/// `O[i] += table[code]·V[j]` then reads 1 byte per weight instead of 4,
/// and is bit-identical to [`AttnAvOp`] over [`E2SoftmaxOp`] output
/// because both paths dequantize through the same table in the same
/// order.
pub struct AttnE2AvOp {
    l: usize,
    d: usize,
    sm: E2Softmax,
}

/// Per-worker arena: quantized logit codes, packed shift codes, per-row
/// divider tables, and the E2Softmax kernel scratch.
struct E2AvScratch {
    q: Vec<i64>,
    codes: Vec<u8>,
    val: Vec<f32>,
    e2: E2Scratch,
}

impl AttnE2AvOp {
    /// Sequence length `l`, head dimension `d`, at the same default
    /// E2Softmax datapath configuration the registered `e2softmax`
    /// family serves.
    pub fn try_new(l: usize, d: usize) -> Result<AttnE2AvOp> {
        ensure_shape("attn-e2av", l, d)?;
        Ok(AttnE2AvOp { l, d, sm: E2Softmax::new(E2SoftmaxConfig::default()) })
    }
}

impl Op for AttnE2AvOp {
    fn name(&self) -> &str {
        "attn-e2av"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l * self.l + self.l * self.d
    }

    fn out_len(&self) -> usize {
        self.l * self.d
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(E2AvScratch {
            q: Vec::new(),
            codes: Vec::new(),
            val: Vec::new(),
            e2: E2Scratch::default(),
        })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<E2AvScratch>()
            .context("attn-e2av handed a foreign scratch arena")?;
        for (item, out_item) in
            input.chunks_exact(self.item_len()).zip(out.chunks_exact_mut(self.out_len()))
        {
            let (s_in, v) = item.split_at(self.l * self.l);
            quantize_logits_batch_into(s_in, self.l, self.sm.cfg().e, &mut s.q);
            self.sm.forward_batch_codes(&s.q, self.l, &mut s.codes, &mut s.val, &mut s.e2);
            for ((code_row, val_row), o_row) in s
                .codes
                .chunks_exact(self.l)
                .zip(s.val.chunks_exact(VAL_TABLE_LEN))
                .zip(out_item.chunks_exact_mut(self.d))
            {
                o_row.fill(0.0);
                for (&code, v_row) in code_row.iter().zip(v.chunks_exact(self.d)) {
                    let pij = val_row[code as usize];
                    for (o, &vv) in o_row.iter_mut().zip(v_row) {
                        *o += pij * vv;
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn attention_items(rng: &mut Rng, l: usize, d: usize, rows: usize) -> Vec<f32> {
        let mut v = vec![0f32; rows * 3 * l * d];
        rng.fill_normal(&mut v, 0.0, 1.0);
        v
    }

    fn run(op: &dyn Op, rows: usize, input: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; rows * op.out_len()];
        let mut scratch = op.make_scratch();
        op.run_batch(rows, input, &mut out, &mut scratch).unwrap();
        out
    }

    #[test]
    fn fused_is_bit_exact_to_unfused() {
        let mut rng = Rng::new(0xA77);
        for &(l, d) in &[(1usize, 4usize), (7, 3), (32, 16)] {
            let fused = fused_pipeline(l, d).unwrap();
            let unfused = unfused_pipeline(l, d).unwrap();
            let input = attention_items(&mut rng, l, d, 3);
            assert_eq!(run(&fused, 3, &input), run(&unfused, 3, &input), "L{l}xD{d}");
        }
    }

    #[test]
    fn fused_tracks_exact_softmax_attention() {
        // the e2 pipeline approximates the exact one: context vectors stay
        // close because softmax rows are near each other elementwise
        let (l, d) = (24, 8);
        let mut rng = Rng::new(0xA78);
        let input = attention_items(&mut rng, l, d, 4);
        let fused = run(&fused_pipeline(l, d).unwrap(), 4, &input);
        let exact = run(&exact_pipeline(l, d).unwrap(), 4, &input);
        let mut worst = 0f32;
        for (a, b) in fused.iter().zip(&exact) {
            worst = worst.max((a - b).abs());
        }
        // per-weight softmax error is < 0.16 (see e2 tests); the L-term
        // context sum over unit-normal V keeps the same order of
        // magnitude, far below the O(L) blowup a broken A·V would show
        assert!(worst < 1.0, "worst {worst}");
        assert!(worst > 0.0, "degenerate comparison");
    }

    #[test]
    fn pipeline_spec_and_shapes_advertise_the_contract() {
        let p = fused_pipeline(49, 64).unwrap();
        assert_eq!(p.spec().to_string(), "attention/L49xD64");
        assert_eq!(p.item_len(), 3 * 49 * 64);
        assert_eq!(p.out_len(), 49 * 64);
        assert_eq!(p.stages().len(), 2);
        let u = unfused_pipeline(49, 64).unwrap();
        assert_eq!(u.stages().len(), 3);
        assert_eq!(u.item_len(), p.item_len());
        assert_eq!(u.out_len(), p.out_len());
    }

    #[test]
    fn mismatched_stage_chain_is_rejected_at_construction() {
        let bad = PipelineOp::try_new(
            attention_spec("attention", 8, 4),
            vec![
                Arc::new(AttnLogitsOp::try_new(8, 4).unwrap()),
                Arc::new(AttnAvOp::try_new(16, 4).unwrap()), // wrong L
            ],
        );
        let err = format!("{:#}", bad.unwrap_err());
        assert!(err.contains("attn-logits"), "{err}");
        assert!(err.contains("attn-av"), "{err}");
        // degenerate shapes die in the stage constructors
        assert!(AttnLogitsOp::try_new(0, 4).is_err());
        assert!(AttnE2AvOp::try_new(4, 0).is_err());
    }
}
