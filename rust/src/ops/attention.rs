//! Attention pipelines: S = QKᵀ-scaled logits → softmax → A·V, as
//! [`PipelineOp`]s (DESIGN.md §3.2–3.3).
//!
//! This is the workload E2Softmax was co-designed for: the paper stores
//! attention probabilities as log2-quantized codes precisely so the
//! downstream A·V product degenerates into shift-and-accumulate instead
//! of full-width multiplies.  Three variants of the same datapath:
//!
//! * **`attention/L<len>xD<dim>`** (registered, fused) — [`AttnLogitsOp`]
//!   → [`AttnSoftmaxOp`] over a `Log2Code5`-ported [`E2SoftmaxOp`] →
//!   [`AttnAvOp`] with a `Log2Code5` in-port: the softmax→A·V boundary is
//!   staged as packed 5-bit shift codes plus each row's compact divider
//!   header, and the A·V stage dequantizes each weight through the
//!   expanded ≤ 32-entry shift table inside the accumulation loop — the
//!   probability matrix is never materialized at f32 width.  The fusion
//!   falls out of the typed port system (`ops/port.rs`) rather than a
//!   bespoke fused op.
//! * **`attention-unfused`** (unregistered comparator, built by
//!   [`unfused_pipeline`]) — the same chain with an f32-ported
//!   [`E2SoftmaxOp`] and the f32 [`AttnAvOp`]: identical arithmetic
//!   staged through a full f32 probability buffer.  Bit-identical to the
//!   fused pipeline (pinned by `tests/op_conformance.rs`): both
//!   dequantize through the same table and accumulate in the same order,
//!   the fused path just never stores the f32s.
//! * **`attention-exact/L<len>xD<dim>`** (registered) — the same chain
//!   over [`ExactSoftmaxOp`], the error/latency reference.
//!
//! One item is one attention head instance, packed `[Q | K | V]` with
//! each of Q, K, V a row-major `L x D` block (item length `3·L·D`); the
//! output item is the `L x D` context block `O = softmax(QKᵀ/√D)·V`.  On
//! the code port, V rides the boundary as the sidecar's f32 passthrough
//! tail — identical bytes either way; only the probability payload
//! changes width.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::port::{check_batch_ports, PortMut, PortRef, PortType};
use super::{check_batch, E2SoftmaxOp, ExactSoftmaxOp, Op, OpScratch, OpSpec, PipelineOp};
use crate::simd::Dispatch;
use crate::softmax::e2::{expand_row_side, CODE_SIDE_LEN};

/// The canonical spec of an attention-family pipeline:
/// `<op>/L<len>xD<dim>`.
pub fn attention_spec(op: &str, l: usize, d: usize) -> OpSpec {
    OpSpec { op: op.to_string(), dim: 'L', len: l, extra: vec![('D', d)] }
}

/// The canonical spec of a multi-head attention-family pipeline:
/// `<op>/H<heads>xL<len>xD<dim>`.
pub fn attention_heads_spec(op: &str, h: usize, l: usize, d: usize) -> OpSpec {
    OpSpec { op: op.to_string(), dim: 'H', len: h, extra: vec![('L', l), ('D', d)] }
}

/// The three fused stages (logits → code-port softmax → shift-accumulate
/// A·V) shared by the single-head and multi-head fused pipelines.
fn fused_stages(l: usize, d: usize) -> Result<Vec<Arc<dyn Op>>> {
    Ok(vec![
        Arc::new(AttnLogitsOp::try_new(l, d)?),
        Arc::new(AttnSoftmaxOp::try_new(
            l,
            d,
            Arc::new(E2SoftmaxOp::with_out_port(l, PortType::Log2Code5)?),
        )?),
        Arc::new(AttnAvOp::with_in_port(l, d, PortType::Log2Code5)?),
    ])
}

/// The fused pipeline behind the registered `attention/L<len>xD<dim>`
/// spec: logits, softmax emitting the `Log2Code5` port, then
/// shift-accumulate A·V consuming it — the probability matrix crosses
/// the stage boundary at 1 byte per weight.
pub fn fused_pipeline(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(attention_spec("attention", l, d), fused_stages(l, d)?)
}

/// The multi-head fused pipeline behind `attention/H<h>xL<len>xD<dim>`:
/// one item packs `h` heads, each staged through the same single-head
/// stages (`PipelineOp::with_heads` — pure batch geometry, SIMD arms and
/// dispatch untouched).
pub fn fused_pipeline_heads(h: usize, l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::with_heads(attention_heads_spec("attention", h, l, d), h, fused_stages(l, d)?)
}

/// The staged comparator (`attention-unfused`, not registered): the same
/// E2Softmax arithmetic through a materialized f32 probability buffer.
/// Bit-identical to [`fused_pipeline`]; exists so benches and tests can
/// measure exactly what the code port buys.
pub fn unfused_pipeline(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(
        attention_spec("attention-unfused", l, d),
        vec![
            Arc::new(AttnLogitsOp::try_new(l, d)?),
            Arc::new(AttnSoftmaxOp::try_new(l, d, Arc::new(E2SoftmaxOp::try_new(l)?))?),
            Arc::new(AttnAvOp::try_new(l, d)?),
        ],
    )
}

/// The exact-softmax stages shared by the single-head and multi-head
/// exact pipelines.
fn exact_stages(l: usize, d: usize) -> Result<Vec<Arc<dyn Op>>> {
    Ok(vec![
        Arc::new(AttnLogitsOp::try_new(l, d)?),
        Arc::new(AttnSoftmaxOp::try_new(l, d, Arc::new(ExactSoftmaxOp::try_new(l)?))?),
        Arc::new(AttnAvOp::try_new(l, d)?),
    ])
}

/// The exact-softmax pipeline behind the registered
/// `attention-exact/L<len>xD<dim>` spec: the error/latency reference the
/// fused pipeline is compared against.
pub fn exact_pipeline(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(attention_spec("attention-exact", l, d), exact_stages(l, d)?)
}

/// The multi-head exact pipeline behind
/// `attention-exact/H<h>xL<len>xD<dim>`.
pub fn exact_pipeline_heads(h: usize, l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::with_heads(attention_heads_spec("attention-exact", h, l, d), h, exact_stages(l, d)?)
}

fn ensure_shape(name: &str, l: usize, d: usize) -> Result<()> {
    anyhow::ensure!(l > 0, "{name}: sequence length must be positive");
    anyhow::ensure!(d > 0, "{name}: head dimension must be positive");
    Ok(())
}

/// Stage 1 of every attention pipeline: `[Q | K | V]` (each `L x D`) →
/// `[S | V]` where `S = (QKᵀ)/√D` is the `L x L` logit block and V
/// passes through untouched for the downstream A·V stage.
pub struct AttnLogitsOp {
    l: usize,
    d: usize,
    scale: f32,
}

impl AttnLogitsOp {
    /// Sequence length `l`, head dimension `d`; the logit scale is the
    /// standard `1/√d`.
    pub fn try_new(l: usize, d: usize) -> Result<AttnLogitsOp> {
        ensure_shape("attn-logits", l, d)?;
        Ok(AttnLogitsOp { l, d, scale: 1.0 / (d as f32).sqrt() })
    }
}

impl Op for AttnLogitsOp {
    fn name(&self) -> &str {
        "attn-logits"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        3 * self.l * self.d
    }

    fn out_len(&self) -> usize {
        self.l * self.l + self.l * self.d
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let ld = self.l * self.d;
        for (item, out_item) in
            input.chunks_exact(self.item_len()).zip(out.chunks_exact_mut(self.out_len()))
        {
            let (q, rest) = item.split_at(ld);
            let (k, v) = rest.split_at(ld);
            let (s_out, v_out) = out_item.split_at_mut(self.l * self.l);
            for (qi, s_row) in q.chunks_exact(self.d).zip(s_out.chunks_exact_mut(self.l)) {
                for (kj, s_elem) in k.chunks_exact(self.d).zip(s_row.iter_mut()) {
                    let mut acc = 0f32;
                    for (&x, &y) in qi.iter().zip(kj) {
                        acc += x * y;
                    }
                    *s_elem = acc * self.scale;
                }
            }
            v_out.copy_from_slice(v);
        }
        Ok(())
    }
}

/// The softmax stage: applies any row softmax [`Op`] (item length `l`)
/// to the `L x L` logit block of `[S | V]`, passing V through.  The
/// stage's out-port mirrors the inner op's: an f32 inner keeps the
/// shape-preserving `[S | V]` → `[P | V]` contract; a `Log2Code5` inner
/// emits the `L x L` probabilities as packed shift codes, with the `L`
/// per-row divider headers and the untouched V block in the f32 sidecar.
pub struct AttnSoftmaxOp {
    l: usize,
    d: usize,
    inner: Arc<dyn Op>,
    /// Sidecar f32 the inner op emits per logit row (its per-item
    /// `out_side_len`; 0 for an f32 inner).
    side_per_row: usize,
}

/// Per-worker arena: the wrapped softmax op's own scratch.
struct SoftmaxScratch {
    inner: OpScratch,
}

impl AttnSoftmaxOp {
    /// Wrap `inner` (a row softmax of item length `l`, f32 or
    /// `Log2Code5` out-port) as the softmax stage of an `L x D`
    /// attention pipeline.
    pub fn try_new(l: usize, d: usize, inner: Arc<dyn Op>) -> Result<AttnSoftmaxOp> {
        ensure_shape("attn-softmax", l, d)?;
        anyhow::ensure!(
            inner.item_len() == l && inner.out_len() == l,
            "attn-softmax: inner op '{}' is {}->{} f32/item, need {l}->{l}",
            inner.name(),
            inner.item_len(),
            inner.out_len()
        );
        anyhow::ensure!(
            inner.in_port() == PortType::F32,
            "attn-softmax: inner op '{}' wants a {} in-port, logits arrive as f32",
            inner.name(),
            inner.in_port()
        );
        anyhow::ensure!(
            inner.out_port() != PortType::PtfU8,
            "attn-softmax: inner op '{}' emits ptf-u8; attention consumes f32 or log2c5 \
             probabilities",
            inner.name()
        );
        if inner.out_port() == PortType::Log2Code5 {
            anyhow::ensure!(
                inner.out_code_rows() == 1,
                "attn-softmax: inner op '{}' splits one row into {} code rows, need 1",
                inner.name(),
                inner.out_code_rows()
            );
        }
        let side_per_row = inner.out_side_len();
        Ok(AttnSoftmaxOp { l, d, inner, side_per_row })
    }
}

impl Op for AttnSoftmaxOp {
    fn name(&self) -> &str {
        "attn-softmax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l * self.l + self.l * self.d
    }

    fn out_len(&self) -> usize {
        match self.inner.out_port() {
            // shape-preserving [P | V]
            PortType::F32 => self.item_len(),
            // L x L probability codes; V moves to the sidecar tail
            _ => self.l * self.l,
        }
    }

    fn out_port(&self) -> PortType {
        self.inner.out_port()
    }

    fn out_side_len(&self) -> usize {
        match self.inner.out_port() {
            PortType::F32 => 0,
            _ => self.l * self.side_per_row + self.l * self.d,
        }
    }

    fn out_code_rows(&self) -> usize {
        match self.inner.out_port() {
            PortType::F32 => 1,
            _ => self.l,
        }
    }

    fn dispatch(&self) -> Option<Dispatch> {
        self.inner.dispatch()
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(SoftmaxScratch { inner: self.inner.make_scratch() })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::ensure!(
            self.inner.out_port() == PortType::F32,
            "attn-softmax over a {} inner must be driven through run_batch_ports",
            self.inner.out_port()
        );
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<SoftmaxScratch>()
            .context("attn-softmax handed a foreign scratch arena")?;
        let area = self.item_len();
        for (item, out_item) in input.chunks_exact(area).zip(out.chunks_exact_mut(area)) {
            let (s_in, v_in) = item.split_at(self.l * self.l);
            let (s_out, v_out) = out_item.split_at_mut(self.l * self.l);
            self.inner.run_batch(self.l, s_in, s_out, &mut s.inner)?;
            v_out.copy_from_slice(v_in);
        }
        Ok(())
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        match (input, out) {
            (PortRef::F32(input), PortMut::F32(out)) => self.run_batch(rows, input, out, scratch),
            (PortRef::F32(input), PortMut::Log2Code5 { codes, side }) => {
                let s = scratch
                    .downcast_mut::<SoftmaxScratch>()
                    .context("attn-softmax handed a foreign scratch arena")?;
                let area = self.item_len();
                let ll = self.l * self.l;
                let hdr = self.l * self.side_per_row;
                for ((item, c_item), s_item) in input
                    .chunks_exact(area)
                    .zip(codes.chunks_exact_mut(ll))
                    .zip(side.chunks_exact_mut(hdr + self.l * self.d))
                {
                    let (s_in, v_in) = item.split_at(ll);
                    let (headers, v_out) = s_item.split_at_mut(hdr);
                    self.inner.run_batch_ports(
                        self.l,
                        PortRef::F32(s_in),
                        PortMut::Log2Code5 { codes: c_item, side: headers },
                        &mut s.inner,
                    )?;
                    v_out.copy_from_slice(v_in);
                }
                Ok(())
            }
            (input, out) => anyhow::bail!(
                "attn-softmax: no {} -> {} path",
                input.port(),
                out.port()
            ),
        }
    }
}

/// The A·V stage: probabilities × V → `O[i] = Σ_j P[i,j]·V[j]`, with the
/// probabilities arriving on either port.  On f32 (`try_new`) the item
/// is the staged `[P | V]` block and the stage is a plain matmul.  On
/// `Log2Code5` ([`AttnAvOp::with_in_port`]) the item is the `L x L`
/// packed shift codes, with divider headers and the V block in the
/// sidecar: each weight dequantizes through the row's expanded shift
/// table *inside* the accumulation loop — 1 byte read per weight — and
/// the j-then-d accumulation order matches the f32 path exactly, so both
/// ports produce bit-identical output.
pub struct AttnAvOp {
    l: usize,
    d: usize,
    in_port: PortType,
    /// Kernel arm of the accumulation loop, chosen once at construction
    /// (DESIGN.md §3.4); the AVX2 arm vectorizes across the output lanes
    /// so the per-lane `j` accumulation order stays scalar-identical.
    dispatch: Dispatch,
}

impl AttnAvOp {
    /// Sequence length `l`, head dimension `d`, staged f32 `[P | V]`
    /// in-port.
    pub fn try_new(l: usize, d: usize) -> Result<AttnAvOp> {
        AttnAvOp::with_in_port(l, d, PortType::F32)
    }

    /// Construction with an explicit in-port (`F32` or `Log2Code5`).
    pub fn with_in_port(l: usize, d: usize, port: PortType) -> Result<AttnAvOp> {
        AttnAvOp::with_dispatch(l, d, port, Dispatch::detect())
    }

    /// Construction with an explicit kernel arm (tests and benches pin
    /// arms to compare them); the request is clamped to what this host
    /// can run.
    pub fn with_dispatch(l: usize, d: usize, port: PortType, dispatch: Dispatch) -> Result<AttnAvOp> {
        ensure_shape("attn-av", l, d)?;
        anyhow::ensure!(
            port != PortType::PtfU8,
            "attn-av has no ptf-u8 in-port (attention probabilities are f32 or log2 codes)"
        );
        Ok(AttnAvOp { l, d, in_port: port, dispatch: dispatch.sanitize() })
    }
}

impl Op for AttnAvOp {
    fn name(&self) -> &str {
        "attn-av"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        match self.in_port {
            PortType::F32 => self.l * self.l + self.l * self.d,
            // codes carry only the probability payload; V is sidecar
            _ => self.l * self.l,
        }
    }

    fn out_len(&self) -> usize {
        self.l * self.d
    }

    fn in_port(&self) -> PortType {
        self.in_port
    }

    fn in_side_len(&self) -> usize {
        match self.in_port {
            PortType::F32 => 0,
            _ => CODE_SIDE_LEN * self.l + self.l * self.d,
        }
    }

    fn dispatch(&self) -> Option<Dispatch> {
        Some(self.dispatch)
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::ensure!(
            self.in_port == PortType::F32,
            "attn-av with a {} in-port must be driven through run_batch_ports",
            self.in_port
        );
        check_batch(self, rows, input, out)?;
        for (item, out_item) in
            input.chunks_exact(self.item_len()).zip(out.chunks_exact_mut(self.out_len()))
        {
            let (p, v) = item.split_at(self.l * self.l);
            for (p_row, o_row) in p.chunks_exact(self.l).zip(out_item.chunks_exact_mut(self.d)) {
                if self.dispatch == Dispatch::Avx2 {
                    // SAFETY: the Avx2 arm only exists after runtime
                    // detection (Dispatch::sanitize); shapes checked above.
                    unsafe { crate::simd::av::av_row_f32_avx2(p_row, v, self.d, o_row) };
                    continue;
                }
                o_row.fill(0.0);
                for (&pij, v_row) in p_row.iter().zip(v.chunks_exact(self.d)) {
                    for (o, &vv) in o_row.iter_mut().zip(v_row) {
                        *o += pij * vv;
                    }
                }
            }
        }
        Ok(())
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        match (input, out) {
            (PortRef::F32(input), PortMut::F32(out)) => self.run_batch(rows, input, out, scratch),
            (PortRef::Log2Code5 { codes, side }, PortMut::F32(out)) => {
                let ll = self.l * self.l;
                let hdr = CODE_SIDE_LEN * self.l;
                for ((c_item, s_item), out_item) in codes
                    .chunks_exact(ll)
                    .zip(side.chunks_exact(hdr + self.l * self.d))
                    .zip(out.chunks_exact_mut(self.l * self.d))
                {
                    let (headers, v) = s_item.split_at(hdr);
                    for ((code_row, h), o_row) in c_item
                        .chunks_exact(self.l)
                        .zip(headers.chunks_exact(CODE_SIDE_LEN))
                        .zip(out_item.chunks_exact_mut(self.d))
                    {
                        // the software model of the hardware shift
                        // network: one table expansion per row, then a
                        // 1-byte indexed load per weight
                        let val = expand_row_side(h);
                        if self.dispatch == Dispatch::Avx2 {
                            // SAFETY: detected arm; shapes checked above.
                            unsafe {
                                crate::simd::av::av_row_codes_avx2(code_row, &val, v, self.d, o_row)
                            };
                            continue;
                        }
                        o_row.fill(0.0);
                        for (&code, v_row) in code_row.iter().zip(v.chunks_exact(self.d)) {
                            let pij = val[code as usize];
                            for (o, &vv) in o_row.iter_mut().zip(v_row) {
                                *o += pij * vv;
                            }
                        }
                    }
                }
                Ok(())
            }
            (input, out) => anyhow::bail!(
                "attn-av: no {} -> {} path",
                input.port(),
                out.port()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn attention_items(rng: &mut Rng, l: usize, d: usize, rows: usize) -> Vec<f32> {
        let mut v = vec![0f32; rows * 3 * l * d];
        rng.fill_normal(&mut v, 0.0, 1.0);
        v
    }

    fn run(op: &dyn Op, rows: usize, input: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; rows * op.out_len()];
        let mut scratch = op.make_scratch();
        op.run_batch(rows, input, &mut out, &mut scratch).unwrap();
        out
    }

    #[test]
    fn fused_is_bit_exact_to_unfused() {
        let mut rng = Rng::new(0xA77);
        for &(l, d) in &[(1usize, 4usize), (7, 3), (32, 16)] {
            let fused = fused_pipeline(l, d).unwrap();
            let unfused = unfused_pipeline(l, d).unwrap();
            let input = attention_items(&mut rng, l, d, 3);
            assert_eq!(run(&fused, 3, &input), run(&unfused, 3, &input), "L{l}xD{d}");
        }
    }

    #[test]
    fn fused_tracks_exact_softmax_attention() {
        // the e2 pipeline approximates the exact one: context vectors stay
        // close because softmax rows are near each other elementwise
        let (l, d) = (24, 8);
        let mut rng = Rng::new(0xA78);
        let input = attention_items(&mut rng, l, d, 4);
        let fused = run(&fused_pipeline(l, d).unwrap(), 4, &input);
        let exact = run(&exact_pipeline(l, d).unwrap(), 4, &input);
        let mut worst = 0f32;
        for (a, b) in fused.iter().zip(&exact) {
            worst = worst.max((a - b).abs());
        }
        // per-weight softmax error is < 0.16 (see e2 tests); the L-term
        // context sum over unit-normal V keeps the same order of
        // magnitude, far below the O(L) blowup a broken A·V would show
        assert!(worst < 1.0, "worst {worst}");
        assert!(worst > 0.0, "degenerate comparison");
    }

    #[test]
    fn pipeline_spec_and_shapes_advertise_the_contract() {
        let p = fused_pipeline(49, 64).unwrap();
        assert_eq!(p.spec().to_string(), "attention/L49xD64");
        assert_eq!(p.item_len(), 3 * 49 * 64);
        assert_eq!(p.out_len(), 49 * 64);
        // logits -> softmax -> A·V, no adapter: the code boundary is
        // consumed natively, so nothing dequantizes in between
        assert_eq!(p.stages().len(), 3);
        assert_eq!(p.boundary_ports(), vec![PortType::F32, PortType::Log2Code5]);
        assert_eq!((p.in_port(), p.out_port()), (PortType::F32, PortType::F32));
        let u = unfused_pipeline(49, 64).unwrap();
        assert_eq!(u.stages().len(), 3);
        assert_eq!(u.boundary_ports(), vec![PortType::F32, PortType::F32]);
        assert_eq!(u.item_len(), p.item_len());
        assert_eq!(u.out_len(), p.out_len());
    }

    #[test]
    fn code_port_stages_advertise_the_quantized_shapes() {
        let (l, d) = (8, 4);
        let sm = AttnSoftmaxOp::try_new(
            l,
            d,
            Arc::new(E2SoftmaxOp::with_out_port(l, PortType::Log2Code5).unwrap()),
        )
        .unwrap();
        assert_eq!(sm.out_port(), PortType::Log2Code5);
        assert_eq!(sm.out_len(), l * l);
        assert_eq!(sm.out_side_len(), l * CODE_SIDE_LEN + l * d);
        assert_eq!(sm.out_code_rows(), l);
        let av = AttnAvOp::with_in_port(l, d, PortType::Log2Code5).unwrap();
        assert_eq!(av.in_port(), PortType::Log2Code5);
        assert_eq!(av.item_len(), l * l);
        assert_eq!(av.in_side_len(), l * CODE_SIDE_LEN + l * d);
        assert_eq!(av.out_len(), l * d);
        // both refuse the untyped f32 entry point
        let mut s = sm.make_scratch();
        let area = l * l + l * d;
        let err = sm.run_batch(1, &vec![0.0; area], &mut vec![0.0; area], &mut s).unwrap_err();
        assert!(format!("{err:#}").contains("run_batch_ports"), "{err:#}");
        let mut s = av.make_scratch();
        let err = av.run_batch(1, &vec![0.0; l * l], &mut vec![0.0; l * d], &mut s).unwrap_err();
        assert!(format!("{err:#}").contains("run_batch_ports"), "{err:#}");
    }

    #[test]
    fn mismatched_stage_chain_is_rejected_at_construction() {
        let bad = PipelineOp::try_new(
            attention_spec("attention", 8, 4),
            vec![
                Arc::new(AttnLogitsOp::try_new(8, 4).unwrap()),
                Arc::new(AttnAvOp::try_new(16, 4).unwrap()), // wrong L
            ],
        );
        let err = format!("{:#}", bad.unwrap_err());
        assert!(err.contains("attn-logits"), "{err}");
        assert!(err.contains("attn-av"), "{err}");
        // degenerate shapes die in the stage constructors
        assert!(AttnLogitsOp::try_new(0, 4).is_err());
        assert!(AttnAvOp::with_in_port(4, 0, PortType::Log2Code5).is_err());
        // port constraints too: no ptf-u8 anywhere in attention
        assert!(AttnAvOp::with_in_port(4, 4, PortType::PtfU8).is_err());
        let ptf_inner =
            Arc::new(crate::ops::AiLayerNormOp::with_out_port(8, PortType::PtfU8).unwrap());
        let err = format!("{:#}", AttnSoftmaxOp::try_new(8, 4, ptf_inner).unwrap_err());
        assert!(err.contains("ptf-u8"), "{err}");
    }
}
