//! The paper's AILayerNorm as an [`Op`]: PTF batch quantization + the
//! fused integer-statistics batch kernel behind the one operator API.

use anyhow::{Context, Result};

use super::{check_batch, Op, OpScratch};
use crate::layernorm::{config::DEFAULT_ZP, AiLayerNorm};
use crate::quant::{ptf_quantize_batch_into, PtfCalib};

/// Bit-exact AILayerNorm over f32 rows of `c` channels (spec
/// `ailayernorm/C<c>`), PTF-quantized with the op's calibration and
/// normalized by the fused stage-2 kernel.
pub struct AiLayerNormOp {
    c: usize,
    ln: AiLayerNorm,
    cal: PtfCalib,
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

/// Per-worker arena: the packed PTF code buffer.
struct Scratch {
    codes: Vec<u8>,
}

/// The registry-default calibration: alpha = 0 everywhere with a layer
/// scale that maps roughly N(0, 4) inputs onto the u8 code grid.  Public
/// so the conformance suite and callers can reproduce `try_new` exactly.
pub fn identity_calibration(c: usize) -> PtfCalib {
    PtfCalib { alpha: vec![0u8; c], s: 1.0 / 32.0, zp: DEFAULT_ZP }
}

impl AiLayerNormOp {
    /// Identity-affine op (gamma = 1, beta = 0) over the
    /// [`identity_calibration`].
    pub fn try_new(c: usize) -> Result<AiLayerNormOp> {
        AiLayerNormOp::with_calibration(c, identity_calibration(c), vec![1f32; c], vec![0f32; c])
    }

    /// Fully-specified op: a PTF calibration plus affine parameters, all
    /// validated here on the caller's thread.
    pub fn with_calibration(
        c: usize,
        cal: PtfCalib,
        gamma: Vec<f32>,
        beta: Vec<f32>,
    ) -> Result<AiLayerNormOp> {
        anyhow::ensure!(c > 0, "ailayernorm rows must be non-empty");
        anyhow::ensure!(
            cal.alpha.len() == c && gamma.len() == c && beta.len() == c,
            "calibration lengths must match {c} channels"
        );
        let ln = AiLayerNorm { zp: cal.zp };
        Ok(AiLayerNormOp { c, ln, cal, gamma, beta })
    }
}

impl Op for AiLayerNormOp {
    fn name(&self) -> &str {
        "ailayernorm"
    }

    fn dim(&self) -> char {
        'C'
    }

    fn item_len(&self) -> usize {
        self.c
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(Scratch { codes: Vec::with_capacity(self.c) })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<Scratch>()
            .context("ailayernorm op handed a foreign scratch arena")?;
        ptf_quantize_batch_into(input, &self.cal, &mut s.codes);
        self.ln.forward_batch_f32(&s.codes, &self.cal.alpha, &self.gamma, &self.beta, out);
        Ok(())
    }
}
