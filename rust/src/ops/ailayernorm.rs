//! The paper's AILayerNorm as an [`Op`]: PTF batch quantization + the
//! fused integer-statistics batch kernel behind the one operator API.
//! With a `PtfU8` out-port the op stores its output as u8 codes plus one
//! per-row scale — the low bit-width inter-block storage the paper
//! claims — instead of widening back to f32 inside the kernel.

use anyhow::{Context, Result};

use super::port::{check_batch_ports, PortMut, PortRef, PortType};
use super::{check_batch, Op, OpScratch};
use crate::layernorm::{config::DEFAULT_ZP, AiLayerNorm};
use crate::quant::{ptf_quantize_batch_into, PtfCalib};

/// Bit-exact AILayerNorm over f32 rows of `c` channels (spec
/// `ailayernorm/C<c>`), PTF-quantized with the op's calibration and
/// normalized by the fused stage-2 kernel.
pub struct AiLayerNormOp {
    c: usize,
    ln: AiLayerNorm,
    cal: PtfCalib,
    gamma: Vec<f32>,
    beta: Vec<f32>,
    out_port: PortType,
}

/// Per-worker arena: the packed PTF code buffer plus the f32 row scratch
/// the q8 out-port quantizes from.
struct Scratch {
    codes: Vec<u8>,
    row: Vec<f32>,
}

/// The registry-default calibration: alpha = 0 everywhere with a layer
/// scale that maps roughly N(0, 4) inputs onto the u8 code grid.  Public
/// so the conformance suite and callers can reproduce `try_new` exactly.
pub fn identity_calibration(c: usize) -> PtfCalib {
    PtfCalib { alpha: vec![0u8; c], s: 1.0 / 32.0, zp: DEFAULT_ZP }
}

impl AiLayerNormOp {
    /// Identity-affine op (gamma = 1, beta = 0) over the
    /// [`identity_calibration`], plain f32 out-port.
    pub fn try_new(c: usize) -> Result<AiLayerNormOp> {
        AiLayerNormOp::with_calibration(c, identity_calibration(c), vec![1f32; c], vec![0f32; c])
    }

    /// Fully-specified op: a PTF calibration plus affine parameters, all
    /// validated here on the caller's thread.
    pub fn with_calibration(
        c: usize,
        cal: PtfCalib,
        gamma: Vec<f32>,
        beta: Vec<f32>,
    ) -> Result<AiLayerNormOp> {
        anyhow::ensure!(c > 0, "ailayernorm rows must be non-empty");
        anyhow::ensure!(
            cal.alpha.len() == c && gamma.len() == c && beta.len() == c,
            "calibration lengths must match {c} channels"
        );
        let ln = AiLayerNorm::new(cal.zp);
        Ok(AiLayerNormOp { c, ln, cal, gamma, beta, out_port: PortType::F32 })
    }

    /// Construction with an explicit out-port over the default
    /// calibration: `PtfU8` makes the op emit one u8 code per channel
    /// plus a single f32 row scale (`quant::q8_quantize_row_into`), for
    /// a consumer — or the auto-inserted dequant adapter — to widen on
    /// its own side of the boundary.
    pub fn with_out_port(c: usize, port: PortType) -> Result<AiLayerNormOp> {
        anyhow::ensure!(
            port != PortType::Log2Code5,
            "ailayernorm has no log2c5 out-port (its codes are affine u8, not log2 shifts)"
        );
        let mut op = AiLayerNormOp::try_new(c)?;
        op.out_port = port;
        Ok(op)
    }
}

impl Op for AiLayerNormOp {
    fn name(&self) -> &str {
        "ailayernorm"
    }

    fn dim(&self) -> char {
        'C'
    }

    fn item_len(&self) -> usize {
        self.c
    }

    fn out_port(&self) -> PortType {
        self.out_port
    }

    fn out_side_len(&self) -> usize {
        match self.out_port {
            PortType::PtfU8 => 1,
            _ => 0,
        }
    }

    fn dispatch(&self) -> Option<crate::simd::Dispatch> {
        Some(self.ln.dispatch())
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(Scratch { codes: Vec::with_capacity(self.c), row: Vec::new() })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::ensure!(
            self.out_port == PortType::F32,
            "ailayernorm with a {} out-port must be driven through run_batch_ports",
            self.out_port
        );
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<Scratch>()
            .context("ailayernorm op handed a foreign scratch arena")?;
        ptf_quantize_batch_into(input, &self.cal, &mut s.codes);
        self.ln.forward_batch_f32(&s.codes, &self.cal.alpha, &self.gamma, &self.beta, out);
        Ok(())
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        match (input, out) {
            (PortRef::F32(input), PortMut::F32(out)) => self.run_batch(rows, input, out, scratch),
            (PortRef::F32(input), PortMut::PtfU8 { codes, side }) => {
                let s = scratch
                    .downcast_mut::<Scratch>()
                    .context("ailayernorm op handed a foreign scratch arena")?;
                ptf_quantize_batch_into(input, &self.cal, &mut s.codes);
                self.ln.forward_batch_q8(
                    &s.codes,
                    &self.cal.alpha,
                    &self.gamma,
                    &self.beta,
                    &mut s.row,
                    codes,
                    side,
                );
                Ok(())
            }
            (input, out) => anyhow::bail!(
                "ailayernorm: no {} -> {} path",
                input.port(),
                out.port()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::q8_dequantize;
    use crate::util::rng::Rng;

    #[test]
    fn q8_port_is_the_f32_op_through_the_row_codec() {
        let c = 64;
        let rows = 3;
        let f32_op = AiLayerNormOp::try_new(c).unwrap();
        let q8_op = AiLayerNormOp::with_out_port(c, PortType::PtfU8).unwrap();
        assert_eq!(q8_op.out_port(), PortType::PtfU8);
        assert_eq!((q8_op.out_side_len(), q8_op.out_code_rows()), (1, 1));
        let mut rng = Rng::new(17);
        let mut input = vec![0f32; rows * c];
        rng.fill_normal(&mut input, 0.3, 1.5);
        let mut want = vec![0f32; rows * c];
        let mut s = f32_op.make_scratch();
        f32_op.run_batch(rows, &input, &mut want, &mut s).unwrap();
        let mut codes = vec![0u8; rows * c];
        let mut side = vec![0f32; rows];
        let mut s = q8_op.make_scratch();
        q8_op
            .run_batch_ports(
                rows,
                PortRef::F32(&input),
                PortMut::PtfU8 { codes: &mut codes, side: &mut side },
                &mut s,
            )
            .unwrap();
        let mut want_codes = vec![0u8; c];
        for r in 0..rows {
            let want_scale =
                crate::quant::q8_quantize_row_into(&want[r * c..(r + 1) * c], &mut want_codes);
            assert_eq!(side[r].to_bits(), want_scale.to_bits(), "row {r} scale");
            assert_eq!(&codes[r * c..(r + 1) * c], &want_codes[..], "row {r} codes");
            // and the roundtrip error is within half a code step
            for i in 0..c {
                let back = q8_dequantize(codes[r * c + i], side[r]);
                assert!(
                    (back - want[r * c + i]).abs() <= side[r] * 0.5 + 1e-6,
                    "row {r} ch {i}"
                );
            }
        }
    }

    #[test]
    fn q8_port_refuses_the_f32_entry_point_and_log2_construction() {
        let q8_op = AiLayerNormOp::with_out_port(8, PortType::PtfU8).unwrap();
        let mut s = q8_op.make_scratch();
        let err = q8_op.run_batch(1, &[0.0; 8], &mut [0.0; 8], &mut s).unwrap_err();
        assert!(format!("{err:#}").contains("run_batch_ports"), "{err:#}");
        let err = AiLayerNormOp::with_out_port(8, PortType::Log2Code5).unwrap_err();
        assert!(format!("{err:#}").contains("no log2c5 out-port"), "{err:#}");
        let op = AiLayerNormOp::with_out_port(8, PortType::F32).unwrap();
        assert_eq!(op.out_port(), PortType::F32);
    }
}
