//! Exact f64 baselines as [`Op`]s — the reference points every SOLE
//! number is compared against, finally servable through the same router.
//!
//! Both ops call the reference kernels (`softmax::e2::softmax_exact`,
//! `layernorm::ai::layernorm_exact`) row by row and cast to f32 at the
//! output, so the served values can never drift from the functions the
//! accuracy experiments use.  Like the prior-work comparators they
//! allocate per row — baselines are measurement points, not hot paths.

use anyhow::Result;

use super::{check_batch, Op, OpScratch};
use crate::layernorm::ai::layernorm_exact;
use crate::softmax::e2::softmax_exact;

/// Epsilon of the exact-layernorm baseline (the value every accuracy
/// cross-check in the repo uses with `layernorm_exact`).
pub const EXACT_LN_EPS: f64 = 1e-9;

/// Exact f64 softmax over f32 logit rows of length `l` (spec
/// `softmax-exact/L<l>`) — the accuracy ceiling and the throughput floor
/// E2Softmax is measured against.
pub struct ExactSoftmaxOp {
    l: usize,
}

impl ExactSoftmaxOp {
    /// Row length `l`.
    pub fn try_new(l: usize) -> Result<ExactSoftmaxOp> {
        anyhow::ensure!(l > 0, "softmax-exact rows must be non-empty");
        Ok(ExactSoftmaxOp { l })
    }
}

impl Op for ExactSoftmaxOp {
    fn name(&self) -> &str {
        "softmax-exact"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        for (row, row_out) in input.chunks_exact(self.l).zip(out.chunks_exact_mut(self.l)) {
            for (o, v) in row_out.iter_mut().zip(softmax_exact(row)) {
                *o = v as f32;
            }
        }
        Ok(())
    }
}

/// Exact f64 layernorm over f32 rows of `c` channels (spec
/// `layernorm-exact/C<c>`), identity affine (gamma = 1, beta = 0) to
/// mirror the registry-default `ailayernorm` service.
pub struct ExactLayerNormOp {
    c: usize,
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

impl ExactLayerNormOp {
    /// Channel count `c`, identity affine (gamma = 1, beta = 0).
    pub fn try_new(c: usize) -> Result<ExactLayerNormOp> {
        anyhow::ensure!(c > 0, "layernorm-exact rows must be non-empty");
        Ok(ExactLayerNormOp { c, gamma: vec![1f32; c], beta: vec![0f32; c] })
    }
}

impl Op for ExactLayerNormOp {
    fn name(&self) -> &str {
        "layernorm-exact"
    }

    fn dim(&self) -> char {
        'C'
    }

    fn item_len(&self) -> usize {
        self.c
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        for (row, row_out) in input.chunks_exact(self.c).zip(out.chunks_exact_mut(self.c)) {
            let y = layernorm_exact(row, &self.gamma, &self.beta, EXACT_LN_EPS);
            for (o, v) in row_out.iter_mut().zip(y) {
                *o = v as f32;
            }
        }
        Ok(())
    }
}
