//! Transformer-block pipelines: pre-LN self-attention with a residual
//! add, `Y = X + Attn(LN(X))`, as a [`PipelineOp`] (DESIGN.md §3.5).
//!
//! This is the paper's end-to-end story: *both* SOLE units live in one
//! datapath and every inter-stage boundary that the hardware stores at
//! low width stays low-width in software too.  The registered fused
//! `block/L<len>xD<dim>` pipeline chains
//!
//! 1. [`BlockLnOp`] — AILayerNorm over each token row, emitting the
//!    normed rows as `ptf-u8` codes (one affine scale per token) with
//!    the raw input X riding the sidecar tail for the residual;
//! 2. [`BlockLogitsOp`] — consumes the `ptf-u8` port *directly*,
//!    dequantizing each normed row inside the logit loop (no adapter),
//!    and emits `[S | N' | X]` f32 where `S = (N'N'ᵀ)/√D`;
//! 3. [`AttnSoftmaxOp`] over a `Log2Code5`-ported [`E2SoftmaxOp`] — the
//!    probability matrix crosses as packed 5-bit shift codes, `[N' | X]`
//!    passes through as the sidecar tail;
//! 4. [`BlockAvOp`] — shift-accumulate `O = P·N'` straight from the
//!    codes, then re-quantizes each context row to `ptf-u8` (one scale
//!    per token) with X still in the sidecar;
//! 5. [`BlockResidualOp`] — the quantized consumer the port system was
//!    built for: `Y = X + dequant(O')`, reading the `ptf-u8` codes
//!    inside the add loop.  No f32 attention output is ever staged.
//!
//! The boundary ports are `[ptf-u8, f32, log2c5, ptf-u8]` with **zero**
//! auto-inserted [`DequantOp`](super::DequantOp) adapters.  The
//! unregistered comparator built by [`unfused_block`] keeps the same
//! quantized producers but f32 consumers, so `PipelineOp::try_new`
//! inserts the adapters and every value is dequantized through the same
//! arithmetic in the same order — bit-identical output, pinned by the
//! tests here and by `tests/op_conformance.rs`.
//!
//! One item is one token block: `L x D` f32 in, `L x D` f32 out.  The
//! multi-head `block/H<h>xL<len>xD<dim>` variant packs `h` such blocks
//! per item via `PipelineOp::with_heads` (pure batch geometry).

use std::sync::Arc;

use anyhow::{Context, Result};

use super::attention::AttnSoftmaxOp;
use super::port::{check_batch_ports, PortMut, PortRef, PortType};
use super::{check_batch, AiLayerNormOp, E2SoftmaxOp, Op, OpScratch, OpSpec, PipelineOp};
use crate::quant::{q8_dequantize, q8_quantize_row_into};
use crate::simd::Dispatch;
use crate::softmax::e2::{expand_row_side, CODE_SIDE_LEN, VAL_TABLE_LEN};

/// The canonical spec of a block-family pipeline: `<op>/L<len>xD<dim>`.
pub fn block_spec(op: &str, l: usize, d: usize) -> OpSpec {
    OpSpec { op: op.to_string(), dim: 'L', len: l, extra: vec![('D', d)] }
}

/// The canonical spec of a multi-head block-family pipeline:
/// `<op>/H<heads>xL<len>xD<dim>`.
pub fn block_heads_spec(op: &str, h: usize, l: usize, d: usize) -> OpSpec {
    OpSpec { op: op.to_string(), dim: 'H', len: h, extra: vec![('L', l), ('D', d)] }
}

/// The five stages of the fused block: every quantized boundary is
/// consumed natively (see module docs).
fn fused_block_stages(l: usize, d: usize) -> Result<Vec<Arc<dyn Op>>> {
    Ok(vec![
        Arc::new(BlockLnOp::try_new(l, d)?),
        Arc::new(BlockLogitsOp::with_in_port(l, d, PortType::PtfU8)?),
        Arc::new(AttnSoftmaxOp::try_new(
            l,
            2 * d,
            Arc::new(E2SoftmaxOp::with_out_port(l, PortType::Log2Code5)?),
        )?),
        Arc::new(BlockAvOp::with_in_port(l, d, PortType::Log2Code5)?),
        Arc::new(BlockResidualOp::with_in_port(l, d, PortType::PtfU8)?),
    ])
}

/// The fused pipeline behind the registered `block/L<len>xD<dim>` spec.
pub fn fused_block(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(block_spec("block", l, d), fused_block_stages(l, d)?)
}

/// The multi-head fused pipeline behind `block/H<h>xL<len>xD<dim>`: one
/// item packs `h` token blocks through the same single-head stages.
pub fn fused_block_heads(h: usize, l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::with_heads(block_heads_spec("block", h, l, d), h, fused_block_stages(l, d)?)
}

/// The staged comparator (`block-unfused`, not registered): the same
/// quantized producers but f32 consumers, so the pipeline auto-inserts
/// [`DequantOp`](super::DequantOp) adapters at the `ptf-u8` boundaries
/// and the softmax stays on the f32 port.  Bit-identical to
/// [`fused_block`]; exists so tests and benches can measure exactly what
/// consuming the quantized ports in place buys.
pub fn unfused_block(l: usize, d: usize) -> Result<PipelineOp> {
    PipelineOp::try_new(
        block_spec("block-unfused", l, d),
        vec![
            Arc::new(BlockLnOp::try_new(l, d)?),
            Arc::new(BlockLogitsOp::try_new(l, d)?),
            Arc::new(AttnSoftmaxOp::try_new(l, 2 * d, Arc::new(E2SoftmaxOp::try_new(l)?))?),
            Arc::new(BlockAvOp::try_new(l, d)?),
            Arc::new(BlockResidualOp::try_new(l, d)?),
        ],
    )
}

fn ensure_shape(name: &str, l: usize, d: usize) -> Result<()> {
    anyhow::ensure!(l > 0, "{name}: sequence length must be positive");
    anyhow::ensure!(d > 0, "{name}: channel dimension must be positive");
    Ok(())
}

/// Stage 1: AILayerNorm over each of the `L` token rows (`D` channels),
/// emitted on the `ptf-u8` port — `L x D` u8 codes with one affine scale
/// per token row — and the untouched input X appended to the sidecar
/// tail so the residual stage downstream can close the loop.
pub struct BlockLnOp {
    l: usize,
    d: usize,
    ln: AiLayerNormOp,
}

/// Per-worker arena: the wrapped layernorm op's own scratch.
struct LnScratch {
    inner: OpScratch,
}

impl BlockLnOp {
    /// Sequence length `l`, channel dimension `d`; the inner
    /// [`AiLayerNormOp`] runs at the identity calibration on a `ptf-u8`
    /// out-port.
    pub fn try_new(l: usize, d: usize) -> Result<BlockLnOp> {
        ensure_shape("block-ln", l, d)?;
        Ok(BlockLnOp { l, d, ln: AiLayerNormOp::with_out_port(d, PortType::PtfU8)? })
    }
}

impl Op for BlockLnOp {
    fn name(&self) -> &str {
        "block-ln"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l * self.d
    }

    fn out_port(&self) -> PortType {
        PortType::PtfU8
    }

    fn out_code_rows(&self) -> usize {
        self.l
    }

    fn out_side_len(&self) -> usize {
        // one scale per token row, then the X passthrough tail
        self.l + self.l * self.d
    }

    fn dispatch(&self) -> Option<Dispatch> {
        self.ln.dispatch()
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(LnScratch { inner: self.ln.make_scratch() })
    }

    fn run_batch(
        &self,
        _rows: usize,
        _input: &[f32],
        _out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::bail!("block-ln with a ptf-u8 out-port must be driven through run_batch_ports")
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        let (input, codes, side) = match (input, out) {
            (PortRef::F32(input), PortMut::PtfU8 { codes, side }) => (input, codes, side),
            (input, out) => {
                anyhow::bail!("block-ln: no {} -> {} path", input.port(), out.port())
            }
        };
        let s = scratch
            .downcast_mut::<LnScratch>()
            .context("block-ln handed a foreign scratch arena")?;
        let ld = self.l * self.d;
        for ((item, c_item), s_item) in input
            .chunks_exact(ld)
            .zip(codes.chunks_exact_mut(ld))
            .zip(side.chunks_exact_mut(self.l + ld))
        {
            let (scales, x_tail) = s_item.split_at_mut(self.l);
            self.ln.run_batch_ports(
                self.l,
                PortRef::F32(item),
                PortMut::PtfU8 { codes: c_item, side: scales },
                &mut s.inner,
            )?;
            x_tail.copy_from_slice(item);
        }
        Ok(())
    }
}

/// Stage 2: self-attention logits over the normed rows,
/// `S = (N'N'ᵀ)/√D`.  On the `ptf-u8` in-port (the fused path) each
/// normed row is dequantized through its token scale *inside* this
/// stage — no adapter, 1 byte read per element — and the dequantized
/// rows are materialized once into the output where the A·V stage needs
/// them anyway.  On f32 (`try_new`, the comparator) the item is the
/// adapter-widened `[N' | X]` block.  Either way the output is
/// `[S | N' | X]` f32.
pub struct BlockLogitsOp {
    l: usize,
    d: usize,
    scale: f32,
    in_port: PortType,
}

impl BlockLogitsOp {
    /// Sequence length `l`, channel dimension `d`, staged f32 `[N' | X]`
    /// in-port; the logit scale is the standard `1/√d`.
    pub fn try_new(l: usize, d: usize) -> Result<BlockLogitsOp> {
        BlockLogitsOp::with_in_port(l, d, PortType::F32)
    }

    /// Construction with an explicit in-port (`F32` or `PtfU8`).
    pub fn with_in_port(l: usize, d: usize, port: PortType) -> Result<BlockLogitsOp> {
        ensure_shape("block-logits", l, d)?;
        anyhow::ensure!(
            port != PortType::Log2Code5,
            "block-logits has no log2c5 in-port (normed rows are affine u8 or f32)"
        );
        Ok(BlockLogitsOp { l, d, scale: 1.0 / (d as f32).sqrt(), in_port: port })
    }

    /// `S = (N'N'ᵀ)·scale` into `s_out`, accumulation over `d` then one
    /// multiply by the scale — the same order as `AttnLogitsOp`.
    fn logits_into(&self, n: &[f32], s_out: &mut [f32]) {
        for (ni, s_row) in n.chunks_exact(self.d).zip(s_out.chunks_exact_mut(self.l)) {
            for (nj, s_elem) in n.chunks_exact(self.d).zip(s_row.iter_mut()) {
                let mut acc = 0f32;
                for (&x, &y) in ni.iter().zip(nj) {
                    acc += x * y;
                }
                *s_elem = acc * self.scale;
            }
        }
    }
}

impl Op for BlockLogitsOp {
    fn name(&self) -> &str {
        "block-logits"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        match self.in_port {
            // codes carry only the normed rows; scales and X are sidecar
            PortType::PtfU8 => self.l * self.d,
            _ => 2 * self.l * self.d,
        }
    }

    fn out_len(&self) -> usize {
        self.l * self.l + 2 * self.l * self.d
    }

    fn in_port(&self) -> PortType {
        self.in_port
    }

    fn in_side_len(&self) -> usize {
        match self.in_port {
            PortType::PtfU8 => self.l + self.l * self.d,
            _ => 0,
        }
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::ensure!(
            self.in_port == PortType::F32,
            "block-logits with a {} in-port must be driven through run_batch_ports",
            self.in_port
        );
        check_batch(self, rows, input, out)?;
        let ll = self.l * self.l;
        for (item, out_item) in
            input.chunks_exact(self.item_len()).zip(out.chunks_exact_mut(self.out_len()))
        {
            let (s_out, nx_out) = out_item.split_at_mut(ll);
            self.logits_into(&item[..self.l * self.d], s_out);
            nx_out.copy_from_slice(item);
        }
        Ok(())
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        match (input, out) {
            (PortRef::F32(input), PortMut::F32(out)) => self.run_batch(rows, input, out, scratch),
            (PortRef::PtfU8 { codes, side }, PortMut::F32(out)) => {
                let ll = self.l * self.l;
                let ld = self.l * self.d;
                for ((c_item, s_item), out_item) in codes
                    .chunks_exact(ld)
                    .zip(side.chunks_exact(self.l + ld))
                    .zip(out.chunks_exact_mut(self.out_len()))
                {
                    let (scales, x_tail) = s_item.split_at(self.l);
                    let (s_out, rest) = out_item.split_at_mut(ll);
                    let (n_out, x_out) = rest.split_at_mut(ld);
                    // widen each normed row through its token scale once,
                    // straight into the output block the A·V stage reads
                    for ((c_row, &sc), n_row) in
                        c_item.chunks_exact(self.d).zip(scales).zip(n_out.chunks_exact_mut(self.d))
                    {
                        for (o, &c) in n_row.iter_mut().zip(c_row) {
                            *o = q8_dequantize(c, sc);
                        }
                    }
                    self.logits_into(n_out, s_out);
                    x_out.copy_from_slice(x_tail);
                }
                Ok(())
            }
            (input, out) => {
                anyhow::bail!("block-logits: no {} -> {} path", input.port(), out.port())
            }
        }
    }
}

/// Stage 4: shift-accumulate `O = P·N'`, then re-quantize each context
/// row to `ptf-u8` for the residual boundary.  Probabilities arrive on
/// either port — `Log2Code5` (fused: dequantize through the row's
/// expanded shift table inside the loop, exactly like `AttnAvOp`) or
/// f32 (`try_new`, the comparator `[P | N' | X]` block).  The output is
/// always `ptf-u8`: `L x D` codes, one scale per token row, X passed
/// through on the sidecar tail.
pub struct BlockAvOp {
    l: usize,
    d: usize,
    in_port: PortType,
    /// Kernel arm of the accumulation loop, chosen once at construction
    /// (DESIGN.md §3.4); shared with `AttnAvOp` — the AVX2 arm
    /// vectorizes across output lanes, per-lane order stays scalar.
    dispatch: Dispatch,
}

/// Per-worker arena: one f32 context row, quantized per token before the
/// next row overwrites it.
struct AvScratch {
    row: Vec<f32>,
}

impl BlockAvOp {
    /// Sequence length `l`, channel dimension `d`, staged f32
    /// `[P | N' | X]` in-port.
    pub fn try_new(l: usize, d: usize) -> Result<BlockAvOp> {
        BlockAvOp::with_in_port(l, d, PortType::F32)
    }

    /// Construction with an explicit in-port (`F32` or `Log2Code5`).
    pub fn with_in_port(l: usize, d: usize, port: PortType) -> Result<BlockAvOp> {
        BlockAvOp::with_dispatch(l, d, port, Dispatch::detect())
    }

    /// Construction with an explicit kernel arm (tests pin arms to
    /// compare them); the request is clamped to what this host can run.
    pub fn with_dispatch(
        l: usize,
        d: usize,
        port: PortType,
        dispatch: Dispatch,
    ) -> Result<BlockAvOp> {
        ensure_shape("block-av", l, d)?;
        anyhow::ensure!(
            port != PortType::PtfU8,
            "block-av has no ptf-u8 in-port (attention probabilities are f32 or log2 codes)"
        );
        Ok(BlockAvOp { l, d, in_port: port, dispatch: dispatch.sanitize() })
    }

    /// One context row `o = Σ_j p_j·n'_j` from f32 probabilities.
    fn av_row_f32(&self, p_row: &[f32], n: &[f32], o_row: &mut [f32]) {
        if self.dispatch == Dispatch::Avx2 {
            // SAFETY: the Avx2 arm only exists after runtime detection
            // (Dispatch::sanitize); shapes checked by the caller.
            unsafe { crate::simd::av::av_row_f32_avx2(p_row, n, self.d, o_row) };
            return;
        }
        o_row.fill(0.0);
        for (&pij, n_row) in p_row.iter().zip(n.chunks_exact(self.d)) {
            for (o, &nv) in o_row.iter_mut().zip(n_row) {
                *o += pij * nv;
            }
        }
    }

    /// One context row from packed shift codes and the row's expanded
    /// dequantization table.
    fn av_row_codes(
        &self,
        code_row: &[u8],
        val: &[f32; VAL_TABLE_LEN],
        n: &[f32],
        o_row: &mut [f32],
    ) {
        if self.dispatch == Dispatch::Avx2 {
            // SAFETY: detected arm; shapes checked by the caller.
            unsafe { crate::simd::av::av_row_codes_avx2(code_row, val, n, self.d, o_row) };
            return;
        }
        o_row.fill(0.0);
        for (&code, n_row) in code_row.iter().zip(n.chunks_exact(self.d)) {
            let pij = val[code as usize];
            for (o, &nv) in o_row.iter_mut().zip(n_row) {
                *o += pij * nv;
            }
        }
    }
}

impl Op for BlockAvOp {
    fn name(&self) -> &str {
        "block-av"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        match self.in_port {
            PortType::F32 => self.l * self.l + 2 * self.l * self.d,
            // codes carry only the probability payload; [N' | X] is sidecar
            _ => self.l * self.l,
        }
    }

    fn out_len(&self) -> usize {
        self.l * self.d
    }

    fn in_port(&self) -> PortType {
        self.in_port
    }

    fn in_side_len(&self) -> usize {
        match self.in_port {
            PortType::F32 => 0,
            _ => CODE_SIDE_LEN * self.l + 2 * self.l * self.d,
        }
    }

    fn out_port(&self) -> PortType {
        PortType::PtfU8
    }

    fn out_code_rows(&self) -> usize {
        self.l
    }

    fn out_side_len(&self) -> usize {
        self.l + self.l * self.d
    }

    fn dispatch(&self) -> Option<Dispatch> {
        Some(self.dispatch)
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(AvScratch { row: vec![0f32; self.d] })
    }

    fn run_batch(
        &self,
        _rows: usize,
        _input: &[f32],
        _out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::bail!("block-av with a ptf-u8 out-port must be driven through run_batch_ports")
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        let s = scratch
            .downcast_mut::<AvScratch>()
            .context("block-av handed a foreign scratch arena")?;
        let ll = self.l * self.l;
        let ld = self.l * self.d;
        match (input, out) {
            (PortRef::F32(input), PortMut::PtfU8 { codes, side }) => {
                for ((item, c_item), s_item) in input
                    .chunks_exact(ll + 2 * ld)
                    .zip(codes.chunks_exact_mut(ld))
                    .zip(side.chunks_exact_mut(self.l + ld))
                {
                    let (p, rest) = item.split_at(ll);
                    let (n, x) = rest.split_at(ld);
                    let (scales, x_out) = s_item.split_at_mut(self.l);
                    for ((p_row, c_row), scale) in p
                        .chunks_exact(self.l)
                        .zip(c_item.chunks_exact_mut(self.d))
                        .zip(scales.iter_mut())
                    {
                        self.av_row_f32(p_row, n, &mut s.row);
                        *scale = q8_quantize_row_into(&s.row, c_row);
                    }
                    x_out.copy_from_slice(x);
                }
                Ok(())
            }
            (PortRef::Log2Code5 { codes, side }, PortMut::PtfU8 { codes: oc, side: os }) => {
                let hdr = CODE_SIDE_LEN * self.l;
                for ((c_in, s_in), (c_item, s_item)) in codes
                    .chunks_exact(ll)
                    .zip(side.chunks_exact(hdr + 2 * ld))
                    .zip(oc.chunks_exact_mut(ld).zip(os.chunks_exact_mut(self.l + ld)))
                {
                    let (headers, rest) = s_in.split_at(hdr);
                    let (n, x) = rest.split_at(ld);
                    let (scales, x_out) = s_item.split_at_mut(self.l);
                    for ((code_row, h), (c_row, scale)) in c_in
                        .chunks_exact(self.l)
                        .zip(headers.chunks_exact(CODE_SIDE_LEN))
                        .zip(c_item.chunks_exact_mut(self.d).zip(scales.iter_mut()))
                    {
                        // the hardware shift network: one table expansion
                        // per row, then a 1-byte indexed load per weight
                        let val = expand_row_side(h);
                        self.av_row_codes(code_row, &val, n, &mut s.row);
                        *scale = q8_quantize_row_into(&s.row, c_row);
                    }
                    x_out.copy_from_slice(x);
                }
                Ok(())
            }
            (input, out) => {
                anyhow::bail!("block-av: no {} -> {} path", input.port(), out.port())
            }
        }
    }
}

/// Stage 5: the residual add `Y = X + O'`, with the attention output
/// arriving as `ptf-u8` codes on the fused path — each element widens
/// through its token scale *inside* the add loop (the "quantized
/// consumer" this PR exists to prove out; DESIGN.md §3.5).  On f32
/// (`try_new`, the comparator) the item is the adapter-widened
/// `[O' | X]` block.
pub struct BlockResidualOp {
    l: usize,
    d: usize,
    in_port: PortType,
}

impl BlockResidualOp {
    /// Sequence length `l`, channel dimension `d`, staged f32 `[O' | X]`
    /// in-port.
    pub fn try_new(l: usize, d: usize) -> Result<BlockResidualOp> {
        BlockResidualOp::with_in_port(l, d, PortType::F32)
    }

    /// Construction with an explicit in-port (`F32` or `PtfU8`).
    pub fn with_in_port(l: usize, d: usize, port: PortType) -> Result<BlockResidualOp> {
        ensure_shape("block-residual", l, d)?;
        anyhow::ensure!(
            port != PortType::Log2Code5,
            "block-residual has no log2c5 in-port (context rows are affine u8 or f32)"
        );
        Ok(BlockResidualOp { l, d, in_port: port })
    }
}

impl Op for BlockResidualOp {
    fn name(&self) -> &str {
        "block-residual"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        match self.in_port {
            PortType::PtfU8 => self.l * self.d,
            _ => 2 * self.l * self.d,
        }
    }

    fn out_len(&self) -> usize {
        self.l * self.d
    }

    fn in_port(&self) -> PortType {
        self.in_port
    }

    fn in_side_len(&self) -> usize {
        match self.in_port {
            PortType::PtfU8 => self.l + self.l * self.d,
            _ => 0,
        }
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::ensure!(
            self.in_port == PortType::F32,
            "block-residual with a {} in-port must be driven through run_batch_ports",
            self.in_port
        );
        check_batch(self, rows, input, out)?;
        let ld = self.l * self.d;
        for (item, out_item) in input.chunks_exact(2 * ld).zip(out.chunks_exact_mut(ld)) {
            let (o_prime, x) = item.split_at(ld);
            for ((y, &xv), &ov) in out_item.iter_mut().zip(x).zip(o_prime) {
                *y = xv + ov;
            }
        }
        Ok(())
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        match (input, out) {
            (PortRef::F32(input), PortMut::F32(out)) => self.run_batch(rows, input, out, scratch),
            (PortRef::PtfU8 { codes, side }, PortMut::F32(out)) => {
                let ld = self.l * self.d;
                for ((c_item, s_item), out_item) in codes
                    .chunks_exact(ld)
                    .zip(side.chunks_exact(self.l + ld))
                    .zip(out.chunks_exact_mut(ld))
                {
                    let (scales, x) = s_item.split_at(self.l);
                    for (((c_row, &sc), x_row), o_row) in c_item
                        .chunks_exact(self.d)
                        .zip(scales)
                        .zip(x.chunks_exact(self.d))
                        .zip(out_item.chunks_exact_mut(self.d))
                    {
                        for ((y, &xv), &c) in o_row.iter_mut().zip(x_row).zip(c_row) {
                            *y = xv + q8_dequantize(c, sc);
                        }
                    }
                }
                Ok(())
            }
            (input, out) => {
                anyhow::bail!("block-residual: no {} -> {} path", input.port(), out.port())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn block_items(rng: &mut Rng, l: usize, d: usize, rows: usize) -> Vec<f32> {
        let mut v = vec![0f32; rows * l * d];
        rng.fill_normal(&mut v, 0.0, 1.0);
        v
    }

    fn run(op: &dyn Op, rows: usize, input: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; rows * op.out_len()];
        let mut scratch = op.make_scratch();
        op.run_batch(rows, input, &mut out, &mut scratch).unwrap();
        out
    }

    #[test]
    fn fused_is_bit_exact_to_unfused() {
        let mut rng = Rng::new(0xB10C);
        for &(l, d) in &[(1usize, 4usize), (7, 3), (17, 8), (32, 16)] {
            let fused = fused_block(l, d).unwrap();
            let unfused = unfused_block(l, d).unwrap();
            let input = block_items(&mut rng, l, d, 3);
            assert_eq!(run(&fused, 3, &input), run(&unfused, 3, &input), "L{l}xD{d}");
        }
    }

    #[test]
    fn residual_actually_rides_the_input_through() {
        // Y - X must equal the quantized attention branch, so zero input
        // maps to zero output and the op is not a pure attention clone
        let (l, d) = (8, 4);
        let fused = fused_block(l, d).unwrap();
        let zeros = vec![0f32; l * d];
        assert_eq!(run(&fused, 1, &zeros), zeros);
        let mut rng = Rng::new(0xB11);
        let input = block_items(&mut rng, l, d, 1);
        let y = run(&fused, 1, &input);
        let mut moved = 0usize;
        for (a, b) in y.iter().zip(&input) {
            if a != b {
                moved += 1;
            }
        }
        assert!(moved > 0, "residual output never departed from X");
    }

    #[test]
    fn fused_block_advertises_the_quantized_boundaries() {
        let (l, d) = (16, 8);
        let p = fused_block(l, d).unwrap();
        assert_eq!(p.spec().to_string(), "block/L16xD8");
        assert_eq!((p.item_len(), p.out_len()), (l * d, l * d));
        assert_eq!((p.in_port(), p.out_port()), (PortType::F32, PortType::F32));
        // five stages, zero adapters: every quantized boundary has a
        // native consumer on the other side
        assert_eq!(p.stages().len(), 5);
        assert!(
            p.stages().iter().all(|s| !s.name().starts_with("dequant")),
            "fused block grew a dequant adapter"
        );
        assert_eq!(
            p.boundary_ports(),
            vec![PortType::PtfU8, PortType::F32, PortType::Log2Code5, PortType::PtfU8]
        );
        // the comparator pays two adapters (both ptf-u8 boundaries; the
        // softmax comparator stays f32 end to end)
        let u = unfused_block(l, d).unwrap();
        assert_eq!(u.stages().len(), 7);
        assert_eq!(u.stages().iter().filter(|s| s.name().starts_with("dequant")).count(), 2);
        // staged bytes per boundary: codes at 1 byte/elem plus the f32
        // sidecar, vs 4 bytes/elem everywhere on the f32 comparator
        let staging = p.staging_bytes_per_item();
        assert_eq!(staging.len(), 4);
        assert_eq!(staging[0], l * d + 4 * (l + l * d));
        assert_eq!(staging[2], l * l + 4 * (2 * l + 2 * l * d));
    }

    #[test]
    fn multi_head_packing_is_pure_batch_geometry() {
        let (h, l, d) = (3usize, 9, 4);
        let packed = fused_block_heads(h, l, d).unwrap();
        assert_eq!(packed.spec().to_string(), "block/H3xL9xD4");
        assert_eq!(packed.item_len(), h * l * d);
        assert_eq!(packed.out_len(), h * l * d);
        let single = fused_block(l, d).unwrap();
        let rows = 2;
        let mut rng = Rng::new(0xB12);
        let input = block_items(&mut rng, l, d, rows * h);
        assert_eq!(run(&packed, rows, &input), run(&single, rows * h, &input));
    }

    #[test]
    fn stage_ports_reject_what_the_datapath_cannot_carry() {
        assert!(BlockLogitsOp::with_in_port(4, 4, PortType::Log2Code5).is_err());
        assert!(BlockAvOp::with_in_port(4, 4, PortType::PtfU8).is_err());
        assert!(BlockResidualOp::with_in_port(4, 4, PortType::Log2Code5).is_err());
        assert!(BlockLnOp::try_new(0, 4).is_err());
        assert!(BlockAvOp::try_new(4, 0).is_err());
        // quantized-ported stages refuse the untyped f32 entry point
        let (l, d) = (4, 4);
        for op in [
            Arc::new(BlockLnOp::try_new(l, d).unwrap()) as Arc<dyn Op>,
            Arc::new(BlockLogitsOp::with_in_port(l, d, PortType::PtfU8).unwrap()),
            Arc::new(BlockAvOp::with_in_port(l, d, PortType::Log2Code5).unwrap()),
            Arc::new(BlockResidualOp::with_in_port(l, d, PortType::PtfU8).unwrap()),
        ] {
            let mut s = op.make_scratch();
            let input = vec![0f32; op.item_len()];
            let mut out = vec![0f32; op.out_len()];
            let err = op.run_batch(1, &input, &mut out, &mut s).unwrap_err();
            assert!(format!("{err:#}").contains("run_batch_ports"), "{}: {err:#}", op.name());
        }
    }
}
