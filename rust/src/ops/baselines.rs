//! Prior-work comparators as [`Op`]s: Softermax (DAC'21) and the I-BERT
//! integer softmax/layernorm.  These wrap the functional models in
//! `softmax/baselines.rs` / `layernorm/baselines.rs` so the router can
//! serve them side by side with SOLE for accuracy/throughput comparison.
//!
//! Comparator ops call the reference functions row by row and therefore
//! allocate per row — they are measurement baselines, not hot paths; the
//! allocation-free contract applies to the ops actually optimized
//! (`e2softmax`, `ailayernorm`).

use anyhow::Result;

use super::{check_batch, Op, OpScratch};
use crate::layernorm::baselines::ibert_layernorm;
use crate::softmax::baselines::{ibert_softmax, softermax};

/// Fraction bits of the registered `softermax` service (the 16-bit
/// Softermax unit's buffer format).
pub const SOFTERMAX_FRAC_BITS: u32 = 8;

/// Input scale of the registered `ibert-softmax` service.
pub const IBERT_SOFTMAX_SCALE: f64 = 1.0 / 16.0;

/// Input scale of the registered `ibert-layernorm` service.
pub const IBERT_LAYERNORM_SCALE: f64 = 1.0 / 64.0;

/// Softermax rows of length `l` (spec `softermax/L<l>`).
pub struct SoftermaxOp {
    l: usize,
    frac_bits: u32,
}

impl SoftermaxOp {
    /// Row length `l` at the registered fraction-bit width.
    pub fn try_new(l: usize) -> Result<SoftermaxOp> {
        anyhow::ensure!(l > 0, "softermax rows must be non-empty");
        Ok(SoftermaxOp { l, frac_bits: SOFTERMAX_FRAC_BITS })
    }
}

impl Op for SoftermaxOp {
    fn name(&self) -> &str {
        "softermax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        for (row, row_out) in input.chunks_exact(self.l).zip(out.chunks_exact_mut(self.l)) {
            for (o, v) in row_out.iter_mut().zip(softermax(row, self.frac_bits)) {
                *o = v as f32;
            }
        }
        Ok(())
    }
}

/// I-BERT i-exp softmax rows of length `l` (spec `ibert-softmax/L<l>`).
pub struct IbertSoftmaxOp {
    l: usize,
    scale: f64,
}

impl IbertSoftmaxOp {
    /// Row length `l` at the registered input scale.
    pub fn try_new(l: usize) -> Result<IbertSoftmaxOp> {
        anyhow::ensure!(l > 0, "ibert-softmax rows must be non-empty");
        Ok(IbertSoftmaxOp { l, scale: IBERT_SOFTMAX_SCALE })
    }
}

impl Op for IbertSoftmaxOp {
    fn name(&self) -> &str {
        "ibert-softmax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        for (row, row_out) in input.chunks_exact(self.l).zip(out.chunks_exact_mut(self.l)) {
            for (o, v) in row_out.iter_mut().zip(ibert_softmax(row, self.scale)) {
                *o = v as f32;
            }
        }
        Ok(())
    }
}

/// I-BERT integer layernorm over `c` channels (spec
/// `ibert-layernorm/C<c>`), identity affine like the other registered
/// layernorm services.
pub struct IbertLayerNormOp {
    c: usize,
    scale: f64,
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

impl IbertLayerNormOp {
    /// Channel count `c`, identity affine, registered input scale.
    pub fn try_new(c: usize) -> Result<IbertLayerNormOp> {
        anyhow::ensure!(c > 0, "ibert-layernorm rows must be non-empty");
        Ok(IbertLayerNormOp {
            c,
            scale: IBERT_LAYERNORM_SCALE,
            gamma: vec![1f32; c],
            beta: vec![0f32; c],
        })
    }
}

impl Op for IbertLayerNormOp {
    fn name(&self) -> &str {
        "ibert-layernorm"
    }

    fn dim(&self) -> char {
        'C'
    }

    fn item_len(&self) -> usize {
        self.c
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        for (row, row_out) in input.chunks_exact(self.c).zip(out.chunks_exact_mut(self.c)) {
            let y = ibert_layernorm(row, &self.gamma, &self.beta, self.scale);
            for (o, v) in row_out.iter_mut().zip(y) {
                *o = v as f32;
            }
        }
        Ok(())
    }
}
