//! `PipelineOp`: chain [`Op`] stages behind the same one-op contract.
//!
//! A pipeline is itself an `Op`, so everything that serves single ops —
//! `OpBackend`, the `ServiceRouter`, `sole serve --ops`, the benches —
//! serves multi-stage computations with zero extra plumbing.  Stage
//! boundaries are staged through two ping-pong [`StageBuf`]s living in
//! the pipeline's scratch arena: each stage writes the format its
//! out-port declares (f32, packed `Log2Code5` shift codes, `PtfU8`
//! codes — DESIGN.md §3.3), and the buffer is retagged in place so
//! capacity ratchets to the largest batch seen and steady-state
//! execution allocates nothing.  Each stage keeps its own scratch inside
//! the same arena.
//!
//! Boundaries are validated once at construction, exactly like shape:
//! stage `i`'s `out_len`/`out_side_len` must equal stage `i+1`'s
//! `item_len`/`in_side_len`, and the ports must agree.  The one repair
//! the constructor performs itself: where a quantized producer meets an
//! f32 consumer (including the pipeline's own f32 output edge), it
//! auto-inserts an explicit [`DequantOp`] adapter — a real, named,
//! benchable stage, not hidden glue.  No other conversion is implied; a
//! quantize step is always an op the caller chose.  Both outer edges of
//! a pipeline are f32: that is what the router and `OpBackend` speak.
//!
//! The in-tree pipelines are the attention datapaths built in
//! [`super::attention`] (`attention/L<len>xD<dim>`, DESIGN.md §3.2) and
//! the `ailayernorm-ptf` chain, whose quantized tail exists purely so
//! the adapter path is served and benched.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::port::{DequantOp, PortMut, PortRef, PortType, StageBuf};
use super::{check_batch, Op, OpScratch, OpSpec};

/// A chain of [`Op`] stages executed as one op: the output batch of
/// stage `i` is the input batch of stage `i+1`, staged at whatever port
/// the boundary declares.
///
/// ## Multi-head packing
///
/// A pipeline built with [`PipelineOp::with_heads`] packs `H` heads into
/// one item: the item is `H` consecutive single-head items, and every
/// stage runs over `rows * H` inner rows through the *same* single-head
/// stage ops — the SIMD arms and dispatch are untouched, the packing is
/// pure batch geometry.  `item_len`/`out_len`/`staging_bytes_per_item`
/// all scale by `H`, so one request carries a whole multi-head attention
/// (or block) item through the router.
pub struct PipelineOp {
    spec: OpSpec,
    heads: usize,
    stages: Vec<Arc<dyn Op>>,
}

/// Per-worker arena: one scratch per stage plus the two ping-pong
/// staging buffers for the intermediate batches.
struct Scratch {
    stages: Vec<OpScratch>,
    a: StageBuf,
    b: StageBuf,
}

impl PipelineOp {
    /// Chain `stages` under the canonical `spec` (the spec is what the
    /// registry advertises; `spec.op` is the pipeline's name).  Errors
    /// if the chain is empty, the entry stage is not f32, any boundary
    /// disagrees on item shape or sidecar length, or a boundary mixes
    /// formats in a way no dequant adapter repairs.  Where a quantized
    /// out-port meets an f32 in-port (or the final f32 output edge), the
    /// matching [`DequantOp`] is inserted as an explicit stage.
    pub fn try_new(spec: OpSpec, stages: Vec<Arc<dyn Op>>) -> Result<PipelineOp> {
        PipelineOp::with_heads(spec, 1, stages)
    }

    /// [`PipelineOp::try_new`] with `heads` single-head items packed per
    /// pipeline item: each stage executes `rows * heads` inner rows, so
    /// per-head slices stage through the same boundary ports and kernels
    /// as the single-head pipeline.  `heads == 1` is exactly `try_new`.
    pub fn with_heads(spec: OpSpec, heads: usize, stages: Vec<Arc<dyn Op>>) -> Result<PipelineOp> {
        anyhow::ensure!(heads > 0, "pipeline '{spec}': head count must be positive");
        anyhow::ensure!(!stages.is_empty(), "pipeline '{spec}' needs at least one stage");
        anyhow::ensure!(
            stages[0].in_port() == PortType::F32,
            "pipeline '{spec}': entry stage '{}' wants a {} in-port; router-facing edges are f32",
            stages[0].name(),
            stages[0].in_port()
        );
        let mut chain: Vec<Arc<dyn Op>> = Vec::with_capacity(stages.len() + 1);
        for stage in stages {
            if let Some(prev) = chain.last() {
                if prev.out_port() != stage.in_port() {
                    anyhow::ensure!(
                        stage.in_port() == PortType::F32,
                        "pipeline '{spec}': no adapter from {} stage '{}' to {} stage '{}' — \
                         only dequant-to-f32 boundaries auto-insert",
                        prev.out_port(),
                        prev.name(),
                        stage.in_port(),
                        stage.name()
                    );
                    let adapter = DequantOp::for_producer(prev.as_ref())
                        .with_context(|| format!("pipeline '{spec}'"))?;
                    chain.push(Arc::new(adapter));
                }
                let prev = chain.last().unwrap();
                anyhow::ensure!(
                    prev.out_len() == stage.item_len(),
                    "pipeline '{spec}': stage '{}' outputs {} f32/item but stage '{}' expects {}",
                    prev.name(),
                    prev.out_len(),
                    stage.name(),
                    stage.item_len()
                );
                anyhow::ensure!(
                    prev.out_side_len() == stage.in_side_len(),
                    "pipeline '{spec}': stage '{}' emits {} sidecar f32/item but stage '{}' \
                     expects {}",
                    prev.name(),
                    prev.out_side_len(),
                    stage.name(),
                    stage.in_side_len()
                );
            }
            chain.push(stage);
        }
        if chain.last().unwrap().out_port() != PortType::F32 {
            let tail = DequantOp::for_producer(chain.last().unwrap().as_ref())
                .with_context(|| format!("pipeline '{spec}'"))?;
            chain.push(Arc::new(tail));
        }
        Ok(PipelineOp { spec, heads, stages: chain })
    }

    /// The chained stages, in execution order — auto-inserted dequant
    /// adapters included.
    pub fn stages(&self) -> &[Arc<dyn Op>] {
        &self.stages
    }

    /// Heads packed per item (1 for single-head pipelines).
    pub fn heads(&self) -> usize {
        self.heads
    }
}

impl Op for PipelineOp {
    fn name(&self) -> &str {
        &self.spec.op
    }

    fn dim(&self) -> char {
        self.spec.dim
    }

    fn item_len(&self) -> usize {
        self.heads * self.stages[0].item_len()
    }

    fn out_len(&self) -> usize {
        self.heads * self.stages[self.stages.len() - 1].out_len()
    }

    fn spec(&self) -> OpSpec {
        self.spec.clone()
    }

    fn boundary_ports(&self) -> Vec<PortType> {
        self.stages[..self.stages.len() - 1].iter().map(|s| s.out_port()).collect()
    }

    /// Bytes one item occupies in the staging buffer at each internal
    /// boundary, in execution order (length `stages() - 1`): code bytes
    /// at the port's width plus the f32 sidecar, summed over the packed
    /// heads.  This is the number the paper's inter-stage storage claim
    /// lives in — `sole ops` and `bench_kernels --json` report it per
    /// pipeline as `staging_bytes_per_item`.
    fn staging_bytes_per_item(&self) -> Vec<usize> {
        self.stages[..self.stages.len() - 1]
            .iter()
            .map(|s| {
                self.heads * (s.out_port().bytes_per_elem() * s.out_len() + 4 * s.out_side_len())
            })
            .collect()
    }

    fn dispatch(&self) -> Option<crate::simd::Dispatch> {
        self.stages.iter().find_map(|s| s.dispatch())
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(Scratch {
            stages: self.stages.iter().map(|s| s.make_scratch()).collect(),
            a: StageBuf::default(),
            b: StageBuf::default(),
        })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<Scratch>()
            .with_context(|| format!("pipeline '{}' handed a foreign scratch arena", self.spec))?;
        anyhow::ensure!(
            s.stages.len() == self.stages.len(),
            "pipeline '{}' scratch arena has {} stage slots, expected {}",
            self.spec,
            s.stages.len(),
            self.stages.len()
        );
        let Scratch { stages: scr, a, b } = s;
        let last = self.stages.len() - 1;
        // multi-head packing is pure batch geometry: one pipeline item is
        // `heads` consecutive single-head items, so every stage runs over
        // `rows * heads` inner rows through the unchanged single-head op
        let inner = rows * self.heads;
        // ping-pong through a/b: stage i reads the buffer stage i-1 wrote
        // (or `input` for stage 0), and writes the other buffer (or `out`
        // for the last stage) at stage i's declared out-port.  `prepare`
        // resizes without clearing, so a warm buffer is not re-zeroed
        // every batch: the `Op` contract requires each stage to write
        // every code and sidecar f32 of its output, so stale content from
        // a previous batch — even one staged at a different format — is
        // never observable (pinned per registered pipeline by the
        // scratch-reuse determinism conformance test).
        let mut src_is_a = false;
        for (i, stage) in self.stages.iter().enumerate() {
            let sc = &mut scr[i];
            let result = if i == last {
                let src = if i == 0 {
                    PortRef::F32(input)
                } else if src_is_a {
                    a.as_port_ref()
                } else {
                    b.as_port_ref()
                };
                stage.run_batch_ports(inner, src, PortMut::F32(out), sc)
            } else {
                let elems = inner * stage.out_len();
                let side = inner * stage.out_side_len();
                let (src, dst) = if i == 0 {
                    src_is_a = true;
                    (PortRef::F32(input), a.prepare(stage.out_port(), elems, side))
                } else if src_is_a {
                    src_is_a = false;
                    (a.as_port_ref(), b.prepare(stage.out_port(), elems, side))
                } else {
                    src_is_a = true;
                    (b.as_port_ref(), a.prepare(stage.out_port(), elems, side))
                };
                stage.run_batch_ports(inner, src, dst, sc)
            };
            result.with_context(|| {
                format!("pipeline '{}' stage {} ('{}')", self.spec, i, stage.name())
            })?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::E2SoftmaxOp;
    use crate::util::rng::Rng;

    fn spec(text: &str) -> OpSpec {
        OpSpec::parse(text).unwrap()
    }

    fn code_softmax(l: usize) -> Arc<dyn Op> {
        Arc::new(E2SoftmaxOp::with_out_port(l, PortType::Log2Code5).unwrap())
    }

    #[test]
    fn quantized_tail_gets_an_explicit_dequant_adapter() {
        let l = 8;
        let p = PipelineOp::try_new(spec("e2softmax/L8"), vec![code_softmax(l)]).unwrap();
        assert_eq!(p.stages().len(), 2, "adapter must appear as a real stage");
        assert_eq!(p.stages()[1].name(), "dequant-log2c5");
        assert_eq!(p.boundary_ports(), vec![PortType::Log2Code5]);
        // 1 byte/code + the 2-f32 header, vs 4 bytes/f32 staged
        assert_eq!(p.staging_bytes_per_item(), vec![l + 4 * 2]);
        // and the staged result is bit-identical to the plain f32 op
        let plain = E2SoftmaxOp::try_new(l).unwrap();
        let mut rng = Rng::new(0x9E2);
        let mut input = vec![0f32; 5 * l];
        rng.fill_normal(&mut input, 0.0, 2.0);
        let (mut got, mut want) = (vec![0f32; 5 * l], vec![0f32; 5 * l]);
        let mut sp = p.make_scratch();
        p.run_batch(5, &input, &mut got, &mut sp).unwrap();
        let mut ss = plain.make_scratch();
        plain.run_batch(5, &input, &mut want, &mut ss).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn quantized_entry_and_unadaptable_boundaries_are_rejected() {
        let consumer: Arc<dyn Op> =
            Arc::new(DequantOp::for_producer(code_softmax(8).as_ref()).unwrap());
        let err = format!(
            "{:#}",
            PipelineOp::try_new(spec("e2softmax/L8"), vec![consumer.clone()]).unwrap_err()
        );
        assert!(err.contains("router-facing edges are f32"), "{err}");
        // f32 producer into a log2c5 consumer: nothing auto-inserts a
        // quantize step
        let f32_softmax: Arc<dyn Op> = Arc::new(E2SoftmaxOp::try_new(8).unwrap());
        let err = format!(
            "{:#}",
            PipelineOp::try_new(spec("e2softmax/L8"), vec![f32_softmax, consumer]).unwrap_err()
        );
        assert!(err.contains("only dequant-to-f32 boundaries auto-insert"), "{err}");
        assert!(PipelineOp::try_new(spec("e2softmax/L8"), vec![]).is_err());
    }

    #[test]
    fn packed_heads_are_pure_batch_geometry() {
        // an H-head packed item is H consecutive single-head items: the
        // packed pipeline over `rows` items must be bit-identical to the
        // single-head pipeline over `rows * H` inner rows
        let (l, heads, rows) = (8usize, 3usize, 2usize);
        let single = PipelineOp::try_new(spec("e2softmax/L8"), vec![code_softmax(l)]).unwrap();
        let packed =
            PipelineOp::with_heads(spec("e2softmax/H3xL8"), heads, vec![code_softmax(l)]).unwrap();
        assert_eq!(packed.heads(), heads);
        assert_eq!(packed.item_len(), heads * l);
        assert_eq!(packed.out_len(), heads * l);
        assert_eq!(packed.staging_bytes_per_item(), vec![heads * (l + 4 * 2)]);
        let mut rng = Rng::new(0x9E3);
        let mut input = vec![0f32; rows * heads * l];
        rng.fill_normal(&mut input, 0.0, 2.0);
        let (mut got, mut want) = (vec![0f32; rows * heads * l], vec![0f32; rows * heads * l]);
        let mut sp = packed.make_scratch();
        packed.run_batch(rows, &input, &mut got, &mut sp).unwrap();
        let mut ss = single.make_scratch();
        single.run_batch(rows * heads, &input, &mut want, &mut ss).unwrap();
        assert_eq!(got, want);
        // zero heads is a construction error, not a degenerate op
        assert!(PipelineOp::with_heads(spec("e2softmax/L8"), 0, vec![code_softmax(l)]).is_err());
    }

    #[test]
    fn empty_batches_are_a_no_op_success() {
        let p = PipelineOp::try_new(spec("e2softmax/L8"), vec![code_softmax(8)]).unwrap();
        let mut s = p.make_scratch();
        p.run_batch(0, &[], &mut [], &mut s).unwrap();
    }

    #[test]
    fn foreign_scratch_arena_is_rejected() {
        let p = PipelineOp::try_new(spec("e2softmax/L8"), vec![code_softmax(8)]).unwrap();
        let mut wrong: OpScratch = Box::new(());
        let err =
            format!("{:#}", p.run_batch(1, &[0.0; 8], &mut [0.0; 8], &mut wrong).unwrap_err());
        assert!(err.contains("foreign scratch arena"), "{err}");
    }

    #[test]
    fn mismatched_stage_slot_count_is_rejected() {
        // same Scratch type, wrong geometry: a 1-stage pipeline's arena
        // handed to the adapted 2-stage one
        let two = PipelineOp::try_new(spec("e2softmax/L8"), vec![code_softmax(8)]).unwrap();
        let one = PipelineOp::try_new(
            spec("e2softmax/L8"),
            vec![Arc::new(E2SoftmaxOp::try_new(8).unwrap()) as Arc<dyn Op>],
        )
        .unwrap();
        assert_eq!((two.stages().len(), one.stages().len()), (2, 1));
        let mut arena = one.make_scratch();
        let err =
            format!("{:#}", two.run_batch(1, &[0.0; 8], &mut [0.0; 8], &mut arena).unwrap_err());
        assert!(err.contains("1 stage slots, expected 2"), "{err}");
    }
}
