//! `PipelineOp`: chain [`Op`] stages behind the same one-op contract.
//!
//! A pipeline is itself an `Op`, so everything that serves single ops —
//! `OpBackend`, the `ServiceRouter`, `sole serve --ops`, the benches —
//! serves multi-stage computations with zero extra plumbing.  Stage
//! boundaries are staged through two ping-pong buffers living in the
//! pipeline's scratch arena (resize-based reuse, so capacity ratchets to
//! the largest batch seen and steady-state execution allocates nothing),
//! and each stage keeps its own scratch inside the same arena.  Stage
//! shapes are validated once at construction: stage `i`'s `out_len` must
//! equal stage `i+1`'s `item_len`.
//!
//! The in-tree pipelines are the attention datapaths built in
//! [`super::attention`] (`attention/L<len>xD<dim>`, DESIGN.md §3.2).

use std::sync::Arc;

use anyhow::{Context, Result};

use super::{check_batch, Op, OpScratch, OpSpec};

/// A chain of [`Op`] stages executed as one op: the output batch of
/// stage `i` is the input batch of stage `i+1`.
pub struct PipelineOp {
    spec: OpSpec,
    stages: Vec<Arc<dyn Op>>,
}

/// Per-worker arena: one scratch per stage plus the two ping-pong
/// staging buffers for the intermediate batches.
struct Scratch {
    stages: Vec<OpScratch>,
    a: Vec<f32>,
    b: Vec<f32>,
}

impl PipelineOp {
    /// Chain `stages` under the canonical `spec` (the spec is what the
    /// registry advertises; `spec.op` is the pipeline's name).  Errors if
    /// the chain is empty or any stage boundary disagrees on item shape.
    pub fn try_new(spec: OpSpec, stages: Vec<Arc<dyn Op>>) -> Result<PipelineOp> {
        anyhow::ensure!(!stages.is_empty(), "pipeline '{spec}' needs at least one stage");
        for pair in stages.windows(2) {
            anyhow::ensure!(
                pair[0].out_len() == pair[1].item_len(),
                "pipeline '{spec}': stage '{}' outputs {} f32/item but stage '{}' expects {}",
                pair[0].name(),
                pair[0].out_len(),
                pair[1].name(),
                pair[1].item_len()
            );
        }
        Ok(PipelineOp { spec, stages })
    }

    /// The chained stages, in execution order.
    pub fn stages(&self) -> &[Arc<dyn Op>] {
        &self.stages
    }
}

impl Op for PipelineOp {
    fn name(&self) -> &str {
        &self.spec.op
    }

    fn dim(&self) -> char {
        self.spec.dim
    }

    fn item_len(&self) -> usize {
        self.stages[0].item_len()
    }

    fn out_len(&self) -> usize {
        self.stages[self.stages.len() - 1].out_len()
    }

    fn spec(&self) -> OpSpec {
        self.spec.clone()
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(Scratch {
            stages: self.stages.iter().map(|s| s.make_scratch()).collect(),
            a: Vec::new(),
            b: Vec::new(),
        })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<Scratch>()
            .with_context(|| format!("pipeline '{}' handed a foreign scratch arena", self.spec))?;
        anyhow::ensure!(
            s.stages.len() == self.stages.len(),
            "pipeline '{}' scratch arena has {} stage slots, expected {}",
            self.spec,
            s.stages.len(),
            self.stages.len()
        );
        let Scratch { stages: scr, a, b } = s;
        let last = self.stages.len() - 1;
        // ping-pong through a/b: stage i reads the buffer stage i-1 wrote
        // (or `input` for stage 0), and writes the other buffer (or `out`
        // for the last stage).  Plain resize (no clear) so a warm buffer
        // is not re-zeroed every batch: the `Op` contract requires each
        // stage to write every one of its `rows * out_len()` output f32s,
        // so stale content from a previous batch is never observable
        // (pinned per registered pipeline by the scratch-reuse
        // determinism conformance test).
        let mut src_is_a = false;
        for (i, stage) in self.stages.iter().enumerate() {
            let sc = &mut scr[i];
            let result = if i == last {
                let src: &[f32] = if i == 0 {
                    input
                } else if src_is_a {
                    &a[..]
                } else {
                    &b[..]
                };
                stage.run_batch(rows, src, out, sc)
            } else if i == 0 {
                a.resize(rows * stage.out_len(), 0.0);
                src_is_a = true;
                stage.run_batch(rows, input, &mut a[..], sc)
            } else if src_is_a {
                b.resize(rows * stage.out_len(), 0.0);
                src_is_a = false;
                stage.run_batch(rows, &a[..], &mut b[..], sc)
            } else {
                a.resize(rows * stage.out_len(), 0.0);
                src_is_a = true;
                stage.run_batch(rows, &b[..], &mut a[..], sc)
            };
            result.with_context(|| {
                format!("pipeline '{}' stage {} ('{}')", self.spec, i, stage.name())
            })?;
        }
        Ok(())
    }
}
