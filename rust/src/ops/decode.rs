//! Stateful KV-cache decode attention: one query row per step against a
//! server-resident key/value cache (DESIGN.md §3.5).
//!
//! Prefill (the `attention/*` pipelines) scores a whole `L x L` block at
//! once; decode is the serving regime where the sequence grows one token
//! per request and recomputing the full block would be `O(L²·D)` per
//! step.  [`DecodeAttnOp`] keeps K and V cached in per-session
//! [`DecodeState`] — the state lives in the serving worker, keyed by
//! session id, never inside the op (`coordinator/session.rs`) — and each
//! step costs one `O(t·D)` row: score the new query against the `t`
//! cached keys, E2Softmax the row to 5-bit shift codes, then
//! shift-accumulate over the cached V.
//!
//! Every kernel here is the row-length-parameterized arm the prefill
//! pipelines already run (`quantize_logits_batch_into`,
//! `E2Softmax::forward_batch_codes`, `av_row_codes_avx2`), and
//! E2Softmax quantizes each row against its own max — so step `t` of a
//! decode session is **bit-identical** to row `t` of a one-shot
//! `attention/L<t>xD<d>` prefill over the same tokens.  That oracle is
//! pinned by `tests/decode_prefill.rs` under both kernel arms.
//!
//! The op registers as `decode-attention/L<cap>xD<dim>`: `L` is the
//! session *capacity* (cache slots), the per-request item is one packed
//! `[q | k | v]` row (`3·D` f32) and the output is the `D`-wide context
//! row.  `run_batch` errors by design — `OpBackend` refuses stateful
//! ops, the decode service drives [`Op::run_batch_stateful`] instead.

use anyhow::{Context, Result};

use super::{check_batch, Op, OpScratch, OpSpec, OpState};
use crate::simd::Dispatch;
use crate::softmax::e2::{
    expand_row_side, quantize_logits_batch_into, E2Scratch, CODE_SIDE_LEN,
};
use crate::softmax::{E2Softmax, E2SoftmaxConfig};

/// Decode-attention op: spec `decode-attention/L<cap>xD<dim>`, item
/// `[q | k | v]` (`3·D` f32), output one `D`-wide context row per step.
pub struct DecodeAttnOp {
    l_max: usize,
    d: usize,
    sm: E2Softmax,
    scale: f32,
    dispatch: Dispatch,
}

/// Per-session KV cache: the only state in the system, owned by the
/// serving worker the session is pinned to.
pub struct DecodeState {
    /// Cached key rows, `t * d` f32.
    k: Vec<f32>,
    /// Cached value rows, `t * d` f32.
    v: Vec<f32>,
    /// Steps taken so far (cached tokens).
    t: usize,
}

impl DecodeState {
    /// Number of cached tokens (decode steps taken so far).
    pub fn len(&self) -> usize {
        self.t
    }

    /// True before the first decode step.
    pub fn is_empty(&self) -> bool {
        self.t == 0
    }
}

/// Per-worker arena: the score row, its quantized forms, and the
/// E2Softmax kernel scratch.  Sized to the current `t`, so capacity
/// grows to the longest session the worker has served.
struct Scratch {
    logits: Vec<f32>,
    qcodes: Vec<i64>,
    codes: Vec<u8>,
    side: [f32; CODE_SIDE_LEN],
    e2: E2Scratch,
}

impl DecodeAttnOp {
    /// Session capacity `l_max` (cache slots), head dimension `d`; the
    /// logit scale is the standard `1/√d`.
    pub fn try_new(l_max: usize, d: usize) -> Result<DecodeAttnOp> {
        DecodeAttnOp::with_dispatch(l_max, d, Dispatch::detect())
    }

    /// Construction with an explicit kernel arm (tests pin arms to
    /// compare them); the request is clamped to what this host can run.
    pub fn with_dispatch(l_max: usize, d: usize, dispatch: Dispatch) -> Result<DecodeAttnOp> {
        anyhow::ensure!(l_max > 0, "decode-attention: session capacity must be positive");
        anyhow::ensure!(d > 0, "decode-attention: head dimension must be positive");
        let dispatch = dispatch.sanitize();
        Ok(DecodeAttnOp {
            l_max,
            d,
            sm: E2Softmax::with_dispatch(E2SoftmaxConfig::default(), dispatch),
            scale: 1.0 / (d as f32).sqrt(),
            dispatch,
        })
    }
}

impl Op for DecodeAttnOp {
    fn name(&self) -> &str {
        "decode-attention"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn spec(&self) -> OpSpec {
        let extra = vec![('D', self.d)];
        OpSpec { op: "decode-attention".into(), dim: 'L', len: self.l_max, extra }
    }

    fn item_len(&self) -> usize {
        3 * self.d
    }

    fn out_len(&self) -> usize {
        self.d
    }

    fn dispatch(&self) -> Option<Dispatch> {
        Some(self.dispatch)
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(Scratch {
            logits: Vec::new(),
            qcodes: Vec::new(),
            codes: Vec::new(),
            side: [0.0; CODE_SIDE_LEN],
            e2: E2Scratch::default(),
        })
    }

    fn stateful(&self) -> bool {
        true
    }

    fn make_state(&self) -> OpState {
        let cap = self.l_max * self.d;
        Box::new(DecodeState { k: Vec::with_capacity(cap), v: Vec::with_capacity(cap), t: 0 })
    }

    fn run_batch(
        &self,
        _rows: usize,
        _input: &[f32],
        _out: &mut [f32],
        _scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::bail!(
            "decode-attention is stateful: drive it through run_batch_stateful via the decode \
             service (sole serve --decode), not the stateless batch path"
        )
    }

    fn run_batch_stateful(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
        state: &mut OpState,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<Scratch>()
            .context("decode-attention handed a foreign scratch arena")?;
        let st = state
            .downcast_mut::<DecodeState>()
            .context("decode-attention handed a foreign session state")?;
        let d = self.d;
        for (item, o_row) in input.chunks_exact(3 * d).zip(out.chunks_exact_mut(d)) {
            anyhow::ensure!(
                st.t < self.l_max,
                "decode-attention session is at capacity L{} ({} cached tokens)",
                self.l_max,
                st.t
            );
            let (q, rest) = item.split_at(d);
            let (k, v) = rest.split_at(d);
            st.k.extend_from_slice(k);
            st.v.extend_from_slice(v);
            st.t += 1;
            let t = st.t;
            // score the new query against every cached key — the same
            // acc-over-d-then-scale order as AttnLogitsOp, so row t of a
            // prefill block sees identical f32s
            s.logits.resize(t, 0.0);
            for (kj, s_elem) in st.k.chunks_exact(d).zip(s.logits.iter_mut()) {
                let mut acc = 0f32;
                for (&x, &y) in q.iter().zip(kj) {
                    acc += x * y;
                }
                *s_elem = acc * self.scale;
            }
            // one E2Softmax row: per-row-max quantization, codes + the
            // compact divider header — decode stores exactly what the
            // prefill code port stores
            quantize_logits_batch_into(&s.logits, t, self.sm.cfg().e, &mut s.qcodes);
            s.codes.resize(t, 0);
            self.sm.forward_batch_codes(&s.qcodes, t, &mut s.codes, &mut s.side, &mut s.e2);
            let val = expand_row_side(&s.side);
            if self.dispatch == Dispatch::Avx2 {
                // SAFETY: the Avx2 arm only exists after runtime detection
                // (Dispatch::sanitize); shapes checked above.
                unsafe { crate::simd::av::av_row_codes_avx2(&s.codes, &val, &st.v, d, o_row) };
                continue;
            }
            o_row.fill(0.0);
            for (&code, v_row) in s.codes.iter().zip(st.v.chunks_exact(d)) {
                let pij = val[code as usize];
                for (o, &vv) in o_row.iter_mut().zip(v_row) {
                    *o += pij * vv;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn stateless_entry_points_are_sealed() {
        let op = DecodeAttnOp::try_new(8, 4).unwrap();
        assert!(op.stateful());
        assert_eq!(op.spec().to_string(), "decode-attention/L8xD4");
        assert_eq!((op.item_len(), op.out_len()), (12, 4));
        let mut s = op.make_scratch();
        let err = op.run_batch(1, &[0.0; 12], &mut [0.0; 4], &mut s).unwrap_err();
        assert!(format!("{err:#}").contains("run_batch_stateful"), "{err:#}");
        // degenerate shapes die at construction
        assert!(DecodeAttnOp::try_new(0, 4).is_err());
        assert!(DecodeAttnOp::try_new(8, 0).is_err());
    }

    #[test]
    fn sessions_are_isolated_and_capacity_bounded() {
        let (cap, d) = (3usize, 4usize);
        let op = DecodeAttnOp::try_new(cap, d).unwrap();
        let mut rng = Rng::new(0xDEC0);
        let mut input = vec![0f32; 3 * d];
        let mut scratch = op.make_scratch();
        let mut a = op.make_state();
        let mut b = op.make_state();
        let mut out_a = vec![0f32; d];
        let mut out_b = vec![0f32; d];
        // the same token stream through two sessions gives the same rows
        for step in 0..cap {
            rng.fill_normal(&mut input, 0.0, 1.0);
            op.run_batch_stateful(1, &input, &mut out_a, &mut scratch, &mut a).unwrap();
            op.run_batch_stateful(1, &input, &mut out_b, &mut scratch, &mut b).unwrap();
            assert_eq!(out_a, out_b, "step {step}");
        }
        assert_eq!(a.downcast_ref::<DecodeState>().unwrap().len(), cap);
        // step cap+1 overflows the cache, and the error names the spec's L
        let err = op.run_batch_stateful(1, &input, &mut out_a, &mut scratch, &mut a).unwrap_err();
        assert!(format!("{err:#}").contains("capacity L3"), "{err:#}");
        // a fresh state starts over
        let mut c = op.make_state();
        assert!(c.downcast_ref::<DecodeState>().unwrap().is_empty());
        op.run_batch_stateful(1, &input, &mut out_a, &mut scratch, &mut c).unwrap();
    }

    #[test]
    fn a_batched_call_equals_token_by_token_steps() {
        let (cap, d) = (16usize, 8usize);
        let op = DecodeAttnOp::try_new(cap, d).unwrap();
        let mut rng = Rng::new(0xDEC1);
        let mut input = vec![0f32; cap * 3 * d];
        rng.fill_normal(&mut input, 0.0, 1.0);
        // all 16 steps in one run_batch_stateful call
        let mut batched = vec![0f32; cap * d];
        let mut scratch = op.make_scratch();
        let mut state = op.make_state();
        op.run_batch_stateful(cap, &input, &mut batched, &mut scratch, &mut state).unwrap();
        // vs one call per token on a fresh session
        let mut stepped = vec![0f32; cap * d];
        let mut state = op.make_state();
        for (item, o_row) in input.chunks_exact(3 * d).zip(stepped.chunks_exact_mut(d)) {
            op.run_batch_stateful(1, item, o_row, &mut scratch, &mut state).unwrap();
        }
        assert_eq!(batched, stepped);
    }
}
