//! The paper's E2Softmax as an [`Op`]: quantize-to-codes + the planar
//! LUT-driven batch kernel, packaged behind the one operator API.
//! With a `Log2Code5` out-port the op emits what the hardware stores —
//! packed 5-bit total-shift codes plus the compact per-row divider
//! header — instead of dequantized f32.

use anyhow::{Context, Result};

use super::port::{check_batch_ports, PortMut, PortRef, PortType};
use super::{check_batch, Op, OpScratch};
use crate::softmax::e2::{quantize_logits_batch_into, E2Scratch, CODE_SIDE_LEN};
use crate::softmax::{E2Softmax, E2SoftmaxConfig};

/// Bit-exact E2Softmax over f32 logit rows of length `l` (spec
/// `e2softmax/L<l>`): one pass of per-row-max quantization over the packed
/// batch, then one `forward_batch_f32` (or, on the code port,
/// `forward_batch_codes`) kernel call.
pub struct E2SoftmaxOp {
    l: usize,
    sm: E2Softmax,
    out_port: PortType,
}

/// Per-worker arena: the packed logit->code buffer plus the E2Softmax
/// kernel scratch.
struct Scratch {
    codes: Vec<i64>,
    e2: E2Scratch,
}

impl E2SoftmaxOp {
    /// Row length `l` at the default datapath configuration, plain f32
    /// out-port.
    pub fn try_new(l: usize) -> Result<E2SoftmaxOp> {
        E2SoftmaxOp::with_config(l, E2SoftmaxConfig::default())
    }

    /// Fully-specified construction (ablations pick non-default `e`/lane
    /// counts); the serving registry uses `try_new`.
    pub fn with_config(l: usize, cfg: E2SoftmaxConfig) -> Result<E2SoftmaxOp> {
        anyhow::ensure!(l > 0, "e2softmax rows must be non-empty");
        Ok(E2SoftmaxOp { l, sm: E2Softmax::new(cfg), out_port: PortType::F32 })
    }

    /// Construction with an explicit out-port: `Log2Code5` makes the op
    /// emit one packed shift code per element plus the
    /// [`CODE_SIDE_LEN`]-f32 divider header per row (the paper's 5-bit
    /// storage claim), for a downstream consumer that dequantizes —
    /// bit-exactly — on its own side of the boundary.
    pub fn with_out_port(l: usize, port: PortType) -> Result<E2SoftmaxOp> {
        anyhow::ensure!(
            port != PortType::PtfU8,
            "e2softmax has no ptf-u8 out-port (its codes are log2 shifts, not affine u8)"
        );
        let mut op = E2SoftmaxOp::with_config(l, E2SoftmaxConfig::default())?;
        op.out_port = port;
        Ok(op)
    }
}

impl Op for E2SoftmaxOp {
    fn name(&self) -> &str {
        "e2softmax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l
    }

    fn out_port(&self) -> PortType {
        self.out_port
    }

    fn out_side_len(&self) -> usize {
        match self.out_port {
            PortType::Log2Code5 => CODE_SIDE_LEN,
            _ => 0,
        }
    }

    fn dispatch(&self) -> Option<crate::simd::Dispatch> {
        Some(self.sm.dispatch())
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(Scratch { codes: Vec::with_capacity(self.l), e2: E2Scratch::default() })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        anyhow::ensure!(
            self.out_port == PortType::F32,
            "e2softmax with a {} out-port must be driven through run_batch_ports",
            self.out_port
        );
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<Scratch>()
            .context("e2softmax op handed a foreign scratch arena")?;
        quantize_logits_batch_into(input, self.l, self.sm.cfg().e, &mut s.codes);
        self.sm.forward_batch_f32(&s.codes, self.l, out, &mut s.e2);
        Ok(())
    }

    fn run_batch_ports(
        &self,
        rows: usize,
        input: PortRef<'_>,
        out: PortMut<'_>,
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch_ports(self, rows, &input, &out)?;
        match (input, out) {
            (PortRef::F32(input), PortMut::F32(out)) => self.run_batch(rows, input, out, scratch),
            (PortRef::F32(input), PortMut::Log2Code5 { codes, side }) => {
                let s = scratch
                    .downcast_mut::<Scratch>()
                    .context("e2softmax op handed a foreign scratch arena")?;
                quantize_logits_batch_into(input, self.l, self.sm.cfg().e, &mut s.codes);
                self.sm.forward_batch_codes(&s.codes, self.l, codes, side, &mut s.e2);
                Ok(())
            }
            (input, out) => anyhow::bail!(
                "e2softmax: no {} -> {} path",
                input.port(),
                out.port()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softmax::e2::expand_row_side;
    use crate::util::rng::Rng;

    #[test]
    fn code_port_dequantizes_bitwise_to_the_f32_op() {
        let l = 49;
        let rows = 4;
        let f32_op = E2SoftmaxOp::try_new(l).unwrap();
        let code_op = E2SoftmaxOp::with_out_port(l, PortType::Log2Code5).unwrap();
        assert_eq!(code_op.out_port(), PortType::Log2Code5);
        assert_eq!(code_op.out_side_len(), CODE_SIDE_LEN);
        let mut rng = Rng::new(9);
        let mut input = vec![0f32; rows * l];
        rng.fill_normal(&mut input, 0.0, 2.0);
        let mut want = vec![0f32; rows * l];
        let mut s = f32_op.make_scratch();
        f32_op.run_batch(rows, &input, &mut want, &mut s).unwrap();
        let mut codes = vec![0u8; rows * l];
        let mut side = vec![0f32; rows * CODE_SIDE_LEN];
        let mut s = code_op.make_scratch();
        code_op
            .run_batch_ports(
                rows,
                PortRef::F32(&input),
                PortMut::Log2Code5 { codes: &mut codes, side: &mut side },
                &mut s,
            )
            .unwrap();
        for r in 0..rows {
            let val = expand_row_side(&side[r * CODE_SIDE_LEN..(r + 1) * CODE_SIDE_LEN]);
            for i in 0..l {
                assert_eq!(val[codes[r * l + i] as usize], want[r * l + i], "row {r} elem {i}");
            }
        }
    }

    #[test]
    fn code_port_refuses_the_f32_entry_point_and_ptf_construction() {
        let code_op = E2SoftmaxOp::with_out_port(8, PortType::Log2Code5).unwrap();
        let mut s = code_op.make_scratch();
        let err = code_op.run_batch(1, &[0.0; 8], &mut [0.0; 8], &mut s).unwrap_err();
        assert!(format!("{err:#}").contains("run_batch_ports"), "{err:#}");
        let err = E2SoftmaxOp::with_out_port(8, PortType::PtfU8).unwrap_err();
        assert!(format!("{err:#}").contains("no ptf-u8 out-port"), "{err:#}");
        // an explicit f32 out-port is the plain op
        let op = E2SoftmaxOp::with_out_port(8, PortType::F32).unwrap();
        assert_eq!(op.out_port(), PortType::F32);
        assert_eq!(op.out_side_len(), 0);
    }
}
