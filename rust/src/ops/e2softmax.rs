//! The paper's E2Softmax as an [`Op`]: quantize-to-codes + the planar
//! LUT-driven batch kernel, packaged behind the one operator API.

use anyhow::{Context, Result};

use super::{check_batch, Op, OpScratch};
use crate::softmax::e2::{quantize_logits_batch_into, E2Scratch};
use crate::softmax::{E2Softmax, E2SoftmaxConfig};

/// Bit-exact E2Softmax over f32 logit rows of length `l` (spec
/// `e2softmax/L<l>`): one pass of per-row-max quantization over the packed
/// batch, then one `forward_batch_f32` kernel call.
pub struct E2SoftmaxOp {
    l: usize,
    sm: E2Softmax,
}

/// Per-worker arena: the packed logit->code buffer plus the E2Softmax
/// kernel scratch.
struct Scratch {
    codes: Vec<i64>,
    e2: E2Scratch,
}

impl E2SoftmaxOp {
    /// Row length `l` at the default datapath configuration.
    pub fn try_new(l: usize) -> Result<E2SoftmaxOp> {
        E2SoftmaxOp::with_config(l, E2SoftmaxConfig::default())
    }

    /// Fully-specified construction (ablations pick non-default `e`/lane
    /// counts); the serving registry uses `try_new`.
    pub fn with_config(l: usize, cfg: E2SoftmaxConfig) -> Result<E2SoftmaxOp> {
        anyhow::ensure!(l > 0, "e2softmax rows must be non-empty");
        Ok(E2SoftmaxOp { l, sm: E2Softmax::new(cfg) })
    }
}

impl Op for E2SoftmaxOp {
    fn name(&self) -> &str {
        "e2softmax"
    }

    fn dim(&self) -> char {
        'L'
    }

    fn item_len(&self) -> usize {
        self.l
    }

    fn make_scratch(&self) -> OpScratch {
        Box::new(Scratch { codes: Vec::with_capacity(self.l), e2: E2Scratch::default() })
    }

    fn run_batch(
        &self,
        rows: usize,
        input: &[f32],
        out: &mut [f32],
        scratch: &mut OpScratch,
    ) -> Result<()> {
        check_batch(self, rows, input, out)?;
        let s = scratch
            .downcast_mut::<Scratch>()
            .context("e2softmax op handed a foreign scratch arena")?;
        quantize_logits_batch_into(input, self.l, self.sm.cfg().e, &mut s.codes);
        self.sm.forward_batch_f32(&s.codes, self.l, out, &mut s.e2);
        Ok(())
    }
}
