//! `OpRegistry`: spec strings -> operator constructors.
//!
//! The registry is the single place "which operators exist" is recorded.
//! Each family registers a dimension letter (so `e2softmax/C768` is a
//! caught error, not a silently weird service), a default item length
//! (what `sole ops` advertises and `bench_serving` drives), a one-line
//! summary, and a fallible constructor from a parsed [`OpSpec`].

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::{
    AiLayerNormOp, E2SoftmaxOp, ExactLayerNormOp, ExactSoftmaxOp, IbertLayerNormOp,
    IbertSoftmaxOp, Op, OpSpec, SoftermaxOp,
};

/// Constructor from a validated spec (the registry checks the dimension
/// letter and positive length before calling it).
type OpCtor = Box<dyn Fn(&OpSpec) -> Result<Arc<dyn Op>> + Send + Sync>;

struct OpEntry {
    dim: char,
    default_len: usize,
    summary: String,
    ctor: OpCtor,
}

/// What `sole ops` prints per family.
#[derive(Debug, Clone)]
pub struct OpListing {
    pub name: String,
    pub dim: char,
    pub default_len: usize,
    pub summary: String,
}

/// Registry of operator families, keyed by spec name.
pub struct OpRegistry {
    entries: BTreeMap<String, OpEntry>,
}

impl OpRegistry {
    /// An empty registry (tests, downstream embedders).
    pub fn empty() -> OpRegistry {
        OpRegistry { entries: BTreeMap::new() }
    }

    /// Every in-tree operator: the paper pair, the exact baselines, and
    /// the prior-work comparators.
    pub fn builtin() -> OpRegistry {
        let mut r = OpRegistry::empty();
        // registering a literal name twice is a programmer error; the
        // expect keeps builtin() infallible for callers
        let mut add = |name: &str, dim, default_len, summary: &str, ctor: OpCtor| {
            r.register(name, dim, default_len, summary, ctor)
                .unwrap_or_else(|e| panic!("builtin registry: {e:#}"))
        };
        add(
            "e2softmax",
            'L',
            128,
            "SOLE E2Softmax (Algorithm 1): bit-exact integer softmax, planar LUT kernel",
            Box::new(|spec: &OpSpec| Ok(Arc::new(E2SoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)),
        );
        add(
            "softmax-exact",
            'L',
            128,
            "exact f64 softmax baseline on f32 logit rows",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(ExactSoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "softermax",
            'L',
            128,
            "Softermax (DAC'21) base-2 comparator, 8 fraction bits",
            Box::new(|spec: &OpSpec| Ok(Arc::new(SoftermaxOp::try_new(spec.len)?) as Arc<dyn Op>)),
        );
        add(
            "ibert-softmax",
            'L',
            128,
            "I-BERT i-exp integer softmax comparator, input scale 1/16",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(IbertSoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "ailayernorm",
            'C',
            768,
            "SOLE AILayerNorm (Algorithm 2): bit-exact integer layernorm, PTF-quantized",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(AiLayerNormOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "layernorm-exact",
            'C',
            768,
            "exact f64 layernorm baseline, identity affine",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(ExactLayerNormOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "ibert-layernorm",
            'C',
            768,
            "I-BERT integer layernorm comparator, input scale 1/64",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(IbertLayerNormOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        r
    }

    /// Register a family.  Errors on an invalid name or a duplicate —
    /// silently replacing an operator would invalidate every spec string
    /// already handed out.
    pub fn register(
        &mut self,
        name: &str,
        dim: char,
        default_len: usize,
        summary: &str,
        ctor: OpCtor,
    ) -> Result<()> {
        anyhow::ensure!(!name.is_empty(), "op name must be non-empty");
        anyhow::ensure!(
            !name.contains('/') && !name.contains(char::is_whitespace),
            "op name '{name}' must not contain '/' or whitespace"
        );
        anyhow::ensure!(
            dim.is_ascii_uppercase(),
            "op '{name}': dimension letter must be uppercase"
        );
        anyhow::ensure!(default_len > 0, "op '{name}': default length must be positive");
        anyhow::ensure!(
            !self.entries.contains_key(name),
            "op '{name}' is already registered"
        );
        self.entries.insert(
            name.to_string(),
            OpEntry { dim, default_len, summary: summary.to_string(), ctor },
        );
        Ok(())
    }

    /// Registered family names, ascending.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// One listing per family, ascending by name (the `sole ops` view).
    pub fn listings(&self) -> Vec<OpListing> {
        self.entries
            .iter()
            .map(|(name, e)| OpListing {
                name: name.clone(),
                dim: e.dim,
                default_len: e.default_len,
                summary: e.summary.clone(),
            })
            .collect()
    }

    fn entry(&self, op: &str) -> Result<&OpEntry> {
        self.entries.get(op).with_context(|| {
            format!("unknown op '{op}' (registered: {})", self.names().join(", "))
        })
    }

    /// The family's spec at its default item length.
    pub fn canonical_spec(&self, op: &str) -> Result<OpSpec> {
        let e = self.entry(op)?;
        Ok(OpSpec { op: op.to_string(), dim: e.dim, len: e.default_len })
    }

    /// Parse a spec string and validate it against the registry: known
    /// family, matching dimension letter.
    pub fn parse_spec(&self, s: &str) -> Result<OpSpec> {
        let spec = OpSpec::parse(s)?;
        let e = self.entry(&spec.op)?;
        anyhow::ensure!(
            spec.dim == e.dim,
            "op spec '{s}': '{}' takes {}<len>, not {}<len>",
            spec.op,
            e.dim,
            spec.dim
        );
        Ok(spec)
    }

    /// Parse, validate and construct: the one call sites use.  The
    /// returned spec is canonical (`spec.to_string()` is the service
    /// name).
    pub fn build(&self, s: &str) -> Result<(OpSpec, Arc<dyn Op>)> {
        let spec = self.parse_spec(s)?;
        let op = (self.entry(&spec.op)?.ctor)(&spec)
            .with_context(|| format!("constructing op '{spec}'"))?;
        // the spec string is the service name, so a constructor that
        // renames or resizes the op would advertise a contract the op
        // does not honor — reject it at registration time
        anyhow::ensure!(
            op.name() == spec.op,
            "op '{spec}': constructor returned an op named '{}'",
            op.name()
        );
        anyhow::ensure!(
            op.item_len() == spec.len,
            "op '{spec}': constructor returned item length {}",
            op.item_len()
        );
        Ok((spec, op))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_covers_paper_baselines_and_comparators() {
        let r = OpRegistry::builtin();
        assert_eq!(
            r.names(),
            vec![
                "ailayernorm",
                "e2softmax",
                "ibert-layernorm",
                "ibert-softmax",
                "layernorm-exact",
                "softermax",
                "softmax-exact",
            ]
        );
        for listing in r.listings() {
            assert!(!listing.summary.is_empty(), "{}", listing.name);
            let spec = r.canonical_spec(&listing.name).unwrap();
            assert_eq!(spec.dim, listing.dim);
            assert_eq!(spec.len, listing.default_len);
        }
    }

    #[test]
    fn build_constructs_every_builtin_at_its_canonical_spec() {
        let r = OpRegistry::builtin();
        for name in r.names() {
            let s = r.canonical_spec(name).unwrap().to_string();
            let (spec, op) = r.build(&s).unwrap();
            assert_eq!(op.name(), spec.op, "{s}");
            assert_eq!(op.item_len(), spec.len, "{s}");
            assert_eq!(op.spec(), spec, "{s}");
        }
    }

    #[test]
    fn unknown_op_error_lists_registered_names() {
        let r = OpRegistry::builtin();
        let err = format!("{:#}", r.build("consmax/L64").unwrap_err());
        assert!(err.contains("unknown op 'consmax'"), "{err}");
        assert!(err.contains("e2softmax"), "{err}");
    }

    #[test]
    fn wrong_dimension_letter_is_caught() {
        let r = OpRegistry::builtin();
        let err = format!("{:#}", r.build("e2softmax/C768").unwrap_err());
        assert!(err.contains("takes L<len>"), "{err}");
        assert!(r.build("ailayernorm/L49").is_err());
    }

    #[test]
    fn zero_length_spec_is_rejected() {
        let r = OpRegistry::builtin();
        assert!(r.build("e2softmax/L0").is_err());
    }

    #[test]
    fn register_rejects_duplicates_and_bad_names() {
        let mut r = OpRegistry::builtin();
        let dup = r.register(
            "e2softmax",
            'L',
            64,
            "dup",
            Box::new(|spec: &OpSpec| Ok(Arc::new(E2SoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)),
        );
        assert!(dup.is_err());
        for bad in ["", "a/b", "a b"] {
            let got = r.register(
                bad,
                'L',
                64,
                "bad",
                Box::new(|spec: &OpSpec| {
                    Ok(Arc::new(E2SoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)
                }),
            );
            assert!(got.is_err(), "'{bad}' should be rejected");
        }
    }
}
