//! `OpRegistry`: spec strings -> operator constructors.
//!
//! The registry is the single place "which operators exist" is recorded.
//! Each family registers its dimension signature — letters plus default
//! lengths, e.g. `[('L', 128)]` or `[('L', 128), ('D', 64)]` — so
//! `e2softmax/C768` and `attention/L128` are caught errors, not silently
//! weird services; plus a one-line summary and a fallible constructor
//! from a parsed [`OpSpec`].  Families registered with
//! [`OpRegistry::register_heads`] additionally accept an optional
//! leading `H<heads>` dimension (`attention/H8xL128xD64`): the canonical
//! spec stays single-head, and the constructor sees the full parsed spec
//! so it can build the packed multi-head pipeline.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::{
    attention, block, decode, AiLayerNormOp, ConSmaxOp, E2SoftmaxOp, ExactLayerNormOp,
    ExactSoftmaxOp, GnSoftmaxOp, IbertLayerNormOp, IbertSoftmaxOp, Op, OpSpec, PipelineOp,
    PortType, SoftermaxOp,
};

/// Constructor from a validated spec (the registry checks the dimension
/// signature before calling it, and checks the built op advertises the
/// same spec after).
pub type OpCtor = Box<dyn Fn(&OpSpec) -> Result<Arc<dyn Op>> + Send + Sync>;

struct OpEntry {
    /// (letter, default length) per dimension, primary first.
    dims: Vec<(char, usize)>,
    /// Whether the family accepts an optional leading `H<heads>`
    /// dimension (multi-head packing).
    heads: bool,
    summary: String,
    ctor: OpCtor,
}

/// What `sole ops` prints per family.
#[derive(Debug, Clone)]
pub struct OpListing {
    /// Registry family name.
    pub name: String,
    /// Dimension signature: (letter, default length), primary first.
    pub dims: Vec<(char, usize)>,
    /// Whether the family accepts an optional leading `H<heads>`
    /// dimension.
    pub heads: bool,
    /// One-line description.
    pub summary: String,
}

impl OpListing {
    /// The family's canonical spec (every dimension at its default).
    pub fn canonical(&self) -> OpSpec {
        spec_from_dims(&self.name, &self.dims)
    }

    /// The shape signature as the grammar renders it: `L<len>`,
    /// `L<len>xD<len>`, or `[H<n>x]L<len>xD<len>` for heads-enabled
    /// families.
    pub fn signature(&self) -> String {
        let parts: Vec<String> = self.dims.iter().map(|&(d, _)| format!("{d}<len>")).collect();
        let base = parts.join("x");
        if self.heads {
            format!("[H<n>x]{base}")
        } else {
            base
        }
    }
}

fn spec_from_dims(name: &str, dims: &[(char, usize)]) -> OpSpec {
    OpSpec { op: name.to_string(), dim: dims[0].0, len: dims[0].1, extra: dims[1..].to_vec() }
}

/// Registry of operator families, keyed by spec name.
pub struct OpRegistry {
    entries: BTreeMap<String, OpEntry>,
}

impl OpRegistry {
    /// An empty registry (tests, downstream embedders).
    pub fn empty() -> OpRegistry {
        OpRegistry { entries: BTreeMap::new() }
    }

    /// Every in-tree operator: the paper pair, the exact baselines, the
    /// prior-work comparators, the reduction-free streaming family, the
    /// attention/block pipelines, and the stateful decode family.
    pub fn builtin() -> OpRegistry {
        let mut r = OpRegistry::empty();
        // registering a literal name twice is a programmer error; the
        // expect keeps builtin() infallible for callers
        let mut add = |name: &str, dims: &[(char, usize)], heads: bool, summary: &str, ctor| {
            r.register_entry(name, dims, heads, summary, ctor)
                .unwrap_or_else(|e| panic!("builtin registry: {e:#}"))
        };
        add(
            "e2softmax",
            &[('L', 128)],
            false,
            "SOLE E2Softmax (Algorithm 1): bit-exact integer softmax, planar LUT kernel",
            Box::new(|spec: &OpSpec| Ok(Arc::new(E2SoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)),
        );
        add(
            "softmax-exact",
            &[('L', 128)],
            false,
            "exact f64 softmax baseline on f32 logit rows",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(ExactSoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "softermax",
            &[('L', 128)],
            false,
            "Softermax (DAC'21) base-2 comparator, 8 fraction bits",
            Box::new(|spec: &OpSpec| Ok(Arc::new(SoftermaxOp::try_new(spec.len)?) as Arc<dyn Op>)),
        );
        add(
            "consmax",
            &[('L', 128)],
            false,
            "ConSmax reduction-free softmax (learnable beta/gamma frozen at the registered \
             calibration) — streams row chunks through the stream service",
            Box::new(|spec: &OpSpec| Ok(Arc::new(ConSmaxOp::try_new(spec.len)?) as Arc<dyn Op>)),
        );
        add(
            "gn-softmax",
            &[('L', 128)],
            false,
            "guaranteed-normalization softmax (power-of-two codes, row sum <= 1 by \
             construction) — reduction-free, streams row chunks through the stream service",
            Box::new(|spec: &OpSpec| Ok(Arc::new(GnSoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)),
        );
        add(
            "ibert-softmax",
            &[('L', 128)],
            false,
            "I-BERT i-exp integer softmax comparator, input scale 1/16",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(IbertSoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "ailayernorm",
            &[('C', 768)],
            false,
            "SOLE AILayerNorm (Algorithm 2): bit-exact integer layernorm, PTF-quantized",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(AiLayerNormOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "ailayernorm-ptf",
            &[('C', 768)],
            false,
            "AILayerNorm staged through its ptf-u8 out-port (u8 codes + one f32 row scale), \
             widened back to f32 by the auto-inserted dequant adapter stage",
            Box::new(|spec: &OpSpec| {
                let ln = AiLayerNormOp::with_out_port(spec.len, PortType::PtfU8)?;
                Ok(Arc::new(PipelineOp::try_new(spec.clone(), vec![Arc::new(ln)])?) as Arc<dyn Op>)
            }),
        );
        add(
            "layernorm-exact",
            &[('C', 768)],
            false,
            "exact f64 layernorm baseline, identity affine",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(ExactLayerNormOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "ibert-layernorm",
            &[('C', 768)],
            false,
            "I-BERT integer layernorm comparator, input scale 1/64",
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(IbertLayerNormOp::try_new(spec.len)?) as Arc<dyn Op>)
            }),
        );
        add(
            "attention",
            &[('L', 128), ('D', 64)],
            true,
            "fused attention pipeline: QK^T-scaled logits -> E2Softmax log2 codes -> \
             shift-accumulate A*V (item [Q|K|V], 3*L*D f32 in, L*D f32 out; H packs heads)",
            Box::new(|spec: &OpSpec| {
                Ok(if spec.dim == 'H' {
                    let (h, l, d) = (spec.len, spec.extra[0].1, spec.extra[1].1);
                    Arc::new(attention::fused_pipeline_heads(h, l, d)?) as Arc<dyn Op>
                } else {
                    Arc::new(attention::fused_pipeline(spec.len, spec.extra[0].1)?) as Arc<dyn Op>
                })
            }),
        );
        add(
            "attention-exact",
            &[('L', 128), ('D', 64)],
            true,
            "exact-softmax attention pipeline: the error/latency reference for 'attention'",
            Box::new(|spec: &OpSpec| {
                Ok(if spec.dim == 'H' {
                    let (h, l, d) = (spec.len, spec.extra[0].1, spec.extra[1].1);
                    Arc::new(attention::exact_pipeline_heads(h, l, d)?) as Arc<dyn Op>
                } else {
                    Arc::new(attention::exact_pipeline(spec.len, spec.extra[0].1)?) as Arc<dyn Op>
                })
            }),
        );
        add(
            "block",
            &[('L', 128), ('D', 64)],
            true,
            "transformer block pipeline: AILayerNorm (ptf-u8 port) -> attention over the \
             normed rows -> residual add consuming ptf-u8 directly (item X, L*D f32 in/out)",
            Box::new(|spec: &OpSpec| {
                Ok(if spec.dim == 'H' {
                    let (h, l, d) = (spec.len, spec.extra[0].1, spec.extra[1].1);
                    Arc::new(block::fused_block_heads(h, l, d)?) as Arc<dyn Op>
                } else {
                    Arc::new(block::fused_block(spec.len, spec.extra[0].1)?) as Arc<dyn Op>
                })
            }),
        );
        add(
            "decode-attention",
            &[('L', 128), ('D', 64)],
            false,
            "stateful KV-cache decode attention: each request appends one [q|k|v] step \
             (3*D f32) and returns its context row (D f32); L is the session capacity — \
             served with session affinity by the decode service, never through OpBackend",
            Box::new(|spec: &OpSpec| {
                let op = decode::DecodeAttnOp::try_new(spec.len, spec.extra[0].1)?;
                Ok(Arc::new(op) as Arc<dyn Op>)
            }),
        );
        r
    }

    /// Register a family under its dimension signature (letters with
    /// default lengths, primary first).  Errors on an invalid name, an
    /// invalid signature, or a duplicate — silently replacing an operator
    /// would invalidate every spec string already handed out.
    pub fn register(
        &mut self,
        name: &str,
        dims: &[(char, usize)],
        summary: &str,
        ctor: OpCtor,
    ) -> Result<()> {
        self.register_entry(name, dims, false, summary, ctor)
    }

    /// [`OpRegistry::register`] for a family that also accepts an
    /// optional leading `H<heads>` dimension: `parse_spec` admits both
    /// `<op>/L..xD..` and `<op>/H<n>xL..xD..`, and the constructor
    /// receives the full parsed spec (`spec.dim == 'H'` for the packed
    /// form).  `H` must not appear in `dims`.
    pub fn register_heads(
        &mut self,
        name: &str,
        dims: &[(char, usize)],
        summary: &str,
        ctor: OpCtor,
    ) -> Result<()> {
        self.register_entry(name, dims, true, summary, ctor)
    }

    fn register_entry(
        &mut self,
        name: &str,
        dims: &[(char, usize)],
        heads: bool,
        summary: &str,
        ctor: OpCtor,
    ) -> Result<()> {
        anyhow::ensure!(!name.is_empty(), "op name must be non-empty");
        anyhow::ensure!(
            !name.contains('/') && !name.contains(char::is_whitespace),
            "op name '{name}' must not contain '/' or whitespace"
        );
        anyhow::ensure!(!dims.is_empty(), "op '{name}': dimension signature must be non-empty");
        for &(dim, default_len) in dims {
            anyhow::ensure!(
                dim.is_ascii_uppercase(),
                "op '{name}': dimension letters must be uppercase"
            );
            anyhow::ensure!(
                default_len > 0,
                "op '{name}': default lengths must be positive"
            );
            anyhow::ensure!(
                !(heads && dim == 'H'),
                "op '{name}': 'H' is the implicit heads dimension, not part of the signature"
            );
        }
        anyhow::ensure!(
            !self.entries.contains_key(name),
            "op '{name}' is already registered"
        );
        self.entries.insert(
            name.to_string(),
            OpEntry { dims: dims.to_vec(), heads, summary: summary.to_string(), ctor },
        );
        Ok(())
    }

    /// Registered family names, ascending.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// One listing per family, ascending by name (the `sole ops` view).
    pub fn listings(&self) -> Vec<OpListing> {
        self.entries
            .iter()
            .map(|(name, e)| OpListing {
                name: name.clone(),
                dims: e.dims.clone(),
                heads: e.heads,
                summary: e.summary.clone(),
            })
            .collect()
    }

    fn entry(&self, op: &str) -> Result<&OpEntry> {
        self.entries.get(op).with_context(|| {
            format!("unknown op '{op}' (registered: {})", self.names().join(", "))
        })
    }

    /// The family's spec with every dimension at its default length.
    pub fn canonical_spec(&self, op: &str) -> Result<OpSpec> {
        let e = self.entry(op)?;
        Ok(spec_from_dims(op, &e.dims))
    }

    /// Parse a spec string and validate it against the registry: known
    /// family, matching dimension signature (heads-enabled families also
    /// accept an optional leading `H<heads>` dimension).
    pub fn parse_spec(&self, s: &str) -> Result<OpSpec> {
        let spec = OpSpec::parse(s)?;
        let e = self.entry(&spec.op)?;
        let want: Vec<char> = e.dims.iter().map(|&(d, _)| d).collect();
        let got_letters = spec.letters();
        let matches = if e.heads && spec.dim == 'H' {
            got_letters[1..] == want[..]
        } else {
            got_letters == want
        };
        if !matches {
            let signature: Vec<String> = want.iter().map(|d| format!("{d}<len>")).collect();
            let mut signature = signature.join("x");
            if e.heads {
                signature = format!("[H<n>x]{signature}");
            }
            let got: Vec<String> = got_letters.iter().map(|d| format!("{d}<len>")).collect();
            anyhow::bail!("op spec '{s}': '{}' takes {signature}, not {}", spec.op, got.join("x"));
        }
        Ok(spec)
    }

    /// Parse, validate and construct: the one call sites use.  The
    /// returned spec is canonical (`spec.to_string()` is the service
    /// name).
    pub fn build(&self, s: &str) -> Result<(OpSpec, Arc<dyn Op>)> {
        let spec = self.parse_spec(s)?;
        let op = (self.entry(&spec.op)?.ctor)(&spec)
            .with_context(|| format!("constructing op '{spec}'"))?;
        // the spec string is the service name, so a constructor that
        // renames the op would advertise a contract the op does not
        // honor — reject it at build time
        anyhow::ensure!(
            op.spec() == spec,
            "op '{spec}': constructor returned an op advertising '{}'",
            op.spec()
        );
        // for one-dimensional families the item length IS the spec length
        // — an independent cross-check (a pipeline's spec() echoes its
        // stored spec, so its shape is pinned by the conformance suite
        // instead, where item/out lengths are derived from the stages)
        if spec.extra.is_empty() {
            anyhow::ensure!(
                op.item_len() == spec.len,
                "op '{spec}': constructor returned item length {}",
                op.item_len()
            );
        }
        Ok((spec, op))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_covers_paper_baselines_comparators_and_pipelines() {
        let r = OpRegistry::builtin();
        assert_eq!(
            r.names(),
            vec![
                "ailayernorm",
                "ailayernorm-ptf",
                "attention",
                "attention-exact",
                "block",
                "consmax",
                "decode-attention",
                "e2softmax",
                "gn-softmax",
                "ibert-layernorm",
                "ibert-softmax",
                "layernorm-exact",
                "softermax",
                "softmax-exact",
            ]
        );
        for listing in r.listings() {
            assert!(!listing.summary.is_empty(), "{}", listing.name);
            let spec = r.canonical_spec(&listing.name).unwrap();
            assert_eq!(spec, listing.canonical());
            assert!(!listing.signature().is_empty());
        }
        assert_eq!(r.canonical_spec("attention").unwrap().to_string(), "attention/L128xD64");
        assert_eq!(
            r.listings().iter().find(|l| l.name == "attention").unwrap().signature(),
            "[H<n>x]L<len>xD<len>"
        );
        assert_eq!(
            r.listings().iter().find(|l| l.name == "block").unwrap().signature(),
            "[H<n>x]L<len>xD<len>"
        );
        assert_eq!(
            r.listings().iter().find(|l| l.name == "decode-attention").unwrap().signature(),
            "L<len>xD<len>"
        );
        assert_eq!(
            r.listings().iter().find(|l| l.name == "e2softmax").unwrap().signature(),
            "L<len>"
        );
    }

    #[test]
    fn build_constructs_every_builtin_at_its_canonical_spec() {
        let r = OpRegistry::builtin();
        for name in r.names() {
            let s = r.canonical_spec(name).unwrap().to_string();
            let (spec, op) = r.build(&s).unwrap();
            assert_eq!(op.name(), spec.op, "{s}");
            assert_eq!(op.spec(), spec, "{s}");
            assert!(op.item_len() > 0, "{s}");
            assert!(op.out_len() > 0, "{s}");
        }
    }

    #[test]
    fn attention_build_honors_non_default_shapes() {
        let r = OpRegistry::builtin();
        let (spec, op) = r.build("attention/L49xD32").unwrap();
        assert_eq!(spec.to_string(), "attention/L49xD32");
        assert_eq!(op.item_len(), 3 * 49 * 32);
        assert_eq!(op.out_len(), 49 * 32);
        let (_, exact) = r.build("attention-exact/L49xD32").unwrap();
        assert_eq!(exact.item_len(), op.item_len());
    }

    #[test]
    fn unknown_op_error_lists_registered_names() {
        let r = OpRegistry::builtin();
        let err = format!("{:#}", r.build("flashmax/L64").unwrap_err());
        assert!(err.contains("unknown op 'flashmax'"), "{err}");
        assert!(err.contains("e2softmax"), "{err}");
    }

    #[test]
    fn wrong_dimension_signature_is_caught() {
        let r = OpRegistry::builtin();
        let err = format!("{:#}", r.build("e2softmax/C768").unwrap_err());
        assert!(err.contains("takes L<len>"), "{err}");
        assert!(r.build("ailayernorm/L49").is_err());
        // pipelines validate the full signature, not just the first letter
        let err = format!("{:#}", r.build("attention/L128").unwrap_err());
        assert!(err.contains("takes [H<n>x]L<len>xD<len>"), "{err}");
        assert!(r.build("attention/L128xC64").is_err());
        assert!(r.build("attention/D64xL128").is_err());
        assert!(r.build("attention/L128xD64xD2").is_err());
        // and 1-D families reject trailing dimensions
        let err = format!("{:#}", r.build("e2softmax/L128xD64").unwrap_err());
        assert!(err.contains("takes L<len>"), "{err}");
    }

    #[test]
    fn heads_specs_build_only_for_heads_enabled_families() {
        let r = OpRegistry::builtin();
        for s in ["attention/H8xL16xD8", "attention-exact/H2xL16xD8", "block/H2xL16xD8"] {
            let (spec, op) = r.build(s).unwrap();
            assert_eq!(spec.to_string(), s);
            assert_eq!(op.spec(), spec, "{s}");
        }
        // the multi-head item packs H single-head items
        let (_, packed) = r.build("attention/H8xL16xD8").unwrap();
        let (_, single) = r.build("attention/L16xD8").unwrap();
        assert_eq!(packed.item_len(), 8 * single.item_len());
        assert_eq!(packed.out_len(), 8 * single.out_len());
        // H on a non-heads family is a signature error naming the grammar
        let err = format!("{:#}", r.build("e2softmax/H2xL64").unwrap_err());
        assert!(err.contains("takes L<len>"), "{err}");
        assert!(r.build("decode-attention/H2xL64xD8").is_err());
        // H alone never replaces the required dimensions
        assert!(r.build("attention/H8xL128").is_err());
        assert!(r.build("attention/H8").is_err());
        assert!(r.build("attention/H0xL16xD8").is_err());
    }

    #[test]
    fn zero_length_spec_is_rejected() {
        let r = OpRegistry::builtin();
        assert!(r.build("e2softmax/L0").is_err());
        assert!(r.build("attention/L128xD0").is_err());
    }

    #[test]
    fn register_rejects_duplicates_and_bad_names() {
        let mut r = OpRegistry::builtin();
        let ctor = || {
            Box::new(|spec: &OpSpec| {
                Ok(Arc::new(E2SoftmaxOp::try_new(spec.len)?) as Arc<dyn Op>)
            }) as OpCtor
        };
        assert!(r.register("e2softmax", &[('L', 64)], "dup", ctor()).is_err());
        for bad in ["", "a/b", "a b"] {
            assert!(
                r.register(bad, &[('L', 64)], "bad", ctor()).is_err(),
                "'{bad}' should be rejected"
            );
        }
        // bad signatures: empty, lowercase letter, zero default
        assert!(r.register("ok-name", &[], "bad", ctor()).is_err());
        assert!(r.register("ok-name", &[('l', 64)], "bad", ctor()).is_err());
        assert!(r.register("ok-name", &[('L', 0)], "bad", ctor()).is_err());
        // a heads-enabled family cannot also name 'H' in its signature
        assert!(r.register_heads("ok-name", &[('H', 8), ('L', 64)], "bad", ctor()).is_err());
        // but a plain family may use the letter explicitly
        assert!(r.register("h-name", &[('H', 8)], "ok", ctor()).is_ok());
    }
}
