//! Operator spec strings: the one grammar every layer speaks.
//!
//! A spec names an operator family plus its item shape in a single
//! routable token: `<op>/<DIM><len>[x<DIM><len>...]`.  Examples:
//! `e2softmax/L256`, `softmax-exact/L49`, `ailayernorm/C768`,
//! `attention/L128xD64`, `attention/H8xL128xD64`.  `<op>` is the
//! registry family name (no `/`), each `<DIM>` is one uppercase
//! dimension letter (by convention `L` for sequence/row length, `C` for
//! layernorm channel count, `D` for attention head dimension, `H` for
//! head count), `<len>` is a positive integer, and extra dimensions are
//! separated by a lowercase `x` (unambiguous: dimension letters are
//! uppercase).  Dimension letters must be distinct within one spec.
//! Most families are one-dimensional; pipelines like `attention` carry
//! the extra dimensions their stages need.  The canonical rendering
//! round-trips: `parse(format(spec)) == spec`.

use anyhow::{Context, Result};

/// A parsed operator spec: family name, primary dimension, item length,
/// plus any trailing dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpSpec {
    /// Registry family name, e.g. `e2softmax`.
    pub op: String,
    /// Primary dimension letter (`L` rows, `C` channels).
    pub dim: char,
    /// Primary dimension length (for one-dimensional ops this is the
    /// flat f32 item length; pipelines derive their item length from the
    /// full shape).
    pub len: usize,
    /// Trailing dimensions after the primary one, in spec order — e.g.
    /// `[('D', 64)]` in `attention/L128xD64`.  Empty for the
    /// one-dimensional families.
    pub extra: Vec<(char, usize)>,
}

impl OpSpec {
    /// Parse `<op>/<DIM><len>[x<DIM><len>...]`.  Every failure names the
    /// offending spec — this string is the user-facing API of
    /// `sole serve --ops`.
    pub fn parse(s: &str) -> Result<OpSpec> {
        let (op, shape) = s.rsplit_once('/').with_context(|| {
            format!("op spec '{s}': expected '<op>/<DIM><len>' (e.g. e2softmax/L128)")
        })?;
        anyhow::ensure!(!op.is_empty(), "op spec '{s}': empty op name before '/'");
        anyhow::ensure!(!op.contains('/'), "op spec '{s}': op name must not contain '/'");
        let mut segments = shape.split('x');
        let (dim, len) = parse_segment(s, segments.next().unwrap_or(""))?;
        let extra = segments.map(|seg| parse_segment(s, seg)).collect::<Result<Vec<_>>>()?;
        let spec = OpSpec { op: op.to_string(), dim, len, extra };
        let letters = spec.letters();
        for (i, &d) in letters.iter().enumerate() {
            anyhow::ensure!(
                !letters[..i].contains(&d),
                "op spec '{s}': duplicate dimension letter '{d}'"
            );
        }
        Ok(spec)
    }

    /// Dimension letters in spec order, primary first (`['L', 'D']` for
    /// `attention/L128xD64`); the registry validates these against the
    /// family's registered signature.
    pub fn letters(&self) -> Vec<char> {
        std::iter::once(self.dim).chain(self.extra.iter().map(|&(d, _)| d)).collect()
    }

    /// The shape part of the canonical rendering (`L128xD64`), without
    /// the op name.
    pub fn shape(&self) -> String {
        let mut out = format!("{}{}", self.dim, self.len);
        for (d, l) in &self.extra {
            out.push('x');
            out.push(*d);
            out.push_str(&l.to_string());
        }
        out
    }
}

/// One `<DIM><len>` segment of the shape part.
fn parse_segment(s: &str, seg: &str) -> Result<(char, usize)> {
    let mut chars = seg.chars();
    let dim = chars
        .next()
        .with_context(|| format!("op spec '{s}': missing '<DIM><len>' after '/'"))?;
    anyhow::ensure!(
        dim.is_ascii_uppercase(),
        "op spec '{s}': each dimension must start with an uppercase letter \
         (L rows, C channels, D head dim)"
    );
    let len_str = chars.as_str();
    let len: usize = len_str
        .parse()
        .map_err(|_| anyhow::anyhow!("op spec '{s}': invalid item length '{len_str}'"))?;
    anyhow::ensure!(len > 0, "op spec '{s}': item length must be positive");
    Ok((dim, len))
}

impl std::fmt::Display for OpSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.op, self.shape())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_paper_specs() {
        for (s, op, dim, len) in [
            ("e2softmax/L256", "e2softmax", 'L', 256),
            ("softmax-exact/L49", "softmax-exact", 'L', 49),
            ("ailayernorm/C768", "ailayernorm", 'C', 768),
            ("layernorm-exact/C768", "layernorm-exact", 'C', 768),
        ] {
            let spec = OpSpec::parse(s).unwrap();
            assert_eq!(spec.op, op);
            assert_eq!(spec.dim, dim);
            assert_eq!(spec.len, len);
            assert!(spec.extra.is_empty());
            // canonical round trip
            assert_eq!(spec.to_string(), s);
            assert_eq!(OpSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn parses_multi_dimensional_pipeline_specs() {
        let spec = OpSpec::parse("attention/L128xD64").unwrap();
        assert_eq!(spec.op, "attention");
        assert_eq!((spec.dim, spec.len), ('L', 128));
        assert_eq!(spec.extra, vec![('D', 64)]);
        assert_eq!(spec.letters(), vec!['L', 'D']);
        assert_eq!(spec.shape(), "L128xD64");
        assert_eq!(spec.to_string(), "attention/L128xD64");
        assert_eq!(OpSpec::parse(&spec.to_string()).unwrap(), spec);
        // arbitrary depth parses (the registry enforces family signatures)
        let deep = OpSpec::parse("x/A1xB2xC3").unwrap();
        assert_eq!(deep.extra, vec![('B', 2), ('C', 3)]);
    }

    #[test]
    fn parses_multi_head_specs_with_h_prefix() {
        for (s, h, l, d) in
            [("attention/H8xL128xD64", 8, 128, 64), ("block/H2xL17xD32", 2, 17, 32)]
        {
            let spec = OpSpec::parse(s).unwrap();
            assert_eq!((spec.dim, spec.len), ('H', h));
            assert_eq!(spec.extra, vec![('L', l), ('D', d)]);
            assert_eq!(spec.letters(), vec!['H', 'L', 'D']);
            // canonical round trip
            assert_eq!(spec.to_string(), s);
            assert_eq!(OpSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn rejects_duplicate_dimension_letters() {
        for bad in ["attention/L128xL64", "attention/L128xD64xD2", "x/A1xB2xA3"] {
            let err = format!("{:#}", OpSpec::parse(bad).unwrap_err());
            assert!(err.contains(&format!("'{bad}'")), "'{bad}' -> {err}");
            assert!(err.contains("duplicate dimension letter"), "'{bad}' -> {err}");
        }
    }

    #[test]
    fn rejects_malformed_specs_naming_the_spec() {
        let bad_specs = [
            "",
            "e2softmax",
            "e2softmax/",
            "/L12",
            "e2softmax/l12",
            "e2softmax/L",
            "e2softmax/Lx",
            "e2softmax/L0",
            "a/b/L4",
            "attention/L128x",
            "attention/L128xd64",
            "attention/L128xD0",
            "attention/xD64",
        ];
        for bad in bad_specs {
            let err = format!("{:#}", OpSpec::parse(bad).unwrap_err());
            assert!(err.contains(&format!("'{bad}'")), "'{bad}' -> {err}");
        }
    }
}
