//! Operator spec strings: the one grammar every layer speaks.
//!
//! A spec names an operator family plus its item shape in a single
//! routable token: `<op>/<DIM><len>`, e.g. `e2softmax/L256`,
//! `softmax-exact/L49`, `ailayernorm/C768`, `layernorm-exact/C768`.
//! `<op>` is the registry family name (no `/`), `<DIM>` is one uppercase
//! dimension letter (by convention `L` for softmax row length, `C` for
//! layernorm channel count), `<len>` is the positive flat f32 item length.
//! The canonical rendering round-trips: `parse(format(spec)) == spec`.

use anyhow::{Context, Result};

/// A parsed operator spec: family name, dimension letter, item length.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpSpec {
    /// Registry family name, e.g. `e2softmax`.
    pub op: String,
    /// Dimension letter the family uses (`L` rows, `C` channels).
    pub dim: char,
    /// Flat f32 length of one item.
    pub len: usize,
}

impl OpSpec {
    /// Parse `<op>/<DIM><len>`.  Every failure names the offending spec —
    /// this string is the user-facing API of `sole serve --ops`.
    pub fn parse(s: &str) -> Result<OpSpec> {
        let (op, shape) = s.rsplit_once('/').with_context(|| {
            format!("op spec '{s}': expected '<op>/<DIM><len>' (e.g. e2softmax/L128)")
        })?;
        anyhow::ensure!(!op.is_empty(), "op spec '{s}': empty op name before '/'");
        anyhow::ensure!(!op.contains('/'), "op spec '{s}': op name must not contain '/'");
        let mut chars = shape.chars();
        let dim = chars
            .next()
            .with_context(|| format!("op spec '{s}': missing '<DIM><len>' after '/'"))?;
        anyhow::ensure!(
            dim.is_ascii_uppercase(),
            "op spec '{s}': shape must start with an uppercase dimension letter \
             (L rows, C channels)"
        );
        let len_str = chars.as_str();
        let len: usize = len_str
            .parse()
            .map_err(|_| anyhow::anyhow!("op spec '{s}': invalid item length '{len_str}'"))?;
        anyhow::ensure!(len > 0, "op spec '{s}': item length must be positive");
        Ok(OpSpec { op: op.to_string(), dim, len })
    }
}

impl std::fmt::Display for OpSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}{}", self.op, self.dim, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_paper_specs() {
        for (s, op, dim, len) in [
            ("e2softmax/L256", "e2softmax", 'L', 256),
            ("softmax-exact/L49", "softmax-exact", 'L', 49),
            ("ailayernorm/C768", "ailayernorm", 'C', 768),
            ("layernorm-exact/C768", "layernorm-exact", 'C', 768),
        ] {
            let spec = OpSpec::parse(s).unwrap();
            assert_eq!(spec.op, op);
            assert_eq!(spec.dim, dim);
            assert_eq!(spec.len, len);
            // canonical round trip
            assert_eq!(spec.to_string(), s);
            assert_eq!(OpSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn rejects_malformed_specs_naming_the_spec() {
        let bad_specs = [
            "",
            "e2softmax",
            "e2softmax/",
            "/L12",
            "e2softmax/l12",
            "e2softmax/L",
            "e2softmax/Lx",
            "e2softmax/L0",
            "a/b/L4",
        ];
        for bad in bad_specs {
            let err = format!("{:#}", OpSpec::parse(bad).unwrap_err());
            assert!(err.contains(&format!("'{bad}'")), "'{bad}' -> {err}");
        }
    }
}
