//! AILayerNorm — Algorithm 2, bit-exact integer model.
//!
//! Stage 1 (statistic calculation): signed codes D_i = (X_i - zp) << a_i
//! accumulate E_x; magnitudes are dynamically compressed, squared via the
//! 16-entry LUT, decompressed by << 4s, PTF-shifted by << 2a, and the
//! reduced sum takes the deferred << 4.  Stage 2 (affine): A = gamma *
//! std_inv, Y = A (D - mu) + B.  Matches `ref.ailayernorm_int`.
//!
//! Two surfaces: `forward_introspect` is the f64 reference with pinned
//! intermediates; `forward_row_f32` / `forward_batch_f32` are the serving
//! kernels — stage 1 accumulates in pure i64 off the 256-entry
//! compress-square table, and stage 2 is a single fused f32 pass over the
//! exactly-centered integer numerator `C D_i - E_x` with the per-row
//! scale `std_inv / C` rounded onto the f32 grid once — no per-element
//! f64 anywhere, and no catastrophic cancellation against a rounded mean.

use super::compress::{compressed_square, COMPRESSED_SQUARE_TABLE};
use super::config::DEFAULT_ZP;
use super::rsqrt::rsqrt_hw;
use crate::simd::Dispatch;

/// Per-row output with the intermediates the golden tests pin.
#[derive(Debug, Clone)]
pub struct AiLayerNormOut {
    pub ex: i64,
    pub ex2: i64,
    pub mean: f64,
    pub std_inv: f64,
    pub y: Vec<f64>,
}

/// AILayerNorm over u8 codes with per-channel PTF factors.
pub struct AiLayerNorm {
    /// Quantization zero point of the input codes.
    pub zp: i64,
    /// Kernel arm for the planar hot paths, chosen once at construction
    /// (DESIGN.md §3.4); `forward_introspect` is always scalar.
    dispatch: Dispatch,
}

impl Default for AiLayerNorm {
    fn default() -> Self {
        AiLayerNorm::new(DEFAULT_ZP)
    }
}

/// Per-batch eligibility of the AVX2 arms, computed once from the shared
/// PTF factors (rows reuse it).  The vector arms assume a u8-grid zero
/// point and PTF shifts that keep every intermediate in-lane; anything
/// wider takes the scalar arm whole.
#[derive(Clone, Copy)]
struct SimdGate {
    /// Stage 1 eligible: AVX2 arm, `zp ∈ [0, 255]`, all `alpha < 16`.
    stats: bool,
    /// Largest PTF shift seen — bounds the stage-2 i32 numerator check.
    max_alpha: u32,
}

impl SimdGate {
    const SCALAR: SimdGate = SimdGate { stats: false, max_alpha: 0 };
}

impl AiLayerNorm {
    /// AILayerNorm with the given zero point, kernel arm auto-detected.
    pub fn new(zp: i64) -> Self {
        Self::with_dispatch(zp, Dispatch::detect())
    }

    /// Construction with an explicit kernel arm (tests and benches pin
    /// arms to compare them); the request is clamped to what this host
    /// can run.
    pub fn with_dispatch(zp: i64, dispatch: Dispatch) -> Self {
        AiLayerNorm { zp, dispatch: dispatch.sanitize() }
    }

    /// The kernel arm the planar hot paths run on.
    pub fn dispatch(&self) -> Dispatch {
        self.dispatch
    }

    fn gate(&self, alpha: &[u8]) -> SimdGate {
        if self.dispatch != Dispatch::Avx2 || !(0..=255).contains(&self.zp) {
            return SimdGate::SCALAR;
        }
        let max_alpha = alpha.iter().fold(0u8, |m, &a| m.max(a)) as u32;
        SimdGate { stats: max_alpha < 16, max_alpha }
    }
    /// Full-introspection forward over one row of C channels.
    pub fn forward_introspect(
        &self,
        codes: &[u8],
        alpha: &[u8],
        gamma: &[f32],
        beta: &[f32],
    ) -> AiLayerNormOut {
        let c = codes.len();
        assert!(c > 0 && alpha.len() == c && gamma.len() == c && beta.len() == c);
        let mut ex: i64 = 0;
        let mut ex2: i64 = 0;
        for i in 0..c {
            let xi = codes[i] as i64 - self.zp;
            let a = alpha[i] as u32;
            ex += xi << a;
            let mag = xi.unsigned_abs().min(255) as u8;
            ex2 += compressed_square(mag) << (2 * a);
        }
        ex2 <<= 4; // deferred common decompress shift
        let var_num = ex2 as i128 * c as i128 - (ex as i128) * (ex as i128);
        let mean = ex as f64 / c as f64;
        let std_inv = if var_num > 0 {
            rsqrt_hw(var_num as u128, (c as u128) * (c as u128))
        } else {
            0.0
        };
        let mut y = Vec::with_capacity(c);
        for i in 0..c {
            let d = ((codes[i] as i64 - self.zp) << alpha[i]) as f64;
            y.push(gamma[i] as f64 * std_inv * (d - mean) + beta[i] as f64);
        }
        AiLayerNormOut { ex, ex2, mean, std_inv, y }
    }

    /// Stage 1 shared by the f32 kernels: pure-i64 accumulation over the
    /// 256-entry compress-square table, then (E_x, std_inv).
    #[inline]
    fn row_stats(&self, codes: &[u8], alpha: &[u8], gate: SimdGate) -> (i64, f64) {
        let c = codes.len();
        let sq_table = &*COMPRESSED_SQUARE_TABLE;
        let (ex, ex2) = if gate.stats {
            // SAFETY: the Avx2 arm only exists after runtime detection
            // (Dispatch::sanitize); the gate proved zp and alpha in-lane.
            unsafe { crate::simd::ln::stats_avx2(self.zp as i32, codes, alpha, sq_table) }
        } else {
            let mut ex: i64 = 0;
            let mut ex2: i64 = 0;
            for (&code, &a) in codes.iter().zip(alpha) {
                let xi = code as i64 - self.zp;
                let a = a as u32;
                ex += xi << a;
                let mag = xi.unsigned_abs().min(255) as usize;
                ex2 += sq_table[mag] << (2 * a);
            }
            (ex, ex2)
        };
        let ex2 = ex2 << 4;
        let var_num = ex2 as i128 * c as i128 - (ex as i128) * (ex as i128);
        let std_inv = if var_num > 0 {
            rsqrt_hw(var_num as u128, (c as u128) * (c as u128))
        } else {
            0.0
        };
        (ex, std_inv)
    }

    /// The fused stage-2 kernel behind both f32 entry points: one f32 pass
    /// `y_i = (gamma_i * std_inv / C) * (C D_i - E_x) + beta_i`, no
    /// per-element f64.  `C (D_i - mu) = C D_i - E_x` is computed *exactly*
    /// in i64 — unlike subtracting an f32-rounded mean, the centering has
    /// no cancellation error even for near-constant rows with a large
    /// common-mode offset (and stays exact through the f32 conversion
    /// while `|C D_i - E_x| < 2^24`, which covers the paper shapes).
    #[allow(clippy::too_many_arguments)] // one row's planes plus the hoisted per-batch gate
    fn row_kernel(
        &self,
        codes: &[u8],
        alpha: &[u8],
        gamma: &[f32],
        beta: &[f32],
        out: &mut [f32],
        gate: SimdGate,
    ) {
        let c = codes.len();
        let (ex, std_inv) = self.row_stats(codes, alpha, gate);
        let si_over_c = (std_inv / c as f64) as f32;
        let zp = self.zp;
        // The vector stage 2 builds C·D_i - E_x in i32 lanes; prove the
        // whole row fits (|D_i| <= 255 << max_alpha by the stage-1 gate).
        let num_bound =
            (c as i64).saturating_mul(255i64 << gate.max_alpha).saturating_add(ex.abs());
        if gate.stats && num_bound <= i32::MAX as i64 {
            // SAFETY: detected arm; the bound above keeps every lane exact.
            unsafe {
                crate::simd::ln::stage2_avx2(
                    zp as i32, c as i32, ex as i32, si_over_c, codes, alpha, gamma, beta, out,
                );
            }
            return;
        }
        for i in 0..c {
            let d = (codes[i] as i64 - zp) << alpha[i];
            let num = d * c as i64 - ex;
            out[i] = gamma[i] * si_over_c * num as f32 + beta[i];
        }
    }

    /// Hot path: writes f32 outputs into `out`, no allocation.
    pub fn forward_row_f32(
        &self,
        codes: &[u8],
        alpha: &[u8],
        gamma: &[f32],
        beta: &[f32],
        out: &mut [f32],
    ) {
        let c = codes.len();
        debug_assert!(c > 0 && out.len() == c && alpha.len() == c);
        let gate = self.gate(alpha);
        self.row_kernel(codes, alpha, gamma, beta, out, gate);
    }

    /// Batch hot path: `codes` is a packed planar batch of rows, each
    /// `alpha.len()` channels sharing the per-channel parameters; one call,
    /// no allocation.  Bit-exact to per-row `forward_row_f32` (the rows go
    /// through the same kernel).
    pub fn forward_batch_f32(
        &self,
        codes: &[u8],
        alpha: &[u8],
        gamma: &[f32],
        beta: &[f32],
        out: &mut [f32],
    ) {
        let c = alpha.len();
        assert!(c > 0, "layernorm rows must be non-empty");
        assert!(
            gamma.len() == c && beta.len() == c,
            "affine parameter lengths must match {c} channels"
        );
        assert!(codes.len() % c == 0, "packed batch len {} is not a multiple of {c}", codes.len());
        assert!(codes.len() == out.len(), "out len {} != batch len {}", out.len(), codes.len());
        let gate = self.gate(alpha); // one alpha scan for the whole batch
        for (row, row_out) in codes.chunks_exact(c).zip(out.chunks_exact_mut(c)) {
            self.row_kernel(row, alpha, gamma, beta, row_out, gate);
        }
    }

    /// Batch hot path with a quantized output (the op layer's `PtfU8`
    /// port, `ops/port.rs`): each row is normalized by the same fused
    /// `row_kernel` as `forward_batch_f32`, then collapsed to u8 codes
    /// with one per-row scale by `quant::q8_quantize_row_into` — what the
    /// paper's datapath stores between blocks instead of f32.  `row` is a
    /// reusable f32 scratch (resized to one row, capacity ratchets);
    /// `out_codes` gets one code per input element and `out_scale` one
    /// scale per row.  Dequantizing with `quant::q8_dequantize` is
    /// bit-identical to quantize-roundtripping `forward_batch_f32`'s
    /// output row by row through the same codec.
    #[allow(clippy::too_many_arguments)] // mirrors forward_batch_f32 plus the split quantized output planes
    pub fn forward_batch_q8(
        &self,
        codes: &[u8],
        alpha: &[u8],
        gamma: &[f32],
        beta: &[f32],
        row: &mut Vec<f32>,
        out_codes: &mut [u8],
        out_scale: &mut [f32],
    ) {
        let c = alpha.len();
        assert!(c > 0, "layernorm rows must be non-empty");
        assert!(
            gamma.len() == c && beta.len() == c,
            "affine parameter lengths must match {c} channels"
        );
        assert!(codes.len() % c == 0, "packed batch len {} is not a multiple of {c}", codes.len());
        assert!(
            out_codes.len() == codes.len(),
            "out codes len {} != batch len {}",
            out_codes.len(),
            codes.len()
        );
        let rows = codes.len() / c;
        assert!(
            out_scale.len() == rows,
            "out scale len {} != {rows} rows",
            out_scale.len()
        );
        row.resize(c, 0.0);
        let gate = self.gate(alpha); // one alpha scan for the whole batch
        for ((in_row, out_row), scale) in codes
            .chunks_exact(c)
            .zip(out_codes.chunks_exact_mut(c))
            .zip(out_scale.iter_mut())
        {
            self.row_kernel(in_row, alpha, gamma, beta, row, gate);
            *scale = crate::quant::q8_quantize_row_into(row, out_row);
        }
    }

    /// Quantize a real-valued row with PTF (scale s * 2^alpha, zp) and run.
    pub fn forward_real(
        &self,
        x: &[f32],
        alpha: &[u8],
        s: f64,
        gamma: &[f32],
        beta: &[f32],
    ) -> Vec<f64> {
        let codes: Vec<u8> = x
            .iter()
            .zip(alpha)
            .map(|(&v, &a)| {
                let scale = s * 2f64.powi(a as i32);
                ((v as f64 / scale).round() as i64 + self.zp).clamp(0, 255) as u8
            })
            .collect();
        self.forward_introspect(&codes, alpha, gamma, beta).y
    }
}

/// Exact f64 LayerNorm baseline.
pub fn layernorm_exact(x: &[f32], gamma: &[f32], beta: &[f32], eps: f64) -> Vec<f64> {
    let c = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / c;
    let var = x.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>() / c;
    let inv = 1.0 / (var + eps).sqrt();
    x.iter()
        .zip(gamma.iter().zip(beta))
        .map(|(&v, (&g, &b))| g as f64 * (v as f64 - mean) * inv + b as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, size};
    use crate::util::rng::Rng;

    #[test]
    fn constant_row_gives_beta() {
        let c = 32;
        let ln = AiLayerNorm::default();
        let alpha = vec![0u8; c];
        let gamma = vec![1f32; c];
        let beta = vec![0.25f32; c];
        // codes == zp: ex = ex2 = 0 -> std_inv = 0 -> y = beta
        let o = ln.forward_introspect(&vec![128u8; c], &alpha, &gamma, &beta);
        assert_eq!(o.std_inv, 0.0);
        // constant but nonzero deviation: the rounded compression sees a
        // positive pseudo-variance, but D - mean = 0 still gives y = beta
        let o = ln.forward_introspect(&vec![130u8; c], &alpha, &gamma, &beta);
        for v in o.y {
            assert!((v - 0.25).abs() < 1e-9);
        }
        // the fused f32 kernel agrees on both degenerate rows
        let mut out = vec![0f32; c];
        ln.forward_row_f32(&vec![128u8; c], &alpha, &gamma, &beta, &mut out);
        assert!(out.iter().all(|&v| v == 0.25));
        ln.forward_row_f32(&vec![130u8; c], &alpha, &gamma, &beta, &mut out);
        assert!(out.iter().all(|&v| (v - 0.25).abs() < 1e-6));
    }

    #[test]
    fn mean_of_output_near_zero() {
        check("ai-centered", 60, 61, |rng| {
            let c = size(rng, 256).max(8);
            let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
            let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
            let gamma = vec![1f32; c];
            let beta = vec![0f32; c];
            let o = AiLayerNorm::default().forward_introspect(&codes, &alpha, &gamma, &beta);
            if o.std_inv > 0.0 {
                let m: f64 = o.y.iter().sum::<f64>() / c as f64;
                assert!(m.abs() < 0.05, "mean {m}");
            }
        });
    }

    #[test]
    fn output_std_near_one_for_spread_inputs() {
        let mut rng = Rng::new(3);
        let c = 192;
        let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha = vec![0u8; c];
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let o = AiLayerNorm::default().forward_introspect(&codes, &alpha, &gamma, &beta);
        let m: f64 = o.y.iter().sum::<f64>() / c as f64;
        let sd = (o.y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / c as f64).sqrt();
        assert!((sd - 1.0).abs() < 0.05, "sd {sd}");
    }

    #[test]
    fn tracks_exact_layernorm() {
        let mut rng = Rng::new(7);
        let c = 128;
        // inter-channel variation: a few channels 6x larger
        let x: Vec<f32> = (0..c)
            .map(|i| (rng.normal() * if i % 13 == 0 { 6.0 } else { 1.0 }) as f32)
            .collect();
        let r_max = x.iter().map(|v| v.abs()).fold(0f32, f32::max) as f64;
        let base = x.iter().map(|v| v.abs() as f64).fold(f64::INFINITY, f64::min).max(r_max / 32.0);
        let alpha: Vec<u8> = x
            .iter()
            .map(|v| ((v.abs() as f64 / base).log2().round().clamp(0.0, 5.0)) as u8)
            .collect();
        let s = x
            .iter()
            .zip(&alpha)
            .map(|(v, &a)| v.abs() as f64 / 2f64.powi(a as i32))
            .fold(0.0, f64::max)
            / 127.0;
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let approx = AiLayerNorm::default().forward_real(&x, &alpha, s, &gamma, &beta);
        let exact = layernorm_exact(&x, &gamma, &beta, 1e-9);
        let rms_e: f64 = exact.iter().map(|v| v * v).sum::<f64>().sqrt();
        let rms_d: f64 =
            approx.iter().zip(&exact).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(rms_d / rms_e < 0.25, "rel rms {}", rms_d / rms_e);
    }

    #[test]
    fn hot_path_matches_introspect() {
        // the fused stage 2 centers exactly in i64 but rounds the per-row
        // scale std_inv/C onto the f32 grid, so the agreement bound is a
        // few f32 ulps of the affine term rather than the old cast-only
        // 1e-5; 1e-4 scaled by the output magnitude covers every shape
        check("ai-hotpath", 50, 71, |rng| {
            let c = size(rng, 384).max(4);
            let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
            let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 6) as u8).collect();
            let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.2 * rng.normal() as f32).collect();
            let beta: Vec<f32> = (0..c).map(|_| 0.2 * rng.normal() as f32).collect();
            let ln = AiLayerNorm::default();
            let gold = ln.forward_introspect(&codes, &alpha, &gamma, &beta);
            let mut out = vec![0f32; c];
            ln.forward_row_f32(&codes, &alpha, &gamma, &beta, &mut out);
            for (i, (a, b)) in out.iter().zip(&gold.y).enumerate() {
                let tol = 1e-4 * (1.0 + b.abs());
                assert!((*a as f64 - b).abs() < tol, "i={i} {a} vs {b}");
            }
        });
    }

    #[test]
    fn hot_path_exact_centering_on_offset_rows() {
        // near-constant rows with a large common-mode offset: the regime
        // where subtracting an f32-rounded mean would catastrophically
        // cancel (|mu| >> sigma).  The exact integer numerator keeps the
        // kernel tight against the f64 introspection here too.
        for &(c, a) in &[(768usize, 0u8), (768, 3), (192, 5)] {
            let mut codes = vec![200u8; c];
            codes[c / 2] = 201;
            let alpha = vec![a; c];
            let gamma = vec![1f32; c];
            let beta = vec![0.5f32; c];
            let ln = AiLayerNorm::default();
            let gold = ln.forward_introspect(&codes, &alpha, &gamma, &beta);
            let mut out = vec![0f32; c];
            ln.forward_row_f32(&codes, &alpha, &gamma, &beta, &mut out);
            for (i, (o, g)) in out.iter().zip(&gold.y).enumerate() {
                let tol = 1e-4 * (1.0 + g.abs());
                assert!((*o as f64 - g).abs() < tol, "c={c} a={a} i={i}: {o} vs {g}");
            }
        }
    }

    #[test]
    fn batch_q8_is_the_f32_batch_through_the_row_codec() {
        // the PtfU8 out-port contract: forward_batch_q8 == forward_batch_f32
        // followed by q8_quantize_row_into per row, bit for bit
        let mut rng = Rng::new(53);
        let c = 96;
        let b = 5;
        let codes: Vec<u8> = (0..b * c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 5) as u8).collect();
        let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let beta: Vec<f32> = (0..c).map(|_| 0.3 * rng.normal() as f32).collect();
        let ln = AiLayerNorm::default();
        let mut f32_out = vec![0f32; b * c];
        ln.forward_batch_f32(&codes, &alpha, &gamma, &beta, &mut f32_out);
        let mut q8_codes = vec![0u8; b * c];
        let mut q8_scale = vec![0f32; b];
        let mut row = Vec::new();
        ln.forward_batch_q8(&codes, &alpha, &gamma, &beta, &mut row, &mut q8_codes, &mut q8_scale);
        let mut want_codes = vec![0u8; c];
        for r in 0..b {
            let want_scale =
                crate::quant::q8_quantize_row_into(&f32_out[r * c..(r + 1) * c], &mut want_codes);
            assert_eq!(q8_scale[r].to_bits(), want_scale.to_bits(), "row {r} scale");
            assert_eq!(&q8_codes[r * c..(r + 1) * c], &want_codes[..], "row {r} codes");
        }
        // scratch reuse across a second call stays deterministic
        let first = (q8_codes.clone(), q8_scale.clone());
        ln.forward_batch_q8(&codes, &alpha, &gamma, &beta, &mut row, &mut q8_codes, &mut q8_scale);
        assert_eq!((q8_codes, q8_scale), first);
    }

    #[test]
    fn batch_matches_rows_bitwise() {
        let mut rng = Rng::new(43);
        let c = 192;
        let b = 6;
        let codes: Vec<u8> = (0..b * c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 5) as u8).collect();
        let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let beta: Vec<f32> = (0..c).map(|_| 0.3 * rng.normal() as f32).collect();
        let ln = AiLayerNorm::default();
        let mut batch_out = vec![0f32; b * c];
        ln.forward_batch_f32(&codes, &alpha, &gamma, &beta, &mut batch_out);
        let mut row_out = vec![0f32; c];
        for r in 0..b {
            ln.forward_row_f32(&codes[r * c..(r + 1) * c], &alpha, &gamma, &beta, &mut row_out);
            for (i, (&a, &w)) in batch_out[r * c..(r + 1) * c].iter().zip(&row_out).enumerate() {
                assert_eq!(a.to_bits(), w.to_bits(), "row {r} ch {i}");
            }
        }
    }
}
