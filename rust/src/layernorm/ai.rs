//! AILayerNorm — Algorithm 2, bit-exact integer model.
//!
//! Stage 1 (statistic calculation): signed codes D_i = (X_i - zp) << a_i
//! accumulate E_x; magnitudes are dynamically compressed, squared via the
//! 16-entry LUT, decompressed by << 4s, PTF-shifted by << 2a, and the
//! reduced sum takes the deferred << 4.  Stage 2 (affine): A = gamma *
//! std_inv, Y = A (D - mu) + B.  Matches `ref.ailayernorm_int`.

use super::compress::{compressed_square, COMPRESSED_SQUARE_TABLE};
use super::config::DEFAULT_ZP;
use super::rsqrt::rsqrt_hw;

/// Per-row output with the intermediates the golden tests pin.
#[derive(Debug, Clone)]
pub struct AiLayerNormOut {
    pub ex: i64,
    pub ex2: i64,
    pub mean: f64,
    pub std_inv: f64,
    pub y: Vec<f64>,
}

/// AILayerNorm over u8 codes with per-channel PTF factors.
pub struct AiLayerNorm {
    pub zp: i64,
}

impl Default for AiLayerNorm {
    fn default() -> Self {
        AiLayerNorm { zp: DEFAULT_ZP }
    }
}

impl AiLayerNorm {
    /// Full-introspection forward over one row of C channels.
    pub fn forward_introspect(
        &self,
        codes: &[u8],
        alpha: &[u8],
        gamma: &[f32],
        beta: &[f32],
    ) -> AiLayerNormOut {
        let c = codes.len();
        assert!(c > 0 && alpha.len() == c && gamma.len() == c && beta.len() == c);
        let mut ex: i64 = 0;
        let mut ex2: i64 = 0;
        for i in 0..c {
            let xi = codes[i] as i64 - self.zp;
            let a = alpha[i] as u32;
            ex += xi << a;
            let mag = xi.unsigned_abs().min(255) as u8;
            ex2 += compressed_square(mag) << (2 * a);
        }
        ex2 <<= 4; // deferred common decompress shift
        let var_num = ex2 as i128 * c as i128 - (ex as i128) * (ex as i128);
        let mean = ex as f64 / c as f64;
        let std_inv = if var_num > 0 {
            rsqrt_hw(var_num as u128, (c as u128) * (c as u128))
        } else {
            0.0
        };
        let mut y = Vec::with_capacity(c);
        for i in 0..c {
            let d = ((codes[i] as i64 - self.zp) << alpha[i]) as f64;
            y.push(gamma[i] as f64 * std_inv * (d - mean) + beta[i] as f64);
        }
        AiLayerNormOut { ex, ex2, mean, std_inv, y }
    }

    /// Hot path: writes f32 outputs into `out`, no allocation.
    pub fn forward_row_f32(
        &self,
        codes: &[u8],
        alpha: &[u8],
        gamma: &[f32],
        beta: &[f32],
        out: &mut [f32],
    ) {
        let c = codes.len();
        debug_assert!(out.len() == c && alpha.len() == c);
        let sq_table = &*COMPRESSED_SQUARE_TABLE;
        let mut ex: i64 = 0;
        let mut ex2: i64 = 0;
        for i in 0..c {
            let xi = codes[i] as i64 - self.zp;
            let a = alpha[i] as u32;
            ex += xi << a;
            let mag = xi.unsigned_abs().min(255) as usize;
            ex2 += sq_table[mag] << (2 * a);
        }
        ex2 <<= 4;
        let var_num = ex2 as i128 * c as i128 - (ex as i128) * (ex as i128);
        let mean = ex as f64 / c as f64;
        let std_inv = if var_num > 0 {
            rsqrt_hw(var_num as u128, (c as u128) * (c as u128))
        } else {
            0.0
        };
        for i in 0..c {
            let d = ((codes[i] as i64 - self.zp) << alpha[i]) as f64;
            out[i] = (gamma[i] as f64 * std_inv * (d - mean) + beta[i] as f64) as f32;
        }
    }

    /// Quantize a real-valued row with PTF (scale s * 2^alpha, zp) and run.
    pub fn forward_real(
        &self,
        x: &[f32],
        alpha: &[u8],
        s: f64,
        gamma: &[f32],
        beta: &[f32],
    ) -> Vec<f64> {
        let codes: Vec<u8> = x
            .iter()
            .zip(alpha)
            .map(|(&v, &a)| {
                let scale = s * 2f64.powi(a as i32);
                ((v as f64 / scale).round() as i64 + self.zp).clamp(0, 255) as u8
            })
            .collect();
        self.forward_introspect(&codes, alpha, gamma, beta).y
    }
}

/// Exact f64 LayerNorm baseline.
pub fn layernorm_exact(x: &[f32], gamma: &[f32], beta: &[f32], eps: f64) -> Vec<f64> {
    let c = x.len() as f64;
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / c;
    let var = x.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>() / c;
    let inv = 1.0 / (var + eps).sqrt();
    x.iter()
        .zip(gamma.iter().zip(beta))
        .map(|(&v, (&g, &b))| g as f64 * (v as f64 - mean) * inv + b as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, size};
    use crate::util::rng::Rng;

    #[test]
    fn constant_row_gives_beta() {
        let c = 32;
        let ln = AiLayerNorm::default();
        let alpha = vec![0u8; c];
        let gamma = vec![1f32; c];
        let beta = vec![0.25f32; c];
        // codes == zp: ex = ex2 = 0 -> std_inv = 0 -> y = beta
        let o = ln.forward_introspect(&vec![128u8; c], &alpha, &gamma, &beta);
        assert_eq!(o.std_inv, 0.0);
        // constant but nonzero deviation: the rounded compression sees a
        // positive pseudo-variance, but D - mean = 0 still gives y = beta
        let o = ln.forward_introspect(&vec![130u8; c], &alpha, &gamma, &beta);
        for v in o.y {
            assert!((v - 0.25).abs() < 1e-9);
        }
    }

    #[test]
    fn mean_of_output_near_zero() {
        check("ai-centered", 60, 61, |rng| {
            let c = size(rng, 256).max(8);
            let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
            let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 4) as u8).collect();
            let gamma = vec![1f32; c];
            let beta = vec![0f32; c];
            let o = AiLayerNorm::default().forward_introspect(&codes, &alpha, &gamma, &beta);
            if o.std_inv > 0.0 {
                let m: f64 = o.y.iter().sum::<f64>() / c as f64;
                assert!(m.abs() < 0.05, "mean {m}");
            }
        });
    }

    #[test]
    fn output_std_near_one_for_spread_inputs() {
        let mut rng = Rng::new(3);
        let c = 192;
        let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
        let alpha = vec![0u8; c];
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let o = AiLayerNorm::default().forward_introspect(&codes, &alpha, &gamma, &beta);
        let m: f64 = o.y.iter().sum::<f64>() / c as f64;
        let sd = (o.y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / c as f64).sqrt();
        assert!((sd - 1.0).abs() < 0.05, "sd {sd}");
    }

    #[test]
    fn tracks_exact_layernorm() {
        let mut rng = Rng::new(7);
        let c = 128;
        // inter-channel variation: a few channels 6x larger
        let x: Vec<f32> = (0..c)
            .map(|i| (rng.normal() * if i % 13 == 0 { 6.0 } else { 1.0 }) as f32)
            .collect();
        let r_max = x.iter().map(|v| v.abs()).fold(0f32, f32::max) as f64;
        let base = x.iter().map(|v| v.abs() as f64).fold(f64::INFINITY, f64::min).max(r_max / 32.0);
        let alpha: Vec<u8> = x
            .iter()
            .map(|v| ((v.abs() as f64 / base).log2().round().clamp(0.0, 5.0)) as u8)
            .collect();
        let s = x
            .iter()
            .zip(&alpha)
            .map(|(v, &a)| v.abs() as f64 / 2f64.powi(a as i32))
            .fold(0.0, f64::max)
            / 127.0;
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let approx = AiLayerNorm::default().forward_real(&x, &alpha, s, &gamma, &beta);
        let exact = layernorm_exact(&x, &gamma, &beta, 1e-9);
        let rms_e: f64 = exact.iter().map(|v| v * v).sum::<f64>().sqrt();
        let rms_d: f64 =
            approx.iter().zip(&exact).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(rms_d / rms_e < 0.25, "rel rms {}", rms_d / rms_e);
    }

    #[test]
    fn hot_path_matches_introspect() {
        check("ai-hotpath", 50, 71, |rng| {
            let c = size(rng, 384).max(4);
            let codes: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 256) as u8).collect();
            let alpha: Vec<u8> = (0..c).map(|_| rng.range_i64(0, 6) as u8).collect();
            let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.2 * rng.normal() as f32).collect();
            let beta: Vec<f32> = (0..c).map(|_| 0.2 * rng.normal() as f32).collect();
            let ln = AiLayerNorm::default();
            let gold = ln.forward_introspect(&codes, &alpha, &gamma, &beta);
            let mut out = vec![0f32; c];
            ln.forward_row_f32(&codes, &alpha, &gamma, &beta, &mut out);
            for (a, b) in out.iter().zip(&gold.y) {
                assert!((*a as f64 - b).abs() < 1e-5);
            }
        });
    }
}
