//! The x^-0.5 Unit: a 64-entry Q(.16) LUT over the normalized mantissa
//! v in [1,4) plus a power-of-four shift.  Bit-exact twin of
//! `ref.rsqrt_hw` (exact-rational normalization).

use std::sync::OnceLock;

use super::config::{RSQRT_LUT_BITS, RSQRT_LUT_Q};

/// The LUT contents: round(2^16 / sqrt(1 + (i + 0.5) * 3/64)).
pub fn rsqrt_lut() -> &'static [i64; 64] {
    static LUT: OnceLock<[i64; 64]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0i64; 64];
        for (i, slot) in t.iter_mut().enumerate() {
            let v = 1.0 + (i as f64 + 0.5) * 3.0 / (1u64 << RSQRT_LUT_BITS) as f64;
            *slot = ((1u64 << RSQRT_LUT_Q) as f64 / v.sqrt()).round() as i64;
        }
        t
    })
}

/// Public alias used in docs/tests.
pub static RSQRT_LUT: fn() -> &'static [i64; 64] = rsqrt_lut;

/// Hardware x^-0.5 of the exact rational var = num/den (> 0):
/// normalize to 4^k * v with v in [1,4), LUT the mantissa, shift by k.
pub fn rsqrt_hw(var_num: u128, var_den: u128) -> f64 {
    assert!(var_num > 0 && var_den > 0);
    let mut k: i32 = 0;
    let mut num = var_num;
    let mut den = var_den;
    while num >= 4 * den {
        den *= 4;
        k += 1;
    }
    while num < den {
        num *= 4;
        k -= 1;
    }
    // v = var/4^k in [1,4); index floor((v-1) * 64/3)
    let idx = (((num - den) << RSQRT_LUT_BITS) / (3 * den)) as usize;
    let idx = idx.min((1 << RSQRT_LUT_BITS) - 1);
    rsqrt_lut()[idx] as f64 / (1u64 << RSQRT_LUT_Q) as f64 * 2f64.powi(-k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn lut_is_monotone_decreasing() {
        let lut = rsqrt_lut();
        for w in lut.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(lut[0] <= 1 << 16); // 1/sqrt(1+eps) < 1
    }

    #[test]
    fn exact_at_powers_of_four() {
        // var = 4^k exactly normalizes to v = 1 (bucket 0)
        for k in -3i32..6 {
            let (num, den) = if k >= 0 { (4u128.pow(k as u32), 1u128) } else { (1u128, 4u128.pow(-k as u32)) };
            let got = rsqrt_hw(num, den);
            let exact = 2f64.powi(-k);
            assert!((got / exact - 1.0).abs() < 0.012, "k={k}");
        }
    }

    #[test]
    fn relative_error_below_lut_bound() {
        check("rsqrt-bound", 400, 51, |rng| {
            let num = rng.range_i64(1, 1 << 40) as u128;
            let den = rng.range_i64(1, 1 << 20) as u128;
            let got = rsqrt_hw(num, den);
            let exact = 1.0 / ((num as f64 / den as f64).sqrt());
            let rel = (got / exact - 1.0).abs();
            assert!(rel < 0.012, "num={num} den={den} rel={rel}");
        });
    }
}
