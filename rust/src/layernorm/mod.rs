//! LayerNorm algorithms: the paper's AILayerNorm (bit-exact integer model
//! of Algorithm 2), the exact baseline, and the I-BERT/NN-LUT integer
//! comparator.

pub mod ai;
pub mod baselines;
pub mod compress;
pub mod rsqrt;

pub use ai::{AiLayerNorm, AiLayerNormOut};
pub use compress::{dynamic_compress, square_lut, SQUARE_LUT};
pub use rsqrt::{rsqrt_hw, RSQRT_LUT};

/// Contract constants shared with python/compile/kernels/ref.py.
pub mod config {
    /// 64-entry x^-0.5 LUT.
    pub const RSQRT_LUT_BITS: u32 = 6;
    /// Q(.16) LUT entries.
    pub const RSQRT_LUT_Q: u32 = 16;
    /// Layer-wise zero point (u8 symmetric).
    pub const DEFAULT_ZP: i64 = 128;
}
