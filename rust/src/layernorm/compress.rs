//! Dynamic compression — Eq. (15) region of Algorithm 2.
//!
//! 8-bit magnitude -> 4-bit code + 1-bit shift select; recovery is
//! x ~ y << (2 + 2s).  The square then needs only the 16-entry LUT plus a
//! decompress shift — this is what removes the 12-bit multiplier from the
//! statistic path.

/// The 16-entry square LUT (y^2 for y in 0..16) — in hardware a ROM.
pub const SQUARE_LUT: [i64; 16] =
    [0, 1, 4, 9, 16, 25, 36, 49, 64, 81, 100, 121, 144, 169, 196, 225];

#[inline]
pub fn square_lut(y: u8) -> i64 {
    SQUARE_LUT[y as usize]
}

/// DynamicCompress(x): (y, s) with y in [0,15], s in {0,1}.
/// x >= 64 keeps the top nibble (s=1, shift 4); smaller values keep bits
/// [5:2] (s=0, shift 2).  Rounding is to-nearest (half-LSB carry before
/// the bit-select): truncation would bias E(x^2) by ~8% while the paper
/// claims ~0.2% — only the rounding variant meets that, at the cost of a
/// carry adder.
#[inline]
pub fn dynamic_compress(x: u8) -> (u8, u8) {
    if x >= 64 {
        ((((x as u16 + 8) >> 4) as u8).min(15), 1)
    } else {
        ((((x as u16 + 2) >> 2) as u8).min(15), 0)
    }
}

/// Compressed square with decompression shift applied (the `<< 4` common
/// factor is deferred to the reduced sum — DESIGN.md §2 erratum note):
/// returns y^2 << (4 s) ~ x^2 >> 4.
#[inline]
pub fn compressed_square(x: u8) -> i64 {
    let (y, s) = dynamic_compress(x);
    square_lut(y) << (4 * s)
}

/// Software hot path: the full 256-entry compress->square->decompress map,
/// precomputed (a pure function of the 8-bit magnitude).  Semantically
/// identical to `compressed_square` (tested); the hardware keeps the
/// 16-entry LUT, this table exists only so the L3 software service isn't
/// artificially slow.
pub static COMPRESSED_SQUARE_TABLE: std::sync::LazyLock<[i64; 256]> =
    std::sync::LazyLock::new(|| {
        let mut t = [0i64; 256];
        for (x, slot) in t.iter_mut().enumerate() {
            *slot = compressed_square(x as u8);
        }
        t
    });

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn reconstruction_within_half_lsb() {
        for x in 0u8..=255 {
            let (y, s) = dynamic_compress(x);
            assert!(y <= 15);
            let rec = (y as i64) << (2 + 2 * s);
            let lsb = 1i64 << (2 + 2 * s);
            // round-to-nearest: |x - rec| <= lsb/2, except where y clamps
            // at 15 (x in [62,64) for s=0, x >= 248 for s=1)
            let clamped = (s == 0 && x >= 62) || (s == 1 && x >= 248);
            let bound = if clamped { lsb } else { lsb / 2 };
            assert!(((x as i64) - rec).abs() <= bound, "x={x} rec={rec}");
        }
    }

    #[test]
    fn boundary_at_64() {
        assert_eq!(dynamic_compress(63), (15, 0)); // min((63+2)>>2, 15)
        assert_eq!(dynamic_compress(64), (4, 1)); // (64+8)>>4
        assert_eq!(dynamic_compress(255), (15, 1)); // clamped
        assert_eq!(dynamic_compress(0), (0, 0));
    }

    #[test]
    fn paper_error_claim_uniform_inputs() {
        // ~0.2% error on E(x^2) and ~0.4% on sigma for uniform u8 data.
        let mut rng = Rng::new(21);
        let n = 200_000;
        let (mut se, mut sr, mut sx) = (0f64, 0f64, 0f64);
        for _ in 0..n {
            let x = rng.range_i64(0, 256) as u8;
            se += (x as f64) * (x as f64);
            sr += (compressed_square(x) << 4) as f64;
            sx += x as f64;
        }
        let (ex2, rx2, ex) = (se / n as f64, sr / n as f64, sx / n as f64);
        let rel = (rx2 - ex2).abs() / ex2;
        assert!(rel < 0.01, "E(x^2) rel err {rel}");
        let sd_t = (ex2 - ex * ex).sqrt();
        let sd_r = (rx2 - ex * ex).max(0.0).sqrt();
        assert!((sd_r - sd_t).abs() / sd_t < 0.015, "sigma err");
    }

    #[test]
    fn table_matches_function() {
        for x in 0u8..=255 {
            assert_eq!(COMPRESSED_SQUARE_TABLE[x as usize], compressed_square(x));
        }
    }

    #[test]
    fn small_values_matter_less() {
        // Eq. (14): the squared-share of a small value is below its linear
        // share, so truncating small x hurts x^2 sums less than x sums.
        let (x1, x2) = (10f64, 100f64);
        assert!(x1 * x1 / (x1 * x1 + x2 * x2) < x1 / (x1 + x2));
    }
}
