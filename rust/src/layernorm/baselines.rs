//! I-BERT integer LayerNorm — the arithmetic core of the NN-LUT baseline
//! unit (NN-LUT replaces the non-linear pieces with NN-learned PWL tables
//! but keeps INT32 statistics; for LayerNorm the dominant cost is the
//! 32-bit multiply per element in the variance — exactly what this model
//! reproduces and what Table III's Statistic Unit row measures).

/// I-BERT LayerNorm over real inputs at quantization `scale`.
pub fn ibert_layernorm(x: &[f32], gamma: &[f32], beta: &[f32], scale: f64) -> Vec<f64> {
    let c = x.len();
    let q: Vec<f64> = x.iter().map(|&v| (v as f64 / scale).floor()).collect();
    let mu = (q.iter().sum::<f64>() / c as f64).floor();
    let var = (q.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / c as f64).floor();
    let std = var.sqrt().floor() + 1.0;
    q.iter()
        .zip(gamma.iter().zip(beta))
        .map(|(&v, (&g, &b))| g as f64 * (v - mu) / std + b as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layernorm::ai::layernorm_exact;
    use crate::util::rng::Rng;

    #[test]
    fn tracks_exact() {
        let mut rng = Rng::new(9);
        let c = 128;
        let x: Vec<f32> = (0..c).map(|_| (rng.normal() * 1.5) as f32).collect();
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let a = ibert_layernorm(&x, &gamma, &beta, 1.0 / 64.0);
        let b = layernorm_exact(&x, &gamma, &beta, 1e-9);
        let rms: f64 = a
            .iter()
            .zip(&b)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f64>()
            .sqrt()
            / (c as f64).sqrt();
        assert!(rms < 0.1, "rms {rms}");
    }
}
