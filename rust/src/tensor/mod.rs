//! Tensor bundle reader/writer — the python<->rust interchange format
//! (see python/compile/tensor_io.py): `<stem>.json` manifest + `<stem>.bin`
//! raw little-endian data.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn from_str(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u8" => DType::U8,
            other => bail!("unsupported dtype {other}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U8 => "u8",
        }
    }
}

/// An in-memory tensor (data always held as the original raw bytes plus a
/// typed view accessor — avoids copies for the PJRT literal path).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn from_f32(name: &str, shape: Vec<usize>, vals: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>().max(1), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { name: name.to_string(), dtype: DType::F32, shape, data }
    }

    pub fn from_i32(name: &str, shape: Vec<usize>, vals: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>().max(1), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { name: name.to_string(), dtype: DType::I32, shape, data }
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("{}: not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("{}: not i32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A named collection of tensors backed by one manifest + blob pair.
#[derive(Debug, Default)]
pub struct Bundle {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Bundle {
    /// Load `<stem>.json` + `<stem>.bin`.
    pub fn load(stem: &Path) -> Result<Bundle> {
        let jpath = stem.with_extension("json");
        let bpath = stem.with_extension("bin");
        let text = fs::read_to_string(&jpath)
            .with_context(|| format!("reading {}", jpath.display()))?;
        let manifest = json::parse(&text).with_context(|| format!("parsing {}", jpath.display()))?;
        let blob = fs::read(&bpath).with_context(|| format!("reading {}", bpath.display()))?;
        let mut tensors = BTreeMap::new();
        let entries = manifest
            .get("tensors")
            .and_then(Json::as_arr)
            .context("manifest missing 'tensors'")?;
        for e in entries {
            let name = e.get_str("name").context("tensor missing name")?.to_string();
            let dtype = DType::from_str(e.get_str("dtype").context("missing dtype")?)?;
            let shape: Vec<usize> = e
                .get_vec_i64("shape")
                .context("missing shape")?
                .into_iter()
                .map(|v| v as usize)
                .collect();
            let offset = e.get_i64("offset").context("missing offset")? as usize;
            let nbytes = e.get_i64("nbytes").context("missing nbytes")? as usize;
            if offset + nbytes > blob.len() {
                bail!("{name}: extent {}..{} beyond blob ({})", offset, offset + nbytes, blob.len());
            }
            let expect = shape.iter().product::<usize>().max(1) * dtype.size();
            if expect != nbytes {
                bail!("{name}: shape {shape:?} x {} != {nbytes} bytes", dtype.size());
            }
            tensors.insert(
                name.clone(),
                Tensor { name, dtype, shape, data: blob[offset..offset + nbytes].to_vec() },
            );
        }
        Ok(Bundle { tensors })
    }

    /// Write `<stem>.json` + `<stem>.bin` (used by tests / the examples).
    pub fn save(&self, stem: &Path) -> Result<()> {
        if let Some(parent) = stem.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut blob = Vec::new();
        let mut entries = Vec::new();
        for t in self.tensors.values() {
            entries.push(json::obj(vec![
                ("name", Json::Str(t.name.clone())),
                ("dtype", Json::Str(t.dtype.name().to_string())),
                ("shape", Json::Arr(t.shape.iter().map(|&d| Json::Int(d as i64)).collect())),
                ("offset", Json::Int(blob.len() as i64)),
                ("nbytes", Json::Int(t.data.len() as i64)),
            ]));
            blob.extend_from_slice(&t.data);
        }
        let manifest = json::obj(vec![
            ("version", Json::Int(1)),
            ("tensors", Json::Arr(entries)),
            ("total_bytes", Json::Int(blob.len() as i64)),
        ]);
        fs::write(stem.with_extension("json"), manifest.to_string_compact())?;
        fs::write(stem.with_extension("bin"), &blob)?;
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("bundle missing tensor '{name}'"))
    }

    pub fn insert(&mut self, t: Tensor) {
        self.tensors.insert(t.name.clone(), t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("sole-tensor-{}", std::process::id()));
        let stem = dir.join("bundle");
        let mut b = Bundle::default();
        b.insert(Tensor::from_f32("a", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        b.insert(Tensor::from_i32("b/c", vec![4], &[-1, 0, 1, 7]));
        b.save(&stem).unwrap();
        let back = Bundle::load(&stem).unwrap();
        assert_eq!(back.get("a").unwrap().as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.get("a").unwrap().shape, vec![2, 3]);
        assert_eq!(back.get("b/c").unwrap().as_i32().unwrap(), vec![-1, 0, 1, 7]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let b = Bundle::default();
        assert!(b.get("nope").is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::from_f32("x", vec![1], &[1.0]);
        assert!(t.as_i32().is_err());
    }
}
