//! # SOLE — Hardware-Software Co-design of Softmax and LayerNorm
//!
//! Full-system reproduction of *SOLE: Hardware-Software Co-design of
//! Softmax and LayerNorm for Efficient Transformer Inference* (Wang et
//! al.) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build-time Python)** — the E2Softmax / AILayerNorm
//!   Pallas kernels and the transformer models that embed them, AOT-lowered
//!   to HLO text under `artifacts/`.
//! * **Layer 3 (this crate)** — the inference coordinator (request router,
//!   dynamic batcher, PJRT runtime), the TCP front door (`server`: wire
//!   protocol, admission control/load shedding, worker rebalancing), the
//!   unified operator layer (`ops`:
//!   one `Op` trait + `OpRegistry` serving SOLE's kernels, the exact
//!   baselines and the prior-work comparators behind spec strings), the
//!   bit-exact integer models of both algorithms, the hardware evaluation
//!   substrate (28nm cost model, cycle-accurate unit models, analytical
//!   GPU baseline), and one experiment generator per table/figure of the
//!   paper.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod coordinator;
pub mod experiments;
pub mod fixedpoint;
pub mod hw;
pub mod layernorm;
pub mod model;
pub mod ops;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod simd;
pub mod softmax;
pub mod tensor;
pub mod util;

/// Crate version, surfaced by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
