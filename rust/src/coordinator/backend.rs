//! Coordinator backends: where a packed batch actually executes.
//!
//! * `PjrtBackend` — the real path: bucketed AOT artifacts through the
//!   PJRT runtime (one `LoadedModel` per batch size).
//! * `SoftwareSoftmaxBackend` — the bit-exact Rust E2Softmax as a
//!   row-service; lets the coordinator be tested and benchmarked without
//!   artifacts, and doubles as the op-offload path of `examples/op_offload`.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{Engine, LoadedModel};
use crate::softmax::{E2Softmax, E2SoftmaxConfig};

/// Executes packed, padded batches at one of the advertised bucket sizes.
pub trait Backend: Send + Sync {
    /// Flat f32 length of one item's input.
    fn item_input_len(&self) -> usize;
    /// Flat f32 length of one item's output.
    fn item_output_len(&self) -> usize;
    /// Available batch sizes, ascending.
    fn buckets(&self) -> &[usize];
    /// Run a `bucket`-sized batch (`inputs.len() == bucket * item_input_len`).
    fn run(&self, bucket: usize, inputs: &[f32]) -> Result<Vec<f32>>;
}

/// Real serving: one compiled artifact per bucket size.
pub struct PjrtBackend {
    models: BTreeMap<usize, Arc<LoadedModel>>,
    buckets: Vec<usize>,
    item_in: usize,
    item_out: usize,
}

impl PjrtBackend {
    /// Load every `<model>_<variant>_b<N>` artifact as a bucket.
    pub fn from_family(engine: &Engine, model: &str, variant: &str) -> Result<PjrtBackend> {
        let ids = engine.find(model, variant);
        anyhow::ensure!(!ids.is_empty(), "no artifacts for {model}/{variant}");
        let mut models = BTreeMap::new();
        for id in &ids {
            let m = engine.load(id)?;
            models.insert(m.batch(), m);
        }
        let buckets: Vec<usize> = models.keys().copied().collect();
        let any = models.values().next().unwrap();
        let item_in = any.meta.input_shape.iter().skip(1).product::<usize>();
        let item_out = any.meta.output_shape.iter().skip(1).product::<usize>();
        Ok(PjrtBackend { models, buckets, item_in, item_out })
    }
}

impl Backend for PjrtBackend {
    fn item_input_len(&self) -> usize {
        self.item_in
    }

    fn item_output_len(&self) -> usize {
        self.item_out
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn run(&self, bucket: usize, inputs: &[f32]) -> Result<Vec<f32>> {
        let m = self
            .models
            .get(&bucket)
            .with_context(|| format!("no artifact for bucket {bucket}"))?;
        m.run_f32(inputs)
    }
}

/// Software op-service: each item is one softmax row of length `l`,
/// computed by the bit-exact E2Softmax hot path.  Any bucket size works.
pub struct SoftwareSoftmaxBackend {
    l: usize,
    buckets: Vec<usize>,
    sm: E2Softmax,
}

impl SoftwareSoftmaxBackend {
    pub fn new(l: usize, mut buckets: Vec<usize>) -> SoftwareSoftmaxBackend {
        buckets.sort_unstable();
        SoftwareSoftmaxBackend { l, buckets, sm: E2Softmax::new(E2SoftmaxConfig::default()) }
    }
}

impl Backend for SoftwareSoftmaxBackend {
    fn item_input_len(&self) -> usize {
        self.l
    }

    fn item_output_len(&self) -> usize {
        self.l
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn run(&self, bucket: usize, inputs: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(inputs.len() == bucket * self.l);
        let mut out = Vec::with_capacity(inputs.len());
        for row in inputs.chunks(self.l) {
            out.extend(self.sm.forward_logits(row).into_iter().map(|v| v as f32));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn software_backend_shapes() {
        let be = SoftwareSoftmaxBackend::new(32, vec![4, 1, 2]);
        assert_eq!(be.buckets(), &[1, 2, 4]);
        let out = be.run(2, &vec![0.5; 64]).unwrap();
        assert_eq!(out.len(), 64);
        // uniform logits -> near-uniform probabilities
        let spread = out.iter().cloned().fold(f32::MIN, f32::max)
            - out.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread < 0.05);
    }

    #[test]
    fn software_backend_rejects_bad_len() {
        let be = SoftwareSoftmaxBackend::new(32, vec![1]);
        assert!(be.run(1, &vec![0.0; 31]).is_err());
    }
}
