//! Coordinator backends: where a packed batch actually executes.
//!
//! * `PjrtBackend` — the real path: bucketed AOT artifacts through the
//!   PJRT runtime (one `LoadedModel` per batch size).
//! * `SoftwareSoftmaxBackend` — the bit-exact Rust E2Softmax as a
//!   row-service: the whole packed batch is quantized in one pass and
//!   executed by one `forward_batch_f32` kernel call.
//! * `SoftwareLayerNormBackend` — the bit-exact AILayerNorm as a
//!   row-service (PTF batch quantization + one `forward_batch_f32` call).
//!
//! Execution is arena-style: the worker owns the packed input buffer, the
//! staged output buffer, and an opaque per-worker scratch created by
//! `Backend::make_scratch`.  A backend writes results into the provided
//! `out` slice and keeps every temporary inside its scratch, so the
//! steady-state batch loop performs no heap allocation — and, since the
//! planar-kernel rewrite, no per-row dispatch either: each `run` is a
//! single batch-kernel invocation over the packed buffer.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::batcher::normalize_buckets;
use crate::layernorm::{config::DEFAULT_ZP, AiLayerNorm};
use crate::quant::{ptf_quantize_batch_into, PtfCalib};
use crate::runtime::{Engine, LoadedModel};
use crate::softmax::e2::{quantize_logits_batch_into, E2Scratch};
use crate::softmax::{E2Softmax, E2SoftmaxConfig};

/// Opaque per-worker scratch arena.  Each worker thread creates one via
/// `Backend::make_scratch` and hands it back on every `run`, so backends
/// can reuse buffers without interior mutability or locks.
pub type BackendScratch = Box<dyn std::any::Any + Send>;

/// Executes packed, padded batches at one of the advertised bucket sizes.
pub trait Backend: Send + Sync {
    /// Flat f32 length of one item's input.
    fn item_input_len(&self) -> usize;
    /// Flat f32 length of one item's output.
    fn item_output_len(&self) -> usize;
    /// Available batch sizes, ascending.
    fn buckets(&self) -> &[usize];

    /// Create the per-worker scratch arena (stateless backends keep the
    /// default).
    fn make_scratch(&self) -> BackendScratch {
        Box::new(())
    }

    /// Run a `bucket`-sized batch: `inputs.len() == bucket * item_input_len`,
    /// writing `bucket * item_output_len` f32s into `out`.  Implementations
    /// must keep every temporary in `scratch` so steady-state execution is
    /// allocation-free.
    fn run(
        &self,
        bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        scratch: &mut BackendScratch,
    ) -> Result<()>;

    /// Convenience wrapper allocating fresh output + scratch (tests and
    /// one-shot callers; the serving hot path never uses this).
    fn run_alloc(&self, bucket: usize, inputs: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; bucket * self.item_output_len()];
        let mut scratch = self.make_scratch();
        self.run(bucket, inputs, &mut out, &mut scratch)?;
        Ok(out)
    }
}

/// Real serving: one compiled artifact per bucket size.
pub struct PjrtBackend {
    models: BTreeMap<usize, Arc<LoadedModel>>,
    buckets: Vec<usize>,
    item_in: usize,
    item_out: usize,
}

impl PjrtBackend {
    /// Load every `<model>_<variant>_b<N>` artifact as a bucket.
    pub fn from_family(engine: &Engine, model: &str, variant: &str) -> Result<PjrtBackend> {
        let ids = engine.find(model, variant);
        anyhow::ensure!(!ids.is_empty(), "no artifacts for {model}/{variant}");
        let mut models = BTreeMap::new();
        for id in &ids {
            let m = engine.load(id)?;
            models.insert(m.batch(), m);
        }
        let buckets = normalize_buckets(models.keys().copied().collect())
            .with_context(|| format!("artifact family {model}/{variant}"))?;
        let any = models.values().next().unwrap();
        let item_in = any.meta.input_shape.iter().skip(1).product::<usize>();
        let item_out = any.meta.output_shape.iter().skip(1).product::<usize>();
        Ok(PjrtBackend { models, buckets, item_in, item_out })
    }
}

impl Backend for PjrtBackend {
    fn item_input_len(&self) -> usize {
        self.item_in
    }

    fn item_output_len(&self) -> usize {
        self.item_out
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn run(
        &self,
        bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        _scratch: &mut BackendScratch,
    ) -> Result<()> {
        let m = self
            .models
            .get(&bucket)
            .with_context(|| format!("no artifact for bucket {bucket}"))?;
        // run-into-caller-buffer path: the output transfer lands directly
        // in the worker's staged arena, no intermediate Vec at this layer
        m.run_f32_into(inputs, out)
    }
}

/// Software op-service: each item is one softmax row of length `l`,
/// computed by the bit-exact E2Softmax batch kernel.  Any bucket size
/// works.
pub struct SoftwareSoftmaxBackend {
    l: usize,
    buckets: Vec<usize>,
    sm: E2Softmax,
}

/// Per-worker arena of the softmax service: the packed logit->code
/// quantization buffer plus the E2Softmax kernel scratch.
struct SoftmaxScratch {
    codes: Vec<i64>,
    e2: E2Scratch,
}

impl SoftwareSoftmaxBackend {
    /// Infallible constructor for known-good configs; panics with the
    /// validation error otherwise (see `try_new`).
    pub fn new(l: usize, buckets: Vec<usize>) -> SoftwareSoftmaxBackend {
        SoftwareSoftmaxBackend::try_new(l, buckets)
            .unwrap_or_else(|e| panic!("invalid SoftwareSoftmaxBackend config: {e}"))
    }

    /// Validating constructor: row length and bucket list are checked here,
    /// on the caller's thread, not later inside a worker's `Batcher::new`.
    pub fn try_new(l: usize, buckets: Vec<usize>) -> Result<SoftwareSoftmaxBackend> {
        anyhow::ensure!(l > 0, "softmax rows must be non-empty");
        let buckets = normalize_buckets(buckets).context("softmax service buckets")?;
        Ok(SoftwareSoftmaxBackend { l, buckets, sm: E2Softmax::new(E2SoftmaxConfig::default()) })
    }
}

impl Backend for SoftwareSoftmaxBackend {
    fn item_input_len(&self) -> usize {
        self.l
    }

    fn item_output_len(&self) -> usize {
        self.l
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn make_scratch(&self) -> BackendScratch {
        Box::new(SoftmaxScratch { codes: Vec::with_capacity(self.l), e2: E2Scratch::default() })
    }

    fn run(
        &self,
        bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        scratch: &mut BackendScratch,
    ) -> Result<()> {
        anyhow::ensure!(inputs.len() == bucket * self.l);
        anyhow::ensure!(out.len() == bucket * self.l);
        let s = scratch
            .downcast_mut::<SoftmaxScratch>()
            .context("softmax backend handed a foreign scratch arena")?;
        // one pass of per-row-max quantization over the packed batch, then
        // one batch-kernel call — no per-row dispatch
        quantize_logits_batch_into(inputs, self.l, self.sm.cfg().e, &mut s.codes);
        self.sm.forward_batch_f32(&s.codes, self.l, out, &mut s.e2);
        Ok(())
    }
}

/// Software op-service for AILayerNorm: each item is one f32 row of `c`
/// channels, PTF-quantized with the backend's calibration and normalized
/// by the bit-exact hot path.
pub struct SoftwareLayerNormBackend {
    c: usize,
    buckets: Vec<usize>,
    ln: AiLayerNorm,
    cal: PtfCalib,
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

/// Per-worker arena of the layernorm service: the packed PTF code buffer.
struct LayerNormScratch {
    codes: Vec<u8>,
}

impl SoftwareLayerNormBackend {
    /// Identity-affine service (alpha = 0, gamma = 1, beta = 0) with a
    /// layer scale that maps roughly N(0, 4) inputs onto the u8 code grid.
    /// Panics with the validation error on a bad bucket list (see
    /// `with_calibration` for the error-returning path).
    pub fn new(c: usize, buckets: Vec<usize>) -> SoftwareLayerNormBackend {
        let cal = PtfCalib { alpha: vec![0u8; c], s: 1.0 / 32.0, zp: DEFAULT_ZP };
        SoftwareLayerNormBackend::with_calibration(c, buckets, cal, vec![1f32; c], vec![0f32; c])
            .unwrap_or_else(|e| panic!("invalid SoftwareLayerNormBackend config: {e}"))
    }

    /// Fully-specified service: a PTF calibration plus affine parameters.
    /// Channel counts and the bucket list are validated here, on the
    /// caller's thread, not later inside a worker's `Batcher::new`.
    pub fn with_calibration(
        c: usize,
        buckets: Vec<usize>,
        cal: PtfCalib,
        gamma: Vec<f32>,
        beta: Vec<f32>,
    ) -> Result<SoftwareLayerNormBackend> {
        anyhow::ensure!(c > 0, "layernorm rows must be non-empty");
        anyhow::ensure!(
            cal.alpha.len() == c && gamma.len() == c && beta.len() == c,
            "calibration lengths must match {c} channels"
        );
        let buckets = normalize_buckets(buckets).context("layernorm service buckets")?;
        let ln = AiLayerNorm { zp: cal.zp };
        Ok(SoftwareLayerNormBackend { c, buckets, ln, cal, gamma, beta })
    }
}

impl Backend for SoftwareLayerNormBackend {
    fn item_input_len(&self) -> usize {
        self.c
    }

    fn item_output_len(&self) -> usize {
        self.c
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn make_scratch(&self) -> BackendScratch {
        Box::new(LayerNormScratch { codes: Vec::with_capacity(self.c) })
    }

    fn run(
        &self,
        bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        scratch: &mut BackendScratch,
    ) -> Result<()> {
        anyhow::ensure!(inputs.len() == bucket * self.c);
        anyhow::ensure!(out.len() == bucket * self.c);
        let s = scratch
            .downcast_mut::<LayerNormScratch>()
            .context("layernorm backend handed a foreign scratch arena")?;
        ptf_quantize_batch_into(inputs, &self.cal, &mut s.codes);
        self.ln.forward_batch_f32(&s.codes, &self.cal.alpha, &self.gamma, &self.beta, out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ptf_quantize_into;

    #[test]
    fn software_backend_shapes() {
        let be = SoftwareSoftmaxBackend::new(32, vec![4, 1, 2]);
        assert_eq!(be.buckets(), &[1, 2, 4]);
        let out = be.run_alloc(2, &vec![0.5; 64]).unwrap();
        assert_eq!(out.len(), 64);
        // uniform logits -> near-uniform probabilities
        let spread = out.iter().cloned().fold(f32::MIN, f32::max)
            - out.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread < 0.05);
    }

    #[test]
    fn software_backend_rejects_bad_len() {
        let be = SoftwareSoftmaxBackend::new(32, vec![1]);
        assert!(be.run_alloc(1, &vec![0.0; 31]).is_err());
    }

    #[test]
    fn constructors_reject_bad_bucket_lists() {
        // empty and zero-sized bucket lists used to slip through and panic
        // later inside Batcher::new on a worker thread; now they fail at
        // construction with a clear error
        assert!(SoftwareSoftmaxBackend::try_new(32, vec![]).is_err());
        let err = SoftwareSoftmaxBackend::try_new(32, vec![4, 0]).unwrap_err();
        assert!(format!("{err:#}").contains("zero"), "{err:#}");
        assert!(SoftwareSoftmaxBackend::try_new(0, vec![1]).is_err());

        let cal = PtfCalib { alpha: vec![0u8; 8], s: 1.0, zp: DEFAULT_ZP };
        assert!(SoftwareLayerNormBackend::with_calibration(
            8,
            vec![],
            cal.clone(),
            vec![1f32; 8],
            vec![0f32; 8]
        )
        .is_err());
        assert!(SoftwareLayerNormBackend::with_calibration(
            8,
            vec![0, 2],
            cal,
            vec![1f32; 8],
            vec![0f32; 8]
        )
        .is_err());
    }

    #[test]
    fn constructors_dedup_and_sort_buckets() {
        let be = SoftwareSoftmaxBackend::try_new(16, vec![8, 1, 8, 4]).unwrap();
        assert_eq!(be.buckets(), &[1, 4, 8]);
        let ln = SoftwareLayerNormBackend::new(16, vec![4, 4, 1]);
        assert_eq!(ln.buckets(), &[1, 4]);
    }

    #[test]
    fn softmax_backend_matches_forward_logits() {
        // the arena hot path must be bit-identical to the reference
        // forward_logits pipeline it replaced
        let l = 48;
        let be = SoftwareSoftmaxBackend::new(l, vec![1, 4]);
        let mut rng = crate::util::rng::Rng::new(3);
        let mut rows = vec![0f32; 4 * l];
        rng.fill_normal(&mut rows, 0.0, 2.0);
        let got = be.run_alloc(4, &rows).unwrap();
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        for r in 0..4 {
            let want: Vec<f32> =
                sm.forward_logits(&rows[r * l..(r + 1) * l]).into_iter().map(|v| v as f32).collect();
            assert_eq!(&got[r * l..(r + 1) * l], &want[..], "row {r}");
        }
    }

    #[test]
    fn softmax_backend_survives_nan_logits() {
        // a NaN-poisoned request must not corrupt its own row beyond the
        // NaN slots (they quantize to the bottom code) nor its batchmates
        let l = 16;
        let be = SoftwareSoftmaxBackend::new(l, vec![2]);
        let mut rows = vec![0.5f32; 2 * l];
        rows[3] = f32::NAN;
        let got = be.run_alloc(2, &rows).unwrap();
        assert!(got.iter().all(|v| v.is_finite()));
        // the clean second row matches a clean single-row run exactly
        let clean = be.run_alloc(2, &vec![0.5f32; 2 * l]).unwrap();
        assert_eq!(&got[l..], &clean[l..]);
        // the NaN slot gets the smallest probability in its row
        assert!(got[3] <= got[0]);
    }

    #[test]
    fn softmax_scratch_reuse_is_stable() {
        // same inputs through one reused scratch arena: identical outputs
        let l = 64;
        let be = SoftwareSoftmaxBackend::new(l, vec![1, 8]);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut rows = vec![0f32; 8 * l];
        rng.fill_normal(&mut rows, 0.0, 1.5);
        let mut scratch = be.make_scratch();
        let mut out1 = vec![0f32; 8 * l];
        let mut out2 = vec![0f32; 8 * l];
        be.run(8, &rows, &mut out1, &mut scratch).unwrap();
        be.run(8, &rows, &mut out2, &mut scratch).unwrap();
        assert_eq!(out1, out2);
    }

    #[test]
    fn layernorm_backend_matches_direct_kernel() {
        let c = 96;
        let be = SoftwareLayerNormBackend::new(c, vec![1, 4]);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut rows = vec![0f32; 4 * c];
        rng.fill_normal(&mut rows, 0.0, 2.0);
        let got = be.run_alloc(4, &rows).unwrap();
        // direct kernel invocation with the same identity calibration
        let cal = PtfCalib { alpha: vec![0u8; c], s: 1.0 / 32.0, zp: DEFAULT_ZP };
        let ln = AiLayerNorm { zp: cal.zp };
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let mut codes = Vec::new();
        let mut want = vec![0f32; c];
        for r in 0..4 {
            ptf_quantize_into(&rows[r * c..(r + 1) * c], &cal, &mut codes);
            ln.forward_row_f32(&codes, &cal.alpha, &gamma, &beta, &mut want);
            assert_eq!(&got[r * c..(r + 1) * c], &want[..], "row {r}");
        }
    }

    #[test]
    fn layernorm_backend_normalizes_rows() {
        let c = 192;
        let be = SoftwareLayerNormBackend::new(c, vec![1]);
        let mut rng = crate::util::rng::Rng::new(11);
        let mut row = vec![0f32; c];
        rng.fill_normal(&mut row, 0.5, 2.0);
        let out = be.run_alloc(1, &row).unwrap();
        let mean: f32 = out.iter().sum::<f32>() / c as f32;
        let sd = (out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32).sqrt();
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((sd - 1.0).abs() < 0.1, "sd {sd}");
    }

    #[test]
    fn layernorm_backend_rejects_mismatched_calibration() {
        let cal = PtfCalib { alpha: vec![0u8; 4], s: 1.0, zp: DEFAULT_ZP };
        assert!(SoftwareLayerNormBackend::with_calibration(
            8,
            vec![1],
            cal,
            vec![1f32; 8],
            vec![0f32; 8]
        )
        .is_err());
    }
}
