//! Coordinator backends: where a packed batch actually executes.
//!
//! * `PjrtBackend` — the real path: bucketed AOT artifacts through the
//!   PJRT runtime (one `LoadedModel` per batch size).
//! * `OpBackend` — the software path: ANY [`Op`] (E2Softmax, AILayerNorm,
//!   the exact baselines, the prior-work comparators — everything the
//!   `OpRegistry` can construct) wrapped with shared bucket validation
//!   and per-worker scratch.  One generic struct serves every software
//!   operator, so a new operator needs zero backend code.
//!
//! Execution is arena-style: the worker owns the packed input buffer, the
//! staged output buffer, and an opaque per-worker scratch created by
//! `Backend::make_scratch`.  A backend writes results into the provided
//! `out` slice and keeps every temporary inside its scratch, so the
//! steady-state batch loop performs no heap allocation — and each `run`
//! is a single batch-kernel invocation over the packed buffer.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::batcher::normalize_buckets;
use crate::ops::{Op, OpRegistry, OpScratch};
use crate::runtime::{Engine, LoadedModel};

/// Opaque per-worker scratch arena.  Each worker thread creates one via
/// `Backend::make_scratch` and hands it back on every `run`, so backends
/// can reuse buffers without interior mutability or locks.
pub type BackendScratch = Box<dyn std::any::Any + Send>;

/// Executes packed, padded batches at one of the advertised bucket sizes.
pub trait Backend: Send + Sync {
    /// Flat f32 length of one item's input.
    fn item_input_len(&self) -> usize;
    /// Flat f32 length of one item's output.
    fn item_output_len(&self) -> usize;
    /// Available batch sizes, ascending.
    fn buckets(&self) -> &[usize];

    /// Create the per-worker scratch arena (stateless backends keep the
    /// default).
    fn make_scratch(&self) -> BackendScratch {
        Box::new(())
    }

    /// Run a `bucket`-sized batch: `inputs.len() == bucket * item_input_len`,
    /// writing `bucket * item_output_len` f32s into `out`.  Implementations
    /// must keep every temporary in `scratch` so steady-state execution is
    /// allocation-free.
    fn run(
        &self,
        bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        scratch: &mut BackendScratch,
    ) -> Result<()>;

    /// Convenience wrapper allocating fresh output + scratch (tests and
    /// one-shot callers; the serving hot path never uses this).
    fn run_alloc(&self, bucket: usize, inputs: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; bucket * self.item_output_len()];
        let mut scratch = self.make_scratch();
        self.run(bucket, inputs, &mut out, &mut scratch)?;
        Ok(out)
    }
}

/// Real serving: one compiled artifact per bucket size.
pub struct PjrtBackend {
    models: BTreeMap<usize, Arc<LoadedModel>>,
    buckets: Vec<usize>,
    item_in: usize,
    item_out: usize,
}

impl PjrtBackend {
    /// Load every `<model>_<variant>_b<N>` artifact as a bucket.
    pub fn from_family(engine: &Engine, model: &str, variant: &str) -> Result<PjrtBackend> {
        let ids = engine.find(model, variant);
        anyhow::ensure!(!ids.is_empty(), "no artifacts for {model}/{variant}");
        let mut models = BTreeMap::new();
        for id in &ids {
            let m = engine.load(id)?;
            models.insert(m.batch(), m);
        }
        let buckets = normalize_buckets(models.keys().copied().collect())
            .with_context(|| format!("artifact family {model}/{variant}"))?;
        let any = models.values().next().unwrap();
        let item_in = any.meta.input_shape.iter().skip(1).product::<usize>();
        let item_out = any.meta.output_shape.iter().skip(1).product::<usize>();
        Ok(PjrtBackend { models, buckets, item_in, item_out })
    }
}

impl Backend for PjrtBackend {
    fn item_input_len(&self) -> usize {
        self.item_in
    }

    fn item_output_len(&self) -> usize {
        self.item_out
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn run(
        &self,
        bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        _scratch: &mut BackendScratch,
    ) -> Result<()> {
        let m = self
            .models
            .get(&bucket)
            .with_context(|| format!("no artifact for bucket {bucket}"))?;
        // run-into-caller-buffer path: the output transfer lands directly
        // in the worker's staged arena, no intermediate Vec at this layer
        m.run_f32_into(inputs, out)
    }
}

/// The generic software op-service: wraps any `Arc<dyn Op>` as a bucketed
/// backend.  Bucket-list validation happens once here (construction
/// time, caller's thread) and the per-batch shape checks are shared —
/// operator implementations only provide the kernel call.
pub struct OpBackend {
    op: Arc<dyn Op>,
    buckets: Vec<usize>,
}

impl OpBackend {
    /// Wrap an op with a validated bucket list.  The only construction
    /// path — there is deliberately no panicking `new`.
    pub fn try_new(op: Arc<dyn Op>, buckets: Vec<usize>) -> Result<OpBackend> {
        anyhow::ensure!(op.item_len() > 0, "op '{}' has an empty item", op.name());
        // stateless workers would silently give every request a fresh
        // (empty) session; stateful ops are served with session affinity
        // by the decode service instead
        anyhow::ensure!(
            !op.stateful(),
            "op '{}' is stateful; serve it through the decode service (sole serve --decode), \
             not a stateless op backend",
            op.name()
        );
        // the serving edge speaks f32 only: an op with a quantized outer
        // port must be wrapped in a PipelineOp, which dequantizes its
        // tail and rejects quantized entry stages
        anyhow::ensure!(
            op.in_port() == crate::ops::PortType::F32
                && op.out_port() == crate::ops::PortType::F32,
            "op '{}' exposes a {} -> {} port pair; router-facing edges are f32 \
             (wrap quantized ports in a PipelineOp)",
            op.name(),
            op.in_port(),
            op.out_port()
        );
        let buckets = normalize_buckets(buckets)
            .with_context(|| format!("op '{}' service buckets", op.name()))?;
        Ok(OpBackend { op, buckets })
    }

    /// Registry path: construct the op named by `spec` and wrap it.
    pub fn from_spec(registry: &OpRegistry, spec: &str, buckets: Vec<usize>) -> Result<OpBackend> {
        let (_, op) = registry.build(spec)?;
        OpBackend::try_new(op, buckets)
    }

    /// The wrapped operator (its `spec()` is the canonical service name).
    pub fn op(&self) -> &Arc<dyn Op> {
        &self.op
    }
}

impl Backend for OpBackend {
    fn item_input_len(&self) -> usize {
        self.op.item_len()
    }

    fn item_output_len(&self) -> usize {
        // pipelines (e.g. attention) consume one shape and produce
        // another; shape-preserving row ops report item_len here
        self.op.out_len()
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn make_scratch(&self) -> BackendScratch {
        // the op's own scratch rides inside the backend-level box; `run`
        // unwraps exactly one layer before handing it to `run_batch`
        Box::new(self.op.make_scratch())
    }

    fn run(
        &self,
        bucket: usize,
        inputs: &[f32],
        out: &mut [f32],
        scratch: &mut BackendScratch,
    ) -> Result<()> {
        // the builtin ops re-check via ops::check_batch, but this is the
        // serving boundary: an externally registered op that forgets its
        // own checks must still never see a mis-sized worker buffer
        crate::ops::check_batch(&*self.op, bucket, inputs, out)?;
        let s = scratch
            .downcast_mut::<OpScratch>()
            .with_context(|| format!("op '{}' handed a foreign scratch arena", self.op.name()))?;
        self.op.run_batch(bucket, inputs, out, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layernorm::config::DEFAULT_ZP;
    use crate::layernorm::AiLayerNorm;
    use crate::ops::ailayernorm::identity_calibration;
    use crate::ops::{AiLayerNormOp, E2SoftmaxOp};
    use crate::quant::{ptf_quantize_into, PtfCalib};
    use crate::softmax::{E2Softmax, E2SoftmaxConfig};

    fn softmax_backend(l: usize, buckets: Vec<usize>) -> OpBackend {
        OpBackend::try_new(Arc::new(E2SoftmaxOp::try_new(l).unwrap()), buckets).unwrap()
    }

    fn layernorm_backend(c: usize, buckets: Vec<usize>) -> OpBackend {
        OpBackend::try_new(Arc::new(AiLayerNormOp::try_new(c).unwrap()), buckets).unwrap()
    }

    #[test]
    fn op_backend_shapes() {
        let be = softmax_backend(32, vec![4, 1, 2]);
        assert_eq!(be.buckets(), &[1, 2, 4]);
        assert_eq!(be.op().spec().to_string(), "e2softmax/L32");
        let out = be.run_alloc(2, &vec![0.5; 64]).unwrap();
        assert_eq!(out.len(), 64);
        // uniform logits -> near-uniform probabilities
        let spread = out.iter().cloned().fold(f32::MIN, f32::max)
            - out.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread < 0.05);
    }

    #[test]
    fn op_backend_rejects_bad_len() {
        let be = softmax_backend(32, vec![1]);
        assert!(be.run_alloc(1, &vec![0.0; 31]).is_err());
    }

    #[test]
    fn constructors_reject_bad_bucket_lists() {
        // empty and zero-sized bucket lists fail at construction with a
        // clear error, not later inside Batcher::new on a worker thread
        let op = || Arc::new(E2SoftmaxOp::try_new(32).unwrap()) as Arc<dyn Op>;
        assert!(OpBackend::try_new(op(), vec![]).is_err());
        let err = OpBackend::try_new(op(), vec![4, 0]).unwrap_err();
        assert!(format!("{err:#}").contains("zero"), "{err:#}");
        // a zero item length dies in the op constructor itself
        assert!(E2SoftmaxOp::try_new(0).is_err());
        assert!(AiLayerNormOp::try_new(0).is_err());
    }

    #[test]
    fn quantized_port_edges_are_rejected_at_the_serving_boundary() {
        use crate::ops::PortType;
        let op: Arc<dyn Op> =
            Arc::new(E2SoftmaxOp::with_out_port(32, PortType::Log2Code5).unwrap());
        let err = format!("{:#}", OpBackend::try_new(op, vec![1]).unwrap_err());
        assert!(err.contains("router-facing edges are f32"), "{err}");
        // the registered ailayernorm-ptf family wraps the same port in a
        // pipeline, so it serves fine
        let reg = OpRegistry::builtin();
        let be = OpBackend::from_spec(&reg, "ailayernorm-ptf/C64", vec![1]).unwrap();
        assert_eq!((be.item_input_len(), be.item_output_len()), (64, 64));
    }

    #[test]
    fn stateful_ops_are_rejected_at_the_serving_boundary() {
        // decode-attention keeps a KV cache per session: a stateless
        // worker pool must refuse it and point at the decode service
        let reg = OpRegistry::builtin();
        let be = OpBackend::from_spec(&reg, "decode-attention/L8xD4", vec![1]);
        let err = format!("{:#}", be.unwrap_err());
        assert!(err.contains("stateful"), "{err}");
        assert!(err.contains("decode service"), "{err}");
    }

    #[test]
    fn constructors_dedup_and_sort_buckets() {
        let be = softmax_backend(16, vec![8, 1, 8, 4]);
        assert_eq!(be.buckets(), &[1, 4, 8]);
        let ln = layernorm_backend(16, vec![4, 4, 1]);
        assert_eq!(ln.buckets(), &[1, 4]);
    }

    #[test]
    fn from_spec_builds_and_rejects() {
        let reg = OpRegistry::builtin();
        let be = OpBackend::from_spec(&reg, "e2softmax/L48", vec![1, 4]).unwrap();
        assert_eq!(be.item_input_len(), 48);
        assert!(OpBackend::from_spec(&reg, "nosuchop/L48", vec![1]).is_err());
        assert!(OpBackend::from_spec(&reg, "e2softmax/L48", vec![0]).is_err());
    }

    #[test]
    fn pipeline_backend_reports_asymmetric_item_lens() {
        // the attention pipeline consumes [Q|K|V] (3*L*D) and produces
        // O (L*D): the backend must advertise both lengths so the
        // coordinator sizes its arenas and response slices correctly
        let reg = OpRegistry::builtin();
        let be = OpBackend::from_spec(&reg, "attention/L8xD4", vec![1, 2]).unwrap();
        assert_eq!(be.item_input_len(), 3 * 8 * 4);
        assert_eq!(be.item_output_len(), 8 * 4);
        let mut rng = crate::util::rng::Rng::new(17);
        let mut items = vec![0f32; 2 * be.item_input_len()];
        rng.fill_normal(&mut items, 0.0, 1.0);
        let out = be.run_alloc(2, &items).unwrap();
        assert_eq!(out.len(), 2 * 8 * 4);
        // each context row is a convex-ish combination of V rows: finite
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn softmax_backend_matches_forward_logits() {
        // the arena hot path must be bit-identical to the reference
        // forward_logits pipeline it replaced
        let l = 48;
        let be = softmax_backend(l, vec![1, 4]);
        let mut rng = crate::util::rng::Rng::new(3);
        let mut rows = vec![0f32; 4 * l];
        rng.fill_normal(&mut rows, 0.0, 2.0);
        let got = be.run_alloc(4, &rows).unwrap();
        let sm = E2Softmax::new(E2SoftmaxConfig::default());
        for r in 0..4 {
            let want: Vec<f32> =
                sm.forward_logits(&rows[r * l..(r + 1) * l]).into_iter().map(|v| v as f32).collect();
            assert_eq!(&got[r * l..(r + 1) * l], &want[..], "row {r}");
        }
    }

    #[test]
    fn softmax_backend_survives_nan_logits() {
        // a NaN-poisoned request must not corrupt its own row beyond the
        // NaN slots (they quantize to the bottom code) nor its batchmates
        let l = 16;
        let be = softmax_backend(l, vec![2]);
        let mut rows = vec![0.5f32; 2 * l];
        rows[3] = f32::NAN;
        let got = be.run_alloc(2, &rows).unwrap();
        assert!(got.iter().all(|v| v.is_finite()));
        // the clean second row matches a clean single-row run exactly
        let clean = be.run_alloc(2, &vec![0.5f32; 2 * l]).unwrap();
        assert_eq!(&got[l..], &clean[l..]);
        // the NaN slot gets the smallest probability in its row
        assert!(got[3] <= got[0]);
    }

    #[test]
    fn softmax_scratch_reuse_is_stable() {
        // same inputs through one reused scratch arena: identical outputs
        let l = 64;
        let be = softmax_backend(l, vec![1, 8]);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut rows = vec![0f32; 8 * l];
        rng.fill_normal(&mut rows, 0.0, 1.5);
        let mut scratch = be.make_scratch();
        let mut out1 = vec![0f32; 8 * l];
        let mut out2 = vec![0f32; 8 * l];
        be.run(8, &rows, &mut out1, &mut scratch).unwrap();
        be.run(8, &rows, &mut out2, &mut scratch).unwrap();
        assert_eq!(out1, out2);
    }

    #[test]
    fn layernorm_backend_matches_direct_kernel() {
        let c = 96;
        let be = layernorm_backend(c, vec![1, 4]);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut rows = vec![0f32; 4 * c];
        rng.fill_normal(&mut rows, 0.0, 2.0);
        let got = be.run_alloc(4, &rows).unwrap();
        // direct kernel invocation with the same identity calibration
        let cal = identity_calibration(c);
        let ln = AiLayerNorm::new(cal.zp);
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let mut codes = Vec::new();
        let mut want = vec![0f32; c];
        for r in 0..4 {
            ptf_quantize_into(&rows[r * c..(r + 1) * c], &cal, &mut codes);
            ln.forward_row_f32(&codes, &cal.alpha, &gamma, &beta, &mut want);
            assert_eq!(&got[r * c..(r + 1) * c], &want[..], "row {r}");
        }
    }

    #[test]
    fn layernorm_backend_normalizes_rows() {
        let c = 192;
        let be = layernorm_backend(c, vec![1]);
        let mut rng = crate::util::rng::Rng::new(11);
        let mut row = vec![0f32; c];
        rng.fill_normal(&mut row, 0.5, 2.0);
        let out = be.run_alloc(1, &row).unwrap();
        let mean: f32 = out.iter().sum::<f32>() / c as f32;
        let sd = (out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32).sqrt();
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((sd - 1.0).abs() < 0.1, "sd {sd}");
    }

    #[test]
    fn layernorm_op_rejects_mismatched_calibration() {
        let cal = PtfCalib { alpha: vec![0u8; 4], s: 1.0, zp: DEFAULT_ZP };
        assert!(AiLayerNormOp::with_calibration(8, cal, vec![1f32; 8], vec![0f32; 8]).is_err());
    }
}
