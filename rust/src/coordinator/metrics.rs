//! Serving metrics: latency histograms + counters, sharded per worker.
//!
//! Counters are plain atomics.  The histogram/streaming state lives in one
//! shard per worker (`record_shard`), so concurrent workers never contend
//! on a lock in the hot path; readers (`summary`, `total_latency`,
//! `mean_batch`) merge the shards on demand — reads are rare and cheap,
//! writes are per-request and must not serialize the pool.
//!
//! The counters pin the request-conservation invariant: every request the
//! client enqueues bumps `accepted`, and eventually bumps exactly one of
//! `completed` (response delivered) or `errors` (dropped by a failed
//! batch), so `completed + errors == accepted` once the queue is drained.
//! A request turned away at admission (a full bounded queue, or the
//! network front door's load shedder) bumps `shed` instead of `accepted`,
//! so the full-front-door ledger is `offered == completed + errors +
//! shed` — nothing that arrived is ever unaccounted for.
//!
//! One `Metrics` instance covers one service; the router's cross-service
//! view is merge-on-read too (`merged_summary` / `total_latency_of`).
//! `in_flight()` (accepted minus resolved) is the cheap three-atomic-read
//! pressure snapshot the shedder and the rebalancer poll.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::{LatencyHist, Streaming};

/// Aggregated serving metrics (interior-mutable, worker-sharded).
pub struct Metrics {
    accepted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
    shards: Vec<Mutex<Inner>>,
}

#[derive(Default)]
struct Inner {
    queue_hist: LatencyHist,
    exec_hist: LatencyHist,
    total_hist: LatencyHist,
    batch_sizes: Streaming,
    padding_waste: Streaming,
}

impl Inner {
    fn merge_from(&mut self, other: &Inner) {
        self.queue_hist.merge(&other.queue_hist);
        self.exec_hist.merge(&other.exec_hist);
        self.total_hist.merge(&other.total_hist);
        self.batch_sizes.merge(&other.batch_sizes);
        self.padding_waste.merge(&other.padding_waste);
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_shards(1)
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// One shard per worker; the coordinator sizes this to its pool.
    pub fn with_shards(n: usize) -> Metrics {
        Metrics {
            accepted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            shards: (0..n.max(1)).map(|_| Mutex::new(Inner::default())).collect(),
        }
    }

    /// Record one request entering the queue (counted at enqueue, so
    /// `completed + errors == accepted` holds once the queue drains).
    pub fn record_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record into shard 0 (single-writer callers).
    pub fn record(&self, queue: Duration, exec: Duration, bucket: usize, actual: usize) {
        self.record_shard(0, queue, exec, bucket, actual);
    }

    /// Record one completed request from worker `shard` — lock-free with
    /// respect to every other worker.
    pub fn record_shard(
        &self,
        shard: usize,
        queue: Duration,
        exec: Duration,
        bucket: usize,
        actual: usize,
    ) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut g = self.shards[shard % self.shards.len()].lock().unwrap();
        g.queue_hist.record(queue.as_secs_f64());
        g.exec_hist.record(exec.as_secs_f64());
        g.total_hist.record((queue + exec).as_secs_f64());
        g.batch_sizes.push(actual as f64);
        g.padding_waste.push((bucket - actual) as f64 / bucket.max(1) as f64);
    }

    pub fn record_error(&self) {
        self.record_errors(1);
    }

    /// Record one request turned away before it entered the queue (a full
    /// bounded queue, or the front door's admission controller).  Shed
    /// requests never bump `accepted`, so `offered == accepted + shed`.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` dropped requests at once (a failed batch drops every
    /// request it carried — one error each, not one per batch).
    pub fn record_errors(&self, n: u64) {
        self.errors.fetch_add(n, Ordering::Relaxed);
    }

    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Everything that ever arrived at this service: `accepted + shed`.
    /// Once the queue drains, `offered == completed + errors + shed`.
    pub fn offered(&self) -> u64 {
        self.accepted() + self.shed()
    }

    /// Accepted requests not yet resolved (completed or errored) — three
    /// relaxed atomic loads, cheap enough for the shedder to poll per
    /// request.  Saturating: concurrent updates can transiently make the
    /// resolved count read ahead of `accepted`.
    pub fn in_flight(&self) -> u64 {
        self.accepted().saturating_sub(self.completed() + self.errors())
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Merge every shard into one view (exact for the histograms, parallel
    /// Welford for the streaming stats).
    fn merged(&self) -> Inner {
        let mut acc = Inner::default();
        for s in &self.shards {
            acc.merge_from(&s.lock().unwrap());
        }
        acc
    }

    /// One-line summary for the CLI / examples (this service's view).
    pub fn summary(&self) -> String {
        format_summary(
            self.accepted(),
            self.completed(),
            self.errors(),
            self.shed(),
            &self.merged(),
        )
    }

    /// One-line summary merged across many services' metrics — the
    /// router's cross-service view (exact histogram merge, parallel
    /// Welford for the streaming stats, summed counters).
    pub fn merged_summary<'a, I: IntoIterator<Item = &'a Metrics>>(all: I) -> String {
        let (accepted, completed, errors, shed, g) = merge_all(all);
        format_summary(accepted, completed, errors, shed, &g)
    }

    /// (p50, p99, mean) of end-to-end latency in seconds, over all shards.
    pub fn total_latency(&self) -> (f64, f64, f64) {
        let g = self.merged();
        (g.total_hist.p50(), g.total_hist.p99(), g.total_hist.mean())
    }

    /// (p50, p99, mean) of end-to-end latency merged across many services
    /// (the router's cross-service latency view).
    pub fn total_latency_of<'a, I: IntoIterator<Item = &'a Metrics>>(all: I) -> (f64, f64, f64) {
        let (_, _, _, _, g) = merge_all(all);
        (g.total_hist.p50(), g.total_hist.p99(), g.total_hist.mean())
    }

    pub fn mean_batch(&self) -> f64 {
        self.merged().batch_sizes.mean()
    }
}

/// Sum the counters and merge the shard state of many metrics instances.
fn merge_all<'a, I: IntoIterator<Item = &'a Metrics>>(all: I) -> (u64, u64, u64, u64, Inner) {
    let (mut accepted, mut completed, mut errors, mut shed) = (0, 0, 0, 0);
    let mut acc = Inner::default();
    for m in all {
        accepted += m.accepted();
        completed += m.completed();
        errors += m.errors();
        shed += m.shed();
        acc.merge_from(&m.merged());
    }
    (accepted, completed, errors, shed, acc)
}

fn format_summary(accepted: u64, completed: u64, errors: u64, shed: u64, g: &Inner) -> String {
    format!(
        "accepted={accepted} completed={completed} errors={errors} shed={shed} | \
         total p50={:.2}ms p99={:.2}ms mean={:.2}ms | \
         exec p50={:.2}ms | queue p50={:.2}ms | avg_batch={:.2} pad_waste={:.0}%",
        g.total_hist.p50() * 1e3,
        g.total_hist.p99() * 1e3,
        g.total_hist.mean() * 1e3,
        g.exec_hist.p50() * 1e3,
        g.queue_hist.p50() * 1e3,
        g.batch_sizes.mean(),
        g.padding_waste.mean() * 100.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record(
                Duration::from_micros(i * 10),
                Duration::from_micros(i * 100),
                8,
                (i % 8 + 1) as usize,
            );
        }
        m.record_error();
        assert_eq!(m.completed(), 100);
        assert_eq!(m.errors(), 1);
        let (p50, p99, mean) = m.total_latency();
        assert!(p50 > 0.0 && p99 >= p50 && mean > 0.0);
        let s = m.summary();
        assert!(s.contains("completed=100"));
        assert!(m.mean_batch() > 1.0);
    }

    #[test]
    fn sharded_recording_merges_to_single_shard_view() {
        let sharded = Metrics::with_shards(4);
        let single = Metrics::with_shards(1);
        assert_eq!(sharded.shard_count(), 4);
        for i in 1..=200u64 {
            let q = Duration::from_micros(i * 7);
            let e = Duration::from_micros(i * 31);
            let actual = (i % 8 + 1) as usize;
            sharded.record_shard(i as usize % 4, q, e, 8, actual);
            single.record(q, e, 8, actual);
        }
        assert_eq!(sharded.completed(), single.completed());
        let (sp50, sp99, smean) = sharded.total_latency();
        let (gp50, gp99, gmean) = single.total_latency();
        // histogram merge is exact; streaming means agree to fp rounding
        assert_eq!(sp50, gp50);
        assert_eq!(sp99, gp99);
        assert!((smean - gmean).abs() < 1e-12);
        assert!((sharded.mean_batch() - single.mean_batch()).abs() < 1e-9);
    }

    #[test]
    fn shard_index_wraps() {
        let m = Metrics::with_shards(2);
        // worker ids beyond the shard count must not panic
        m.record_shard(7, Duration::from_micros(5), Duration::from_micros(9), 4, 2);
        assert_eq!(m.completed(), 1);
        assert!(m.mean_batch() > 0.0);
    }

    #[test]
    fn error_batches_count_per_request() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_accepted();
        }
        for _ in 0..7 {
            m.record(Duration::from_micros(3), Duration::from_micros(5), 4, 4);
        }
        m.record_errors(3); // one failed 3-request batch
        assert_eq!(m.accepted(), 10);
        assert_eq!(m.completed() + m.errors(), m.accepted());
        let s = m.summary();
        assert!(s.contains("accepted=10"), "{s}");
        assert!(s.contains("errors=3"), "{s}");
    }

    #[test]
    fn merged_views_sum_across_services() {
        let a = Metrics::new();
        let b = Metrics::with_shards(2);
        for i in 1..=50u64 {
            a.record_accepted();
            a.record(Duration::from_micros(i), Duration::from_micros(2 * i), 8, 4);
            b.record_accepted();
            let (q, e) = (Duration::from_micros(3 * i), Duration::from_micros(i));
            b.record_shard(i as usize % 2, q, e, 8, 2);
        }
        b.record_accepted();
        b.record_error();
        let s = Metrics::merged_summary([&a, &b]);
        assert!(s.contains("accepted=101"), "{s}");
        assert!(s.contains("completed=100"), "{s}");
        assert!(s.contains("errors=1"), "{s}");
        let (p50, p99, mean) = Metrics::total_latency_of([&a, &b]);
        assert!(p50 > 0.0 && p99 >= p50 && mean > 0.0);
        // merging one instance reproduces its own view exactly
        assert_eq!(Metrics::total_latency_of([&a]), a.total_latency());
    }

    #[test]
    fn shed_and_in_flight_accounting() {
        let m = Metrics::new();
        for _ in 0..8 {
            m.record_accepted();
        }
        for _ in 0..3 {
            m.record_shed();
        }
        assert_eq!(m.shed(), 3);
        assert_eq!(m.offered(), 11);
        assert_eq!(m.in_flight(), 8);
        for _ in 0..5 {
            m.record(Duration::from_micros(2), Duration::from_micros(4), 4, 4);
        }
        m.record_error();
        assert_eq!(m.in_flight(), 2);
        // full front-door ledger once the queue would drain
        m.record(Duration::from_micros(2), Duration::from_micros(4), 4, 4);
        m.record(Duration::from_micros(2), Duration::from_micros(4), 4, 4);
        assert_eq!(m.offered(), m.completed() + m.errors() + m.shed());
        assert_eq!(m.in_flight(), 0);
        let s = m.summary();
        assert!(s.contains("shed=3"), "{s}");
        let merged = Metrics::merged_summary([&m]);
        assert!(merged.contains("shed=3"), "{merged}");
    }

    #[test]
    fn ledger_conserves_under_concurrent_shard_writes() {
        // eight writers hammer their own shards concurrently with a
        // fixed per-thread script (shed every 10th, error every 25th
        // accepted, complete the rest); the counters must conserve and
        // the merged view must equal a sequential replay of the same
        // multiset — the invariant the stream/decode/batching pools all
        // lean on when they report through one Metrics instance
        const WORKERS: usize = 8;
        const PER: u64 = 400;
        let m = Metrics::with_shards(WORKERS);
        std::thread::scope(|s| {
            for w in 0..WORKERS {
                let m = &m;
                s.spawn(move || {
                    for i in 1..=PER {
                        if i % 10 == 0 {
                            m.record_shed();
                        } else if i % 25 == 0 {
                            m.record_accepted();
                            m.record_error();
                        } else {
                            m.record_accepted();
                            let q = Duration::from_micros(i * 3);
                            let e = Duration::from_micros(i * 11);
                            m.record_shard(w, q, e, 8, (i % 8 + 1) as usize);
                        }
                    }
                });
            }
        });
        assert_eq!(m.offered(), WORKERS as u64 * PER, "every scripted event is accounted");
        assert_eq!(m.completed() + m.errors() + m.shed(), m.offered(), "conservation");
        assert_eq!(m.in_flight(), 0, "everything accepted was resolved");

        // the same multiset recorded sequentially into one shard: the
        // merged histograms are exact, the Welford merge agrees to fp
        let single = Metrics::with_shards(1);
        for _ in 0..WORKERS {
            for i in 1..=PER {
                if i % 10 == 0 || i % 25 == 0 {
                    continue;
                }
                let q = Duration::from_micros(i * 3);
                let e = Duration::from_micros(i * 11);
                single.record(q, e, 8, (i % 8 + 1) as usize);
            }
        }
        assert_eq!(m.completed(), single.completed());
        let (p50, p99, mean) = m.total_latency();
        let (sp50, sp99, smean) = single.total_latency();
        assert_eq!(p50, sp50, "histogram merge is exact under concurrency");
        assert_eq!(p99, sp99);
        assert!((mean - smean).abs() < 1e-12);
        assert!((m.mean_batch() - single.mean_batch()).abs() < 1e-9);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let m = Metrics::with_shards(0);
        assert_eq!(m.shard_count(), 1);
        m.record(Duration::from_micros(1), Duration::from_micros(1), 1, 1);
        assert_eq!(m.completed(), 1);
    }
}
