//! Serving metrics: latency histograms + counters, shared across workers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::{LatencyHist, Streaming};

/// Aggregated serving metrics (interior-mutable, worker-shared).
#[derive(Default)]
pub struct Metrics {
    completed: AtomicU64,
    errors: AtomicU64,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    queue_hist: LatencyHist,
    exec_hist: LatencyHist,
    total_hist: LatencyHist,
    batch_sizes: Streaming,
    padding_waste: Streaming,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, queue: Duration, exec: Duration, bucket: usize, actual: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        g.queue_hist.record(queue.as_secs_f64());
        g.exec_hist.record(exec.as_secs_f64());
        g.total_hist.record((queue + exec).as_secs_f64());
        g.batch_sizes.push(actual as f64);
        g.padding_waste.push((bucket - actual) as f64 / bucket.max(1) as f64);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// One-line summary for the CLI / examples.
    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        format!(
            "completed={} errors={} | total p50={:.2}ms p99={:.2}ms mean={:.2}ms | \
             exec p50={:.2}ms | queue p50={:.2}ms | avg_batch={:.2} pad_waste={:.0}%",
            self.completed(),
            self.errors(),
            g.total_hist.p50() * 1e3,
            g.total_hist.p99() * 1e3,
            g.total_hist.mean() * 1e3,
            g.exec_hist.p50() * 1e3,
            g.queue_hist.p50() * 1e3,
            g.batch_sizes.mean(),
            g.padding_waste.mean() * 100.0,
        )
    }

    /// (p50, p99, mean) of end-to-end latency in seconds.
    pub fn total_latency(&self) -> (f64, f64, f64) {
        let g = self.inner.lock().unwrap();
        (g.total_hist.p50(), g.total_hist.p99(), g.total_hist.mean())
    }

    pub fn mean_batch(&self) -> f64 {
        self.inner.lock().unwrap().batch_sizes.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record(
                Duration::from_micros(i * 10),
                Duration::from_micros(i * 100),
                8,
                (i % 8 + 1) as usize,
            );
        }
        m.record_error();
        assert_eq!(m.completed(), 100);
        assert_eq!(m.errors(), 1);
        let (p50, p99, mean) = m.total_latency();
        assert!(p50 > 0.0 && p99 >= p50 && mean > 0.0);
        let s = m.summary();
        assert!(s.contains("completed=100"));
        assert!(m.mean_batch() > 1.0);
    }
}
