//! StreamService: chunked row streaming for reduction-free ops
//! (DESIGN.md §3.6).
//!
//! The batching `Coordinator` and the session-affine `DecodeService`
//! both require a *whole item* per request — for a softmax that means
//! buffering the full row before dispatch, which caps L at what a client
//! is willing to hold.  A reduction-free op ([`Op::reduction_free`]:
//! `consmax`, `gn-softmax`) never looks across elements, so a row can be
//! processed online, chunk by chunk, with L unbounded.  This service is
//! the lane that does it:
//!
//! * **Row state lives in the worker, never the op.**  Mirroring
//!   `DecodeService`'s session map, each worker owns a
//!   `row id -> OpState` map of *open rows* and hands the state mutably
//!   to the streaming trio (`begin_row`/`push_chunk`/`finish_row`) one
//!   chunk at a time.
//! * **Row affinity.**  A row's chunks must execute in order against the
//!   same state, so a row is pinned to lane `row % n_workers` and each
//!   lane is a FIFO owned by one worker — per-row program order with no
//!   cross-lane coordination, different rows in parallel.
//! * **Typed protocol violations.**  A chunk for a row that is not open,
//!   a second `begin` for an open row, or an empty chunk is a *client*
//!   error, not a server fault: the reply channel carries
//!   `Result<Response, StreamViolation>` so the front door can answer
//!   with a typed `ErrCode` and keep the connection alive.  Violations
//!   count as errors in the conservation ledger; they never disturb the
//!   row state they bounced off.
//!
//! Open rows are bounded the same two ways as decode sessions: `finish`
//! frees the state inline, and an **idle TTL** (`start_with`) evicts
//! rows abandoned mid-stream — the owning lane sweeps its own map on
//! wake ticks.  An evicted (or finished) row id is reusable: the next
//! `begin` under it opens a fresh row.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::Response;
use crate::ops::{Op, PortType};

/// A streaming-protocol violation: the client broke the chunk sequence
/// contract.  The request is refused with a typed reply; server state
/// (the row map, the lane, the connection) is untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamViolation {
    /// A non-`begin` chunk named a row that is not open (never begun,
    /// already finished, or evicted by the idle TTL).
    RowNotOpen,
    /// A `begin` chunk named a row that is already open.
    RowAlreadyOpen,
    /// The chunk carried no elements.
    EmptyChunk,
}

impl StreamViolation {
    /// Stable wire-facing description.
    pub fn as_str(&self) -> &'static str {
        match self {
            StreamViolation::RowNotOpen => "row is not open (begin it first)",
            StreamViolation::RowAlreadyOpen => "row is already open",
            StreamViolation::EmptyChunk => "chunk must carry at least one element",
        }
    }
}

impl std::fmt::Display for StreamViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::error::Error for StreamViolation {}

/// What a chunk request resolves to: the chunk's outputs, or the typed
/// violation the client committed.
pub type StreamReply = std::result::Result<Response, StreamViolation>;

/// One chunk request, already pinned to a lane.
struct ChunkRequest {
    id: u64,
    row: u64,
    begin: bool,
    finish: bool,
    data: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<StreamReply>,
}

/// One worker's private FIFO.
struct Lane {
    queue: Mutex<VecDeque<ChunkRequest>>,
    available: Condvar,
}

/// An open row: its op state plus the last time a chunk touched it
/// (drives idle-TTL eviction of abandoned streams).
struct RowSlot {
    state: crate::ops::OpState,
    last_used: Instant,
}

/// The row-affine chunk-streaming pool for one reduction-free op.
pub struct StreamService {
    lanes: Arc<Vec<Arc<Lane>>>,
    workers: Vec<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    /// Sharded latency/throughput counters, one shard per lane.
    pub metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    rows: Arc<AtomicU64>,
    open: Arc<AtomicU64>,
}

impl StreamService {
    /// Start `n_workers` lanes with no idle eviction (abandoned rows live
    /// until shutdown).
    pub fn start(op: Arc<dyn Op>, n_workers: usize) -> Result<StreamService> {
        StreamService::start_with(op, n_workers, None)
    }

    /// Start `n_workers` lanes over a shared reduction-free op.  Refuses
    /// ops that carry a reduction (they belong in a batching
    /// `Coordinator`) and quantized outer ports, mirroring `OpBackend`.
    /// With `idle_ttl` set, a row taking no chunk for that long is
    /// evicted by its lane's periodic sweep (granularity: the 50ms wake
    /// tick).
    pub fn start_with(
        op: Arc<dyn Op>,
        n_workers: usize,
        idle_ttl: Option<Duration>,
    ) -> Result<StreamService> {
        anyhow::ensure!(
            op.reduction_free(),
            "op '{}' is not reduction-free; serve it through a Coordinator over an OpBackend",
            op.name()
        );
        anyhow::ensure!(
            !op.stateful(),
            "op '{}' is stateful; register it with decode_service, not stream_service",
            op.name()
        );
        anyhow::ensure!(
            op.in_port() == PortType::F32 && op.out_port() == PortType::F32,
            "op '{}' exposes a {} -> {} port pair; stream edges are f32",
            op.name(),
            op.in_port(),
            op.out_port()
        );
        let n_workers = n_workers.max(1);
        let lanes: Arc<Vec<Arc<Lane>>> = Arc::new(
            (0..n_workers)
                .map(|_| {
                    Arc::new(Lane { queue: Mutex::new(VecDeque::new()), available: Condvar::new() })
                })
                .collect(),
        );
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Metrics::with_shards(n_workers));
        let rows = Arc::new(AtomicU64::new(0));
        let open = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::new();
        for (wid, lane) in lanes.iter().enumerate() {
            let lane = lane.clone();
            let stop = shutdown.clone();
            let op = op.clone();
            let mt = metrics.clone();
            let nr = rows.clone();
            let lv = open.clone();
            workers.push(std::thread::spawn(move || {
                lane_loop(wid, lane, stop, op, mt, nr, lv, idle_ttl)
            }));
        }
        Ok(StreamService {
            lanes,
            workers,
            shutdown,
            metrics,
            next_id: Arc::new(AtomicU64::new(0)),
            rows,
            open,
        })
    }

    /// A cloneable submission handle.
    pub fn client(&self) -> StreamClient {
        StreamClient {
            lanes: self.lanes.clone(),
            shutdown: self.shutdown.clone(),
            next_id: self.next_id.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Number of lanes (= workers = metrics shards).
    pub fn workers(&self) -> usize {
        self.lanes.len()
    }

    /// Rows ever opened (a reused id after finish/eviction counts again).
    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    /// Rows currently open across all lanes (begun minus
    /// finished/evicted) — the gauge the idle TTL bounds.
    pub fn open_rows(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }

    /// Chunks parked across all lanes right now (pressure snapshot for
    /// the shedder).
    pub fn queue_depth(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.lock().unwrap().len()).sum()
    }

    /// Graceful shutdown: drains every lane — each accepted chunk is
    /// answered (or observes a send-side drop on a failed chunk) before
    /// the workers exit, mirroring `DecodeService::shutdown`.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for lane in self.lanes.iter() {
            lane.available.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Submission handle: routes each chunk to its row's pinned lane.
#[derive(Clone)]
pub struct StreamClient {
    lanes: Arc<Vec<Arc<Lane>>>,
    shutdown: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
}

impl StreamClient {
    /// Submit one chunk for `row`; returns the receiver for its reply.
    /// `begin` opens the row (it must not be open), `finish` closes it
    /// after this chunk (both may be set: a single-chunk row).  Chunks
    /// submitted for one row from one thread execute in submission order
    /// — the lane is a FIFO owned by a single worker.  There is no
    /// length check: streamed rows are L-unbounded by design.
    pub fn submit(
        &self,
        row: u64,
        begin: bool,
        finish: bool,
        data: Vec<f32>,
    ) -> Result<mpsc::Receiver<StreamReply>> {
        let lane = &self.lanes[(row % self.lanes.len() as u64) as usize];
        let mut q = lane.queue.lock().unwrap();
        // checked under the lane lock, as in DecodeClient::submit: the
        // worker only exits once the flag is set AND its lane is empty
        anyhow::ensure!(
            !self.shutdown.load(Ordering::SeqCst),
            "stream service is shutting down"
        );
        let (tx, rx) = mpsc::channel();
        q.push_back(ChunkRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            row,
            begin,
            finish,
            data,
            submitted: Instant::now(),
            resp: tx,
        });
        self.metrics.record_accepted();
        drop(q);
        lane.available.notify_one();
        Ok(rx)
    }

    /// Blocking one-chunk convenience.
    pub fn chunk(&self, row: u64, begin: bool, finish: bool, data: Vec<f32>) -> Result<StreamReply> {
        Ok(self.submit(row, begin, finish, data)?.recv()?)
    }

    /// Stream a whole row through the service in `chunk`-sized pieces
    /// and return the concatenated outputs — the convenience the
    /// equivalence tests compare against `run_batch`.  A violation
    /// (e.g. the row id is already open) surfaces as an error.
    pub fn stream_row(&self, row: u64, input: &[f32], chunk: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(chunk > 0, "chunk size must be positive");
        anyhow::ensure!(!input.is_empty(), "streamed rows must be non-empty");
        let mut out = Vec::with_capacity(input.len());
        let last = input.len().div_ceil(chunk) - 1;
        for (i, piece) in input.chunks(chunk).enumerate() {
            let reply = self.chunk(row, i == 0, i == last, piece.to_vec())?;
            let resp = reply.map_err(|v| anyhow::anyhow!("stream protocol violation: {v}"))?;
            out.extend_from_slice(&resp.output);
        }
        Ok(out)
    }
}

/// Drop every row idle for `ttl` or longer, updating the open gauge.
fn evict_idle(states: &mut HashMap<u64, RowSlot>, ttl: Duration, open: &AtomicU64) {
    let before = states.len();
    states.retain(|_, slot| slot.last_used.elapsed() < ttl);
    let evicted = before - states.len();
    if evicted > 0 {
        open.fetch_sub(evicted as u64, Ordering::Relaxed);
    }
}

/// One lane's worker: pops chunks in FIFO order and runs each against
/// its row's state.  The row map is a plain local — only this thread
/// ever touches the rows pinned here, which is also why idle-TTL sweeps
/// run here rather than from any shared reaper thread.
#[allow(clippy::too_many_arguments)]
fn lane_loop(
    wid: usize,
    lane: Arc<Lane>,
    shutdown: Arc<AtomicBool>,
    op: Arc<dyn Op>,
    metrics: Arc<Metrics>,
    rows: Arc<AtomicU64>,
    open: Arc<AtomicU64>,
    idle_ttl: Option<Duration>,
) {
    let mut states: HashMap<u64, RowSlot> = HashMap::new();
    // sweep at half the TTL (floored) so an abandoned row outlives its
    // TTL by at most one sweep interval, busy lane or not
    let sweep_every = idle_ttl.map(|t| (t / 2).max(Duration::from_millis(10)));
    let mut last_sweep = Instant::now();
    loop {
        let req = {
            let mut q = lane.queue.lock().unwrap();
            loop {
                if let Some(m) = q.pop_front() {
                    break m;
                }
                if shutdown.load(Ordering::SeqCst) {
                    return; // lane drained
                }
                let (guard, _t) =
                    lane.available.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = guard;
                if let (Some(ttl), Some(every)) = (idle_ttl, sweep_every) {
                    if last_sweep.elapsed() >= every {
                        evict_idle(&mut states, ttl, &open);
                        last_sweep = Instant::now();
                    }
                }
            }
        };
        let violation = if req.data.is_empty() {
            Some(StreamViolation::EmptyChunk)
        } else if req.begin && states.contains_key(&req.row) {
            Some(StreamViolation::RowAlreadyOpen)
        } else if !req.begin && !states.contains_key(&req.row) {
            Some(StreamViolation::RowNotOpen)
        } else {
            None
        };
        if let Some(v) = violation {
            // a client-sequence error: typed reply, row state untouched
            metrics.record_error();
            let _ = req.resp.send(Err(v));
        } else {
            if req.begin {
                rows.fetch_add(1, Ordering::Relaxed);
                open.fetch_add(1, Ordering::Relaxed);
                states
                    .insert(req.row, RowSlot { state: op.begin_row(), last_used: Instant::now() });
            }
            let slot = states.get_mut(&req.row).expect("open row has a slot");
            slot.last_used = Instant::now();
            let mut output = Vec::with_capacity(req.data.len());
            let t0 = Instant::now();
            let result = op.push_chunk(&mut slot.state, &req.data, &mut output).and_then(|()| {
                if req.finish {
                    op.finish_row(&mut slot.state, &mut output)
                } else {
                    Ok(())
                }
            });
            let exec = t0.elapsed();
            match result {
                Ok(()) => {
                    if req.finish && states.remove(&req.row).is_some() {
                        open.fetch_sub(1, Ordering::Relaxed);
                    }
                    let queue_time = t0.duration_since(req.submitted);
                    metrics.record_shard(wid, queue_time, exec, 1, 1);
                    let _ = req.resp.send(Ok(Response {
                        id: req.id,
                        output,
                        queue_time,
                        exec_time: exec,
                        batch_size: 1,
                    }));
                }
                Err(e) => {
                    // a failed chunk is a server fault: the row is in an
                    // unknown state, so drop it along with the sender
                    if states.remove(&req.row).is_some() {
                        open.fetch_sub(1, Ordering::Relaxed);
                    }
                    metrics.record_error();
                    eprintln!("stream chunk failed (row {}): {e:#}", req.row);
                }
            }
        }
        if let (Some(ttl), Some(every)) = (idle_ttl, sweep_every) {
            if last_sweep.elapsed() >= every {
                evict_idle(&mut states, ttl, &open);
                last_sweep = Instant::now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{ConSmaxOp, E2SoftmaxOp, GnSoftmaxOp};
    use crate::util::rng::Rng;

    fn consmax_service(l: usize, workers: usize) -> StreamService {
        StreamService::start(Arc::new(ConSmaxOp::try_new(l).unwrap()), workers).unwrap()
    }

    #[test]
    fn rejects_reduction_bearing_ops() {
        let op: Arc<dyn Op> = Arc::new(E2SoftmaxOp::try_new(8).unwrap());
        let err = format!("{:#}", StreamService::start(op, 2).unwrap_err());
        assert!(err.contains("not reduction-free"), "{err}");
    }

    #[test]
    fn streamed_rows_match_run_batch_bitwise() {
        let l = 256;
        let svc = consmax_service(l, 2);
        let cl = svc.client();
        let op = ConSmaxOp::try_new(l).unwrap();
        let mut scratch = op.make_scratch();
        let mut rng = Rng::new(0x57E0);
        for (row_id, &chunk) in [1usize, 7, 64, l].iter().enumerate() {
            let mut x = vec![0f32; l];
            rng.fill_normal(&mut x, 0.0, 2.0);
            let mut want = vec![0f32; l];
            op.run_batch(1, &x, &mut want, &mut scratch).unwrap();
            let got = cl.stream_row(row_id as u64, &x, chunk).unwrap();
            assert_eq!(got, want, "chunk={chunk}");
        }
        assert_eq!(svc.rows(), 4);
        assert_eq!(svc.open_rows(), 0, "finished rows are freed");
        assert_eq!(
            svc.metrics.completed() + svc.metrics.errors(),
            svc.metrics.accepted(),
            "conservation over the streamed chunks"
        );
        svc.shutdown();
    }

    #[test]
    fn interleaved_rows_on_one_client_stay_isolated() {
        let l = 64;
        let svc = consmax_service(l, 2);
        let cl = svc.client();
        let op = ConSmaxOp::try_new(l).unwrap();
        let mut scratch = op.make_scratch();
        let mut rng = Rng::new(0x57E1);
        let mut x = [vec![0f32; l], vec![0f32; l]];
        let mut want = [vec![0f32; l], vec![0f32; l]];
        for r in 0..2 {
            rng.fill_normal(&mut x[r], 0.0, 2.0);
            op.run_batch(1, &x[r], &mut want[r], &mut scratch).unwrap();
        }
        // alternate 16-element chunks between the two rows
        let mut got = [Vec::new(), Vec::new()];
        let pieces: Vec<Vec<&[f32]>> = x.iter().map(|v| v.chunks(16).collect()).collect();
        let n = pieces[0].len();
        for i in 0..n {
            for r in 0..2 {
                let reply =
                    cl.chunk(r as u64, i == 0, i == n - 1, pieces[r][i].to_vec()).unwrap();
                got[r].extend_from_slice(&reply.unwrap().output);
            }
        }
        assert_eq!(got[0], want[0]);
        assert_eq!(got[1], want[1]);
        svc.shutdown();
    }

    #[test]
    fn protocol_violations_are_typed_and_leave_the_lane_serving() {
        let svc = consmax_service(16, 1);
        let cl = svc.client();
        // chunk for a row never begun
        let r = cl.chunk(9, false, false, vec![0.5; 4]).unwrap();
        assert_eq!(r.unwrap_err(), StreamViolation::RowNotOpen);
        // begin twice
        cl.chunk(9, true, false, vec![0.5; 4]).unwrap().unwrap();
        let r = cl.chunk(9, true, false, vec![0.5; 4]).unwrap();
        assert_eq!(r.unwrap_err(), StreamViolation::RowAlreadyOpen);
        // empty chunk (flags do not excuse it)
        let r = cl.chunk(9, false, true, Vec::new()).unwrap();
        assert_eq!(r.unwrap_err(), StreamViolation::EmptyChunk);
        // the row survived those bounces and still finishes cleanly
        cl.chunk(9, false, true, vec![0.5; 4]).unwrap().unwrap();
        // chunk after finish: the row is gone
        let r = cl.chunk(9, false, false, vec![0.5; 4]).unwrap();
        assert_eq!(r.unwrap_err(), StreamViolation::RowNotOpen);
        assert_eq!(svc.metrics.errors(), 4);
        assert_eq!(
            svc.metrics.completed() + svc.metrics.errors(),
            svc.metrics.accepted(),
            "violations stay on the ledger"
        );
        assert_eq!(svc.open_rows(), 0);
        svc.shutdown();
    }

    #[test]
    fn idle_ttl_evicts_abandoned_rows_and_the_id_is_reusable() {
        let op = Arc::new(GnSoftmaxOp::try_new(32).unwrap());
        let svc = StreamService::start_with(op, 1, Some(Duration::from_millis(60))).unwrap();
        let cl = svc.client();
        cl.chunk(3, true, false, vec![0.5; 8]).unwrap().unwrap();
        assert_eq!((svc.rows(), svc.open_rows()), (1, 1));
        let deadline = Instant::now() + Duration::from_secs(2);
        while svc.open_rows() != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(svc.open_rows(), 0, "abandoned row was not evicted");
        // the evicted id is not open any more...
        let r = cl.chunk(3, false, false, vec![0.5; 8]).unwrap();
        assert_eq!(r.unwrap_err(), StreamViolation::RowNotOpen);
        // ...and a fresh begin under it opens a new row
        cl.chunk(3, true, true, vec![0.5; 8]).unwrap().unwrap();
        assert_eq!(svc.rows(), 2);
        svc.shutdown();
    }

    #[test]
    fn in_flight_chunks_survive_shutdown_and_new_ones_bounce() {
        let svc = consmax_service(64, 2);
        let cl = svc.client();
        let rxs: Vec<_> =
            (0..10).map(|row| cl.submit(row, true, true, vec![0.25; 16]).unwrap()).collect();
        svc.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap_or_else(|e| panic!("chunk {i} dropped: {e}")).unwrap();
            assert_eq!(r.output.len(), 16);
        }
        assert!(cl.submit(0, true, true, vec![0.25; 16]).is_err());
    }
}
