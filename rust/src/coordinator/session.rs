//! DecodeService: session-affine serving for stateful decode ops
//! (DESIGN.md §3.5).
//!
//! The stateless `Coordinator` packs whatever requests arrive into one
//! batch — correct only because its backends are pure functions of the
//! item.  A decode op ([`crate::ops::DecodeAttnOp`]) is a function of
//! the item *and* a growing per-session KV cache, which forces two
//! departures from the batching pool:
//!
//! * **State lives in the worker, never the op.**  The op stays `Sync`
//!   and shared; each worker owns a `session id -> OpState` map and
//!   hands the state mutably to `run_batch_stateful` one request at a
//!   time.  Nothing about a session is reachable from any other thread.
//! * **Session affinity.**  A session's steps must execute in order
//!   against the same state, so each worker owns its own FIFO lane and
//!   a session is pinned to lane `session % n_workers`.  One worker per
//!   lane + FIFO order = per-session program order, with no cross-lane
//!   coordination.  Different sessions on different lanes still run in
//!   parallel.
//!
//! Steps execute at batch size 1 — decode is the latency-bound regime;
//! the bucketed batcher exists for prefill.  Metrics reuse the sharded
//! [`Metrics`] (one shard per lane), so `bench_serving` reports decode
//! rows with the same schema as prefill rows.
//!
//! Session state is bounded two ways (a front door cannot trust clients
//! to be tidy): an **idle TTL** (`start_with`) evicts sessions that take
//! no step for the configured duration — the owning lane sweeps its own
//! map on wake ticks, so eviction needs no cross-thread access to state —
//! and an explicit **`end_session`** message frees a session immediately.
//! Either way the id becomes reusable: the next step under it builds a
//! fresh state at step 0.  `live_sessions` gauges resident sessions;
//! `sessions` keeps counting every session ever created.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::Response;
use crate::ops::{Op, PortType};

/// One decode step request, already pinned to a lane.
struct StepRequest {
    id: u64,
    session: u64,
    input: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// What flows down a lane: a decode step, or an explicit session end.
/// Ends ride the same FIFO as steps so a `submit(s) ; end_session(s)`
/// sequence frees the state only after the step ran.
enum LaneMsg {
    Step(StepRequest),
    End { id: u64, session: u64, submitted: Instant, resp: mpsc::Sender<Response> },
}

/// One worker's private FIFO.
struct Lane {
    queue: Mutex<VecDeque<LaneMsg>>,
    available: Condvar,
}

/// A resident session: its op state plus the last time a step touched it
/// (drives idle-TTL eviction).
struct SessionSlot {
    state: crate::ops::OpState,
    last_used: Instant,
}

/// The session-affine serving pool for one stateful op.
pub struct DecodeService {
    lanes: Arc<Vec<Arc<Lane>>>,
    workers: Vec<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    /// Sharded latency/throughput counters, one shard per lane.
    pub metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    sessions: Arc<AtomicU64>,
    live: Arc<AtomicU64>,
    item_len: usize,
    out_len: usize,
}

impl DecodeService {
    /// Start `n_workers` lanes with no idle eviction (sessions live until
    /// `end_session` or shutdown).
    pub fn start(op: Arc<dyn Op>, n_workers: usize) -> Result<DecodeService> {
        DecodeService::start_with(op, n_workers, None)
    }

    /// Start `n_workers` lanes over a shared stateful op.  Refuses
    /// stateless ops (they belong in a batching `Coordinator`) and
    /// quantized outer ports, mirroring `OpBackend`.  With `idle_ttl`
    /// set, a session taking no step for that long is evicted by its
    /// lane's periodic sweep (granularity: the 50ms wake tick).
    pub fn start_with(
        op: Arc<dyn Op>,
        n_workers: usize,
        idle_ttl: Option<Duration>,
    ) -> Result<DecodeService> {
        anyhow::ensure!(
            op.stateful(),
            "op '{}' is stateless; serve it through a Coordinator over an OpBackend",
            op.name()
        );
        anyhow::ensure!(op.item_len() > 0, "op '{}' has an empty item", op.name());
        anyhow::ensure!(
            op.in_port() == PortType::F32 && op.out_port() == PortType::F32,
            "op '{}' exposes a {} -> {} port pair; decode edges are f32",
            op.name(),
            op.in_port(),
            op.out_port()
        );
        let n_workers = n_workers.max(1);
        let lanes: Arc<Vec<Arc<Lane>>> = Arc::new(
            (0..n_workers)
                .map(|_| {
                    Arc::new(Lane { queue: Mutex::new(VecDeque::new()), available: Condvar::new() })
                })
                .collect(),
        );
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Metrics::with_shards(n_workers));
        let sessions = Arc::new(AtomicU64::new(0));
        let live = Arc::new(AtomicU64::new(0));
        let item_len = op.item_len();
        let out_len = op.out_len();
        let mut workers = Vec::new();
        for (wid, lane) in lanes.iter().enumerate() {
            let lane = lane.clone();
            let stop = shutdown.clone();
            let op = op.clone();
            let mt = metrics.clone();
            let ns = sessions.clone();
            let lv = live.clone();
            workers.push(std::thread::spawn(move || {
                lane_loop(wid, lane, stop, op, mt, ns, lv, idle_ttl)
            }));
        }
        Ok(DecodeService {
            lanes,
            workers,
            shutdown,
            metrics,
            next_id: Arc::new(AtomicU64::new(0)),
            sessions,
            live,
            item_len,
            out_len,
        })
    }

    /// A cloneable submission handle.
    pub fn client(&self) -> DecodeClient {
        DecodeClient {
            lanes: self.lanes.clone(),
            shutdown: self.shutdown.clone(),
            next_id: self.next_id.clone(),
            metrics: self.metrics.clone(),
            item_len: self.item_len,
        }
    }

    /// Flat f32 length of one step's input (`[q | k | v]` for decode
    /// attention).
    pub fn item_len(&self) -> usize {
        self.item_len
    }

    /// Flat f32 length of one step's output.
    pub fn out_len(&self) -> usize {
        self.out_len
    }

    /// Number of lanes (= workers = metrics shards).
    pub fn workers(&self) -> usize {
        self.lanes.len()
    }

    /// Sessions ever created (a reused id after eviction counts again).
    pub fn sessions(&self) -> u64 {
        self.sessions.load(Ordering::Relaxed)
    }

    /// Sessions currently resident across all lanes (created minus
    /// evicted/ended) — the gauge the TTL satellite bounds.
    pub fn live_sessions(&self) -> u64 {
        self.live.load(Ordering::Relaxed)
    }

    /// Steps/ends parked across all lanes right now (pressure snapshot
    /// for the shedder).
    pub fn queue_depth(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.lock().unwrap().len()).sum()
    }

    /// Graceful shutdown: drains every lane — each accepted step is
    /// answered (or observes a send-side drop on a failed step) before
    /// the workers exit, mirroring `Coordinator::shutdown`.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for lane in self.lanes.iter() {
            lane.available.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Submission handle: routes each step to its session's pinned lane.
#[derive(Clone)]
pub struct DecodeClient {
    lanes: Arc<Vec<Arc<Lane>>>,
    shutdown: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    item_len: usize,
}

impl DecodeClient {
    /// Submit one decode step for `session`; returns the receiver for
    /// its response.  Steps submitted for one session from one thread
    /// execute (and cache-append) in submission order — the lane is a
    /// FIFO owned by a single worker.
    pub fn submit(&self, session: u64, input: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        anyhow::ensure!(
            input.len() == self.item_len,
            "decode step len {} != {}",
            input.len(),
            self.item_len
        );
        let lane = &self.lanes[(session % self.lanes.len() as u64) as usize];
        let mut q = lane.queue.lock().unwrap();
        // checked under the lane lock, as in Coordinator::enqueue: the
        // worker only exits once the flag is set AND its lane is empty
        anyhow::ensure!(
            !self.shutdown.load(Ordering::SeqCst),
            "decode service is shutting down"
        );
        let (tx, rx) = mpsc::channel();
        q.push_back(LaneMsg::Step(StepRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            session,
            input,
            submitted: Instant::now(),
            resp: tx,
        }));
        self.metrics.record_accepted();
        drop(q);
        lane.available.notify_one();
        Ok(rx)
    }

    /// Blocking one-step convenience.
    pub fn infer(&self, session: u64, input: Vec<f32>) -> Result<Response> {
        Ok(self.submit(session, input)?.recv()?)
    }

    /// Free `session`'s state explicitly.  Rides the session's FIFO lane
    /// behind any steps already submitted for it; the (empty-output)
    /// response confirms the state is gone.  Idempotent — ending an
    /// unknown or already-ended session still succeeds.
    pub fn end_session(&self, session: u64) -> Result<mpsc::Receiver<Response>> {
        let lane = &self.lanes[(session % self.lanes.len() as u64) as usize];
        let mut q = lane.queue.lock().unwrap();
        anyhow::ensure!(
            !self.shutdown.load(Ordering::SeqCst),
            "decode service is shutting down"
        );
        let (tx, rx) = mpsc::channel();
        q.push_back(LaneMsg::End {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            session,
            submitted: Instant::now(),
            resp: tx,
        });
        // an end is a request like any other for the conservation ledger
        self.metrics.record_accepted();
        drop(q);
        lane.available.notify_one();
        Ok(rx)
    }

    /// Blocking `end_session` convenience.
    pub fn end_session_wait(&self, session: u64) -> Result<Response> {
        Ok(self.end_session(session)?.recv()?)
    }

    /// Flat f32 length one step expects.
    pub fn item_len(&self) -> usize {
        self.item_len
    }
}

/// Drop every session idle for `ttl` or longer, updating the live gauge.
fn evict_idle(states: &mut HashMap<u64, SessionSlot>, ttl: Duration, live: &AtomicU64) {
    let before = states.len();
    states.retain(|_, slot| slot.last_used.elapsed() < ttl);
    let evicted = before - states.len();
    if evicted > 0 {
        live.fetch_sub(evicted as u64, Ordering::Relaxed);
    }
}

/// One lane's worker: pops steps in FIFO order and runs each against its
/// session's state.  The state map is a plain local — only this thread
/// ever touches the sessions pinned here, which is also why idle-TTL
/// sweeps run here (on wake ticks and between messages) rather than from
/// any shared reaper thread.
#[allow(clippy::too_many_arguments)]
fn lane_loop(
    wid: usize,
    lane: Arc<Lane>,
    shutdown: Arc<AtomicBool>,
    op: Arc<dyn Op>,
    metrics: Arc<Metrics>,
    sessions: Arc<AtomicU64>,
    live: Arc<AtomicU64>,
    idle_ttl: Option<Duration>,
) {
    let mut states: HashMap<u64, SessionSlot> = HashMap::new();
    let mut scratch = op.make_scratch();
    let out_len = op.out_len();
    // sweep at half the TTL (floored) so an idle session outlives its TTL
    // by at most one sweep interval, busy lane or not
    let sweep_every = idle_ttl.map(|t| (t / 2).max(Duration::from_millis(10)));
    let mut last_sweep = Instant::now();
    loop {
        let msg = {
            let mut q = lane.queue.lock().unwrap();
            loop {
                if let Some(m) = q.pop_front() {
                    break m;
                }
                if shutdown.load(Ordering::SeqCst) {
                    return; // lane drained
                }
                let (guard, _t) =
                    lane.available.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = guard;
                if let (Some(ttl), Some(every)) = (idle_ttl, sweep_every) {
                    if last_sweep.elapsed() >= every {
                        evict_idle(&mut states, ttl, &live);
                        last_sweep = Instant::now();
                    }
                }
            }
        };
        match msg {
            LaneMsg::Step(req) => {
                let slot = states.entry(req.session).or_insert_with(|| {
                    sessions.fetch_add(1, Ordering::Relaxed);
                    live.fetch_add(1, Ordering::Relaxed);
                    SessionSlot { state: op.make_state(), last_used: Instant::now() }
                });
                slot.last_used = Instant::now();
                let mut output = vec![0f32; out_len];
                let t0 = Instant::now();
                let result = op.run_batch_stateful(
                    1,
                    &req.input,
                    &mut output,
                    &mut scratch,
                    &mut slot.state,
                );
                let exec = t0.elapsed();
                match result {
                    Ok(()) => {
                        let queue_time = t0.duration_since(req.submitted);
                        metrics.record_shard(wid, queue_time, exec, 1, 1);
                        let _ = req.resp.send(Response {
                            id: req.id,
                            output,
                            queue_time,
                            exec_time: exec,
                            batch_size: 1,
                        });
                    }
                    Err(e) => {
                        // a failed step (e.g. a session at capacity) drops
                        // only its own request; the session state stays
                        metrics.record_error();
                        eprintln!("decode step failed (session {}): {e:#}", req.session);
                    }
                }
            }
            LaneMsg::End { id, session, submitted, resp } => {
                if states.remove(&session).is_some() {
                    live.fetch_sub(1, Ordering::Relaxed);
                }
                let queue_time = submitted.elapsed();
                metrics.record_shard(wid, queue_time, Duration::ZERO, 1, 1);
                let _ = resp.send(Response {
                    id,
                    output: Vec::new(),
                    queue_time,
                    exec_time: Duration::ZERO,
                    batch_size: 1,
                });
            }
        }
        if let (Some(ttl), Some(every)) = (idle_ttl, sweep_every) {
            if last_sweep.elapsed() >= every {
                evict_idle(&mut states, ttl, &live);
                last_sweep = Instant::now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{DecodeAttnOp, E2SoftmaxOp};
    use crate::util::rng::Rng;

    fn decode_service(cap: usize, d: usize, workers: usize) -> DecodeService {
        DecodeService::start(Arc::new(DecodeAttnOp::try_new(cap, d).unwrap()), workers).unwrap()
    }

    #[test]
    fn rejects_stateless_ops() {
        let op: Arc<dyn Op> = Arc::new(E2SoftmaxOp::try_new(8).unwrap());
        let err = format!("{:#}", DecodeService::start(op, 2).unwrap_err());
        assert!(err.contains("stateless"), "{err}");
    }

    #[test]
    fn sessions_accumulate_state_server_side() {
        let (cap, d) = (16usize, 8usize);
        let svc = decode_service(cap, d, 2);
        let cl = svc.client();
        assert_eq!(cl.item_len(), 3 * d);
        // run two interleaved sessions through the service, and the same
        // token streams through a local op: every step must match, which
        // is only possible if each session's KV cache persists and grows
        // server-side between requests
        let op = DecodeAttnOp::try_new(cap, d).unwrap();
        let mut scratch = op.make_scratch();
        let mut rng = Rng::new(0x5E55);
        for sid in [0u64, 1] {
            let mut state = op.make_state();
            let mut want = vec![0f32; d];
            for step in 0..cap {
                let mut item = vec![0f32; 3 * d];
                rng.fill_normal(&mut item, 0.0, 1.0);
                op.run_batch_stateful(1, &item, &mut want, &mut scratch, &mut state).unwrap();
                let got = cl.infer(sid, item).unwrap();
                assert_eq!(got.output, want, "session {sid} step {step}");
            }
        }
        assert_eq!(svc.sessions(), 2);
        assert_eq!(svc.metrics.completed(), 2 * cap as u64);
        svc.shutdown();
    }

    #[test]
    fn a_session_over_capacity_fails_without_poisoning_its_lane() {
        let svc = decode_service(2, 4, 1);
        let cl = svc.client();
        let step = vec![0.5f32; 12];
        cl.infer(7, step.clone()).unwrap();
        cl.infer(7, step.clone()).unwrap();
        // step 3 overflows session 7's cache: its sender is dropped
        assert!(cl.submit(7, step.clone()).unwrap().recv().is_err());
        // the lane (and a fresh session on it) keeps serving
        cl.infer(8, step.clone()).unwrap();
        assert_eq!(svc.metrics.errors(), 1);
        assert_eq!(
            svc.metrics.completed() + svc.metrics.errors(),
            svc.metrics.accepted(),
            "conservation: completed + errors == accepted"
        );
        svc.shutdown();
    }

    #[test]
    fn in_flight_steps_survive_shutdown_and_new_ones_bounce() {
        let (cap, d) = (32usize, 4usize);
        let svc = decode_service(cap, d, 2);
        let cl = svc.client();
        let rxs: Vec<_> =
            (0..20).map(|i| cl.submit(i % 4, vec![0.25; 3 * d]).unwrap()).collect();
        svc.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap_or_else(|e| panic!("step {i} dropped: {e}"));
            assert_eq!(r.output.len(), d);
        }
        assert!(cl.submit(0, vec![0.25; 3 * d]).is_err());
    }

    #[test]
    fn wrong_item_len_is_rejected_at_submit() {
        let svc = decode_service(4, 4, 1);
        let cl = svc.client();
        assert!(cl.submit(0, vec![0.0; 5]).is_err());
        svc.shutdown();
    }

    #[test]
    fn idle_ttl_evicts_and_reused_id_restarts_at_step_zero() {
        let (cap, d) = (8usize, 4usize);
        let op = Arc::new(DecodeAttnOp::try_new(cap, d).unwrap());
        let svc =
            DecodeService::start_with(op, 1, Some(Duration::from_millis(60))).unwrap();
        let cl = svc.client();
        let mut rng = Rng::new(0xE71C);
        let steps: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut v = vec![0f32; 3 * d];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        // advance session 5 two steps, then go idle past the TTL
        cl.infer(5, steps[0].clone()).unwrap();
        cl.infer(5, steps[1].clone()).unwrap();
        assert_eq!((svc.sessions(), svc.live_sessions()), (1, 1));
        let deadline = Instant::now() + Duration::from_secs(2);
        while svc.live_sessions() != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(svc.live_sessions(), 0, "idle session was not evicted");
        // the reused id restarts at step 0: its next step matches a fresh
        // local replay of step 0, not a continuation of the evicted cache
        // (step 2 would attend over three cached tokens, not one)
        let local = DecodeAttnOp::try_new(cap, d).unwrap();
        let mut scratch = local.make_scratch();
        let mut state = local.make_state();
        let mut want = vec![0f32; d];
        local.run_batch_stateful(1, &steps[2], &mut want, &mut scratch, &mut state).unwrap();
        let got = cl.infer(5, steps[2].clone()).unwrap();
        assert_eq!(got.output, want);
        assert_eq!(svc.sessions(), 2, "a reused id creates a fresh session");
        svc.shutdown();
    }

    #[test]
    fn end_session_frees_state_and_reused_id_restarts_at_step_zero() {
        let (cap, d) = (4usize, 4usize);
        let svc = decode_service(cap, d, 2);
        let cl = svc.client();
        let step = vec![0.5f32; 3 * d];
        // fill session 3 to cache capacity: one more step would error
        for _ in 0..cap {
            cl.infer(3, step.clone()).unwrap();
        }
        assert_eq!(svc.live_sessions(), 1);
        let r = cl.end_session_wait(3).unwrap();
        assert!(r.output.is_empty());
        assert_eq!(svc.live_sessions(), 0);
        // ending a session that no longer exists is still fine
        cl.end_session_wait(3).unwrap();
        // proof the id restarted at step 0: a *continued* session would be
        // at capacity and error immediately, a fresh one takes cap steps
        for _ in 0..cap {
            cl.infer(3, step.clone()).unwrap();
        }
        assert_eq!((svc.sessions(), svc.live_sessions()), (2, 1));
        assert_eq!(svc.metrics.errors(), 0);
        assert_eq!(
            svc.metrics.completed() + svc.metrics.errors(),
            svc.metrics.accepted(),
            "conservation across steps and ends"
        );
        svc.shutdown();
    }
}
