//! Dynamic batching policy: wait up to `max_wait` for the queue to fill,
//! then dispatch into the largest lowered bucket that fits (vLLM-style
//! bucketed static shapes — XLA artifacts are fixed-shape, so batch sizes
//! are quantized to the buckets the AOT step lowered).

use std::time::Duration;

/// Tunables for the batcher and the request queue.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Longest a request may wait for companions before dispatch.
    pub max_wait: Duration,
    /// Hard cap on batch size (<= largest lowered bucket).
    pub max_batch: usize,
    /// Bounded-queue backpressure: when set, `Client::submit` blocks while
    /// the queue holds this many requests and `Client::try_submit` returns
    /// the input back instead of enqueueing.  `None` = unbounded (the
    /// seed's behavior).
    pub queue_cap: Option<usize>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_wait: Duration::from_millis(5), max_batch: 16, queue_cap: None }
    }
}

/// Validate + canonicalize a backend's bucket list at construction time:
/// non-empty, no zero-sized bucket, sorted ascending, deduped.  Backends
/// call this from their constructors so a bad list fails right there with
/// a clear error instead of panicking later inside `Batcher::new` on a
/// worker thread (where the panic is invisible to the caller).
pub fn normalize_buckets(mut buckets: Vec<usize>) -> anyhow::Result<Vec<usize>> {
    anyhow::ensure!(!buckets.is_empty(), "bucket list is empty: need at least one batch size");
    anyhow::ensure!(
        !buckets.contains(&0),
        "bucket list {buckets:?} contains a zero batch size"
    );
    buckets.sort_unstable();
    buckets.dedup();
    Ok(buckets)
}

/// Pure decision logic (separated from the queue for testability).
#[derive(Debug, Clone)]
pub struct Batcher {
    policy: BatchPolicy,
    /// Sorted ascending bucket sizes (e.g. [1, 4, 8, 16]).
    buckets: Vec<usize>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, mut buckets: Vec<usize>) -> Batcher {
        assert!(!buckets.is_empty(), "need at least one bucket");
        buckets.sort_unstable();
        buckets.dedup();
        Batcher { policy, buckets }
    }

    /// Dispatch now?  Yes when the queue already fills the biggest usable
    /// bucket, or the oldest request has waited out the window.
    pub fn should_dispatch(&self, queued: usize, oldest_wait: Duration) -> bool {
        if queued == 0 {
            return false;
        }
        queued >= self.max_usable() || oldest_wait >= self.policy.max_wait
    }

    /// How much longer the batcher may wait given the oldest request's age.
    pub fn remaining_wait(&self, oldest_wait: Duration) -> Duration {
        self.policy.max_wait.saturating_sub(oldest_wait).max(Duration::from_micros(100))
    }

    /// Largest bucket <= max_batch.
    fn max_usable(&self) -> usize {
        self.buckets
            .iter()
            .rev()
            .find(|&&b| b <= self.policy.max_batch)
            .copied()
            .unwrap_or(self.buckets[0])
    }

    /// Bucket for `n` queued requests: the smallest bucket >= n, capped at
    /// the largest usable one (padding fills the gap).
    pub fn pick_bucket(&self, n: usize) -> usize {
        let cap = self.max_usable();
        let n = n.clamp(1, cap);
        self.buckets
            .iter()
            .find(|&&b| b >= n)
            .copied()
            .unwrap_or(cap)
            .min(cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(max_wait_ms: u64, max_batch: usize, buckets: &[usize]) -> Batcher {
        Batcher::new(
            BatchPolicy {
                max_wait: Duration::from_millis(max_wait_ms),
                max_batch,
                ..BatchPolicy::default()
            },
            buckets.to_vec(),
        )
    }

    #[test]
    fn picks_smallest_covering_bucket() {
        let b = mk(5, 16, &[1, 4, 8, 16]);
        assert_eq!(b.pick_bucket(1), 1);
        assert_eq!(b.pick_bucket(2), 4);
        assert_eq!(b.pick_bucket(4), 4);
        assert_eq!(b.pick_bucket(5), 8);
        assert_eq!(b.pick_bucket(9), 16);
        assert_eq!(b.pick_bucket(100), 16);
    }

    #[test]
    fn max_batch_caps_bucket() {
        let b = mk(5, 8, &[1, 4, 8, 16]);
        assert_eq!(b.pick_bucket(100), 8);
        assert!(b.should_dispatch(8, Duration::ZERO));
        assert!(!b.should_dispatch(7, Duration::ZERO));
    }

    #[test]
    fn timeout_forces_dispatch() {
        let b = mk(5, 16, &[1, 4, 8, 16]);
        assert!(!b.should_dispatch(2, Duration::from_millis(1)));
        assert!(b.should_dispatch(2, Duration::from_millis(6)));
        assert!(b.should_dispatch(1, Duration::from_millis(6)));
    }

    #[test]
    fn empty_queue_never_dispatches() {
        let b = mk(5, 16, &[1, 4]);
        assert!(!b.should_dispatch(0, Duration::from_secs(1)));
    }

    #[test]
    fn remaining_wait_counts_down() {
        let b = mk(10, 16, &[1]);
        assert!(b.remaining_wait(Duration::from_millis(3)) <= Duration::from_millis(7));
        assert!(b.remaining_wait(Duration::from_millis(30)) >= Duration::from_micros(100));
    }

    #[test]
    fn buckets_deduped_and_sorted() {
        let b = mk(5, 16, &[8, 1, 8, 4]);
        assert_eq!(b.pick_bucket(3), 4);
    }

    #[test]
    fn normalize_buckets_canonicalizes() {
        assert_eq!(normalize_buckets(vec![8, 1, 8, 4]).unwrap(), vec![1, 4, 8]);
        assert_eq!(normalize_buckets(vec![16]).unwrap(), vec![16]);
    }

    #[test]
    fn normalize_buckets_rejects_empty_and_zero() {
        assert!(normalize_buckets(vec![]).is_err());
        let err = normalize_buckets(vec![4, 0, 8]).unwrap_err().to_string();
        assert!(err.contains("zero"), "{err}");
    }
}
