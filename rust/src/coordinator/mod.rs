//! Inference coordinator (Layer 3): request router + dynamic batcher +
//! worker pool + metrics.
//!
//! The paper's contribution is the *operator* co-design, so the
//! coordinator is the serving shell that makes it deployable: requests
//! arrive one item at a time, the batcher packs them into the bucketed
//! batch sizes the AOT artifacts were lowered for (1/4/8/16), a worker
//! executes the compiled PJRT model (or one of the bit-exact software
//! op-services), and per-request latency is tracked through per-worker
//! metrics shards.  Everything is std::thread — no async runtime exists in
//! the offline vendor set, and a thread-per-worker design is the right
//! shape for PJRT's blocking execute anyway.
//!
//! The execution hot path is arena-style: every worker owns a packed input
//! buffer, a staged output buffer, and the backend's opaque scratch, all
//! reused across batches, so steady-state batch execution performs no heap
//! allocation beyond handing each caller its owned `Response`.  The
//! software op-services execute the packed buffer with a single
//! batch-kernel call (`forward_batch_f32`) — the per-row loop lives inside
//! the planar kernel, not in the dispatch layer.
//!
//! One `Coordinator` serves one backend at one item length; `router`
//! (DESIGN.md §5.1) stacks many of them behind named services so a single
//! process serves the paper's full mixed-op, mixed-shape workload,
//! `session` adds the session-affine decode pool for stateful KV-cache
//! ops (DESIGN.md §3.5) — the batching pool here is the prefill path —
//! and `stream` adds the row-affine chunk-streaming pool for
//! reduction-free softmax ops (DESIGN.md §3.6), where L is unbounded.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod router;
pub mod session;
pub mod stream;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

pub use backend::{Backend, BackendScratch, OpBackend, PjrtBackend};
pub use batcher::{normalize_buckets, BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use router::{
    paper_service_specs, paper_services, RouterClient, ServiceRouter, ServiceRouterBuilder,
    ServiceSpec,
};
pub use session::{DecodeClient, DecodeService};
pub use stream::{StreamClient, StreamReply, StreamService, StreamViolation};

/// One inference request: a flat f32 item (e.g. one image or one row).
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub submitted: Instant,
    pub resp: mpsc::Sender<Response>,
}

/// The reply: flat f32 output plus timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    pub queue_time: Duration,
    pub exec_time: Duration,
    pub batch_size: usize,
}

/// Outcome of a non-blocking submission attempt.
pub enum TrySubmit {
    /// Enqueued; the receiver yields the response.
    Accepted(mpsc::Receiver<Response>),
    /// The bounded queue was full; the input is handed back for retry.
    Full(Vec<f32>),
}

struct Shared {
    queue: Mutex<std::collections::VecDeque<Request>>,
    /// Signals workers: a request arrived (or shutdown began).
    available: Condvar,
    /// Signals bounded-queue submitters: the queue drained (or shutdown).
    space: Condvar,
    shutdown: AtomicBool,
    queue_cap: Option<usize>,
    /// Outstanding retirement requests (`shrink`): each worker that claims
    /// one (atomic decrement) exits its loop.  Which worker retires is
    /// deliberately unspecified — workers are interchangeable (the arena is
    /// per-worker, the queue is shared), so the first to notice leaves.
    retire: AtomicUsize,
}

/// Decrement `retire` if positive; `true` means this worker claimed a
/// retirement and must exit.
fn claim_retirement(retire: &AtomicUsize) -> bool {
    retire
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
        .is_ok()
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    next_id: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    item_len: usize,
}

impl Client {
    /// Submit one item; returns the receiver for its response.  With a
    /// bounded queue (`BatchPolicy::queue_cap`) this blocks until space
    /// frees up, and errors if the coordinator shuts down first.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        match self.enqueue(input, true)? {
            TrySubmit::Accepted(rx) => Ok(rx),
            TrySubmit::Full(_) => unreachable!("blocking enqueue never reports Full"),
        }
    }

    /// Non-blocking submit: `Full(input)` hands the item back when the
    /// bounded queue is at capacity (always accepts when unbounded).
    pub fn try_submit(&self, input: Vec<f32>) -> Result<TrySubmit> {
        self.enqueue(input, false)
    }

    fn enqueue(&self, input: Vec<f32>, block: bool) -> Result<TrySubmit> {
        anyhow::ensure!(input.len() == self.item_len, "item len {} != {}", input.len(), self.item_len);
        let mut q = self.shared.queue.lock().unwrap();
        // checked under the queue lock: workers only exit once the flag is
        // set AND the queue is empty, so anything enqueued before the flag
        // is still drained, and nothing can be enqueued after it
        anyhow::ensure!(
            !self.shared.shutdown.load(Ordering::SeqCst),
            "coordinator is shutting down"
        );
        if let Some(cap) = self.shared.queue_cap {
            while q.len() >= cap {
                anyhow::ensure!(
                    !self.shared.shutdown.load(Ordering::SeqCst),
                    "coordinator is shutting down"
                );
                if !block {
                    // shed, not accepted: the front-door ledger is
                    // offered == completed + errors + shed
                    self.metrics.record_shed();
                    return Ok(TrySubmit::Full(input));
                }
                let (guard, _t) = self
                    .shared
                    .space
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
        }
        let (tx, rx) = mpsc::channel();
        q.push_back(Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            submitted: Instant::now(),
            resp: tx,
        });
        // counted under the queue lock: once enqueued the request is owned
        // by the coordinator and will resolve as exactly one completion or
        // one error, so completed + errors == accepted after a drain
        self.metrics.record_accepted();
        drop(q);
        self.shared.available.notify_one();
        Ok(TrySubmit::Accepted(rx))
    }

    /// Blocking one-shot convenience.
    pub fn infer(&self, input: Vec<f32>) -> Result<Response> {
        Ok(self.submit(input)?.recv()?)
    }

    /// Flat f32 length this client's service expects per item.
    pub fn item_len(&self) -> usize {
        self.item_len
    }

    /// Requests currently parked in the queue (not yet picked up by a
    /// worker) — one lock, read by the shedder and the rebalancer.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }
}

/// The coordinator: owns the worker threads.  The pool is dynamic: the
/// rebalancer can `grow` / `shrink` it at runtime (retired threads stay in
/// `workers` until shutdown joins them — they have already returned, so the
/// join is free).
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    pub metrics: Arc<Metrics>,
    item_len: usize,
    next_id: Arc<AtomicU64>,
    backend: Arc<dyn Backend>,
    policy: BatchPolicy,
    /// Workers currently serving (spawned minus retired), maintained under
    /// the `workers` lock; reads are lock-free.
    live: AtomicUsize,
    next_wid: AtomicUsize,
}

impl Coordinator {
    /// Start `n_workers` workers over a shared backend.  Each worker gets
    /// its own scratch arena (`Backend::make_scratch`) and its own metrics
    /// shard, so workers never contend outside the request queue itself.
    pub fn start(backend: Arc<dyn Backend>, policy: BatchPolicy, n_workers: usize) -> Coordinator {
        let n_workers = n_workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            space: Condvar::new(),
            shutdown: AtomicBool::new(false),
            queue_cap: policy.queue_cap,
            retire: AtomicUsize::new(0),
        });
        let metrics = Arc::new(Metrics::with_shards(n_workers));
        let item_len = backend.item_input_len();
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            workers.push(spawn_worker(wid, &shared, &backend, &policy, &metrics));
        }
        Coordinator {
            shared,
            workers: Mutex::new(workers),
            metrics,
            item_len,
            next_id: Arc::new(AtomicU64::new(0)),
            backend,
            policy,
            live: AtomicUsize::new(n_workers),
            next_wid: AtomicUsize::new(n_workers),
        }
    }

    /// Workers currently serving this coordinator's queue.
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Requests parked in the queue right now.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Add `n` workers to the pool (fresh arenas; metrics shards wrap, so
    /// worker ids beyond the original shard count stay valid).
    pub fn grow(&self, n: usize) {
        if n == 0 {
            return;
        }
        let mut workers = self.workers.lock().unwrap();
        for _ in 0..n {
            let wid = self.next_wid.fetch_add(1, Ordering::SeqCst);
            let w = spawn_worker(wid, &self.shared, &self.backend, &self.policy, &self.metrics);
            workers.push(w);
        }
        self.live.fetch_add(n, Ordering::SeqCst);
    }

    /// Ask up to `n` workers to retire, never dropping the pool below one
    /// live worker (a service must always drain its queue).  Returns how
    /// many retirements were actually posted; each is claimed by the next
    /// worker to pass its loop head or condvar wake (≤ ~50ms), so the pool
    /// shrinks shortly after, not synchronously.
    pub fn shrink(&self, n: usize) -> usize {
        let workers = self.workers.lock().unwrap();
        let live = self.live.load(Ordering::SeqCst);
        let take = n.min(live.saturating_sub(1));
        if take > 0 {
            self.shared.retire.fetch_add(take, Ordering::SeqCst);
            self.live.fetch_sub(take, Ordering::SeqCst);
            // wake sleepers so an idle worker claims the retirement promptly
            self.shared.available.notify_all();
        }
        drop(workers);
        take
    }

    pub fn client(&self) -> Client {
        Client {
            shared: self.shared.clone(),
            next_id: self.next_id.clone(),
            metrics: self.metrics.clone(),
            item_len: self.item_len,
        }
    }

    /// Flat f32 length of one item this coordinator's backend expects.
    pub fn item_len(&self) -> usize {
        self.item_len
    }

    /// Graceful shutdown: **drains the queue** — every request already
    /// accepted receives its response (or observes a send-side drop on
    /// backend error) before the workers exit.  Submitters blocked on a
    /// full bounded queue error out instead of enqueueing.
    pub fn shutdown(self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        self.shared.space.notify_all();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for w in handles {
            let _ = w.join();
        }
    }
}

fn spawn_worker(
    wid: usize,
    shared: &Arc<Shared>,
    backend: &Arc<dyn Backend>,
    policy: &BatchPolicy,
    metrics: &Arc<Metrics>,
) -> JoinHandle<()> {
    let sh = shared.clone();
    let be = backend.clone();
    let mt = metrics.clone();
    let pol = policy.clone();
    std::thread::spawn(move || worker_loop(wid, sh, be, pol, mt))
}

/// Per-worker reusable buffers: the packed input, the staged output, the
/// drained batch, and the backend's opaque scratch.  Everything keeps its
/// capacity across batches, so the steady state allocates nothing here.
struct WorkerArena {
    inputs: Vec<f32>,
    outputs: Vec<f32>,
    batch: Vec<Request>,
    scratch: BackendScratch,
}

fn worker_loop(
    wid: usize,
    shared: Arc<Shared>,
    backend: Arc<dyn Backend>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let batcher = Batcher::new(policy, backend.buckets().to_vec());
    let mut arena = WorkerArena {
        inputs: Vec::new(),
        outputs: Vec::new(),
        batch: Vec::new(),
        scratch: backend.make_scratch(),
    };
    loop {
        // collect a batch (blocks until at least one request or shutdown);
        // the bucket is picked exactly once, here, and passed down
        let bucket = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                    return;
                }
                // a retirement posted by `shrink` is claimed between
                // batches, never mid-batch; `shrink` guarantees at least
                // one worker outlives every posted retirement, so the
                // queue always keeps a consumer
                if claim_retirement(&shared.retire) {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                let (guard, _t) = shared
                    .available
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
            // the *current* front's age decides whether we keep waiting for
            // more — re-read it after every wake: the lock is released
            // inside wait_timeout, so a peer worker may dispatch the request
            // this iteration started from, and a dead request's age must not
            // drive should_dispatch / remaining_wait (it would dispatch a
            // fresh request prematurely or mis-size the sleep)
            loop {
                if q.is_empty() {
                    break; // a peer drained everything while we slept
                }
                let oldest_wait = q.front().unwrap().submitted.elapsed();
                if batcher.should_dispatch(q.len(), oldest_wait)
                    || shared.shutdown.load(Ordering::SeqCst)
                {
                    break;
                }
                let (guard, _t) = shared
                    .available
                    .wait_timeout(q, batcher.remaining_wait(oldest_wait))
                    .unwrap();
                q = guard;
            }
            let bucket = batcher.pick_bucket(q.len());
            let take = bucket.min(q.len());
            arena.batch.clear();
            arena.batch.extend(q.drain(..take));
            bucket
        };
        if arena.batch.is_empty() {
            continue;
        }
        // bounded-queue submitters may proceed now that the queue drained
        shared.space.notify_all();
        execute_batch(&*backend, bucket, &metrics, wid, &mut arena);
    }
}

/// Execute one batch at the pre-picked `bucket` size out of the worker's
/// arena.  Pack + zero-pad into `arena.inputs`, run the backend into
/// `arena.outputs`, then hand each caller its slice.
fn execute_batch(
    backend: &dyn Backend,
    bucket: usize,
    metrics: &Metrics,
    shard: usize,
    arena: &mut WorkerArena,
) {
    let n = arena.batch.len();
    debug_assert!(n <= bucket, "batch {n} exceeds bucket {bucket}");
    let item_in = backend.item_input_len();
    let item_out = backend.item_output_len();
    arena.inputs.clear();
    arena.inputs.resize(bucket * item_in, 0f32);
    for (i, r) in arena.batch.iter().enumerate() {
        arena.inputs[i * item_in..(i + 1) * item_in].copy_from_slice(&r.input);
    }
    arena.outputs.clear();
    arena.outputs.resize(bucket * item_out, 0f32);
    let t0 = Instant::now();
    let result = backend.run(bucket, &arena.inputs, &mut arena.outputs, &mut arena.scratch);
    let exec = t0.elapsed();
    match result {
        Ok(()) => {
            for (i, r) in arena.batch.drain(..).enumerate() {
                let slice = arena.outputs[i * item_out..(i + 1) * item_out].to_vec();
                let queue_time = t0.duration_since(r.submitted);
                metrics.record_shard(shard, queue_time, exec, bucket, n);
                let _ = r.resp.send(Response {
                    id: r.id,
                    output: slice,
                    queue_time,
                    exec_time: exec,
                    batch_size: n,
                });
            }
        }
        Err(e) => {
            // a failed batch drops every request it carried: count one
            // error per dropped request, not one per batch, so that
            // completed + errors always accounts for every accepted request
            metrics.record_errors(n as u64);
            // drop senders -> callers observe RecvError
            eprintln!("batch execution failed ({n} requests dropped): {e:#}");
            arena.batch.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::OpBackend;
    use crate::ops::E2SoftmaxOp;

    fn softmax_backend(l: usize, buckets: Vec<usize>) -> Arc<OpBackend> {
        Arc::new(OpBackend::try_new(Arc::new(E2SoftmaxOp::try_new(l).unwrap()), buckets).unwrap())
    }

    fn start_sw(policy: BatchPolicy) -> Coordinator {
        Coordinator::start(softmax_backend(64, vec![1, 4, 8]), policy, 1)
    }

    fn policy(max_wait_ms: u64, max_batch: usize) -> BatchPolicy {
        BatchPolicy {
            max_wait: Duration::from_millis(max_wait_ms),
            max_batch,
            ..BatchPolicy::default()
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let co = start_sw(policy(1, 8));
        let cl = co.client();
        let x: Vec<f32> = (0..64).map(|i| i as f32 * 0.1).collect();
        let resp = cl.infer(x).unwrap();
        assert_eq!(resp.output.len(), 64);
        let s: f32 = resp.output.iter().sum();
        assert!((s - 1.0).abs() < 0.4); // e2softmax row sums near 1
        co.shutdown();
    }

    #[test]
    fn many_requests_all_answered() {
        let co = start_sw(policy(2, 8));
        let cl = co.client();
        let rxs: Vec<_> = (0..50)
            .map(|i| cl.submit(vec![(i % 7) as f32; 64]).unwrap())
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.output.len(), 64);
        }
        assert_eq!(co.metrics.completed(), 50);
        co.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let co = start_sw(policy(30, 8));
        let cl = co.client();
        let rxs: Vec<_> = (0..8).map(|_| cl.submit(vec![1.0; 64]).unwrap()).collect();
        let sizes: Vec<usize> = rxs.into_iter().map(|rx| rx.recv().unwrap().batch_size).collect();
        // at least one multi-request batch formed under the 30ms window
        assert!(sizes.iter().any(|&s| s > 1), "sizes {sizes:?}");
        co.shutdown();
    }

    #[test]
    fn rejects_wrong_item_len() {
        let co = start_sw(BatchPolicy::default());
        let cl = co.client();
        assert!(cl.submit(vec![0.0; 3]).is_err());
        co.shutdown();
    }

    #[test]
    fn shutdown_idempotent_under_load() {
        let co = start_sw(policy(1, 4));
        let cl = co.client();
        for _ in 0..10 {
            let _ = cl.submit(vec![0.5; 64]);
        }
        co.shutdown(); // must not hang
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        // the documented contract: every accepted request is answered even
        // when shutdown lands while the queue is deep and the batcher is
        // still waiting for companions
        let co = start_sw(policy(250, 8));
        let cl = co.client();
        let rxs: Vec<_> = (0..30).map(|_| cl.submit(vec![0.25; 64]).unwrap()).collect();
        co.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap_or_else(|e| panic!("request {i} dropped: {e}"));
            assert_eq!(r.output.len(), 64);
        }
    }

    #[test]
    fn multi_worker_answers_everything() {
        let co = Coordinator::start(softmax_backend(64, vec![1, 4, 8]), policy(1, 8), 4);
        let cl = co.client();
        let rxs: Vec<_> = (0..120).map(|_| cl.submit(vec![0.5; 64]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
        assert_eq!(co.metrics.completed(), 120);
        assert_eq!(co.metrics.shard_count(), 4);
        co.shutdown();
    }

    /// Slow test backend: copies input to output after a fixed delay.
    struct SlowEcho {
        l: usize,
        buckets: Vec<usize>,
        delay: Duration,
    }

    impl Backend for SlowEcho {
        fn item_input_len(&self) -> usize {
            self.l
        }
        fn item_output_len(&self) -> usize {
            self.l
        }
        fn buckets(&self) -> &[usize] {
            &self.buckets
        }
        fn run(
            &self,
            _bucket: usize,
            inputs: &[f32],
            out: &mut [f32],
            _scratch: &mut BackendScratch,
        ) -> Result<()> {
            std::thread::sleep(self.delay);
            out.copy_from_slice(inputs);
            Ok(())
        }
    }

    #[test]
    fn stale_front_age_does_not_dispatch_fresh_requests_prematurely() {
        // regression (stale dispatch age): a worker used to capture the
        // front request's `submitted` once before its condvar loop; when a
        // peer dispatched that request, the stale age made should_dispatch
        // fire immediately for the *next* (fresh) request, breaking up
        // batches.  With the fix, a fresh burst must batch.
        let be = Arc::new(SlowEcho { l: 4, buckets: vec![1, 8], delay: Duration::from_millis(1) });
        let co = Coordinator::start(be, policy(150, 8), 2);
        let cl = co.client();
        for round in 0..3 {
            // an aging solo request: one worker dispatches it at ~150ms,
            // leaving any peer sitting in the batching wait with its age
            let lone = cl.submit(vec![0.0; 4]).unwrap();
            std::thread::sleep(Duration::from_millis(200));
            // a fresh burst that fills a whole bucket: no request in it has
            // waited anywhere near max_wait, so none may dispatch solo
            let fresh: Vec<_> = (0..8).map(|_| cl.submit(vec![1.0; 4]).unwrap()).collect();
            lone.recv().unwrap();
            for rx in fresh {
                let r = rx.recv().unwrap();
                assert!(
                    r.batch_size > 1 || r.queue_time >= Duration::from_millis(100),
                    "round {round}: fresh request dispatched solo after only {:?}",
                    r.queue_time
                );
            }
        }
        co.shutdown();
    }

    /// Backend that fails any batch carrying the poison sentinel; clean
    /// batches echo their input.
    struct PoisonEcho {
        l: usize,
        buckets: Vec<usize>,
    }

    impl Backend for PoisonEcho {
        fn item_input_len(&self) -> usize {
            self.l
        }
        fn item_output_len(&self) -> usize {
            self.l
        }
        fn buckets(&self) -> &[usize] {
            &self.buckets
        }
        fn run(
            &self,
            _bucket: usize,
            inputs: &[f32],
            out: &mut [f32],
            _scratch: &mut BackendScratch,
        ) -> Result<()> {
            anyhow::ensure!(!inputs.contains(&POISON), "poisoned batch");
            out.copy_from_slice(inputs);
            Ok(())
        }
    }

    const POISON: f32 = -1e30;

    #[test]
    fn failing_backend_counts_one_error_per_dropped_request() {
        // regression (error accounting): a failed batch used to record ONE
        // error while dropping n requests, so completed + errors
        // undercounted accepted.  Pin the conservation invariant, and that
        // batches after a failure are still served.
        let be = Arc::new(PoisonEcho { l: 4, buckets: vec![1, 4] });
        let co = Coordinator::start(be, policy(2, 4), 2);
        let cl = co.client();
        let rxs: Vec<_> = (0..40)
            .map(|i| {
                let v = if i % 5 == 0 { POISON } else { 0.5 };
                cl.submit(vec![v; 4]).unwrap()
            })
            .collect();
        let (mut oks, mut drops) = (0u64, 0u64);
        for rx in rxs {
            match rx.recv() {
                Ok(r) => {
                    assert_eq!(r.output, vec![0.5; 4]);
                    oks += 1;
                }
                Err(_) => drops += 1, // sender dropped by the failed batch
            }
        }
        // poison hit at least its own 8 requests; clean singleton batches
        // may still have served some of the rest
        assert!(drops >= 8, "drops {drops}");
        // the pool keeps serving after failures: a clean request succeeds
        let tail = cl.infer(vec![0.25; 4]).unwrap();
        assert_eq!(tail.output, vec![0.25; 4]);
        oks += 1;
        assert_eq!(co.metrics.accepted(), 41);
        assert_eq!(co.metrics.completed(), oks);
        assert_eq!(co.metrics.errors(), drops);
        assert_eq!(
            co.metrics.completed() + co.metrics.errors(),
            co.metrics.accepted(),
            "conservation: completed + errors == accepted"
        );
        co.shutdown();
    }

    #[test]
    fn bounded_queue_try_submit_reports_full() {
        let be = Arc::new(SlowEcho { l: 4, buckets: vec![1], delay: Duration::from_millis(300) });
        let co = Coordinator::start(
            be,
            BatchPolicy {
                max_wait: Duration::ZERO,
                max_batch: 1,
                queue_cap: Some(1),
            },
            1,
        );
        let cl = co.client();
        // first request: the worker picks it up and sleeps on it
        let rx1 = cl.submit(vec![1.0; 4]).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        // second request parks in the queue (cap 1 -> queue now full)
        let rx2 = cl.submit(vec![2.0; 4]).unwrap();
        // third must bounce with its input handed back, counted as shed
        match cl.try_submit(vec![3.0; 4]).unwrap() {
            TrySubmit::Full(input) => assert_eq!(input, vec![3.0; 4]),
            TrySubmit::Accepted(_) => panic!("queue should be full"),
        }
        assert_eq!(co.metrics.shed(), 1);
        // blocking submit waits for space and eventually lands
        let rx3 = cl.submit(vec![4.0; 4]).unwrap();
        for rx in [rx1, rx2, rx3] {
            assert!(rx.recv().is_ok());
        }
        // full ledger: offered == completed + errors + shed
        assert_eq!(co.metrics.offered(), 4);
        assert_eq!(
            co.metrics.offered(),
            co.metrics.completed() + co.metrics.errors() + co.metrics.shed()
        );
        co.shutdown();
    }

    #[test]
    fn grow_and_shrink_resize_the_pool() {
        let co = start_sw(policy(1, 8));
        let cl = co.client();
        assert_eq!(co.live_workers(), 1);
        co.grow(2);
        assert_eq!(co.live_workers(), 3);
        // shrink floors at one live worker no matter how much is asked
        assert_eq!(co.shrink(10), 2);
        assert_eq!(co.live_workers(), 1);
        assert_eq!(co.shrink(1), 0);
        // the surviving worker still serves (retirements are claimed on
        // wake ticks, so give them a moment to land first)
        std::thread::sleep(Duration::from_millis(200));
        let rxs: Vec<_> = (0..20).map(|_| cl.submit(vec![0.25; 64]).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
        assert_eq!(co.metrics.completed(), 20);
        // grow again after a shrink: fresh workers join the same queue
        co.grow(1);
        assert_eq!(co.live_workers(), 2);
        let r = cl.infer(vec![0.5; 64]).unwrap();
        assert_eq!(r.output.len(), 64);
        co.shutdown();
    }

    #[test]
    fn shrink_while_loaded_still_drains_everything() {
        let be = Arc::new(SlowEcho { l: 4, buckets: vec![1, 4], delay: Duration::from_millis(2) });
        let co = Coordinator::start(be, policy(1, 4), 4);
        let cl = co.client();
        let rxs: Vec<_> = (0..80).map(|i| cl.submit(vec![i as f32; 4]).unwrap()).collect();
        assert_eq!(co.shrink(3), 3);
        for rx in rxs {
            assert!(rx.recv().is_ok(), "request dropped across a shrink");
        }
        assert_eq!(co.live_workers(), 1);
        assert_eq!(co.metrics.completed(), 80);
        co.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        // the drain contract's flip side: once shutdown is initiated no new
        // request can be accepted (it would never be drained)
        let co = start_sw(policy(1, 8));
        let cl = co.client();
        co.shutdown();
        assert!(cl.submit(vec![0.0; 64]).is_err());
        assert!(cl.try_submit(vec![0.0; 64]).is_err());
        assert!(cl.infer(vec![0.0; 64]).is_err());
    }

    #[test]
    fn unbounded_try_submit_always_accepts() {
        let co = start_sw(policy(1, 8));
        let cl = co.client();
        match cl.try_submit(vec![0.0; 64]).unwrap() {
            TrySubmit::Accepted(rx) => assert!(rx.recv().is_ok()),
            TrySubmit::Full(_) => panic!("unbounded queue can never be full"),
        }
        co.shutdown();
    }
}
