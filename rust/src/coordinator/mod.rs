//! Inference coordinator (Layer 3): request router + dynamic batcher +
//! worker pool + metrics.
//!
//! The paper's contribution is the *operator* co-design, so the
//! coordinator is the serving shell that makes it deployable: requests
//! arrive one item at a time, the batcher packs them into the bucketed
//! batch sizes the AOT artifacts were lowered for (1/4/8/16), a worker
//! executes the compiled PJRT model, and per-request latency is tracked
//! through a lock-free-enough metrics layer.  Everything is std::thread —
//! no async runtime exists in the offline vendor set, and a thread-per-
//! worker design is the right shape for PJRT's blocking execute anyway.

pub mod backend;
pub mod batcher;
pub mod metrics;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

pub use backend::{Backend, PjrtBackend, SoftwareSoftmaxBackend};
pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;

/// One inference request: a flat f32 item (e.g. one image).
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub submitted: Instant,
    pub resp: mpsc::Sender<Response>,
}

/// The reply: flat f32 output plus timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    pub queue_time: Duration,
    pub exec_time: Duration,
    pub batch_size: usize,
}

struct Shared {
    queue: Mutex<std::collections::VecDeque<Request>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    next_id: Arc<AtomicU64>,
    item_len: usize,
}

impl Client {
    /// Submit one item; returns the receiver for its response.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        anyhow::ensure!(input.len() == self.item_len, "item len {} != {}", input.len(), self.item_len);
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            submitted: Instant::now(),
            resp: tx,
        };
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(req);
        drop(q);
        self.shared.available.notify_one();
        Ok(rx)
    }

    /// Blocking one-shot convenience.
    pub fn infer(&self, input: Vec<f32>) -> Result<Response> {
        Ok(self.submit(input)?.recv()?)
    }
}

/// The coordinator: owns the worker threads.
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    item_len: usize,
    next_id: Arc<AtomicU64>,
}

impl Coordinator {
    /// Start `n_workers` workers over a shared backend.
    pub fn start(backend: Arc<dyn Backend>, policy: BatchPolicy, n_workers: usize) -> Coordinator {
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(Metrics::new());
        let item_len = backend.item_input_len();
        let mut workers = Vec::new();
        for wid in 0..n_workers.max(1) {
            let sh = shared.clone();
            let be = backend.clone();
            let mt = metrics.clone();
            let pol = policy.clone();
            workers.push(std::thread::spawn(move || worker_loop(wid, sh, be, pol, mt)));
        }
        Coordinator { shared, workers, metrics, item_len, next_id: Arc::new(AtomicU64::new(0)) }
    }

    pub fn client(&self) -> Client {
        Client { shared: self.shared.clone(), next_id: self.next_id.clone(), item_len: self.item_len }
    }

    /// Graceful shutdown: drains nothing, drops pending requests' senders.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    _wid: usize,
    shared: Arc<Shared>,
    backend: Arc<dyn Backend>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let batcher = Batcher::new(policy, backend.buckets().to_vec());
    loop {
        // collect a batch (blocks until at least one request or shutdown)
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                let (guard, _t) = shared
                    .available
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = guard;
            }
            // first request's age decides whether we keep waiting for more
            let oldest = q.front().unwrap().submitted;
            let mut q = q;
            loop {
                let n = q.len();
                if batcher.should_dispatch(n, oldest.elapsed()) {
                    break;
                }
                let (guard, timeout) = shared
                    .available
                    .wait_timeout(q, batcher.remaining_wait(oldest.elapsed()))
                    .unwrap();
                q = guard;
                if timeout.timed_out() || shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            let bucket = batcher.pick_bucket(q.len());
            let take = bucket.min(q.len());
            q.drain(..take).collect::<Vec<_>>()
        };
        if batch.is_empty() {
            continue;
        }
        execute_batch(&*backend, &batcher, batch, &metrics);
    }
}

fn execute_batch(backend: &dyn Backend, batcher: &Batcher, batch: Vec<Request>, metrics: &Metrics) {
    let n = batch.len();
    let bucket = batcher.pick_bucket(n);
    let item_in = backend.item_input_len();
    let item_out = backend.item_output_len();
    // pack + zero-pad to the bucket shape
    let mut inputs = vec![0f32; bucket * item_in];
    for (i, r) in batch.iter().enumerate() {
        inputs[i * item_in..(i + 1) * item_in].copy_from_slice(&r.input);
    }
    let t0 = Instant::now();
    let result = backend.run(bucket, &inputs);
    let exec = t0.elapsed();
    match result {
        Ok(out) => {
            for (i, r) in batch.into_iter().enumerate() {
                let slice = out[i * item_out..(i + 1) * item_out].to_vec();
                let queue_time = t0.duration_since(r.submitted);
                metrics.record(queue_time, exec, bucket, n);
                let _ = r.resp.send(Response {
                    id: r.id,
                    output: slice,
                    queue_time,
                    exec_time: exec,
                    batch_size: n,
                });
            }
        }
        Err(e) => {
            metrics.record_error();
            // drop senders -> callers observe RecvError
            eprintln!("batch execution failed: {e:#}");
            drop(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SoftwareSoftmaxBackend;

    fn start_sw(policy: BatchPolicy) -> Coordinator {
        let be = Arc::new(SoftwareSoftmaxBackend::new(64, vec![1, 4, 8]));
        Coordinator::start(be, policy, 1)
    }

    #[test]
    fn single_request_roundtrip() {
        let co = start_sw(BatchPolicy { max_wait: Duration::from_millis(1), max_batch: 8 });
        let cl = co.client();
        let x: Vec<f32> = (0..64).map(|i| i as f32 * 0.1).collect();
        let resp = cl.infer(x).unwrap();
        assert_eq!(resp.output.len(), 64);
        let s: f32 = resp.output.iter().sum();
        assert!((s - 1.0).abs() < 0.4); // e2softmax row sums near 1
        co.shutdown();
    }

    #[test]
    fn many_requests_all_answered() {
        let co = start_sw(BatchPolicy { max_wait: Duration::from_millis(2), max_batch: 8 });
        let cl = co.client();
        let rxs: Vec<_> = (0..50)
            .map(|i| cl.submit(vec![(i % 7) as f32; 64]).unwrap())
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.output.len(), 64);
        }
        assert_eq!(co.metrics.completed(), 50);
        co.shutdown();
    }

    #[test]
    fn batching_actually_batches() {
        let co = start_sw(BatchPolicy { max_wait: Duration::from_millis(30), max_batch: 8 });
        let cl = co.client();
        let rxs: Vec<_> = (0..8).map(|_| cl.submit(vec![1.0; 64]).unwrap()).collect();
        let sizes: Vec<usize> = rxs.into_iter().map(|rx| rx.recv().unwrap().batch_size).collect();
        // at least one multi-request batch formed under the 30ms window
        assert!(sizes.iter().any(|&s| s > 1), "sizes {sizes:?}");
        co.shutdown();
    }

    #[test]
    fn rejects_wrong_item_len() {
        let co = start_sw(BatchPolicy::default());
        let cl = co.client();
        assert!(cl.submit(vec![0.0; 3]).is_err());
        co.shutdown();
    }

    #[test]
    fn shutdown_idempotent_under_load() {
        let co = start_sw(BatchPolicy { max_wait: Duration::from_millis(1), max_batch: 4 });
        let cl = co.client();
        for _ in 0..10 {
            let _ = cl.submit(vec![0.5; 64]);
        }
        co.shutdown(); // must not hang
    }
}
