//! ServiceRouter: one serving process, many op/shape services.
//!
//! SOLE's point is serving *both* E2Softmax and AILayerNorm — at the
//! paper's mixed shapes (softmax L ∈ {49, 128, 785, 1024}, layernorm at
//! transformer channel widths, plus the fused attention pipeline the
//! softmax unit was co-designed for) — from one inference stack.  A single
//! `Coordinator` serves exactly one backend at one item length, so the
//! router layers a registry of named services on top: each service owns a
//! full coordinator (bucketed queue, worker pool, metrics shards) and the
//! `RouterClient` routes a request to its service by name, validating the
//! item length against that service's contract.
//!
//! The worker budget is shared: `total_workers` is split across services
//! by weight (largest-remainder, minimum one worker each), so hot
//! services — the shapes carrying most of the traffic — can be given a
//! larger share without starving the rest.  Metrics stay per-service
//! (each coordinator keeps its own sharded `Metrics`) and merge on read
//! for the cross-service view (`Metrics::merged_summary`).
//!
//! Stateful decode ops join the same budget through
//! [`ServiceRouterBuilder::decode_service`]: they get a session-affine
//! [`DecodeService`] pool instead of a batching coordinator (a stateless
//! pool would hand every request a fresh, empty KV cache), and
//! `RouterClient::infer_decode` routes `(service, session, step)`
//! triples to the session's pinned lane.  Reduction-free streaming ops
//! join through [`ServiceRouterBuilder::stream_service`]: a row-affine
//! [`StreamService`] pool (DESIGN.md §3.6) that accepts a row chunk by
//! chunk, with `RouterClient::stream_chunk` routing
//! `(service, row, chunk)` triples to the row's pinned lane.

use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{Context, Result};

use super::backend::{Backend, OpBackend};
use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use super::session::{DecodeClient, DecodeService};
use super::stream::{StreamClient, StreamReply, StreamService};
use super::{Client, Coordinator, Response, TrySubmit};
use crate::ops::{Op, OpRegistry};

/// Declarative description of one named service before the router starts.
pub struct ServiceSpec {
    pub name: String,
    pub backend: Arc<dyn Backend>,
    pub policy: BatchPolicy,
    /// Worker-budget weight: the service's share of `total_workers` is
    /// proportional to this (every service keeps at least one worker).
    pub weight: usize,
}

/// Declarative description of one decode service: a stateful op served
/// with session affinity instead of a batching pool.
struct DecodeSpec {
    name: String,
    op: Arc<dyn Op>,
    weight: usize,
    idle_ttl: Option<Duration>,
}

/// Declarative description of one stream service: a reduction-free op
/// served chunk by chunk with row affinity instead of a batching pool.
struct StreamSpec {
    name: String,
    op: Arc<dyn Op>,
    weight: usize,
    idle_ttl: Option<Duration>,
}

/// Builder: register services, then `start()` the per-service pools.
pub struct ServiceRouterBuilder {
    total_workers: usize,
    default_policy: BatchPolicy,
    specs: Vec<ServiceSpec>,
    decode_specs: Vec<DecodeSpec>,
    stream_specs: Vec<StreamSpec>,
}

impl ServiceRouterBuilder {
    /// Policy applied to services registered without an explicit one.
    pub fn default_policy(mut self, policy: BatchPolicy) -> Self {
        self.default_policy = policy;
        self
    }

    /// Register a service under the default policy, weight 1.
    pub fn service(self, name: &str, backend: Arc<dyn Backend>) -> Self {
        let policy = self.default_policy.clone();
        self.spec(ServiceSpec { name: name.to_string(), backend, policy, weight: 1 })
    }

    /// Register a hot service: default policy, `weight`x worker share.
    pub fn hot_service(self, name: &str, backend: Arc<dyn Backend>, weight: usize) -> Self {
        let policy = self.default_policy.clone();
        self.spec(ServiceSpec { name: name.to_string(), backend, policy, weight })
    }

    /// Register a fully-specified service.
    pub fn spec(mut self, spec: ServiceSpec) -> Self {
        self.specs.push(spec);
        self
    }

    /// Register a software op-service from a registry spec string
    /// (`e2softmax/L128`, `softmax-exact/L49`, …): the canonical spec is
    /// the service name, the backend is an `OpBackend` over the
    /// constructed op, weight 1 under the default policy.
    pub fn op_service(
        self,
        registry: &OpRegistry,
        spec: &str,
        buckets: Vec<usize>,
    ) -> Result<Self> {
        self.weighted_op_service(registry, spec, buckets, 1)
    }

    /// `op_service` with an explicit worker-budget weight.
    pub fn weighted_op_service(
        self,
        registry: &OpRegistry,
        spec: &str,
        buckets: Vec<usize>,
        weight: usize,
    ) -> Result<Self> {
        let (parsed, op) = registry.build(spec)?;
        let backend = Arc::new(OpBackend::try_new(op, buckets)?);
        let policy = self.default_policy.clone();
        Ok(self.spec(ServiceSpec { name: parsed.to_string(), backend, policy, weight }))
    }

    /// Register a decode service from a registry spec string
    /// (`decode-attention/L128xD64`): the op must be stateful, and the
    /// service draws `weight` shares of the worker budget as
    /// session-pinned lanes rather than a batching pool.
    pub fn decode_service(
        self,
        registry: &OpRegistry,
        spec: &str,
        weight: usize,
    ) -> Result<Self> {
        self.decode_service_with_ttl(registry, spec, weight, None)
    }

    /// `decode_service` with an idle-session TTL: sessions taking no step
    /// for `idle_ttl` are evicted by their lane (see `DecodeService`).
    pub fn decode_service_with_ttl(
        mut self,
        registry: &OpRegistry,
        spec: &str,
        weight: usize,
        idle_ttl: Option<Duration>,
    ) -> Result<Self> {
        let (parsed, op) = registry.build(spec)?;
        anyhow::ensure!(
            op.stateful(),
            "op '{parsed}' is stateless; register it with op_service, not decode_service"
        );
        self.decode_specs.push(DecodeSpec { name: parsed.to_string(), op, weight, idle_ttl });
        Ok(self)
    }

    /// Register a stream service from a registry spec string
    /// (`consmax/L128`): the op must be reduction-free, and the service
    /// draws `weight` shares of the worker budget as row-pinned lanes
    /// rather than a batching pool.  The same spec may also be
    /// registered as a batching `op_service` under its own name — the
    /// stream service is named `<spec>/stream` so both paths coexist.
    pub fn stream_service(
        self,
        registry: &OpRegistry,
        spec: &str,
        weight: usize,
    ) -> Result<Self> {
        self.stream_service_with_ttl(registry, spec, weight, None)
    }

    /// `stream_service` with an idle-row TTL: rows abandoned mid-stream
    /// for `idle_ttl` are evicted by their lane (see `StreamService`).
    pub fn stream_service_with_ttl(
        mut self,
        registry: &OpRegistry,
        spec: &str,
        weight: usize,
        idle_ttl: Option<Duration>,
    ) -> Result<Self> {
        let (parsed, op) = registry.build(spec)?;
        anyhow::ensure!(
            op.reduction_free(),
            "op '{parsed}' carries a reduction; register it with op_service, not stream_service"
        );
        let name = format!("{parsed}/stream");
        self.stream_specs.push(StreamSpec { name, op, weight, idle_ttl });
        Ok(self)
    }

    /// Split the worker budget and start every service's pool —
    /// batching coordinators and session-affine decode pools draw from
    /// the same budget.
    pub fn start(self) -> Result<ServiceRouter> {
        anyhow::ensure!(
            !self.specs.is_empty() || !self.decode_specs.is_empty() || !self.stream_specs.is_empty(),
            "router needs at least one service"
        );
        // validate every name before spawning anything: a failure after
        // Coordinator::start would leak running worker pools
        {
            let mut seen = std::collections::BTreeSet::new();
            for name in self
                .specs
                .iter()
                .map(|s| &s.name)
                .chain(self.decode_specs.iter().map(|d| &d.name))
                .chain(self.stream_specs.iter().map(|t| &t.name))
            {
                anyhow::ensure!(!name.is_empty(), "service name must be non-empty");
                anyhow::ensure!(seen.insert(name), "duplicate service name '{name}'");
            }
        }
        let weights: Vec<usize> = self
            .specs
            .iter()
            .map(|s| s.weight.max(1))
            .chain(self.decode_specs.iter().map(|d| d.weight.max(1)))
            .chain(self.stream_specs.iter().map(|t| t.weight.max(1)))
            .collect();
        let shares = split_workers(self.total_workers, &weights);
        let (batch_shares, rest) = shares.split_at(self.specs.len());
        let (decode_shares, stream_shares) = rest.split_at(self.decode_specs.len());
        let mut services = BTreeMap::new();
        for (spec, &workers) in self.specs.into_iter().zip(batch_shares) {
            let coordinator = Coordinator::start(spec.backend, spec.policy, workers);
            services.insert(spec.name, Service { coordinator });
        }
        let mut decode = BTreeMap::new();
        for (spec, &workers) in self.decode_specs.into_iter().zip(decode_shares) {
            let service = DecodeService::start_with(spec.op, workers, spec.idle_ttl)?;
            decode.insert(spec.name, service);
        }
        let mut stream = BTreeMap::new();
        for (spec, &workers) in self.stream_specs.into_iter().zip(stream_shares) {
            let service = StreamService::start_with(spec.op, workers, spec.idle_ttl)?;
            stream.insert(spec.name, service);
        }
        Ok(ServiceRouter { services, decode, stream })
    }
}

/// One running service: a coordinator with its own queue, worker pool and
/// metrics shards.  The pool size is dynamic (`rebalance_one`), so it is
/// always read from the coordinator, never cached here.
struct Service {
    coordinator: Coordinator,
}

/// The registry of running services behind one process.
pub struct ServiceRouter {
    services: BTreeMap<String, Service>,
    decode: BTreeMap<String, DecodeService>,
    stream: BTreeMap<String, StreamService>,
}

impl ServiceRouter {
    /// Start building a router over a shared worker budget.
    pub fn builder(total_workers: usize) -> ServiceRouterBuilder {
        ServiceRouterBuilder {
            total_workers: total_workers.max(1),
            default_policy: BatchPolicy::default(),
            specs: Vec::new(),
            decode_specs: Vec::new(),
            stream_specs: Vec::new(),
        }
    }

    /// Registered batching service names, ascending (decode services are
    /// listed by [`ServiceRouter::decode_services`]).
    pub fn services(&self) -> Vec<&str> {
        self.services.keys().map(String::as_str).collect()
    }

    /// Registered decode service names, ascending.
    pub fn decode_services(&self) -> Vec<&str> {
        self.decode.keys().map(String::as_str).collect()
    }

    /// Registered stream service names, ascending.
    pub fn stream_services(&self) -> Vec<&str> {
        self.stream.keys().map(String::as_str).collect()
    }

    /// This service's metrics (None for an unknown name); decode and
    /// stream services report through the same sharded type.
    pub fn metrics(&self, service: &str) -> Option<&Arc<Metrics>> {
        self.services
            .get(service)
            .map(|s| &s.coordinator.metrics)
            .or_else(|| self.decode.get(service).map(|d| &d.metrics))
            .or_else(|| self.stream.get(service).map(|t| &t.metrics))
    }

    /// Workers serving this service right now (the initial budget split,
    /// as later adjusted by `rebalance_one`).
    pub fn workers(&self, service: &str) -> Option<usize> {
        self.services
            .get(service)
            .map(|s| s.coordinator.live_workers())
            .or_else(|| self.decode.get(service).map(|d| d.workers()))
            .or_else(|| self.stream.get(service).map(|t| t.workers()))
    }

    /// Requests parked in this service's queue (lanes summed for decode
    /// and stream services).
    pub fn queue_depth(&self, service: &str) -> Option<usize> {
        self.services
            .get(service)
            .map(|s| s.coordinator.queue_depth())
            .or_else(|| self.decode.get(service).map(|d| d.queue_depth()))
            .or_else(|| self.stream.get(service).map(|t| t.queue_depth()))
    }

    /// Accepted-but-unresolved requests for this service (queued or
    /// executing) — see `Metrics::in_flight`.
    pub fn in_flight(&self, service: &str) -> Option<u64> {
        self.metrics(service).map(|m| m.in_flight())
    }

    /// Sessions ever created by a decode service (None for unknown or
    /// batching services).
    pub fn sessions(&self, service: &str) -> Option<u64> {
        self.decode.get(service).map(|d| d.sessions())
    }

    /// Sessions currently resident in a decode service.
    pub fn live_sessions(&self, service: &str) -> Option<u64> {
        self.decode.get(service).map(|d| d.live_sessions())
    }

    /// Rows ever opened by a stream service (None for unknown or
    /// non-stream services).
    pub fn stream_rows(&self, service: &str) -> Option<u64> {
        self.stream.get(service).map(|t| t.rows())
    }

    /// Rows currently open in a stream service.
    pub fn open_rows(&self, service: &str) -> Option<u64> {
        self.stream.get(service).map(|t| t.open_rows())
    }

    /// Move one worker from `from` to `to` (both batching services —
    /// decode lanes are session-pinned and never resize).  `Ok(false)`
    /// means no move happened because `from` is at its floor of one
    /// worker; the rebalancer invariant is that no service ever serves
    /// with zero workers.
    pub fn rebalance_one(&self, from: &str, to: &str) -> Result<bool> {
        anyhow::ensure!(from != to, "rebalance needs two distinct services");
        let lookup = |name: &str| {
            self.services.get(name).with_context(|| {
                if self.decode.contains_key(name) {
                    format!("decode service '{name}' has session-pinned lanes; not rebalanceable")
                } else if self.stream.contains_key(name) {
                    format!("stream service '{name}' has row-pinned lanes; not rebalanceable")
                } else {
                    format!("unknown batching service '{name}'")
                }
            })
        };
        let from_svc = lookup(from)?;
        let to_svc = lookup(to)?;
        if from_svc.coordinator.shrink(1) == 0 {
            return Ok(false);
        }
        to_svc.coordinator.grow(1);
        Ok(true)
    }

    /// One compact line of live pressure per service — workers, queue
    /// depth, in-flight (plus resident sessions for decode) — for the
    /// `sole serve` status line and the wire `status` reply.
    pub fn load_report(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for (name, s) in &self.services {
            parts.push(format!(
                "{name}[w={} q={} if={}]",
                s.coordinator.live_workers(),
                s.coordinator.queue_depth(),
                s.coordinator.metrics.in_flight()
            ));
        }
        for (name, d) in &self.decode {
            parts.push(format!(
                "{name}[w={} q={} if={} live={}]",
                d.workers(),
                d.queue_depth(),
                d.metrics.in_flight(),
                d.live_sessions()
            ));
        }
        for (name, t) in &self.stream {
            parts.push(format!(
                "{name}[w={} q={} if={} open={}]",
                t.workers(),
                t.queue_depth(),
                t.metrics.in_flight(),
                t.open_rows()
            ));
        }
        parts.join(" ")
    }

    /// A cloneable handle routing requests by service name.
    pub fn client(&self) -> RouterClient {
        RouterClient {
            routes: Arc::new(
                self.services
                    .iter()
                    .map(|(name, s)| (name.clone(), s.coordinator.client()))
                    .collect(),
            ),
            decode_routes: Arc::new(
                self.decode.iter().map(|(name, d)| (name.clone(), d.client())).collect(),
            ),
            stream_routes: Arc::new(
                self.stream.iter().map(|(name, t)| (name.clone(), t.client())).collect(),
            ),
        }
    }

    fn all_metrics(&self) -> impl Iterator<Item = &Metrics> {
        self.services
            .values()
            .map(|s| &*s.coordinator.metrics)
            .chain(self.decode.values().map(|d| &*d.metrics))
            .chain(self.stream.values().map(|t| &*t.metrics))
    }

    /// Cross-service merged metrics line (batching + decode).
    pub fn merged_summary(&self) -> String {
        Metrics::merged_summary(self.all_metrics())
    }

    /// Cross-service merged (p50, p99, mean) end-to-end latency, seconds.
    pub fn merged_latency(&self) -> (f64, f64, f64) {
        Metrics::total_latency_of(self.all_metrics())
    }

    /// Multi-line report: one line per service plus the merged view.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (name, s) in &self.services {
            let line = format!(
                "{name} [{}w]: {}\n",
                s.coordinator.live_workers(),
                s.coordinator.metrics.summary()
            );
            out.push_str(&line);
        }
        for (name, d) in &self.decode {
            let line = format!(
                "{name} [{}w decode, {} sessions]: {}\n",
                d.workers(),
                d.sessions(),
                d.metrics.summary()
            );
            out.push_str(&line);
        }
        for (name, t) in &self.stream {
            let line = format!(
                "{name} [{}w stream, {} rows]: {}\n",
                t.workers(),
                t.rows(),
                t.metrics.summary()
            );
            out.push_str(&line);
        }
        out.push_str(&format!("merged: {}", self.merged_summary()));
        out
    }

    /// Graceful shutdown of every service — each pool drains its
    /// queue(s), so every accepted request is answered first.
    pub fn shutdown(self) {
        for (_, s) in self.services {
            s.coordinator.shutdown();
        }
        for (_, d) in self.decode {
            d.shutdown();
        }
        for (_, t) in self.stream {
            t.shutdown();
        }
    }
}

/// Routing handle: validates the service name, then defers to that
/// service's `Client` (which validates the per-service item length).
/// Decode services route through `submit_decode`/`infer_decode`, which
/// additionally carry the session id the step belongs to.
#[derive(Clone)]
pub struct RouterClient {
    routes: Arc<BTreeMap<String, Client>>,
    decode_routes: Arc<BTreeMap<String, DecodeClient>>,
    stream_routes: Arc<BTreeMap<String, StreamClient>>,
}

impl RouterClient {
    fn route(&self, service: &str) -> Result<&Client> {
        self.routes.get(service).with_context(|| {
            let known: Vec<&str> = self.routes.keys().map(String::as_str).collect();
            format!("unknown service '{service}' (registered: {})", known.join(", "))
        })
    }

    fn decode_route(&self, service: &str) -> Result<&DecodeClient> {
        self.decode_routes.get(service).with_context(|| {
            let known: Vec<&str> = self.decode_routes.keys().map(String::as_str).collect();
            format!("unknown decode service '{service}' (registered: {})", known.join(", "))
        })
    }

    fn stream_route(&self, service: &str) -> Result<&StreamClient> {
        self.stream_routes.get(service).with_context(|| {
            let known: Vec<&str> = self.stream_routes.keys().map(String::as_str).collect();
            format!("unknown stream service '{service}' (registered: {})", known.join(", "))
        })
    }

    /// Registered batching service names, ascending.
    pub fn services(&self) -> Vec<&str> {
        self.routes.keys().map(String::as_str).collect()
    }

    /// Registered decode service names, ascending.
    pub fn decode_services(&self) -> Vec<&str> {
        self.decode_routes.keys().map(String::as_str).collect()
    }

    /// Flat f32 item length `service` expects.
    pub fn item_len(&self, service: &str) -> Result<usize> {
        Ok(self.route(service)?.item_len())
    }

    /// Flat f32 length one decode step of `service` expects.
    pub fn decode_item_len(&self, service: &str) -> Result<usize> {
        Ok(self.decode_route(service)?.item_len())
    }

    /// Submit one item to `service`; returns the response receiver.
    pub fn submit(&self, service: &str, input: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.route(service)?.submit(input).with_context(|| format!("service '{service}'"))
    }

    /// Non-blocking submit to `service` (see `Client::try_submit`).
    pub fn try_submit(&self, service: &str, input: Vec<f32>) -> Result<TrySubmit> {
        self.route(service)?.try_submit(input).with_context(|| format!("service '{service}'"))
    }

    /// Blocking one-shot convenience.
    pub fn infer(&self, service: &str, input: Vec<f32>) -> Result<Response> {
        self.route(service)?.infer(input).with_context(|| format!("service '{service}'"))
    }

    /// Submit one decode step for `session` to a decode `service`; the
    /// step lands on the session's pinned lane.
    pub fn submit_decode(
        &self,
        service: &str,
        session: u64,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Response>> {
        self.decode_route(service)?
            .submit(session, input)
            .with_context(|| format!("decode service '{service}'"))
    }

    /// Blocking one-step decode convenience.
    pub fn infer_decode(&self, service: &str, session: u64, input: Vec<f32>) -> Result<Response> {
        self.decode_route(service)?
            .infer(session, input)
            .with_context(|| format!("decode service '{service}'"))
    }

    /// End a decode session explicitly, freeing its lane-resident state
    /// (blocking; idempotent — see `DecodeClient::end_session`).
    pub fn end_session(&self, service: &str, session: u64) -> Result<Response> {
        self.decode_route(service)?
            .end_session_wait(session)
            .with_context(|| format!("decode service '{service}'"))
    }

    /// Registered stream service names, ascending.
    pub fn stream_services(&self) -> Vec<&str> {
        self.stream_routes.keys().map(String::as_str).collect()
    }

    /// Submit one chunk of `row` to a stream `service`; the chunk lands
    /// on the row's pinned lane (see `StreamClient::submit`).
    pub fn submit_stream(
        &self,
        service: &str,
        row: u64,
        begin: bool,
        finish: bool,
        chunk: Vec<f32>,
    ) -> Result<mpsc::Receiver<StreamReply>> {
        self.stream_route(service)?
            .submit(row, begin, finish, chunk)
            .with_context(|| format!("stream service '{service}'"))
    }

    /// Blocking one-chunk stream convenience; the `Ok` reply still
    /// carries the typed violation arm.
    pub fn stream_chunk(
        &self,
        service: &str,
        row: u64,
        begin: bool,
        finish: bool,
        chunk: Vec<f32>,
    ) -> Result<StreamReply> {
        self.stream_route(service)?
            .chunk(row, begin, finish, chunk)
            .with_context(|| format!("stream service '{service}'"))
    }

    /// Stream a whole row through `service` in `chunk`-sized pieces and
    /// return the concatenated outputs (see `StreamClient::stream_row`).
    pub fn stream_row(
        &self,
        service: &str,
        row: u64,
        input: &[f32],
        chunk: usize,
    ) -> Result<Vec<f32>> {
        self.stream_route(service)?
            .stream_row(row, input, chunk)
            .with_context(|| format!("stream service '{service}'"))
    }
}

/// Largest-remainder split of `total` workers across `weights`, minimum
/// one worker per service (so the sum exceeds `total` when there are more
/// services than workers).  Deterministic: remainder ties break by index.
fn split_workers(total: usize, weights: &[usize]) -> Vec<usize> {
    let sum: usize = weights.iter().sum::<usize>().max(1);
    let mut shares: Vec<usize> = weights.iter().map(|&w| total * w / sum).collect();
    let assigned: usize = shares.iter().sum();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(total * weights[i] % sum), i));
    for &i in order.iter().take(total.saturating_sub(assigned)) {
        shares[i] += 1;
    }
    for s in &mut shares {
        *s = (*s).max(1);
    }
    shares
}

/// The paper's mixed software workload as registry spec strings: bit-exact
/// E2Softmax at the evaluated sequence lengths L ∈ {49, 128, 785, 1024},
/// AILayerNorm at the transformer channel width C = 768, and the fused
/// attention pipeline at the transformer head shape L = 128, D = 64 —
/// the first multi-op pipeline the system serves end to end.
pub fn paper_service_specs() -> Vec<String> {
    let mut v: Vec<String> =
        [49usize, 128, 785, 1024].iter().map(|l| format!("e2softmax/L{l}")).collect();
    v.push("ailayernorm/C768".to_string());
    v.push("attention/L128xD64".to_string());
    v
}

/// The paper workload as ready-to-register (name, backend) pairs, built
/// purely through the `OpRegistry` spec path, all bucketed 1/4/8/16.
pub fn paper_services() -> Result<Vec<(String, Arc<dyn Backend>)>> {
    let registry = OpRegistry::builtin();
    paper_service_specs()
        .iter()
        .map(|s| {
            let (spec, op) = registry.build(s)?;
            let be = Arc::new(OpBackend::try_new(op, vec![1, 4, 8, 16])?) as Arc<dyn Backend>;
            Ok((spec.to_string(), be))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{E2SoftmaxOp, Op};
    use std::time::Duration;

    fn quick_policy() -> BatchPolicy {
        BatchPolicy { max_wait: Duration::from_millis(1), max_batch: 8, queue_cap: None }
    }

    fn softmax_backend(l: usize, buckets: Vec<usize>) -> Arc<OpBackend> {
        Arc::new(OpBackend::try_new(Arc::new(E2SoftmaxOp::try_new(l).unwrap()), buckets).unwrap())
    }

    fn two_service_router(total_workers: usize) -> ServiceRouter {
        let registry = OpRegistry::builtin();
        ServiceRouter::builder(total_workers)
            .default_policy(quick_policy())
            .op_service(&registry, "e2softmax/L32", vec![1, 4, 8])
            .unwrap()
            .op_service(&registry, "ailayernorm/C64", vec![1, 4, 8])
            .unwrap()
            .start()
            .unwrap()
    }

    #[test]
    fn routes_by_service_name_and_answers() {
        let router = two_service_router(2);
        assert_eq!(router.services(), vec!["ailayernorm/C64", "e2softmax/L32"]);
        let cl = router.client();
        let sm = cl.infer("e2softmax/L32", vec![0.5; 32]).unwrap();
        assert_eq!(sm.output.len(), 32);
        let ln = cl.infer("ailayernorm/C64", vec![0.5; 64]).unwrap();
        assert_eq!(ln.output.len(), 64);
        assert_eq!(router.metrics("e2softmax/L32").unwrap().completed(), 1);
        assert_eq!(router.metrics("ailayernorm/C64").unwrap().completed(), 1);
        router.shutdown();
    }

    #[test]
    fn unknown_service_and_wrong_len_error_clearly() {
        let router = two_service_router(2);
        let cl = router.client();
        let err = format!("{:#}", cl.infer("e2softmax/L999", vec![0.0; 32]).unwrap_err());
        assert!(err.contains("unknown service"), "{err}");
        assert!(err.contains("e2softmax/L32"), "listing registered names: {err}");
        // per-service item-length validation names the service
        let err = format!("{:#}", cl.submit("e2softmax/L32", vec![0.0; 31]).unwrap_err());
        assert!(err.contains("e2softmax/L32"), "{err}");
        assert!(err.contains("31"), "{err}");
        router.shutdown();
    }

    #[test]
    fn builder_rejects_duplicates_and_empty() {
        assert!(ServiceRouter::builder(2).start().is_err());
        let dup = ServiceRouter::builder(2)
            .service("a", softmax_backend(8, vec![1]))
            .service("a", softmax_backend(8, vec![1]))
            .start();
        assert!(dup.is_err());
        let unnamed = ServiceRouter::builder(2).service("", softmax_backend(8, vec![1])).start();
        assert!(unnamed.is_err());
        // an op spec that fails to parse surfaces at registration time
        let registry = OpRegistry::builtin();
        assert!(ServiceRouter::builder(2)
            .op_service(&registry, "e2softmax/Lnope", vec![1])
            .is_err());
    }

    #[test]
    fn worker_budget_split_is_weighted_with_floor_one() {
        // equal weights: 8 workers over 4 services -> 2 each
        assert_eq!(split_workers(8, &[1, 1, 1, 1]), vec![2, 2, 2, 2]);
        // hot service takes its share, everyone keeps >= 1
        assert_eq!(split_workers(6, &[1, 1, 4]), vec![1, 1, 4]);
        // more services than workers: floor of one each
        assert_eq!(split_workers(2, &[1, 1, 1]), vec![1, 1, 1]);
        // largest remainder gets the leftover, ties by index
        assert_eq!(split_workers(5, &[1, 1, 1]), vec![2, 2, 1]);
        let total: usize = split_workers(16, &[3, 1, 1, 1]).iter().sum();
        assert_eq!(total, 16);
    }

    #[test]
    fn hot_service_receives_larger_pool() {
        let router = ServiceRouter::builder(6)
            .default_policy(quick_policy())
            .hot_service("hot", softmax_backend(16, vec![1, 4]), 4)
            .service("cold", softmax_backend(16, vec![1, 4]))
            .start()
            .unwrap();
        assert!(router.workers("hot").unwrap() > router.workers("cold").unwrap());
        assert_eq!(router.metrics("hot").unwrap().shard_count(), router.workers("hot").unwrap());
        router.shutdown();
    }

    #[test]
    fn summary_reports_per_service_and_merged() {
        let router = two_service_router(2);
        let cl = router.client();
        for _ in 0..5 {
            cl.infer("e2softmax/L32", vec![0.1; 32]).unwrap();
            cl.infer("ailayernorm/C64", vec![0.1; 64]).unwrap();
        }
        let s = router.summary();
        assert!(s.contains("e2softmax/L32"), "{s}");
        assert!(s.contains("ailayernorm/C64"), "{s}");
        assert!(s.contains("merged: accepted=10 completed=10"), "{s}");
        router.shutdown();
    }

    #[test]
    fn decode_sessions_ride_the_router() {
        let registry = OpRegistry::builtin();
        let (cap, d) = (8usize, 4usize);
        let router = ServiceRouter::builder(3)
            .default_policy(quick_policy())
            .op_service(&registry, "e2softmax/L32", vec![1, 4])
            .unwrap()
            .decode_service(&registry, "decode-attention/L8xD4", 1)
            .unwrap()
            .start()
            .unwrap();
        // decode services are listed separately; the batching list is
        // unchanged by their presence
        assert_eq!(router.services(), vec!["e2softmax/L32"]);
        assert_eq!(router.decode_services(), vec!["decode-attention/L8xD4"]);
        assert!(router.workers("decode-attention/L8xD4").unwrap() >= 1);
        let cl = router.client();
        assert_eq!(cl.decode_item_len("decode-attention/L8xD4").unwrap(), 3 * d);
        // two interleaved sessions must each match a local op replay —
        // only possible if the router pins each session to a lane that
        // keeps its KV cache across requests
        let op = crate::ops::DecodeAttnOp::try_new(cap, d).unwrap();
        let mut scratch = op.make_scratch();
        let mut rng = crate::util::rng::Rng::new(0x2007);
        let mut states = [op.make_state(), op.make_state()];
        let mut want = vec![0f32; d];
        for step in 0..cap {
            for sid in [0u64, 1] {
                let mut item = vec![0f32; 3 * d];
                rng.fill_normal(&mut item, 0.0, 1.0);
                let st = &mut states[sid as usize];
                op.run_batch_stateful(1, &item, &mut want, &mut scratch, st).unwrap();
                let got = cl.infer_decode("decode-attention/L8xD4", sid, item).unwrap();
                assert_eq!(got.output, want, "session {sid} step {step}");
            }
        }
        assert_eq!(router.sessions("decode-attention/L8xD4"), Some(2));
        assert_eq!(router.sessions("e2softmax/L32"), None);
        let m = router.metrics("decode-attention/L8xD4").unwrap();
        assert_eq!(m.completed(), 2 * cap as u64);
        // decode traffic shows up in the per-service and merged report
        cl.infer("e2softmax/L32", vec![0.1; 32]).unwrap();
        let s = router.summary();
        assert!(s.contains("decode-attention/L8xD4"), "{s}");
        assert!(s.contains("sessions"), "{s}");
        assert!(s.contains(&format!("merged: accepted={}", 2 * cap + 1)), "{s}");
        router.shutdown();
    }

    #[test]
    fn decode_registration_rejects_misuse() {
        let registry = OpRegistry::builtin();
        // a stateless op cannot be a decode service
        let err = format!(
            "{:#}",
            ServiceRouter::builder(2).decode_service(&registry, "e2softmax/L8", 1).unwrap_err()
        );
        assert!(err.contains("stateless"), "{err}");
        // duplicate names are rejected across the batching + decode lists
        let dup = ServiceRouter::builder(2)
            .decode_service(&registry, "decode-attention/L8xD4", 1)
            .unwrap()
            .decode_service(&registry, "decode-attention/L8xD4", 1)
            .unwrap()
            .start();
        assert!(dup.is_err());
        // a decode-only router is a valid router
        let router = ServiceRouter::builder(2)
            .decode_service(&registry, "decode-attention/L4xD4", 1)
            .unwrap()
            .start()
            .unwrap();
        let cl = router.client();
        assert!(cl.services().is_empty());
        // routing errors name the decode registry, not the batching one
        let err = format!("{:#}", cl.infer_decode("nope", 0, vec![0.0; 12]).unwrap_err());
        assert!(err.contains("unknown decode service"), "{err}");
        assert!(err.contains("decode-attention/L4xD4"), "{err}");
        // and a stateful spec cannot sneak into the batching path
        let err = format!(
            "{:#}",
            ServiceRouter::builder(2)
                .op_service(&registry, "decode-attention/L4xD4", vec![1])
                .unwrap_err()
        );
        assert!(err.contains("stateful"), "{err}");
        router.shutdown();
    }

    #[test]
    fn stream_rows_ride_the_router() {
        let registry = OpRegistry::builtin();
        let l = 64usize;
        let router = ServiceRouter::builder(3)
            .default_policy(quick_policy())
            .op_service(&registry, "consmax/L64", vec![1, 4])
            .unwrap()
            .stream_service(&registry, "consmax/L64", 1)
            .unwrap()
            .start()
            .unwrap();
        // the stream path coexists with the batching path for the same
        // spec under its suffixed name
        assert_eq!(router.services(), vec!["consmax/L64"]);
        assert_eq!(router.stream_services(), vec!["consmax/L64/stream"]);
        assert!(router.workers("consmax/L64/stream").unwrap() >= 1);
        let cl = router.client();
        let mut rng = crate::util::rng::Rng::new(0x2010);
        let mut x = vec![0f32; l];
        rng.fill_normal(&mut x, 0.0, 2.0);
        // chunked streaming matches the whole-row batching service bitwise
        let want = cl.infer("consmax/L64", x.clone()).unwrap().output;
        let got = cl.stream_row("consmax/L64/stream", 0, &x, 7).unwrap();
        assert_eq!(got, want);
        // a typed violation comes back through the reply, not an error
        let reply = cl.stream_chunk("consmax/L64/stream", 99, false, false, vec![0.5; 4]).unwrap();
        assert!(reply.is_err());
        assert_eq!(router.stream_rows("consmax/L64/stream"), Some(1));
        assert_eq!(router.open_rows("consmax/L64/stream"), Some(0));
        assert_eq!(router.stream_rows("consmax/L64"), None);
        // stream traffic shows up in the reports
        let s = router.summary();
        assert!(s.contains("consmax/L64/stream"), "{s}");
        assert!(s.contains("rows"), "{s}");
        assert!(router.load_report().contains("consmax/L64/stream[w="), "{}", router.load_report());
        router.shutdown();
    }

    #[test]
    fn stream_registration_rejects_misuse() {
        let registry = OpRegistry::builtin();
        // a reduction-bearing op cannot be a stream service
        let err = format!(
            "{:#}",
            ServiceRouter::builder(2).stream_service(&registry, "e2softmax/L8", 1).unwrap_err()
        );
        assert!(err.contains("carries a reduction"), "{err}");
        // a stream-only router is a valid router
        let router = ServiceRouter::builder(2)
            .stream_service(&registry, "gn-softmax/L32", 1)
            .unwrap()
            .start()
            .unwrap();
        let cl = router.client();
        assert!(cl.services().is_empty());
        // routing errors name the stream registry, not the batching one
        let err = format!("{:#}", cl.stream_row("nope", 0, &[0.5; 8], 4).unwrap_err());
        assert!(err.contains("unknown stream service"), "{err}");
        assert!(err.contains("gn-softmax/L32/stream"), "{err}");
        // stream lanes are row-pinned: not a rebalance target
        let err =
            format!("{:#}", router.rebalance_one("gn-softmax/L32/stream", "x").unwrap_err());
        assert!(err.contains("row-pinned"), "{err}");
        router.shutdown();
    }

    #[test]
    fn rebalance_moves_workers_with_floor_one() {
        let router = two_service_router(4); // 2 workers each
        let (a, b) = ("ailayernorm/C64", "e2softmax/L32");
        assert_eq!(router.workers(a), Some(2));
        assert_eq!(router.workers(b), Some(2));
        assert!(router.rebalance_one(a, b).unwrap());
        assert_eq!(router.workers(a), Some(1));
        assert_eq!(router.workers(b), Some(3));
        // the donor never drops below one worker — no move happens
        assert!(!router.rebalance_one(a, b).unwrap());
        assert_eq!(router.workers(a), Some(1));
        assert_eq!(router.workers(b), Some(3));
        // both services still answer after the move
        let cl = router.client();
        assert_eq!(cl.infer(a, vec![0.2; 64]).unwrap().output.len(), 64);
        assert_eq!(cl.infer(b, vec![0.2; 32]).unwrap().output.len(), 32);
        // pressure snapshots exist and settle to zero once drained
        assert_eq!(router.queue_depth(a), Some(0));
        assert_eq!(router.in_flight(a), Some(0));
        assert!(router.load_report().contains("e2softmax/L32[w=3"));
        // self-moves and unknown names are errors, not silent no-ops
        assert!(router.rebalance_one(a, a).is_err());
        assert!(router.rebalance_one(a, "nope").is_err());
        router.shutdown();
    }

    #[test]
    fn router_end_session_frees_decode_state() {
        let registry = OpRegistry::builtin();
        let svc = "decode-attention/L2xD4";
        let router = ServiceRouter::builder(2)
            .decode_service(&registry, svc, 1)
            .unwrap()
            .start()
            .unwrap();
        // decode lanes are session-pinned: not a rebalance target
        assert!(router.rebalance_one(svc, svc).is_err());
        let cl = router.client();
        let step = vec![0.5f32; 12];
        // fill session 0 to its cache capacity (L=2)
        cl.infer_decode(svc, 0, step.clone()).unwrap();
        cl.infer_decode(svc, 0, step.clone()).unwrap();
        assert_eq!(router.live_sessions(svc), Some(1));
        cl.end_session(svc, 0).unwrap();
        assert_eq!(router.live_sessions(svc), Some(0));
        // the reused id restarts at step 0: a continued session would be
        // at capacity and error on the next step
        cl.infer_decode(svc, 0, step.clone()).unwrap();
        assert_eq!(router.sessions(svc), Some(2));
        assert_eq!(router.metrics(svc).unwrap().errors(), 0);
        router.shutdown();
    }

    #[test]
    fn paper_services_cover_the_evaluated_shapes() {
        let svcs = paper_services().unwrap();
        let names: Vec<&str> = svcs.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "e2softmax/L49",
                "e2softmax/L128",
                "e2softmax/L785",
                "e2softmax/L1024",
                "ailayernorm/C768",
                "attention/L128xD64",
            ]
        );
        assert_eq!(names, paper_service_specs());
        let registry = OpRegistry::builtin();
        for (name, be) in &svcs {
            let (_, op) = registry.build(name).unwrap();
            assert_eq!(be.item_input_len(), op.item_len(), "{name}");
            assert_eq!(be.item_output_len(), op.out_len(), "{name}");
            assert_eq!(be.buckets(), &[1, 4, 8, 16], "{name}");
        }
        // the attention service has asymmetric item lengths
        let attn = &svcs.last().unwrap().1;
        assert_eq!(attn.item_input_len(), 3 * 128 * 64);
        assert_eq!(attn.item_output_len(), 128 * 64);
    }
}
