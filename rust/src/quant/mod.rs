//! Quantization substrate: affine INT8 + PTF calibration (FQ-ViT style).
//!
//! The Python side calibrates at build time; this Rust twin exists so the
//! coordinator can (re)calibrate on live tensors (e.g. the software
//! fallback path of `examples/op_offload.rs`) and so the behaviour is
//! testable without Python.

use crate::layernorm::config::DEFAULT_ZP;

/// Per-tensor symmetric INT8 parameters.
#[derive(Debug, Clone, Copy)]
pub struct QParams {
    pub scale: f64,
    pub zp: i64,
}

/// Symmetric per-tensor calibration: scale = max|x| / 127.
pub fn calibrate_symmetric(x: &[f32]) -> QParams {
    let m = x.iter().fold(0f32, |a, &v| a.max(v.abs())) as f64;
    QParams { scale: (m / 127.0).max(1e-12), zp: 0 }
}

pub fn quantize_i8(x: &[f32], p: QParams) -> Vec<i8> {
    x.iter()
        .map(|&v| ((v as f64 / p.scale).round() as i64 + p.zp).clamp(-128, 127) as i8)
        .collect()
}

pub fn dequantize_i8(q: &[i8], p: QParams) -> Vec<f32> {
    q.iter().map(|&v| ((v as i64 - p.zp) as f64 * p.scale) as f32).collect()
}

/// PTF calibration result for one LayerNorm instance.
#[derive(Debug, Clone)]
pub struct PtfCalib {
    /// Per-channel power-of-two factors.
    pub alpha: Vec<u8>,
    /// Layer-wise scale.
    pub s: f64,
    /// Layer-wise zero point (u8).
    pub zp: i64,
}

/// Fit PTF over rows x channels samples (rows-major), Eq. (6):
/// alpha_c = round(log2(range_c / base)) clipped to [0, alpha_max]; the
/// base is the 10th-percentile channel range, s covers the largest
/// post-shift channel.
pub fn ptf_calibrate(samples: &[f32], channels: usize, alpha_max: u8) -> PtfCalib {
    assert!(channels > 0 && samples.len() % channels == 0);
    let rows = samples.len() / channels;
    let mut r = vec![0f64; channels];
    for row in 0..rows {
        for c in 0..channels {
            let v = samples[row * channels + c].abs() as f64;
            if v > r[c] {
                r[c] = v;
            }
        }
    }
    for v in r.iter_mut() {
        *v += 1e-12;
    }
    let mut sorted = r.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let base = sorted[(channels as f64 * 0.10) as usize].max(1e-9);
    let alpha: Vec<u8> = r
        .iter()
        .map(|&rc| ((rc / base).log2().round()).clamp(0.0, alpha_max as f64) as u8)
        .collect();
    let s = r
        .iter()
        .zip(&alpha)
        .map(|(&rc, &a)| rc / 2f64.powi(a as i32))
        .fold(0.0, f64::max)
        / 127.0;
    PtfCalib { alpha, s, zp: DEFAULT_ZP }
}

/// PTF-quantize one row with a calibration.
pub fn ptf_quantize(x: &[f32], cal: &PtfCalib) -> Vec<u8> {
    let mut out = Vec::with_capacity(x.len());
    ptf_quantize_into(x, cal, &mut out);
    out
}

/// Exact 2^-a as f64 via exponent-bit construction (a <= 255 stays far
/// above the subnormal range) — the hot-path stand-in for
/// `2f64.powi(-(a as i32))`: two integer ops, no libm call.
#[inline]
fn pow2_neg(a: u8) -> f64 {
    f64::from_bits((1023 - a as u64) << 52)
}

/// One row of PTF quantization appended to `out`.  The per-element work is
/// two multiplies: the layer-scale reciprocal is hoisted out of the loop
/// (one extra rounding vs a direct divide — codes can differ from the
/// pre-hoist ones only when `v/s` lands within an ulp of a .5 rounding
/// boundary, and every consumer quantizes through this same function), and
/// scaling by 2^-a is exact.
fn ptf_append_row(x: &[f32], cal: &PtfCalib, out: &mut Vec<u8>) {
    let inv_s = 1.0 / cal.s;
    out.extend(x.iter().zip(&cal.alpha).map(|(&v, &a)| {
        let q = v as f64 * inv_s * pow2_neg(a);
        (q.round() as i64 + cal.zp).clamp(0, 255) as u8
    }));
}

/// PTF-quantize one row into a reusable buffer — the coordinator's
/// software layernorm backend uses this so steady-state quantization
/// allocates nothing.
pub fn ptf_quantize_into(x: &[f32], cal: &PtfCalib, out: &mut Vec<u8>) {
    out.clear();
    ptf_append_row(x, cal, out);
}

/// Row codec of the op layer's `PtfU8` staging port (`ops/port.rs`): the
/// degenerate per-row PTF — `alpha = 0` on every channel, zero point
/// [`DEFAULT_ZP`] — with the layer scale fitted per row (`max|x| / 127`),
/// so one normalized row spans the full u8 code range.  Writes one code
/// per element and returns the row scale for the port's f32 sidecar.
/// Degenerate rows get scale 0 and every code at the zero point
/// (dequantizing back to exact zero): all-zero and all-NaN rows leave the
/// NaN-ignoring max at 0, a row containing ±Inf makes it non-finite.
pub fn q8_quantize_row_into(x: &[f32], codes: &mut [u8]) -> f32 {
    assert_eq!(x.len(), codes.len(), "codes buffer must match the row");
    let m = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
    if m == 0.0 || !m.is_finite() {
        codes.fill(DEFAULT_ZP as u8);
        return 0.0;
    }
    let scale = m / 127.0;
    // hoisted reciprocal, f64 rounding: same policy as `ptf_append_row`
    let inv_s = 1.0 / scale as f64;
    for (c, &v) in codes.iter_mut().zip(x) {
        let q = (v as f64 * inv_s).round() as i64 + DEFAULT_ZP;
        *c = q.clamp(0, 255) as u8;
    }
    scale
}

/// Dequantize one `PtfU8`-port code with its row scale — the exact
/// inverse grid of [`q8_quantize_row_into`], shared by the dequant
/// adapter and the conformance references so every consumer widens
/// through the same arithmetic.
pub fn q8_dequantize(code: u8, scale: f32) -> f32 {
    (code as i64 - DEFAULT_ZP) as f32 * scale
}

/// Batch variant: `x` is a packed planar batch of rows, each
/// `cal.alpha.len()` channels; row-for-row identical to
/// `ptf_quantize_into` (the calibration is per-channel, so batching is
/// pure layout).
pub fn ptf_quantize_batch_into(x: &[f32], cal: &PtfCalib, out: &mut Vec<u8>) {
    let c = cal.alpha.len();
    assert!(c > 0, "calibration must cover at least one channel");
    assert!(x.len() % c == 0, "packed batch len {} is not a multiple of {c}", x.len());
    out.clear();
    out.reserve(x.len());
    for row in x.chunks_exact(c) {
        ptf_append_row(row, cal, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn symmetric_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..256).map(|_| (rng.normal() * 3.0) as f32).collect();
        let p = calibrate_symmetric(&x);
        let back = dequantize_i8(&quantize_i8(&x, p), p);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() as f64 <= p.scale * 0.5 + 1e-9);
        }
    }

    #[test]
    fn ptf_assigns_bigger_alpha_to_bigger_channels() {
        let mut rng = Rng::new(2);
        let channels = 32;
        let rows = 64;
        let mut samples = vec![0f32; rows * channels];
        for row in 0..rows {
            for c in 0..channels {
                let scale = if c == 5 { 16.0 } else { 1.0 };
                samples[row * channels + c] = (rng.normal() * scale) as f32;
            }
        }
        let cal = ptf_calibrate(&samples, channels, 5);
        let a5 = cal.alpha[5];
        let amed = {
            let mut v = cal.alpha.clone();
            v.sort_unstable();
            v[channels / 2]
        };
        assert!(a5 > amed, "alpha[5]={a5} median={amed}");
    }

    #[test]
    fn ptf_quantize_in_code_range() {
        let mut rng = Rng::new(3);
        let channels = 16;
        let samples: Vec<f32> = (0..channels * 8).map(|_| rng.normal() as f32).collect();
        let cal = ptf_calibrate(&samples, channels, 5);
        let q = ptf_quantize(&samples[..channels], &cal);
        assert!(q.iter().all(|&c| (0..=255).contains(&(c as i64))));
    }

    #[test]
    fn pow2_neg_matches_powi() {
        for a in 0u8..=255 {
            assert_eq!(pow2_neg(a), 2f64.powi(-(a as i32)), "a={a}");
        }
    }

    #[test]
    fn ptf_batch_matches_per_row() {
        let mut rng = Rng::new(6);
        let channels = 24;
        let rows = 5;
        let samples: Vec<f32> =
            (0..channels * rows).map(|_| (rng.normal() * 2.0) as f32).collect();
        let cal = ptf_calibrate(&samples, channels, 5);
        let mut batch = Vec::new();
        ptf_quantize_batch_into(&samples, &cal, &mut batch);
        assert_eq!(batch.len(), samples.len());
        let mut row = Vec::new();
        for r in 0..rows {
            ptf_quantize_into(&samples[r * channels..(r + 1) * channels], &cal, &mut row);
            assert_eq!(&batch[r * channels..(r + 1) * channels], &row[..], "row {r}");
        }
    }

    #[test]
    fn q8_row_codec_roundtrip_error_bounded() {
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..96).map(|_| (rng.normal() * 2.5) as f32).collect();
        let mut codes = vec![0u8; 96];
        let scale = q8_quantize_row_into(&x, &mut codes);
        assert!(scale > 0.0);
        // the row max must hit the edge of the code range exactly
        let m = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
        assert_eq!(scale, m / 127.0);
        for (i, (&v, &c)) in x.iter().zip(&codes).enumerate() {
            let back = q8_dequantize(c, scale);
            assert!((v - back).abs() <= scale * 0.5 + 1e-6, "elem {i}: {v} vs {back}");
        }
    }

    #[test]
    fn q8_zero_and_nonfinite_rows_collapse_to_the_zero_point() {
        let mut codes = vec![1u8; 8];
        assert_eq!(q8_quantize_row_into(&[0.0; 8], &mut codes), 0.0);
        assert!(codes.iter().all(|&c| c as i64 == DEFAULT_ZP));
        assert!(codes.iter().all(|&c| q8_dequantize(c, 0.0) == 0.0));
        let mut codes = vec![1u8; 4];
        assert_eq!(q8_quantize_row_into(&[f32::NAN, f32::INFINITY, 1.0, -2.0], &mut codes), 0.0);
        assert!(codes.iter().all(|&c| c as i64 == DEFAULT_ZP));
    }

    #[test]
    fn ptf_reconstruction_decent() {
        let mut rng = Rng::new(4);
        let channels = 64;
        let rows = 32;
        let mut samples = vec![0f32; rows * channels];
        for (i, v) in samples.iter_mut().enumerate() {
            let c = i % channels;
            let scale = if c % 11 == 0 { 8.0 } else { 1.0 };
            *v = (rng.normal() * scale) as f32;
        }
        let cal = ptf_calibrate(&samples, channels, 5);
        let row = &samples[..channels];
        let q = ptf_quantize(row, &cal);
        let mut err = 0f64;
        let mut sig = 0f64;
        for c in 0..channels {
            let scale = cal.s * 2f64.powi(cal.alpha[c] as i32);
            let back = (q[c] as i64 - cal.zp) as f64 * scale;
            err += (back - row[c] as f64).powi(2);
            sig += (row[c] as f64).powi(2);
        }
        assert!((err / sig).sqrt() < 0.05, "rel {}", (err / sig).sqrt());
    }
}
