//! Fixed-point arithmetic substrate (DESIGN.md §5 item 4).
//!
//! Everything SOLE's datapaths need: arithmetic (floor) shifts that mirror
//! hardware, leading-one detection, round-half-up division by powers of
//! two, saturation, and Mitchell's logarithmic multiply/divide (the basis
//! of the paper's Approximate Log-based Division).

/// Arithmetic right shift that matches hardware/Python semantics (floor).
/// Rust's `>>` on signed ints is already arithmetic; this exists to make
/// call sites self-documenting and to guard the shift amount.
#[inline]
pub fn asr(v: i64, n: u32) -> i64 {
    debug_assert!(n < 64);
    v >> n
}

/// Round-half-up of `v / 2^n` for `v >= 0` (the hardware "add half then
/// truncate" rounder used at the Log2Exp output).
#[inline]
pub fn round_half_up_shift(v: i64, n: u32) -> i64 {
    debug_assert!(v >= 0 && n < 63);
    (v + (1 << (n - 1))) >> n
}

/// Position of the leading one (floor(log2(v))) — the LOD block.
#[inline]
pub fn leading_one(v: u64) -> u32 {
    debug_assert!(v > 0);
    63 - v.leading_zeros()
}

/// Saturate to `[0, 2^bits - 1]`.
#[inline]
pub fn sat_u(v: i64, bits: u32) -> i64 {
    v.clamp(0, (1 << bits) - 1)
}

/// Saturate to signed `bits`-bit two's complement range.
#[inline]
pub fn sat_s(v: i64, bits: u32) -> i64 {
    let hi = (1 << (bits - 1)) - 1;
    v.clamp(-hi - 1, hi)
}

/// Mitchell logarithm: for X = 2^k (1 + x), returns (k, x_q) with the
/// fractional part x in Q(`frac_bits`).  Eq. (3) of the paper.
#[inline]
pub fn mitchell_log2(v: u64, frac_bits: u32) -> (u32, u64) {
    let k = leading_one(v);
    let mantissa = v - (1u64 << k); // v - 2^k in [0, 2^k)
    let x = if k >= frac_bits {
        mantissa >> (k - frac_bits)
    } else {
        mantissa << (frac_bits - k)
    };
    (k, x)
}

/// Mitchell antilog: 2^(k + x/2^frac) ~ 2^k (1 + x/2^frac).
#[inline]
pub fn mitchell_exp2(k: u32, x: u64, frac_bits: u32) -> u64 {
    let base = 1u64 << k;
    if k >= frac_bits {
        base + (x << (k - frac_bits))
    } else {
        base + (x >> (frac_bits - k))
    }
}

/// Mitchell division X1/X2 via log-domain subtraction — Eq. (4)/(5).
/// Returns the quotient in Q(`out_frac`).
pub fn mitchell_div(x1: u64, x2: u64, out_frac: u32) -> u64 {
    debug_assert!(x1 > 0 && x2 > 0);
    const F: u32 = 24;
    let (k1, f1) = mitchell_log2(x1, F);
    let (k2, f2) = mitchell_log2(x2, F);
    let kd = k1 as i64 - k2 as i64;
    let fd = f1 as i64 - f2 as i64;
    // Eq. (5): borrow from the characteristic when the fraction is negative
    let (kq, mant) = if fd < 0 {
        (kd - 1, (2i64 << F) + fd) // 2 + (x1 - x2), in Q(F)
    } else {
        (kd, (1i64 << F) + fd) // 1 + (x1 - x2)
    };
    let shift = kq + out_frac as i64 - F as i64;
    if shift >= 0 {
        (mant as u64) << shift
    } else if shift > -64 {
        (mant as u64) >> (-shift)
    } else {
        0
    }
}

/// A value in Q(int.frac) notation used by the unit models for
/// self-describing intermediates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Q {
    pub raw: i64,
    pub frac: u32,
}

impl Q {
    pub fn from_f64(v: f64, frac: u32) -> Q {
        Q { raw: (v * (1i64 << frac) as f64).round() as i64, frac }
    }

    pub fn to_f64(self) -> f64 {
        self.raw as f64 / (1i64 << self.frac) as f64
    }

    /// Rescale to a different fraction width (floor on narrowing).
    pub fn rescale(self, frac: u32) -> Q {
        let raw = if frac >= self.frac {
            self.raw << (frac - self.frac)
        } else {
            self.raw >> (self.frac - frac)
        };
        Q { raw, frac }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn asr_is_floor() {
        assert_eq!(asr(-7, 1), -4); // floor(-3.5)
        assert_eq!(asr(7, 1), 3);
        assert_eq!(asr(-1, 4), -1);
    }

    #[test]
    fn round_half_up() {
        assert_eq!(round_half_up_shift(7, 1), 4); // 3.5 -> 4
        assert_eq!(round_half_up_shift(5, 1), 3); // 2.5 -> 3
        assert_eq!(round_half_up_shift(4, 2), 1); // 1.0 -> 1
        assert_eq!(round_half_up_shift(5, 2), 1); // 1.25 -> 1
        assert_eq!(round_half_up_shift(6, 2), 2); // 1.5 -> 2
    }

    #[test]
    fn lod() {
        assert_eq!(leading_one(1), 0);
        assert_eq!(leading_one(2), 1);
        assert_eq!(leading_one(3), 1);
        assert_eq!(leading_one(1 << 40), 40);
    }

    #[test]
    fn saturation() {
        assert_eq!(sat_u(300, 8), 255);
        assert_eq!(sat_u(-5, 8), 0);
        assert_eq!(sat_s(200, 8), 127);
        assert_eq!(sat_s(-200, 8), -128);
    }

    #[test]
    fn mitchell_log_exact_at_powers() {
        for k in 0..40 {
            let (kk, x) = mitchell_log2(1u64 << k, 16);
            assert_eq!((kk, x), (k, 0));
        }
    }

    #[test]
    fn mitchell_roundtrip_error_bounded() {
        check("mitchell-roundtrip", 200, 11, |rng| {
            let v = rng.range_i64(1, 1 << 40) as u64;
            let (k, x) = mitchell_log2(v, 24);
            let back = mitchell_exp2(k, x, 24);
            // exact up to the mantissa truncation: one LSB at 2^(k-frac)
            let lsb = 1i64 << (k as i64 - 24).max(0);
            let err = (back as i64 - v as i64).abs();
            assert!(err <= lsb, "v={v} back={back} lsb={lsb}");
        });
    }

    #[test]
    fn mitchell_div_error_within_known_bound() {
        // Mitchell's division relative error is bounded by ~11% on either
        // side (two +-8.6% log approximations partially cancel)
        check("mitchell-div", 500, 13, |rng| {
            let a = rng.range_i64(1, 1 << 30) as u64;
            let b = rng.range_i64(1, 1 << 30) as u64;
            let q = mitchell_div(a, b, 24) as f64 / (1u64 << 24) as f64;
            let exact = a as f64 / b as f64;
            let rel = q / exact - 1.0;
            assert!((-0.14..=0.14).contains(&rel), "a={a} b={b} rel={rel}");
        });
    }

    #[test]
    fn q_format_roundtrip() {
        let q = Q::from_f64(1.636, 23);
        assert!((q.to_f64() - 1.636).abs() < 1e-6);
        let r = q.rescale(8);
        assert!((r.to_f64() - 1.636).abs() < 0.01);
        assert_eq!(r.rescale(23).frac, 23);
    }
}
