//! End-to-end latency composition: GPU matmuls + nonlinear ops on either
//! the GPU or the SOLE units.  Drives Fig 1(a) and Fig 6(b).

use crate::hw::gpu;
use crate::hw::units::HwUnit;
use crate::hw::{AiLayerNormUnit, E2SoftmaxUnit};

use super::PaperModel;

/// Where each op class executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Everything FP32 on the GPU.
    Fp32Gpu,
    /// INT8 matmuls on tensor cores; Softmax/LayerNorm still FP32 on GPU
    /// (the paper's "INT8" bars — the non-linear bottleneck remains).
    Int8Gpu,
    /// INT8 matmuls + Softmax/LayerNorm offloaded to the SOLE units.
    Int8Sole,
}

/// Latency breakdown in seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub matmul: f64,
    pub softmax: f64,
    pub layernorm: f64,
    pub elementwise: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.matmul + self.softmax + self.layernorm + self.elementwise
    }

    pub fn nonlinear_share(&self) -> f64 {
        (self.softmax + self.layernorm) / self.total()
    }
}

/// Number of SOLE units in the scaled-up comparison (paper: 32, to match
/// a 32-lane MAC datapath's throughput).
pub const SOLE_UNITS: usize = 32;

/// Compose the end-to-end latency of `model` at `batch` under `mode`.
pub fn latency(model: &PaperModel, batch: usize, mode: ExecMode) -> Breakdown {
    let int8 = mode != ExecMode::Fp32Gpu;
    let mut b = Breakdown::default();

    for (m, n, k, count) in model.gemms(batch) {
        b.matmul += gpu::gemm_time(m, n, k, int8) * count as f64;
    }
    b.elementwise = gpu::elementwise_time(model.elementwise_elems(batch), 2.0);

    match mode {
        ExecMode::Fp32Gpu | ExecMode::Int8Gpu => {
            for w in model.softmax_work(batch) {
                b.softmax += gpu::softmax_time(w.rows, w.len) * w.kernels as f64;
            }
            for w in model.layernorm_work(batch) {
                b.layernorm += gpu::layernorm_time(w.rows, w.len) * w.kernels as f64;
            }
        }
        ExecMode::Int8Sole => {
            let sm = E2SoftmaxUnit::default();
            let ln = AiLayerNormUnit::default();
            for w in model.softmax_work(batch) {
                b.softmax += sm.seconds(w.rows, w.len, SOLE_UNITS) * w.kernels as f64;
            }
            for w in model.layernorm_work(batch) {
                b.layernorm += ln.seconds(w.rows, w.len, SOLE_UNITS) * w.kernels as f64;
            }
        }
    }
    b
}

/// Standalone nonlinear-op comparison for Fig 6(a): (gpu_time, sole_time).
pub fn softmax_gpu_vs_sole(model: &PaperModel, batch: usize) -> (f64, f64) {
    let sm = E2SoftmaxUnit::default();
    let mut tg = 0.0;
    let mut ts = 0.0;
    for w in model.softmax_work(batch) {
        tg += gpu::softmax_time(w.rows, w.len) * w.kernels as f64;
        ts += sm.seconds(w.rows, w.len, SOLE_UNITS) * w.kernels as f64;
    }
    (tg, ts)
}

pub fn layernorm_gpu_vs_sole(model: &PaperModel, batch: usize) -> (f64, f64) {
    let ln = AiLayerNormUnit::default();
    let mut tg = 0.0;
    let mut ts = 0.0;
    for w in model.layernorm_work(batch) {
        tg += gpu::layernorm_time(w.rows, w.len) * w.kernels as f64;
        ts += ln.seconds(w.rows, w.len, SOLE_UNITS) * w.kernels as f64;
    }
    (tg, ts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deit_t() -> PaperModel {
        PaperModel::deit("deit_t", 192, 3)
    }

    #[test]
    fn int8_speedup_band_matches_paper() {
        // paper Fig 6(b): INT8 over FP32 only 1.10-1.28x
        for batch in [1usize, 4, 8, 16] {
            let f = latency(&deit_t(), batch, ExecMode::Fp32Gpu).total();
            let i = latency(&deit_t(), batch, ExecMode::Int8Gpu).total();
            let s = f / i;
            assert!(s > 1.02 && s < 1.45, "batch {batch}: int8 speedup {s}");
        }
    }

    #[test]
    fn sole_speedup_band_matches_paper() {
        // paper Fig 6(b): INT8+SOLE reaches 1.50-2.09x over FP32
        for batch in [1usize, 4, 8, 16] {
            let f = latency(&deit_t(), batch, ExecMode::Fp32Gpu).total();
            let s = latency(&deit_t(), batch, ExecMode::Int8Sole).total();
            let sp = f / s;
            assert!(sp > 1.3 && sp < 2.6, "batch {batch}: sole speedup {sp}");
        }
    }

    #[test]
    fn nonlinear_share_grows_under_int8() {
        // Fig 1(a): quantizing matmuls inflates the Softmax/LN share
        let f = latency(&deit_t(), 8, ExecMode::Fp32Gpu);
        let i = latency(&deit_t(), 8, ExecMode::Int8Gpu);
        assert!(i.nonlinear_share() > f.nonlinear_share());
        assert!(i.nonlinear_share() > 0.25, "share {}", i.nonlinear_share());
    }

    #[test]
    fn standalone_softmax_speedup_in_paper_band() {
        // paper Fig 6(a): 29.3-57.5x for softmax across batch 1..16
        for batch in [1usize, 2, 4, 8, 16] {
            let (g, s) = softmax_gpu_vs_sole(&deit_t(), batch);
            let sp = g / s;
            assert!(sp > 15.0 && sp < 90.0, "batch {batch}: {sp}");
        }
    }

    #[test]
    fn standalone_layernorm_speedup_in_paper_band() {
        // paper Fig 6(a): 38.4-86.8x for layernorm
        for batch in [1usize, 2, 4, 8, 16] {
            let (g, s) = layernorm_gpu_vs_sole(&deit_t(), batch);
            let sp = g / s;
            assert!(sp > 15.0 && sp < 140.0, "batch {batch}: {sp}");
        }
    }
}

#[cfg(test)]
mod calib_probe {
    use super::*;

    #[test]
    fn probe_breakdowns() {
        let m = PaperModel::deit("deit_t", 192, 3);
        for batch in [1usize, 4, 8, 16] {
            let f = latency(&m, batch, ExecMode::Fp32Gpu);
            let i = latency(&m, batch, ExecMode::Int8Gpu);
            let s = latency(&m, batch, ExecMode::Int8Sole);
            println!(
                "b={batch:2} fp32: mm={:.2}ms sm={:.2}ms ln={:.2}ms ew={:.2}ms share={:.2} | int8 {:.2}x | sole {:.2}x",
                f.matmul * 1e3, f.softmax * 1e3, f.layernorm * 1e3, f.elementwise * 1e3,
                f.nonlinear_share(),
                f.total() / i.total(),
                f.total() / s.total()
            );
        }
    }
}
