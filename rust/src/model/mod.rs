//! Transformer workload descriptions (DESIGN.md §5 item 9).
//!
//! The *paper-shape* model zoo: DeiT-T/S/B at 448x448 (785 tokens — the
//! Fig 1/6 setting), Swin-T/S/B (stage pyramid, 7x7 = 49-token windows)
//! and BERT-Base.  These drive the hardware evaluation (op inventories,
//! softmax/LN row counts, latency composition); the *accuracy* surrogates
//! live on the Python side (artifacts/manifest.json).

pub mod latency;

/// One pipeline stage of an encoder (plain ViT/BERT models have one).
#[derive(Debug, Clone, Copy)]
pub struct Stage {
    pub depth: usize,
    pub dim: usize,
    pub heads: usize,
    pub tokens: usize,
    /// Softmax row length: `tokens` for global attention, window size for
    /// Swin-style windowed attention.
    pub attn_len: usize,
}

/// A transformer's workload description.
#[derive(Debug, Clone)]
pub struct PaperModel {
    pub name: &'static str,
    pub stages: Vec<Stage>,
}

/// One op-level workload item for the nonlinear units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowWork {
    /// Number of rows (per batch element).
    pub rows: usize,
    /// Elements per row.
    pub len: usize,
    /// Kernels launched on GPU for this work (one per layer).
    pub kernels: usize,
}

impl PaperModel {
    /// DeiT at 448x448 (patch 16 -> 28x28 + cls = 785 tokens).
    pub fn deit(name: &'static str, dim: usize, heads: usize) -> PaperModel {
        PaperModel {
            name,
            stages: vec![Stage { depth: 12, dim, heads, tokens: 785, attn_len: 785 }],
        }
    }

    /// Swin at 224x224: stage pyramid with 7x7 windows.
    pub fn swin(name: &'static str, base_dim: usize, depths: [usize; 4], base_heads: usize) -> PaperModel {
        let tokens = [3136, 784, 196, 49];
        let stages = (0..4)
            .map(|i| Stage {
                depth: depths[i],
                dim: base_dim << i,
                heads: base_heads << i,
                tokens: tokens[i],
                attn_len: 49,
            })
            .collect();
        PaperModel { name, stages }
    }

    pub fn bert_base(seq: usize) -> PaperModel {
        PaperModel {
            name: "bert_base",
            stages: vec![Stage { depth: 12, dim: 768, heads: 12, tokens: seq, attn_len: seq }],
        }
    }

    /// The paper's evaluation zoo.
    pub fn zoo() -> Vec<PaperModel> {
        vec![
            PaperModel::deit("deit_t", 192, 3),
            PaperModel::deit("deit_s", 384, 6),
            PaperModel::deit("deit_b", 768, 12),
            PaperModel::swin("swin_t", 96, [2, 2, 6, 2], 3),
            PaperModel::swin("swin_s", 96, [2, 2, 18, 2], 3),
            PaperModel::swin("swin_b", 128, [2, 2, 18, 2], 4),
        ]
    }

    pub fn by_name(name: &str) -> Option<PaperModel> {
        match name {
            "deit_t" => Some(PaperModel::deit("deit_t", 192, 3)),
            "deit_s" => Some(PaperModel::deit("deit_s", 384, 6)),
            "deit_b" => Some(PaperModel::deit("deit_b", 768, 12)),
            "swin_t" => Some(PaperModel::swin("swin_t", 96, [2, 2, 6, 2], 3)),
            "swin_s" => Some(PaperModel::swin("swin_s", 96, [2, 2, 18, 2], 3)),
            "swin_b" => Some(PaperModel::swin("swin_b", 128, [2, 2, 18, 2], 4)),
            "bert_base" => Some(PaperModel::bert_base(128)),
            _ => None,
        }
    }

    /// Softmax work per batch element: rows of attn_len per layer.
    pub fn softmax_work(&self, batch: usize) -> Vec<RowWork> {
        self.stages
            .iter()
            .map(|s| {
                let windows = s.tokens / s.attn_len;
                RowWork {
                    rows: batch * s.heads * windows * s.attn_len,
                    len: s.attn_len,
                    kernels: s.depth,
                }
            })
            .collect()
    }

    /// LayerNorm work per batch element: 2 LNs per layer, rows = tokens,
    /// row length = dim.
    pub fn layernorm_work(&self, batch: usize) -> Vec<RowWork> {
        self.stages
            .iter()
            .map(|s| RowWork { rows: batch * s.tokens, len: s.dim, kernels: 2 * s.depth })
            .collect()
    }

    /// GEMM inventory per layer of each stage:
    /// (m, n, k) x count, per batch element.
    pub fn gemms(&self, batch: usize) -> Vec<(usize, usize, usize, usize)> {
        let mut out = Vec::new();
        for s in &self.stages {
            let t = s.tokens * batch;
            let d = s.dim;
            // qkv, attn-logits, attn-v, proj, mlp-in, mlp-out per layer
            out.push((t, 3 * d, d, s.depth));
            out.push((t, s.attn_len, d, s.depth)); // q k^T (per-head folded)
            out.push((t, d, s.attn_len, s.depth)); // probs v
            out.push((t, d, d, s.depth));
            out.push((t, 4 * d, d, s.depth));
            out.push((t, d, 4 * d, s.depth));
        }
        out
    }

    /// Elementwise element count per batch element (GELU + residuals).
    pub fn elementwise_elems(&self, batch: usize) -> usize {
        self.stages
            .iter()
            .map(|s| batch * s.tokens * s.dim * s.depth * 6)
            .sum()
    }

    pub fn total_softmax_rows(&self, batch: usize) -> usize {
        self.softmax_work(batch).iter().map(|w| w.rows * w.kernels).sum()
    }

    pub fn total_layernorm_rows(&self, batch: usize) -> usize {
        self.layernorm_work(batch).iter().map(|w| w.rows * w.kernels).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deit_t_shapes_match_paper() {
        let m = PaperModel::deit("deit_t", 192, 3);
        let sw = m.softmax_work(1);
        assert_eq!(sw.len(), 1);
        assert_eq!(sw[0].rows, 3 * 785); // heads x tokens
        assert_eq!(sw[0].len, 785);
        assert_eq!(sw[0].kernels, 12);
        let lw = m.layernorm_work(1);
        assert_eq!(lw[0].rows, 785);
        assert_eq!(lw[0].len, 192);
        assert_eq!(lw[0].kernels, 24);
    }

    #[test]
    fn swin_windows_shrink_rows() {
        let m = PaperModel::swin("swin_t", 96, [2, 2, 6, 2], 3);
        let sw = m.softmax_work(1);
        assert_eq!(sw.len(), 4);
        // stage 0: 3136 tokens in 64 windows of 49
        assert_eq!(sw[0].len, 49);
        assert_eq!(sw[0].rows, 3 * 64 * 49);
        // deepest stage: 1 window
        assert_eq!(sw[3].rows, 24 * 49);
    }

    #[test]
    fn batch_scales_rows_linearly() {
        let m = PaperModel::bert_base(128);
        assert_eq!(m.total_softmax_rows(4), 4 * m.total_softmax_rows(1));
        assert_eq!(m.total_layernorm_rows(8), 8 * m.total_layernorm_rows(1));
    }

    #[test]
    fn zoo_all_resolvable() {
        for m in PaperModel::zoo() {
            assert!(PaperModel::by_name(m.name).is_some());
        }
    }
}
