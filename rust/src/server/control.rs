//! Adaptive control plane: admission control, load shedding, and the
//! worker rebalancer (DESIGN.md §5.3).
//!
//! Both controllers are driven by the per-service sharded metrics the
//! coordinators already collect — queue depth, in-flight count, and
//! p99 latency — no second bookkeeping layer is introduced.
//!
//! **Admission** is checked per request on the connection threads, so
//! it must be cheap: queue depth is one lock, in-flight is three
//! relaxed atomic loads, and p99 — which requires merging histogram
//! shards — is *sampled* by the control thread into a lock-free board
//! and only read on the request path.  The p99 histograms are
//! cumulative over the run, so a past overload would latch the gate
//! shut forever; the p99 rule therefore only sheds while the service
//! also has current congestion (queue deeper than its worker count).
//!
//! **Rebalancing** compares per-worker queue pressure across batching
//! services and moves one worker per tick from the coldest donor to the
//! hottest service (`ServiceRouter::rebalance_one`).  Invariants: a
//! donor never drops below one worker, decode services never
//! participate (their lanes are session-pinned), and at most one
//! worker moves per tick so a bursty minute cannot slosh the whole
//! pool back and forth.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::ServiceRouter;

/// Per-request admission limits.  `None` disables that rule; with every
/// rule disabled (the default) the gate always admits and only the
/// bounded queue itself sheds.
#[derive(Debug, Clone, Default)]
pub struct AdmissionConfig {
    /// Shed when the service's queue depth reaches this.
    pub max_queue_depth: Option<usize>,
    /// Shed when accepted-but-unresolved requests reach this.
    pub max_in_flight: Option<u64>,
    /// Shed when sampled p99 latency exceeds this *and* the queue is
    /// deeper than the service's live worker count (see module docs).
    pub max_p99: Option<Duration>,
}

/// Why a request was shed at admission.
#[derive(Debug, Clone, PartialEq)]
pub enum ShedReason {
    QueueDepth { depth: usize, limit: usize },
    InFlight { in_flight: u64, limit: u64 },
    P99 { p99: Duration, limit: Duration },
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::QueueDepth { depth, limit } => {
                write!(f, "queue depth {depth} >= limit {limit}")
            }
            ShedReason::InFlight { in_flight, limit } => {
                write!(f, "in-flight {in_flight} >= limit {limit}")
            }
            ShedReason::P99 { p99, limit } => {
                write!(
                    f,
                    "p99 {:.2}ms > limit {:.2}ms under congestion",
                    p99.as_secs_f64() * 1e3,
                    limit.as_secs_f64() * 1e3
                )
            }
        }
    }
}

/// Rebalancer tuning.
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    /// How often the rebalancer evaluates a move.
    pub interval: Duration,
    /// Minimum per-worker queue-pressure gap between the hottest and
    /// coldest service before a worker moves.
    pub min_gap: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig { interval: Duration::from_millis(250), min_gap: 2.0 }
    }
}

/// Sampled p99 per service, written by the control thread and read
/// lock-free on the request path (f64 seconds as bits).
pub(crate) struct P99Board {
    entries: BTreeMap<String, AtomicU64>,
}

impl P99Board {
    fn new(services: &[String]) -> P99Board {
        P99Board {
            entries: services
                .iter()
                .map(|s| (s.clone(), AtomicU64::new(0f64.to_bits())))
                .collect(),
        }
    }

    fn store(&self, service: &str, p99_s: f64) {
        if let Some(e) = self.entries.get(service) {
            e.store(p99_s.to_bits(), Ordering::Relaxed);
        }
    }

    fn load(&self, service: &str) -> f64 {
        self.entries.get(service).map_or(0.0, |e| f64::from_bits(e.load(Ordering::Relaxed)))
    }
}

/// The per-request admission gate.
pub(crate) struct Shedder {
    router: Arc<ServiceRouter>,
    cfg: AdmissionConfig,
    board: Arc<P99Board>,
}

impl Shedder {
    pub(crate) fn admit(&self, service: &str) -> Result<(), ShedReason> {
        let cfg = &self.cfg;
        if cfg.max_queue_depth.is_none() && cfg.max_in_flight.is_none() && cfg.max_p99.is_none() {
            return Ok(());
        }
        let depth = self.router.queue_depth(service).unwrap_or(0);
        if let Some(limit) = cfg.max_queue_depth {
            if depth >= limit {
                return Err(ShedReason::QueueDepth { depth, limit });
            }
        }
        if let Some(limit) = cfg.max_in_flight {
            let in_flight = self.router.in_flight(service).unwrap_or(0);
            if in_flight >= limit {
                return Err(ShedReason::InFlight { in_flight, limit });
            }
        }
        if let Some(limit) = cfg.max_p99 {
            let workers = self.router.workers(service).unwrap_or(1);
            if depth > workers {
                let p99 = Duration::from_secs_f64(self.board.load(service));
                if p99 > limit {
                    return Err(ShedReason::P99 { p99, limit });
                }
            }
        }
        Ok(())
    }
}

/// Pick one worker move from per-service `(name, queue_depth, workers)`
/// loads: the coldest donor with spare workers gives one to the hottest
/// service, if the per-worker pressure gap is at least `min_gap`.
/// Returns `(from, to)` indices, or `None` when balanced (or no donor
/// has more than its floor worker).
pub fn plan_move(loads: &[(String, usize, usize)], min_gap: f64) -> Option<(usize, usize)> {
    if loads.len() < 2 {
        return None;
    }
    let pressure =
        |&(_, depth, workers): &(String, usize, usize)| depth as f64 / (workers.max(1)) as f64;
    let (hot, hot_p) = loads
        .iter()
        .enumerate()
        .map(|(i, l)| (i, pressure(l)))
        .max_by(|a, b| a.1.total_cmp(&b.1))?;
    let (cold, cold_p) = loads
        .iter()
        .enumerate()
        .filter(|(i, l)| *i != hot && l.2 > 1)
        .map(|(i, l)| (i, pressure(l)))
        .min_by(|a, b| a.1.total_cmp(&b.1))?;
    if hot_p - cold_p >= min_gap {
        Some((cold, hot))
    } else {
        None
    }
}

/// The background control thread: samples p99 into the board on every
/// tick and (optionally) evaluates one rebalance move per interval.
pub(crate) struct ControlPlane {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ControlPlane {
    /// Spawn over `router`.  Returns the plane plus the shedder sharing
    /// its p99 board.
    pub(crate) fn spawn(
        router: Arc<ServiceRouter>,
        admission: AdmissionConfig,
        rebalance: Option<RebalanceConfig>,
    ) -> (ControlPlane, Shedder) {
        let names: Vec<String> = router
            .services()
            .iter()
            .chain(router.decode_services().iter())
            .map(|s| s.to_string())
            .collect();
        let batch_names: Vec<String> = router.services().iter().map(|s| s.to_string()).collect();
        let board = Arc::new(P99Board::new(&names));
        let stop = Arc::new(AtomicBool::new(false));
        let shedder = Shedder { router: router.clone(), cfg: admission, board: board.clone() };
        let tick = Duration::from_millis(25)
            .min(rebalance.as_ref().map_or(Duration::from_millis(25), |r| r.interval));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut since_rebalance = Duration::ZERO;
            while !stop2.load(Ordering::SeqCst) {
                for name in &names {
                    if let Some(m) = router.metrics(name) {
                        let (_, p99, _) = m.total_latency();
                        board.store(name, p99);
                    }
                }
                if let Some(rb) = &rebalance {
                    since_rebalance += tick;
                    if since_rebalance >= rb.interval {
                        since_rebalance = Duration::ZERO;
                        let loads: Vec<(String, usize, usize)> = batch_names
                            .iter()
                            .map(|n| {
                                (
                                    n.clone(),
                                    router.queue_depth(n).unwrap_or(0),
                                    router.workers(n).unwrap_or(1),
                                )
                            })
                            .collect();
                        if let Some((from, to)) = plan_move(&loads, rb.min_gap) {
                            let _ = router.rebalance_one(&loads[from].0, &loads[to].0);
                        }
                    }
                }
                std::thread::sleep(tick);
            }
        });
        (ControlPlane { stop, handle: Some(handle) }, shedder)
    }

    /// Stop and join the control thread.
    pub(crate) fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(v: &[(&str, usize, usize)]) -> Vec<(String, usize, usize)> {
        v.iter().map(|&(n, d, w)| (n.to_string(), d, w)).collect()
    }

    #[test]
    fn plan_move_targets_the_hot_service() {
        // hot has 12 queued over 2 workers (6/worker), cold is idle with
        // 2 workers -> move one cold worker to hot
        let l = loads(&[("hot", 12, 2), ("cold", 0, 2)]);
        assert_eq!(plan_move(&l, 2.0), Some((1, 0)));
        // order independence: indices follow the slice, not the names
        let l = loads(&[("cold", 0, 2), ("hot", 12, 2)]);
        assert_eq!(plan_move(&l, 2.0), Some((0, 1)));
    }

    #[test]
    fn plan_move_respects_the_floor_and_the_gap() {
        // the only cold donor is at one worker: no move, ever
        let l = loads(&[("hot", 50, 2), ("cold", 0, 1)]);
        assert_eq!(plan_move(&l, 2.0), None);
        // balanced load: gap below threshold, no move
        let l = loads(&[("a", 4, 2), ("b", 3, 2)]);
        assert_eq!(plan_move(&l, 2.0), None);
        // single service or empty: nothing to balance
        assert_eq!(plan_move(&loads(&[("a", 99, 4)]), 2.0), None);
        assert_eq!(plan_move(&[], 2.0), None);
    }

    #[test]
    fn plan_move_picks_the_coldest_donor_among_several() {
        let l = loads(&[("hot", 40, 2), ("warm", 8, 2), ("cool", 2, 2), ("idle", 0, 3)]);
        // hottest is "hot" (20/worker), coldest donor is "idle" (0/worker)
        assert_eq!(plan_move(&l, 2.0), Some((3, 0)));
    }

    #[test]
    fn zero_worker_entries_do_not_divide_by_zero() {
        let l = loads(&[("a", 10, 0), ("b", 0, 2)]);
        // pressure for a clamps workers to 1; b is the only donor
        assert_eq!(plan_move(&l, 2.0), Some((1, 0)));
    }
}
