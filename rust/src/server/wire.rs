//! Wire protocol for the TCP front door (DESIGN.md §5.3).
//!
//! Frames are `u32` little-endian length prefixes followed by that many
//! body bytes; the first body byte is the message type.  Everything
//! multi-byte is little-endian, payloads are raw `f32` bit patterns —
//! the point of the protocol is that the bytes that leave the server are
//! the same bits `RouterClient::infer` would have returned in-process,
//! so bit-exactness survives the socket.
//!
//! Decoding is strict: truncated bodies, non-UTF-8 service names,
//! payload lengths that disagree with their declared counts, unknown
//! message types, and trailing bytes are all rejected as typed
//! [`ErrCode::Malformed`] errors rather than best-effort parses.  A
//! frame whose declared length exceeds the cap is reported *before*
//! reading the body ([`FrameRead::TooLarge`]) because the stream is
//! unrecoverable past that point — the server answers with
//! [`ErrCode::FrameTooLarge`] and closes.
//!
//! This module is pure encode/decode over `io::Read`/`io::Write` (plus
//! in-memory slices), so every frame shape is unit-testable without a
//! socket; the connection-handling policy (timeouts, shedding, the
//! stop flag) lives in the server module.

use std::fmt;
use std::io::{Read, Write};

use anyhow::Result;

/// Hard cap on one frame's body (64 MiB) — far above any real batch
/// (the largest paper item, attention/L1024xD64, is ~1.5 MiB), low
/// enough that a corrupt length prefix cannot OOM the server.
pub const MAX_FRAME: u32 = 64 << 20;

const MSG_INFER: u8 = 1;
const MSG_DECODE: u8 = 2;
const MSG_END_SESSION: u8 = 3;
const MSG_STATUS: u8 = 4;
const MSG_SHUTDOWN: u8 = 5;
const MSG_STREAM: u8 = 6;

/// Flag bit on a stream chunk: this chunk opens its row.
pub const STREAM_BEGIN: u8 = 1;
/// Flag bit on a stream chunk: this chunk closes its row.
pub const STREAM_FINISH: u8 = 2;

const RESP_OUTPUT: u8 = 0x80;
const RESP_ERROR: u8 = 0x81;
const RESP_TEXT: u8 = 0x82;

/// Typed rejection codes carried by error responses, so clients can
/// distinguish "shed, retry later" from "your frame is garbage" without
/// string matching.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// The frame could not be decoded (truncation, bad UTF-8, trailing
    /// bytes, unknown message type).
    Malformed = 1,
    /// No service with that name is registered.
    UnknownService = 2,
    /// The payload length does not match the service's item length.
    BadItemLen = 3,
    /// Load-shed: the admission controller or the bounded queue turned
    /// the request away.  Retryable by construction.
    Shed = 4,
    /// The server is draining for shutdown.
    ShuttingDown = 5,
    /// The request was accepted but its batch failed server-side.
    Internal = 6,
    /// The declared frame length exceeds the server's cap; the
    /// connection is closed after this error.
    FrameTooLarge = 7,
    /// A chunk-streaming rule was broken (chunk on a row that is not
    /// open, re-begin of an open row, empty chunk).  The connection and
    /// the row-id space stay usable; only the offending chunk is
    /// rejected.
    StreamProtocol = 8,
}

impl ErrCode {
    pub fn from_u8(v: u8) -> Option<ErrCode> {
        match v {
            1 => Some(ErrCode::Malformed),
            2 => Some(ErrCode::UnknownService),
            3 => Some(ErrCode::BadItemLen),
            4 => Some(ErrCode::Shed),
            5 => Some(ErrCode::ShuttingDown),
            6 => Some(ErrCode::Internal),
            7 => Some(ErrCode::FrameTooLarge),
            8 => Some(ErrCode::StreamProtocol),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ErrCode::Malformed => "malformed",
            ErrCode::UnknownService => "unknown-service",
            ErrCode::BadItemLen => "bad-item-len",
            ErrCode::Shed => "shed",
            ErrCode::ShuttingDown => "shutting-down",
            ErrCode::Internal => "internal",
            ErrCode::FrameTooLarge => "frame-too-large",
            ErrCode::StreamProtocol => "stream-protocol",
        }
    }
}

impl fmt::Display for ErrCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A typed wire-level rejection: code plus a human-readable detail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub code: ErrCode,
    pub msg: String,
}

impl WireError {
    pub fn new(code: ErrCode, msg: impl Into<String>) -> WireError {
        WireError { code, msg: msg.into() }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.msg)
    }
}

impl std::error::Error for WireError {}

/// Client-to-server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// One item for a batching service.
    Infer { service: String, input: Vec<f32> },
    /// One decode step for `session` on a decode service.
    Decode { service: String, session: u64, input: Vec<f32> },
    /// Free a decode session's state explicitly.
    EndSession { service: String, session: u64 },
    /// One chunk of one row for a stream service.  `flags` is a bitmask
    /// of [`STREAM_BEGIN`] / [`STREAM_FINISH`]; rows are keyed by the
    /// client-chosen `row` id, so chunks of different rows may
    /// interleave on one connection.  Because each chunk is its own
    /// frame, the row length is unbounded by [`MAX_FRAME`].
    Stream { service: String, row: u64, flags: u8, chunk: Vec<f32> },
    /// Ask for the live status report.
    Status,
    /// Ask the server to shut down gracefully.
    Shutdown,
}

/// Server-to-client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Resp {
    /// A served request: the output bits plus the same timing the
    /// in-process `Response` carries.
    Output { output: Vec<f32>, queue_s: f64, exec_s: f64, batch: u32 },
    /// A typed rejection.
    Error(WireError),
    /// Human-readable text (status reports, shutdown acks).
    Text(String),
}

/// Strict little-endian cursor over one frame body.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, off: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.b.len()).ok_or_else(|| {
            WireError::new(
                ErrCode::Malformed,
                format!("truncated frame: wanted {n} bytes at offset {}", self.off),
            )
        })?;
        let s = &self.b[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String, WireError> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| WireError::new(ErrCode::Malformed, "service name is not UTF-8"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| {
            WireError::new(ErrCode::Malformed, "f32 count overflows the frame")
        })?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn text(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| WireError::new(ErrCode::Malformed, "text is not UTF-8"))
    }

    fn finish(self) -> Result<(), WireError> {
        if self.off == self.b.len() {
            Ok(())
        } else {
            Err(WireError::new(
                ErrCode::Malformed,
                format!("{} trailing bytes after message", self.b.len() - self.off),
            ))
        }
    }
}

fn put_name(out: &mut Vec<u8>, name: &str) {
    let n = name.len().min(u16::MAX as usize) as u16;
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&name.as_bytes()[..n as usize]);
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_text(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Encode one client message as a frame body (no length prefix).
pub fn encode_msg(msg: &Msg) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Msg::Infer { service, input } => {
            out.push(MSG_INFER);
            put_name(&mut out, service);
            put_f32s(&mut out, input);
        }
        Msg::Decode { service, session, input } => {
            out.push(MSG_DECODE);
            put_name(&mut out, service);
            out.extend_from_slice(&session.to_le_bytes());
            put_f32s(&mut out, input);
        }
        Msg::EndSession { service, session } => {
            out.push(MSG_END_SESSION);
            put_name(&mut out, service);
            out.extend_from_slice(&session.to_le_bytes());
        }
        Msg::Stream { service, row, flags, chunk } => {
            out.push(MSG_STREAM);
            put_name(&mut out, service);
            out.extend_from_slice(&row.to_le_bytes());
            out.push(*flags);
            put_f32s(&mut out, chunk);
        }
        Msg::Status => out.push(MSG_STATUS),
        Msg::Shutdown => out.push(MSG_SHUTDOWN),
    }
    out
}

/// Decode one client message from a frame body.
pub fn decode_msg(body: &[u8]) -> Result<Msg, WireError> {
    let mut c = Cur::new(body);
    let msg = match c.u8()? {
        MSG_INFER => Msg::Infer { service: c.name()?, input: c.f32s()? },
        MSG_DECODE => Msg::Decode { service: c.name()?, session: c.u64()?, input: c.f32s()? },
        MSG_END_SESSION => Msg::EndSession { service: c.name()?, session: c.u64()? },
        MSG_STREAM => {
            let service = c.name()?;
            let row = c.u64()?;
            let flags = c.u8()?;
            if flags & !(STREAM_BEGIN | STREAM_FINISH) != 0 {
                return Err(WireError::new(
                    ErrCode::Malformed,
                    format!("unknown stream flags {flags:#04x}"),
                ));
            }
            Msg::Stream { service, row, flags, chunk: c.f32s()? }
        }
        MSG_STATUS => Msg::Status,
        MSG_SHUTDOWN => Msg::Shutdown,
        t => {
            return Err(WireError::new(ErrCode::Malformed, format!("unknown message type {t}")));
        }
    };
    c.finish()?;
    Ok(msg)
}

/// Encode one server response as a frame body (no length prefix).
pub fn encode_resp(resp: &Resp) -> Vec<u8> {
    let mut out = Vec::new();
    match resp {
        Resp::Output { output, queue_s, exec_s, batch } => {
            out.push(RESP_OUTPUT);
            put_f32s(&mut out, output);
            out.extend_from_slice(&queue_s.to_le_bytes());
            out.extend_from_slice(&exec_s.to_le_bytes());
            out.extend_from_slice(&batch.to_le_bytes());
        }
        Resp::Error(e) => {
            out.push(RESP_ERROR);
            out.push(e.code as u8);
            put_text(&mut out, &e.msg);
        }
        Resp::Text(s) => {
            out.push(RESP_TEXT);
            put_text(&mut out, s);
        }
    }
    out
}

/// Decode one server response from a frame body.
pub fn decode_resp(body: &[u8]) -> Result<Resp, WireError> {
    let mut c = Cur::new(body);
    let resp = match c.u8()? {
        RESP_OUTPUT => {
            let output = c.f32s()?;
            let queue_s = c.f64()?;
            let exec_s = c.f64()?;
            let batch = c.u32()?;
            Resp::Output { output, queue_s, exec_s, batch }
        }
        RESP_ERROR => {
            let raw = c.u8()?;
            let code = ErrCode::from_u8(raw).ok_or_else(|| {
                WireError::new(ErrCode::Malformed, format!("unknown error code {raw}"))
            })?;
            Resp::Error(WireError { code, msg: c.text()? })
        }
        RESP_TEXT => Resp::Text(c.text()?),
        t => {
            return Err(WireError::new(ErrCode::Malformed, format!("unknown response type {t}")));
        }
    };
    c.finish()?;
    Ok(resp)
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> std::io::Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Outcome of reading one frame off a stream.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete frame body.
    Frame(Vec<u8>),
    /// Clean end-of-stream at a frame boundary.
    Eof,
    /// The declared length exceeds the cap; the body was *not* read, so
    /// the stream is desynchronized and must be closed.
    TooLarge(u32),
}

/// Blocking read of one frame.  EOF exactly at a frame boundary is
/// `Eof`; EOF mid-frame is an `UnexpectedEof` error.
pub fn read_frame(r: &mut impl Read, max: u32) -> std::io::Result<FrameRead> {
    let mut hdr = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = r.read(&mut hdr[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(FrameRead::Eof);
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "eof mid frame header",
            ));
        }
        got += n;
    }
    let len = u32::from_le_bytes(hdr);
    if len > max {
        return Ok(FrameRead::TooLarge(len));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    Ok(FrameRead::Frame(body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_msg(msg: Msg) {
        let body = encode_msg(&msg);
        assert_eq!(decode_msg(&body).unwrap(), msg);
    }

    fn roundtrip_resp(resp: Resp) {
        let body = encode_resp(&resp);
        assert_eq!(decode_resp(&body).unwrap(), resp);
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip_msg(Msg::Infer {
            service: "e2softmax/L64".into(),
            input: vec![0.0, -1.5, f32::MIN_POSITIVE, 1e30],
        });
        roundtrip_msg(Msg::Infer { service: "x".into(), input: vec![] });
        roundtrip_msg(Msg::Decode {
            service: "decode-attention/L8xD4".into(),
            session: u64::MAX,
            input: vec![1.0; 12],
        });
        roundtrip_msg(Msg::EndSession { service: "d".into(), session: 7 });
        roundtrip_msg(Msg::Stream {
            service: "consmax/L128/stream".into(),
            row: 42,
            flags: STREAM_BEGIN,
            chunk: vec![0.5, -3.0, f32::NEG_INFINITY],
        });
        roundtrip_msg(Msg::Stream {
            service: "gn-softmax/L64/stream".into(),
            row: u64::MAX,
            flags: STREAM_BEGIN | STREAM_FINISH,
            chunk: vec![1.0; 9],
        });
        roundtrip_msg(Msg::Stream { service: "s".into(), row: 0, flags: 0, chunk: vec![] });
        roundtrip_msg(Msg::Status);
        roundtrip_msg(Msg::Shutdown);
    }

    #[test]
    fn every_response_roundtrips() {
        roundtrip_resp(Resp::Output {
            output: vec![0.25, -0.0, f32::NAN.to_bits() as f32],
            queue_s: 0.001,
            exec_s: 2.5e-6,
            batch: 16,
        });
        roundtrip_resp(Resp::Output { output: vec![], queue_s: 0.0, exec_s: 0.0, batch: 1 });
        for code in [
            ErrCode::Malformed,
            ErrCode::UnknownService,
            ErrCode::BadItemLen,
            ErrCode::Shed,
            ErrCode::ShuttingDown,
            ErrCode::Internal,
            ErrCode::FrameTooLarge,
            ErrCode::StreamProtocol,
        ] {
            assert_eq!(ErrCode::from_u8(code as u8), Some(code));
            roundtrip_resp(Resp::Error(WireError::new(code, format!("detail for {code}"))));
        }
        roundtrip_resp(Resp::Text("line one\nline two".into()));
    }

    #[test]
    fn f32_bits_survive_the_wire_exactly() {
        // bit-exactness is the contract: encode/decode must preserve the
        // exact bit pattern, including negative zero and NaN payloads
        let tricky =
            vec![f32::from_bits(0x8000_0000), f32::from_bits(0x7FC0_1234), f32::MIN, f32::MAX];
        let body = encode_msg(&Msg::Infer { service: "s".into(), input: tricky.clone() });
        match decode_msg(&body).unwrap() {
            Msg::Infer { input, .. } => {
                let got: Vec<u32> = input.iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = tricky.iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn malformed_bodies_are_typed_rejections() {
        // empty body
        assert_eq!(decode_msg(&[]).unwrap_err().code, ErrCode::Malformed);
        // unknown message type
        assert_eq!(decode_msg(&[99]).unwrap_err().code, ErrCode::Malformed);
        // truncated: name length promises more bytes than exist
        let err = decode_msg(&[MSG_INFER, 10, 0, b'a']).unwrap_err();
        assert_eq!(err.code, ErrCode::Malformed);
        assert!(err.msg.contains("truncated"), "{err}");
        // bad utf-8 name
        let mut body = vec![MSG_INFER, 2, 0, 0xFF, 0xFE];
        body.extend_from_slice(&0u32.to_le_bytes());
        assert_eq!(decode_msg(&body).unwrap_err().code, ErrCode::Malformed);
        // declared f32 count larger than payload
        let mut body = vec![MSG_INFER, 1, 0, b's'];
        body.extend_from_slice(&5u32.to_le_bytes());
        body.extend_from_slice(&1.0f32.to_le_bytes());
        assert_eq!(decode_msg(&body).unwrap_err().code, ErrCode::Malformed);
        // trailing junk after a complete message
        let mut body = encode_msg(&Msg::Status);
        body.push(0);
        let err = decode_msg(&body).unwrap_err();
        assert_eq!(err.code, ErrCode::Malformed);
        assert!(err.msg.contains("trailing"), "{err}");
        // stream chunk with undefined flag bits set
        let mut body = vec![MSG_STREAM, 1, 0, b's'];
        body.extend_from_slice(&7u64.to_le_bytes());
        body.push(0x80);
        body.extend_from_slice(&0u32.to_le_bytes());
        let err = decode_msg(&body).unwrap_err();
        assert_eq!(err.code, ErrCode::Malformed);
        assert!(err.msg.contains("stream flags"), "{err}");
        // stream chunk truncated before its payload
        let mut body = vec![MSG_STREAM, 1, 0, b's'];
        body.extend_from_slice(&7u64.to_le_bytes());
        assert_eq!(decode_msg(&body).unwrap_err().code, ErrCode::Malformed);
        // responses are just as strict
        assert_eq!(decode_resp(&[0x7F]).unwrap_err().code, ErrCode::Malformed);
        assert_eq!(decode_resp(&[RESP_ERROR, 200]).unwrap_err().code, ErrCode::Malformed);
    }

    #[test]
    fn frames_roundtrip_over_io() {
        let bodies = [
            encode_msg(&Msg::Status),
            encode_msg(&Msg::Infer { service: "s".into(), input: vec![1.0; 7] }),
        ];
        let mut buf = Vec::new();
        for b in &bodies {
            write_frame(&mut buf, b).unwrap();
        }
        let mut r = &buf[..];
        for b in &bodies {
            match read_frame(&mut r, MAX_FRAME).unwrap() {
                FrameRead::Frame(got) => assert_eq!(&got, b),
                other => panic!("expected a frame, got {other:?}"),
            }
        }
        assert!(matches!(read_frame(&mut r, MAX_FRAME).unwrap(), FrameRead::Eof));
    }

    #[test]
    fn oversized_and_truncated_frames_are_detected() {
        // a frame that declares more than the cap is reported unread
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut r = &buf[..];
        match read_frame(&mut r, MAX_FRAME).unwrap() {
            FrameRead::TooLarge(n) => assert_eq!(n, MAX_FRAME + 1),
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // eof mid-header and mid-body are hard errors, not clean Eof
        let mut r: &[u8] = &[1, 0];
        assert!(read_frame(&mut r, MAX_FRAME).is_err());
        let mut buf = Vec::new();
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]);
        let mut r = &buf[..];
        assert!(read_frame(&mut r, MAX_FRAME).is_err());
    }
}
