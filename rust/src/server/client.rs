//! Blocking TCP client for the front door's wire protocol.
//!
//! One `NetClient` owns one connection and speaks strict
//! request/response framing over it.  The server handles a
//! connection's frames strictly in order, one at a time — so a single
//! connection carries at most one request through the router, and
//! offered load past capacity is generated with many *connections*
//! (one client thread each; see `bench_serving`'s overload phase and
//! the server integration tests).  The split-phase API (`send_infer` +
//! `recv_reply`) still lets one client queue a bounded window of
//! frames to hide round-trip latency; replies match sends by position.
//!
//! A typed server rejection (shed, unknown service, malformed, …) is
//! *data*, not an error: it comes back as [`Reply::Rejected`] so
//! callers can count sheds without string-matching.  Transport-level
//! failures (connection closed, timeouts) are `Err`.

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{Context, Result};

use super::wire::{self, FrameRead, Msg, Resp, WireError, STREAM_BEGIN, STREAM_FINISH};

/// A served response: the output plus server-side timing.
#[derive(Debug, Clone)]
pub struct NetResponse {
    pub output: Vec<f32>,
    pub queue_s: f64,
    pub exec_s: f64,
    pub batch: u32,
}

/// What one request came back as.
#[derive(Debug, Clone)]
pub enum Reply {
    /// Served.
    Output(NetResponse),
    /// Typed rejection (shed, unknown service, bad length, …).
    Rejected(WireError),
    /// Text payload (status / shutdown acks).
    Text(String),
}

/// One blocking connection to a front door.
pub struct NetClient {
    stream: TcpStream,
}

impl NetClient {
    /// Connect to `addr` (e.g. `127.0.0.1:7411`) with a read/write
    /// timeout applied to every subsequent operation.
    pub fn connect(addr: &str, timeout: Duration) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(timeout)).context("set read timeout")?;
        stream.set_write_timeout(Some(timeout)).context("set write timeout")?;
        Ok(NetClient { stream })
    }

    fn send(&mut self, msg: &Msg) -> Result<()> {
        wire::write_frame(&mut self.stream, &wire::encode_msg(msg)).context("send frame")
    }

    /// Read one reply frame (blocking, bounded by the connect timeout).
    pub fn recv_reply(&mut self) -> Result<Reply> {
        let body = match wire::read_frame(&mut self.stream, wire::MAX_FRAME)? {
            FrameRead::Frame(b) => b,
            FrameRead::Eof => anyhow::bail!("server closed the connection"),
            FrameRead::TooLarge(n) => anyhow::bail!("server sent an oversized frame ({n} bytes)"),
        };
        Ok(match wire::decode_resp(&body).context("decode server response")? {
            Resp::Output { output, queue_s, exec_s, batch } => {
                Reply::Output(NetResponse { output, queue_s, exec_s, batch })
            }
            Resp::Error(e) => Reply::Rejected(e),
            Resp::Text(s) => Reply::Text(s),
        })
    }

    /// Queue one infer request without waiting for its reply (pipelining;
    /// replies come back in send order).
    pub fn send_infer(&mut self, service: &str, input: &[f32]) -> Result<()> {
        self.send(&Msg::Infer { service: service.to_string(), input: input.to_vec() })
    }

    /// One blocking infer round-trip.
    pub fn infer(&mut self, service: &str, input: &[f32]) -> Result<Reply> {
        self.send_infer(service, input)?;
        self.recv_reply()
    }

    /// One blocking decode-step round-trip for `session`.
    pub fn infer_decode(&mut self, service: &str, session: u64, input: &[f32]) -> Result<Reply> {
        self.send(&Msg::Decode { service: service.to_string(), session, input: input.to_vec() })?;
        self.recv_reply()
    }

    /// Free a decode session's server-side state.
    pub fn end_session(&mut self, service: &str, session: u64) -> Result<Reply> {
        self.send(&Msg::EndSession { service: service.to_string(), session })?;
        self.recv_reply()
    }

    /// Queue one chunk of `row` for a stream service without waiting
    /// for its reply (pipelining; replies come back in send order).
    pub fn send_stream_chunk(
        &mut self,
        service: &str,
        row: u64,
        begin: bool,
        finish: bool,
        chunk: &[f32],
    ) -> Result<()> {
        let flags = if begin { STREAM_BEGIN } else { 0 } | if finish { STREAM_FINISH } else { 0 };
        self.send(&Msg::Stream { service: service.to_string(), row, flags, chunk: chunk.to_vec() })
    }

    /// One blocking stream-chunk round-trip.
    pub fn stream_chunk(
        &mut self,
        service: &str,
        row: u64,
        begin: bool,
        finish: bool,
        chunk: &[f32],
    ) -> Result<Reply> {
        self.send_stream_chunk(service, row, begin, finish, chunk)?;
        self.recv_reply()
    }

    /// Stream a whole row through a stream service in `chunk`-sized
    /// pieces and return the concatenated outputs.  Because each chunk
    /// travels in its own frame, `input` may be far longer than the
    /// service's registered `L` (or than one frame could carry).  Any
    /// typed rejection mid-row is returned as an error naming the code.
    pub fn stream_row(
        &mut self,
        service: &str,
        row: u64,
        input: &[f32],
        chunk: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(chunk > 0, "chunk size must be positive");
        anyhow::ensure!(!input.is_empty(), "cannot stream an empty row");
        let last = input.len().div_ceil(chunk) - 1;
        let mut out = Vec::with_capacity(input.len());
        for (i, piece) in input.chunks(chunk).enumerate() {
            match self.stream_chunk(service, row, i == 0, i == last, piece)? {
                Reply::Output(r) => out.extend_from_slice(&r.output),
                Reply::Rejected(e) => {
                    return Err(anyhow::anyhow!("chunk {i} of row {row} rejected: {e}"));
                }
                Reply::Text(s) => anyhow::bail!("chunk {i} of row {row} got text reply: {s}"),
            }
        }
        Ok(out)
    }

    /// Fetch the server's live status report.
    pub fn status(&mut self) -> Result<String> {
        self.send(&Msg::Status)?;
        match self.recv_reply()? {
            Reply::Text(s) => Ok(s),
            Reply::Rejected(e) => Err(e.into()),
            Reply::Output(_) => anyhow::bail!("status got an output frame"),
        }
    }

    /// Ask the server to shut down gracefully; returns its ack text.
    pub fn shutdown_server(&mut self) -> Result<String> {
        self.send(&Msg::Shutdown)?;
        match self.recv_reply()? {
            Reply::Text(s) => Ok(s),
            Reply::Rejected(e) => Err(e.into()),
            Reply::Output(_) => anyhow::bail!("shutdown got an output frame"),
        }
    }
}
