//! The TCP front door (DESIGN.md §5.3): network ingress for a
//! [`ServiceRouter`].
//!
//! Layering, outside in:
//!
//! * an **accept thread** polls a non-blocking listener and hands each
//!   socket to a bounded connection queue — when every connection slot
//!   is taken *and* the queue is full, the connection itself is shed
//!   with a typed error instead of parking unboundedly;
//! * a fixed pool of **connection threads** speaks the wire protocol
//!   ([`wire`]), one frame in → one frame out, in order, per
//!   connection.  Socket reads poll in short slices so a connection
//!   blocked on an idle peer still observes server shutdown and its
//!   own idle timeout;
//! * each request passes the **admission gate** ([`AdmissionConfig`])
//!   and then [`RouterClient::try_submit`] — a full bounded queue
//!   propagates to the socket as a typed [`ErrCode::Shed`] rather than
//!   blocking the connection thread, so backpressure reaches clients
//!   instead of accumulating in the server;
//! * the **control plane** ([`control`]) samples p99 for the gate and
//!   periodically rebalances workers toward hot services.
//!
//! Conservation extends to the wire: every decoded request frame is
//! answered by exactly one response frame (output or typed error), and
//! the router-side ledger `offered == completed + errors + shed` is
//! checked in the integration tests with real sockets in the loop.
//! Chunked streaming ([`Msg::Stream`]) keeps the same one-frame-in /
//! one-frame-out discipline — each chunk is answered by its own output
//! or typed [`ErrCode::StreamProtocol`] error — so a row of unbounded
//! length never needs an unbounded frame.
//!
//! Everything is std::thread + blocking sockets, consistent with the
//! coordinator's design (no async runtime in the vendor set); a fixed
//! connection pool is the honest shape for a worker-bound serving
//! system — overload policy should be explicit (shed) rather than
//! hidden in unbounded accept queues.

pub mod client;
pub mod control;
pub mod wire;

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{RouterClient, ServiceRouter, TrySubmit};

pub use client::{NetClient, NetResponse, Reply};
pub use control::{plan_move, AdmissionConfig, RebalanceConfig, ShedReason};
pub use wire::{ErrCode, WireError, STREAM_BEGIN, STREAM_FINISH};

use control::{ControlPlane, Shedder};
use wire::{Msg, Resp};

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Connection-handler threads (concurrent connections served).
    pub conn_threads: usize,
    /// Accepted sockets that may wait for a free handler before new
    /// connections are shed.
    pub pending_conns: usize,
    /// Idle read timeout: a connection sending no frame for this long
    /// is closed.
    pub read_timeout: Duration,
    /// Per-frame write timeout (a client not draining its socket cannot
    /// wedge a handler forever).
    pub write_timeout: Duration,
    /// Largest accepted frame body.
    pub max_frame: u32,
    /// Per-request admission limits.
    pub admission: AdmissionConfig,
    /// Worker rebalancing; `None` keeps the static split.
    pub rebalance: Option<RebalanceConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            conn_threads: 4,
            pending_conns: 16,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
            max_frame: wire::MAX_FRAME,
            admission: AdmissionConfig::default(),
            rebalance: None,
        }
    }
}

/// State shared by the accept thread, connection handlers, and the
/// owning [`Server`] handle.
struct Inner {
    router: Arc<ServiceRouter>,
    client: RouterClient,
    cfg: ServerConfig,
    shedder: Shedder,
    stop: AtomicBool,
    /// Set when a wire `shutdown` message arrives; `Server::wait`
    /// observes it.
    shutdown_requested: Mutex<bool>,
    shutdown_cv: Condvar,
    conns_served: AtomicU64,
    conns_shed: AtomicU64,
}

impl Inner {
    fn request_shutdown(&self) {
        *self.shutdown_requested.lock().unwrap() = true;
        self.shutdown_cv.notify_all();
    }
}

/// A running front door.  Owns the accept thread, the connection pool,
/// and the control plane; `shutdown` tears all of it down and returns
/// the router for final metrics.
pub struct Server {
    inner: Arc<Inner>,
    accept: Option<JoinHandle<()>>,
    pool: Vec<JoinHandle<()>>,
    control: Option<ControlPlane>,
    addr: SocketAddr,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving `router` over it.
    pub fn start(router: ServiceRouter, addr: &str, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("local addr")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let router = Arc::new(router);
        let (control, shedder) =
            ControlPlane::spawn(router.clone(), cfg.admission.clone(), cfg.rebalance.clone());
        let client = router.client();
        let conn_threads = cfg.conn_threads.max(1);
        let pending = cfg.pending_conns.max(1);
        let inner = Arc::new(Inner {
            router,
            client,
            cfg,
            shedder,
            stop: AtomicBool::new(false),
            shutdown_requested: Mutex::new(false),
            shutdown_cv: Condvar::new(),
            conns_served: AtomicU64::new(0),
            conns_shed: AtomicU64::new(0),
        });
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(pending);
        let rx = Arc::new(Mutex::new(rx));
        let mut pool = Vec::new();
        for _ in 0..conn_threads {
            let rx = rx.clone();
            let inner = inner.clone();
            pool.push(std::thread::spawn(move || loop {
                // handlers take one socket at a time; when the sender is
                // gone (accept thread exited) the pool drains and stops
                let sock = match rx.lock().unwrap().recv() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                inner.conns_served.fetch_add(1, Ordering::Relaxed);
                handle_conn(sock, &inner);
            }));
        }
        let accept_inner = inner.clone();
        let accept = std::thread::spawn(move || loop {
            if accept_inner.stop.load(Ordering::SeqCst) {
                return; // dropping `tx` stops the idle pool threads
            }
            match listener.accept() {
                Ok((sock, _peer)) => match tx.try_send(sock) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(sock)) => {
                        // connection-level shed: every handler busy and
                        // the pending queue full — tell the client and
                        // close instead of queueing unboundedly
                        accept_inner.conns_shed.fetch_add(1, Ordering::Relaxed);
                        shed_connection(sock);
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return,
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    eprintln!("accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        });
        Ok(Server { inner, accept: Some(accept), pool, control: Some(control), addr: local })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The served router, for observation while the server runs (live
    /// worker counts, queue depths, metrics).  Borrowed, not cloned, so
    /// observers cannot keep the router alive past [`Server::shutdown`].
    pub fn router(&self) -> &ServiceRouter {
        &self.inner.router
    }

    /// Live status: the router's per-service pressure line plus
    /// connection counters.
    pub fn status_line(&self) -> String {
        format!(
            "conns served={} shed={} | {}",
            self.inner.conns_served.load(Ordering::Relaxed),
            self.inner.conns_shed.load(Ordering::Relaxed),
            self.inner.router.load_report()
        )
    }

    /// Block up to `timeout` for a wire-level shutdown request; `true`
    /// once one has arrived.
    pub fn wait(&self, timeout: Duration) -> bool {
        let g = self.inner.shutdown_requested.lock().unwrap();
        let (g, _t) = self.inner.shutdown_cv.wait_timeout_while(g, timeout, |req| !*req).unwrap();
        *g
    }

    /// Stop accepting, drain the connection pool, stop the control
    /// plane, and hand the router back (so callers can read final
    /// metrics and shut the services down).  In-flight requests finish:
    /// handlers observe the stop flag only between frames.
    pub fn shutdown(mut self) -> Result<ServiceRouter> {
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.request_shutdown();
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for h in self.pool.drain(..) {
            let _ = h.join();
        }
        if let Some(c) = self.control.take() {
            c.stop();
        }
        let inner = Arc::try_unwrap(self.inner)
            .map_err(|_| anyhow::anyhow!("server threads still hold state"))?;
        let Inner { router, client, shedder, .. } = inner;
        drop(client);
        drop(shedder);
        Arc::try_unwrap(router)
            .map_err(|_| anyhow::anyhow!("router still referenced; drop external handles first"))
    }
}

/// Send a typed shed error on a socket we will not serve, then close.
fn shed_connection(mut sock: TcpStream) {
    sock.set_write_timeout(Some(Duration::from_millis(250))).ok();
    let resp = Resp::Error(WireError::new(ErrCode::Shed, "connection limit reached"));
    let _ = wire::write_frame(&mut sock, &wire::encode_resp(&resp));
}

/// Outcome of reading one frame with the poll-slice strategy.
enum ConnRead {
    Frame(Vec<u8>),
    TooLarge(u32),
    /// Clean close, idle timeout, server stop, or a transport error —
    /// in every case the connection is done.
    Done,
}

/// Read one length-prefixed frame, polling in short slices so the
/// handler notices `stop` and the idle deadline while blocked.
fn read_frame_polled(sock: &mut TcpStream, inner: &Inner) -> ConnRead {
    let deadline = Instant::now() + inner.cfg.read_timeout;
    let mut hdr = [0u8; 4];
    match read_exact_polled(sock, &mut hdr, deadline, &inner.stop) {
        ReadExact::Done => {}
        ReadExact::Closed => return ConnRead::Done,
    }
    let len = u32::from_le_bytes(hdr);
    if len > inner.cfg.max_frame {
        return ConnRead::TooLarge(len);
    }
    let mut body = vec![0u8; len as usize];
    // the body follows immediately; an idle stall mid-frame is a dead
    // or hostile peer, bounded by the same deadline
    match read_exact_polled(sock, &mut body, deadline, &inner.stop) {
        ReadExact::Done => ConnRead::Frame(body),
        ReadExact::Closed => ConnRead::Done,
    }
}

enum ReadExact {
    Done,
    Closed,
}

/// Fill `buf` from `sock`, waking every poll slice to check `stop` and
/// `deadline`.  EOF — clean at a frame boundary or mid-frame — maps to
/// `Closed` either way: the connection is done.
fn read_exact_polled(
    sock: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
    stop: &AtomicBool,
) -> ReadExact {
    sock.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let mut got = 0;
    while got < buf.len() {
        if stop.load(Ordering::SeqCst) || Instant::now() >= deadline {
            return ReadExact::Closed;
        }
        match sock.read(&mut buf[got..]) {
            Ok(0) => return ReadExact::Closed,
            Ok(n) => got += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return ReadExact::Closed,
        }
    }
    ReadExact::Done
}

/// Serve one connection: frames in, responses out, strictly in order.
fn handle_conn(mut sock: TcpStream, inner: &Inner) {
    sock.set_nodelay(true).ok();
    sock.set_write_timeout(Some(inner.cfg.write_timeout)).ok();
    loop {
        let resp = match read_frame_polled(&mut sock, inner) {
            ConnRead::Done => return,
            ConnRead::TooLarge(n) => {
                // the unread body desynchronizes the stream: answer
                // with the typed error, then close
                let resp = Resp::Error(WireError::new(
                    ErrCode::FrameTooLarge,
                    format!("frame of {n} bytes exceeds cap {}", inner.cfg.max_frame),
                ));
                let _ = wire::write_frame(&mut sock, &wire::encode_resp(&resp));
                return;
            }
            ConnRead::Frame(body) => match wire::decode_msg(&body) {
                Ok(msg) => dispatch(msg, inner),
                Err(e) => Resp::Error(e),
            },
        };
        if wire::write_frame(&mut sock, &wire::encode_resp(&resp)).is_err() {
            return;
        }
    }
}

/// Execute one decoded message against the router.  Every arm returns
/// exactly one response — the wire side of request conservation.
fn dispatch(msg: Msg, inner: &Inner) -> Resp {
    if inner.stop.load(Ordering::SeqCst) {
        return Resp::Error(WireError::new(ErrCode::ShuttingDown, "server is stopping"));
    }
    match msg {
        Msg::Infer { service, input } => {
            let want = match inner.client.item_len(&service) {
                Ok(n) => n,
                Err(_) => {
                    return Resp::Error(WireError::new(
                        ErrCode::UnknownService,
                        format!(
                            "no batching service '{service}' (registered: {})",
                            inner.client.services().join(", ")
                        ),
                    ));
                }
            };
            if input.len() != want {
                return Resp::Error(WireError::new(
                    ErrCode::BadItemLen,
                    format!("item len {} != {want} for '{service}'", input.len()),
                ));
            }
            if let Err(reason) = inner.shedder.admit(&service) {
                if let Some(m) = inner.router.metrics(&service) {
                    m.record_shed();
                }
                return Resp::Error(WireError::new(ErrCode::Shed, reason.to_string()));
            }
            match inner.client.try_submit(&service, input) {
                // `try_submit` already counted the shed in the metrics
                Ok(TrySubmit::Full(_)) => Resp::Error(WireError::new(
                    ErrCode::Shed,
                    format!("queue full for '{service}'"),
                )),
                Ok(TrySubmit::Accepted(rx)) => match rx.recv() {
                    Ok(r) => response_to_wire(&r),
                    Err(_) => Resp::Error(WireError::new(
                        ErrCode::Internal,
                        format!("batch failed server-side for '{service}'"),
                    )),
                },
                Err(e) => Resp::Error(WireError::new(ErrCode::ShuttingDown, format!("{e:#}"))),
            }
        }
        Msg::Decode { service, session, input } => {
            let want = match inner.client.decode_item_len(&service) {
                Ok(n) => n,
                Err(_) => {
                    return Resp::Error(WireError::new(
                        ErrCode::UnknownService,
                        format!(
                            "no decode service '{service}' (registered: {})",
                            inner.client.decode_services().join(", ")
                        ),
                    ));
                }
            };
            if input.len() != want {
                return Resp::Error(WireError::new(
                    ErrCode::BadItemLen,
                    format!("step len {} != {want} for '{service}'", input.len()),
                ));
            }
            if let Err(reason) = inner.shedder.admit(&service) {
                if let Some(m) = inner.router.metrics(&service) {
                    m.record_shed();
                }
                return Resp::Error(WireError::new(ErrCode::Shed, reason.to_string()));
            }
            match inner.client.submit_decode(&service, session, input) {
                Ok(rx) => match rx.recv() {
                    Ok(r) => response_to_wire(&r),
                    Err(_) => Resp::Error(WireError::new(
                        ErrCode::Internal,
                        format!("decode step failed server-side (session {session})"),
                    )),
                },
                Err(e) => Resp::Error(WireError::new(ErrCode::ShuttingDown, format!("{e:#}"))),
            }
        }
        Msg::EndSession { service, session } => {
            let names = inner.client.decode_services();
            if !names.contains(&service.as_str()) {
                return Resp::Error(WireError::new(
                    ErrCode::UnknownService,
                    format!("no decode service '{service}' (registered: {})", names.join(", ")),
                ));
            }
            match inner.client.end_session(&service, session) {
                Ok(r) => response_to_wire(&r),
                Err(e) => Resp::Error(WireError::new(ErrCode::Internal, format!("{e:#}"))),
            }
        }
        Msg::Stream { service, row, flags, chunk } => {
            let names = inner.client.stream_services();
            if !names.contains(&service.as_str()) {
                return Resp::Error(WireError::new(
                    ErrCode::UnknownService,
                    format!("no stream service '{service}' (registered: {})", names.join(", ")),
                ));
            }
            // shed happens before the chunk reaches the lane, so the
            // row's server-side state is untouched and the client can
            // resend the same chunk after backing off
            if let Err(reason) = inner.shedder.admit(&service) {
                if let Some(m) = inner.router.metrics(&service) {
                    m.record_shed();
                }
                return Resp::Error(WireError::new(ErrCode::Shed, reason.to_string()));
            }
            let begin = flags & wire::STREAM_BEGIN != 0;
            let finish = flags & wire::STREAM_FINISH != 0;
            match inner.client.stream_chunk(&service, row, begin, finish, chunk) {
                Ok(Ok(r)) => response_to_wire(&r),
                Ok(Err(v)) => Resp::Error(WireError::new(
                    ErrCode::StreamProtocol,
                    format!("row {row}: {}", v.as_str()),
                )),
                Err(e) => Resp::Error(WireError::new(ErrCode::ShuttingDown, format!("{e:#}"))),
            }
        }
        Msg::Status => Resp::Text(format!(
            "conns served={} shed={}\n{}\n{}",
            inner.conns_served.load(Ordering::Relaxed),
            inner.conns_shed.load(Ordering::Relaxed),
            inner.router.load_report(),
            inner.router.summary()
        )),
        Msg::Shutdown => {
            inner.request_shutdown();
            Resp::Text("shutting down".to_string())
        }
    }
}

fn response_to_wire(r: &crate::coordinator::Response) -> Resp {
    Resp::Output {
        output: r.output.clone(),
        queue_s: r.queue_time.as_secs_f64(),
        exec_s: r.exec_time.as_secs_f64(),
        batch: r.batch_size as u32,
    }
}
