//! `sole` — the leader binary: experiment harness + serving CLI.
//!
//! ```text
//! sole experiment <fig1a|fig3|fig6a|fig6b|table1|table2|table3|compress-error|ablation|all>
//!      [--artifacts DIR] [--samples N] [--batches 1,2,4,8,16]
//! sole serve [--artifacts DIR] [--model deit_t] [--variant fp32_sole] [--all-families]
//!      [--ops <spec,...>] [--requests N] [--rate R] [--max-wait-ms W] [--workers K]
//!      [--queue-cap N] [--decode <spec>] [--decode-steps N] [--sessions S]
//!      [--stream-ops <spec,...>]
//! sole serve --listen <addr> [--ops ...] [--stream-ops ...] [--decode <spec>]
//!      [--session-ttl-ms T] [--conn-threads C] [--shed-depth N] [--shed-p99-ms P]
//!      [--rebalance-ms R]
//! sole ops
//! sole info [--artifacts DIR]
//! ```
//!
//! `serve` runs one `ServiceRouter` process.  With artifacts (and the
//! `pjrt` feature) it discovers the manifest's (model, variant) families
//! and serves the requested one — or every family with `--all-families` —
//! as named services; otherwise it serves software op-services built from
//! registry spec strings: `--ops e2softmax/L256,attention/L128xD64,...`
//! picks them explicitly, the default is the paper's mixed workload
//! (`e2softmax` at L ∈ {49, 128, 785, 1024}, `ailayernorm` at C = 768,
//! plus the fused `attention` pipeline at L = 128, D = 64).
//! `sole ops` lists every registered operator family with its spec
//! grammar.  `--workers` is the *total* worker budget, split across
//! services (hot service weighted up, minimum one each).
//!
//! `--decode decode-attention/L128xD64` additionally registers a
//! session-affine decode service on the same router and drives
//! `--sessions` interleaved KV-cache sessions for `--decode-steps`
//! tokens each — the prefill services batch, the decode service pins
//! each session to a lane (DESIGN.md §3.5).
//!
//! `--stream-ops consmax/L128,gn-softmax/L128` registers row-affine
//! chunk-streaming services for reduction-free ops (DESIGN.md §3.6);
//! each spec is served as `<spec>/stream` and accepts rows of unbounded
//! length in chunks.  In the self-driven path the CLI streams one long
//! demonstration row per service; under `--listen` clients drive them
//! with the wire protocol's chunked-infer message.
//!
//! `--listen <addr>` swaps the self-driven workload for the TCP front
//! door (DESIGN.md §5.3): the same software op-services are served to
//! network clients over the length-prefixed wire protocol, with
//! admission control (`--shed-depth`, `--shed-p99-ms`), dynamic worker
//! rebalancing (`--rebalance-ms`, 0 disables), and idle decode-session
//! eviction (`--session-ttl-ms`, 0 keeps sessions forever).  The
//! process runs until a client sends the wire `shutdown` message
//! (`sole`'s own `serve_net` example does with `--shutdown`).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use sole::coordinator::{paper_service_specs, BatchPolicy, PjrtBackend, ServiceRouter};
use sole::experiments::{self, ExperimentOut};
use sole::ops::{Op, OpRegistry};
use sole::server::{AdmissionConfig, RebalanceConfig, Server, ServerConfig};
use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::cli::Args;
use sole::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("ops") => cmd_ops(),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "sole {} — SOLE reproduction CLI\n\
                 usage:\n  sole experiment <fig1a|fig3|fig6a|fig6b|table1|table2|table3|compress-error|ablation|all>\n\
                 \x20 sole serve [--model deit_t] [--variant fp32_sole] [--all-families] \
                 [--ops e2softmax/L128,attention/L128xD64] \
                 [--stream-ops consmax/L128] \
                 [--requests 64] [--rate 8] [--workers 4]\n\
                 \x20 sole ops\n\
                 \x20 sole info",
                sole::VERSION
            );
            Ok(())
        }
    }
}

fn artifacts_path(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_str("artifacts", "artifacts"))
}

/// `--batches 1,2,4,8,16`.  Strict: an unparsable entry is an error
/// naming the flag (it used to be silently dropped by a `filter_map`).
fn parse_batches(args: &Args) -> Result<Vec<usize>> {
    let batches: Vec<usize> = args.opt_list("batches", "1,2,4,8,16")?;
    anyhow::ensure!(!batches.contains(&0), "--batches: batch sizes must be positive");
    Ok(batches)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let artifacts = artifacts_path(args);
    let samples = args.opt_usize("samples", 512)?;
    let batches = parse_batches(args)?;

    let mut outs: Vec<ExperimentOut> = Vec::new();
    let needs_engine = matches!(which, "table1" | "table2" | "all");
    let engine = if needs_engine {
        Some(Engine::open(&artifacts).context("experiments table1/table2 need artifacts")?)
    } else {
        None
    };

    match which {
        "fig1a" => outs.push(experiments::fig1::run(args.opt_usize("batch", 8)?)),
        "fig3" => outs.push(experiments::fig3::run(&artifacts)?),
        "fig6a" => outs.push(experiments::fig6::run_a(&batches)),
        "fig6b" => outs.push(experiments::fig6::run_b(&batches)),
        "table3" => outs.push(experiments::table3::run()),
        "compress-error" => outs.push(experiments::compress_error::run()),
        "ablation" => outs.push(experiments::ablation::run()),
        "table1" => {
            outs.push(experiments::accuracy::table1(engine.as_ref().unwrap(), &artifacts, samples)?)
        }
        "table2" => {
            outs.push(experiments::accuracy::table2(engine.as_ref().unwrap(), &artifacts, samples)?)
        }
        "all" => {
            outs.push(experiments::fig1::run(8));
            if let Ok(f3) = experiments::fig3::run(&artifacts) {
                outs.push(f3);
            }
            outs.push(experiments::fig6::run_a(&batches));
            outs.push(experiments::fig6::run_b(&batches));
            outs.push(experiments::table3::run());
            outs.push(experiments::compress_error::run());
            outs.push(experiments::ablation::run());
            let e = engine.as_ref().unwrap();
            outs.push(experiments::accuracy::table1(e, &artifacts, samples)?);
            outs.push(experiments::accuracy::table2(e, &artifacts, samples)?);
        }
        other => bail!("unknown experiment '{other}'"),
    }
    for o in &outs {
        o.print();
        o.save(&artifacts)?;
    }
    println!("results saved under {}/results/", artifacts.display());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = artifacts_path(args);
    let n_requests = args.opt_usize("requests", 64)?;
    let rate = args.opt_f64("rate", 16.0)?; // req/s (Poisson arrivals)
    anyhow::ensure!(rate > 0.0, "--rate: must be positive, got {rate}");
    let max_wait = Duration::from_millis(args.opt_usize("max-wait-ms", 20)? as u64);
    let workers = args.opt_usize("workers", 4)?; // total budget, split across services
    let queue_cap = match args.opt_usize("queue-cap", 0)? {
        0 => None,
        cap => Some(cap),
    };
    let policy = BatchPolicy { max_wait, max_batch: 16, queue_cap };

    // --ops pins the workload to explicit registry specs (software path)
    let specs: Vec<String> = match args.opt("ops") {
        Some(raw) => raw.split(',').map(|s| s.trim().to_string()).collect(),
        None => paper_service_specs(),
    };
    // --decode adds a session-affine decode service (software path only)
    let decode = DecodeDrive {
        spec: args.opt("decode").map(str::to_string),
        steps: args.opt_usize("decode-steps", 32)?,
        sessions: args.opt_usize("sessions", 4)?,
    };
    // --stream-ops adds row-affine chunk-streaming services for
    // reduction-free ops (software path only)
    let stream_specs: Vec<String> = match args.opt("stream-ops") {
        Some(raw) => raw.split(',').map(|s| s.trim().to_string()).collect(),
        None => Vec::new(),
    };

    // --listen replaces the self-driven workload with the TCP front door
    if let Some(addr) = args.opt("listen") {
        return serve_listen(args, addr, &specs, &stream_specs, &decode, workers, policy);
    }

    let software_only =
        args.opt("ops").is_some() || decode.spec.is_some() || !stream_specs.is_empty();
    let have_artifacts = artifacts.join("manifest.json").exists();
    if !software_only && have_artifacts && cfg!(feature = "pjrt") {
        serve_artifact_families(args, &artifacts, n_requests, rate, workers, policy)
    } else {
        if !software_only && have_artifacts {
            println!(
                "artifacts found but built without --features pjrt — \
                 serving the software op-services instead"
            );
        }
        serve_software_ops(&specs, &stream_specs, &decode, n_requests, rate, workers, policy)
    }
}

/// `sole ops` — list every registered operator family: what `--ops`
/// accepts, what the spec grammar looks like, each family's in/out item
/// lengths at the canonical spec, and the port chain its data crosses
/// (outer edges are always f32; quantized entries are internal staging
/// boundaries, DESIGN.md §3.3).
fn cmd_ops() -> Result<()> {
    let registry = OpRegistry::builtin();
    println!(
        "registered ops (spec grammar: <op>/<DIM><len>[x<DIM><len>...], \
         e.g. e2softmax/L128, attention/L128xD64; dispatch: the SIMD \
         kernel arm selected on this host, - for ops with none):\n"
    );
    println!(
        "{:<18} {:>14} {:>12} {:>14} {:>8}  {:<24} {}",
        "op", "shape", "default", "in->out f32", "dispatch", "ports", "summary"
    );
    for l in registry.listings() {
        let (_, op) = registry.build(&l.canonical().to_string())?;
        let mut ports = vec!["f32".to_string()];
        ports.extend(op.boundary_ports().iter().map(|p| p.to_string()));
        ports.push("f32".to_string());
        println!(
            "{:<18} {:>14} {:>12} {:>14} {:>8}  {:<24} {}",
            l.name,
            l.signature(),
            l.canonical().shape(),
            format!("{}->{}", op.item_len(), op.out_len()),
            op.dispatch().map_or("-", |d| d.as_str()),
            ports.join("->"),
            l.summary
        );
        // pipelines: bytes one item occupies at each stage boundary —
        // the number the low-bit ports exist to shrink (DESIGN.md §3.3)
        let staging = op.staging_bytes_per_item();
        if !staging.is_empty() {
            let cells: Vec<String> = staging.iter().map(|b| b.to_string()).collect();
            println!(
                "{:<18} {:>14} staging bytes/item at stage boundaries: [{}]",
                "",
                "",
                cells.join(", ")
            );
        }
    }
    println!(
        "\nserve them with e.g.:\n  sole serve --ops {}",
        paper_service_specs().join(",")
    );
    Ok(())
}

/// Artifact path: discover the manifest's (model, variant) families,
/// register them as router services, drive the eval-set workload against
/// the requested (hot) one.
fn serve_artifact_families(
    args: &Args,
    artifacts: &Path,
    n_requests: usize,
    rate: f64,
    workers: usize,
    policy: BatchPolicy,
) -> Result<()> {
    let model = args.opt_str("model", "deit_t").to_string();
    let variant = args.opt_str("variant", "fp32_sole").to_string();
    let target = format!("{model}/{variant}");
    let engine = Engine::open(artifacts)?;
    println!("platform {}", engine.platform());

    let families = engine.manifest.families();
    let names: Vec<String> = families.iter().map(|f| f.service_name()).collect();
    anyhow::ensure!(
        names.iter().any(|n| n == &target),
        "no artifacts for {target} (families: {})",
        names.join(", ")
    );
    let mut builder = ServiceRouter::builder(workers).default_policy(policy);
    for fam in &families {
        let name = fam.service_name();
        if !args.flag("all-families") && name != target {
            continue;
        }
        let backend = Arc::new(PjrtBackend::from_family(&engine, &fam.model, &fam.variant)?);
        println!("service {name}: buckets {:?}, item {} f32", fam.buckets, fam.item_len);
        builder = if name == target {
            builder.hot_service(&name, backend, 2) // the driven family gets 2x share
        } else {
            builder.service(&name, backend)
        };
    }
    let router = builder.start()?;
    let client = router.client();
    let item_len = client.item_len(&target)?;

    // drive a Poisson-arrival open-loop workload from the eval set
    let data = Bundle::load(&artifacts.join("data/cv_eval"))?;
    let xs = data.get("x")?.as_f32()?;
    let mut rng = Rng::new(1234);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let start = (i * item_len) % (xs.len() - item_len);
        pending.push(client.submit(&target, xs[start..start + item_len].to_vec())?);
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
    }
    for rx in pending {
        let _ = rx.recv()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("served {n_requests} requests in {wall:.2}s ({:.1} req/s)", n_requests as f64 / wall);
    println!("{}", router.summary());
    router.shutdown();
    Ok(())
}

/// The `--decode` workload: which stateful spec to register (None to
/// skip), how many tokens per session, how many interleaved sessions.
struct DecodeDrive {
    spec: Option<String>,
    steps: usize,
    sessions: usize,
}

/// Software path (no artifacts needed): serve the requested op specs —
/// by default the paper's full mixed workload — through one router,
/// requests interleaved round-robin across services.  With `--decode`,
/// a session-affine decode service joins the same worker budget and is
/// driven with interleaved KV-cache sessions after the prefill workload;
/// with `--stream-ops`, chunk-streaming services join it and each gets
/// one long demonstration row streamed through.
fn serve_software_ops(
    specs: &[String],
    stream_specs: &[String],
    decode: &DecodeDrive,
    n_requests: usize,
    rate: f64,
    workers: usize,
    policy: BatchPolicy,
) -> Result<()> {
    anyhow::ensure!(!specs.is_empty(), "--ops: need at least one op spec");
    println!(
        "serving software op-services [{}] ({workers} total workers)",
        specs.join(", ")
    );
    let registry = OpRegistry::builtin();
    let mut builder = ServiceRouter::builder(workers).default_policy(policy);
    let mut names = Vec::with_capacity(specs.len());
    for spec in specs {
        let name = registry.parse_spec(spec)?.to_string();
        builder = builder.op_service(&registry, &name, vec![1, 4, 8, 16])?;
        names.push(name);
    }
    let mut stream_drives = Vec::with_capacity(stream_specs.len());
    for spec in stream_specs {
        let parsed = registry.parse_spec(spec)?;
        builder = builder.stream_service(&registry, &parsed.to_string(), 1)?;
        stream_drives.push((format!("{parsed}/stream"), parsed.len));
    }
    let mut decode_name = None;
    if let Some(spec) = &decode.spec {
        let parsed = registry.parse_spec(spec)?;
        anyhow::ensure!(
            decode.steps <= parsed.len,
            "--decode-steps {} exceeds the session capacity L{} of '{parsed}'",
            decode.steps,
            parsed.len
        );
        let name = parsed.to_string();
        builder = builder.decode_service(&registry, &name, 1)?;
        decode_name = Some(name);
    }
    let router = builder.start()?;
    let client = router.client();

    let mut rng = Rng::new(1234);
    let inputs: Vec<(String, Vec<f32>)> = names
        .iter()
        .map(|name| {
            let mut row = vec![0f32; client.item_len(name)?];
            rng.fill_normal(&mut row, 0.0, 2.0);
            Ok((name.clone(), row))
        })
        .collect::<Result<_>>()?;
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let (name, row) = &inputs[i % inputs.len()];
        pending.push(client.submit(name, row.clone())?);
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
    }
    for rx in pending {
        let _ = rx.recv()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {n_requests} mixed requests in {wall:.2}s ({:.1} req/s)",
        n_requests as f64 / wall
    );

    if let Some(name) = &decode_name {
        // decode soak: interleave the sessions step-by-step so every
        // request depends on state the service must have kept from the
        // session's previous step
        let item_len = client.decode_item_len(name)?;
        let n_steps = decode.steps * decode.sessions.max(1);
        println!(
            "decoding {} sessions x {} tokens through {name}",
            decode.sessions.max(1),
            decode.steps
        );
        let d0 = Instant::now();
        let mut item = vec![0f32; item_len];
        for _step in 0..decode.steps {
            let rxs: Vec<_> = (0..decode.sessions.max(1) as u64)
                .map(|sid| {
                    rng.fill_normal(&mut item, 0.0, 1.0);
                    client.submit_decode(name, sid, item.clone())
                })
                .collect::<Result<_>>()?;
            for rx in rxs {
                let _ = rx.recv()?;
            }
        }
        let dwall = d0.elapsed().as_secs_f64();
        println!(
            "decoded {n_steps} steps in {dwall:.2}s ({:.1} tok/s)",
            n_steps as f64 / dwall
        );
    }

    // stream demo: one row of 4x the registered L through each stream
    // service, in 64-element chunks — showing L-unbounded streaming
    for (row_id, (name, l)) in stream_drives.iter().enumerate() {
        let mut row = vec![0f32; 4 * l];
        rng.fill_normal(&mut row, 0.0, 2.0);
        let s0 = Instant::now();
        let out = client.stream_row(name, row_id as u64, &row, 64)?;
        println!(
            "streamed a {}-element row through {name} in {} chunks ({:.2}ms)",
            row.len(),
            row.len().div_ceil(64),
            s0.elapsed().as_secs_f64() * 1e3
        );
        anyhow::ensure!(out.len() == row.len(), "stream output length mismatch for {name}");
    }
    println!("{}", router.summary());
    router.shutdown();
    Ok(())
}

/// `sole serve --listen <addr>`: put the TCP front door in front of the
/// software op-services and run until a wire-level shutdown arrives.
/// Prints a status line (connections, per-service queue pressure and
/// worker counts) every `--status-ms` while serving.
fn serve_listen(
    args: &Args,
    addr: &str,
    specs: &[String],
    stream_specs: &[String],
    decode: &DecodeDrive,
    workers: usize,
    policy: BatchPolicy,
) -> Result<()> {
    anyhow::ensure!(!specs.is_empty(), "--ops: need at least one op spec");
    let session_ttl = match args.opt_usize("session-ttl-ms", 0)? {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    let shed_depth = args.opt_usize("shed-depth", 256)?; // 0 disables the rule
    let shed_p99_ms = args.opt_usize("shed-p99-ms", 0)?; // 0 disables the rule
    let rebalance_ms = args.opt_usize("rebalance-ms", 250)?; // 0 keeps the static split
    let conn_threads = args.opt_usize("conn-threads", 4)?;
    let status_every = Duration::from_millis(args.opt_usize("status-ms", 1000)? as u64);

    let registry = OpRegistry::builtin();
    let mut builder = ServiceRouter::builder(workers).default_policy(policy);
    let mut names = Vec::with_capacity(specs.len());
    for spec in specs {
        let name = registry.parse_spec(spec)?.to_string();
        builder = builder.op_service(&registry, &name, vec![1, 4, 8, 16])?;
        names.push(name);
    }
    if let Some(spec) = &decode.spec {
        let name = registry.parse_spec(spec)?.to_string();
        builder = builder.decode_service_with_ttl(&registry, &name, 1, session_ttl)?;
        names.push(name);
    }
    for spec in stream_specs {
        let parsed = registry.parse_spec(spec)?;
        // --session-ttl-ms doubles as the idle-row TTL for stream rows
        builder =
            builder.stream_service_with_ttl(&registry, &parsed.to_string(), 1, session_ttl)?;
        names.push(format!("{parsed}/stream"));
    }
    let router = builder.start()?;

    let cfg = ServerConfig {
        conn_threads: conn_threads.max(1),
        admission: AdmissionConfig {
            max_queue_depth: if shed_depth == 0 { None } else { Some(shed_depth) },
            max_in_flight: None,
            max_p99: if shed_p99_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(shed_p99_ms as u64))
            },
        },
        rebalance: if rebalance_ms == 0 {
            None
        } else {
            Some(RebalanceConfig {
                interval: Duration::from_millis(rebalance_ms as u64),
                ..RebalanceConfig::default()
            })
        },
        ..ServerConfig::default()
    };
    let server = Server::start(router, addr, cfg)?;
    println!(
        "listening on {} — services [{}] ({workers} workers)",
        server.addr(),
        names.join(", ")
    );
    println!("send the wire shutdown message to stop (serve_net example: --shutdown)");
    while !server.wait(status_every) {
        println!("{}", server.status_line());
    }
    println!("shutdown requested; draining connections");
    let router = server.shutdown()?;
    println!("{}", router.summary());
    router.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = artifacts_path(args);
    let engine = Engine::open(&artifacts)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", artifacts.display());
    println!("serving families (register as router services):");
    for f in engine.manifest.families() {
        println!("  {}: buckets {:?}, item {} f32", f.service_name(), f.buckets, f.item_len);
    }
    println!("ops:");
    for e in engine.manifest.entries.values().filter(|e| e.model.is_none()) {
        println!("  {} {:?} -> {:?}", e.id, e.input_shape, e.output_shape);
    }
    Ok(())
}
