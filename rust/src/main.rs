//! `sole` — the leader binary: experiment harness + serving CLI.
//!
//! ```text
//! sole experiment <fig1a|fig3|fig6a|fig6b|table1|table2|table3|compress-error|ablation|all>
//!      [--artifacts DIR] [--samples N] [--batches 1,2,4,8,16]
//! sole serve [--artifacts DIR] [--model deit_t] [--variant fp32_sole]
//!      [--requests N] [--rate R] [--max-wait-ms W] [--workers K] [--queue-cap N]
//! sole info [--artifacts DIR]
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use sole::coordinator::{BatchPolicy, Coordinator, PjrtBackend};
use sole::experiments::{self, ExperimentOut};
use sole::runtime::Engine;
use sole::tensor::Bundle;
use sole::util::cli::Args;
use sole::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "sole {} — SOLE reproduction CLI\n\
                 usage:\n  sole experiment <fig1a|fig3|fig6a|fig6b|table1|table2|table3|compress-error|ablation|all>\n\
                 \x20 sole serve [--model deit_t] [--variant fp32_sole] [--requests 64] [--rate 8]\n\
                 \x20 sole info",
                sole::VERSION
            );
            Ok(())
        }
    }
}

fn artifacts_path(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_str("artifacts", "artifacts"))
}

fn parse_batches(args: &Args) -> Vec<usize> {
    args.opt_str("batches", "1,2,4,8,16")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect()
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let artifacts = artifacts_path(args);
    let samples = args.opt_usize("samples", 512);
    let batches = parse_batches(args);

    let mut outs: Vec<ExperimentOut> = Vec::new();
    let needs_engine = matches!(which, "table1" | "table2" | "all");
    let engine = if needs_engine {
        Some(Engine::open(&artifacts).context("experiments table1/table2 need artifacts")?)
    } else {
        None
    };

    match which {
        "fig1a" => outs.push(experiments::fig1::run(args.opt_usize("batch", 8))),
        "fig3" => outs.push(experiments::fig3::run(&artifacts)?),
        "fig6a" => outs.push(experiments::fig6::run_a(&batches)),
        "fig6b" => outs.push(experiments::fig6::run_b(&batches)),
        "table3" => outs.push(experiments::table3::run()),
        "compress-error" => outs.push(experiments::compress_error::run()),
        "ablation" => outs.push(experiments::ablation::run()),
        "table1" => {
            outs.push(experiments::accuracy::table1(engine.as_ref().unwrap(), &artifacts, samples)?)
        }
        "table2" => {
            outs.push(experiments::accuracy::table2(engine.as_ref().unwrap(), &artifacts, samples)?)
        }
        "all" => {
            outs.push(experiments::fig1::run(8));
            if let Ok(f3) = experiments::fig3::run(&artifacts) {
                outs.push(f3);
            }
            outs.push(experiments::fig6::run_a(&batches));
            outs.push(experiments::fig6::run_b(&batches));
            outs.push(experiments::table3::run());
            outs.push(experiments::compress_error::run());
            outs.push(experiments::ablation::run());
            let e = engine.as_ref().unwrap();
            outs.push(experiments::accuracy::table1(e, &artifacts, samples)?);
            outs.push(experiments::accuracy::table2(e, &artifacts, samples)?);
        }
        other => bail!("unknown experiment '{other}'"),
    }
    for o in &outs {
        o.print();
        o.save(&artifacts)?;
    }
    println!("results saved under {}/results/", artifacts.display());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = artifacts_path(args);
    let model = args.opt_str("model", "deit_t").to_string();
    let variant = args.opt_str("variant", "fp32_sole").to_string();
    let n_requests = args.opt_usize("requests", 64);
    let rate = args.opt_f64("rate", 16.0); // req/s (Poisson arrivals)
    let max_wait = Duration::from_millis(args.opt_usize("max-wait-ms", 20) as u64);
    let workers = args.opt_usize("workers", 1);
    let queue_cap = match args.opt_usize("queue-cap", 0) {
        0 => None,
        cap => Some(cap),
    };

    let engine = Engine::open(&artifacts)?;
    println!("platform {}; loading {model}/{variant} buckets ...", engine.platform());
    let backend = Arc::new(PjrtBackend::from_family(&engine, &model, &variant)?);
    let (buckets, item_len) = {
        use sole::coordinator::Backend as _;
        (backend.buckets().to_vec(), backend.item_input_len())
    };
    println!("buckets: {buckets:?}");
    let co =
        Coordinator::start(backend, BatchPolicy { max_wait, max_batch: 16, queue_cap }, workers);
    let client = co.client();

    // drive a Poisson-arrival open-loop workload from the eval set
    let data = Bundle::load(&artifacts.join("data/cv_eval"))?;
    let xs = data.get("x")?.as_f32()?;
    let mut rng = Rng::new(1234);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let start = (i * item_len) % (xs.len() - item_len);
        pending.push(client.submit(xs[start..start + item_len].to_vec())?);
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
    }
    for rx in pending {
        let _ = rx.recv()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("served {n_requests} requests in {wall:.2}s ({:.1} req/s)", n_requests as f64 / wall);
    println!("{}", co.metrics.summary());
    co.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = artifacts_path(args);
    let engine = Engine::open(&artifacts)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", artifacts.display());
    println!("models:");
    for m in engine.manifest.models() {
        let variants: Vec<String> = engine
            .manifest
            .entries
            .values()
            .filter(|e| e.model.as_deref() == Some(&m))
            .map(|e| format!("{}@b{}", e.variant.clone().unwrap_or_default(), e.batch))
            .collect();
        println!("  {m}: {}", variants.join(", "));
    }
    println!("ops:");
    for e in engine.manifest.entries.values().filter(|e| e.model.is_none()) {
        println!("  {} {:?} -> {:?}", e.id, e.input_shape, e.output_shape);
    }
    Ok(())
}
