//! 28 nm operation cost library (energy pJ / area um^2) — the substitution
//! for the paper's Design Compiler + PrimeTimePX flow (DESIGN.md §2).
//!
//! Base numbers are the widely-cited 45 nm measurements (Horowitz,
//! "Computing's energy problem", ISSCC 2014): INT8 add 0.03 pJ / 36 um^2,
//! INT32 add 0.1 pJ / 137 um^2, INT8 mult 0.2 pJ / 282 um^2, INT32 mult
//! 3.1 pJ / 3495 um^2, and SRAM ~1.25 pJ/byte for a small (8 KB) array.
//! Scaling 45->28 nm applies the usual ~0.5x energy and ~0.4x area factors.
//!
//! Adds scale ~linearly in bit-width, multipliers ~quadratically, shifters
//! and muxes ~N log N and ~N; LUTs as one read of an entries x bits ROM.
//! What Table III actually measures is the *ratio* between designs whose
//! op mixes and buffer widths differ — those ratios are insensitive to the
//! absolute constants here (tested in experiments::table3).

/// Technology scaling applied to the 45 nm base numbers.
const ENERGY_SCALE: f64 = 0.5; // 45 nm -> 28 nm dynamic energy
const AREA_SCALE: f64 = 0.4; // 45 nm -> 28 nm area

/// Energy of an integer adder (pJ per operation).
pub fn add_energy(bits: u32) -> f64 {
    0.03 * (bits as f64 / 8.0) * ENERGY_SCALE
}

/// Area of an integer adder (um^2).
pub fn add_area(bits: u32) -> f64 {
    36.0 * (bits as f64 / 8.0) * AREA_SCALE
}

/// Energy of an a x b integer multiplier.
pub fn mult_energy(a_bits: u32, b_bits: u32) -> f64 {
    0.2 * (a_bits as f64 * b_bits as f64 / 64.0) * ENERGY_SCALE
}

/// Area of an a x b integer multiplier.
pub fn mult_area(a_bits: u32, b_bits: u32) -> f64 {
    282.0 * (a_bits as f64 * b_bits as f64 / 64.0) * AREA_SCALE
}

/// FP32 ops (for the GPU-side comparisons): Horowitz 0.9 pJ add, 3.7 pJ mul.
pub fn fp32_add_energy() -> f64 {
    0.9 * ENERGY_SCALE
}

pub fn fp32_mult_energy() -> f64 {
    3.7 * ENERGY_SCALE
}

/// Barrel shifter: ~N log2(N) mux cells.
pub fn shift_energy(bits: u32) -> f64 {
    let n = bits as f64;
    0.03 * (n * n.log2().max(1.0)) / (8.0 * 3.0) * ENERGY_SCALE
}

pub fn shift_area(bits: u32) -> f64 {
    let n = bits as f64;
    36.0 * (n * n.log2().max(1.0)) / (8.0 * 3.0) * AREA_SCALE
}

/// Comparator ~ subtractor.
pub fn cmp_energy(bits: u32) -> f64 {
    add_energy(bits)
}

pub fn cmp_area(bits: u32) -> f64 {
    add_area(bits)
}

/// Two-way mux.
pub fn mux_energy(bits: u32) -> f64 {
    0.002 * (bits as f64 / 8.0) * ENERGY_SCALE
}

pub fn mux_area(bits: u32) -> f64 {
    4.0 * (bits as f64 / 8.0) * AREA_SCALE
}

/// Leading-one detector over `bits` (priority encoder ~ N log N).
pub fn lod_energy(bits: u32) -> f64 {
    shift_energy(bits) * 0.7
}

pub fn lod_area(bits: u32) -> f64 {
    shift_area(bits) * 0.7
}

/// ROM/LUT read: entries x out_bits array; cost ~ decoder + word line.
pub fn lut_energy(entries: u32, out_bits: u32) -> f64 {
    let bitcells = entries as f64 * out_bits as f64;
    (0.01 + 0.00008 * bitcells) * ENERGY_SCALE
}

pub fn lut_area(entries: u32, out_bits: u32) -> f64 {
    // ROM bitcell ~0.35 um^2 at 45 nm + decoder overhead
    (entries as f64 * out_bits as f64 * 0.35 + 30.0) * AREA_SCALE
}

/// Small SRAM/register-file buffer access, energy per *bit*.
/// Horowitz 8 KB ~ 1.25 pJ/byte; small buffers used here (<= 4 KB) are
/// register-file-like, slightly cheaper per bit and size-dependent.
pub fn buffer_access_energy_per_bit(size_bits: u64) -> f64 {
    let kb = (size_bits as f64 / 8192.0).max(0.03125);
    // ~0.08 pJ/bit at 1 KB, growing ~ sqrt(size)
    0.08 * kb.sqrt().max(0.25) * ENERGY_SCALE
}

/// Buffer area per bit (6T-ish cell + periphery amortization).
pub fn buffer_area_per_bit(size_bits: u64) -> f64 {
    let periphery = 400.0 / (size_bits as f64).max(64.0); // amortized decoder
    (0.9 + periphery) * AREA_SCALE
}

/// Register (flop) energy per bit per toggle and area per bit.
pub fn reg_energy_per_bit() -> f64 {
    0.004 * ENERGY_SCALE
}

pub fn reg_area_per_bit() -> f64 {
    6.0 * AREA_SCALE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplier_quadratic_adder_linear() {
        assert!((mult_energy(32, 32) / mult_energy(8, 8) - 16.0).abs() < 1e-9);
        assert!((add_energy(32) / add_energy(8) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn horowitz_anchors() {
        // the 8-bit 45 nm anchors survive the 0.5x energy scaling; wider
        // widths follow the linear/quadratic model (INT32 add comes out
        // 0.06 vs Horowitz's measured 0.05 — the model is bit-linear)
        assert!((mult_energy(8, 8) - 0.1).abs() < 1e-9);
        assert!((add_energy(8) - 0.015).abs() < 1e-9);
        assert!((add_energy(32) - 0.06).abs() < 1e-9);
    }

    #[test]
    fn int32_mult_dominates_everything_else() {
        // the key asymmetry behind Table III's Statistic Unit win
        let m32 = mult_energy(32, 32);
        assert!(m32 > 10.0 * mult_energy(8, 8));
        assert!(m32 > 30.0 * add_energy(16));
        assert!(m32 > 20.0 * lut_energy(16, 8));
    }

    #[test]
    fn buffer_energy_grows_with_size() {
        let small = buffer_access_energy_per_bit(1024);
        let big = buffer_access_energy_per_bit(64 * 8192);
        assert!(big > small);
    }

    #[test]
    fn all_positive() {
        for b in [4u32, 8, 12, 16, 23, 26, 32] {
            assert!(add_energy(b) > 0.0 && add_area(b) > 0.0);
            assert!(shift_energy(b) > 0.0 && shift_area(b) > 0.0);
            assert!(mux_energy(b) > 0.0 && lod_energy(b) > 0.0);
        }
        assert!(lut_energy(16, 8) > 0.0 && lut_area(64, 16) > 0.0);
    }
}
