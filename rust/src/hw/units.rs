//! Cycle/energy/area models of the four custom hardware designs Table III
//! compares: the paper's E2Softmax Unit and AILayerNorm Unit, and the
//! re-implemented baselines (Softermax unit, NN-LUT/I-BERT LayerNorm
//! unit).  Each model counts the exact datapath inventory of its design
//! (Fig. 4/5 for SOLE; the baseline papers' descriptions for the others)
//! against the 28 nm cost library.
//!
//! Breakdown convention (matching the paper's Table III rows):
//!   * softmax designs:  `stage2` = the *Normalization Unit* subunit
//!   * layernorm designs: `stage1` = the *Statistic Unit* subunit
//!   * `buffers` = the ping-pong intermediate storage — the memory-bound
//!     part the paper's 4-bit/8-bit compression attacks.

use super::cost::*;
use super::pipeline::Pipeline;

/// Energy per row of `l` elements, split by source (pJ).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBk {
    pub stage1: f64,
    pub stage2: f64,
    pub buffers: f64,
}

impl EnergyBk {
    pub fn total(&self) -> f64 {
        self.stage1 + self.stage2 + self.buffers
    }
}

/// Area split (um^2).
#[derive(Debug, Clone, Copy, Default)]
pub struct AreaBk {
    pub stage1: f64,
    pub stage2: f64,
    pub buffers: f64,
    pub regs: f64,
}

impl AreaBk {
    pub fn total(&self) -> f64 {
        self.stage1 + self.stage2 + self.buffers + self.regs
    }
}

/// Uniform interface for the experiment harness.
pub trait HwUnit {
    fn name(&self) -> &'static str;
    fn pipeline(&self) -> Pipeline;
    fn area(&self) -> AreaBk;
    /// Energy to process one row of `l` elements (pJ).
    fn energy_per_row(&self, l: usize) -> EnergyBk;

    /// Wall-clock for rows x l on `units` parallel units (s).
    fn seconds(&self, rows: usize, l: usize, units: usize) -> f64 {
        self.pipeline().seconds(rows, l, units)
    }

    /// Average power at full utilization (mW) for rows of length `l`.
    fn power_mw(&self, l: usize) -> f64 {
        // pJ per row / ns per row = mW
        let e = self.energy_per_row(l).total();
        let cycles = 2 * self.pipeline().stage_cycles(l); // both stages busy
        e / (cycles as f64 / self.pipeline().freq_ghz)
    }

    /// Energy for a full workload (J).
    fn energy_j(&self, rows: usize, l: usize) -> f64 {
        self.energy_per_row(l).total() * rows as f64 * 1e-12
    }
}

/// Pipeline registers between stages: `stages` ranks of `width` bits.
fn pipe_regs_area(lanes: usize, width: u32, stages: u32) -> f64 {
    lanes as f64 * (width * stages) as f64 * reg_area_per_bit()
}

fn pipe_regs_energy_per_elem(width: u32, stages: u32) -> f64 {
    (width * stages) as f64 * reg_energy_per_bit()
}

// ---------------------------------------------------------------------------
// E2Softmax Unit (Fig. 4)
// ---------------------------------------------------------------------------

/// The paper's E2Softmax Unit: V-lane, two-stage, LUT-free and
/// multiplication-free.  4-bit log2-quantized intermediates in the
/// ping-pong Output Buffer.
#[derive(Debug, Clone)]
pub struct E2SoftmaxUnit {
    pub lanes: usize,
    /// Output Buffer capacity in elements (the paper supports rows <= 1024).
    pub l_max: usize,
}

impl Default for E2SoftmaxUnit {
    fn default() -> Self {
        E2SoftmaxUnit { lanes: 32, l_max: 1024 }
    }
}

impl E2SoftmaxUnit {
    /// Ping-pong buffer size in bits: 2 x L x 4-bit codes.
    fn buffer_bits(&self) -> u64 {
        2 * self.l_max as u64 * 4
    }

    fn stage1_energy_per_elem(&self) -> f64 {
        // Max Unit share (comparison tree: V-1 comparators per V elems)
        cmp_energy(8)
        // subtract input - running max (9-bit)
        + add_energy(9)
        // Log2Exp: two shifts + two adds on the Q(8) value + rounder
        + 2.0 * shift_energy(12) + 3.0 * add_energy(12)
        // Reduction Unit: sum >> sub + add in Q(17.15)
        + shift_energy(26) / self.lanes as f64 // sum rescale once per slice
        + add_energy(26)
        + pipe_regs_energy_per_elem(12, 2)
    }

    fn stage2_energy_per_elem(&self) -> f64 {
        // Correction add (4-bit) + divider: subtract, 2-way mux between the
        // 1.636/1.136 constants, output shifter, output rounder
        add_energy(4)
            + add_energy(6)
            + mux_energy(23)
            + shift_energy(23)
            + add_energy(23)
            + pipe_regs_energy_per_elem(23, 1)
    }

    fn stage2_energy_per_row(&self) -> f64 {
        lod_energy(26) // LOD on the reduced sum, once per row
    }
}

impl HwUnit for E2SoftmaxUnit {
    fn name(&self) -> &'static str {
        "sole_e2softmax"
    }

    fn pipeline(&self) -> Pipeline {
        Pipeline { lanes: self.lanes, row_overhead: 2, freq_ghz: 1.0 }
    }

    fn area(&self) -> AreaBk {
        let v = self.lanes as f64;
        let stage1 = v
            * (cmp_area(8)
                + add_area(9)
                + 2.0 * shift_area(12)
                + 3.0 * add_area(12)
                + add_area(26))
            + shift_area(26); // shared sum-rescale shifter
        let stage2 = v * (add_area(4) + add_area(6) + mux_area(23) + shift_area(23) + add_area(23))
            + lod_area(26);
        let buffers = self.buffer_bits() as f64 * buffer_area_per_bit(self.buffer_bits());
        let regs = pipe_regs_area(self.lanes, 12, 2) + pipe_regs_area(self.lanes, 23, 1);
        AreaBk { stage1, stage2, buffers, regs }
    }

    fn energy_per_row(&self, l: usize) -> EnergyBk {
        let n = l as f64;
        let bb = self.buffer_bits();
        let per_bit = buffer_access_energy_per_bit(bb);
        EnergyBk {
            stage1: n * self.stage1_energy_per_elem(),
            stage2: n * self.stage2_energy_per_elem() + self.stage2_energy_per_row(),
            // input read 8b + code write 4b + code read 4b + output write 8b
            buffers: n * (8.0 + 4.0 + 4.0 + 8.0) * per_bit,
        }
    }
}

// ---------------------------------------------------------------------------
// Softermax Unit (Stevens et al., DAC'21) — the Table III softmax baseline
// ---------------------------------------------------------------------------

/// Softermax: base-2 softmax, PWL 2^x (multiplier + slope/intercept LUT),
/// 16-bit un-normalized intermediates, reciprocal-multiply normalization.
#[derive(Debug, Clone)]
pub struct SoftermaxUnit {
    pub lanes: usize,
    pub l_max: usize,
}

impl Default for SoftermaxUnit {
    fn default() -> Self {
        SoftermaxUnit { lanes: 32, l_max: 1024 }
    }
}

impl SoftermaxUnit {
    /// 2 x L x 16-bit un-normalized values (the paper's key memory cost).
    fn buffer_bits(&self) -> u64 {
        2 * self.l_max as u64 * 16
    }
}

impl HwUnit for SoftermaxUnit {
    fn name(&self) -> &'static str {
        "softermax"
    }

    fn pipeline(&self) -> Pipeline {
        Pipeline { lanes: self.lanes, row_overhead: 2, freq_ghz: 1.0 }
    }

    fn area(&self) -> AreaBk {
        let v = self.lanes as f64;
        // stage1: max cmp + subtract + PWL 2^x (8x8 mult + 32-entry LUT) + 16b accum
        let stage1 = v
            * (cmp_area(8)
                + add_area(9)
                + mult_area(8, 8)
                + lut_area(32, 16)
                + add_area(16)
                + add_area(16));
        // stage2 (Normalization Unit): reciprocal (PWL: 64-entry LUT + 16x16
        // mult, shared) + per-lane 16x16 normalize multiply + rounder
        let stage2 = v * (mult_area(16, 16) + add_area(16)) + lut_area(64, 16) + mult_area(16, 16);
        let buffers = self.buffer_bits() as f64 * buffer_area_per_bit(self.buffer_bits());
        let regs = pipe_regs_area(self.lanes, 16, 3);
        AreaBk { stage1, stage2, buffers, regs }
    }

    fn energy_per_row(&self, l: usize) -> EnergyBk {
        let n = l as f64;
        let per_bit = buffer_access_energy_per_bit(self.buffer_bits());
        EnergyBk {
            stage1: n
                * (cmp_energy(8)
                    + add_energy(9)
                    + mult_energy(8, 8)
                    + lut_energy(32, 16)
                    + 2.0 * add_energy(16)
                    + pipe_regs_energy_per_elem(16, 2)),
            stage2: n * (mult_energy(16, 16) + add_energy(16) + pipe_regs_energy_per_elem(16, 1))
                + lut_energy(64, 16)
                + mult_energy(16, 16),
            // input 8b + intermediate write 16b + read 16b + output 8b
            buffers: n * (8.0 + 16.0 + 16.0 + 8.0) * per_bit,
        }
    }
}

// ---------------------------------------------------------------------------
// AILayerNorm Unit (Fig. 5)
// ---------------------------------------------------------------------------

/// The paper's AILayerNorm Unit: dynamic compression + 16-entry square LUT
/// statistics, PTF shifts, x^-0.5 LUT preprocess, fused affine stage.
#[derive(Debug, Clone)]
pub struct AiLayerNormUnit {
    pub lanes: usize,
    /// Input Buffer capacity in channels (ping-pong).
    pub c_max: usize,
}

impl Default for AiLayerNormUnit {
    fn default() -> Self {
        AiLayerNormUnit { lanes: 32, c_max: 1024 }
    }
}

impl AiLayerNormUnit {
    /// 2 x C x 8-bit input codes.
    fn buffer_bits(&self) -> u64 {
        2 * self.c_max as u64 * 8
    }
}

impl HwUnit for AiLayerNormUnit {
    fn name(&self) -> &'static str {
        "sole_ailayernorm"
    }

    fn pipeline(&self) -> Pipeline {
        Pipeline { lanes: self.lanes, row_overhead: 2, freq_ghz: 1.0 }
    }

    fn area(&self) -> AreaBk {
        let v = self.lanes as f64;
        // Statistic Unit (stage 1): zp-sub, compress (cmp + mux), square LUT,
        // decompress+PTF barrel shifter (24b), Ex tree (16b), Ex2 tree (26b)
        let stage1 = v
            * (add_area(9)
                + cmp_area(8)
                + mux_area(4)
                + lut_area(16, 8)
                + shift_area(24)
                + shift_area(12)
                + add_area(16)
                + add_area(26))
            // Preprocess (shared): two 1/C mults, x^-0.5 LUT, LOD normalizer
            + 2.0 * mult_area(16, 16)
            + lut_area(64, 16)
            + lod_area(26);
        // Affine Unit (stage 2): A = gamma*std_inv (8x16), PTF shift + sub,
        // Y = A*X + B (16x16 + add)
        let stage2 = v
            * (mult_area(8, 16) + shift_area(12) + add_area(16) + mult_area(16, 16) + add_area(16));
        let buffers = self.buffer_bits() as f64 * buffer_area_per_bit(self.buffer_bits());
        let regs = pipe_regs_area(self.lanes, 26, 2) + pipe_regs_area(self.lanes, 16, 2);
        AreaBk { stage1, stage2, buffers, regs }
    }

    fn energy_per_row(&self, c: usize) -> EnergyBk {
        let n = c as f64;
        let per_bit = buffer_access_energy_per_bit(self.buffer_bits());
        EnergyBk {
            stage1: n
                * (add_energy(9)
                    + cmp_energy(8)
                    + mux_energy(4)
                    + lut_energy(16, 8)
                    + shift_energy(24)
                    + shift_energy(12)
                    + add_energy(16)
                    + add_energy(26)
                    + pipe_regs_energy_per_elem(26, 2))
                + 2.0 * mult_energy(16, 16)
                + lut_energy(64, 16)
                + lod_energy(26),
            stage2: n
                * (mult_energy(8, 16)
                    + shift_energy(12)
                    + add_energy(16)
                    + mult_energy(16, 16)
                    + add_energy(16)
                    + pipe_regs_energy_per_elem(16, 2)),
            // input write 8b + read 8b (stage2 re-read) + output 8b
            buffers: n * (8.0 + 8.0 + 8.0 + 8.0) * per_bit,
        }
    }
}

// ---------------------------------------------------------------------------
// NN-LUT / I-BERT LayerNorm unit — the Table III layernorm baseline
// ---------------------------------------------------------------------------

/// NN-LUT keeps I-BERT's INT32 statistic pipeline (32-bit multiply per
/// element for x^2, INT32 accumulation) and replaces the non-linear
/// x^-0.5 with its NN-learned PWL table (segment compare + 16x16 mult).
#[derive(Debug, Clone)]
pub struct NnLutLayerNormUnit {
    pub lanes: usize,
    pub c_max: usize,
}

impl Default for NnLutLayerNormUnit {
    fn default() -> Self {
        NnLutLayerNormUnit { lanes: 32, c_max: 1024 }
    }
}

impl NnLutLayerNormUnit {
    /// 2 x C x 32-bit buffered values (I-BERT stores INT32).
    fn buffer_bits(&self) -> u64 {
        2 * self.c_max as u64 * 32
    }
}

impl HwUnit for NnLutLayerNormUnit {
    fn name(&self) -> &'static str {
        "nnlut_layernorm"
    }

    fn pipeline(&self) -> Pipeline {
        Pipeline { lanes: self.lanes, row_overhead: 2, freq_ghz: 1.0 }
    }

    fn area(&self) -> AreaBk {
        let v = self.lanes as f64;
        // Statistic Unit: INT32 x^2 multiplier + two INT32 accumulators
        let stage1 = v * (mult_area(32, 32) + 2.0 * add_area(32))
            // shared PWL rsqrt: NN-LUT table + segment select + 16x16 mult
            + lut_area(16, 32)
            + cmp_area(16) * 4.0
            + mult_area(16, 16);
        // stage 2: normalize multiply (32x16) + affine (16x16 + adds)
        let stage2 =
            v * (mult_area(32, 16) + mult_area(16, 16) + add_area(32) + add_area(16));
        let buffers = self.buffer_bits() as f64 * buffer_area_per_bit(self.buffer_bits());
        let regs = pipe_regs_area(self.lanes, 32, 3);
        AreaBk { stage1, stage2, buffers, regs }
    }

    fn energy_per_row(&self, c: usize) -> EnergyBk {
        let n = c as f64;
        let per_bit = buffer_access_energy_per_bit(self.buffer_bits());
        EnergyBk {
            stage1: n
                * (mult_energy(32, 32)
                    + 2.0 * add_energy(32)
                    + pipe_regs_energy_per_elem(32, 2))
                + lut_energy(16, 32)
                + 4.0 * cmp_energy(16)
                + mult_energy(16, 16),
            stage2: n
                * (mult_energy(32, 16)
                    + mult_energy(16, 16)
                    + add_energy(32)
                    + add_energy(16)
                    + pipe_regs_energy_per_elem(32, 1)),
            // input 32b write + 32b read + in 8b + out 8b
            buffers: n * (32.0 + 32.0 + 8.0 + 8.0) * per_bit,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sole_softmax_beats_softermax_on_both_axes() {
        let sole = E2SoftmaxUnit::default();
        let soft = SoftermaxUnit::default();
        let e_ratio = soft.energy_per_row(785).total() / sole.energy_per_row(785).total();
        let a_ratio = soft.area().total() / sole.area().total();
        // paper: 3.04x energy, 2.82x area — require the right ballpark
        assert!(e_ratio > 1.8 && e_ratio < 5.0, "energy ratio {e_ratio}");
        assert!(a_ratio > 1.5 && a_ratio < 5.0, "area ratio {a_ratio}");
    }

    #[test]
    fn sole_layernorm_beats_nnlut_on_both_axes() {
        let sole = AiLayerNormUnit::default();
        let nn = NnLutLayerNormUnit::default();
        let e_ratio = nn.energy_per_row(192).total() / sole.energy_per_row(192).total();
        let a_ratio = nn.area().total() / sole.area().total();
        // paper: 3.86x energy, 3.32x area
        assert!(e_ratio > 2.0 && e_ratio < 7.0, "energy ratio {e_ratio}");
        assert!(a_ratio > 1.8 && a_ratio < 6.0, "area ratio {a_ratio}");
    }

    #[test]
    fn normalization_subunit_ratio_in_band() {
        // paper: Normalization Unit 2.46x energy, 2.89x area
        let sole = E2SoftmaxUnit::default();
        let soft = SoftermaxUnit::default();
        let e = soft.energy_per_row(785).stage2 / sole.energy_per_row(785).stage2;
        let a = soft.area().stage2 / sole.area().stage2;
        assert!(e > 1.5 && e < 6.0, "norm subunit energy ratio {e}");
        assert!(a > 1.5 && a < 6.0, "norm subunit area ratio {a}");
    }

    #[test]
    fn statistic_subunit_ratio_in_band() {
        // paper: Statistic Unit 11.3x energy, 3.79x area
        let sole = AiLayerNormUnit::default();
        let nn = NnLutLayerNormUnit::default();
        let e = nn.energy_per_row(192).stage1 / sole.energy_per_row(192).stage1;
        let a = nn.area().stage1 / sole.area().stage1;
        assert!(e > 4.0 && e < 20.0, "stat subunit energy ratio {e}");
        assert!(a > 2.0 && a < 10.0, "stat subunit area ratio {a}");
    }

    #[test]
    fn buffers_dominate_full_unit_gap() {
        // the paper's memory-bound argument: the full-unit ratio comes
        // substantially from buffer width (4/8-bit vs 16/32-bit)
        let sole = E2SoftmaxUnit::default().energy_per_row(1024);
        let soft = SoftermaxUnit::default().energy_per_row(1024);
        assert!(soft.buffers > 2.0 * sole.buffers);
    }

    #[test]
    fn power_in_plausible_asic_range() {
        // a 32-lane unit at 1 GHz should be mW-scale, not W-scale
        for (name, p) in [
            ("e2", E2SoftmaxUnit::default().power_mw(785)),
            ("softermax", SoftermaxUnit::default().power_mw(785)),
            ("ailn", AiLayerNormUnit::default().power_mw(192)),
            ("nnlut", NnLutLayerNormUnit::default().power_mw(192)),
        ] {
            assert!(p > 0.1 && p < 500.0, "{name} power {p} mW");
        }
    }
}
