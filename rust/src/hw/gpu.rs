//! Analytical 2080Ti model — the substitution for the paper's GPU
//! measurements (DESIGN.md §2).
//!
//! Softmax/LayerNorm at transformer sizes are *memory-bound* kernels with
//! significant per-launch overhead; matmuls follow a compute/memory
//! roofline.  The model:
//!
//!   t_kernel = launch + bytes / (BW * eff(work)) ,  eff grows with
//!   occupancy and saturates around `PEAK_BW_EFF` (measured softmax
//!   kernels reach ~35-60% of peak DRAM bandwidth at these shapes).
//!
//! Constants are the 2080Ti datasheet numbers; `eff` is calibrated so the
//! INT8-over-FP32 end-to-end curve lands in the paper's measured
//! 1.10-1.28x band (experiments::fig6 asserts this).

/// RTX 2080Ti datasheet.
pub const DRAM_BW: f64 = 616e9; // bytes/s
pub const FP32_TFLOPS: f64 = 13.45e12;
pub const INT8_TOPS: f64 = 107.6e12; // tensor cores
pub const FP16_TFLOPS: f64 = 26.9e12;
pub const KERNEL_LAUNCH: f64 = 4.0e-6; // s, typical CUDA launch+sync share
pub const TDP_W: f64 = 250.0;

/// Peak fraction of DRAM bandwidth elementwise kernels actually reach.
pub const PEAK_BW_EFF: f64 = 0.55;
/// L2 size and the effective bandwidth of L2-resident elementwise work.
pub const L2_BYTES: f64 = 5.5e6;
pub const L2_BW_EFF: f64 = 900e9;

/// Effective bandwidth for a kernel whose working set is `tensor` bytes:
/// L2-resident work streams much faster than DRAM-bound work.  This blend
/// is what makes the paper's Fig 6(a) trend emerge — GPU softmax gets
/// *relatively* slower as batch grows and the attention matrix spills L2,
/// while the SOLE units' throughput is size-independent.
pub fn eff_bw(tensor: f64) -> f64 {
    let w = (L2_BYTES / tensor.max(1.0)).min(1.0);
    w * L2_BW_EFF + (1.0 - w) * DRAM_BW * PEAK_BW_EFF
}

/// Back-compat shim for the batched efficiency curve (fraction of DRAM BW).
pub fn bw_eff(bytes: f64) -> f64 {
    (eff_bw(bytes) / DRAM_BW).min(1.0)
}

/// One softmax kernel over `rows` x `l` FP32: 3 reads + 2 writes of the
/// attention tensor (max, exp+sum, divide), with ~20% uncoalesced-access
/// overhead typical of row-reduction kernels.
pub fn softmax_time(rows: usize, l: usize) -> f64 {
    let tensor = rows as f64 * l as f64 * 4.0;
    let bytes = 4.0 * tensor;
    KERNEL_LAUNCH + bytes / eff_bw(tensor)
}

/// One LayerNorm kernel over `rows` x `c` FP32 (two-pass: 3 reads + 1
/// write); short rows (C ~ 192-768) coalesce poorly -> ~0.6 efficiency.
pub fn layernorm_time(rows: usize, c: usize) -> f64 {
    let tensor = rows as f64 * c as f64 * 4.0;
    let bytes = 4.0 * tensor / 0.7;
    KERNEL_LAUNCH + bytes / eff_bw(tensor)
}

/// GEMM roofline.  INT8 on 2080Ti tensor cores at transformer-inference
/// shapes (k = 192..768) reaches only ~1.5x the FP32 effective
/// throughput — far below the 8x datasheet ratio (the paper's Fig 6(b)
/// INT8 bars land at only 1.10-1.28x end-to-end for exactly this reason).
pub fn gemm_time(m: usize, n: usize, k: usize, int8: bool) -> f64 {
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let util = 0.55;
    let peak = if int8 { 1.5 * FP32_TFLOPS } else { FP32_TFLOPS };
    let eb = if int8 { 1.0 } else { 4.0 };
    let bytes = (m as f64 * k as f64 + k as f64 * n as f64 + m as f64 * n as f64) * eb;
    KERNEL_LAUNCH + (flops / (peak * util)).max(bytes / (DRAM_BW * PEAK_BW_EFF))
}

/// Elementwise op (GELU, residual add, bias): bytes-limited.
pub fn elementwise_time(elems: usize, passes: f64) -> f64 {
    let tensor = elems as f64 * 4.0;
    KERNEL_LAUNCH + tensor * passes / eff_bw(tensor)
}

/// GPU energy for a kernel: TDP x time x activity (elementwise kernels
/// do not pull full TDP; ~0.6 is typical for memory-bound work).
pub fn energy_j(time_s: f64) -> f64 {
    TDP_W * 0.6 * time_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_dominates_tiny_kernels() {
        let t = softmax_time(3, 128);
        assert!(t > KERNEL_LAUNCH && t < 2.0 * KERNEL_LAUNCH);
    }

    #[test]
    fn bandwidth_dominates_big_kernels() {
        let t = softmax_time(16 * 3 * 785, 785); // DeiT-T batch 16 softmax
        let bytes = (16 * 3 * 785 * 785) as f64 * 16.0; // 4 passes of f32
        assert!(t > bytes / (DRAM_BW * PEAK_BW_EFF) * 0.8);
        assert!(t > 10.0 * KERNEL_LAUNCH);
    }

    #[test]
    fn eff_monotone_saturating() {
        // effective bandwidth *decreases* as the working set spills L2
        assert!(eff_bw(1e5) >= eff_bw(1e7));
        assert!(eff_bw(1e7) > eff_bw(1e9));
        assert!((eff_bw(1e12) - DRAM_BW * PEAK_BW_EFF) / (DRAM_BW * PEAK_BW_EFF) < 0.02);
    }

    #[test]
    fn int8_gemm_faster_than_fp32() {
        let f = gemm_time(785, 192, 192, false);
        let i = gemm_time(785, 192, 192, true);
        assert!(i < f);
    }

    #[test]
    fn gemm_compute_bound_when_large() {
        let t = gemm_time(4096, 4096, 4096, false);
        let flops = 2.0 * 4096f64.powi(3);
        assert!(t > flops / FP32_TFLOPS); // can't beat peak
    }
}
