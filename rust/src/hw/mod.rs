//! Hardware evaluation substrate (DESIGN.md §5 items 7-8): the 28 nm cost
//! library, the cycle-accurate two-stage pipeline model, the four custom
//! unit models Table III compares, and the analytical 2080Ti baseline.

pub mod cost;
pub mod gpu;
pub mod pipeline;
pub mod units;

pub use pipeline::{Cycles, Pipeline};
pub use units::{
    AiLayerNormUnit, AreaBk, E2SoftmaxUnit, EnergyBk, HwUnit, NnLutLayerNormUnit, SoftermaxUnit,
};
