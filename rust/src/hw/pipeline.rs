//! Two-stage ping-pong pipeline cycle model.
//!
//! Both SOLE units (and the re-implemented baselines) share the dataflow of
//! Fig. 4/5: stage 1 streams V-element slices of each row through the
//! compute datapath while stage 2 drains the *previous* row from the
//! ping-pong buffer.  With R rows of L elements and V lanes at `freq_ghz`:
//!
//!   cycles/stage/row = ceil(L / V) (+ a small per-row overhead)
//!   pipelined total  = (R + 1) * max(stage1, stage2) (steady-state overlap)

/// Static description of one unit's pipeline.
#[derive(Debug, Clone, Copy)]
pub struct Pipeline {
    /// Vector lanes (the paper's vector size, 32).
    pub lanes: usize,
    /// Extra cycles per row per stage (drain/latch, reduction tree depth).
    pub row_overhead: usize,
    /// Clock (GHz) — the paper synthesizes at 1 GHz.
    pub freq_ghz: f64,
}

impl Default for Pipeline {
    fn default() -> Self {
        Pipeline { lanes: 32, row_overhead: 4, freq_ghz: 1.0 }
    }
}

/// Cycle counts for an (R rows x L elements) workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cycles {
    pub per_row_stage: u64,
    pub total: u64,
}

impl Pipeline {
    /// Cycles for one stage over one row.
    pub fn stage_cycles(&self, elems_per_row: usize) -> u64 {
        (elems_per_row.div_ceil(self.lanes) + self.row_overhead) as u64
    }

    /// Total cycles for R rows with both stages overlapped (ping-pong).
    pub fn run(&self, rows: usize, elems_per_row: usize) -> Cycles {
        let per = self.stage_cycles(elems_per_row);
        let total = per * (rows as u64 + 1); // +1: fill/drain of the pipeline
        Cycles { per_row_stage: per, total }
    }

    /// Wall-clock seconds for R rows of L elements on `units` parallel
    /// units (the paper scales to 32 units for the GPU comparison).
    pub fn seconds(&self, rows: usize, elems_per_row: usize, units: usize) -> f64 {
        let rows_per_unit = rows.div_ceil(units.max(1));
        self.run(rows_per_unit, elems_per_row).total as f64 * 1e-9 / self.freq_ghz
    }

    /// Element throughput (elements/s) at steady state on one unit.
    pub fn throughput(&self, elems_per_row: usize) -> f64 {
        let per = self.stage_cycles(elems_per_row) as f64;
        elems_per_row as f64 / per * self.freq_ghz * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_cycles_ceil() {
        let p = Pipeline { lanes: 32, row_overhead: 0, freq_ghz: 1.0 };
        assert_eq!(p.stage_cycles(32), 1);
        assert_eq!(p.stage_cycles(33), 2);
        assert_eq!(p.stage_cycles(785), 25);
    }

    #[test]
    fn pipelining_overlaps_stages() {
        let p = Pipeline { lanes: 32, row_overhead: 0, freq_ghz: 1.0 };
        let c = p.run(100, 64);
        // 2 cycles/row, 100 rows -> ~202 total, NOT 2 stages * 200
        assert_eq!(c.total, 2 * 101);
    }

    #[test]
    fn units_scale_seconds_down() {
        let p = Pipeline::default();
        let t1 = p.seconds(32_000, 785, 1);
        let t32 = p.seconds(32_000, 785, 32);
        assert!(t1 / t32 > 30.0 && t1 / t32 < 33.0);
    }

    #[test]
    fn throughput_matches_hand_calc() {
        let p = Pipeline { lanes: 32, row_overhead: 4, freq_ghz: 1.0 };
        // 785 elems -> 25+4 = 29 cycles -> 785/29 G elem/s
        let t = p.throughput(785);
        assert!((t - 785.0 / 29.0 * 1e9).abs() / t < 1e-12);
    }
}
