//! Lane-parallel kernel arms with runtime dispatch (DESIGN.md §3.4).
//!
//! The planar kernels in `softmax/e2.rs`, `layernorm/ai.rs` and
//! `ops/attention.rs` each carry two implementations of their hot loop:
//! the original scalar code (kept verbatim — it is both the portable
//! fallback and the bit-exactness oracle) and an explicit-width AVX2 arm.
//! Which arm runs is a [`Dispatch`] value chosen **once at construction**
//! via [`Dispatch::detect`] and stored on the op, so the per-row/per-batch
//! paths never re-probe CPU features and every existing caller gets the
//! vector arm with zero API change.
//!
//! Ground rules that keep the arms bit-identical (enforced by
//! `tests/simd_dispatch.rs` and the `bench_kernels` exactness gate):
//!
//! * integer stage-1 reductions may reassociate (addition is exact), but
//!   every f32 operation keeps the scalar evaluation order — no FMA, no
//!   reassociated float sums (A·V vectorizes across the *output* lanes so
//!   each lane's j-walk is the scalar one);
//! * inputs the vector arm cannot represent (out-of-grid deltas, wide PTF
//!   shifts, non-u8 zero points) fall through to the scalar code path at
//!   group or row granularity;
//! * remainder tails shorter than a vector always run the scalar epilogue.
//!
//! `SOLE_FORCE_SCALAR=1` (read once, like the bench quick-mode switch)
//! pins everything to [`Dispatch::Scalar`] for A/B timing and CI.

use std::fmt;
use std::sync::OnceLock;

pub mod av;
pub mod e2;
pub mod ln;

/// Which kernel arm an op selected at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// The portable scalar arm — also the bit-exactness oracle.
    Scalar,
    /// The AVX2 arm (x86-64 with runtime `avx2` support only).
    Avx2,
}

impl Dispatch {
    /// Probe once: AVX2 when the host supports it and
    /// `SOLE_FORCE_SCALAR` is not set, scalar otherwise.
    pub fn detect() -> Dispatch {
        if force_scalar() || !avx2_supported() {
            Dispatch::Scalar
        } else {
            Dispatch::Avx2
        }
    }

    /// Clamp an explicitly requested arm to what this host can actually
    /// run (and to scalar under `SOLE_FORCE_SCALAR`), so `with_dispatch`
    /// constructors are safe on any machine.
    pub fn sanitize(self) -> Dispatch {
        match self {
            Dispatch::Avx2 if !force_scalar() && avx2_supported() => Dispatch::Avx2,
            _ => Dispatch::Scalar,
        }
    }

    /// The arms runnable on this host right now — what conformance tests
    /// and benches iterate to compare every available arm against scalar.
    pub fn available() -> Vec<Dispatch> {
        let mut arms = vec![Dispatch::Scalar];
        if !force_scalar() && avx2_supported() {
            arms.push(Dispatch::Avx2);
        }
        arms
    }

    /// Stable lowercase name for bench records and the `sole ops` table.
    pub fn as_str(self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx2 => "avx2",
        }
    }
}

impl fmt::Display for Dispatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// `SOLE_FORCE_SCALAR` set (and not "0"), read once per process — same
/// latch-on-first-read discipline as the bench quick-mode switch, so
/// toggling the variable mid-run cannot desync ops constructed before
/// and after.
pub fn force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        std::env::var_os("SOLE_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0")
    })
}

#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_supported() -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_is_an_available_arm() {
        let arms = Dispatch::available();
        assert!(arms.contains(&Dispatch::Scalar));
        assert!(arms.contains(&Dispatch::detect()));
    }

    #[test]
    fn sanitize_is_idempotent_and_never_invents_an_arm() {
        for &arm in &[Dispatch::Scalar, Dispatch::Avx2] {
            let s = arm.sanitize();
            assert_eq!(s.sanitize(), s);
            assert!(Dispatch::available().contains(&s), "{arm:?} -> {s:?}");
        }
        assert_eq!(Dispatch::Scalar.sanitize(), Dispatch::Scalar);
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Dispatch::Scalar.as_str(), "scalar");
        assert_eq!(Dispatch::Avx2.as_str(), "avx2");
        assert_eq!(Dispatch::Avx2.to_string(), "avx2");
    }
}
