//! AVX2 arms of the E2Softmax planar kernels (`softmax/e2.rs`).
//!
//! Stage 1 vectorizes the per-slice running max (4 × i64 compare-blend
//! tree) and the k-code / Q(.15)-summand generation: eight deltas at a
//! time are narrowed to i32, gathered through the widened
//! [`Log2ExpTable`] k table, turned into summands with one variable
//! shift (`2^(SUM_FRAC - k)` — recomputing beats a second gather), and
//! byte-packed back into the scratch `k` buffer with an in-register
//! shuffle.  The online sum is exact integer addition, so lanes may
//! accumulate independently and reduce horizontally per slice — the
//! truncating inter-slice `>>` rescale still sees exactly the scalar
//! value.  Any group holding a delta outside the 8-bit code grid (only
//! reachable with hand-built rows) falls through to the scalar
//! `k_pow` fallback for that group.
//!
//! Stage 2 is a pure `table[k + sub]` expansion: eight bytes widen to
//! dword indices, one `vgatherdps` against the ≤ 32-entry ALDivision
//! value table, one store.  The code twin is a straight byte add
//! (`k + sub <= 30`, no carry).  Both index in `[0, 30]` by
//! construction — k and sub saturate at 15 — so the gather never leaves
//! the table.
//!
//! Everything here is bit-identical to the scalar loops in
//! `softmax/e2.rs` (pinned by `tests/simd_dispatch.rs`); tails shorter
//! than a vector run the same scalar epilogue inline.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use crate::softmax::e2::VAL_TABLE_LEN;
use crate::softmax::log2exp::Log2ExpTable;

#[cfg(target_arch = "x86_64")]
use crate::softmax::config::SUM_FRAC;

/// Flush the lane accumulator of Q(.15) summands after this many
/// 8-element groups: each lane add is at most `2^SUM_FRAC`, so the u32
/// lanes stay exact for far longer than any real row, but a hand-built
/// mega-slice must not overflow either.
#[cfg(target_arch = "x86_64")]
const POW_FLUSH_GROUPS: u32 = 1 << 16;

/// Stage 1 of the planar row kernel: fills `k_out` (byte k codes) and
/// `slice_m` (per-slice running max), returns `(sum_q15, m_final)` —
/// bit-identical to the scalar loop in `E2Softmax::row_prepare`.
///
/// # Safety
///
/// Caller must ensure the host supports AVX2 (the `Dispatch::Avx2` arm
/// only exists after runtime detection) and that `k_out.len() == q.len()`
/// and `slice_m.len() == q.len().div_ceil(chunk)` with `chunk >= 1` and
/// `q` non-empty, exactly as `row_prepare` sizes them.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn stage1_avx2(
    t: &Log2ExpTable,
    chunk: usize,
    q: &[i64],
    k_out: &mut [u8],
    slice_m: &mut [i64],
) -> (u64, i64) {
    debug_assert!(!q.is_empty());
    debug_assert_eq!(k_out.len(), q.len());
    debug_assert_eq!(slice_m.len(), q.len().div_ceil(chunk));
    let k32 = t.k32().as_ptr();
    // byte selector: dword lanes 0..3 -> packed bytes 0..3 per 128 lane
    let pack = _mm256_set_epi8(
        -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, -1, 12, 8, 4, 0, -1, -1, -1, -1, -1, -1, -1,
        -1, -1, -1, -1, -1, 12, 8, 4, 0,
    );
    let ones = _mm256_set1_epi32(1);
    let frac = _mm256_set1_epi32(SUM_FRAC as i32);
    let grid = _mm256_set1_epi64x(255);
    let zero = _mm256_setzero_si256();
    let mut sum: u64 = 0;
    let mut m_prev = i64::MIN;
    for (sl, (ks, ms)) in q.chunks(chunk).zip(k_out.chunks_mut(chunk).zip(slice_m.iter_mut())) {
        let n = sl.len();
        // local max: 4-lane i64 compare-blend, scalar tail
        let mut local = i64::MIN;
        let mut i = 0;
        if n >= 4 {
            let mut vmax = _mm256_loadu_si256(sl.as_ptr() as *const __m256i);
            i = 4;
            while i + 4 <= n {
                let v = _mm256_loadu_si256(sl.as_ptr().add(i) as *const __m256i);
                vmax = _mm256_blendv_epi8(vmax, v, _mm256_cmpgt_epi64(v, vmax));
                i += 4;
            }
            let mut lanes = [0i64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, vmax);
            for &v in &lanes {
                local = local.max(v);
            }
        }
        while i < n {
            local = local.max(sl[i]);
            i += 1;
        }
        let m_new = if m_prev == i64::MIN { local } else { m_prev.max(local) };
        if m_prev != i64::MIN && m_prev != m_new {
            sum >>= t.k(m_prev - m_new) as u32;
        }
        // k codes + online sum, 8 deltas per step
        let mvec = _mm256_set1_epi64x(m_new);
        let mut acc = _mm256_setzero_si256();
        let mut groups = 0u32;
        let mut j = 0;
        while j + 8 <= n {
            let a = _mm256_loadu_si256(sl.as_ptr().add(j) as *const __m256i);
            let b = _mm256_loadu_si256(sl.as_ptr().add(j + 4) as *const __m256i);
            let da = _mm256_sub_epi64(mvec, a); // -delta, >= 0 on the grid
            let db = _mm256_sub_epi64(mvec, b);
            // off-grid delta (or i64 wraparound) in the group -> the
            // scalar fallback owns it; sum order is irrelevant (exact)
            let oor = _mm256_or_si256(
                _mm256_or_si256(_mm256_cmpgt_epi64(da, grid), _mm256_cmpgt_epi64(db, grid)),
                _mm256_or_si256(_mm256_cmpgt_epi64(zero, da), _mm256_cmpgt_epi64(zero, db)),
            );
            if _mm256_testz_si256(oor, oor) == 0 {
                for jj in j..j + 8 {
                    let (k, pow) = t.k_pow(sl[jj] - m_new);
                    sum += pow;
                    ks[jj] = k;
                }
                j += 8;
                continue;
            }
            // narrow the eight in-grid i64 deltas to packed i32 lanes
            let sa = _mm256_shuffle_epi32::<0x88>(da);
            let sb = _mm256_shuffle_epi32::<0x88>(db);
            let idx = _mm256_permute4x64_epi64::<0xD8>(_mm256_unpacklo_epi64(sa, sb));
            let k = _mm256_i32gather_epi32::<4>(k32, idx);
            // summand 2^(SUM_FRAC - k): one variable shift per lane
            let pw = _mm256_sllv_epi32(ones, _mm256_sub_epi32(frac, k));
            acc = _mm256_add_epi32(acc, pw);
            groups += 1;
            if groups == POW_FLUSH_GROUPS {
                sum += hsum_u32(acc);
                acc = _mm256_setzero_si256();
                groups = 0;
            }
            // byte-pack the eight k codes (each <= 15) and store
            let bytes = _mm256_shuffle_epi8(k, pack);
            let eight =
                _mm_unpacklo_epi32(_mm256_castsi256_si128(bytes), _mm256_extracti128_si256::<1>(bytes));
            _mm_storel_epi64(ks.as_mut_ptr().add(j) as *mut __m128i, eight);
            j += 8;
        }
        sum += hsum_u32(acc);
        while j < n {
            let (k, pow) = t.k_pow(sl[j] - m_new);
            sum += pow;
            ks[j] = k;
            j += 1;
        }
        *ms = m_new;
        m_prev = m_new;
    }
    (sum, m_prev)
}

/// Stage 2 of the f32 row kernel: `out[i] = val[k[i] + sub_slice]` —
/// bit-identical to the scalar loop in `E2Softmax::row_kernel` (the
/// gather reads the same table entries the scalar index would).
///
/// # Safety
///
/// AVX2 host required; `k`, `out` are the full row (`k.len() ==
/// out.len()`) and `slice_m` its per-slice maxima as filled by stage 1
/// with the same `chunk`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn stage2_f32_avx2(
    t: &Log2ExpTable,
    chunk: usize,
    k: &[u8],
    slice_m: &[i64],
    m_final: i64,
    val: &[f32; VAL_TABLE_LEN],
    out: &mut [f32],
) {
    debug_assert_eq!(k.len(), out.len());
    let vp = val.as_ptr();
    for ((ks, os), &m_sl) in k.chunks(chunk).zip(out.chunks_mut(chunk)).zip(slice_m.iter()) {
        let sub = t.k(m_sl - m_final);
        let subv = _mm256_set1_epi32(sub as i32);
        let n = ks.len();
        let mut j = 0;
        while j + 8 <= n {
            let kb = _mm_loadl_epi64(ks.as_ptr().add(j) as *const __m128i);
            let idx = _mm256_add_epi32(_mm256_cvtepu8_epi32(kb), subv);
            // k, sub <= 15 -> idx <= 30, always inside the 32-entry table
            _mm256_storeu_ps(os.as_mut_ptr().add(j), _mm256_i32gather_ps::<4>(vp, idx));
            j += 8;
        }
        while j < n {
            os[j] = val[(ks[j] as i64 + sub) as usize];
            j += 1;
        }
    }
}

/// Stage 2 of the code twin: `codes[i] = k[i] + sub_slice` as one wide
/// byte add (both operands <= 15, no carry) — bit-identical to the
/// scalar loop in `E2Softmax::row_codes`.
///
/// # Safety
///
/// AVX2 host required; same buffer contract as [`stage2_f32_avx2`] with
/// `codes` in place of `out`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn stage2_codes_avx2(
    t: &Log2ExpTable,
    chunk: usize,
    k: &[u8],
    slice_m: &[i64],
    m_final: i64,
    codes: &mut [u8],
) {
    debug_assert_eq!(k.len(), codes.len());
    for ((ks, cs), &m_sl) in k.chunks(chunk).zip(codes.chunks_mut(chunk)).zip(slice_m.iter()) {
        let sub = t.k(m_sl - m_final) as u8;
        let subv = _mm256_set1_epi8(sub as i8);
        let n = ks.len();
        let mut j = 0;
        while j + 32 <= n {
            let v = _mm256_loadu_si256(ks.as_ptr().add(j) as *const __m256i);
            _mm256_storeu_si256(cs.as_mut_ptr().add(j) as *mut __m256i, _mm256_add_epi8(v, subv));
            j += 32;
        }
        while j < n {
            cs[j] = ks[j] + sub;
            j += 1;
        }
    }
}

/// Horizontal sum of eight u32 lanes into u64.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_u32(v: __m256i) -> u64 {
    let mut lanes = [0u32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes.iter().map(|&x| x as u64).sum()
}

// ---- portable stubs ----------------------------------------------------
//
// `Dispatch::sanitize` guarantees the Avx2 arm is never selected off
// x86-64, so these exist only to keep call sites cfg-free.

/// Non-x86 stub; never reached (see module docs).
///
/// # Safety
///
/// Never called: `Dispatch::Avx2` cannot be constructed on this target.
#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn stage1_avx2(
    _t: &Log2ExpTable,
    _chunk: usize,
    _q: &[i64],
    _k_out: &mut [u8],
    _slice_m: &mut [i64],
) -> (u64, i64) {
    unreachable!("avx2 arm selected on a non-x86_64 target")
}

/// Non-x86 stub; never reached (see module docs).
///
/// # Safety
///
/// Never called: `Dispatch::Avx2` cannot be constructed on this target.
#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn stage2_f32_avx2(
    _t: &Log2ExpTable,
    _chunk: usize,
    _k: &[u8],
    _slice_m: &[i64],
    _m_final: i64,
    _val: &[f32; VAL_TABLE_LEN],
    _out: &mut [f32],
) {
    unreachable!("avx2 arm selected on a non-x86_64 target")
}

/// Non-x86 stub; never reached (see module docs).
///
/// # Safety
///
/// Never called: `Dispatch::Avx2` cannot be constructed on this target.
#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn stage2_codes_avx2(
    _t: &Log2ExpTable,
    _chunk: usize,
    _k: &[u8],
    _slice_m: &[i64],
    _m_final: i64,
    _codes: &mut [u8],
) {
    unreachable!("avx2 arm selected on a non-x86_64 target")
}
