//! AVX2 arms of the AILayerNorm planar kernels (`layernorm/ai.rs`).
//!
//! Stage 1 vectorizes the statistic calculation: eight u8 codes and
//! their PTF factors widen to dwords, `(code - zp) << a` accumulates
//! `E_x` in four i64 lanes, and the compress-square magnitudes gather
//! through the 256-entry [`COMPRESSED_SQUARE_TABLE`] as i64 pairs
//! (`vpgatherdq`), PTF-shifted by `2a` with a 64-bit variable shift.
//! Both reductions are exact integer sums, so lane accumulation +
//! horizontal reduction reproduces the scalar value bit for bit.
//!
//! Stage 2 vectorizes the fused affine pass: the exactly-centered
//! numerator `C·D_i - E_x` is built in i32 lanes (the caller proves it
//! fits), converted with `vcvtdq2ps` — which rounds nearest-even exactly
//! like the scalar `as f32` — and finished as
//! `(gamma * si_over_c) * num + beta` in the scalar evaluation order
//! (mul, mul, add — **no FMA**, which would change the rounding).
//!
//! Eligibility is the caller's job (`AiLayerNorm` gates on
//! `zp ∈ [0, 255]`, `alpha < 16`, and the stage-2 i32 bound); rows that
//! fail any gate take the scalar arm whole.  Pinned bit-exact by
//! `tests/simd_dispatch.rs`.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Stage 1: `(Σ (code-zp)<<a, Σ sq[|code-zp|]<<2a)` — the raw sums
/// before the deferred `<< 4` decompress, bit-identical to the scalar
/// accumulation in `AiLayerNorm::row_stats`.
///
/// # Safety
///
/// AVX2 host required; `codes.len() == alpha.len()`, `sq` is the
/// 256-entry compress-square table, `zp ∈ [0, 255]` and every
/// `alpha < 16` (the caller's eligibility gate — it keeps `(code-zp)<<a`
/// in i32 and the 64-bit shifts under 64).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn stats_avx2(zp: i32, codes: &[u8], alpha: &[u8], sq: &[i64; 256]) -> (i64, i64) {
    debug_assert_eq!(codes.len(), alpha.len());
    debug_assert!((0..=255).contains(&zp));
    let c = codes.len();
    let zpv = _mm256_set1_epi32(zp);
    let cap = _mm256_set1_epi32(255);
    let sqp = sq.as_ptr();
    let mut ex_acc = _mm256_setzero_si256(); // 4 x i64
    let mut ex2_acc = _mm256_setzero_si256(); // 4 x i64
    let mut i = 0;
    while i + 8 <= c {
        let cb = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
        let ab = _mm_loadl_epi64(alpha.as_ptr().add(i) as *const __m128i);
        let xi = _mm256_sub_epi32(_mm256_cvtepu8_epi32(cb), zpv);
        let a = _mm256_cvtepu8_epi32(ab);
        // E_x term: (code - zp) << a, widened to i64 before accumulating
        let sh = _mm256_sllv_epi32(xi, a);
        let sh_lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(sh));
        let sh_hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(sh));
        ex_acc = _mm256_add_epi64(ex_acc, _mm256_add_epi64(sh_lo, sh_hi));
        // E_x2 term: gather the compressed square by magnitude, << 2a
        let mag = _mm256_min_epi32(_mm256_abs_epi32(xi), cap);
        let sq_lo = _mm256_i32gather_epi64::<8>(sqp, _mm256_castsi256_si128(mag));
        let sq_hi = _mm256_i32gather_epi64::<8>(sqp, _mm256_extracti128_si256::<1>(mag));
        let a2 = _mm256_add_epi32(a, a);
        let a2_lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(a2));
        let a2_hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(a2));
        ex2_acc = _mm256_add_epi64(ex2_acc, _mm256_sllv_epi64(sq_lo, a2_lo));
        ex2_acc = _mm256_add_epi64(ex2_acc, _mm256_sllv_epi64(sq_hi, a2_hi));
        i += 8;
    }
    let mut ex = hsum_i64(ex_acc);
    let mut ex2 = hsum_i64(ex2_acc);
    while i < c {
        let xi = codes[i] as i64 - zp as i64;
        let a = alpha[i] as u32;
        ex += xi << a;
        let mag = xi.unsigned_abs().min(255) as usize;
        ex2 += sq[mag] << (2 * a);
        i += 1;
    }
    (ex, ex2)
}

/// Stage 2: `out[i] = gamma[i] * si_over_c * (D_i·C - E_x) + beta[i]`
/// with the numerator built in i32 lanes — bit-identical to the scalar
/// loop in `AiLayerNorm::row_kernel` (same float evaluation order, and
/// `vcvtdq2ps` rounds exactly like the scalar `i64 as f32` in range).
///
/// # Safety
///
/// AVX2 host required; all slices are one row of equal length,
/// `zp ∈ [0, 255]`, every `alpha < 16`, and the caller has proven
/// `|D_i·C - E_x|` and `|D_i·C|` fit in i32 for the row (the
/// `C·(255 << max_alpha) + |E_x|` bound in `AiLayerNorm`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)] // one row's worth of planes, mirrors row_kernel
pub unsafe fn stage2_avx2(
    zp: i32,
    c: i32,
    ex: i32,
    si_over_c: f32,
    codes: &[u8],
    alpha: &[u8],
    gamma: &[f32],
    beta: &[f32],
    out: &mut [f32],
) {
    let n = codes.len();
    debug_assert!(alpha.len() == n && gamma.len() == n && beta.len() == n && out.len() == n);
    let zpv = _mm256_set1_epi32(zp);
    let cv = _mm256_set1_epi32(c);
    let exv = _mm256_set1_epi32(ex);
    let siv = _mm256_set1_ps(si_over_c);
    let mut i = 0;
    while i + 8 <= n {
        let cb = _mm_loadl_epi64(codes.as_ptr().add(i) as *const __m128i);
        let ab = _mm_loadl_epi64(alpha.as_ptr().add(i) as *const __m128i);
        let d = _mm256_sllv_epi32(
            _mm256_sub_epi32(_mm256_cvtepu8_epi32(cb), zpv),
            _mm256_cvtepu8_epi32(ab),
        );
        let num = _mm256_sub_epi32(_mm256_mullo_epi32(d, cv), exv);
        let numf = _mm256_cvtepi32_ps(num);
        let g = _mm256_loadu_ps(gamma.as_ptr().add(i));
        let b = _mm256_loadu_ps(beta.as_ptr().add(i));
        let y = _mm256_add_ps(_mm256_mul_ps(_mm256_mul_ps(g, siv), numf), b);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), y);
        i += 8;
    }
    while i < n {
        let d = (codes[i] as i64 - zp as i64) << alpha[i];
        let num = d * c as i64 - ex as i64;
        out[i] = gamma[i] * si_over_c * num as f32 + beta[i];
        i += 1;
    }
}

/// Horizontal sum of four i64 lanes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_i64(v: __m256i) -> i64 {
    let mut lanes = [0i64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes.iter().sum()
}

// ---- portable stubs ----------------------------------------------------

/// Non-x86 stub; never reached (see module docs).
///
/// # Safety
///
/// Never called: `Dispatch::Avx2` cannot be constructed on this target.
#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn stats_avx2(_zp: i32, _codes: &[u8], _alpha: &[u8], _sq: &[i64; 256]) -> (i64, i64) {
    unreachable!("avx2 arm selected on a non-x86_64 target")
}

/// Non-x86 stub; never reached (see module docs).
///
/// # Safety
///
/// Never called: `Dispatch::Avx2` cannot be constructed on this target.
#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub unsafe fn stage2_avx2(
    _zp: i32,
    _c: i32,
    _ex: i32,
    _si_over_c: f32,
    _codes: &[u8],
    _alpha: &[u8],
    _gamma: &[f32],
    _beta: &[f32],
    _out: &mut [f32],
) {
    unreachable!("avx2 arm selected on a non-x86_64 target")
}
