//! AVX2 arms of the shift-accumulate A·V stage (`ops/attention.rs`).
//!
//! One output row `O[i] = Σ_j P[i,j]·V[j]` vectorizes across the *head
//! dimension*: eight output lanes accumulate in one register while `j`
//! walks the full probability row, broadcasting each weight.  That keeps
//! every output lane's float additions in exactly the scalar `j` order
//! (mul then add, **no FMA**), which is what makes the arm bit-identical
//! to the scalar triple loop — vectorizing across `j` instead would
//! reassociate the sum and drift by ulps.
//!
//! On the `Log2Code5` port the weight is `val[code]` — the row's
//! expanded ≤ 32-entry ALDivision shift table, one byte read per weight,
//! same as the scalar code path.  `d` tails shorter than a vector run a
//! scalar epilogue that also walks `j` sequentially per lane.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

use crate::softmax::e2::VAL_TABLE_LEN;

/// One f32 A·V output row: `o_row[t] = Σ_j p_row[j] * v[j*d + t]`.
///
/// # Safety
///
/// AVX2 host required; `v.len() == p_row.len() * d` and
/// `o_row.len() == d`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn av_row_f32_avx2(p_row: &[f32], v: &[f32], d: usize, o_row: &mut [f32]) {
    let l = p_row.len();
    debug_assert_eq!(v.len(), l * d);
    debug_assert_eq!(o_row.len(), d);
    let mut t = 0;
    while t + 8 <= d {
        let mut acc = _mm256_setzero_ps();
        for (j, &pij) in p_row.iter().enumerate() {
            let p = _mm256_set1_ps(pij);
            let vv = _mm256_loadu_ps(v.as_ptr().add(j * d + t));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(p, vv));
        }
        _mm256_storeu_ps(o_row.as_mut_ptr().add(t), acc);
        t += 8;
    }
    while t < d {
        let mut acc = 0f32;
        for (j, &pij) in p_row.iter().enumerate() {
            acc += pij * v[j * d + t];
        }
        o_row[t] = acc;
        t += 1;
    }
}

/// One `Log2Code5` A·V output row: the weight dequantizes through the
/// row's expanded shift table, `o_row[t] = Σ_j val[code[j]] * v[j*d+t]`.
///
/// # Safety
///
/// AVX2 host required; `v.len() == code_row.len() * d`,
/// `o_row.len() == d`, and every code indexes inside `val` (codes are
/// `k + sub <= 30` by construction; a hand-built out-of-table code
/// panics exactly like the scalar index would).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn av_row_codes_avx2(
    code_row: &[u8],
    val: &[f32; VAL_TABLE_LEN],
    v: &[f32],
    d: usize,
    o_row: &mut [f32],
) {
    let l = code_row.len();
    debug_assert_eq!(v.len(), l * d);
    debug_assert_eq!(o_row.len(), d);
    let mut t = 0;
    while t + 8 <= d {
        let mut acc = _mm256_setzero_ps();
        for (j, &code) in code_row.iter().enumerate() {
            let p = _mm256_set1_ps(val[code as usize]);
            let vv = _mm256_loadu_ps(v.as_ptr().add(j * d + t));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(p, vv));
        }
        _mm256_storeu_ps(o_row.as_mut_ptr().add(t), acc);
        t += 8;
    }
    while t < d {
        let mut acc = 0f32;
        for (j, &code) in code_row.iter().enumerate() {
            acc += val[code as usize] * v[j * d + t];
        }
        o_row[t] = acc;
        t += 1;
    }
}

// ---- portable stubs ----------------------------------------------------

/// Non-x86 stub; never reached (see module docs).
///
/// # Safety
///
/// Never called: `Dispatch::Avx2` cannot be constructed on this target.
#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn av_row_f32_avx2(_p_row: &[f32], _v: &[f32], _d: usize, _o_row: &mut [f32]) {
    unreachable!("avx2 arm selected on a non-x86_64 target")
}

/// Non-x86 stub; never reached (see module docs).
///
/// # Safety
///
/// Never called: `Dispatch::Avx2` cannot be constructed on this target.
#[cfg(not(target_arch = "x86_64"))]
pub unsafe fn av_row_codes_avx2(
    _code_row: &[u8],
    _val: &[f32; VAL_TABLE_LEN],
    _v: &[f32],
    _d: usize,
    _o_row: &mut [f32],
) {
    unreachable!("avx2 arm selected on a non-x86_64 target")
}
