//! Streaming statistics + latency histogram (coordinator metrics substrate).

/// Welford streaming mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Streaming {
    pub fn new() -> Self {
        Streaming { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator into this one (Chan et al. parallel
    /// Welford) — the metrics shard-merge path.
    pub fn merge(&mut self, other: &Streaming) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Log-bucketed latency histogram: buckets are `base * 2^(i/4)` seconds —
/// ~19% resolution from 1us to ~1000s, fixed memory, O(1) insert.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

const HIST_BASE: f64 = 1e-6; // 1 us
const HIST_BUCKETS: usize = 128;

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { counts: vec![0; HIST_BUCKETS], total: 0, sum: 0.0 }
    }

    fn bucket(secs: f64) -> usize {
        if secs <= HIST_BASE {
            return 0;
        }
        let idx = (4.0 * (secs / HIST_BASE).log2()).floor() as i64;
        idx.clamp(0, HIST_BUCKETS as i64 - 1) as usize
    }

    pub fn record(&mut self, secs: f64) {
        self.counts[Self::bucket(secs)] += 1;
        self.total += 1;
        self.sum += secs;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    /// Approximate quantile (bucket upper edge), q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return HIST_BASE * 2f64.powf((i as f64 + 1.0) / 4.0);
            }
        }
        HIST_BASE * 2f64.powf(HIST_BUCKETS as f64 / 4.0)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold another histogram into this one (same fixed bucketing, so the
    /// merge is exact) — the metrics shard-merge path.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn hist_quantiles_bracket_true_values() {
        let mut h = LatencyHist::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5); // 10us .. 10ms uniform
        }
        let p50 = h.p50();
        assert!(p50 > 3e-3 && p50 < 8e-3, "p50 {p50}");
        let p99 = h.p99();
        assert!(p99 > 8e-3 && p99 < 1.5e-2, "p99 {p99}");
        assert!((h.mean() - 5.005e-3).abs() < 1e-4);
    }

    #[test]
    fn hist_empty() {
        let h = LatencyHist::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn streaming_merge_matches_single_pass() {
        let xs: Vec<f64> = (0..200).map(|i| (i as f64 * 0.37).sin() * 5.0 + 2.0).collect();
        let mut whole = Streaming::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Streaming::new();
        let mut b = Streaming::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 3 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
        }
        let mut merged = Streaming::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.count(), whole.count());
        assert!((merged.mean() - whole.mean()).abs() < 1e-9);
        assert!((merged.var() - whole.var()).abs() < 1e-9);
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
        // merging an empty accumulator is a no-op
        merged.merge(&Streaming::new());
        assert_eq!(merged.count(), whole.count());
    }

    #[test]
    fn merge_is_order_insensitive_across_many_shards() {
        // the metrics reader folds worker shards in whatever order the
        // shard vector happens to hold; the result must not depend on
        // that order — exact for the histogram, fp-tight for Welford
        let xs: Vec<f64> = (0..600).map(|i| ((i as f64 * 0.61).cos() * 3.0 + 3.5).abs()).collect();
        let mut shards_s = vec![Streaming::new(); 5];
        let mut shards_h = vec![LatencyHist::new(); 5];
        for (i, &x) in xs.iter().enumerate() {
            shards_s[i % 5].push(x);
            shards_h[i % 5].record(x * 1e-4);
        }
        let fold = |order: &[usize]| {
            let mut s = Streaming::new();
            let mut h = LatencyHist::new();
            for &i in order {
                s.merge(&shards_s[i]);
                h.merge(&shards_h[i]);
            }
            (s, h)
        };
        let (s_fwd, h_fwd) = fold(&[0, 1, 2, 3, 4]);
        let (s_rev, h_rev) = fold(&[4, 3, 2, 1, 0]);
        let (s_mix, h_mix) = fold(&[2, 0, 4, 1, 3]);
        for (s, h) in [(&s_rev, &h_rev), (&s_mix, &h_mix)] {
            assert_eq!(s.count(), s_fwd.count());
            assert!((s.mean() - s_fwd.mean()).abs() < 1e-9);
            assert!((s.var() - s_fwd.var()).abs() < 1e-9);
            assert_eq!(s.min(), s_fwd.min());
            assert_eq!(s.max(), s_fwd.max());
            assert_eq!(h.count(), h_fwd.count());
            assert_eq!(h.p50(), h_fwd.p50());
            assert_eq!(h.p99(), h_fwd.p99());
            assert!((h.mean() - h_fwd.mean()).abs() < 1e-12);
        }
        // merging into an empty accumulator reproduces the source
        let mut empty = LatencyHist::new();
        empty.merge(&h_fwd);
        assert_eq!(empty.count(), h_fwd.count());
        assert_eq!(empty.p50(), h_fwd.p50());
    }

    #[test]
    fn hist_merge_is_exact() {
        let mut whole = LatencyHist::new();
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        for i in 1..=1000 {
            let v = i as f64 * 1e-5;
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.p50(), whole.p50());
        assert_eq!(a.p99(), whole.p99());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
    }
}
