//! Micro property-testing driver (offline substrate — `proptest` is not
//! vendored).  Runs a closure over N seeded RNGs; on failure reports the
//! seed so the case is replayable.  No shrinking — cases are generated
//! small-biased instead (generators draw sizes log-uniformly).

use super::rng::Rng;

/// Run `case(rng)` for `n` deterministic seeds (derived from `base_seed`).
/// Panics with the failing seed on the first assertion failure.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, n: usize, base_seed: u64, case: F) {
    for i in 0..n {
        let seed = base_seed.wrapping_mul(0x100000001b3).wrapping_add(i as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            case(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Log-uniform size in [1, max] — biases toward small cases like shrinking
/// would find.
pub fn size(rng: &mut Rng, max: usize) -> usize {
    let lg = (max as f64).ln();
    ((rng.f64() * lg).exp() as usize).clamp(1, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 50, 1, |rng| {
            let a = rng.range_i64(-1000, 1000);
            let b = rng.range_i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 10, 2, |rng| {
            let x = rng.range_i64(0, 10);
            assert!(x < 0, "x was {x}");
        });
    }

    #[test]
    fn size_in_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let s = size(&mut rng, 64);
            assert!((1..=64).contains(&s));
        }
    }
}
