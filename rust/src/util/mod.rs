//! In-tree substrates (this build environment is offline: only the `xla`
//! crate's dependency closure is vendored, so JSON, CLI parsing, RNG,
//! stats, benchmarking and property testing are implemented here —
//! DESIGN.md §5 item 13).

pub mod bench;
pub mod cli;
pub mod dist;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
