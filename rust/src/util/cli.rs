//! Tiny CLI argument parser (offline substrate — `clap` is not vendored).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["experiment", "fig6a", "--batches", "1,2,4", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig6a"]);
        assert_eq!(a.opt("batches"), Some("1,2,4"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["serve", "--port=8080"]);
        assert_eq!(a.opt_usize("port", 0), 8080);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.opt_usize("n", 7), 7);
        assert_eq!(a.opt_f64("r", 1.5), 1.5);
        assert_eq!(a.opt_str("s", "d"), "d");
        assert!(!a.flag("q"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["cmd", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }
}
