//! Tiny CLI argument parser (offline substrate — `clap` is not vendored).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Typed accessors are fallible: a malformed value (`--workers=abc`) is an
//! error naming the flag, never a silent fall-through to the default.

use std::collections::BTreeMap;

use anyhow::Result;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// `--name N` as usize; `default` when absent, an error naming the
    /// flag when present but malformed (`--workers=abc` used to silently
    /// become the default).
    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected an unsigned integer, got '{s}'")),
        }
    }

    /// `--name X` as f64; same contract as `opt_usize`.
    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => {
                s.parse().map_err(|_| anyhow::anyhow!("--{name}: expected a number, got '{s}'"))
            }
        }
    }

    /// `--name a,b,c` as a comma-separated list of `T` (`default` uses
    /// the same syntax).  Any unparsable entry is an error naming the
    /// flag, never a silently dropped element.
    pub fn opt_list<T: std::str::FromStr>(&self, name: &str, default: &str) -> Result<Vec<T>> {
        let raw = self.opt(name).unwrap_or(default);
        let mut out = Vec::new();
        for tok in raw.split(',') {
            let tok = tok.trim();
            match tok.parse() {
                Ok(v) => out.push(v),
                Err(_) => anyhow::bail!("--{name}: invalid entry '{tok}' in '{raw}'"),
            }
        }
        Ok(out)
    }

    pub fn opt_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["experiment", "fig6a", "--batches", "1,2,4", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig6a"]);
        assert_eq!(a.opt("batches"), Some("1,2,4"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["serve", "--port=8080"]);
        assert_eq!(a.opt_usize("port", 0).unwrap(), 8080);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.opt_usize("n", 7).unwrap(), 7);
        assert_eq!(a.opt_f64("r", 1.5).unwrap(), 1.5);
        assert_eq!(a.opt_str("s", "d"), "d");
        assert!(!a.flag("q"));
    }

    #[test]
    fn malformed_values_error_naming_the_flag() {
        // the old behavior silently fell back to the default — a typo'd
        // `--workers=abc` ran with 4 workers and nobody noticed
        let a = parse(&["serve", "--workers=abc", "--rate", "fast"]);
        let err = a.opt_usize("workers", 4).unwrap_err().to_string();
        assert!(err.contains("--workers"), "{err}");
        assert!(err.contains("abc"), "{err}");
        let err = a.opt_f64("rate", 16.0).unwrap_err().to_string();
        assert!(err.contains("--rate"), "{err}");
        // a negative count is malformed for a usize flag, not clamped
        let a = parse(&["serve", "--workers=-2"]);
        assert!(a.opt_usize("workers", 4).is_err());
    }

    #[test]
    fn list_values_parse_strictly() {
        let a = parse(&["bench", "--batches", "1, 2,16"]);
        let got: Vec<usize> = a.opt_list("batches", "4,8").unwrap();
        assert_eq!(got, vec![1, 2, 16]);
        // absent flag falls back to the default list
        let dflt: Vec<usize> = a.opt_list("rates", "4,8").unwrap();
        assert_eq!(dflt, vec![4, 8]);
        // a bad entry is an error naming the flag, not a dropped element
        let a = parse(&["bench", "--batches", "1,two,4"]);
        let err = a.opt_list::<usize>("batches", "1").unwrap_err().to_string();
        assert!(err.contains("--batches"), "{err}");
        assert!(err.contains("'two'"), "{err}");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["cmd", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }
}
