//! Shared logit-distribution generator: the one place benches and the
//! accuracy harness sample "realistic" softmax inputs from, so a number
//! in `BENCH_serving.json` and a row in `ACCURACY.md` describe the same
//! workload.  Each consumer notes the [`LogitDist`] name and its seed
//! next to the measurement.
//!
//! Three legs, matching the accuracy-harness axes in ISSUE 10:
//! Gaussian logits at the family's calibration σ, a heavy-tailed Laplace
//! leg at the same standard deviation (outlier logits are where the
//! approximations earn or lose their keep), and post-QKᵀ attention
//! logits — `q·kᵢ/√d` over unit-normal Q/K at the paper head width — the
//! distribution the served `attention` pipelines actually feed their
//! softmax stage.

use super::rng::Rng;

/// Standard deviation of the Gaussian and heavy-tail legs — the same
/// reference σ the ConSmax/GN-Softmax default calibrations target.
pub const DIST_SIGMA: f64 = 2.0;

/// Head width of the attention-logits leg (the paper's D = 64).
pub const ATTN_D: usize = 64;

/// Base seed shared by the accuracy harness and `bench_serving`'s
/// workload generators (each consumer derives per-case seeds from it and
/// records the derived seed beside the measurement).
pub const DIST_SEED: u64 = 0xD157;

/// A named logit distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LogitDist {
    /// N(0, σ²) at σ = [`DIST_SIGMA`].
    Gaussian,
    /// Laplace (two-sided exponential) scaled to the same σ — matched
    /// second moment, heavier tails.
    HeavyTail,
    /// Post-QKᵀ attention logits: one unit-normal query against `L`
    /// unit-normal keys at head width [`ATTN_D`], scaled by 1/√d.
    Attention,
}

impl LogitDist {
    /// Every leg, in the order tables render them.
    pub const ALL: [LogitDist; 3] =
        [LogitDist::Gaussian, LogitDist::HeavyTail, LogitDist::Attention];

    /// Stable name used in `ACCURACY.md` / `BENCH_*.json` rows.
    pub fn name(&self) -> &'static str {
        match self {
            LogitDist::Gaussian => "gaussian",
            LogitDist::HeavyTail => "heavy-tail",
            LogitDist::Attention => "attention",
        }
    }

    /// Fill one logit row (any length) from this distribution.
    pub fn fill_row(&self, rng: &mut Rng, out: &mut [f32]) {
        match self {
            LogitDist::Gaussian => rng.fill_normal(out, 0.0, DIST_SIGMA),
            LogitDist::HeavyTail => {
                // Laplace scale b has variance 2b², so b = σ/√2 matches
                // the Gaussian leg's second moment
                let b = DIST_SIGMA / std::f64::consts::SQRT_2;
                for v in out.iter_mut() {
                    let mag = rng.exponential(1.0) * b;
                    *v = if rng.f64() < 0.5 { -mag } else { mag } as f32;
                }
            }
            LogitDist::Attention => {
                let mut q = vec![0f32; ATTN_D];
                rng.fill_normal(&mut q, 0.0, 1.0);
                let scale = 1.0 / (ATTN_D as f32).sqrt();
                let mut k = vec![0f32; ATTN_D];
                for v in out.iter_mut() {
                    rng.fill_normal(&mut k, 0.0, 1.0);
                    let mut acc = 0f32;
                    for (&x, &y) in q.iter().zip(&k) {
                        acc += x * y;
                    }
                    *v = acc * scale;
                }
            }
        }
    }

    /// Fill a packed planar batch of `rows` rows of length `l`.
    pub fn fill_batch(&self, rng: &mut Rng, l: usize, out: &mut [f32]) {
        assert!(l > 0 && out.len() % l == 0, "batch len {} is not a multiple of {l}", out.len());
        for row in out.chunks_exact_mut(l) {
            self.fill_row(rng, row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        for dist in LogitDist::ALL {
            let mut a = vec![0f32; 256];
            let mut b = vec![0f32; 256];
            dist.fill_row(&mut Rng::new(DIST_SEED), &mut a);
            dist.fill_row(&mut Rng::new(DIST_SEED), &mut b);
            assert_eq!(a, b, "{}", dist.name());
            dist.fill_row(&mut Rng::new(DIST_SEED + 1), &mut b);
            assert_ne!(a, b, "{}", dist.name());
        }
    }

    #[test]
    fn names_are_stable_and_unique() {
        let names: Vec<&str> = LogitDist::ALL.iter().map(|d| d.name()).collect();
        assert_eq!(names, vec!["gaussian", "heavy-tail", "attention"]);
    }

    #[test]
    fn legs_have_the_matched_scale() {
        // mean ≈ 0 and std ≈ DIST_SIGMA for the iid legs; the attention
        // leg is unit-ish by the 1/√d scaling (per-row correlation via
        // the shared query keeps it looser)
        let n = 40_000;
        for dist in [LogitDist::Gaussian, LogitDist::HeavyTail] {
            let mut x = vec![0f32; n];
            dist.fill_row(&mut Rng::new(9), &mut x);
            let mean: f64 = x.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
            let var: f64 =
                x.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>() / n as f64;
            assert!(mean.abs() < 0.05, "{} mean {mean}", dist.name());
            assert!(
                (var.sqrt() - DIST_SIGMA).abs() < 0.08,
                "{} std {}",
                dist.name(),
                var.sqrt()
            );
        }
        let mut x = vec![0f32; n];
        LogitDist::Attention.fill_row(&mut Rng::new(9), &mut x);
        let var: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64;
        assert!((0.3..3.0).contains(&var), "attention var {var}");
    }

    #[test]
    fn heavy_tail_is_heavier_than_gaussian() {
        // excess kurtosis: Laplace has 3, Gaussian 0 — compare the raw
        // fourth moments at matched variance
        let n = 60_000;
        let kurt = |dist: LogitDist| {
            let mut x = vec![0f32; n];
            dist.fill_row(&mut Rng::new(21), &mut x);
            let m2: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / n as f64;
            let m4: f64 = x.iter().map(|&v| (v as f64).powi(4)).sum::<f64>() / n as f64;
            m4 / (m2 * m2)
        };
        let g = kurt(LogitDist::Gaussian);
        let h = kurt(LogitDist::HeavyTail);
        assert!(h > g + 1.0, "gaussian {g}, heavy-tail {h}");
    }

    #[test]
    fn batch_fill_is_row_fill_in_sequence() {
        let mut rng = Rng::new(5);
        let mut batch = vec![0f32; 3 * 64];
        LogitDist::Gaussian.fill_batch(&mut rng, 64, &mut batch);
        let mut rng2 = Rng::new(5);
        let mut rows = vec![0f32; 3 * 64];
        for row in rows.chunks_exact_mut(64) {
            LogitDist::Gaussian.fill_row(&mut rng2, row);
        }
        assert_eq!(batch, rows);
    }
}
