//! Wall-clock micro-benchmark harness (offline substrate — `criterion` is
//! not vendored).  Warmup + timed iterations, reports mean / p50 / p99 /
//! throughput; used by every target in `rust/benches/`.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 { 1.0 / self.mean.as_secs_f64() } else { 0.0 }
    }
}

static QUICK: OnceLock<bool> = OnceLock::new();

/// True when `SOLE_BENCH_QUICK` is set (or [`set_quick_mode`] ran first):
/// every bench target shrinks to a smoke-test length so CI can execute
/// all of them cheaply (the numbers are meaningless in this mode — it
/// exists so bench code cannot rot uncompiled or un-run).  Latched on
/// first query, so the answer is stable for the whole process.
pub fn quick_mode() -> bool {
    *QUICK.get_or_init(|| std::env::var_os("SOLE_BENCH_QUICK").is_some())
}

/// Programmatic opt-in to quick mode, for bench binaries honoring a
/// `--quick` flag.  Must run before the first `quick_mode()` query (a
/// later call is a no-op: the latch is already set).  This replaces the
/// former `std::env::set_var` route, which is unsound in a process that
/// may have running threads.
pub fn set_quick_mode(on: bool) {
    let _ = QUICK.set(on);
}

/// Benchmark `f`, auto-scaling iteration count to ~`target` total runtime.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    let target = if quick_mode() { Duration::from_millis(2).min(target) } else { target };
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((target.as_secs_f64() / once.as_secs_f64()).ceil() as usize).clamp(5, 100_000);
    for _ in 0..(iters / 10).clamp(1, 50) {
        f(); // warmup
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p99: samples[(iters * 99 / 100).min(iters - 1)],
        min: samples[0],
    }
}

/// Print one result row (keeps all bench binaries uniform).
pub fn report(r: &BenchResult) {
    println!(
        "{:<48} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p99  ({} iters, {:>12.1}/s)",
        r.name, r.mean, r.p50, r.p99, r.iters, r.per_sec()
    );
}

/// Run + report in one call; returns the result for further table building.
pub fn run<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_millis(400), f);
    report(&r);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleepless_work() {
        let mut acc = 0u64;
        let r = bench("spin", Duration::from_millis(20), || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.iters >= 5);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.p50 <= r.p99);
        assert!(r.min <= r.p50);
        std::hint::black_box(acc);
    }
}
