//! Minimal JSON parser + emitter (offline substrate — no serde available).
//!
//! Supports the full JSON grammar the build-time Python emits: objects,
//! arrays, strings (with escapes), numbers (f64 + i64 fast path), booleans,
//! null.  Not streaming; designed for manifests and golden-vector files up
//! to a few tens of MB.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All JSON numbers; integer-exact values round-trip through i64 when
    /// possible (needed for 64-bit golden intermediates like `ex2`).
    Num(f64),
    /// Integers that do not fit f64 exactly are kept verbatim.
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access (None when not an object or key missing).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Convenience: `get(key)` then f64.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Json::as_i64)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// Array of i64 (errors collapsed to None).
    pub fn get_vec_i64(&self, key: &str) -> Option<Vec<i64>> {
        self.get(key)?.as_arr()?.iter().map(Json::as_i64).collect()
    }

    pub fn get_vec_f64(&self, key: &str) -> Option<Vec<f64>> {
        self.get(key)?.as_arr()?.iter().map(Json::as_f64).collect()
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { pos: self.i, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| ParseError { pos: self.i, msg: "bad utf8".into() })?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| ParseError { pos: self.i, msg: "bad hex".into() })?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        ParseError { pos: start, msg: "bad utf8".into() }
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_int = true;
        if self.peek() == Some(b'.') {
            is_int = false;
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_int = false;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if is_int {
            if let Ok(i) = txt.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError { pos: start, msg: format!("bad number '{txt}'") })
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Emitting
// ---------------------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl Json {
    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{:.1}", n));
                    } else {
                        out.push_str(&format!("{}", n));
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.emit_into(&mut s);
        s
    }
}

/// Builder conveniences for emitting results from the experiment harness.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

pub fn arr_str(v: &[&str]) -> Json {
    Json::Arr(v.iter().map(|s| Json::Str(s.to_string())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-3.5").unwrap(), Json::Num(-3.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": -1.5e-2}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert!((v.get("d").unwrap().get_f64("e").unwrap() + 0.015).abs() < 1e-12);
    }

    #[test]
    fn big_ints_survive() {
        let v = parse("{\"x\": 9007199254740993}").unwrap(); // 2^53 + 1
        assert_eq!(v.get_i64("x").unwrap(), 9007199254740993);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"s",null,true],"b":{"c":-7}}"#;
        let v = parse(src).unwrap();
        let emitted = v.to_string_compact();
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
