//! SplitMix64 PRNG (offline substrate — `rand` is not vendored).
//!
//! Deterministic, fast, good enough for workload generation, property
//! tests, and the Monte-Carlo error studies.  Not cryptographic.

/// SplitMix64 state.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times for the
    /// coordinator's Poisson request generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fill a slice with N(mu, sigma).
    pub fn fill_normal(&mut self, out: &mut [f32], mu: f64, sigma: f64) {
        for v in out.iter_mut() {
            *v = (mu + sigma * self.normal()) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.range_i64(-5, 7);
            assert!((-5..7).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
